#!/usr/bin/env bash
# CI for the rust crate.
#
# Hard gates (tier-1): cargo build --release && cargo test -q — the
# default feature set is artifact-free; the engine-equivalence suite
# runs on the pure-Rust reference backend.  The PJRT path is
# typechecked against the vendored stub (--features pjrt).
#
# Rustdoc is a hard gate: every module must build docs warning-free
# (RUSTDOCFLAGS="-D warnings" cargo doc --no-deps).
#
# Lint stage: cargo fmt --check and cargo clippy -D warnings are HARD
# gates for the whole crate (the PARD_CI_STRICT escape hatch is gone —
# ROADMAP open item closed with the paged-cache refactor).  Lints are
# skipped only when the component is not installed at all.
#
# Perf gate (opt-in): point PARD_CI_BENCH_BASELINE at a committed
# BENCH_hotpath.json and the script reruns `pard bench --compare` —
# any >10% per-cell tokens/s regression fails CI (q8 cells from the
# report's `quant` section are gated too once the baseline carries
# them; older baselines get a warning, not a failure).
#
# Python mirror gate: when python3 exists, the executable
# layout-equality mirror (python/refsim/hostsim.py, which also replays
# the int8 per-panel quantization of runtime/quant.rs — codes, scales,
# half-away-from-zero rounding, the zero-accumulator panel sweep, and
# the bounded-per-logit-error contract of the host-q8 forward — plus
# the paged block table, prefix-sharing/COW layout, the stochastic
# sampling accept/residual math of coordinator/sampling.rs, and the
# adaptive speculation-policy gates of coordinator/policy.rs — the
# integer K rule, windowed accounting, and the strict-win/dual-mode
# replays from rust/tests/adaptive_policy.rs on the work-costed
# virtual clock — plus the fault-plan mirror of substrate/fault.rs:
# the seeded chaos schedule replays bit-for-bit, the scripted chaos
# serve keeps survivors bit-identical with counters matching the plan
# replay exactly, and budget-0 deadlines expire everything typed,
# mirroring rust/tests/fault_injection.rs) must pass — auto-skipped
# only when python3 is not installed at all.
#
# Usage: ./ci.sh            # build + test + stub typecheck + doc gate
#                           # + whole-crate fmt/clippy hard gates
#                           # + refsim mirror gate (needs python3)
set -euo pipefail
ROOT="$(cd "$(dirname "$0")" && pwd)"
cd "$ROOT/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo check --features pjrt (stub typecheck) =="
cargo check --features pjrt --all-targets

echo "== cargo doc --no-deps (rustdoc warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check (hard gate) =="
    cargo fmt --check
else
    echo "!! rustfmt not installed — skipping cargo fmt --check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings (whole crate, hard gate) =="
    cargo clippy --all-targets -- -D warnings
else
    echo "!! clippy not installed — skipping cargo clippy" >&2
fi

if command -v python3 >/dev/null 2>&1; then
    echo "== python3 python/refsim/hostsim.py (layout-equality gate) =="
    (cd "$ROOT" && python3 python/refsim/hostsim.py)
else
    echo "!! python3 not installed — skipping refsim hostsim mirror" >&2
fi

# Opt-in perf gate against a committed baseline report.
if [ -n "${PARD_CI_BENCH_BASELINE:-}" ]; then
    echo "== pard bench --compare $PARD_CI_BENCH_BASELINE =="
    ./target/release/pard bench --out /tmp/BENCH_ci.json \
        --compare "$PARD_CI_BENCH_BASELINE"
fi

echo "CI OK"
