#!/usr/bin/env bash
# CI for the rust crate.
#
# Hard gates (tier-1): cargo build --release && cargo test -q — the
# default feature set is artifact-free; the engine-equivalence suite
# runs on the pure-Rust reference backend.  The PJRT path is
# typechecked against the vendored stub (--features pjrt).
#
# Rustdoc is a hard gate: every module must build docs warning-free
# (RUSTDOCFLAGS="-D warnings" cargo doc --no-deps).
#
# Lint stage: cargo fmt --check and cargo clippy -D warnings are HARD
# gates for the whole crate (the PARD_CI_STRICT escape hatch is gone —
# ROADMAP open item closed with the paged-cache refactor).  Lints are
# skipped only when the component is not installed at all.
#
# Perf gate (opt-in): point PARD_CI_BENCH_BASELINE at a committed
# BENCH_hotpath.json and the script reruns `pard bench --compare` —
# any >10% per-cell tokens/s regression fails CI (q8 cells from the
# report's `quant` section are gated too once the baseline carries
# them; older baselines get a warning, not a failure).
#
# Python mirror gate: when python3 exists, the executable
# layout-equality mirror (python/refsim/hostsim.py, which also replays
# the int8 per-panel quantization of runtime/quant.rs — codes, scales,
# half-away-from-zero rounding, the zero-accumulator panel sweep, and
# the bounded-per-logit-error contract of the host-q8 forward — plus
# the paged block table, prefix-sharing/COW layout, the stochastic
# sampling accept/residual math of coordinator/sampling.rs, and the
# adaptive speculation-policy gates of coordinator/policy.rs — the
# integer K rule, windowed accounting, and the strict-win/dual-mode
# replays from rust/tests/adaptive_policy.rs on the work-costed
# virtual clock — plus the fault-plan mirror of substrate/fault.rs:
# the seeded chaos schedule replays bit-for-bit, the scripted chaos
# serve keeps survivors bit-identical with counters matching the plan
# replay exactly, and budget-0 deadlines expire everything typed,
# mirroring rust/tests/fault_injection.rs) must pass — auto-skipped
# only when python3 is not installed at all.
#
# Static-analysis gate: `pard audit` (DESIGN.md §11) runs over the
# crate's own sources from the freshly built release binary and fails
# CI on any unwaived violation; python/refsim/auditsim.py is the
# executable mirror (same rules, same waiver syntax, same
# pard-audit-v1 JSON schema) and is a hard gate wherever python3
# exists — including toolchain-less containers where the cargo stages
# cannot run.
#
# Concurrency gates (opt-in — each needs extra tooling the offline
# image does not carry):
#   PARD_CI_LOOM=1  — model-check the worker-pool publish/park
#       handshake (runtime/pool.rs loom_tests).  Needs `cargo add
#       loom --dev` first (local only — never commit the Cargo.toml
#       change; the vendored offline build must stay dependency-free).
#   PARD_CI_MIRI=1  — run the pool + cache test suites under miri
#       (needs `rustup component add miri` on a nightly toolchain).
#   PARD_CI_TSAN=1  — run the pool tests under ThreadSanitizer
#       (needs a nightly toolchain with rust-src).
#
# Usage: ./ci.sh            # build + test + stub typecheck + doc gate
#                           # + whole-crate fmt/clippy hard gates
#                           # + audit gate + refsim mirror gates
set -euo pipefail
ROOT="$(cd "$(dirname "$0")" && pwd)"
cd "$ROOT/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo check --features pjrt (stub typecheck) =="
cargo check --features pjrt --all-targets

echo "== cargo doc --no-deps (rustdoc warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check (hard gate) =="
    cargo fmt --check
else
    echo "!! rustfmt not installed — skipping cargo fmt --check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings (whole crate, hard gate) =="
    cargo clippy --all-targets -- -D warnings
else
    echo "!! clippy not installed — skipping cargo clippy" >&2
fi

echo "== pard audit (static-analysis gate) =="
./target/release/pard audit --root "$ROOT"

if command -v python3 >/dev/null 2>&1; then
    echo "== python3 python/refsim/hostsim.py (layout-equality gate) =="
    (cd "$ROOT" && python3 python/refsim/hostsim.py)
    echo "== python3 python/refsim/auditsim.py (audit mirror gate) =="
    (cd "$ROOT" && python3 python/refsim/auditsim.py)
else
    echo "!! python3 not installed — skipping refsim mirrors" >&2
fi

# Opt-in concurrency gates (see header for the tooling each needs).
if [ -n "${PARD_CI_LOOM:-}" ]; then
    echo "== loom model checks (runtime/pool.rs) =="
    cargo metadata --format-version 1 2>/dev/null \
        | grep -q '"name":"loom"' \
        || { echo "PARD_CI_LOOM=1 but loom is not available — run" \
                  "'cargo add loom --dev' locally first (do NOT" \
                  "commit it)" >&2; exit 1; }
    RUSTFLAGS="--cfg loom" cargo test --release loom_
fi
if [ -n "${PARD_CI_MIRI:-}" ]; then
    echo "== miri (pool + cache suites) =="
    cargo +nightly miri test pool:: cache::
fi
if [ -n "${PARD_CI_TSAN:-}" ]; then
    echo "== ThreadSanitizer (pool suite) =="
    RUSTFLAGS="-Z sanitizer=thread" cargo +nightly test \
        -Z build-std --target x86_64-unknown-linux-gnu pool::
fi

# Opt-in perf gate against a committed baseline report.
if [ -n "${PARD_CI_BENCH_BASELINE:-}" ]; then
    echo "== pard bench --compare $PARD_CI_BENCH_BASELINE =="
    ./target/release/pard bench --out /tmp/BENCH_ci.json \
        --compare "$PARD_CI_BENCH_BASELINE"
fi

echo "CI OK"
