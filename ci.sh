#!/usr/bin/env bash
# CI for the rust crate.
#
# Hard gates (tier-1): cargo build --release && cargo test -q — the
# default feature set is artifact-free; the engine-equivalence suite
# runs on the pure-Rust reference backend.  The PJRT path is
# typechecked against the vendored stub (--features pjrt).
#
# Rustdoc is a hard gate: every module must build docs warning-free
# (RUSTDOCFLAGS="-D warnings" cargo doc --no-deps).
#
# Lint stage: cargo fmt --check and cargo clippy -D warnings are wired
# here but the inherited codebase is not yet lint-clean; they fail the
# script only with PARD_CI_STRICT=1 (see ROADMAP open items —
# rust/src/runtime/ and the bench subsystem are kept clippy-clean as
# the down-payment).
#
# Usage: ./ci.sh            # build + test + stub typecheck + doc gate
#                           # + soft lints
#        PARD_CI_STRICT=1 ./ci.sh   # lints are hard gates too
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo check --features pjrt (stub typecheck) =="
cargo check --features pjrt --all-targets

echo "== cargo doc --no-deps (rustdoc warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

lint_rc=0
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check || lint_rc=1
else
    echo "!! rustfmt not installed — skipping cargo fmt --check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings || lint_rc=1
else
    echo "!! clippy not installed — skipping cargo clippy" >&2
fi

if [ "$lint_rc" -ne 0 ]; then
    if [ "${PARD_CI_STRICT:-0}" = "1" ]; then
        echo "CI FAILED (lints, strict mode)" >&2
        exit 1
    fi
    echo "!! lints reported issues (non-fatal; set PARD_CI_STRICT=1)" >&2
fi

echo "CI OK"
