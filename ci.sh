#!/usr/bin/env bash
# CI for the rust crate.
#
# Hard gates (tier-1): cargo build --release && cargo test -q — the
# default feature set is artifact-free; the engine-equivalence suite
# runs on the pure-Rust reference backend.  The PJRT path is
# typechecked against the vendored stub (--features pjrt).
#
# Rustdoc is a hard gate: every module must build docs warning-free
# (RUSTDOCFLAGS="-D warnings" cargo doc --no-deps).
#
# Lint stage: clippy warnings in rust/src/runtime/ are a HARD gate
# (the serving hot path stays clippy-clean — first step toward
# dropping PARD_CI_STRICT).  Whole-crate cargo fmt --check and cargo
# clippy -D warnings fail the script only with PARD_CI_STRICT=1 (see
# ROADMAP open items).
#
# Perf gate (opt-in): point PARD_CI_BENCH_BASELINE at a committed
# BENCH_hotpath.json and the script reruns `pard bench --compare` —
# any >10% per-cell tokens/s regression fails CI.
#
# Usage: ./ci.sh            # build + test + stub typecheck + doc gate
#                           # + runtime/ clippy gate + soft lints
#        PARD_CI_STRICT=1 ./ci.sh   # all lints are hard gates too
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo check --features pjrt (stub typecheck) =="
cargo check --features pjrt --all-targets

echo "== cargo doc --no-deps (rustdoc warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

lint_rc=0
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check || lint_rc=1
else
    echo "!! rustfmt not installed — skipping cargo fmt --check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy (src/runtime/ warnings are a HARD gate) =="
    clippy_out=$(cargo clippy --all-targets --message-format=short 2>&1) \
        || lint_rc=1
    runtime_warn=$(printf '%s\n' "$clippy_out" \
        | grep -E '^src/runtime/[^ ]*:[0-9]+:[0-9]+: (warning|error)' \
        || true)
    if [ -n "$runtime_warn" ]; then
        printf '%s\n' "$runtime_warn" >&2
        echo "CI FAILED: clippy findings in src/runtime/ (hard gate)" >&2
        exit 1
    fi
    # whole-crate clippy stays a soft gate until the crate is clean —
    # but always show the findings, or strict-mode failures are mute
    if printf '%s\n' "$clippy_out" | grep -qE ': (warning|error)'; then
        printf '%s\n' "$clippy_out" \
            | grep -E ': (warning|error)' >&2 || true
        lint_rc=1
    fi
else
    echo "!! clippy not installed — skipping cargo clippy" >&2
fi

if [ "$lint_rc" -ne 0 ]; then
    if [ "${PARD_CI_STRICT:-0}" = "1" ]; then
        echo "CI FAILED (lints, strict mode)" >&2
        exit 1
    fi
    echo "!! lints reported issues (non-fatal; set PARD_CI_STRICT=1)" >&2
fi

# Opt-in perf gate against a committed baseline report.
if [ -n "${PARD_CI_BENCH_BASELINE:-}" ]; then
    echo "== pard bench --compare $PARD_CI_BENCH_BASELINE =="
    ./target/release/pard bench --out /tmp/BENCH_ci.json \
        --compare "$PARD_CI_BENCH_BASELINE"
fi

echo "CI OK"
