"""Corpus/tokenizer substrate tests."""

import json

import numpy as np
import pytest

from compile import corpus


class TestVocab:
    def test_layout(self):
        assert corpus.BOS == 0 and corpus.EOS == 1
        assert corpus.PAD == 2 and corpus.MASK == 3
        assert len(corpus.DISTINCT_MASKS) == 8

    def test_dump_vocab(self, tmp_path):
        p = tmp_path / "vocab.json"
        corpus.dump_vocab(str(p))
        v = json.loads(p.read_text())
        assert v["vocab_size"] == corpus.VOCAB_SIZE
        assert v["mask"] == 3

    def test_detok_roundtrip_readable(self):
        data = corpus.build_corpus(1, 64, seed=0, tasks=("code",))
        text = corpus.detok(data.tokens[0][: data.valid_len[0]])
        assert "def" in text and "return" in text


class TestGenerators:
    @pytest.mark.parametrize("task", corpus.TASKS)
    def test_determinism(self, task):
        a = corpus.build_corpus(8, 64, seed=42, tasks=(task,))
        b = corpus.build_corpus(8, 64, seed=42, tasks=(task,))
        assert (a.tokens == b.tokens).all()
        assert (a.prompt_len == b.prompt_len).all()

    @pytest.mark.parametrize("task", corpus.TASKS)
    def test_structure(self, task):
        data = corpus.build_corpus(32, 64, seed=1, tasks=(task,))
        for i in range(32):
            v, p = int(data.valid_len[i]), int(data.prompt_len[i])
            assert 0 < p < v <= 64
            assert data.tokens[i, 0] == corpus.BOS
            row = data.tokens[i]
            assert (row[:v] != corpus.PAD).all()
            assert (row[v:] == corpus.PAD).all()
            # the generation region is non-trivial
            assert v - p >= 3

    def test_tokens_in_vocab(self):
        data = corpus.build_corpus(64, 64, seed=2)
        assert data.tokens.min() >= 0
        assert data.tokens.max() < corpus.VOCAB_SIZE
        # no mask tokens in natural text
        assert not np.isin(data.tokens,
                           [corpus.MASK] + corpus.DISTINCT_MASKS).any()

    def test_mix(self):
        data = corpus.build_corpus(300, 64, seed=3)
        counts = {t: data.task.count(t) for t in corpus.TASKS}
        assert all(c > 50 for c in counts.values())

    def test_dump_prompts(self, tmp_path):
        data = corpus.build_eval_prompts("gsm", 8, seed=9, seq_len=64)
        p = tmp_path / "prompts.json"
        corpus.dump_prompts(data, str(p))
        rows = json.loads(p.read_text())
        assert len(rows) == 8
        for r in rows:
            assert r["task"] == "gsm"
            assert len(r["prompt"]) > 0 and len(r["reference"]) > 0

    def test_eval_disjoint_from_train(self):
        """Eval prompts (seed 1234+) differ from the training corpus."""
        train = corpus.build_corpus(64, 64, seed=0, tasks=("code",))
        ev = corpus.build_eval_prompts("code", 64, seed=1234, seq_len=64)
        same = 0
        for i in range(64):
            if any((train.tokens[j] == ev.tokens[i]).all()
                   for j in range(64)):
                same += 1
        assert same < 32  # grammar collisions possible, identity not


class TestArLabels:
    def test_labels(self):
        from compile.train.pretrain import ar_labels
        toks = np.array([[0, 10, 11, 12, 2, 2]], dtype=np.int32)
        lab = ar_labels(toks, np.array([4]))
        assert list(lab[0]) == [10, 11, 12, -1, -1, -1]
