"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The CORE correctness signal for the serving stack: every HLO artifact the
rust coordinator executes contains these kernels, so allclose-vs-ref here
(plus the hypothesis shape/position sweeps) is what certifies the numeric
path end to end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (cached_attention,
                                       vmem_footprint_bytes)
from compile.kernels.ref import cached_attention_ref, swiglu_ref
from compile.kernels.swiglu import swiglu


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def assert_close(a, b, atol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# cached_attention
# ---------------------------------------------------------------------------


class TestCachedAttention:
    def test_decode_shape(self):
        rng = np.random.default_rng(0)
        q = rand(rng, 1, 1, 4, 32)
        kc, vc = rand(rng, 1, 128, 4, 32), rand(rng, 1, 128, 4, 32)
        qpos = jnp.array([[17]], jnp.int32)
        out = cached_attention(q, kc, vc, qpos)
        assert out.shape == (1, 1, 4, 32)
        assert_close(out, cached_attention_ref(q, kc, vc, qpos))

    def test_verify_window(self):
        """T=K+1 verify-phase queries at consecutive positions."""
        rng = np.random.default_rng(1)
        q = rand(rng, 2, 9, 4, 32)
        kc, vc = rand(rng, 2, 128, 4, 32), rand(rng, 2, 128, 4, 32)
        qpos = jnp.stack([jnp.arange(40, 49), jnp.arange(3, 12)]
                         ).astype(jnp.int32)
        assert_close(cached_attention(q, kc, vc, qpos),
                     cached_attention_ref(q, kc, vc, qpos))

    def test_position_zero_attends_only_slot_zero(self):
        """A query at position 0 must see exactly cache slot 0."""
        rng = np.random.default_rng(2)
        q = rand(rng, 1, 1, 2, 16)
        kc, vc = rand(rng, 1, 64, 2, 16), rand(rng, 1, 64, 2, 16)
        qpos = jnp.zeros((1, 1), jnp.int32)
        out = cached_attention(q, kc, vc, qpos)
        # softmax over one slot = that slot's value exactly
        assert_close(out[0, 0], vc[0, 0], atol=1e-6)

    def test_last_slot(self):
        rng = np.random.default_rng(3)
        s = 128
        q = rand(rng, 1, 2, 2, 16)
        kc, vc = rand(rng, 1, s, 2, 16), rand(rng, 1, s, 2, 16)
        qpos = jnp.array([[s - 2, s - 1]], jnp.int32)
        assert_close(cached_attention(q, kc, vc, qpos),
                     cached_attention_ref(q, kc, vc, qpos))

    def test_mask_independence(self):
        """Slots beyond q_pos must not influence the output (garbage-proof:
        the L3 cache holds stale speculative entries there)."""
        rng = np.random.default_rng(4)
        q = rand(rng, 1, 3, 4, 32)
        kc, vc = rand(rng, 1, 128, 4, 32), rand(rng, 1, 128, 4, 32)
        qpos = jnp.array([[10, 11, 12]], jnp.int32)
        out1 = cached_attention(q, kc, vc, qpos)
        # trash everything after slot 12
        kc2 = kc.at[:, 13:].set(1e4)
        vc2 = vc.at[:, 13:].set(-1e4)
        out2 = cached_attention(q, kc2, vc2, qpos)
        assert_close(out1, out2, atol=1e-6)

    def test_nonuniform_positions_per_row(self):
        """PARD-draft layout: reals then masks, arbitrary positions."""
        rng = np.random.default_rng(5)
        q = rand(rng, 2, 16, 4, 32)
        kc, vc = rand(rng, 2, 256, 4, 32), rand(rng, 2, 256, 4, 32)
        qpos = jnp.asarray(rng.integers(0, 256, size=(2, 16)), jnp.int32)
        assert_close(cached_attention(q, kc, vc, qpos),
                     cached_attention_ref(q, kc, vc, qpos))

    @pytest.mark.parametrize("block_kv", [32, 64, 128])
    def test_block_shapes_equivalent(self, block_kv):
        """The perf-tunable tile size must not change numerics."""
        rng = np.random.default_rng(6)
        q = rand(rng, 1, 4, 2, 16)
        kc, vc = rand(rng, 1, 128, 2, 16), rand(rng, 1, 128, 2, 16)
        qpos = jnp.array([[5, 6, 7, 8]], jnp.int32)
        assert_close(cached_attention(q, kc, vc, qpos, block_kv=block_kv),
                     cached_attention_ref(q, kc, vc, qpos))

    def test_bad_block_size_raises(self):
        rng = np.random.default_rng(7)
        q = rand(rng, 1, 1, 2, 16)
        kc, vc = rand(rng, 1, 100, 2, 16), rand(rng, 1, 100, 2, 16)
        with pytest.raises(ValueError):
            cached_attention(q, kc, vc, jnp.zeros((1, 1), jnp.int32))

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 3),
        t=st.integers(1, 12),
        h=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([8, 16, 32]),
        s=st.sampled_from([64, 128, 192]),
        seed=st.integers(0, 2 ** 16),
    )
    def test_hypothesis_sweep(self, b, t, h, d, s, seed):
        """Property: kernel == oracle across the serving shape space."""
        rng = np.random.default_rng(seed)
        q = rand(rng, b, t, h, d)
        kc, vc = rand(rng, b, s, h, d), rand(rng, b, s, h, d)
        qpos = jnp.asarray(rng.integers(0, s, size=(b, t)), jnp.int32)
        assert_close(cached_attention(q, kc, vc, qpos),
                     cached_attention_ref(q, kc, vc, qpos))


# ---------------------------------------------------------------------------
# swiglu
# ---------------------------------------------------------------------------


class TestSwiglu:
    def test_basic(self):
        rng = np.random.default_rng(0)
        x = rand(rng, 4, 64) * 0.5
        w1, w3 = rand(rng, 64, 256) * 0.1, rand(rng, 64, 256) * 0.1
        w2 = rand(rng, 256, 64) * 0.1
        assert_close(swiglu(x, w1, w2, w3), swiglu_ref(x, w1, w2, w3),
                     atol=1e-5)

    @pytest.mark.parametrize("block_ff", [64, 128, 256])
    def test_block_sweep(self, block_ff):
        rng = np.random.default_rng(1)
        x = rand(rng, 2, 32) * 0.5
        w1, w3 = rand(rng, 32, 256) * 0.1, rand(rng, 32, 256) * 0.1
        w2 = rand(rng, 256, 32) * 0.1
        assert_close(swiglu(x, w1, w2, w3, block_ff=block_ff),
                     swiglu_ref(x, w1, w2, w3), atol=1e-5)

    def test_bad_block_raises(self):
        rng = np.random.default_rng(2)
        x = rand(rng, 2, 32)
        w1 = rand(rng, 32, 100)
        with pytest.raises(ValueError):
            swiglu(x, w1, rand(rng, 100, 32), rand(rng, 32, 100))

    @settings(max_examples=15, deadline=None)
    @given(t=st.integers(1, 16), d=st.sampled_from([16, 32, 64]),
           f=st.sampled_from([128, 256]), seed=st.integers(0, 2 ** 16))
    def test_hypothesis_sweep(self, t, d, f, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, t, d) * 0.5
        w1, w3 = rand(rng, d, f) * 0.1, rand(rng, d, f) * 0.1
        w2 = rand(rng, f, d) * 0.1
        assert_close(swiglu(x, w1, w2, w3), swiglu_ref(x, w1, w2, w3),
                     atol=1e-5)


# ---------------------------------------------------------------------------
# VMEM model (the L1 profiling surface)
# ---------------------------------------------------------------------------


class TestVmemModel:
    def test_fits_vmem(self):
        """The default serving shapes must fit a 16 MiB VMEM budget."""
        for t in (1, 16, 32):
            fp = vmem_footprint_bytes(t=t, s=256, d=32, block_kv=64)
            assert fp["total"] < 16 * 2 ** 20

    def test_hbm_reads_k_independent(self):
        """Table 6 analogue: one cache pass regardless of draft K."""
        a = vmem_footprint_bytes(t=2, s=256, d=32, block_kv=64)
        b = vmem_footprint_bytes(t=16, s=256, d=32, block_kv=64)
        assert a["hbm_reads"] == b["hbm_reads"]
