"""Algorithm 1 (COD data processing) invariants — paper §3.2.2.

Property-based checks that the expanded training batch obeys the paper's
constraints: Eq. 9/10/11 retention counts, chain-nested retention (the
"preceding KV cache is complete" rule), the Fig. 4 attention pattern, and
Eq. 8 loss weighting.
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import corpus
from compile.train.pard import (PardSpec, anchor_depths, build_pard_batch,
                                VARIANTS, MAIN_VARIANT)


def make_data(b, n, seed):
    return corpus.build_corpus(b, n, seed=seed)


class TestRetention:
    def test_eq9_counts(self):
        spec = PardSpec(k=8, r=0.5, r_min=0.0)
        n = 64
        for k in range(1, 9):
            assert spec.retained(n, k) == math.ceil(n * 0.5 ** (k - 1))

    def test_eq11_floor(self):
        spec = PardSpec(k=8, r=0.5, r_min=0.2)
        n = 100
        assert spec.retained(n, 8) == 20  # floor kicks in

    def test_eq10_bound(self):
        """N_COD < N / (1 - r) when r_min = 0 (paper Eq. 10)."""
        spec = PardSpec(k=8, r=0.5, r_min=0.0)
        n = 128
        total = sum(spec.retained(n, k) for k in range(1, 9))
        assert total < n / (1 - 0.5) + spec.k  # ceil slack

    def test_cod_token_ratio_near_3x(self):
        """Paper: r=0.7, r_min=0.2 gives ~3x training-token reduction."""
        spec = VARIANTS[MAIN_VARIANT]
        n = 64
        ratio = spec.full_tokens(n) / spec.expanded_len(n)
        assert 2.0 < ratio < 3.2

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(8, 128), k=st.integers(2, 8),
           r=st.floats(0.2, 1.0), r_min=st.floats(0.0, 0.5),
           seed=st.integers(0, 2 ** 16))
    def test_depths_match_retention(self, n, k, r, r_min, seed):
        spec = PardSpec(k=k, r=r, r_min=r_min)
        rng = np.random.default_rng(seed)
        depth = anchor_depths(n, spec, rng)
        for sub_k in range(2, k + 1):
            assert int((depth >= sub_k).sum()) == spec.retained(n, sub_k)


class TestBatchInvariants:
    @pytest.fixture(scope="class")
    def batch(self):
        spec = VARIANTS[MAIN_VARIANT]
        data = make_data(4, 64, seed=3)
        rng = np.random.default_rng(3)
        return (build_pard_batch(data.tokens, data.valid_len, spec, rng),
                spec, data)

    def test_shapes_fixed(self, batch):
        b, spec, data = batch
        m = spec.expanded_len(64)
        assert b["tokens"].shape == (4, m)
        assert b["attn"].shape == (4, m, m)

    def test_mask_tokens_are_masks(self, batch):
        b, spec, data = batch
        n = 64
        ext = b["tokens"][:, n:]
        assert set(np.unique(ext)) <= {corpus.MASK}

    def test_chain_positions_consecutive(self, batch):
        """tau(k, a) sits at position a+k-1: within one anchor's chain the
        positions are consecutive and start one past an existing real."""
        b, spec, data = batch
        n = 64
        for i in range(4):
            pos = b["pos_ids"][i, n:]
            att = b["attn"][i, n:, :]
            for j in range(len(pos)):
                reals = att[j, :n]
                a = int(reals.nonzero()[0].max())  # last real attended
                chain = att[j, n:].nonzero()[0]
                # chain slots (incl self) occupy positions a+1 .. pos[j]
                chain_pos = sorted(int(pos[c]) for c in chain)
                assert chain_pos == list(range(a + 1, int(pos[j]) + 1))

    def test_kv_completeness(self, batch):
        """Paper's COD constraint: every retained mask query attends a
        complete prefix — all k-1 earlier chain members exist."""
        b, spec, data = batch
        n = 64
        for i in range(4):
            att = b["attn"][i]
            pos = b["pos_ids"][i]
            for j in range(n, att.shape[0]):
                reals = att[j, :n].nonzero()[0]
                a = int(reals.max())
                k = int(pos[j]) - a + 1  # subtask index
                n_chain = int(att[j, n:].sum())
                assert n_chain == k - 1, (j, k, n_chain)
                # and the real prefix is exactly 0..a
                assert list(reals) == list(range(a + 1))

    def test_labels_are_future_tokens(self, batch):
        b, spec, data = batch
        n = 64
        for i in range(4):
            v = int(data.valid_len[i])
            pos = b["pos_ids"][i]
            lab = b["labels"][i]
            for j in range(n, len(lab)):
                if lab[j] >= 0:
                    # mask standing at position p predicts x_{p+1}
                    assert lab[j] == data.tokens[i, int(pos[j]) + 1]
                    assert int(pos[j]) + 1 < v

    def test_weights_sum_to_one(self, batch):
        b, _, _ = batch
        assert abs(float(b["weights"].sum()) - 1.0) < 1e-5

    def test_eq8_per_subtask_normalization(self, batch):
        """Within one sample, each populated subtask carries equal total
        weight (the per-subtask mean of Eq. 8)."""
        b, spec, data = batch
        n = 64
        for i in range(4):
            pos = b["pos_ids"][i]
            att = b["attn"][i]
            w = b["weights"][i]
            lab = b["labels"][i]
            per_k: dict[int, float] = {}
            for j in range(len(w)):
                if lab[j] < 0:
                    continue
                if j < n:
                    k = 1
                else:
                    a = int(att[j, :n].nonzero()[0].max())
                    k = int(pos[j]) - a + 1
                per_k[k] = per_k.get(k, 0.0) + float(w[j])
            totals = list(per_k.values())
            assert max(totals) - min(totals) < 1e-5

    def test_distinct_mask_variant(self):
        spec = PardSpec(k=4, r=0.7, r_min=0.2, shared=False)
        data = make_data(2, 32, seed=5)
        rng = np.random.default_rng(5)
        b = build_pard_batch(data.tokens, data.valid_len, spec, rng)
        ext = b["tokens"][:, 32:]
        used = set(np.unique(ext))
        assert used <= set(corpus.DISTINCT_MASKS)
        assert len(used) > 1  # multiple offsets materialized

    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from([16, 32, 64]), k=st.integers(2, 8),
           seed=st.integers(0, 2 ** 12))
    def test_no_future_leakage(self, n, k, seed):
        """No query may attend any slot whose position exceeds its own —
        the train==serve causality property."""
        spec = PardSpec(k=k, r=0.6, r_min=0.1)
        data = make_data(2, n, seed=seed)
        rng = np.random.default_rng(seed)
        b = build_pard_batch(data.tokens, data.valid_len, spec, rng)
        pos = b["pos_ids"]
        att = b["attn"]
        for i in range(2):
            q, s = np.nonzero(att[i])
            assert (pos[i][s] <= pos[i][q]).all()
