"""L2 correctness: the serving path (`extend`) against the training path.

The serving==training equivalence is what makes "train == serve" claims
real: incremental cached `extend` calls must reproduce the full-sequence
`train_forward` logits, and the PARD mask layout at inference must match
the attention pattern PARD training teaches (paper Fig. 3/4).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model


CFG = model.ModelConfig("tiny", d_model=64, n_layers=2, n_heads=2,
                        d_head=32, d_ff=128, s_max=128)


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), CFG)


def toks_of(rng, n):
    return jnp.asarray(rng.integers(12, corpus.VOCAB_SIZE, size=(1, n)),
                       jnp.int32)


class TestExtendVsTrainForward:
    def test_prefill_matches_full_forward(self, params):
        rng = np.random.default_rng(0)
        toks = toks_of(rng, 12)
        full = model.train_forward(params, CFG, toks)
        ck, cv = model.empty_cache(CFG, 1)
        pos = jnp.arange(12, dtype=jnp.int32)[None]
        logits, _, _ = model.extend(params, CFG, toks, pos, ck, cv)
        np.testing.assert_allclose(np.asarray(full), np.asarray(logits),
                                   atol=3e-5, rtol=1e-4)

    def test_incremental_decode_matches(self, params):
        """prefill 8 then decode 4 one-at-a-time == full forward on 12."""
        rng = np.random.default_rng(1)
        toks = toks_of(rng, 12)
        full = model.train_forward(params, CFG, toks)
        ck, cv = model.empty_cache(CFG, 1)
        pos = jnp.arange(8, dtype=jnp.int32)[None]
        logits, ck, cv = model.extend(params, CFG, toks[:, :8], pos, ck, cv)
        np.testing.assert_allclose(np.asarray(full[:, :8]),
                                   np.asarray(logits), atol=3e-5, rtol=1e-4)
        for i in range(8, 12):
            pos = jnp.array([[i]], jnp.int32)
            step, ck, cv = model.extend(params, CFG, toks[:, i:i + 1],
                                        pos, ck, cv)
            np.testing.assert_allclose(np.asarray(full[:, i]),
                                       np.asarray(step[:, 0]),
                                       atol=5e-5, rtol=1e-4)

    def test_verify_window_matches(self, params):
        """prefill 6 + one verify call over 6 tokens == full forward."""
        rng = np.random.default_rng(2)
        toks = toks_of(rng, 12)
        full = model.train_forward(params, CFG, toks)
        ck, cv = model.empty_cache(CFG, 1)
        pos = jnp.arange(6, dtype=jnp.int32)[None]
        _, ck, cv = model.extend(params, CFG, toks[:, :6], pos, ck, cv)
        pos2 = jnp.arange(6, 12, dtype=jnp.int32)[None]
        logits, ck, cv = model.extend(params, CFG, toks[:, 6:], pos2, ck, cv)
        np.testing.assert_allclose(np.asarray(full[:, 6:]),
                                   np.asarray(logits), atol=5e-5, rtol=1e-4)

    def test_rewind_semantics(self, params):
        """Speculative rewind: stale entries past cur_len are overwritten
        by the next extend, so a rejected-then-rewritten cache gives
        identical logits to a never-polluted one."""
        rng = np.random.default_rng(3)
        toks = toks_of(rng, 10)
        ck, cv = model.empty_cache(CFG, 1)
        pos = jnp.arange(6, dtype=jnp.int32)[None]
        _, ck, cv = model.extend(params, CFG, toks[:, :6], pos, ck, cv)
        # speculative junk at positions 6..9 (rejected draft)
        junk = jnp.asarray(rng.integers(12, 500, size=(1, 4)), jnp.int32)
        pos_j = jnp.arange(6, 10, dtype=jnp.int32)[None]
        _, ck_j, cv_j = model.extend(params, CFG, junk, pos_j, ck, cv)
        # rewind == just reuse positions: overwrite with the real tokens
        pos_r = jnp.arange(6, 10, dtype=jnp.int32)[None]
        l_clean, _, _ = model.extend(params, CFG, toks[:, 6:], pos_r, ck, cv)
        l_rewind, _, _ = model.extend(params, CFG, toks[:, 6:], pos_r,
                                      ck_j, cv_j)
        np.testing.assert_allclose(np.asarray(l_clean),
                                   np.asarray(l_rewind), atol=1e-5)

    def test_parked_pads_do_not_perturb(self, params):
        """Pad tokens parked past the live window must not change real
        logits — the L3 bucket-padding contract (DESIGN.md §7)."""
        rng = np.random.default_rng(4)
        toks = toks_of(rng, 6)
        ck, cv = model.empty_cache(CFG, 1)
        pos = jnp.arange(6, dtype=jnp.int32)[None]
        base, _, _ = model.extend(params, CFG, toks, pos, ck, cv)
        # same call padded to T=16 with pads parked at position 30
        padded = jnp.concatenate(
            [toks, jnp.full((1, 10), corpus.PAD, jnp.int32)], axis=1)
        pos_p = jnp.concatenate(
            [pos, jnp.full((1, 10), 30, jnp.int32)], axis=1)
        lp, _, _ = model.extend(params, CFG, padded, pos_p, ck, cv)
        np.testing.assert_allclose(np.asarray(base),
                                   np.asarray(lp[:, :6]), atol=1e-5)

    def test_pallas_vs_ref_path(self, params):
        rng = np.random.default_rng(5)
        toks = toks_of(rng, 8)
        ck, cv = model.empty_cache(CFG, 1)
        pos = jnp.arange(8, dtype=jnp.int32)[None]
        a, _, _ = model.extend(params, CFG, toks, pos, ck, cv,
                               use_pallas=True)
        b, _, _ = model.extend(params, CFG, toks, pos, ck, cv,
                               use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                                   rtol=1e-4)


class TestPardLayoutSemantics:
    """The inference-side PARD layout: [reals, <mask>*(K-1)] in one pass."""

    def test_mask_queries_predict_future_offsets(self, params):
        """Mask at position p yields a distribution over x_{p+1}; shapes
        and layout must round-trip regardless of mask count."""
        rng = np.random.default_rng(6)
        prefix = toks_of(rng, 5)
        ck, cv = model.empty_cache(CFG, 1)
        pos = jnp.arange(5, dtype=jnp.int32)[None]
        _, ck, cv = model.extend(params, CFG, prefix, pos, ck, cv)
        k = 4
        last = prefix[:, -1:]  # re-feed pattern uses committed reals
        draft_toks = jnp.concatenate(
            [toks_of(rng, 1),
             jnp.full((1, k - 1), corpus.MASK, jnp.int32)], axis=1)
        draft_pos = jnp.arange(5, 5 + k, dtype=jnp.int32)[None]
        logits, ck, cv = model.extend(params, CFG, draft_toks, draft_pos,
                                      ck, cv)
        assert logits.shape == (1, k, corpus.VOCAB_SIZE)

    def test_mask_kv_never_visible_after_overwrite(self, params):
        """After rust re-feeds accepted reals over mask slots, logits match
        a trajectory that never wrote masks at all."""
        rng = np.random.default_rng(7)
        seq = toks_of(rng, 10)
        # trajectory A: clean prefill 8
        ck_a, cv_a = model.empty_cache(CFG, 1)
        pos8 = jnp.arange(8, dtype=jnp.int32)[None]
        _, ck_a, cv_a = model.extend(params, CFG, seq[:, :8], pos8,
                                     ck_a, cv_a)
        # trajectory B: prefill 5, pard-draft writes masks at 5..7,
        # then reals 5..7 re-fed (accepted)
        ck_b, cv_b = model.empty_cache(CFG, 1)
        pos5 = jnp.arange(5, dtype=jnp.int32)[None]
        _, ck_b, cv_b = model.extend(params, CFG, seq[:, :5], pos5,
                                     ck_b, cv_b)
        masks = jnp.full((1, 3), corpus.MASK, jnp.int32)
        mpos = jnp.arange(5, 8, dtype=jnp.int32)[None]
        _, ck_b, cv_b = model.extend(params, CFG, masks, mpos, ck_b, cv_b)
        _, ck_b, cv_b = model.extend(params, CFG, seq[:, 5:8], mpos,
                                     ck_b, cv_b)
        # both caches now produce identical decode logits at position 8
        step = seq[:, 8:9]
        p8 = jnp.array([[8]], jnp.int32)
        la, _, _ = model.extend(params, CFG, step, p8, ck_a, cv_a)
        lb, _, _ = model.extend(params, CFG, step, p8, ck_b, cv_b)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-5)


class TestEagleHead:
    def test_shapes_and_chaining(self):
        ecfg = model.eagle_config_for(CFG)
        head = model.eagle_init(jax.random.PRNGKey(1), ecfg)
        rng = np.random.default_rng(8)
        b, t = 1, 4
        hidden = jnp.asarray(rng.normal(size=(b, t, CFG.d_model)),
                             jnp.float32)
        toks = toks_of(rng, t)
        pos = jnp.arange(t, dtype=jnp.int32)[None]
        shape = (1, b, ecfg.s_max, ecfg.n_heads, ecfg.d_head)
        ck = jnp.zeros(shape, jnp.float32)
        cv = jnp.zeros(shape, jnp.float32)
        logits, ck, cv, hh = model.eagle_extend(head, ecfg, hidden, toks,
                                                pos, ck, cv)
        assert logits.shape == (b, t, corpus.VOCAB_SIZE)
        assert hh.shape == (b, t, CFG.d_model)
        # chained draft step re-consumes the head hidden
        l2, ck, cv, _ = model.eagle_extend(
            head, ecfg, hh[:, -1:], toks[:, -1:],
            jnp.array([[t]], jnp.int32), ck, cv)
        assert l2.shape == (b, 1, corpus.VOCAB_SIZE)

    def test_train_forward_shape(self):
        ecfg = model.eagle_config_for(CFG)
        head = model.eagle_init(jax.random.PRNGKey(1), ecfg)
        rng = np.random.default_rng(9)
        hidden = jnp.asarray(rng.normal(size=(2, 6, CFG.d_model)),
                             jnp.float32)
        toks = jnp.asarray(rng.integers(12, 500, size=(2, 6)), jnp.int32)
        logits = model.eagle_train_forward(head, ecfg, hidden, toks)
        assert logits.shape == (2, 6, corpus.VOCAB_SIZE)


class TestConfigs:
    def test_family_param_counts_monotone(self):
        sizes = [model.FAMILY[n].n_params for n in
                 ("draft-s", "target-m", "target-l", "target-xl")]
        assert sizes == sorted(sizes)
        # draft:target ratios bracket the paper's 0.5B:7B .. 1B:8B regimes
        assert sizes[-1] / sizes[0] > 10

    def test_s_max_divisible_by_block(self):
        for cfg in model.FAMILY.values():
            assert cfg.s_max % 64 == 0
