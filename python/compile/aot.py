"""AOT export: lower every serving entry point to HLO text + manifest.

Python's last act: after training, each model is lowered per (batch, T)
bucket to **HLO text** (xla_extension 0.5.1 rejects jax>=0.5 serialized
protos — 64-bit instruction ids; the text parser reassigns ids, see
/opt/xla-example/README.md).  Weights stay in ``.npz`` checkpoints (keys
p000… in tree-flatten order — the exact HLO parameter order) and
``manifest.json`` tells the rust runtime what exists.

Two executables per (model, b, t) keep the KV cache device-resident
(PJRT returns tuples as a single opaque buffer, so anything returned in a
tuple must round-trip through the host — the cache must therefore never
be a tuple member):

* ``fwd``   (params…, tokens, pos, cache[2,L,B,S,H,D]) ->
            tuple(logits, k_new[L,B,T,H,D], v_new[, hidden]) — reads the
            cache buffer in place; only small outputs cross to the host.
* ``commit`` (cache, k_new, v_new, pos) -> cache'  — single-array output
            (lowered with return_tuple=False), so the updated cache stays
            a plain device buffer.  Speculative rewind = rust redirects
            rejected columns to the reserved garbage slot S_max-1.

The rust coordinator composes prefill / decode / verify / PARD-parallel-
draft purely by choosing (tokens, pos_ids) layouts and a T bucket — see
DESIGN.md §7.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import corpus, model
from .train import common
from .train.pard import VARIANTS, MAIN_VARIANT

# T buckets: 1 = decode / AR+VSD draft step; 2..32 = verify (K+1) and PARD
# parallel draft (2K) for K_infer in 1..16; 32 = prefill (prompts are <32).
# 10 and 12 exist purely for §Perf: K=8 verify needs T=9 and the typical
# PARD draft call needs 10-12 — on a compute-bound CPU backend, padding
# those up to 16 wastes ~40% of the dominant verify FLOPs.
T_FULL = (1, 2, 4, 8, 10, 12, 16, 24, 32, 48, 64)
T_BATCH = (1, 10, 12, 16, 32)
BATCHES = (2, 4, 8, 16)


def to_hlo_text(lowered, return_tuple: bool) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple)
    return comp.as_hlo_text()


def spec_like(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def cache_spec(n_layers: int, b: int, s_max: int, h: int, dh: int):
    return jax.ShapeDtypeStruct((2, n_layers, b, s_max, h, dh), jnp.float32)


def kv_new_spec(n_layers: int, b: int, t: int, h: int, dh: int):
    return jax.ShapeDtypeStruct((n_layers, b, t, h, dh), jnp.float32)


def _gather_new(ck2, pos):
    """[L,B,S,H,D] cache, [B,T] pos -> [L,B,T,H,D] this call's K or V."""
    bidx = jnp.arange(pos.shape[0])[:, None]
    return ck2[:, bidx, pos]


def lower_fwd(params, cfg: model.ModelConfig, b: int, t: int,
              hidden: bool) -> str:
    def f(p, tokens, pos, cache):
        out = model.extend(p, cfg, tokens, pos, cache[0], cache[1],
                           return_hidden=hidden)
        logits, ck2, cv2 = out[0], out[1], out[2]
        k_new = _gather_new(ck2, pos)
        v_new = _gather_new(cv2, pos)
        if hidden:
            return logits, k_new, v_new, out[3]
        return logits, k_new, v_new

    tok = jax.ShapeDtypeStruct((b, t), jnp.int32)
    cache = cache_spec(cfg.n_layers, b, cfg.s_max, cfg.n_heads, cfg.d_head)
    lowered = jax.jit(f).lower(spec_like(params), tok, tok, cache)
    return to_hlo_text(lowered, return_tuple=True)


def lower_commit(n_layers: int, b: int, t: int, s_max: int, h: int,
                 dh: int) -> str:
    def g(cache, k_new, v_new, pos):
        bidx = jnp.arange(b)[:, None]
        ck = cache[0].at[:, bidx, pos].set(k_new)
        cv = cache[1].at[:, bidx, pos].set(v_new)
        return jnp.stack([ck, cv])

    cache = cache_spec(n_layers, b, s_max, h, dh)
    kv = kv_new_spec(n_layers, b, t, h, dh)
    pos = jax.ShapeDtypeStruct((b, t), jnp.int32)
    lowered = jax.jit(g).lower(cache, kv, kv, pos)
    return to_hlo_text(lowered, return_tuple=False)


def lower_eagle_fwd(head, ecfg: model.EagleConfig, b: int, t: int) -> str:
    def f(p, hid, tokens, pos, cache):
        logits, ck2, cv2, hh = model.eagle_extend(p, ecfg, hid, tokens,
                                                  pos, cache[0], cache[1])
        return logits, _gather_new(ck2, pos), _gather_new(cv2, pos), hh

    tok = jax.ShapeDtypeStruct((b, t), jnp.int32)
    hid = jax.ShapeDtypeStruct((b, t, ecfg.d_model), jnp.float32)
    cache = cache_spec(1, b, ecfg.s_max, ecfg.n_heads, ecfg.d_head)
    lowered = jax.jit(f).lower(spec_like(head), hid, tok, tok, cache)
    return to_hlo_text(lowered, return_tuple=True)


def _write(path: str, text_fn) -> None:
    if not os.path.exists(path):
        with open(path, "w") as f:
            f.write(text_fn())
    print(f"  {os.path.basename(path)}", flush=True)


def export_commits(out: str, arch_name: str, n_layers: int, s_max: int,
                   h: int, dh: int, grid, manifest: dict) -> None:
    """Commit executables are weight-independent: one set per architecture,
    shared by every variant (pard-* reuse draft-s commits)."""
    entries = []
    for b, t in grid:
        fname = f"hlo/commit_{arch_name}__b{b}_t{t}.hlo.txt"
        _write(f"{out}/{fname}",
               lambda b=b, t=t: lower_commit(n_layers, b, t, s_max, h, dh))
        entries.append({"b": b, "t": t, "file": fname})
    manifest["commits"][arch_name] = entries


def export_model(out: str, name: str, cfg: model.ModelConfig, params,
                 grid, hidden: bool, base: str, manifest: dict) -> None:
    entries = []
    suffix = "_h" if hidden else ""
    for b, t in grid:
        fname = f"hlo/{name}{suffix}__b{b}_t{t}.hlo.txt"
        _write(f"{out}/{fname}",
               lambda b=b, t=t: lower_fwd(params, cfg, b, t, hidden))
        entries.append({"b": b, "t": t, "file": fname})
    manifest["models"][f"{name}{suffix}"] = {
        "kind": "lm", "hidden": hidden, "arch": base,
        "weights": f"ckpt/{name}.npz",
        "config": cfg.to_dict(), "entries": entries,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--eval-prompts", type=int, default=96)
    ap.add_argument("--eval-seed", type=int, default=1234)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--skip-batch", action="store_true",
                    help="only export b=1 entries (fast dev builds)")
    args = ap.parse_args()
    out = args.out
    os.makedirs(f"{out}/hlo", exist_ok=True)

    manifest = {
        "vocab_size": corpus.VOCAB_SIZE,
        "bos": corpus.BOS, "eos": corpus.EOS, "pad": corpus.PAD,
        "mask": corpus.MASK, "distinct_masks": corpus.DISTINCT_MASKS,
        "models": {}, "commits": {}, "prompts": {}, "pard_variants": {},
        "main_pard": MAIN_VARIANT,
    }

    b1_grid = [(1, t) for t in T_FULL]
    batch_grid = [] if args.skip_batch else [
        (b, t) for b in BATCHES for t in T_BATCH]

    # --- family members (AR models: targets + the VSD draft) -------------
    for name, cfg in model.FAMILY.items():
        ck = f"{out}/ckpt/{name}.npz"
        if not os.path.exists(ck):
            raise SystemExit(f"missing checkpoint {ck}; run pretrain first")
        params = common.load_ckpt(
            ck, model.init_params(jax.random.PRNGKey(0), cfg))
        grid = list(b1_grid)
        if name in ("draft-s", "target-l"):
            grid += batch_grid
        print(f"[aot] {name}", flush=True)
        export_model(out, name, cfg, params, grid, hidden=False,
                     base=name, manifest=manifest)
        export_commits(out, name, cfg.n_layers, cfg.s_max, cfg.n_heads,
                       cfg.d_head, grid, manifest)
        if name == "target-l":  # hidden variant for the EAGLE baseline
            print(f"[aot] {name} (hidden)", flush=True)
            export_model(out, name, cfg, params, grid, hidden=True,
                         base=name, manifest=manifest)

    # --- PARD-adapted drafts (main + any trained ablation variants) ------
    dcfg = model.FAMILY["draft-s"]
    template = model.init_params(jax.random.PRNGKey(0), dcfg)
    for vname, spec in VARIANTS.items():
        ck = f"{out}/ckpt/{vname}.npz"
        if not os.path.exists(ck):
            if vname == MAIN_VARIANT:
                raise SystemExit(f"missing {ck}; run pard training first")
            continue  # ablation variant not trained in this build
        params = common.load_ckpt(ck, template)
        grid = list(b1_grid)
        if vname == MAIN_VARIANT:
            grid += batch_grid
        print(f"[aot] {vname}", flush=True)
        export_model(out, vname, dcfg, params, grid, hidden=False,
                     base="draft-s", manifest=manifest)
        manifest["pard_variants"][vname] = {
            "k_train": spec.k, "r": spec.r, "r_min": spec.r_min,
            "shared_mask": spec.shared}

    # --- EAGLE head (target-dependent baseline) ---------------------------
    tcfg = model.FAMILY["target-l"]
    ecfg = model.eagle_config_for(tcfg)
    eck = f"{out}/ckpt/{ecfg.name}.npz"
    if os.path.exists(eck):
        head = common.load_ckpt(
            eck, model.eagle_init(jax.random.PRNGKey(7), ecfg))
        grid = [(1, t) for t in (1, 32)] + (
            [] if args.skip_batch else [(b, t) for b in BATCHES
                                        for t in (1, 32)])
        print(f"[aot] {ecfg.name}", flush=True)
        entries = []
        for b, t in grid:
            fname = f"hlo/{ecfg.name}__b{b}_t{t}.hlo.txt"
            _write(f"{out}/{fname}",
                   lambda b=b, t=t: lower_eagle_fwd(head, ecfg, b, t))
            entries.append({"b": b, "t": t, "file": fname})
        manifest["models"][ecfg.name] = {
            "kind": "eagle", "hidden": True, "arch": ecfg.name,
            "weights": f"ckpt/{ecfg.name}.npz",
            "config": ecfg.to_dict(), "entries": entries,
        }
        export_commits(out, ecfg.name, 1, ecfg.s_max, ecfg.n_heads,
                       ecfg.d_head, grid, manifest)

    # --- vocab + held-out eval prompts ------------------------------------
    corpus.dump_vocab(f"{out}/vocab.json")
    for i, task in enumerate(corpus.TASKS):
        data = corpus.build_eval_prompts(task, args.eval_prompts,
                                         seed=args.eval_seed + i,
                                         seq_len=args.seq_len)
        fname = f"prompts_{task}.json"
        corpus.dump_prompts(data, f"{out}/{fname}")
        manifest["prompts"][task] = fname

    with open(f"{out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {out}/manifest.json "
          f"({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
