"""Synthetic corpus + tokenizer substrate.

The paper trains draft models on Magpie / Evol-CodeAlpaca / OpenR1-Math and
evaluates on HumanEval / GSM8K / MATH500.  We have no real corpora or
checkpoints (repro band 0/5), so we substitute seeded grammar generators
that produce three task distributions with the properties that matter for
speculative decoding: structured, learnable token streams whose
predictability differs per task (code > gsm > math), so the draft/target
agreement rate — the quantity PARD exploits — is realistic and
task-dependent.  See DESIGN.md §3.

Token ids are emitted directly (no text round trip); ``vocab.json`` is
exported for the rust side so prompts/outputs can be detokenized for
debugging and examples.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

# ---------------------------------------------------------------------------
# Vocabulary layout (fixed, shared by every model in the family)
# ---------------------------------------------------------------------------

VOCAB_SIZE = 512

BOS, EOS, PAD, MASK = 0, 1, 2, 3
# Distinct mask ids for the "shared vs distinct mask id" ablation (§4.3).
DISTINCT_MASKS = list(range(4, 12))  # m_0..m_7

_SPECIAL = {0: "<bos>", 1: "<eos>", 2: "<pad>", 3: "<mask>"}
for _i, _m in enumerate(DISTINCT_MASKS):
    _SPECIAL[_m] = f"<mask{_i}>"

_next = 12
_id_of: dict[str, int] = {}
_tok_of: dict[int, str] = dict(_SPECIAL)


def _intern(words: list[str]) -> list[int]:
    global _next
    out = []
    for w in words:
        if w not in _id_of:
            _id_of[w] = _next
            _tok_of[_next] = w
            _next += 1
        out.append(_id_of[w])
    return out


DIGITS = _intern([str(d) for d in range(10)])
OPS = _intern(["+", "-", "*", "/", "%", "==", "<", ">", "="])
PUNCT = _intern(["(", ")", "[", "]", ":", ",", ".", "->", "\n", "  "])
KEYWORDS = _intern(
    ["def", "return", "if", "else", "for", "in", "while", "range",
     "len", "not", "and", "or", "print", "pass", "lambda", "assert"]
)
IDENTS = _intern([f"v{i}" for i in range(24)] + [f"fn{i}" for i in range(8)])
GSM_WORDS = _intern(
    ["alice", "bob", "carol", "dave", "has", "buys", "sells", "gives",
     "apples", "books", "coins", "cards", "each", "day", "week", "then",
     "total", "how", "many", "left", "answer", "is", "so", "now",
     "gets", "loses", "more", "fewer", "twice", "half", "per", "after",
     "first", "second", "third", "spends", "earns", "shares", "keeps",
     "boxes", "bags", "friends", "times", "and", "the", "of", "with"]
)
MATH_SYMS = _intern(
    ["x", "y", "z", "a", "b", "c", "^", "sqrt", "frac", "sum", "=>",
     "therefore", "let", "solve", "factor", "expand", "substitute",
     "roots", "where", "implies", "qed", "{", "}", "|", "pm", "neq",
     "leq", "geq", "int", "d", "prime", "mod", "gcd", "lcm"]
)

assert _next <= VOCAB_SIZE, f"vocab overflow: {_next}"

TASKS = ("code", "gsm", "math")


def detok(ids) -> str:
    return " ".join(_tok_of.get(int(i), f"<{int(i)}>") for i in ids)


def dump_vocab(path: str) -> None:
    with open(path, "w") as f:
        json.dump(
            {
                "vocab_size": VOCAB_SIZE,
                "bos": BOS, "eos": EOS, "pad": PAD, "mask": MASK,
                "distinct_masks": DISTINCT_MASKS,
                "tokens": {str(k): v for k, v in _tok_of.items()},
            },
            f, indent=1,
        )


# ---------------------------------------------------------------------------
# Grammar generators.  Each returns (ids, prompt_len): `ids` includes BOS and
# EOS; `prompt_len` is where the eval prompt ends (everything before it is
# the "question", everything after is what serving must generate).
# ---------------------------------------------------------------------------


def _num(rng: np.random.Generator, lo=0, hi=99) -> list[int]:
    n = int(rng.integers(lo, hi + 1))
    return [DIGITS[int(c)] for c in str(n)]


def gen_code(rng: np.random.Generator, max_len: int) -> tuple[list[int], int]:
    """HumanEval-like: a function signature then a highly structured body.

    The body is largely determined by the signature (same identifiers
    reappear, fixed statement templates), giving the high-predictability
    regime where the paper reports its biggest PARD wins.
    """
    D, O, P, K, I = DIGITS, OPS, PUNCT, KEYWORDS, IDENTS
    lp, rp, lb, rb, colon, comma, dot, arrow, nl, ind = P
    fn = I[24 + int(rng.integers(0, 8))]
    a, b, c = (I[int(rng.integers(0, 24))] for _ in range(3))
    ids = [BOS, K[0], fn, lp, a, comma, b, rp, colon, nl]
    prompt_len = len(ids)
    body_kind = int(rng.integers(0, 4))
    if body_kind == 0:  # return a OP b
        op = O[int(rng.integers(0, 5))]
        ids += [ind, K[1], a, op, b, nl]
    elif body_kind == 1:  # if a < b: return a else: return b
        ids += [ind, K[2], a, O[6], b, colon, nl,
                ind, ind, K[1], a, nl,
                ind, K[3], colon, nl,
                ind, ind, K[1], b, nl]
    elif body_kind == 2:  # for c in range(a): b = b + c ; return b
        ids += [ind, K[4], c, K[5], K[7], lp, a, rp, colon, nl,
                ind, ind, b, O[8], b, O[0], c, nl,
                ind, K[1], b, nl]
    else:  # while a > 0: a = a - 1 ; return b
        one = D[1]
        zero = D[0]
        ids += [ind, K[6], a, O[7], zero, colon, nl,
                ind, ind, a, O[8], a, O[1], one, nl,
                ind, K[1], b, nl]
    ids.append(EOS)
    return ids[:max_len], min(prompt_len, max_len - 1)


def gen_gsm(rng: np.random.Generator, max_len: int) -> tuple[list[int], int]:
    """GSM8K-like word problem followed by an arithmetic chain answer."""
    W = GSM_WORDS
    (alice, bob, carol, dave, has, buys, sells, gives, apples, books, coins,
     cards, each, day, week, then, total, how, many, left, answer, is_, so,
     now, gets, loses, more, fewer, twice, half, per, after, first, second,
     third, spends, earns, shares, keeps, boxes, bags, friends, times, and_,
     the, of, with_) = W
    who = [alice, bob, carol, dave][int(rng.integers(0, 4))]
    item = [apples, books, coins, cards][int(rng.integers(0, 4))]
    n1 = int(rng.integers(2, 50))
    n2 = int(rng.integers(1, n1))
    verb2, sign = [(buys, +1), (sells, -1), (gives, -1), (gets, +1)][
        int(rng.integers(0, 4))
    ]
    n3 = n1 + sign * n2
    dd = lambda n: [DIGITS[int(ch)] for ch in str(n)]
    ids = [BOS, who, has, *dd(n1), item, then, verb2, *dd(n2), more,
           how, many, item, now]
    prompt_len = len(ids)
    op = OPS[0] if sign > 0 else OPS[1]
    ids += [answer, is_, *dd(n1), op, *dd(n2), OPS[8], *dd(n3), so,
            who, has, *dd(n3), item, EOS]
    return ids[:max_len], min(prompt_len, max_len - 1)


def gen_math(rng: np.random.Generator, max_len: int) -> tuple[list[int], int]:
    """MATH500-like symbolic derivation: solve x^2 - s x + p = 0 by factoring.

    Less template-determined than the code task (root values inject
    entropy mid-sequence), giving a lower acceptance-rate regime.
    """
    M, O, P = MATH_SYMS, OPS, PUNCT
    x = M[0]
    caret, arrow, solve, factor, roots = M[6], M[10], M[13], M[14], M[17]
    r1 = int(rng.integers(1, 10))
    r2 = int(rng.integers(1, 10))
    s, p = r1 + r2, r1 * r2
    dd = lambda n: [DIGITS[int(ch)] for ch in str(n)]
    two = DIGITS[2]
    zero = DIGITS[0]
    ids = [BOS, solve, x, caret, two, O[1], *dd(s), x, O[0], *dd(p),
           O[8], zero]
    prompt_len = len(ids)
    lp, rp = P[0], P[1]
    ids += [arrow, factor, lp, x, O[1], *dd(r1), rp, lp, x, O[1], *dd(r2),
            rp, O[8], zero,
            arrow, roots, x, O[8], *dd(r1), M[24], x, O[8], *dd(r2),
            M[20], EOS]
    return ids[:max_len], min(prompt_len, max_len - 1)


_GEN = {"code": gen_code, "gsm": gen_gsm, "math": gen_math}


# ---------------------------------------------------------------------------
# Batched dataset assembly
# ---------------------------------------------------------------------------


@dataclass
class Corpus:
    """Fixed-shape token matrix with per-row prompt/valid lengths."""

    tokens: np.ndarray  # [n, seq_len] int32, PAD-filled
    prompt_len: np.ndarray  # [n] int32
    valid_len: np.ndarray  # [n] int32
    task: list = field(default_factory=list)


def build_corpus(
    n: int,
    seq_len: int,
    seed: int,
    tasks: tuple[str, ...] = TASKS,
    mix: tuple[float, ...] | None = None,
) -> Corpus:
    rng = np.random.default_rng(seed)
    mix = mix or tuple(1.0 / len(tasks) for _ in tasks)
    probs = np.asarray(mix) / np.sum(mix)
    toks = np.full((n, seq_len), PAD, dtype=np.int32)
    plen = np.zeros(n, dtype=np.int32)
    vlen = np.zeros(n, dtype=np.int32)
    names = []
    for i in range(n):
        t = tasks[int(rng.choice(len(tasks), p=probs))]
        ids, pl = _GEN[t](rng, seq_len)
        toks[i, : len(ids)] = ids
        plen[i] = pl
        vlen[i] = len(ids)
        names.append(t)
    return Corpus(toks, plen, vlen, names)


def build_eval_prompts(task: str, n: int, seed: int, seq_len: int) -> Corpus:
    """Held-out prompts for one task (HumanEval/GSM8K/MATH500 stand-ins)."""
    return build_corpus(n, seq_len, seed=seed, tasks=(task,))


def dump_prompts(corpus: Corpus, path: str) -> None:
    rows = []
    for i in range(corpus.tokens.shape[0]):
        v = int(corpus.valid_len[i])
        p = int(corpus.prompt_len[i])
        rows.append(
            {
                "task": corpus.task[i],
                "prompt": [int(x) for x in corpus.tokens[i, :p]],
                "reference": [int(x) for x in corpus.tokens[i, p:v]],
            }
        )
    with open(path, "w") as f:
        json.dump(rows, f)
