"""L1 Pallas kernel: fused SwiGLU MLP for the serving path.

Computes ``(silu(x @ w1) * (x @ w3)) @ w2`` in one kernel so the two gate
projections and the elementwise silu/multiply never round-trip through HBM.
Tiled over the FFN dimension: each grid step loads one ``block_ff`` column
panel of w1/w3 and the matching row panel of w2 into VMEM, accumulating the
down-projection online — the same stream-through-VMEM schedule as the
attention kernel.  interpret=True on CPU (see attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_FF = 128


def _swiglu_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    """One FFN column-panel step; accumulates into o_ref across the grid.

    Refs: x [T, D]; w1/w3 panel [D, BF]; w2 panel [BF, D]; o [T, D].
    Grid dim 0 walks the FFN panels sequentially, so read-modify-write on
    o_ref is safe (Pallas grids execute in order).
    """
    i = pl.program_id(0)
    x = x_ref[...]
    g = jnp.dot(x, w1_ref[...])
    u = jnp.dot(x, w3_ref[...])
    h = (g * jax.nn.sigmoid(g)) * u  # silu(g) * u
    part = jnp.dot(h, w2_ref[...])

    @pl.when(i == 0)
    def _init():
        o_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        o_ref[...] = o_ref[...] + part


@functools.partial(jax.jit, static_argnames=("block_ff",))
def swiglu(x: jax.Array, w1: jax.Array, w2: jax.Array, w3: jax.Array,
           block_ff: int = DEFAULT_BLOCK_FF) -> jax.Array:
    """Fused SwiGLU: x [T, D], w1/w3 [D, F], w2 [F, D] -> [T, D]."""
    t, d = x.shape
    f = w1.shape[1]
    if f % block_ff != 0:
        raise ValueError(f"F={f} must be a multiple of block_ff={block_ff}")
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(f // block_ff,),
        in_specs=[
            pl.BlockSpec((t, d), lambda i: (0, 0)),
            pl.BlockSpec((d, block_ff), lambda i: (0, i)),
            pl.BlockSpec((d, block_ff), lambda i: (0, i)),
            pl.BlockSpec((block_ff, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((t, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, w1, w3, w2)
