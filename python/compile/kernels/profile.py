"""L1 structural profiling: VMEM footprint + MXU utilization per block
shape for the cached-attention kernel (paper §Perf deliverable).

interpret=True gives CPU-numpy timings that are NOT a TPU proxy, so the
kernel is optimized structurally: the working set must sit in ~16 MiB
VMEM, the KV stream must be read exactly once (K-independent — Table 6's
claim at kernel level), and the matmul tiles must be MXU-shaped
(multiples of 128 lanes where possible at these model sizes).

Usage: python -m compile.kernels.profile            (prints the table)
The chosen default (block_kv=64) is recorded in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from .attention import vmem_footprint_bytes


PHASES = [
    ("decode (T=1)", 1),
    ("verify K=8 (T=9)", 9),
    ("pard draft K=8 (T=16)", 16),
    ("prefill (T=32)", 32),
]


def mxu_utilization(t: int, block_kv: int, d: int,
                    mxu: int = 128) -> float:
    """Fraction of the MXU systolic array busy for the q·kᵀ tile.

    The tile is (t × d)·(d × block_kv); the array is mxu×mxu.  Small t
    (decode) strands rows — the reason serving batches/speculates at all.
    """
    rows = min(t, mxu) / mxu
    cols = min(block_kv, mxu) / mxu
    inner = min(d, mxu) / mxu
    return rows * cols * inner


def table(s_max: int = 256, d: int = 32) -> list[dict]:
    rows = []
    for block_kv in (32, 64, 128, 256):
        for name, t in PHASES:
            fp = vmem_footprint_bytes(t=t, s=s_max, d=d, block_kv=block_kv)
            rows.append({
                "block_kv": block_kv,
                "phase": name,
                "vmem_kib": fp["total"] / 1024,
                "hbm_read_kib": fp["hbm_reads"] / 1024,
                "mxu_util": mxu_utilization(t, block_kv, d),
                "softmax_steps": s_max // block_kv,
            })
    return rows


def main():
    print(f"{'block_kv':>8} {'phase':<24} {'VMEM KiB':>9} "
          f"{'HBM KiB':>8} {'MXU util':>9} {'steps':>6}")
    for r in table():
        print(f"{r['block_kv']:>8} {r['phase']:<24} "
              f"{r['vmem_kib']:>9.1f} {r['hbm_read_kib']:>8.1f} "
              f"{r['mxu_util']:>9.3f} {r['softmax_steps']:>6}")
    print("\nHBM reads are identical across phases and block sizes: the "
          "cache streams once per call regardless of K (Table 6 at "
          "kernel level).")


if __name__ == "__main__":
    main()
