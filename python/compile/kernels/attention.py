"""L1 Pallas kernel: cached causal flash-attention for the serving path.

This is the decode/verify/PARD-draft hot spot.  One kernel serves all three
phases: queries are T new tokens (T=1 decode, T=K+1 verify, T≈2K PARD
draft) attending a fixed-capacity KV cache ``[B, S, H, D]`` into which the
new tokens' K/V have already been written.  Masking is positional:
slot ``s`` is attendable by query ``t`` iff ``s <= q_pos[b, t]`` — the
L3 coordinator guarantees every slot ``<= q_pos`` holds live data (see
DESIGN.md §7), so no separate validity mask is needed.

Hardware adaptation (paper targets A100 HBM↔SM; we express the TPU
analogue): the KV cache streams through VMEM in ``block_kv``-row tiles
consumed by an online-softmax accumulator (flash-attention v2 structure),
so HBM traffic is one pass over the cache *regardless of K* — the kernel-
level mirror of the paper's Table 6 claim that PARD draft bandwidth is
constant in K.  ``q·kᵀ`` and ``p·v`` are MXU-shaped matmuls.

``interpret=True`` always: real-TPU lowering emits Mosaic custom-calls the
CPU PJRT plugin cannot execute.  Correctness is pinned to ``ref.py`` via
pytest + hypothesis sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_KV = 64
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, qpos_ref, o_ref, *, block_kv: int,
                 s_max: int, scale: float):
    """One (batch, head) tile: flash-attention over the KV cache.

    Refs (VMEM blocks):
      q_ref    [1, T, 1, D]   queries for this (b, h)
      k_ref    [1, S, 1, D]   full cache column for this (b, h)
      v_ref    [1, S, 1, D]
      qpos_ref [1, T]         absolute position of each query token
      o_ref    [1, T, 1, D]
    """
    q = q_ref[0, :, 0, :]  # [T, D]
    qpos = qpos_ref[0, :]  # [T]
    t, d = q.shape

    m0 = jnp.full((t,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((t,), dtype=jnp.float32)
    acc0 = jnp.zeros((t, d), dtype=jnp.float32)

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        k = pl.load(k_ref, (0, pl.dslice(i * block_kv, block_kv), 0,
                            slice(None)))  # [BK, D]
        v = pl.load(v_ref, (0, pl.dslice(i * block_kv, block_kv), 0,
                            slice(None)))
        s = jnp.dot(q, k.T) * scale  # [T, BK] — MXU-shaped
        slot = i * block_kv + jnp.arange(block_kv)
        s = jnp.where(slot[None, :] <= qpos[:, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc_new

    n_blocks = s_max // block_kv
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    # Every real query can attend at least its own slot, so l > 0; parked
    # pad queries may hit garbage but their outputs are discarded by L3.
    o_ref[0, :, 0, :] = acc / jnp.maximum(l, 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("block_kv",))
def cached_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     q_pos: jax.Array,
                     block_kv: int = DEFAULT_BLOCK_KV) -> jax.Array:
    """Flash-attention of new-token queries against the KV cache.

    Args:
      q:       [B, T, H, D] new-token queries (RoPE already applied).
      k_cache: [B, S, H, D] cache with this step's K already scattered in.
      v_cache: [B, S, H, D]
      q_pos:   [B, T] int32 absolute position of each query.
      block_kv: KV tile rows streamed through VMEM per online-softmax step.

    Returns: [B, T, H, D] attention outputs.
    """
    b, t, h, d = q.shape
    s = k_cache.shape[1]
    if s % block_kv != 0:
        raise ValueError(f"S={s} must be a multiple of block_kv={block_kv}")
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_attn_kernel, block_kv=block_kv, s_max=s,
                             scale=scale)
    return pl.pallas_call(
        kern,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, t, 1, d), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, s, 1, d), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, s, 1, d), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, 1, d), lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, h, d), jnp.float32),
        interpret=True,
    )(q, k_cache, v_cache, q_pos)


def vmem_footprint_bytes(t: int, s: int, d: int, block_kv: int) -> dict:
    """Static VMEM budget of one grid step — the L1 profiling surface.

    interpret=True gives no hardware timing, so optimization is structural:
    keep the working set inside ~16 MiB VMEM and the matmul tiles
    MXU-shaped.  Recorded per block-shape candidate in EXPERIMENTS.md §Perf.
    """
    f32 = 4
    q_bytes = t * d * f32
    kv_tile = 2 * block_kv * d * f32
    acc = t * d * f32 + 2 * t * f32
    scores = t * block_kv * f32
    total = q_bytes + kv_tile + acc + scores
    return {
        "q": q_bytes, "kv_tile": kv_tile, "acc": acc, "scores": scores,
        "total": total,
        "hbm_reads": 2 * s * d * f32,  # one pass over the cache, K-independent
        "mxu_macs": t * s * d * 2,
    }
