"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package has a reference here with identical
signature semantics; pytest + hypothesis assert allclose across shape,
length, and position sweeps (python/tests/test_kernel.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cached_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, q_pos: jax.Array) -> jax.Array:
    """Dense-mask attention over the full cache.

    q [B, T, H, D]; k_cache/v_cache [B, S, H, D]; q_pos [B, T] int32.
    Slot s attendable by query t iff s <= q_pos[b, t].
    """
    b, t, h, d = q.shape
    s = k_cache.shape[1]
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bthd,bshd->bhts", q, k_cache) * scale  # [B,H,T,S]
    slot = jnp.arange(s)
    mask = slot[None, None, None, :] <= q_pos[:, None, :, None]  # [B,1,T,S]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", p, v_cache)


def swiglu_ref(x: jax.Array, w1: jax.Array, w2: jax.Array,
               w3: jax.Array) -> jax.Array:
    """x [T, D], w1/w3 [D, F], w2 [F, D]."""
    g = x @ w1
    return ((g * jax.nn.sigmoid(g)) * (x @ w3)) @ w2
