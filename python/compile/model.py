"""L2: the SynLlama model family — LLaMA-architecture decoders in JAX.

Two forward paths share one parameter pytree:

* ``extend`` — the **serving** path that is AOT-lowered to HLO and executed
  from rust.  It is a single generic entry point: write T new tokens' K/V
  into the fixed-capacity KV cache at caller-supplied positions, run the
  L1 Pallas cached-attention kernel, and return logits (+ optionally the
  final hidden state for the EAGLE baseline).  Prefill, decode, verify and
  PARD parallel-draft are all ``extend`` with different (tokens, pos_ids)
  layouts composed by the rust coordinator — see DESIGN.md §7.

* ``train_forward`` — the **training** path (pure jnp, dense attention
  mask) used by pretrain / PARD-adaptation / EAGLE-head training.  The
  PARD mask-token subtask structure (paper Fig. 4/5) is expressed entirely
  through the explicit ``attn_mask`` and ``pos_ids`` built by
  ``train/pard.py``, so the model code is identical for AR and PARD
  training.

Weights are float32; the lm head is tied to the embedding.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp

from .kernels.attention import cached_attention
from .kernels.ref import cached_attention_ref
from . import corpus


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = corpus.VOCAB_SIZE
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_head: int = 32
    d_ff: int = 256
    s_max: int = 256  # KV-cache capacity (max position + headroom)
    rope_theta: float = 10000.0

    def to_dict(self):
        return asdict(self)

    @property
    def n_params(self) -> int:
        attn = 4 * self.d_model * self.n_heads * self.d_head
        mlp = 3 * self.d_model * self.d_ff
        norms = 2 * self.d_model
        per_layer = attn + mlp + norms
        return (self.vocab * self.d_model + self.n_layers * per_layer
                + self.d_model)


# The synthetic family (paper: LLaMA3.2-1B draft vs 1B/3B/8B/… targets).
# Size ratios draft:target span ~1:5 … ~1:23, bracketing the paper's
# 0.5B:7B and 1B:8B regimes.
FAMILY = {
    "draft-s": ModelConfig("draft-s", d_model=128, n_layers=2, n_heads=4,
                           d_head=32, d_ff=256),
    "target-m": ModelConfig("target-m", d_model=192, n_layers=4, n_heads=6,
                            d_head=32, d_ff=512),
    "target-l": ModelConfig("target-l", d_model=256, n_layers=6, n_heads=8,
                            d_head=32, d_ff=704),
    "target-xl": ModelConfig("target-xl", d_model=320, n_layers=8, n_heads=10,
                             d_head=32, d_ff=896),
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(rng: jax.Array, cfg: ModelConfig) -> dict:
    def dense(key, shape, scale=None):
        scale = scale if scale is not None else (shape[0] ** -0.5)
        return jax.random.normal(key, shape, jnp.float32) * scale

    keys = jax.random.split(rng, 2 + cfg.n_layers)
    d, h, dh, f = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff
    layers = []
    for li in range(cfg.n_layers):
        k = jax.random.split(keys[2 + li], 7)
        layers.append({
            "wq": dense(k[0], (d, h * dh)),
            "wk": dense(k[1], (d, h * dh)),
            "wv": dense(k[2], (d, h * dh)),
            "wo": dense(k[3], (h * dh, d)),
            "w1": dense(k[4], (d, f)),
            "w2": dense(k[5], (f, d)),
            "w3": dense(k[6], (d, f)),
            "ln_attn": jnp.ones((d,), jnp.float32),
            "ln_mlp": jnp.ones((d,), jnp.float32),
        })
    return {
        "embed": dense(keys[0], (cfg.vocab, d), scale=0.02),
        "ln_f": jnp.ones((d,), jnp.float32),
        "layers": layers,
    }


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x [B, T, H, D], pos [B, T] int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = pos[..., None].astype(jnp.float32) * freqs  # [B, T, half]
    cos = jnp.cos(angles)[:, :, None, :]  # [B, T, 1, half]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _swiglu(x, lyr):
    g = x @ lyr["w1"]
    return ((g * jax.nn.sigmoid(g)) * (x @ lyr["w3"])) @ lyr["w2"]


# ---------------------------------------------------------------------------
# Serving path (AOT-exported): extend the cache by T tokens
# ---------------------------------------------------------------------------


def extend(params: dict, cfg: ModelConfig, tokens: jax.Array,
           pos_ids: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
           return_hidden: bool = False, use_pallas: bool = True):
    """The single serving entry point.

    Args:
      tokens:  [B, T] int32 new tokens (reals / MASKs / parked pads — the
               rust coordinator decides the layout).
      pos_ids: [B, T] int32 absolute positions; K/V are scattered into the
               cache at these slots before attention.
      cache_k/cache_v: [L, B, S, H, D] fixed-capacity caches.

    Returns (logits [B, T, V], cache_k', cache_v'[, hidden [B, T, D]]).
    """
    b, t = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    x = params["embed"][tokens]  # [B, T, D]
    attn = cached_attention if use_pallas else cached_attention_ref
    bidx = jnp.arange(b)[:, None]  # [B, 1] broadcasts with pos_ids [B, T]
    for li, lyr in enumerate(params["layers"]):
        xn = rmsnorm(x, lyr["ln_attn"])
        q = (xn @ lyr["wq"]).reshape(b, t, h, dh)
        k = (xn @ lyr["wk"]).reshape(b, t, h, dh)
        v = (xn @ lyr["wv"]).reshape(b, t, h, dh)
        q = rope(q, pos_ids, cfg.rope_theta)
        k = rope(k, pos_ids, cfg.rope_theta)
        cache_k = cache_k.at[li, bidx, pos_ids].set(k)
        cache_v = cache_v.at[li, bidx, pos_ids].set(v)
        o = attn(q, cache_k[li], cache_v[li], pos_ids)  # [B, T, H, D]
        x = x + o.reshape(b, t, h * dh) @ lyr["wo"]
        x = x + _swiglu(rmsnorm(x, lyr["ln_mlp"]), lyr)
    hidden = rmsnorm(x, params["ln_f"])
    logits = hidden @ params["embed"].T
    if return_hidden:
        return logits, cache_k, cache_v, hidden
    return logits, cache_k, cache_v


def empty_cache(cfg: ModelConfig, batch: int):
    shape = (cfg.n_layers, batch, cfg.s_max, cfg.n_heads, cfg.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


# ---------------------------------------------------------------------------
# Training path (build-time only): dense-mask attention, no cache
# ---------------------------------------------------------------------------


def train_forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
                  pos_ids: jax.Array | None = None,
                  attn_mask: jax.Array | None = None,
                  return_hidden: bool = False):
    """Full-sequence forward.  attn_mask [B, N, N] bool (True = attend);
    defaults to causal.  pos_ids defaults to arange — PARD training passes
    the subtask layout from Alg. 1 instead.
    """
    b, n = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    if pos_ids is None:
        pos_ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    if attn_mask is None:
        attn_mask = jnp.tril(jnp.ones((n, n), bool))[None]
    mask = attn_mask[:, None]  # [B, 1, N, N]
    x = params["embed"][tokens]
    scale = 1.0 / (dh ** 0.5)
    for lyr in params["layers"]:
        xn = rmsnorm(x, lyr["ln_attn"])
        q = rope((xn @ lyr["wq"]).reshape(b, n, h, dh), pos_ids,
                 cfg.rope_theta)
        k = rope((xn @ lyr["wk"]).reshape(b, n, h, dh), pos_ids,
                 cfg.rope_theta)
        v = (xn @ lyr["wv"]).reshape(b, n, h, dh)
        s = jnp.einsum("bthd,bshd->bhts", q, k) * scale
        s = jnp.where(mask, s, -1e30)
        o = jnp.einsum("bhts,bshd->bthd", jax.nn.softmax(s, -1), v)
        x = x + o.reshape(b, n, h * dh) @ lyr["wo"]
        x = x + _swiglu(rmsnorm(x, lyr["ln_mlp"]), lyr)
    hidden = rmsnorm(x, params["ln_f"])
    logits = hidden @ params["embed"].T
    return (logits, hidden) if return_hidden else logits


# ---------------------------------------------------------------------------
# EAGLE-style head (target-dependent baseline, paper §1 / Tables 3,5,6)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EagleConfig:
    """One decoder layer fed by [target hidden ; token embedding]."""
    name: str
    target: str          # which family member it is coupled to
    d_model: int         # == target's d_model
    n_heads: int
    d_head: int
    d_ff: int
    vocab: int = corpus.VOCAB_SIZE
    s_max: int = 256
    rope_theta: float = 10000.0

    def to_dict(self):
        return asdict(self)


def eagle_config_for(target_cfg: ModelConfig) -> EagleConfig:
    return EagleConfig(
        name=f"eagle-{target_cfg.name}", target=target_cfg.name,
        d_model=target_cfg.d_model, n_heads=target_cfg.n_heads,
        d_head=target_cfg.d_head, d_ff=target_cfg.d_ff,
        s_max=target_cfg.s_max, rope_theta=target_cfg.rope_theta)


def eagle_init(rng: jax.Array, cfg: EagleConfig) -> dict:
    base = ModelConfig("eagle", d_model=cfg.d_model, n_layers=1,
                       n_heads=cfg.n_heads, d_head=cfg.d_head, d_ff=cfg.d_ff)
    p = init_params(rng, base)
    k = jax.random.split(rng, 2)[1]
    d = cfg.d_model
    p["fuse"] = jax.random.normal(k, (2 * d, d), jnp.float32) * (2 * d) ** -0.5
    return p


def eagle_extend(params: dict, cfg: EagleConfig, hidden: jax.Array,
                 tokens: jax.Array, pos_ids: jax.Array, cache_k: jax.Array,
                 cache_v: jax.Array, use_pallas: bool = True):
    """EAGLE draft step: fuse target hidden + token embedding, one layer.

    hidden [B, T, D] is the target model's hidden state at the token's
    position (or the head's own previous output for chained drafting —
    EAGLE's feature-level autoregression).  Caches are [1, B, S, H, D].
    Returns (logits, cache_k', cache_v', head_hidden).
    """
    b, t = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    emb = params["embed"][tokens]
    x = jnp.concatenate([hidden, emb], -1) @ params["fuse"]  # [B, T, D]
    lyr = params["layers"][0]
    attn = cached_attention if use_pallas else cached_attention_ref
    bidx = jnp.arange(b)[:, None]
    xn = rmsnorm(x, lyr["ln_attn"])
    q = rope((xn @ lyr["wq"]).reshape(b, t, h, dh), pos_ids, cfg.rope_theta)
    k = rope((xn @ lyr["wk"]).reshape(b, t, h, dh), pos_ids, cfg.rope_theta)
    v = (xn @ lyr["wv"]).reshape(b, t, h, dh)
    cache_k = cache_k.at[0, bidx, pos_ids].set(k)
    cache_v = cache_v.at[0, bidx, pos_ids].set(v)
    o = attn(q, cache_k[0], cache_v[0], pos_ids)
    x = x + o.reshape(b, t, h * dh) @ lyr["wo"]
    x = x + _swiglu(rmsnorm(x, lyr["ln_mlp"]), lyr)
    head_hidden = rmsnorm(x, params["ln_f"])
    logits = head_hidden @ params["embed"].T
    return logits, cache_k, cache_v, head_hidden


def eagle_train_forward(params: dict, cfg: EagleConfig, hidden: jax.Array,
                        tokens: jax.Array, return_hidden: bool = False):
    """Training forward (EAGLE pairing): the head input at step t is
    ``[h_{t-1} ; embed(x_t)]`` — the target feature of the *previous*
    position fused with the current token — predicting ``x_{t+1}``.
    This matches what serving has available: a freshly committed token is
    always paired with the hidden row that predicted it.  ``h_{-1}`` is
    zeros.  With ``return_hidden`` the head's own feature outputs are
    returned for EAGLE's feature-regression loss (train them toward
    ``h_t`` so chained drafting stays in-distribution).
    """
    b, n = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    pos = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    shifted = jnp.concatenate(
        [jnp.zeros_like(hidden[:, :1]), hidden[:, :-1]], axis=1)
    emb = params["embed"][tokens]
    x = jnp.concatenate([shifted, emb], -1) @ params["fuse"]
    lyr = params["layers"][0]
    xn = rmsnorm(x, lyr["ln_attn"])
    q = rope((xn @ lyr["wq"]).reshape(b, n, h, dh), pos, cfg.rope_theta)
    k = rope((xn @ lyr["wk"]).reshape(b, n, h, dh), pos, cfg.rope_theta)
    v = (xn @ lyr["wv"]).reshape(b, n, h, dh)
    s = jnp.einsum("bthd,bshd->bhts", q, k) * (dh ** -0.5)
    s = jnp.where(jnp.tril(jnp.ones((n, n), bool))[None, None], s, -1e30)
    o = jnp.einsum("bhts,bshd->bthd", jax.nn.softmax(s, -1), v)
    x = x + o.reshape(b, n, h * dh) @ lyr["wo"]
    x = x + _swiglu(rmsnorm(x, lyr["ln_mlp"]), lyr)
    head_hidden = rmsnorm(x, params["ln_f"])
    logits = head_hidden @ params["embed"].T
    return (logits, head_hidden) if return_hidden else logits
