"""Stage 2: PARD adaptation — mask-token training with Conditional Drop.

Implements the paper's §3.2 exactly:

* **Mask-token subtasks** (Fig. 4): the training sequence is expanded with
  appended MASK tokens.  Subtask k (k = 2..K) predicts the k-th next token:
  a mask token standing at position ``a+k-1`` (anchored at real prefix
  ending at ``a``) attends the reals ``0..a`` plus the *same anchor's*
  earlier masks, and is labelled ``x[a+k]`` — the exact attention pattern
  parallel drafting produces at inference (Eq. 7), so train == serve.
  Subtask 1 is the ordinary AR loss on the real tokens.

* **Conditional Drop (COD, Alg. 1 / Fig. 5)**: subtask k retains
  ``N·max(r^{k-1}, r_min)`` anchors.  Retention is *chain-nested*: an
  anchor retained at depth k is retained at every depth < k, so every
  kept mask query still sees its complete preceding mask KV — the
  paper's "preceding KV cache is complete" constraint.  Dropped chains
  simply never materialize; the expanded sequence is the compacted form
  of Fig. 5 (right).

* **Eq. 8 weighting**: the loss averages per subtask, then across
  subtasks (``weights`` below).

* **Shared vs distinct mask ids** (§4.3 ablation): ``shared=True`` uses a
  single <mask> id at every offset (the paper's winning strategy, and the
  source of the K_infer > K_train extrapolation capability);
  ``shared=False`` uses <mask0>..<mask7>.

``VARIANTS`` enumerates the main artifact plus the ablation grid for
Fig. 6a (r, r_min sweep) and Fig. 6b (K_train sweep).
"""

from __future__ import annotations

import argparse
import math
import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .. import corpus, model
from . import common


# ---------------------------------------------------------------------------
# Algorithm 1: data processing
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PardSpec:
    k: int = 8            # K_train: mask offsets trained
    r: float = 0.7        # retention decay
    r_min: float = 0.2    # retention floor
    shared: bool = True   # shared mask id strategy

    def retained(self, n: int, sub_k: int) -> int:
        """N_k' = N * max(r^{k-1}, r_min)   (paper Eq. 11; k is 1-based)."""
        return int(math.ceil(n * max(self.r ** (sub_k - 1), self.r_min)))

    def expanded_len(self, n: int) -> int:
        return n + sum(self.retained(n, k) for k in range(2, self.k + 1))

    def full_tokens(self, n: int) -> int:
        """Token count without COD (K*N) — the Fig. 6a baseline cost."""
        return self.k * n


def anchor_depths(n: int, spec: PardSpec, rng: np.random.Generator
                  ) -> np.ndarray:
    """Per-anchor chain depth (1 = AR only).  Nested by construction:
    the first N_k anchors of a random permutation get depth >= k, and
    N_k is non-increasing in k, so depth-k retention implies depth k-1.
    """
    perm = rng.permutation(n)
    rank = np.empty(n, dtype=np.int64)
    rank[perm] = np.arange(n)
    depth = np.ones(n, dtype=np.int64)
    for k in range(2, spec.k + 1):
        depth[rank < spec.retained(n, k)] = k
    return depth


def build_pard_batch(tokens: np.ndarray, valid_len: np.ndarray,
                     spec: PardSpec, rng: np.random.Generator) -> dict:
    """Expand a [B, N] batch into the COD-compacted PARD training batch.

    Returns fixed-shape arrays (shape depends only on (N, spec)):
      tokens   [B, M]       reals then per-anchor mask chains
      pos_ids  [B, M]       mask tau(k, anchor a) sits at position a+k-1
      attn     [B, M, M]    bool, True = attend
      labels   [B, M]       -1 where no loss
      weights  [B, M]       Eq. 8: 1/(K_eff * |subtask k|) at each query
    """
    b, n = tokens.shape
    m = spec.expanded_len(n)
    mask_id_of = (lambda k: corpus.MASK) if spec.shared else (
        lambda k: corpus.DISTINCT_MASKS[k - 2])

    out_tok = np.full((b, m), corpus.PAD, dtype=np.int32)
    out_pos = np.zeros((b, m), dtype=np.int32)
    out_lab = np.full((b, m), -1, dtype=np.int32)
    out_sub = np.zeros((b, m), dtype=np.int32)  # subtask id per query
    attn = np.zeros((b, m, m), dtype=bool)
    causal = np.tril(np.ones((n, n), dtype=bool))

    for i in range(b):
        v = int(valid_len[i])
        out_tok[i, :n] = tokens[i]
        out_pos[i, :n] = np.arange(n)
        out_lab[i, : n - 1] = tokens[i, 1:]
        out_lab[i, max(v - 1, 0):n] = -1
        out_sub[i, :n][out_lab[i, :n] >= 0] = 1
        attn[i, :n, :n] = causal

        depth = anchor_depths(n, spec, rng)
        cur = n
        for a in range(n):
            d = int(depth[a])
            if d < 2:
                continue
            chain_start = cur
            for k in range(2, d + 1):
                s = cur
                cur += 1
                out_tok[i, s] = mask_id_of(k)
                out_pos[i, s] = a + k - 1
                lab_idx = a + k
                if lab_idx < v:
                    out_lab[i, s] = tokens[i, lab_idx]
                    out_sub[i, s] = k
                # Attend the real prefix 0..a and this anchor's own chain.
                attn[i, s, : a + 1] = True
                attn[i, s, chain_start: s + 1] = True
        assert cur == m, (cur, m)

    # Eq. 8: average within each subtask, then across subtasks.
    weights = np.zeros((b, m), dtype=np.float32)
    counts = np.zeros((b, spec.k + 1), dtype=np.int64)
    for k in range(1, spec.k + 1):
        counts[:, k] = (out_sub == k).sum(axis=1)
    k_eff = (counts[:, 1:] > 0).sum(axis=1)  # subtasks with any valid query
    for i in range(b):
        total = b  # mean over batch
        for k in range(1, spec.k + 1):
            c = counts[i, k]
            if c > 0:
                sel = out_sub[i] == k
                weights[i, sel] = 1.0 / (c * k_eff[i] * total)

    return {"tokens": out_tok, "pos_ids": out_pos, "attn": attn,
            "labels": out_lab, "weights": weights,
            "n_train_tokens": int(b * m)}


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------


def make_step(cfg: model.ModelConfig):
    def loss_fn(params, batch):
        logits = model.train_forward(params, cfg, batch["tokens"],
                                     pos_ids=batch["pos_ids"],
                                     attn_mask=batch["attn"])
        return common.masked_ce(logits, batch["labels"], batch["weights"])

    grad_fn = jax.value_and_grad(loss_fn)

    @jax.jit
    def step(params, opt, batch, lr):
        loss, grads = grad_fn(params, batch)
        t = opt["t"] + 1
        b1, b2, eps = 0.9, 0.99, 1e-8
        mm = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                    opt["m"], grads)
        vv = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                    opt["v"], grads)
        tf = t.astype(jnp.float32)
        params = jax.tree_util.tree_map(
            lambda p, m_, v_: p - lr * (m_ / (1 - b1 ** tf))
            / (jnp.sqrt(v_ / (1 - b2 ** tf)) + eps),
            params, mm, vv)
        return params, {"m": mm, "v": vv, "t": t}, loss

    return step


def adapt(base_params, cfg: model.ModelConfig, data: corpus.Corpus,
          spec: PardSpec, steps: int, batch: int, seed: int,
          base_lr: float = 1e-3, log_every: int = 50, tag: str = "pard"):
    """Adapt an AR draft into a PARD parallel draft (paper §3.2)."""
    rng = np.random.default_rng(seed + 1)
    params = base_params
    opt = common.adam_init(params)
    step = make_step(cfg)
    n_rows = data.tokens.shape[0]
    losses, total_tokens = [], 0
    for s in range(steps):
        idx = rng.integers(0, n_rows, size=batch)
        raw = build_pard_batch(data.tokens[idx], data.valid_len[idx],
                               spec, rng)
        total_tokens += raw.pop("n_train_tokens")
        jb = {k: jnp.asarray(v) for k, v in raw.items()}
        lr = common.cosine_lr(base_lr, s, steps)
        params, opt, loss = step(params, opt, jb, jnp.float32(lr))
        losses.append(float(loss))
        if s % log_every == 0 or s == steps - 1:
            print(f"[{tag}] step {s:4d} loss {float(loss):.4f}", flush=True)
    return params, losses, total_tokens


# ---------------------------------------------------------------------------
# Variant registry — main artifact + ablation grid (Fig. 6a / 6b / §4.3)
# ---------------------------------------------------------------------------

MAIN_VARIANT = "pard-main"

VARIANTS: dict[str, PardSpec] = {
    # Paper's production setting: K=8, r=0.7, r_min=0.2, shared mask id.
    MAIN_VARIANT: PardSpec(k=8, r=0.7, r_min=0.2, shared=True),
    # Fig. 6a: retention sweep (PARD_r_rmin naming as in the paper).
    "pard-r1.0": PardSpec(k=8, r=1.0, r_min=1.0, shared=True),  # no drop
    "pard-r0.5-m0.2": PardSpec(k=8, r=0.5, r_min=0.2, shared=True),
    "pard-r0.5-m0.0": PardSpec(k=8, r=0.5, r_min=0.0, shared=True),
    "pard-r0.3-m0.2": PardSpec(k=8, r=0.3, r_min=0.2, shared=True),
    # Fig. 6b: K_train sweep.
    "pard-k2": PardSpec(k=2, r=0.7, r_min=0.2, shared=True),
    "pard-k4": PardSpec(k=4, r=0.7, r_min=0.2, shared=True),
    # §4.3: distinct mask ids.
    "pard-distinct": PardSpec(k=8, r=0.7, r_min=0.2, shared=False),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--corpus-size", type=int, default=4096)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--draft", default="draft-s")
    ap.add_argument("--variants", default=MAIN_VARIANT,
                    help="comma list, 'all', or 'ablation'")
    args = ap.parse_args()

    cfg = model.FAMILY[args.draft]
    base = common.load_ckpt(f"{args.out}/ckpt/{args.draft}.npz",
                            model.init_params(jax.random.PRNGKey(0), cfg))
    data = corpus.build_corpus(args.corpus_size, args.seq_len,
                               seed=args.seed)
    if args.variants == "all":
        names = list(VARIANTS)
    elif args.variants == "ablation":
        names = [v for v in VARIANTS if v != MAIN_VARIANT]
    else:
        names = args.variants.split(",")

    os.makedirs(f"{args.out}/ckpt", exist_ok=True)
    os.makedirs(f"{args.out}/metrics", exist_ok=True)
    for name in names:
        spec = VARIANTS[name]
        # Ablation variants get a shorter budget (paper: 93K-subset, 1 ep).
        steps = args.steps if name == MAIN_VARIANT else max(args.steps // 2, 1)
        with common.Timer() as t:
            params, losses, toks = adapt(base, cfg, data, spec, steps,
                                         args.batch, args.seed, tag=name)
        n_arrays = common.save_ckpt(f"{args.out}/ckpt/{name}.npz", params)
        full = spec.full_tokens(args.seq_len) * args.batch * steps
        common.dump_json(
            f"{args.out}/metrics/{name}.json",
            {"variant": name, "spec": spec.__dict__, "steps": steps,
             "final_loss": losses[-1], "wall_s": t.seconds,
             "train_tokens": toks, "train_tokens_full_k": full,
             "cod_token_ratio": toks / max(full, 1),
             "n_arrays": n_arrays, "loss_curve": losses[::10]})
        print(f"[{name}] done {t.seconds:.1f}s loss={losses[-1]:.4f} "
              f"COD token ratio {toks / max(full, 1):.3f}")


if __name__ == "__main__":
    main()
