"""Stage 3: EAGLE-style target-dependent baseline head (build time).

The paper compares PARD against EAGLE (Fig. 1a, Tables 3/5/6).  EAGLE's
defining properties are (a) the draft head consumes the *target model's*
hidden features, making it target-dependent, and (b) drafting is
autoregressive at the feature level, so draft bandwidth grows linearly
with k.  We reproduce both with a one-decoder-layer head over
``[target_hidden ; token_embedding]`` trained by teacher forcing against
the next token, the target frozen.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from .. import corpus, model
from . import common
from .pretrain import ar_labels


def make_step(tcfg: model.ModelConfig, ecfg: model.EagleConfig,
              feat_weight: float = 0.5):
    def loss_fn(head, hidden, toks, labels):
        logits, hh = model.eagle_train_forward(head, ecfg, hidden, toks,
                                               return_hidden=True)
        ce = common.masked_ce(logits, labels)
        # EAGLE feature regression: the head's own feature at step t must
        # approximate the target's h_t, so chained (self-fed) drafting
        # stays in-distribution.
        valid = (labels >= 0).astype(jnp.float32)[..., None]
        feat = jnp.sum(jnp.square(hh - hidden) * valid) / (
            jnp.maximum(jnp.sum(valid), 1.0) * hidden.shape[-1])
        return ce + feat_weight * feat

    grad_fn = jax.value_and_grad(loss_fn)

    @jax.jit
    def step(head, opt, hidden, toks, labels, lr):
        loss, grads = grad_fn(head, hidden, toks, labels)
        t = opt["t"] + 1
        b1, b2, eps = 0.9, 0.99, 1e-8
        mm = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                    opt["m"], grads)
        vv = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                    opt["v"], grads)
        tf = t.astype(jnp.float32)
        head = jax.tree_util.tree_map(
            lambda p, m_, v_: p - lr * (m_ / (1 - b1 ** tf))
            / (jnp.sqrt(v_ / (1 - b2 ** tf)) + eps),
            head, mm, vv)
        return head, {"m": mm, "v": vv, "t": t}, loss

    return step


def train_eagle(target_params, tcfg: model.ModelConfig,
                data: corpus.Corpus, steps: int, batch: int, seed: int,
                base_lr: float = 1e-3, log_every: int = 50):
    ecfg = model.eagle_config_for(tcfg)
    head = model.eagle_init(jax.random.PRNGKey(seed + 7), ecfg)
    opt = common.adam_init(head)
    step = make_step(tcfg, ecfg)

    @jax.jit
    def target_hidden(toks):
        _, hidden = model.train_forward(target_params, tcfg, toks,
                                        return_hidden=True)
        return hidden

    rng = np.random.default_rng(seed + 7)
    labels_all = ar_labels(data.tokens, data.valid_len)
    n = data.tokens.shape[0]
    losses = []
    for s in range(steps):
        idx = rng.integers(0, n, size=batch)
        toks = jnp.asarray(data.tokens[idx])
        hidden = target_hidden(toks)
        labels = jnp.asarray(labels_all[idx])
        lr = common.cosine_lr(base_lr, s, steps)
        head, opt, loss = step(head, opt, hidden, toks, labels,
                               jnp.float32(lr))
        losses.append(float(loss))
        if s % log_every == 0 or s == steps - 1:
            print(f"[eagle-{tcfg.name}] step {s:4d} loss "
                  f"{float(loss):.4f}", flush=True)
    return head, ecfg, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--corpus-size", type=int, default=4096)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--target", default="target-l")
    args = ap.parse_args()

    tcfg = model.FAMILY[args.target]
    target = common.load_ckpt(
        f"{args.out}/ckpt/{args.target}.npz",
        model.init_params(jax.random.PRNGKey(0), tcfg))
    data = corpus.build_corpus(args.corpus_size, args.seq_len,
                               seed=args.seed)
    with common.Timer() as t:
        head, ecfg, losses = train_eagle(target, tcfg, data, args.steps,
                                         args.batch, args.seed)
    os.makedirs(f"{args.out}/ckpt", exist_ok=True)
    n_arrays = common.save_ckpt(f"{args.out}/ckpt/{ecfg.name}.npz", head)
    common.dump_json(
        f"{args.out}/metrics/{ecfg.name}.json",
        {"head": ecfg.name, "target": args.target, "steps": args.steps,
         "final_loss": losses[-1], "wall_s": t.seconds,
         "n_arrays": n_arrays, "loss_curve": losses[::10]})
    print(f"[{ecfg.name}] done {t.seconds:.1f}s loss={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
