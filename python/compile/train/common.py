"""Shared training utilities: Adam, masked CE, checkpoints (build-time only).

Checkpoints are ``.npz`` files whose keys ``p000, p001, …`` follow the
``jax.tree_util.tree_flatten`` order of the parameter pytree — the same
order in which AOT-lowered HLO entry points expect their weight
parameters, so the rust runtime can stream the file straight into PJRT
buffers (``PjRtBuffer::read_npz``) with no name mapping.
"""

from __future__ import annotations

import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Checkpoints
# ---------------------------------------------------------------------------


def flat_leaves(params) -> list[jax.Array]:
    return jax.tree_util.tree_flatten(params)[0]


def save_ckpt(path: str, params) -> int:
    leaves = flat_leaves(params)
    np.savez(path, **{f"p{i:03d}": np.asarray(l) for i, l in enumerate(leaves)})
    return len(leaves)


def load_ckpt(path: str, template):
    leaves, treedef = jax.tree_util.tree_flatten(template)
    with np.load(path) as z:
        loaded = [jnp.asarray(z[f"p{i:03d}"]) for i in range(len(leaves))]
    for have, want in zip(loaded, leaves):
        assert have.shape == want.shape, (have.shape, want.shape)
    return jax.tree_util.tree_unflatten(treedef, loaded)


# ---------------------------------------------------------------------------
# Optimizer (hand-rolled Adam; optax is not in the image)
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": zeros, "t": jnp.zeros((), jnp.int32)}


@partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps"))
def adam_update(params, grads, state, lr=3e-3, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    tf = t.astype(jnp.float32)
    bc1 = 1 - b1 ** tf
    bc2 = 1 - b2 ** tf
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps),
        params, m, v)
    return params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def masked_ce(logits: jax.Array, labels: jax.Array,
              weights: jax.Array | None = None) -> jax.Array:
    """Cross entropy; ``labels == -1`` positions are ignored.

    ``weights`` (same shape as labels) implements the paper's Eq. 8
    per-subtask normalization for PARD training; defaults to a plain mean.
    """
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    if weights is None:
        weights = valid.astype(jnp.float32)
        weights = weights / jnp.maximum(jnp.sum(weights), 1.0)
    return jnp.sum(nll * weights)


def token_accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    valid = labels >= 0
    hit = (jnp.argmax(logits, -1) == labels) & valid
    return jnp.sum(hit) / jnp.maximum(jnp.sum(valid), 1)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def cosine_lr(base: float, step: int, total: int, warmup: int = 20) -> float:
    if step < warmup:
        return base * (step + 1) / warmup
    frac = (step - warmup) / max(total - warmup, 1)
    return float(base * 0.5 * (1 + np.cos(np.pi * min(frac, 1.0))))


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def dump_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
