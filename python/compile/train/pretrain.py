"""Stage 1: AR-pretrain every member of the SynLlama family (build time).

The paper starts from released checkpoints (LLaMA3.2-1B, Qwen2.5-0.5B, …);
we have none (repro band 0/5), so the family is pretrained from scratch on
the shared synthetic corpus.  What matters downstream is that draft and
targets share a data distribution — that is what produces the high
draft/target agreement regime vanilla SD and PARD both exploit.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from .. import corpus, model
from . import common


def ar_labels(tokens: np.ndarray, valid_len: np.ndarray) -> np.ndarray:
    """Next-token labels; -1 beyond the valid region."""
    n, s = tokens.shape
    labels = np.full_like(tokens, -1)
    labels[:, :-1] = tokens[:, 1:]
    idx = np.arange(s)[None, :]
    labels[idx >= (valid_len[:, None] - 1)] = -1
    return labels


def make_step_lr(cfg: model.ModelConfig):
    """Train step with a traced learning rate (cosine schedule)."""

    def loss_fn(params, toks, labels):
        logits = model.train_forward(params, cfg, toks)
        return common.masked_ce(logits, labels)

    grad_fn = jax.value_and_grad(loss_fn)

    @jax.jit
    def step(params, opt, toks, labels, lr):
        loss, grads = grad_fn(params, toks, labels)
        t = opt["t"] + 1
        b1, b2, eps = 0.9, 0.99, 1e-8
        m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                   opt["m"], grads)
        v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                   opt["v"], grads)
        tf = t.astype(jnp.float32)
        params = jax.tree_util.tree_map(
            lambda p, m_, v_: p - lr * (m_ / (1 - b1 ** tf))
            / (jnp.sqrt(v_ / (1 - b2 ** tf)) + eps),
            params, m, v)
        return params, {"m": m, "v": v, "t": t}, loss

    return step


def pretrain_one(name: str, cfg: model.ModelConfig, data: corpus.Corpus,
                 steps: int, batch: int, seed: int, base_lr: float = 3e-3,
                 log_every: int = 50, params=None):
    rng = np.random.default_rng(seed)
    if params is None:
        params = model.init_params(jax.random.PRNGKey(seed), cfg)
    opt = common.adam_init(params)
    step = make_step_lr(cfg)
    n = data.tokens.shape[0]
    labels_all = ar_labels(data.tokens, data.valid_len)
    losses = []
    for s in range(steps):
        idx = rng.integers(0, n, size=batch)
        toks = jnp.asarray(data.tokens[idx])
        labels = jnp.asarray(labels_all[idx])
        lr = common.cosine_lr(base_lr, s, steps)
        params, opt, loss = step(params, opt, toks, labels,
                                 jnp.float32(lr))
        losses.append(float(loss))
        if s % log_every == 0 or s == steps - 1:
            print(f"[pretrain {name}] step {s:4d} loss {float(loss):.4f}",
                  flush=True)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=350)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--corpus-size", type=int, default=4096)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--models", default="all",
                    help="comma list or 'all'")
    args = ap.parse_args()

    os.makedirs(f"{args.out}/ckpt", exist_ok=True)
    os.makedirs(f"{args.out}/metrics", exist_ok=True)
    data = corpus.build_corpus(args.corpus_size, args.seq_len,
                               seed=args.seed)
    names = (list(model.FAMILY) if args.models == "all"
             else args.models.split(","))
    for name in names:
        cfg = model.FAMILY[name]
        with common.Timer() as t:
            params, losses = pretrain_one(name, cfg, data, args.steps,
                                          args.batch, args.seed)
        n_arrays = common.save_ckpt(f"{args.out}/ckpt/{name}.npz", params)
        common.dump_json(
            f"{args.out}/metrics/pretrain_{name}.json",
            {"model": name, "params": cfg.n_params, "steps": args.steps,
             "final_loss": losses[-1], "wall_s": t.seconds,
             "n_arrays": n_arrays, "loss_curve": losses[::10]})
        print(f"[pretrain {name}] done in {t.seconds:.1f}s "
              f"final_loss={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
