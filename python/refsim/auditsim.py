#!/usr/bin/env python3
"""Executable mirror of `pard audit` (rust/src/analysis/) — the
determinism/safety/robustness static-analysis pass, runnable without a
Rust toolchain so ci.sh can hard-gate it in this container.

Mirrors rust/src/analysis exactly: same rule IDs, same scope tables,
same lexer-lite line scanner (line-local comment/string stripping,
column-0 `#[cfg(test)]`-to-EOF test regions), same waiver syntax
(`// audit:allow(RULE[,RULE]) reason`, covering its own line and the
next), same file walk (rust/src/**/*.rs, sorted).  Any divergence
between the two implementations is itself a bug.

Rules (DESIGN.md section 11):
  D1 det-hash-iter   no HashMap/HashSet in determinism-path modules
  D2 wall-clock      Instant::now()/SystemTime only in timing modules
  D3 rng-discipline  no ambient entropy; literal Rng seed/stream pairs
                     must not collide across distinct sites
  D4 float-reassoc   no .sum()/.product()/.fold() in backend identity
                     paths (accumulation order is pinned by DESIGN s8)
  S1 unsafe-hygiene  `unsafe` confined to pool/host/quant and always
                     within 8 lines of a SAFETY comment
  R1 no-panic-serving  no unwrap/expect/panic! on serving request paths
  R2 lossy-cast      no narrowing `as` casts in cache index arithmetic
  H1 doc-coverage    public runtime/coordinator items carry doc comments

Exit code contract (same as `pard audit`): 0 when the tree has no
unwaived violations (waived findings are counted and reported), 1
otherwise.  `--json PATH` additionally writes the stable
machine-readable report (schema pard-audit-v1).

Usage: python3 python/refsim/auditsim.py [--root DIR] [--json PATH]
"""

import json
import os
import sys

# ---------------------------------------------------------------------------
# Rule tables — keep in lockstep with rust/src/analysis/rules.rs.
# ---------------------------------------------------------------------------

RULES = {
    "D1": "det-hash-iter: HashMap/HashSet in a determinism path "
          "(iteration order is a bit-identity hazard) — use "
          "BTreeMap/BTreeSet, or waive a pure-lookup use",
    "D2": "wall-clock: Instant::now()/SystemTime outside the timing "
          "whitelist — route through substrate::bench::stopwatch()",
    "D3": "rng-discipline: ambient entropy, or a literal Rng "
          "seed/stream pair colliding with another site",
    "D4": "float-reassoc: .sum()/.product()/.fold() in a backend "
          "identity path — write the explicit k-ascending loop",
    "S1": "unsafe-hygiene: `unsafe` outside pool/host/quant, or "
          "without a SAFETY comment within 8 lines",
    "R1": "no-panic-serving: unwrap/expect/panic! on a serving "
          "request path — surface a typed outcome instead",
    "R2": "lossy-cast: narrowing `as` cast in cache/block-table "
          "index arithmetic — use try_from or widen",
    "H1": "doc-coverage: public runtime/coordinator item without a "
          "doc comment",
}

D1_PREFIXES = ("coordinator/", "runtime/", "substrate/", "server/")
D2_WHITELIST = ("coordinator/metrics.rs", "substrate/bench.rs")
D4_FILES = ("runtime/reference.rs", "runtime/host.rs",
            "runtime/quant.rs")
S1_ALLOWED = ("runtime/pool.rs", "runtime/host.rs", "runtime/quant.rs")
S1_LOOKBACK = 8
R1_FILES = ("server/mod.rs", "coordinator/batcher.rs")
R2_FILES = ("runtime/cache.rs",)
R2_NARROW = ("u32", "i32", "u16", "i16", "u8", "i8")
H1_PREFIXES = ("runtime/", "coordinator/")
H1_ITEMS = ("pub fn ", "pub struct ", "pub enum ", "pub trait ",
            "pub const ", "pub type ")

R1_PATTERNS = (".unwrap()", ".expect(", "panic!", "unreachable!",
               "todo!", "unimplemented!")
D3_ENTROPY = ("rand::", "thread_rng", "from_entropy", "RandomState",
              "DefaultHasher")

WAIVER_MARK = "audit:allow("


# ---------------------------------------------------------------------------
# Lexer-lite scanner — line-local comment/string stripping.
# ---------------------------------------------------------------------------

def _is_ident(c):
    return c.isalnum() or c == "_"


def strip_code(line):
    """Blank string/char-literal contents and drop comment tails.

    Line-local by design (the documented lexer-lite limitation):
    strings and block comments spanning lines leak their continuation
    lines into the scan.  Handles `//` tails, `/* .. */` on one line,
    `"…"` with escapes, `r"…"`/`r#"…"#` raw strings, and the
    char-literal-vs-lifetime ambiguity of `'`.
    """
    out = []
    i, n = 0, len(line)
    while i < n:
        c = line[i]
        if c == "/" and i + 1 < n and line[i + 1] == "/":
            break  # comment tail (///, //!, // alike)
        if c == "/" and i + 1 < n and line[i + 1] == "*":
            end = line.find("*/", i + 2)
            if end < 0:
                break
            out.append("  " + " " * (end - i - 2) + "  ")
            i = end + 2
            continue
        if c in "rb" and (i == 0 or not _is_ident(line[i - 1])):
            # r"…", r#"…"#, b"…", br"…" raw/byte string starts
            j = i + 1
            if j < n and c == "b" and line[j] == "r":
                j += 1
            hashes = 0
            while j < n and line[j] == "#":
                hashes += 1
                j += 1
            if j < n and line[j] == '"':
                close = '"' + "#" * hashes
                end = line.find(close, j + 1)
                stop = n if end < 0 else end + len(close)
                out.append(" " * (stop - i))
                i = stop
                continue
        if c == '"':
            j = i + 1
            while j < n:
                if line[j] == "\\":
                    j += 2
                    continue
                if line[j] == '"':
                    j += 1
                    break
                j += 1
            j = min(j, n)
            out.append(" " * (j - i))
            i = j
            continue
        if c == "'":
            # char literal vs lifetime: '\x' escapes and 'x' forms are
            # literals; anything else is a lifetime tick.
            if i + 1 < n and line[i + 1] == "\\":
                end = line.find("'", i + 3)
                stop = n if end < 0 else end + 1
                out.append(" " * (stop - i))
                i = stop
                continue
            if i + 2 < n and line[i + 2] == "'":
                out.append("   ")
                i += 3
                continue
            out.append(" ")
            i += 1
            continue
        out.append(c)
        i += 1
    return "".join(out)


def has_token(line, tok):
    """Substring match with non-identifier boundaries, enforced only
    on edges where the token itself ends in an identifier char (so
    `rand::` needs no right boundary but `u32` does)."""
    start = 0
    while True:
        i = line.find(tok, start)
        if i < 0:
            return False
        before = (not _is_ident(tok[0]) or i == 0
                  or not _is_ident(line[i - 1]))
        j = i + len(tok)
        after = (not _is_ident(tok[-1]) or j >= len(line)
                 or not _is_ident(line[j]))
        if before and after:
            return True
        start = i + 1


def rng_literal_sites(stripped):
    """Literal-argument Rng constructor calls on one stripped line.

    Returns (seed, stream) string pairs; `Rng::new(s)` registers as
    stream "-".  Non-literal arguments (idents, expressions) are not
    registry entries — only repeated literal pairs are collisions.
    """
    sites = []
    for call, nargs in (("Rng::new_stream(", 2), ("Rng::new(", 1)):
        start = 0
        while True:
            i = stripped.find(call, start)
            if i < 0:
                break
            start = i + len(call)
            close = stripped.find(")", start)
            if close < 0:
                continue
            args = [a.strip().replace("_", "")
                    for a in stripped[start:close].split(",")]
            if len(args) == nargs and all(a.isdigit() for a in args):
                seed = args[0]
                stream = args[1] if nargs == 2 else "-"
                sites.append((seed, stream))
    return sites


# ---------------------------------------------------------------------------
# Per-file scan
# ---------------------------------------------------------------------------

class FileScan:
    """One file's raw/stripped lines, test region, and waiver table."""

    def __init__(self, relpath, text):
        self.relpath = relpath
        self.raw = text.split("\n")
        self.stripped = [strip_code(l) for l in self.raw]
        self.test_start = len(self.raw) + 1  # 1-based; past EOF = none
        for idx, line in enumerate(self.raw):
            if line.startswith("#[cfg(test)]"):
                self.test_start = idx + 1
                break
        # waivers[line] = list of (rules, reason, waiver_line)
        self.waivers = {}
        self.waiver_sites = []  # (line, rules, reason)
        self.waiver_errors = []  # (line, msg)
        for idx, line in enumerate(self.raw):
            m = line.find(WAIVER_MARK)
            if m < 0:
                continue
            lineno = idx + 1
            close = line.find(")", m)
            if close < 0:
                self.waiver_errors.append(
                    (lineno, "unterminated audit:allow(...)"))
                continue
            rules = [r.strip()
                     for r in line[m + len(WAIVER_MARK):close].split(",")]
            bad = [r for r in rules if r not in RULES]
            if bad:
                self.waiver_errors.append(
                    (lineno, "unknown rule id(s) in waiver: "
                             + ",".join(bad)))
                continue
            reason = line[close + 1:].strip()
            if not reason:
                self.waiver_errors.append(
                    (lineno, "audit:allow waiver needs a reason"))
                continue
            self.waiver_sites.append((lineno, rules, reason))
            for covered in (lineno, lineno + 1):
                self.waivers.setdefault(covered, []).append(
                    (rules, reason, lineno))

    def in_test(self, lineno):
        return lineno >= self.test_start


def scan_rules(fs):
    """All single-file rule findings: [(rule, lineno, msg)]."""
    rel = fs.relpath
    findings = []

    d1 = rel.startswith(D1_PREFIXES)
    d2 = rel not in D2_WHITELIST
    d4 = rel in D4_FILES
    s1_ok_file = rel in S1_ALLOWED
    r1 = rel in R1_FILES
    r2 = rel in R2_FILES
    h1 = rel.startswith(H1_PREFIXES)

    for idx, line in enumerate(fs.stripped):
        lineno = idx + 1
        in_test = fs.in_test(lineno)

        if d1 and not in_test:
            for tok in ("HashMap", "HashSet"):
                if has_token(line, tok):
                    findings.append((
                        "D1", lineno,
                        tok + " in determinism path — iteration order "
                        "is a bit-identity hazard"))
        if d2 and not in_test:
            if "Instant::now" in line or has_token(line, "SystemTime"):
                findings.append((
                    "D2", lineno,
                    "wall-clock read outside the timing whitelist — "
                    "use substrate::bench::stopwatch()"))
        if not in_test:
            for tok in D3_ENTROPY:
                if tok.endswith("::"):
                    hit = has_token(line, tok[:-2] + "::")
                else:
                    hit = has_token(line, tok)
                if hit:
                    findings.append((
                        "D3", lineno,
                        "ambient entropy `" + tok + "` — all "
                        "randomness flows through substrate::rng"))
        if d4 and not in_test:
            for pat in (".sum(", ".sum::<", ".product(", ".fold("):
                if pat in line:
                    findings.append((
                        "D4", lineno,
                        "reassociating accumulator `" + pat + "…` in "
                        "a backend identity path"))
                    break
        # S1 applies in test regions too: unsafe is unsafe everywhere.
        if has_token(line, "unsafe"):
            if not s1_ok_file:
                findings.append((
                    "S1", lineno,
                    "`unsafe` outside runtime/{pool,host,quant}.rs"))
            else:
                lo = max(0, idx - S1_LOOKBACK)
                window = fs.raw[lo:idx + 1]
                if not any("SAFETY:" in w or "# Safety" in w
                           for w in window):
                    findings.append((
                        "S1", lineno,
                        "`unsafe` without a SAFETY comment within "
                        + str(S1_LOOKBACK) + " lines"))
        if r1 and not in_test:
            for pat in R1_PATTERNS:
                if pat in line:
                    findings.append((
                        "R1", lineno,
                        "`" + pat + "…` on a serving request path — "
                        "surface a typed outcome"))
        if r2 and not in_test:
            for ty in R2_NARROW:
                if has_token(line, "as " + ty):
                    findings.append((
                        "R2", lineno,
                        "narrowing `as " + ty + "` in cache index "
                        "arithmetic — use try_from or widen"))
        if h1 and not in_test:
            body = line.lstrip()
            if body.startswith(H1_ITEMS):
                j = idx - 1
                while j >= 0 and fs.raw[j].lstrip().startswith("#["):
                    j -= 1
                doc = j >= 0 and fs.raw[j].lstrip().startswith(
                    ("///", "//!", "#[doc"))
                if not doc:
                    findings.append((
                        "H1", lineno,
                        "public item without a doc comment"))
    return findings


def collect_rng_registry(fs):
    """Non-test literal (seed, stream) sites: [(pair, lineno)]."""
    sites = []
    for idx, line in enumerate(fs.stripped):
        lineno = idx + 1
        if fs.in_test(lineno):
            continue
        for pair in rng_literal_sites(line):
            sites.append((pair, lineno))
    return sites


# ---------------------------------------------------------------------------
# Whole-tree audit
# ---------------------------------------------------------------------------

def audit(files):
    """Audit an ordered [(relpath, text)] set.  Returns the report dict.

    The report is the stable machine schema (pard-audit-v1) both
    implementations emit; `violations` are unwaived findings only.
    """
    scans = [FileScan(rel, text) for rel, text in files]

    per_file = []  # (fs, [(rule, lineno, msg)])
    for fs in scans:
        per_file.append((fs, scan_rules(fs)))

    # D3 registry: literal seed/stream pairs must be globally unique
    # across non-test sites (duplicate pairs = colliding rng streams).
    registry = {}
    for fs in scans:
        for pair, lineno in collect_rng_registry(fs):
            registry.setdefault(pair, []).append((fs.relpath, lineno))
    collisions = {}  # (relpath, lineno) -> msg
    for pair, sites in sorted(registry.items()):
        if len(sites) < 2:
            continue
        first = sites[0]
        for rel, lineno in sites[1:]:
            collisions.setdefault(rel, []).append((
                lineno,
                "literal rng seed/stream (" + pair[0] + ", " + pair[1]
                + ") collides with " + first[0] + ":"
                + str(first[1])))

    violations = []
    waived = []
    waiver_errors = []
    rule_counts = {r: {"violations": 0, "waived": 0} for r in RULES}
    used_waivers = set()  # (relpath, waiver_line)

    for fs, findings in per_file:
        findings = findings + [("D3", ln, msg)
                               for ln, msg in
                               collisions.get(fs.relpath, [])]
        findings.sort(key=lambda f: (f[1], f[0]))
        for rule, lineno, msg in findings:
            entry = {"rule": rule, "file": fs.relpath, "line": lineno,
                     "msg": msg}
            waiver = None
            for rules, reason, wline in fs.waivers.get(lineno, []):
                if rule in rules:
                    waiver = (reason, wline)
                    break
            if waiver is not None:
                entry["reason"] = waiver[0]
                waived.append(entry)
                rule_counts[rule]["waived"] += 1
                used_waivers.add((fs.relpath, waiver[1]))
            else:
                violations.append(entry)
                rule_counts[rule]["violations"] += 1
        for lineno, msg in fs.waiver_errors:
            waiver_errors.append({"file": fs.relpath, "line": lineno,
                                  "msg": msg})
        for lineno, rules, reason in fs.waiver_sites:
            if (fs.relpath, lineno) not in used_waivers:
                waiver_errors.append({
                    "file": fs.relpath, "line": lineno,
                    "msg": "unused audit:allow("
                           + ",".join(rules) + ") waiver"})

    return {
        "schema": "pard-audit-v1",
        "files_scanned": len(scans),
        "rules": {r: {"description": RULES[r],
                      "violations": rule_counts[r]["violations"],
                      "waived": rule_counts[r]["waived"]}
                  for r in sorted(RULES)},
        "violations": violations,
        "waived": waived,
        "waiver_errors": waiver_errors,
        "total_violations": len(violations) + len(waiver_errors),
        "total_waived": len(waived),
    }


def walk_sources(root):
    """Sorted [(relpath, text)] under <root>/rust/src/**/*.rs."""
    src = os.path.join(root, "rust", "src")
    out = []
    for dirpath, dirnames, filenames in os.walk(src):
        dirnames.sort()
        for name in sorted(filenames):
            if not name.endswith(".rs"):
                continue
            path = os.path.join(dirpath, name)
            rel = os.path.relpath(path, src).replace(os.sep, "/")
            with open(path, encoding="utf-8") as fh:
                out.append((rel, fh.read()))
    out.sort(key=lambda item: item[0])
    return out


# ---------------------------------------------------------------------------
# Fixture self-tests — one violation + one clean snippet per rule.
# ---------------------------------------------------------------------------

def _violations(files):
    rep = audit(files)
    return [(v["rule"], v["file"], v["line"])
            for v in rep["violations"]], rep


def selftest():
    checks = 0

    def expect(files, want):
        nonlocal checks
        got, _ = _violations(files)
        assert got == want, "fixture mismatch: %r != %r" % (got, want)
        checks += 1

    # D1: dirty in scope; clean as BTreeMap; exempt out of scope and in
    # test regions.
    dirty = "use std::collections::HashMap;\n"
    expect([("runtime/fx.rs", dirty)], [("D1", "runtime/fx.rs", 1)])
    expect([("runtime/fx.rs", "use std::collections::BTreeMap;\n")], [])
    expect([("main.rs", dirty)], [])
    expect([("runtime/fx.rs", "#[cfg(test)]\n" + dirty)], [])

    # D2: dirty anywhere off-whitelist; clean on the whitelist.
    dirty = "let t0 = Instant::now();\n"
    expect([("coordinator/fx.rs", dirty)],
           [("D2", "coordinator/fx.rs", 1)])
    expect([("substrate/bench.rs", dirty)], [])
    expect([("coordinator/fx.rs", "let t = SystemTime::now();\n")],
           [("D2", "coordinator/fx.rs", 1)])

    # D3 entropy: dirty ambient source; clean seeded stream.
    expect([("runtime/fx.rs", "let r = rand::random::<u64>();\n")],
           [("D3", "runtime/fx.rs", 1)])
    expect([("runtime/fx.rs", "let r = Rng::new_stream(seed, i);\n")],
           [])

    # D3 registry: identical literal pairs at distinct sites collide;
    # distinct streams don't; test-region sites are exempt.
    expect([("runtime/a.rs", "let r = Rng::new_stream(7, 1);\n"),
            ("runtime/b.rs", "let r = Rng::new_stream(7, 1);\n")],
           [("D3", "runtime/b.rs", 1)])
    expect([("runtime/a.rs", "let r = Rng::new_stream(7, 1);\n"),
            ("runtime/b.rs", "let r = Rng::new_stream(7, 2);\n")],
           [])
    expect([("runtime/a.rs", "let r = Rng::new(7);\n"),
            ("runtime/b.rs", "#[cfg(test)]\nlet r = Rng::new(7);\n")],
           [])

    # D4: dirty reassociating accumulator in an identity path; the
    # explicit loop and out-of-scope files are clean.
    dirty = "let s: f32 = xs.iter().sum();\n"
    expect([("runtime/host.rs", dirty)], [("D4", "runtime/host.rs", 1)])
    expect([("runtime/host.rs",
             "let mut s = 0f32; for k in 0..n { s += xs[k]; }\n")], [])
    expect([("coordinator/fx.rs", dirty)], [])

    # S1: confinement (wrong file) and hygiene (no SAFETY comment);
    # a commented site in an allowed file is clean — in tests too.
    expect([("coordinator/fx.rs", "unsafe { run() }\n")],
           [("S1", "coordinator/fx.rs", 1)])
    expect([("runtime/pool.rs", "unsafe { run() }\n")],
           [("S1", "runtime/pool.rs", 1)])
    expect([("runtime/pool.rs",
             "// SAFETY: fixture invariant.\nunsafe { run() }\n")], [])
    expect([("runtime/pool.rs",
             "#[cfg(test)]\nmod t {\nunsafe { run() }\n}\n")],
           [("S1", "runtime/pool.rs", 3)])

    # R1: dirty unwrap on a request path; the poison-tolerant
    # restructure and non-serving files are clean.
    dirty = "let g = m.lock().unwrap();\n"
    expect([("server/mod.rs", dirty)], [("R1", "server/mod.rs", 1)])
    expect([("server/mod.rs",
             "let g = m.lock()"
             ".unwrap_or_else(PoisonError::into_inner);\n")], [])
    expect([("runtime/fx.rs", dirty)], [])
    expect([("coordinator/batcher.rs", "panic!(\"boom\");\n")],
           [("R1", "coordinator/batcher.rs", 1)])

    # R2: narrowing cast in cache.rs; widening casts are clean.
    expect([("runtime/cache.rs", "let b = t as u32;\n")],
           [("R2", "runtime/cache.rs", 1)])
    expect([("runtime/cache.rs", "let b = t as usize;\n")], [])

    # H1: undocumented pub item; documented (incl. behind attributes)
    # is clean; pub(crate) and pub mod are out of scope.
    expect([("runtime/fx.rs", "pub fn f() {}\n")],
           [("H1", "runtime/fx.rs", 1)])
    expect([("runtime/fx.rs", "/// Doc.\npub fn f() {}\n")], [])
    expect([("runtime/fx.rs",
             "/// Doc.\n#[inline]\n#[cold]\npub fn f() {}\n")], [])
    expect([("runtime/fx.rs", "pub(crate) fn f() {}\n")], [])
    expect([("runtime/fx.rs", "pub mod fx;\n")], [])

    # Waivers: cover own + next line, count as waived, and must carry
    # a known rule id and a reason; unused waivers are errors.
    src = "// audit:allow(D2) fixture timing\nlet t = Instant::now();\n"
    got, rep = _violations([("coordinator/fx.rs", src)])
    assert got == [] and rep["total_waived"] == 1, rep
    checks += 1
    src = "let t = Instant::now(); // audit:allow(D2) same-line\n"
    got, rep = _violations([("coordinator/fx.rs", src)])
    assert got == [] and rep["total_waived"] == 1, rep
    checks += 1
    _, rep = _violations([("coordinator/fx.rs",
                           "// audit:allow(Z9) what\n")])
    assert rep["total_violations"] == 1, rep
    checks += 1
    _, rep = _violations([("coordinator/fx.rs",
                           "// audit:allow(D2)\n")])
    assert rep["total_violations"] == 1, rep  # missing reason
    checks += 1
    _, rep = _violations([("coordinator/fx.rs",
                           "// audit:allow(D2) nothing here\n")])
    assert rep["total_violations"] == 1, rep  # unused waiver
    checks += 1

    # Scanner: comments and string/char literals never match; raw
    # strings are blanked on their own line.
    expect([("runtime/fx.rs",
             "// HashMap in a comment\n"
             "let s = \"HashMap Instant::now unsafe\";\n"
             "let r = r#\"HashSet .unwrap()\"#;\n"
             "let c = '\"'; let l: &'static str = \"x\";\n")], [])
    checks += 1 - 1  # expect() already counted

    return checks


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def main(argv):
    root = os.path.normpath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "..", ".."))
    json_out = None
    args = list(argv[1:])
    while args:
        a = args.pop(0)
        if a == "--root" and args:
            root = args.pop(0)
        elif a == "--json" and args:
            json_out = args.pop(0)
        else:
            sys.stderr.write("usage: auditsim.py [--root DIR] "
                             "[--json PATH]\n")
            return 2

    checks = selftest()
    print("auditsim self-tests: %d fixture checks OK" % checks)

    files = walk_sources(root)
    rep = audit(files)
    print("pard auditsim — scanned %d files under rust/src"
          % rep["files_scanned"])
    for rule in sorted(RULES):
        rc = rep["rules"][rule]
        print("  %s  %d violations, %d waived"
              % (rule, rc["violations"], rc["waived"]))
    for v in rep["violations"]:
        print("  %s:%d: %s %s" % (v["file"], v["line"], v["rule"],
                                  v["msg"]))
    for e in rep["waiver_errors"]:
        print("  %s:%d: waiver error: %s" % (e["file"], e["line"],
                                             e["msg"]))
    for w in rep["waived"]:
        print("  waived %s at %s:%d — %s" % (w["rule"], w["file"],
                                             w["line"], w["reason"]))
    if json_out:
        with open(json_out, "w", encoding="utf-8") as fh:
            json.dump(rep, fh, indent=1, sort_keys=True)
            fh.write("\n")

    if rep["total_violations"]:
        print("AUDIT FAIL — %d unwaived violation(s)"
              % rep["total_violations"])
        return 1
    print("AUDIT OK — 0 violations, %d waived" % rep["total_waived"])
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
