"""Python mirror of rust/src/runtime/host.rs for design validation.

sim.py mirrors the scalar reference oracle (reference.rs); this file
mirrors the host fast path's *restructurings* (host.rs, DESIGN.md §8)
and asserts they cannot change a live output:

  1. dead-cell skip       — parked (garbage-slot) columns are dropped
                            before any compute; live outputs equal the
                            oracle's, parked outputs are zeros.
  2. in-place cache read  — the per-layer transient cache copy is
                            replaced by a slot -> staged-column map over
                            the persistent tensor; attended bytes are
                            identical by construction.
  3. hoisted rope tables  — sin/cos(pos * inv_freq) computed once per
                            call instead of per layer/head; same values.
  4. commit equivalence   — staged K/V scattered by the host path land
                            exactly where the oracle's scatter puts them.
  5. end-to-end decode    — AR+ greedy streams through the host-style
                            fwd are token-identical to sim.py's.
  6. packed + fused sweep — the column-panel packed weight layout and
                            the fused QKV / W1W3 matmuls (host.rs
                            PackedMat) reproduce the canonical
                            k-ascending matmul bit for bit, for any
                            panel partition / lane order — the §8
                            column-decomposition bit-safety claim.
  7. prefix sharing + COW — rows mapping another row's cached full
                            blocks read bit-identical bytes to a
                            private dense prefill (same tokens, same
                            positions, deterministic weights ⇒ same
                            K/V), suffix-only prefill reproduces the
                            full prefill exactly, and a copy-on-write
                            divergence never leaks into the sharing
                            row (cache.rs prefix pool, DESIGN.md §7).
  8. sampling accept/residual — mirror of coordinator/sampling.rs
                            (DESIGN.md §6): exact first-max one-hot at
                            temperature 0, f64-accumulated CDF walks,
                            and the spec_accept/residual construction
                            preserving the target distribution with
                            the zero-mass-proposal reject guard.
  9. speculation policy    — mirror of coordinator/policy.rs + the
                            work-costed batcher clock (DESIGN.md §9):
                            the integer K rule bit for bit, the
                            windowed accounting (zero-offered skip,
                            admit clear, pinned == fixed collapse),
                            and a line-for-line replay of the
                            rust/tests/adaptive_policy.rs strict-win
                            and dual-mode gates — same mixed trace,
                            same scripted engine, same numbers.
 10. int8 per-panel quant  — mirror of runtime/quant.rs (the
                            `--backend host-q8` twin): symmetric
                            per-panel scales, half-away-from-zero
                            rounding (numpy's round is half-even — the
                            mirror reproduces Rust's f32::round
                            explicitly), the zero-accumulator panel
                            sweep with the scale applied once per
                            chain, partition/order invariance bit for
                            bit, and the relaxed end-to-end contract:
                            q8 logits differ from f32 but stay inside
                            a small absolute bound.

Both mirrors use the same numpy primitives over the same values, so
equality here is exact (==), not approximate.  As with sim.py this
validates the design, not the f32 bit patterns of the Rust build —
rust/tests/host_backend.rs does that where a toolchain exists.
"""
import numpy as np

import sim
from sim import DH, EOS, S_MAX, Model, commit, fwd, synth_prompts


def fwd_host(m, tokens, pos, cache_k, cache_v):
    """b=1 host-path forward: dead-column skip + map-based in-place
    cache reads + rope tables hoisted out of the layer loop.  Returns
    (logits [T,V], k_stage [L,T,hd], v_stage) in the full call layout
    with zeros at parked columns."""
    sim.MODEL_INV_FREQ = m.inv_freq
    t = len(tokens)
    d, h, hd = m.d, m.h, m.h * DH
    half = DH // 2

    # same truncated-view bound as the oracle
    garbage = S_MAX - 1
    clamped = [int(np.clip(p, 0, S_MAX - 1)) for p in pos]
    live_ps = [p for p in clamped if p < garbage]
    s_used = (max(live_ps) + 1) if live_ps else 1

    # (1) dead-cell skip: gather live columns only
    cells = [c for c in range(t) if clamped[c] < s_used]
    logits_out = np.zeros((t, sim.VOCAB), np.float32)
    k_out = np.zeros((m.L, t, hd), np.float32)
    v_out = np.zeros((m.L, t, hd), np.float32)
    n = len(cells)
    if n == 0:
        return logits_out, k_out, v_out
    ps = [clamped[c] for c in cells]
    x = m.embed[np.array([tokens[c] for c in cells])]

    # (3) rope tables hoisted: one sin/cos row per live cell — from the
    # RAW position, like the oracle (clamping is for slots only)
    praw = np.array([pos[c] for c in cells], np.int32)
    ang = praw[:, None].astype(np.float32) * m.inv_freq[None, :]
    cos_t, sin_t = np.cos(ang), np.sin(ang)  # [n, half]

    # (2) slot -> staged-column map (later columns win, like scatter)
    staged_at = np.full(s_used, -1, np.int64)
    for j, p in enumerate(ps):
        staged_at[p] = j

    k_live = np.zeros((m.L, n, hd), np.float32)
    v_live = np.zeros((m.L, n, hd), np.float32)
    for li, lyr in enumerate(m.layers):
        xn = sim.rmsnorm(x, d)
        q = (xn @ lyr["wq"]).astype(np.float32)
        k = (xn @ lyr["wk"]).astype(np.float32)
        v = (xn @ lyr["wv"]).astype(np.float32)

        # rope from the hoisted tables (same arithmetic as sim.rope)
        def rope_t(mat):
            mr = mat.reshape(n, h, DH)
            x1 = mr[:, :, :half]
            x2 = mr[:, :, half:]
            out = np.concatenate(
                [x1 * cos_t[:, None, :] - x2 * sin_t[:, None, :],
                 x1 * sin_t[:, None, :] + x2 * cos_t[:, None, :]], -1)
            return out.reshape(n, hd).astype(np.float32)

        q = rope_t(q)
        k = rope_t(k)
        k_live[li] = k
        v_live[li] = v

        # attention: resolve each attended slot through the map — this
        # call's staged K/V win, else the persistent tensor in place.
        attn = np.zeros((n, hd), np.float32)
        scale = np.float32(1.0 / np.sqrt(DH))
        for j in range(n):
            p = ps[j]
            rows = np.empty((p + 1, hd), np.float32)
            vrows = np.empty((p + 1, hd), np.float32)
            for s in range(p + 1):
                jj = staged_at[s]
                if jj >= 0:
                    rows[s] = k[jj]
                    vrows[s] = v[jj]
                else:
                    rows[s] = cache_k[li, s]
                    vrows[s] = cache_v[li, s]
            ckh = rows.reshape(p + 1, h, DH)
            cvh = vrows.reshape(p + 1, h, DH)
            qh = q[j].reshape(h, DH)
            sc = np.einsum("hd,shd->hs", qh, ckh) * scale
            sc = sc - sc.max(axis=1, keepdims=True)
            w = np.exp(sc)
            w = w / w.sum(axis=1, keepdims=True)
            attn[j] = np.einsum("hs,shd->hd", w, cvh).reshape(hd)
        x = (x + attn @ lyr["wo"]).astype(np.float32)
        xn2 = sim.rmsnorm(x, d)
        g = (xn2 @ lyr["w1"]).astype(np.float32)
        u = (xn2 @ lyr["w3"]).astype(np.float32)
        act = g * (1.0 / (1.0 + np.exp(-g))) * u
        x = (x + act @ lyr["w2"]).astype(np.float32)

    # NOTE: numpy mirrors must use the *same* expression as sim.py here:
    # BLAS picks different accumulation orders for `embed.T` (view) vs a
    # contiguous transpose, which is exactly the class of reassociation
    # the Rust host path forbids (its embed_t matmul keeps the oracle's
    # per-cell k-ascending order; see host.rs).
    hidden = sim.rmsnorm(x, d)
    logits = (hidden @ m.embed.T).astype(np.float32)

    # scatter live results back to the call layout
    for j, c in enumerate(cells):
        logits_out[c] = logits[j]
        k_out[:, c] = k_live[:, j]
        v_out[:, c] = v_live[:, j]
    return logits_out, k_out, v_out


# -- packed/fused matmul mirror (host.rs PackedMat, DESIGN.md §8) -----

PANEL = 16  # mirrors host.rs PANEL


def matmul_acc(a, w, out):
    """Exact mirror of reference.rs matmul_acc: k-ascending accumulate
    into the existing `out` values, float32 rounding at every multiply
    and add.  (Deliberately NOT `a @ w`: BLAS reassociates, this chain
    is the canonical per-cell order both Rust backends share.)"""
    for k in range(a.shape[1]):
        out += a[:, k:k + 1] * w[k][None, :]
    return out


def pack_panels(w):
    """Column-panel packing mirror of host.rs PackedMat.pack: the
    matrix becomes a list of contiguous [din, <=PANEL] panels."""
    return [w[:, p:p + PANEL].copy()
            for p in range(0, w.shape[1], PANEL)]


def matmul_acc_panels(a, panels, out, order):
    """Panel-sweep mirror of host.rs matmul_acc_panels, over an
    arbitrary panel order (simulating any pool-lane partition).  Each
    panel keeps the k-ascending per-cell chain of matmul_acc."""
    for p in order:
        pan = panels[p]
        c0 = p * PANEL
        sub = out[:, c0:c0 + pan.shape[1]]
        for k in range(pan.shape[0]):
            sub += a[:, k:k + 1] * pan[k][None, :]
    return out


def check_packed_fused_matmul(m):
    """host.rs packs every weight matrix into column panels at build
    time and fuses [wq|wk|wv] and [w1|w3] into single sweeps.  Neither
    transform may change an output bit: each output cell's k-ascending
    reduction chain is untouched — packing moves *where* a weight
    lives, fusion moves *which call* computes a column, and the lane
    partition only picks *who* computes it."""
    rng = np.random.default_rng(123)
    lyr = m.layers[0]
    hd = m.h * DH
    n = 5
    xn = rng.standard_normal((m.d,)).astype(np.float32)
    xn = np.stack([xn * np.float32(0.1 * (j + 1)) for j in range(n)])
    # canonical separate projections
    q = matmul_acc(xn, lyr["wq"], np.zeros((n, hd), np.float32))
    k = matmul_acc(xn, lyr["wk"], np.zeros((n, hd), np.float32))
    v = matmul_acc(xn, lyr["wv"], np.zeros((n, hd), np.float32))
    # fused + packed sweep, three "lanes" running their panel chunks
    # out of order
    wqkv = np.concatenate([lyr["wq"], lyr["wk"], lyr["wv"]], axis=1)
    panels = pack_panels(wqkv)
    assert np.array_equal(np.concatenate(panels, axis=1), wqkv), \
        "panel packing must round-trip exactly"
    qkv = np.zeros((n, 3 * hd), np.float32)
    order = list(range(len(panels)))
    for lane in (order[2::3], order[0::3], order[1::3]):
        matmul_acc_panels(xn, panels, qkv, lane)
    assert np.array_equal(qkv[:, :hd], q), "fused Q diverged"
    assert np.array_equal(qkv[:, hd:2 * hd], k), "fused K diverged"
    assert np.array_equal(qkv[:, 2 * hd:], v), "fused V diverged"
    # same property for the fused MLP gate/up sweep
    ff = lyr["w1"].shape[1]
    g = matmul_acc(xn, lyr["w1"], np.zeros((n, ff), np.float32))
    u = matmul_acc(xn, lyr["w3"], np.zeros((n, ff), np.float32))
    w13 = np.concatenate([lyr["w1"], lyr["w3"]], axis=1)
    p13 = pack_panels(w13)
    gu = np.zeros((n, 2 * ff), np.float32)
    half_p = len(p13) // 2
    # a deliberately unbalanced 2-lane partition, run tail-first
    matmul_acc_panels(xn, p13, gu, list(range(half_p, len(p13))))
    matmul_acc_panels(xn, p13, gu, list(range(half_p)))
    assert np.array_equal(gu[:, :ff], g), "fused W1 diverged"
    assert np.array_equal(gu[:, ff:], u), "fused W3 diverged"
    print("  packed panels + fused QKV/W13 bit-identical under any "
          "lane order")


# -- int8 per-panel quantization mirror (runtime/quant.rs) ------------


def q8_round(x):
    """Mirror of Rust f32::round: round half AWAY from zero (numpy's
    `round` is half-to-even, which disagrees at every .5 boundary).
    The |x|+0.5 walk runs in float64, where the add is exact for every
    f32 input, so this reproduces f32::round of the f32 product bit
    for bit (in f32 itself, x+0.5 can round across an integer)."""
    x64 = x.astype(np.float64)
    return (np.sign(x64) * np.floor(np.abs(x64) + 0.5)).astype(np.float32)


def quantize_panels(w):
    """Mirror of quant.rs QuantizedMat::quantize: per column panel,
    scale = max|w|/127 (0 for an all-zero panel), codes =
    clamp(round_half_away(w * (1/scale)), -127, 127) as int8 — every
    arithmetic step in f32, like the Rust build.  Returns
    (panels, scales): live-column code blocks plus one scale each
    (Rust pads ragged tails with zero codes it never stores back)."""
    panels, scales = [], []
    for c0 in range(0, w.shape[1], PANEL):
        pan = w[:, c0:c0 + PANEL]
        amax = np.float32(np.max(np.abs(pan)))
        if amax == 0.0:
            panels.append(np.zeros(pan.shape, np.int8))
            scales.append(np.float32(0.0))
            continue
        scale = amax / np.float32(127.0)
        inv = np.float32(1.0) / scale
        q = np.clip(q8_round(pan * inv), -127.0, 127.0)
        panels.append(q.astype(np.int8))
        scales.append(scale)
    return panels, scales


def matmul_acc_panels_q8(a, panels, scales, out, order):
    """Mirror of quant.rs matmul_acc_panels: widen each panel's int8
    codes to f32 (exact — |q| <= 127), run the SAME k-ascending f32
    chain as the f32 kernel but from a ZERO accumulator, then land
    `out += scale * acc` in one add.  Keeping the scale out of the
    chain makes every intermediate an exact small-integer combination,
    which is why this mirror can replay the Rust kernel's values."""
    for p in order:
        deq = panels[p].astype(np.float32)
        acc = np.zeros((a.shape[0], deq.shape[1]), np.float32)
        for k in range(deq.shape[0]):
            acc += a[:, k:k + 1] * deq[k][None, :]
        c0 = p * PANEL
        out[:, c0:c0 + deq.shape[1]] += np.float32(scales[p]) * acc
    return out


def check_q8_quantize_and_sweep():
    """quant.rs representation + kernel mirror: symmetric in-range
    codes hitting full scale, dequant error bounded by half a step,
    half-away-from-zero rounding (where numpy's default half-even
    disagrees), inert zero panels, and a panel sweep that matches a
    per-cell scalar chain replay bit for bit under any panel order."""
    rng = np.random.default_rng(321)
    w = rng.standard_normal((12, 21)).astype(np.float32)  # ragged tail
    panels, scales = quantize_panels(w)
    assert len(panels) == 2 and panels[1].shape[1] == 21 - PANEL
    hit_full = False
    for p, (pan, scale) in enumerate(zip(panels, scales)):
        assert scale > 0.0, "random panel must get a scale"
        assert np.all((pan >= -127) & (pan <= 127))
        hit_full |= bool(np.any(np.abs(pan) == 127))
        err = np.abs(w[:, p * PANEL:p * PANEL + pan.shape[1]]
                     - scale * pan.astype(np.float32))
        assert np.all(err <= scale * 0.5 + 1e-7), \
            "dequant error must stay within half a quantization step"
    assert hit_full, "some panel max must land a full-scale code"

    # rounding law: pin scale to 1.0 with a 127.0 entry, then place
    # exact .5 products — np.round would give 2 and -2 here.
    wr = np.zeros((2, PANEL), np.float32)
    wr[0, 0] = 127.0
    wr[0, 1] = 2.5
    wr[1, 2] = -2.5
    rp, rs = quantize_panels(wr)
    assert rs[0] == np.float32(1.0)
    assert rp[0][0, 1] == 3 and rp[0][1, 2] == -3, \
        "codes must round half away from zero like Rust f32::round"

    # zero panel: scale 0, codes 0, sweep leaves the output untouched
    wz = np.concatenate([np.zeros((4, PANEL), np.float32),
                         rng.standard_normal((4, 3)).astype(np.float32)],
                        axis=1)
    zp, zs = quantize_panels(wz)
    assert zs[0] == 0.0 and np.all(zp[0] == 0)
    base = np.full((2, PANEL + 3), 7.0, np.float32)
    out = matmul_acc_panels_q8(np.ones((2, 4), np.float32), zp, zs,
                               base.copy(), [0])
    assert np.array_equal(out[:, :PANEL], base[:, :PANEL]), \
        "a zero panel must add exactly nothing"

    # sweep == per-cell scalar chain replay, for any panel order
    a = rng.standard_normal((2, 12)).astype(np.float32)
    start = rng.standard_normal((2, 21)).astype(np.float32)
    want = start.copy()
    for p, (pan, scale) in enumerate(zip(panels, scales)):
        deq = pan.astype(np.float32)
        for i in range(a.shape[0]):
            for c in range(pan.shape[1]):
                acc = np.float32(0.0)
                for k in range(a.shape[1]):
                    acc = np.float32(acc + a[i, k] * deq[k, c])
                want[i, p * PANEL + c] = np.float32(
                    want[i, p * PANEL + c] + scale * acc)
    for order in ([0, 1], [1, 0]):
        got = matmul_acc_panels_q8(a, panels, scales, start.copy(),
                                   order)
        assert np.array_equal(got, want), \
            f"q8 sweep diverged from the scalar chain (order {order})"
    print("  q8 codes/scales/rounding + order-invariant sweep verified")


def check_q8_fwd_bounded(m):
    """The relaxed host-q8 contract end-to-end (quant.rs module docs):
    a b=1 prefill-style forward with every matmul weight quantized —
    fused QKV and W13, WO, W2, and the logits matrix, with the token
    embedding gather left f32 exactly like host.rs build_q8 — lands
    logits *near* the f32 chain's but never equal: bit-identity is
    traded for ~4x less weight traffic, bounded per-logit error kept."""
    hd, half = m.h * DH, DH // 2
    tokens = [0, 13, 20, 21, 33, 40]  # the bench.rs quant-probe call
    t = len(tokens)
    ang = (np.arange(t, dtype=np.float32)[:, None]
           * m.inv_freq[None, :])
    cos_t, sin_t = np.cos(ang), np.sin(ang)

    def fused(lyr):
        return [("wqkv", np.concatenate(
                    [lyr["wq"], lyr["wk"], lyr["wv"]], axis=1)),
                ("wo", lyr["wo"]),
                ("w13", np.concatenate([lyr["w1"], lyr["w3"]], axis=1)),
                ("w2", lyr["w2"])]

    q8 = [{name: quantize_panels(w) for name, w in fused(lyr)}
          for lyr in m.layers]
    logits_w = np.ascontiguousarray(m.embed.T)
    q8_logits = quantize_panels(logits_w)

    def rope_t(mat):
        mr = mat.reshape(t, m.h, DH)
        x1, x2 = mr[:, :, :half], mr[:, :, half:]
        out = np.concatenate(
            [x1 * cos_t[:, None, :] - x2 * sin_t[:, None, :],
             x1 * sin_t[:, None, :] + x2 * cos_t[:, None, :]], -1)
        return out.reshape(t, hd).astype(np.float32)

    def run(quant):
        def mm(x, w, qw):
            out = np.zeros((x.shape[0], w.shape[1]), np.float32)
            if quant:
                pans, scs = qw
                return matmul_acc_panels_q8(x, pans, scs, out,
                                            range(len(pans)))
            return matmul_acc(x, w, out)

        x = m.embed[np.array(tokens)]  # gather stays f32 on both paths
        for li, lyr in enumerate(m.layers):
            mats = dict(fused(lyr))
            xn = sim.rmsnorm(x, m.d)
            qkv = mm(xn, mats["wqkv"], q8[li]["wqkv"])
            q = rope_t(qkv[:, :hd])
            k = rope_t(qkv[:, hd:2 * hd])
            v = qkv[:, 2 * hd:]
            attn = np.zeros((t, hd), np.float32)
            scale = np.float32(1.0 / np.sqrt(DH))
            for j in range(t):  # causal: row j attends to 0..j
                ckh = k[:j + 1].reshape(j + 1, m.h, DH)
                cvh = v[:j + 1].reshape(j + 1, m.h, DH)
                qh = q[j].reshape(m.h, DH)
                sc = np.einsum("hd,shd->hs", qh, ckh) * scale
                sc = sc - sc.max(axis=1, keepdims=True)
                wt = np.exp(sc)
                wt = wt / wt.sum(axis=1, keepdims=True)
                attn[j] = np.einsum("hs,shd->hd", wt, cvh).reshape(hd)
            x = (x + mm(attn, mats["wo"], q8[li]["wo"])).astype(
                np.float32)
            xn2 = sim.rmsnorm(x, m.d)
            ff = m.layers[li]["w1"].shape[1]
            gu = mm(xn2, mats["w13"], q8[li]["w13"])
            g, u = gu[:, :ff], gu[:, ff:]
            act = g * (1.0 / (1.0 + np.exp(-g))) * u
            x = (x + mm(act, mats["w2"], q8[li]["w2"])).astype(
                np.float32)
        return mm(sim.rmsnorm(x, m.d), logits_w, q8_logits)

    lf, lq = run(False), run(True)
    err = float(np.max(np.abs(lf - lq)))
    peak = float(np.max(np.abs(lf)))
    assert err > 0.0, "q8 exactly equal to f32 is suspicious"
    # measured max across the family is ~0.018; 0.1 is ~5x headroom
    # (the Rust-side gate in tests/host_backend.rs is looser because it
    # cannot be recalibrated wherever a toolchain is missing)
    assert err < 0.1, \
        f"q8 per-logit error {err} breaks the bounded contract"
    print(f"  q8 fwd max |logit err| {err:.4f} (peak |logit| "
          f"{peak:.2f}): bounded, not bit-identical")


def fresh_cache(m):
    hd = m.h * DH
    return (np.zeros((m.L, S_MAX, hd), np.float32),
            np.zeros((m.L, S_MAX, hd), np.float32))


# -- paged block-table mirror (rust cache.rs, DESIGN.md §7) -----------

KV_BLOCK = 16  # mirrors cache.rs KV_BLOCK


class PagedKV:
    """Mirror of the Rust paged KV store for one batch row: a pool of
    `[2, L, KV_BLOCK, hd]` blocks, a block table mapping logical slot
    `s` to `(table[s // KV_BLOCK], s % KV_BLOCK)`, and a private
    write-only garbage block for the `S_MAX - 1` redirect.  Blocks are
    taken from a free list on first write, exactly like
    `ensure_covered` / `ensure_garbage` in cache.rs."""

    def __init__(self, m, n_blocks):
        hd = m.h * DH
        self.L = m.L
        self.pool_k = np.zeros((n_blocks, m.L, KV_BLOCK, hd), np.float32)
        self.pool_v = np.zeros((n_blocks, m.L, KV_BLOCK, hd), np.float32)
        self.free = list(range(n_blocks - 1, -1, -1))
        self.table = []
        self.garbage = None

    def _resolve(self, slot):
        """(pool block, in-block offset) for a logical slot, allocating
        on demand — the write-side mirror of cache.rs slot_index."""
        if slot == S_MAX - 1:
            if self.garbage is None:
                self.garbage = self.free.pop()
            return self.garbage, slot % KV_BLOCK
        while len(self.table) * KV_BLOCK <= slot:
            self.table.append(self.free.pop())
        return self.table[slot // KV_BLOCK], slot % KV_BLOCK

    def commit(self, ks, vs, pos):
        """sim.commit through the block table: same clamp, same
        later-column-wins order, relocated destination."""
        for col, p in enumerate(pos):
            s = int(np.clip(p, 0, S_MAX - 1))
            blk, off = self._resolve(s)
            self.pool_k[blk, :, off] = ks[:, col]
            self.pool_v[blk, :, off] = vs[:, col]

    def dense_view(self):
        """Gather the paged store back into the dense `[L, S_MAX, hd]`
        layout (unmapped slots zero) — the bridge the equality check
        rides on."""
        hd = self.pool_k.shape[-1]
        ck = np.zeros((self.L, S_MAX, hd), np.float32)
        cv = np.zeros((self.L, S_MAX, hd), np.float32)
        for lb, blk in enumerate(self.table):
            lo = lb * KV_BLOCK
            hi = min(lo + KV_BLOCK, S_MAX)
            ck[:, lo:hi] = self.pool_k[blk, :, :hi - lo]
            cv[:, lo:hi] = self.pool_v[blk, :, :hi - lo]
        if self.garbage is not None:
            off = (S_MAX - 1) % KV_BLOCK
            ck[:, S_MAX - 1] = self.pool_k[self.garbage, :, off]
            cv[:, S_MAX - 1] = self.pool_v[self.garbage, :, off]
        return ck, cv

    def blocks_in_use(self):
        return len(self.table) + (self.garbage is not None)


def check_paged_block_table(m):
    """Block-table addressing must be invisible: committing the same
    staged K/V through the paged store and through the dense layout,
    then decoding from each, gives bit-equal caches and logits at
    every step — including a speculative verify with rejected columns
    redirected to the garbage block."""
    prompt = [0, 17, 25, 30]
    ck_d, cv_d = fresh_cache(m)
    paged = PagedKV(m, n_blocks=S_MAX // KV_BLOCK + 1)
    pos = list(range(len(prompt)))
    logits, ks, vs = fwd_host(m, prompt, pos, ck_d, cv_d)
    commit(ck_d, cv_d, ks, vs, pos)
    paged.commit(ks, vs, pos)
    ck_p, cv_p = paged.dense_view()
    assert np.array_equal(ck_d, ck_p) and np.array_equal(cv_d, cv_p), \
        "paged commit diverged from dense layout"
    # speculative verify: pending commits live, two candidates rejected
    # to the garbage redirect (later column wins inside the block)
    toks = [31, 32, 33]
    vpos = [4, 5, 6]
    lv_d, ks, vs = fwd_host(m, toks, vpos, ck_d, cv_d)
    lv_p, ks_p, vs_p = fwd_host(m, toks, vpos, *paged.dense_view())
    assert np.array_equal(lv_d, lv_p), "paged verify logits diverged"
    assert np.array_equal(ks, ks_p) and np.array_equal(vs, vs_p)
    cpos = [4, S_MAX - 1, S_MAX - 1]
    commit(ck_d, cv_d, ks, vs, cpos)
    paged.commit(ks, vs, cpos)
    ck_p, cv_p = paged.dense_view()
    assert np.array_equal(ck_d, ck_p) and np.array_equal(cv_d, cv_p), \
        "garbage-block redirect diverged from dense garbage slot"
    # cached decode steps keep matching, reading through the table
    cur, nxt = 5, int(np.argmax(lv_d[0]))
    for _ in range(6):
        ld, ks, vs = fwd_host(m, [nxt], [cur], ck_d, cv_d)
        lp, _, _ = fwd_host(m, [nxt], [cur], *paged.dense_view())
        assert np.array_equal(ld, lp), "paged decode step diverged"
        commit(ck_d, cv_d, ks, vs, [cur])
        paged.commit(ks, vs, [cur])
        cur += 1
        nxt = int(np.argmax(ld[0]))
    assert paged.blocks_in_use() == 2, \
        "12 live slots + garbage = 1 live block + 1 garbage block"
    print("  paged block-table addressing bit-equal to the dense "
          "layout (live, garbage, decode)")


class PrefixPool:
    """Mirror of the Rust prefix-sharing pool: one block store shared
    by several logical rows, per-row tables, per-block refcounts, a
    content index over full committed blocks, and copy-on-write when a
    row commits into a block it shares — the write-side machinery of
    cache.rs reserve_row_prefixed / release_row_cached / cow_copy."""

    def __init__(self, m, n_blocks, rows):
        hd = m.h * DH
        self.L = m.L
        self.pool_k = np.zeros((n_blocks, m.L, KV_BLOCK, hd), np.float32)
        self.pool_v = np.zeros((n_blocks, m.L, KV_BLOCK, hd), np.float32)
        self.free = list(range(n_blocks - 1, -1, -1))
        self.tables = [[] for _ in range(rows)]
        self.refc = [0] * n_blocks
        self.index = {}    # token-prefix tuple -> block id
        self.owner = {}    # block id -> token-prefix tuple
        self.cow_copies = 0

    def register(self, row, tokens):
        """release_row_cached: index the row's full committed blocks
        under the token prefix they hold, then drop the row's refs."""
        for i in range(len(tokens) // KV_BLOCK):
            key = tuple(tokens[:(i + 1) * KV_BLOCK])
            blk = self.tables[row][i]
            if key not in self.index and blk not in self.owner:
                self.index[key] = blk
                self.owner[blk] = key
        for blk in self.tables[row]:
            self.refc[blk] -= 1
            if self.refc[blk] == 0 and blk not in self.owner:
                self.free.append(blk)
        self.tables[row] = []

    def map_prefix(self, row, tokens):
        """reserve_row_prefixed: map the longest cached block-aligned
        proper prefix; returns the matched token count."""
        matched = 0
        for i in range((len(tokens) - 1) // KV_BLOCK):
            key = tuple(tokens[:(i + 1) * KV_BLOCK])
            if key not in self.index:
                break
            blk = self.index[key]
            if self.refc[blk] == 0 and blk in self.free:
                self.free.remove(blk)
            self.refc[blk] += 1
            self.tables[row].append(blk)
            matched = (i + 1) * KV_BLOCK
        return matched

    def _writable(self, row, lb):
        """ensure_covered + the COW hook of host_scatter."""
        while len(self.tables[row]) <= lb:
            blk = self.free.pop()
            self.refc[blk] = 1
            self.tables[row].append(blk)
        blk = self.tables[row][lb]
        if self.refc[blk] > 1:
            fresh = self.free.pop()
            self.pool_k[fresh] = self.pool_k[blk]
            self.pool_v[fresh] = self.pool_v[blk]
            self.refc[blk] -= 1
            self.refc[fresh] = 1
            self.tables[row][lb] = fresh
            self.cow_copies += 1
        return self.tables[row][lb]

    def commit(self, row, ks, vs, pos):
        for col, p in enumerate(pos):
            s = int(np.clip(p, 0, S_MAX - 2))  # no garbage path here
            blk = self._writable(row, s // KV_BLOCK)
            self.pool_k[blk, :, s % KV_BLOCK] = ks[:, col]
            self.pool_v[blk, :, s % KV_BLOCK] = vs[:, col]

    def dense_view(self, row):
        hd = self.pool_k.shape[-1]
        ck = np.zeros((self.L, S_MAX, hd), np.float32)
        cv = np.zeros((self.L, S_MAX, hd), np.float32)
        for lb, blk in enumerate(self.tables[row]):
            lo = lb * KV_BLOCK
            hi = min(lo + KV_BLOCK, S_MAX)
            ck[:, lo:hi] = self.pool_k[blk, :, :hi - lo]
            cv[:, lo:hi] = self.pool_v[blk, :, :hi - lo]
        return ck, cv


def check_prefix_sharing_cow(m):
    """A row admitted over a cached 2-block prefix — suffix-only
    prefill through shared blocks — must hold bit-identical cache
    bytes and produce bit-identical logits to a private row that
    committed its own dense copy of the same prefix; a COW divergence
    stays private.  (Every comparison keeps equal call shapes: numpy's
    BLAS reassociates across different T — the docstring's mirror
    gotcha — whereas reference.rs/host.rs fix the per-cell order and
    are shape-independent by construction, unit-proven Rust-side by
    `commit_then_decode_matches_in_call_attention`.  What this check
    isolates is the sharing LAYOUT: shared blocks vs a private dense
    copy of the identical bytes.)"""
    base = [0] + [13 + (i % 17) for i in range(35)]  # 36 tokens
    pool = PrefixPool(m, n_blocks=12, rows=2)

    # row 0: prefill the 32-token prefix, commit, extend with its own
    # tail, commit, release with registration
    ppos = list(range(32))
    l0, k0, v0 = fwd_host(m, base[:32], ppos, *fresh_cache(m))
    pool.commit(0, k0, v0, ppos)
    tpos0 = list(range(32, len(base)))
    _, kt0, vt0 = fwd_host(m, base[32:], tpos0, *pool.dense_view(0))
    pool.commit(0, kt0, vt0, tpos0)
    pool.register(0, base)
    assert len(pool.index) == 2, "36 committed tokens = 2 full blocks"

    # row 1: same 32-token prefix, different tail
    tail = [40, 41, 42, 43]
    req = base[:32] + tail
    matched = pool.map_prefix(1, req)
    assert matched == 32, f"prefix hit must cover 2 blocks, got {matched}"
    # private dense baseline: commit an OWN copy of the same prefix
    # (identical call shape as row 0's prefill ⇒ identical bytes),
    # then the tail
    ck_d, cv_d = fresh_cache(m)
    lp, kp, vp = fwd_host(m, req[:32], ppos, ck_d, cv_d)
    assert np.array_equal(kp, k0) and np.array_equal(vp, v0), \
        "same tokens, same positions must stage identical K/V"
    commit(ck_d, cv_d, kp, vp, ppos)
    # suffix-only prefill through the SHARED blocks vs the private copy
    spos = list(range(32, len(req)))
    ls, ksuf, vsuf = fwd_host(m, req[32:], spos, *pool.dense_view(1))
    ld, kd, vd = fwd_host(m, req[32:], spos, ck_d, cv_d)
    assert np.array_equal(ls, ld), \
        "suffix prefill through shared blocks diverged from the \
         private dense copy"
    pool.commit(1, ksuf, vsuf, spos)
    commit(ck_d, cv_d, kd, vd, spos)
    ck_p, cv_p = pool.dense_view(1)
    assert np.array_equal(ck_p[:, :len(req)], ck_d[:, :len(req)]), \
        "shared-prefix cache bytes diverged from private prefill"
    assert np.array_equal(cv_p[:, :len(req)], cv_d[:, :len(req)])

    # decode steps through the shared table stay bit-identical
    cur, nxt = len(req), int(np.argmax(ls[-1]))
    for _ in range(4):
        lp, kp, vp = fwd_host(m, [nxt], [cur], *pool.dense_view(1))
        ld, kd, vd = fwd_host(m, [nxt], [cur], ck_d, cv_d)
        assert np.array_equal(lp, ld), "shared decode step diverged"
        pool.commit(1, kp, vp, [cur])
        commit(ck_d, cv_d, kd, vd, [cur])
        cur += 1
        nxt = int(np.argmax(lp[0]))

    # COW: row 0 remaps the prefix, then overwrites slot 3; row 1's
    # bytes must be untouched and row 0 gets a private copy
    pool.map_prefix(0, req)
    before = pool.dense_view(1)[0][:, 3].copy()
    poison_k = np.full((m.L, 1, m.h * DH), 7.25, np.float32)
    pool.commit(0, poison_k, poison_k, [3])
    assert pool.cow_copies == 1, "shared-block write must COW"
    assert np.array_equal(pool.dense_view(1)[0][:, 3], before), \
        "COW leaked into the sharing row"
    assert np.array_equal(pool.dense_view(0)[0][:, 3], poison_k[:, 0]), \
        "writer must see its own bytes"
    print("  prefix sharing: suffix prefill + shared reads bit-equal "
          "to private prefill; COW isolated")


def check_padded_call_matches_oracle(m):
    """Parked pad columns (garbage slot) must not change live logits,
    and the host path must produce zeros for them."""
    prompt = [0, 13, 20, 21]
    ck, cv = fresh_cache(m)
    ref_logits, ref_k, ref_v = fwd(m, prompt, [0, 1, 2, 3], ck, cv)
    g = S_MAX - 1
    toks = prompt + [sim.PAD] * 3
    pos = [0, 1, 2, 3, g, g, g]
    host_logits, host_k, host_v = fwd_host(m, toks, pos, ck, cv)
    assert np.array_equal(ref_logits, host_logits[:4]), "live logits diverged"
    assert np.array_equal(ref_k, host_k[:, :4]), "live staged K diverged"
    assert np.array_equal(ref_v, host_v[:, :4]), "live staged V diverged"
    assert not host_logits[4:].any(), "parked columns must be zeros"
    print("  padded-call live outputs identical, parked zeros OK")


def check_in_place_cache_read(m):
    """Cached decode: host's map-based in-place read must equal the
    oracle's transient-copy semantics, step for step."""
    prompt = [0, 17, 25, 30]
    ck_r, cv_r = fresh_cache(m)
    ck_h, cv_h = fresh_cache(m)
    pos = list(range(len(prompt)))
    lr, kr, vr = fwd(m, prompt, pos, ck_r, cv_r)
    lh, kh, vh = fwd_host(m, prompt, pos, ck_h, cv_h)
    assert np.array_equal(lr, lh)
    commit(ck_r, cv_r, kr, vr, pos)
    commit(ck_h, cv_h, kh, vh, pos)
    assert np.array_equal(ck_r, ck_h) and np.array_equal(cv_r, cv_h), \
        "committed caches diverged"
    cur, nxt = len(prompt), int(np.argmax(lr[len(prompt) - 1]))
    for _ in range(8):
        lr, kr, vr = fwd(m, [nxt], [cur], ck_r, cv_r)
        lh, kh, vh = fwd_host(m, [nxt], [cur], ck_h, cv_h)
        assert np.array_equal(lr[0], lh[0]), "decode step logits diverged"
        commit(ck_r, cv_r, kr, vr, [cur])
        commit(ck_h, cv_h, kh, vh, [cur])
        cur += 1
        nxt = int(np.argmax(lr[0]))
    print("  in-place cache reads identical across 8 cached decode steps")


def check_speculative_layout(m):
    """PARD-shaped verify call: pending commits, candidates in-flight.
    The map must let in-call columns attend each other exactly like the
    oracle's scattered transient view."""
    prompt = [0, 13, 20]
    ck_r, cv_r = fresh_cache(m)
    pos = list(range(len(prompt)))
    _, kr, vr = fwd(m, prompt, pos, ck_r, cv_r)
    commit(ck_r, cv_r, kr, vr, pos)
    ck_h, cv_h = ck_r.copy(), cv_r.copy()
    # verify layout: pending at 3 + three candidates at 4..6 in-flight
    toks = [30, 31, 32, 33]
    vpos = [3, 4, 5, 6]
    lr, kr, vr = fwd(m, toks, vpos, ck_r, cv_r)
    lh, kh, vh = fwd_host(m, toks, vpos, ck_h, cv_h)
    assert np.array_equal(lr, lh), "verify-call logits diverged"
    # rejected candidates -> garbage slot, accepted prefix -> real slots
    cpos = [3, 4, S_MAX - 1, S_MAX - 1]
    commit(ck_r, cv_r, kr, vr, cpos)
    commit(ck_h, cv_h, kh, vh, cpos)
    assert np.array_equal(ck_r[:, :s_live(cpos)], ck_h[:, :s_live(cpos)])
    print("  speculative verify layout + garbage-slot commit identical")


def s_live(cpos):
    return max(p for p in cpos if p < S_MAX - 1) + 1


def check_end_to_end_streams(m, task, n, max_new):
    """AR+ greedy decode through the host-style fwd must reproduce
    sim.py's streams token for token."""
    hd = m.h * DH
    for p in synth_prompts(task, 7)[:n]:
        ref = sim.ar_plus_decode(m, p, max_new)
        ck, cv = fresh_cache(m)
        pos = list(range(len(p)))
        logits, ks, vs = fwd_host(m, p, pos, ck, cv)
        commit(ck, cv, ks, vs, pos)
        cur = len(p)
        nxt = int(np.argmax(logits[len(p) - 1]))
        gen = [nxt]
        while len(gen) < max_new and gen[-1] != EOS:
            logits, ks, vs = fwd_host(m, [nxt], [cur], ck, cv)
            commit(ck, cv, ks, vs, [cur])
            cur += 1
            nxt = int(np.argmax(logits[0]))
            gen.append(nxt)
        assert gen == ref, f"host stream diverged: {gen} vs {ref}"
    print(f"  {n} AR+ streams token-identical (task={task}, "
          f"max_new={max_new})")


def check_out_of_range_pos(m):
    """A raw pos below 0 clamps to slot 0 (live) but must still rope
    with the raw value, exactly like the oracle."""
    ck, cv = fresh_cache(m)
    lr, kr, _ = fwd(m, [5], [-3], ck, cv)
    lh, kh, _ = fwd_host(m, [5], [-3], ck, cv)
    assert np.array_equal(lr, lh), "OOB-pos logits diverged"
    assert np.array_equal(kr, kh), "OOB-pos staged K diverged"
    print("  out-of-range pos ropes with raw value, identical")


# ---------------------------------------------------------------------------
# Stochastic sampling mirror (coordinator/sampling.rs, DESIGN.md §6)
# ---------------------------------------------------------------------------
#
# Mirrors the accept/residual math of the stochastic verification path:
# temperature softmax with an EXACT first-max one-hot at t=0 (never a
# tiny-temperature softmax, which splits tied mass), the nucleus filter
# with index tie-breaking, the f64-accumulated inverse-CDF walk, and
# spec_accept's min(1, p/q) acceptance with residual max(p-q,0)
# resampling — including the q[x]==0 guard that must REJECT when the
# target gives x no mass.  Probabilities stay float32 like the Rust
# side; only CDF accumulation runs at f64.


def sm_softmax(row, temperature):
    row = np.asarray(row, dtype=np.float32)
    p = np.zeros(len(row), dtype=np.float32)
    if temperature <= 0.0:
        p[int(np.argmax(row))] = 1.0  # np.argmax: first max, like Rust
        return p
    z = np.exp((row - row.max()) / np.float32(temperature),
               dtype=np.float32)
    return (z / z.sum(dtype=np.float32)).astype(np.float32)


def sm_top_p(p, top_p):
    if top_p >= 1.0 or len(p) == 0:
        return p
    idx = sorted(range(len(p)), key=lambda i: (-p[i], i))
    cum, keep = 0.0, len(p)
    for n, i in enumerate(idx):
        cum += float(p[i])
        if cum >= top_p:
            keep = n + 1
            break
    out = np.zeros_like(p)
    kept = idx[:keep]
    s = np.float32(sum(p[i] for i in kept))
    for i in kept:
        out[i] = p[i] / s
    return out


def sm_dist(row, temperature, top_p):
    return sm_top_p(sm_softmax(row, temperature), top_p)


def sm_sample(p, u):
    acc = 0.0  # f64 accumulation against the f64 draw
    for i, pi in enumerate(p):
        acc += float(pi)
        if u < acc:
            return i
    nz = [i for i, pi in enumerate(p) if pi > 0.0]
    return nz[-1] if nz else 0


def sm_spec_accept(p, q, x, rng):
    if q[x] <= 0.0:
        ratio = 1.0 if p[x] > 0.0 else 0.0
    else:
        ratio = min(1.0, float(p[x]) / float(q[x]))
    if rng.random() < ratio:
        return True, x
    resid = np.maximum(p - q, 0.0).astype(np.float32)
    s = resid.sum(dtype=np.float32)
    if s <= 0.0:
        return False, sm_sample(p, rng.random())
    return False, sm_sample(resid / s, rng.random())


def check_sampling_t0_and_cdf():
    """t=0 is the exact first-max one-hot (ties included, top-p
    ignored), and the f64 CDF walk never emits a zero-mass token."""
    p = sm_softmax([1.0, 7.0, -2.0, 7.0], 0.0)
    assert list(p) == [0.0, 1.0, 0.0, 0.0], "t=0 must one-hot FIRST max"
    assert list(sm_dist([1.0, 7.0, -2.0], 0.0, 0.3)) == [0.0, 1.0, 0.0]
    rng = np.random.default_rng(17)
    for e in range(3, 30):
        eps = np.float32(10.0 ** -e)
        pd = np.array([1.0 - eps, eps, 0.0], dtype=np.float32)
        for _ in range(500):
            assert sm_sample(pd, rng.random()) < 2, \
                "sampled a zero-probability bin"
    pf = np.array([0.5, 0.4999, 0.0, 0.0], dtype=np.float32)
    for _ in range(2000):
        assert sm_sample(pf, rng.random()) < 2, \
            "fallback must land on the last NONZERO bin"
    print("  t=0 exact one-hot (ties, top-p) + f64 CDF walk verified")


def check_sampling_accept_residual(trials=40_000):
    """spec_accept preserves the target distribution (with support
    holes on both sides), rejects zero-target-mass proposals, and
    reduces to greedy on t=0 one-hots."""
    rng = np.random.default_rng(29)
    p = np.array([0.0, 0.35, 0.15, 0.3, 0.2], dtype=np.float32)
    q = np.array([0.3, 0.0, 0.2, 0.25, 0.25], dtype=np.float32)
    counts = np.zeros(len(p), dtype=np.int64)
    accepts = 0
    for _ in range(trials):
        x = sm_sample(q, rng.random())
        ok, tok = sm_spec_accept(p, q, x, rng)
        accepts += ok
        counts[tok] += 1
    assert counts[0] == 0, "emitted a token outside the target support"
    freq = counts / trials
    assert np.abs(freq - p).max() < 0.02, \
        f"output dist {freq} strayed from target {p}"
    alpha = float(np.minimum(p, q).sum())
    assert abs(accepts / trials - alpha) < 0.02, \
        f"accept rate {accepts / trials:.4f} vs sum min(p,q) {alpha:.4f}"

    hot = lambda i: sm_softmax([9.0 if j == i else 0.0
                                for j in range(4)], 0.0)
    for _ in range(200):
        ok, tok = sm_spec_accept(hot(2), hot(2), 2, rng)
        assert ok and tok == 2
        ok, tok = sm_spec_accept(hot(1), hot(2), 2, rng)
        assert not ok and tok == 1, "residual must BE the target argmax"
        ok, tok = sm_spec_accept(np.zeros(3, dtype=np.float32) + [0.0, 0.6, 0.4],
                                 np.zeros(3, dtype=np.float32) + [0.0, 0.4, 0.6],
                                 0, rng)
        assert not ok and tok != 0, "q[x]=0, p[x]=0 must reject"
    print(f"  accept/residual preserves target dist "
          f"(alpha={alpha:.3f}, {trials} trials); t=0 reduces to greedy")


# ---------------------------------------------------------------------------
# Speculation-policy mirror (coordinator/policy.rs + batcher.rs, DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# The Rust controller is a pure function of integer acceptance history
# plus batch occupancy — no wall clock, no floats in the K rule — so
# this mirror is exact, not approximate.  It replays the two
# rust/tests/adaptive_policy.rs gates (strict win over both fixed-K
# corners on the work-costed clock; dual-mode switch in and back out)
# through the same mixed trace, the same scripted-acceptance engine,
# and the same serve loop, token for token and second for second.

POL_K_LIMIT = 16  # mirrors policy.rs K_LIMIT

# scripted-engine constants (mirrors rust/tests/adaptive_policy.rs)
POL_DRAFT_UNITS = 1
POL_TARGET_UNITS = 8
POL_PASS_S = 1.0
POL_COL_S = 0.05


def pol_k_for_rate(acc, off, k_min, k_max, k_init):
    """Exact mirror of policy.rs k_for_rate: k_min plus the
    rate-proportional share of the span, round-half-up, all in
    (arbitrary-precision, hence u64-exact) integer arithmetic."""
    if off == 0:
        return min(max(k_init, k_min), k_max)
    span = k_max - k_min
    return k_min + (span * 2 * acc + off) // (2 * off)


class PolicyMirror:
    """Mirror of policy.rs SpecPolicy: per-slot sliding windows of
    (offered, accepted), the occupancy-driven dual-mode flag, and the
    plan() decision order (dual check, then per-slot K, then the K
    histogram)."""

    def __init__(self, adaptive, k_min, k_max, window, tau, k_init,
                 batch):
        assert 1 <= k_min <= k_max <= POL_K_LIMIT and window >= 1
        self.adaptive = adaptive
        self.k_min, self.k_max = k_min, k_max
        self.window = window
        self.tau = tau
        self.k_init = k_init
        self.windows = [[] for _ in range(batch)]
        self.dual_mode = False
        self.mode_switches = 0
        self.dual_mode_iters = 0
        self.k_hist = {}

    def on_admit(self, slot):
        self.windows[slot] = []

    def on_acceptance(self, slot, offered, accepted):
        if offered == 0:  # AR+ step, not an acceptance observation
            return
        w = self.windows[slot]
        w.append((offered, accepted))
        del w[:max(0, len(w) - self.window)]

    def k_for_slot(self, slot):
        if not self.adaptive:
            return self.k_init
        off = sum(o for o, _ in self.windows[slot])
        acc = sum(a for _, a in self.windows[slot])
        return pol_k_for_rate(acc, off, self.k_min, self.k_max,
                              self.k_init)

    def plan(self, live):
        n_live = sum(live)
        dual = (self.adaptive and self.tau is not None
                and n_live >= self.tau * len(live))
        if dual != self.dual_mode:
            self.dual_mode = dual
            self.mode_switches += 1
        if dual:
            self.dual_mode_iters += 1
        ks = [0 if (not live[s] or dual) else self.k_for_slot(s)
              for s in range(len(live))]
        for s, k in enumerate(ks):
            if live[s]:
                self.k_hist[k] = self.k_hist.get(k, 0) + 1
        return ks


def check_policy_k_rule():
    """Exhaustive integer checks of the K rule over the same ranges as
    the policy.rs unit tests: bounds, endpoints, monotonicity, and the
    empty-history clamp."""
    for k_min in range(1, 5):
        for k_max in range(k_min, POL_K_LIMIT + 1):
            for off in range(1, 25):
                prev = 0
                for acc in range(off + 1):
                    k = pol_k_for_rate(acc, off, k_min, k_max, 8)
                    assert k_min <= k <= k_max, "K escaped its bounds"
                    assert k >= prev, "K not monotone in acceptance"
                    prev = k
                assert pol_k_for_rate(0, off, k_min, k_max, 8) == k_min
                assert pol_k_for_rate(off, off, k_min, k_max, 8) == k_max
            assert (pol_k_for_rate(0, 0, k_min, k_max, 8)
                    == min(max(8, k_min), k_max)), "cold start must clamp"
    # the documented round-half-up identity at a concrete point
    assert pol_k_for_rate(1, 2, 1, 16, 4) == 1 + (15 * 2 * 1 + 2) // 4
    print("  K rule: bounds/endpoints/monotone exhaustively verified")


def check_policy_windowing():
    """Windowed accounting mirror of the policy.rs unit tests: the
    sliding window ages records out, zero-offered steps are skipped,
    admit clears history, and a pinned controller collapses to
    fixed-K under ANY history."""
    p = PolicyMirror(True, 1, 16, 2, None, 4, 1)
    assert p.plan([True]) == [4], "cold start must plan k_init"
    p.on_acceptance(0, 4, 4)
    assert p.plan([True]) == [16], "full acceptance must reach k_max"
    p.on_acceptance(0, 16, 0)
    assert p.plan([True]) == [4], "mixed window: 4/20 -> 1 + round(3.0)"
    p.on_acceptance(0, 4, 0)
    assert p.plan([True]) == [1], "good record aged out -> k_min"
    p.on_acceptance(0, 0, 0)  # AR+ step: must not be an observation
    assert p.plan([True]) == [1] and len(p.windows[0]) == 2
    p.on_admit(0)
    assert p.plan([True]) == [4], "re-admission must clear history"
    # pinned == fixed for arbitrary histories and live masks
    rng = sim.Rng(99)
    pin = PolicyMirror(True, 5, 5, 4, None, 5, 3)
    fix = PolicyMirror(False, 1, POL_K_LIMIT, 8, None, 5, 3)
    for _ in range(40):
        live = [rng.below(4) > 0 for _ in range(3)]
        ks_p, ks_f = pin.plan(live), fix.plan(live)
        assert ks_p == ks_f, "pinned adaptive must collapse to fixed"
        for s, k in enumerate(ks_p):
            if live[s] and k > 0:
                pin.on_acceptance(s, k, rng.below(k + 1))
    assert pin.mode_switches == 0 and fix.mode_switches == 0
    print("  windowed accounting: aging, zero-offered skip, admit "
          "clear, pinned==fixed")


def pol_mixed_trace(n, seed):
    """Mirror of substrate/workload.rs build_mixed_trace over the
    adaptive_policy.rs base prompts ([0, 12+i] for i in 0..3): Closed
    arrivals, even requests easy (one repeated body token), odd hard
    (distinct-alphabet cycle).  Returns the prompt list."""
    rng = sim.Rng(seed ^ 0x4D49584544)  # "MIXED"
    alphabet = [12, 13, 14]   # base prompts' non-BOS tokens, in order
    distinct = [12, 13, 14]   # already sorted + deduped
    prompts = []
    for i in range(n):
        length = 4 + rng.below(6)
        prompt = [0]
        if i % 2 == 0:
            prompt += [alphabet[rng.below(len(alphabet))]] * length
        else:
            start = rng.below(len(distinct))
            prompt += [distinct[(start + j) % len(distinct)]
                       for j in range(length)]
        prompts.append(prompt)
    return prompts


def pol_serve_costed(prompts, max_new, batch, policy):
    """Mirror of batcher.rs serve_trace_virtual_costed driving the
    adaptive_policy.rs ScriptedSpecEngine: FCFS refill after harvest,
    one draft pass over all planned columns (skipped when nobody
    drafts), one verify pass over K+1 columns per live row, scripted
    acceptance (easy rows take everything, hard rows nothing), and
    dt = PASS_S * d(pass units) + COL_S * d(column units) per
    iteration.  Admission commits one token and charges no work,
    exactly like the Rust engine."""
    queue = list(range(len(prompts)))
    slots = [None] * batch       # request index per busy slot
    remaining = [0] * batch      # tokens still to commit per slot
    easy = [False] * batch
    now, wp, wc = 0.0, 0, 0
    generated, completed = 0, 0
    while True:
        for slot in range(batch):
            if slots[slot] is not None and remaining[slot] == 0:
                slots[slot] = None
                completed += 1
            if slots[slot] is None and queue:
                ri = queue.pop(0)
                body = prompts[ri][1:]
                easy[slot] = all(a == b
                                 for a, b in zip(body, body[1:]))
                policy.on_admit(slot)
                remaining[slot] = max_new - 1  # admit commits 1 token
                generated += 1
                slots[slot] = ri
        live = [slots[s] is not None for s in range(batch)]
        if not any(live):
            break  # Closed arrivals: empty batch means empty queue
        ks = policy.plan(live)
        wp0, wc0 = wp, wc
        draft_cols = sum(ks)
        if draft_cols > 0:
            wp += POL_DRAFT_UNITS
            wc += POL_DRAFT_UNITS * draft_cols
        ver_cols = sum(k + 1 for s, k in enumerate(ks) if live[s])
        wp += POL_TARGET_UNITS
        wc += POL_TARGET_UNITS * ver_cols
        for row in range(batch):
            if not live[row]:
                continue
            offered = ks[row]
            accepted = offered if easy[row] else 0
            policy.on_acceptance(row, offered, accepted)
            taken = min(accepted + 1, remaining[row])
            remaining[row] -= taken
            generated += taken
        now += (POL_PASS_S * (wp - wp0) + POL_COL_S * (wc - wc0))
    tps = generated / now if now > 0.0 else 0.0
    return {"completed": completed, "generated": generated,
            "wall_s": now, "tps": tps}


def check_policy_strict_win():
    """Replay of adaptive_strictly_beats_fixed_k2_and_k16: on the
    seed-7 mixed trace (16 requests, max_new 32, batch 4) every policy
    serves the same 512 tokens, but the adaptive controller is
    strictly faster than BOTH fixed corners on the costed clock —
    under-speculation loses on easy rows, over-speculation on hard."""
    prompts = pol_mixed_trace(16, 7)
    fixed = lambda k: PolicyMirror(False, 1, POL_K_LIMIT, 8, None, k, 4)
    s2 = pol_serve_costed(prompts, 32, 4, fixed(2))
    s16 = pol_serve_costed(prompts, 32, 4, fixed(16))
    pa = PolicyMirror(True, 1, 16, 4, None, 4, 4)
    sa = pol_serve_costed(prompts, 32, 4, pa)
    for s in (s2, s16, sa):
        assert s["completed"] == 16, "all requests must complete"
        assert s["generated"] == 16 * 32, "tokens are policy-invariant"
    assert sa["tps"] > s2["tps"], \
        f"adaptive {sa['tps']:.3f} must beat fixed K=2 {s2['tps']:.3f}"
    assert sa["tps"] > s16["tps"], \
        f"adaptive {sa['tps']:.3f} must beat fixed K=16 {s16['tps']:.3f}"
    assert max(pa.k_hist) >= 2, "the controller must visit K > 1"
    # replay-exact: same trace, same policy, same seconds
    sb = pol_serve_costed(prompts, 32, 4,
                          PolicyMirror(True, 1, 16, 4, None, 4, 4))
    assert sb == sa, "costed serve must replay bit-for-bit"
    print(f"  strict win: adaptive {sa['tps']:.3f} tok/s > "
          f"fixed-2 {s2['tps']:.3f} and fixed-16 {s16['tps']:.3f} "
          f"({sa['tps'] / s2['tps']:.3f}x / {sa['tps'] / s16['tps']:.3f}x)")


def check_policy_dual_mode():
    """Replay of dual_mode_degrades_to_ar_plus_and_switches_back: 13
    requests over 4 slots at tau=0.75 run three full waves in dual
    mode (K=0 everywhere) and a final 1-wide wave drafting again —
    exactly one switch in and one back out, with no tokens lost."""
    prompts = pol_mixed_trace(13, 7)
    pd = PolicyMirror(True, 1, 16, 4, 0.75, 4, 4)
    sd = pol_serve_costed(prompts, 16, 4, pd)
    assert sd["completed"] == 13 and sd["generated"] == 13 * 16
    assert pd.mode_switches == 2, "one switch in, one back out"
    assert pd.dual_mode_iters > 0 and pd.k_hist.get(0, 0) > 0
    pf = PolicyMirror(True, 1, 16, 4, None, 4, 4)
    sf = pol_serve_costed(prompts, 16, 4, pf)
    assert sf["generated"] == sd["generated"], \
        "dual mode commits one token per row, nothing is lost"
    assert pf.mode_switches == 0, "no threshold, no switching"
    print(f"  dual mode: {pd.dual_mode_iters} AR+ iterations, "
          f"2 switches, tokens preserved ({sd['generated']})")


# ---------------------------------------------------------------------------
# Fault-plan mirror (substrate/fault.rs + batcher.rs chaos paths,
# DESIGN.md §10)
# ---------------------------------------------------------------------------
#
# Mirrors the seeded fault schedule and the serving loop's recovery
# semantics: one decorrelated rng stream per spec, exactly one
# Bernoulli draw per spec per iteration-that-steps-a-live-batch, and
# the fault_prologue paths (worker panic caught + clean retry, bounded
# target retries then a single victim row failed, draft faults
# degrading to K=0 under greedy / holding under sampling).  The gate is
# the same as rust/tests/fault_injection.rs: non-faulted requests'
# token streams are bit-identical to a fault-free serve, and every
# robustness counter is predicted EXACTLY by replaying a clone of the
# plan — the schedule is a pure function of (specs, draw index).

FAULT_STREAMS = {"draft": 1, "target": 2, "pool": 3, "worker": 4}
FAULT_MAX_TARGET_RETRIES = 2  # mirrors fault.rs MAX_TARGET_RETRIES

# scripted chaos-engine constants (arbitrary but fixed work prices)
FI_DRAFT_UNITS = 1
FI_TARGET_UNITS = 8
FI_K = 4
FI_PASS_S = 1.0
FI_COL_S = 0.05


def rng_stream(seed, stream):
    """Mirror of rng.rs Rng::new_stream: both words pass through
    splitmix64 before seeding the xoshiro state, so adjacent stream
    ids decorrelate."""
    _, base = sim.splitmix64(seed & sim.M)
    y = (base ^ (stream * 0x9E3779B97F4A7C15)) & sim.M
    r = sim.Rng(0)
    s = []
    for _ in range(4):
        y, z = sim.splitmix64(y)
        s.append(z)
    r.s = s
    return r


class FaultPlanMirror:
    """Line-for-line mirror of fault.rs FaultPlan.  `specs` is a list
    of (kind, rate, seed); scripted one-shots fire by draw index, with
    scripted target faults persistent (fails = retries + 1, victim =
    iteration)."""

    def __init__(self, specs):
        self.specs = [(k, r, rng_stream(s, FAULT_STREAMS[k]))
                      for k, r, s in specs]
        self.scripted = []
        self.iteration = 0
        self.injected = 0

    def script(self, kind, iteration):
        self.scripted.append((kind, iteration))

    def begin_iteration(self):
        fs = {"injected": 0, "draft": False, "target": None,
              "pool": False, "worker": False}
        for kind, rate, rng in self.specs:
            if not rng.f64() < rate:  # Rng::chance
                continue
            fs["injected"] += 1
            if kind == "draft":
                fs["draft"] = True
            elif kind == "target":
                fails = 1 + rng.below(3)
                victim = rng.next_u64()
                if fs["target"] is None:  # first firing wins
                    fs["target"] = (fails, victim)
            elif kind == "pool":
                fs["pool"] = True
            else:
                fs["worker"] = True
        it = self.iteration
        for kind, when in self.scripted:
            if when != it:
                continue
            fs["injected"] += 1
            if kind == "draft":
                fs["draft"] = True
            elif kind == "target":
                fs["target"] = (FAULT_MAX_TARGET_RETRIES + 1, it)
            elif kind == "pool":
                fs["pool"] = True
            else:
                fs["worker"] = True
        self.iteration += 1
        self.injected += fs["injected"]
        return fs


FI_STORM_SPECS = [("draft", 0.25, 11), ("target", 0.15, 13),
                  ("pool", 0.10, 17)]


def fi_storm_plan():
    """The rust/tests/fault_injection.rs storm: every fault kind
    rate-driven plus one scripted worker panic at draw 5."""
    plan = FaultPlanMirror(FI_STORM_SPECS)
    plan.script("worker", 5)
    return plan


def fi_stream(ri, n):
    """Request ri's scripted token stream — a pure function of the
    request, which is exactly the property chaos bit-identity rides
    on (real engines get this from per-request prompts and
    per-admission-ordinal sampling streams)."""
    return [(ri * 97 + 11 * j) % 50_000 for j in range(n)]


def fi_expected(plan, draws):
    """Mirror of the Rust test's replay(): predict every robustness
    counter from a fresh plan by walking `draws` fault sets through
    the documented fault_prologue semantics."""
    e = {"faults_injected": 0, "draft_fallbacks": 0, "row_retries": 0,
         "rows_failed": 0, "pool_rebuilds": 0}
    for _ in range(draws):
        fs = plan.begin_iteration()
        e["faults_injected"] += fs["injected"]
        if fs["worker"]:
            # prologue panics before any other fault takes effect; the
            # armed set is consumed, so the one retry runs clean
            e["pool_rebuilds"] += 1
            continue
        if fs["target"] is not None:
            fails, _ = fs["target"]
            if fails > FAULT_MAX_TARGET_RETRIES:
                e["row_retries"] += FAULT_MAX_TARGET_RETRIES
                e["rows_failed"] += 1
                continue  # Skip: co-fired draft fault never lands
            e["row_retries"] += fails
        if fs["draft"]:
            e["draft_fallbacks"] += 1
    return e


def fi_serve_chaos(n_req, max_new, batch, plan, sampled,
                   deadline_budget=None):
    """Mirror of batcher.rs serve_trace_impl's chaos paths over a
    scripted speculation engine on the work-costed clock: deadline
    sweep -> harvest -> fault draw (only when rows survived harvest,
    so draws stay 1:1 with injected steps) -> admission (paused one
    iteration by a pool fault) -> step under the fault_prologue
    recovery semantics.  Closed arrivals at t=0; a clean iteration
    commits K+1 tokens per live row."""
    queue = list(range(n_req))
    slots = [None] * batch      # request index per busy slot
    committed = [0] * batch
    failed_at = [False] * batch
    outcomes = [None] * n_req
    m = {"faults_injected": 0, "draft_fallbacks": 0, "row_retries": 0,
         "rows_failed": 0, "pool_rebuilds": 0, "deadline_exceeded": 0}
    now, wp, wc = 0.0, 0, 0
    completed = failed = expired = 0
    while True:
        # deadline sweep (strict: now > deadline)
        if deadline_budget is not None and now > deadline_budget:
            for ri in queue:
                outcomes[ri] = ("deadline",)
                expired += 1
                m["deadline_exceeded"] += 1
            queue = []
            for slot in range(batch):
                # done rows (failed or finished) harvest below instead
                if slots[slot] is not None and not failed_at[slot] \
                        and committed[slot] < max_new:
                    outcomes[slots[slot]] = ("deadline",)
                    expired += 1
                    m["deadline_exceeded"] += 1
                    slots[slot] = None
        # harvest finished rows (failed rows were marked done by the
        # prologue and reap here, exactly like the Rust batcher)
        for slot in range(batch):
            if slots[slot] is None:
                continue
            ri = slots[slot]
            if failed_at[slot]:
                outcomes[ri] = ("failed",)
                failed += 1
                failed_at[slot] = False
                slots[slot] = None
            elif committed[slot] >= max_new:
                outcomes[ri] = ("completed", fi_stream(ri, max_new))
                completed += 1
                slots[slot] = None
        # fault draw: only when surviving rows guarantee a step below
        live_before = sum(s is not None for s in slots)
        if plan is not None and live_before > 0:
            fs = plan.begin_iteration()
            m["faults_injected"] += fs["injected"]
        else:
            fs = {"injected": 0, "draft": False, "target": None,
                  "pool": False, "worker": False}
        # admission: FCFS refill, paused for one iteration by a
        # transient pool-exhaustion fault
        if not fs["pool"]:
            for slot in range(batch):
                if slots[slot] is None and queue:
                    slots[slot] = queue.pop(0)
                    committed[slot] = 0
        live = [s for s in range(batch) if slots[s] is not None]
        if not live:
            if not queue:
                break
            continue  # pool fault emptied admission; redraw next pass
        # step: fault_prologue semantics, then scripted commits
        wp0, wc0 = wp, wc
        if fs["worker"]:
            m["pool_rebuilds"] += 1
            fs = {"injected": 0, "draft": False, "target": None,
                  "pool": False, "worker": False}  # consumed; retry clean
        skip = False
        force_k0 = False
        if fs["target"] is not None:
            fails, victim = fs["target"]
            if fails > FAULT_MAX_TARGET_RETRIES:
                wp += (FAULT_MAX_TARGET_RETRIES + 1) * FI_TARGET_UNITS
                m["row_retries"] += FAULT_MAX_TARGET_RETRIES
                m["rows_failed"] += 1
                failed_at[live[victim % len(live)]] = True
                skip = True
            else:
                wp += fails * FI_TARGET_UNITS
                m["row_retries"] += fails
        if not skip and fs["draft"]:
            m["draft_fallbacks"] += 1
            wp += FI_DRAFT_UNITS  # the lost draft pass
            if sampled:
                skip = True  # hold: commit nothing, consume no rng
            else:
                force_k0 = True  # lossless AR+ commit
        if not skip:
            k = 0 if force_k0 else FI_K
            if k > 0:
                wp += FI_DRAFT_UNITS
                wc += FI_DRAFT_UNITS * k * len(live)
            wp += FI_TARGET_UNITS
            wc += FI_TARGET_UNITS * (k + 1) * len(live)
            for slot in live:
                committed[slot] = min(committed[slot] + k + 1, max_new)
        now += FI_PASS_S * (wp - wp0) + FI_COL_S * (wc - wc0)
    return {"completed": completed, "failed": failed,
            "expired": expired, "outcomes": outcomes, "wall_s": now,
            "metrics": m,
            "draws": plan.iteration if plan is not None else 0}


def check_fault_plan_mirror():
    """fault.rs unit semantics: clone-replay is bit-exact, rate-0/1
    corners, and scripted one-shots fire exactly once (with persistent
    target shape)."""
    a = FaultPlanMirror([("draft", 0.3, 7), ("target", 0.2, 9),
                         ("pool", 0.1, 5), ("worker", 0.05, 3)])
    b = FaultPlanMirror([("draft", 0.3, 7), ("target", 0.2, 9),
                         ("pool", 0.1, 5), ("worker", 0.05, 3)])
    for _ in range(256):
        assert a.begin_iteration() == b.begin_iteration(), \
            "fault schedule must replay bit-for-bit"
    assert a.injected == b.injected and a.injected > 0
    p = FaultPlanMirror([("draft", 0.0, 1), ("pool", 1.0, 2)])
    for _ in range(64):
        fs = p.begin_iteration()
        assert not fs["draft"] and fs["pool"] and fs["injected"] == 1
    p = FaultPlanMirror([])
    p.script("worker", 3)
    p.script("target", 5)
    for it in range(8):
        fs = p.begin_iteration()
        assert fs["worker"] == (it == 3)
        if it == 5:
            assert fs["target"] == (FAULT_MAX_TARGET_RETRIES + 1, 5), \
                "scripted target faults are persistent"
        else:
            assert fs["target"] is None
    assert p.injected == 2 and p.iteration == 8
    print("  fault plan: replay bit-exact, rate corners, scripted "
          "one-shots")


def check_chaos_serve(sampled):
    """The fault_injection.rs gate over the scripted engine: the storm
    serve survives, non-faulted requests are bit-identical to the
    fault-free run, failed rows end typed, and every counter equals
    the plan replay's prediction."""
    n_req, max_new, batch = 16, 16, 4
    calm = fi_serve_chaos(n_req, max_new, batch, None, sampled)
    assert calm["completed"] == n_req and calm["failed"] == 0
    storm = fi_serve_chaos(n_req, max_new, batch, fi_storm_plan(),
                           sampled)
    assert storm["completed"] + storm["failed"] == n_req, \
        "every request must end in exactly one typed outcome"
    n_failed = 0
    for ri in range(n_req):
        s, c = storm["outcomes"][ri], calm["outcomes"][ri]
        if s[0] == "failed":
            n_failed += 1
        else:
            assert s == c, \
                f"request {ri}: non-faulted stream diverged"
    draws = storm["draws"]
    assert draws > 5, "the serve must reach the scripted panic"
    exp = fi_expected(fi_storm_plan(), draws)
    got = {k: storm["metrics"][k] for k in exp}
    assert got == exp, f"counters {got} != plan replay {exp}"
    assert exp["pool_rebuilds"] == 1, "exactly the scripted panic"
    assert n_failed == exp["rows_failed"]
    assert exp["draft_fallbacks"] > 0, "a 25% draft rate must fire"
    again = fi_serve_chaos(n_req, max_new, batch, fi_storm_plan(),
                           sampled)
    assert again["outcomes"] == storm["outcomes"] \
        and again["wall_s"] == storm["wall_s"], \
        "chaos serve must replay bit-for-bit"
    mode = "sampled(hold)" if sampled else "greedy(K=0)"
    print(f"  chaos serve [{mode}]: {storm['completed']} ok / "
          f"{storm['failed']} failed over {draws} draws, counters "
          f"exact, survivors bit-identical")


def check_deadline_sweep():
    """Budget-0 deadlines: everything expires typed (queued and
    in-flight), nothing completes, and the counters are per-event."""
    r = fi_serve_chaos(16, 16, 4, None, False, deadline_budget=0.0)
    assert r["expired"] == 16 and r["completed"] == 0
    assert all(o == ("deadline",) for o in r["outcomes"])
    assert r["metrics"]["deadline_exceeded"] == 16
    print("  deadline sweep: budget 0 expires all 16 requests typed")


def main(seed=7):
    for name in ["draft-s", "target-m", "target-l"]:
        print(f"{name}:")
        m = Model(seed, name)
        check_padded_call_matches_oracle(m)
        check_in_place_cache_read(m)
        check_speculative_layout(m)
        check_out_of_range_pos(m)
        check_packed_fused_matmul(m)
        check_q8_fwd_bounded(m)
        check_paged_block_table(m)
        check_prefix_sharing_cow(m)
    check_end_to_end_streams(Model(seed, "target-m"), "code", 4, 16)
    check_end_to_end_streams(Model(seed, "draft-s"), "gsm", 3, 12)
    print("quant:")
    check_q8_quantize_and_sweep()
    print("sampling:")
    check_sampling_t0_and_cdf()
    check_sampling_accept_residual()
    print("policy:")
    check_policy_k_rule()
    check_policy_windowing()
    check_policy_strict_win()
    check_policy_dual_mode()
    print("faults:")
    check_fault_plan_mirror()
    check_chaos_serve(sampled=False)
    check_chaos_serve(sampled=True)
    check_deadline_sweep()
    print("ALL HOST-PATH EQUIVALENCE CHECKS PASSED")


if __name__ == "__main__":
    main()
