"""Simulate the rust engine protocols over the mirrored reference model:
PARD (parallel mask draft) and VSD (chained draft) vs AR+ — checks the
lossless protocol end-to-end, including garbage-slot commits, tentative
candidate KV overwrites, and mask in-flight attention."""
import numpy as np
from sim import (Model, Rng, key_seed, fwd, commit, synth_prompts,
                 ar_plus_decode, VOCAB, S_MAX, EOS, PAD, MASK, DH)

GARBAGE = S_MAX - 1
PREFILL_T = 32

def new_cache(m):
    hd = m.h * DH
    return (np.zeros((m.L, S_MAX, hd), np.float32),
            np.zeros((m.L, S_MAX, hd), np.float32))

def prefill(m, ck, cv, prompt):
    t = max(len(prompt), PREFILL_T)
    toks = list(prompt) + [PAD] * (t - len(prompt))
    pos = list(range(len(prompt))) + [GARBAGE] * (t - len(prompt))
    logits, ks, vs = fwd(m, toks, pos, ck, cv)
    commit(ck, cv, ks, vs, pos)  # pads land in the garbage slot
    return int(np.argmax(logits[len(prompt) - 1]))

def greedy_accept(cands, preds):
    acc = 0
    committed = []
    for j, c in enumerate(cands):
        if c == preds[j]:
            acc += 1
            committed.append(c)
        else:
            break
    committed.append(preds[acc])
    return acc, committed

def push(stream, committed, plen, max_new):
    taken = []
    done = False
    for t in committed:
        stream.append(t)
        taken.append(t)
        if t == EOS or len(stream) - plen >= max_new:
            done = True
            break
    return done

def pard_decode(tm, dm, prompt, k, max_new, distinct=False):
    tck, tcv = new_cache(tm)
    dck, dcv = new_cache(dm)
    stream = list(prompt)
    first = prefill(tm, tck, tcv, prompt)
    prefill(dm, dck, dcv, prompt)
    stream.append(first)
    done = first == EOS or max_new <= 1
    target_len = len(stream) - 1
    draft_len = len(prompt)
    plen = len(prompt)
    iters = 0
    accepts = []
    masks = list(range(4, 12))
    while not done:
        iters += 1
        # --- one parallel draft pass
        reals = stream[draft_len:]
        toks = list(reals)
        pos = list(range(draft_len, draft_len + len(reals)))
        base_m = len(stream)
        for j in range(k - 1):
            mid = MASK if not distinct else masks[min(j, len(masks) - 1)]
            toks.append(mid)
            pos.append(base_m + j)
        logits, ks, vs = fwd(dm, toks, pos, dck, dcv)
        cpos = pos[: len(reals)] + [GARBAGE] * (k - 1)  # masks never commit
        commit(dck, dcv, ks, vs, cpos)
        fed = len(reals)
        cands = [int(np.argmax(logits[fed - 1 + j])) for j in range(k)]
        draft_len = len(stream)
        # --- verify
        base = target_len
        vtoks = [stream[-1]] + cands
        vpos = list(range(base, base + k + 1))
        logits, ks, vs = fwd(tm, vtoks, vpos, tck, tcv)
        preds = [int(np.argmax(logits[i])) for i in range(k + 1)]
        acc, committed = greedy_accept(cands, preds)
        accepts.append(acc)
        vcpos = [base] + [base + 1 + j if j < acc else GARBAGE
                          for j in range(k)]
        commit(tck, tcv, ks, vs, vcpos)
        done = push(stream, committed, plen, max_new)
        target_len = len(stream) - 1
    return stream[plen:], iters, accepts

def vsd_decode(tm, dm, prompt, k, max_new):
    tck, tcv = new_cache(tm)
    dck, dcv = new_cache(dm)
    stream = list(prompt)
    first = prefill(tm, tck, tcv, prompt)
    prefill(dm, dck, dcv, prompt)
    stream.append(first)
    done = first == EOS or max_new <= 1
    target_len = len(stream) - 1
    draft_len = len(prompt)
    plen = len(prompt)
    iters = 0
    while not done:
        iters += 1
        # catch-up pass
        reals = stream[draft_len:]
        pos = list(range(draft_len, draft_len + len(reals)))
        logits, ks, vs = fwd(dm, reals, pos, dck, dcv)
        commit(dck, dcv, ks, vs, pos)
        cands = [int(np.argmax(logits[len(reals) - 1]))]
        draft_len = len(stream)
        # k-1 chained singles (tentative commits past draft_len)
        for j in range(1, k):
            p = draft_len + j - 1
            logits, ks, vs = fwd(dm, [cands[-1]], [p], dck, dcv)
            commit(dck, dcv, ks, vs, [p])
            cands.append(int(np.argmax(logits[0])))
        # verify
        base = target_len
        vtoks = [stream[-1]] + cands
        vpos = list(range(base, base + k + 1))
        logits, ks, vs = fwd(tm, vtoks, vpos, tck, tcv)
        preds = [int(np.argmax(logits[i])) for i in range(k + 1)]
        acc, committed = greedy_accept(cands, preds)
        vcpos = [base] + [base + 1 + j if j < acc else GARBAGE
                          for j in range(k)]
        commit(tck, tcv, ks, vs, vcpos)
        done = push(stream, committed, plen, max_new)
        target_len = len(stream) - 1
    return stream[plen:], iters

def ar_with_trunc(m, prompt, max_new):
    g = ar_plus_decode(m, prompt, max_new)
    return g

def main(seed=7):
    tm = Model(seed, "target-m")
    dm = Model(seed, "pard-main")
    prompts = synth_prompts("code", seed)[:3]
    ok = True
    for i, p in enumerate(prompts):
        base = ar_with_trunc(tm, p, 20)
        for k in (1, 2, 4, 8, 12, 16):
            out, iters, accepts = pard_decode(tm, dm, p, k, 20)
            if out != base:
                ok = False
                print(f"PARD MISMATCH prompt {i} k={k}: {out} vs {base}")
            else:
                alpha = (np.mean([a > 0 for a in accepts])
                         if accepts else 0)
                if k == 8 and i == 0:
                    print(f"prompt {i} k={k}: lossless, iters={iters}, "
                          f"gen={len(out)}, mean accepted="
                          f"{np.mean(accepts):.2f}")
        out, iters, accepts = pard_decode(tm, dm, p, 12, 20, distinct=True)
        if out != base:
            ok = False
            print(f"PARD-distinct MISMATCH prompt {i}")
        out, iters = vsd_decode(tm, dm, p, 8, 20)
        if out != base:
            ok = False
            print(f"VSD MISMATCH prompt {i}: {out} vs {base}")
    # self-draft full-accept check on draft-s
    ds = Model(seed, "draft-s")
    for p in prompts[:2]:
        base = ar_with_trunc(ds, p, 20)
        out, iters = vsd_decode(ds, ds, p, 4, 20)
        assert out == base
        gen = len(out)
        # +--- every iteration must commit k+1 (up to truncation)
        expect_iters = -(-(gen - 1) // 5) if gen > 1 else 0
        print(f"self-draft: gen={gen}, iters={iters} "
              f"(expect {expect_iters})")
        assert iters == expect_iters, "self-draft not accept-all!"
        outp, itersp, acc = pard_decode(ds, ds, p, 8, 20)
        assert outp == base
        assert all(a >= 1 for a in acc), f"pard c0 not always accepted {acc}"
    print("ALL LOSSLESS CHECKS PASSED" if ok else "FAILURES ABOVE")

if __name__ == "__main__":
    main()
