"""Python mirror of rust/src/runtime/reference.rs for design validation.

Reproduces the RNG (splitmix64 + xoshiro256**), weight init draw order,
and the extend-semantics forward pass in float32 numpy, then simulates
the decode loops the tests exercise to check seed-7 behaviour:
  - AR+ greedy streams on code/gsm prompts (EOS timing, lengths)
  - self-draft VSD tokens/iter (accept-all chunking)
  - PARD pos_alpha(0) feasibility (iterations >= 1)
  - serve_trace occupancy feasibility

Sync note: the Rust fwd truncates its transient cache view at the
highest LIVE position and skips parked (garbage-slot) columns entirely;
this mirror keeps the full window.  Live outputs are identical either
way — parked columns only ever touch the unattendable garbage slot.
Float caveat: numpy BLAS accumulation order differs from the Rust
scalar loops, so streams here are representative, not bit-certified.
"""
import numpy as np

M = (1 << 64) - 1

def splitmix64(x):
    x = (x + 0x9E3779B97F4A7C15) & M
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M
    return x, z ^ (z >> 31)

def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M

class Rng:
    def __init__(self, seed):
        x = seed & M
        s = []
        for _ in range(4):
            x, z = splitmix64(x)
            s.append(z)
        self.s = s

    def next_u64(self):
        s = self.s
        r = (rotl((s[1] * 5) & M, 7) * 9) & M
        t = (s[1] << 17) & M
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return r

    def below(self, n):
        return self.next_u64() % n

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def rng_range(self, lo, hi):
        return lo + self.below(hi - lo + 1)

    def normal(self):
        u1 = 1.0 - self.f64()
        u2 = self.f64()
        return np.sqrt(-2.0 * np.log(u1)) * np.cos(2 * np.pi * u2)

def key_seed(base, name):
    h = (base ^ 0xCBF29CE484222325) & M
    for b in name.encode():
        h = ((h ^ b) * 0x100000001B3) & M
    return h

VOCAB, S_MAX, DH = 64, 96, 16
BOS, EOS, PAD, MASK = 0, 1, 2, 3
FAMILY = {
    "draft-s":  (32, 2, 2, 64,  "draft-s"),
    "target-m": (48, 3, 3, 96,  "target-m"),
    "target-l": (64, 4, 4, 128, "target-l"),
    "target-xl": (80, 5, 5, 160, "target-xl"),
    "target-l_h": (64, 4, 4, 128, "target-l"),
    "pard-main": (32, 2, 2, 64, "draft-s"),
}

def dense(rng, rows, cols, scale):
    out = np.empty(rows * cols, np.float32)
    for i in range(rows * cols):
        out[i] = np.float32(rng.normal()) * np.float32(scale)
    return out.reshape(rows, cols)

class Model:
    def __init__(self, seed, name):
        d, L, h, ff, wkey = FAMILY[name]
        self.d, self.L, self.h, self.ff = d, L, h, ff
        hd = h * DH
        rng = Rng(key_seed(seed, wkey))
        self.embed = dense(rng, VOCAB, d, 0.02)
        self.layers = []
        for _ in range(L):
            lyr = {
                "wq": dense(rng, d, hd, d ** -0.5),
                "wk": dense(rng, d, hd, d ** -0.5),
                "wv": dense(rng, d, hd, d ** -0.5),
                "wo": dense(rng, hd, d, hd ** -0.5),
                "w1": dense(rng, d, ff, d ** -0.5),
                "w2": dense(rng, ff, d, ff ** -0.5),
                "w3": dense(rng, d, ff, d ** -0.5),
            }
            self.layers.append(lyr)
        half = DH // 2
        self.inv_freq = (10000.0 ** (-(np.arange(half, dtype=np.float32)) / half)).astype(np.float32)

def rmsnorm(x, d):
    var = np.mean(np.square(x), axis=-1, keepdims=True, dtype=np.float32)
    return (x / np.sqrt(var + np.float32(1e-5))).astype(np.float32)

def rope(x, pos, h):
    # x [T, h*DH], pos [T]
    half = DH // 2
    t = x.shape[0]
    xr = x.reshape(t, h, DH)
    ang = pos[:, None].astype(np.float32) * MODEL_INV_FREQ[None, :]
    cos, sin = np.cos(ang), np.sin(ang)  # [T, half]
    x1 = xr[:, :, :half]
    x2 = xr[:, :, half:]
    out = np.concatenate([x1 * cos[:, None, :] - x2 * sin[:, None, :],
                          x1 * sin[:, None, :] + x2 * cos[:, None, :]], -1)
    return out.reshape(t, h * DH).astype(np.float32)

MODEL_INV_FREQ = None

def fwd(m, tokens, pos, cache_k, cache_v):
    """b=1 forward. tokens/pos lists. cache [L, S, hd]. returns logits [T,V],
    staged k/v [L,T,hd] (rope'd)."""
    global MODEL_INV_FREQ
    MODEL_INV_FREQ = m.inv_freq
    t = len(tokens)
    d, h, hd = m.d, m.h, m.h * DH
    x = m.embed[np.array(tokens)]
    posa = np.array(pos, np.int32)
    k_stage = np.zeros((m.L, t, hd), np.float32)
    v_stage = np.zeros((m.L, t, hd), np.float32)
    for li, lyr in enumerate(m.layers):
        xn = rmsnorm(x, d)
        q = (xn @ lyr["wq"]).astype(np.float32)
        k = (xn @ lyr["wk"]).astype(np.float32)
        v = (xn @ lyr["wv"]).astype(np.float32)
        q = rope(q, posa, h)
        k = rope(k, posa, h)
        k_stage[li] = k
        v_stage[li] = v
        ck = cache_k[li].copy()
        cv = cache_v[li].copy()
        for col in range(t):
            s = int(np.clip(pos[col], 0, S_MAX - 1))
            ck[s] = k[col]
            cv[s] = v[col]
        # attention per col
        attn = np.zeros((t, hd), np.float32)
        ckh = ck.reshape(S_MAX, h, DH)
        cvh = cv.reshape(S_MAX, h, DH)
        qh = q.reshape(t, h, DH)
        scale = np.float32(1.0 / np.sqrt(DH))
        for col in range(t):
            p = int(np.clip(pos[col], 0, S_MAX - 1))
            sc = np.einsum("hd,shd->hs", qh[col], ckh[: p + 1]) * scale
            sc = sc - sc.max(axis=1, keepdims=True)
            w = np.exp(sc)
            w = w / w.sum(axis=1, keepdims=True)
            o = np.einsum("hs,shd->hd", w, cvh[: p + 1])
            attn[col] = o.reshape(hd)
        x = (x + attn @ lyr["wo"]).astype(np.float32)
        xn2 = rmsnorm(x, d)
        g = (xn2 @ lyr["w1"]).astype(np.float32)
        u = (xn2 @ lyr["w3"]).astype(np.float32)
        act = g * (1.0 / (1.0 + np.exp(-g))) * u
        x = (x + act @ lyr["w2"]).astype(np.float32)
    hidden = rmsnorm(x, d)
    logits = (hidden @ m.embed.T).astype(np.float32)
    return logits, k_stage, v_stage

def commit(cache_k, cache_v, k_stage, v_stage, pos):
    for li in range(cache_k.shape[0]):
        for col, p in enumerate(pos):
            s = int(np.clip(p, 0, S_MAX - 1))
            cache_k[li, s] = k_stage[li, col]
            cache_v[li, s] = v_stage[li, col]

def synth_prompts(task, seed, n=32):
    rng = Rng(key_seed(seed, task) ^ 0x50524F4D5054)
    out = []
    for _ in range(n):
        ln = rng.rng_range(4, 9)
        ids = [BOS] + [rng.rng_range(12, VOCAB - 1) for _ in range(ln)]
        out.append(ids)
    return out

def ar_plus_decode(m, prompt, max_new):
    """Greedy KV-cached decode, returns generated tokens (stops at EOS)."""
    hd = m.h * DH
    ck = np.zeros((m.L, S_MAX, hd), np.float32)
    cv = np.zeros((m.L, S_MAX, hd), np.float32)
    pos = list(range(len(prompt)))
    logits, ks, vs = fwd(m, prompt, pos, ck, cv)
    commit(ck, cv, ks, vs, pos)
    cur = len(prompt)
    nxt = int(np.argmax(logits[len(prompt) - 1]))
    gen = [nxt]
    while len(gen) < max_new and gen[-1] != EOS:
        logits, ks, vs = fwd(m, [nxt], [cur], ck, cv)
        commit(ck, cv, ks, vs, [cur])
        cur += 1
        nxt = int(np.argmax(logits[0]))
        gen.append(nxt)
    return gen

def main(seed=7):
    for tgt in ["target-l", "target-m", "draft-s"]:
        m = Model(seed, tgt)
        prompts = synth_prompts("code", seed)[:6]
        lens, firsts = [], []
        for p in prompts:
            g = ar_plus_decode(m, p, 20)
            lens.append(len(g))
            firsts.append(g[0])
        print(f"{tgt}: code gen lens (max 20) = {lens}, first tokens = {firsts}")

    # self-draft VSD accept-all chunking on draft-s, k=4, 2 prompts, max_new 20
    m = Model(seed, "draft-s")
    total_gen = tot_iters = 0
    for p in synth_prompts("code", seed)[:2]:
        g = ar_plus_decode(m, p, 20)
        total_gen += len(g)
        remaining = len(g) - 1  # first token from prefill
        iters = 0
        while remaining > 0:
            iters += 1
            remaining -= min(5, remaining)
        tot_iters += iters
        print(f"  vsd-self prompt: stream len {len(g)}, iters {iters}")
    tpi = total_gen / max(tot_iters, 1)
    print(f"self-draft VSD k=4: tokens/iter = {tpi:.2f} (assert > 3.0)")

    # PARD pos_alpha(0) feasibility on draft-s target: first tokens != EOS?
    firsts = []
    for p in synth_prompts("code", seed)[:2]:
        g = ar_plus_decode(m, p, 20)
        firsts.append((g[0], len(g)))
    print(f"pard-on-draft-s: (first, len) = {firsts} (need >=1 prompt with len>1)")

    # serve_trace occupancy: gsm 9 requests on target-m, max_new 16
    m2 = Model(seed, "target-m")
    gs = synth_prompts("gsm", seed)[:9]
    lens = [len(ar_plus_decode(m2, p, 16)) for p in gs]
    print(f"gsm stream lens (max 16) = {lens}")

    # eval-prompt determinism smoke
    a = synth_prompts("code", seed)[:2]
    b = synth_prompts("code", seed)[:2]
    assert a == b
    print("prompts deterministic OK; sample:", a[0])

if __name__ == "__main__":
    main()
