//! Typecheck-only stub of the xla-0.1.6 PJRT bindings.
//!
//! The offline deployment image vendors the real crate tree at this
//! path; this stub mirrors exactly the API surface `pard` uses so that
//! `cargo check --features pjrt` works anywhere.  Every entry point
//! fails at runtime with an explanatory error — the stub can never be
//! mistaken for a working runtime (see README.md).

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>() -> Result<T> {
    Err(Error(
        "xla stub: the vendored PJRT crate tree is not present in this \
         build — replace rust/vendor/xla with the real xla-0.1.6 crate \
         (see vendor/xla/README.md) or run with the reference backend"
            .to_string(),
    ))
}

/// Host element types accepted by buffer upload / literal download.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

#[derive(Debug)]
pub struct ArrayShape(Vec<i64>);

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.0
    }
}

pub struct PjRtDevice;

#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        stub()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self, _data: &[T], _dims: &[usize], _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        stub()
    }

    pub fn compile(&self, _c: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        stub()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub()
    }

    pub fn on_device_shape(&self) -> Result<ArrayShape> {
        stub()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer])
                     -> Result<Vec<Vec<PjRtBuffer>>> {
        stub()
    }
}

pub struct Literal;

impl Literal {
    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        stub()
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        stub()
    }
}

/// Deserialization entry points (`Literal::read_npz` in the real crate
/// comes from this trait).
pub trait FromRawBytes: Sized {
    fn read_npz<P: AsRef<Path>>(path: P, ctx: &())
                                -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    fn read_npz<P: AsRef<Path>>(_path: P, _ctx: &())
                                -> Result<Vec<(String, Self)>> {
        stub()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        stub()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}
