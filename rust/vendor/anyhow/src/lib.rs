//! Minimal offline shim of the `anyhow` API surface this crate uses:
//! `Error`, `Result`, `anyhow!`, `bail!`, `ensure!`, and the `Context`
//! extension trait.  Errors flatten to a context chain of strings —
//! enough for CLI/test diagnostics without vendoring the real crate.

use std::fmt::{self, Display};

/// A context chain: `chain[0]` is the outermost (most recent) message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap(mut self, ctx: String) -> Error {
        self.chain.insert(0, ctx);
        self
    }

    /// Outermost message (parity with `anyhow::Error::to_string`).
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, so this blanket conversion cannot collide with
// the reflexive `From<Error> for Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible result (subset of anyhow's trait: the
/// codebase only calls it on `Result`).
pub trait Context<T> {
    fn context<C: Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F)
                                                  -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(ctx.to_string()))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F)
                                                  -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = Err(io_err()).context("reading foo");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "reading foo");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
