//! Experiment runners that regenerate every table and figure in the
//! paper's evaluation (the per-experiment index lives in DESIGN.md §5).
//! Shared by `pard tables/fig`, examples/, and rust/benches/.  The
//! artifact-free perf-baseline sweep behind `pard bench` lives in
//! [`bench`] (DESIGN.md §Perf).

pub mod bench;

use anyhow::Result;

use crate::coordinator::engines::{EngineConfig, EngineKind};
use crate::coordinator::evaluate::{run_eval, EvalResult};
use crate::coordinator::policy::PolicyCfg;
use crate::coordinator::router::default_draft;
use crate::runtime::Backend;
use crate::substrate::bench::Table;
use crate::substrate::devices::{paper_model, DeviceProfile, ModelCost,
                                A100_40GB, MI250X};
use crate::Runtime;

pub const TASKS: [&str; 3] = ["math", "code", "gsm"];
/// Task display names mapped to the paper's benchmarks.
pub fn task_label(t: &str) -> &'static str {
    match t {
        "math" => "MATH500*",
        "code" => "HumanEval*",
        "gsm" => "GSM8K*",
        _ => "?",
    }
}

#[derive(Debug, Clone, Copy)]
pub struct RunScale {
    /// Prompts per (engine, task) cell.
    pub n_prompts: usize,
    pub max_new: usize,
}

impl RunScale {
    pub fn quick() -> Self {
        RunScale { n_prompts: 8, max_new: 48 }
    }

    pub fn full() -> Self {
        RunScale { n_prompts: 24, max_new: 64 }
    }
}

pub fn cell(rt: &Runtime, kind: EngineKind, target: &str, task: &str,
            k: usize, batch: usize, scale: RunScale)
            -> Result<EvalResult> {
    let draft = default_draft(&rt.manifest, kind, target)?;
    let cfg = EngineConfig {
        kind,
        target: target.to_string(),
        draft,
        batch,
        k,
        max_new: scale.max_new,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    };
    let prompts = rt.prompts(task)?.take(scale.n_prompts);
    run_eval(rt, &cfg, &prompts, scale.max_new, task)
}

fn fmt(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

// ---------------------------------------------------------------------------
// Table 1 — main results: AR / AR+ / VSD / PARD on the large targets × 3 tasks
// ---------------------------------------------------------------------------

pub fn table1(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "Table 1 — TPS & speedup vs AR+ (targets: target-l, target-xl; \
         draft: draft-s / PARD)",
        &["Target", "Method", "Draft", "MATH500*", "", "HumanEval*", "",
          "GSM8K*", "", "Avg TPS", "Avg Speedup"],
    );
    for target in ["target-l", "target-xl"] {
        let mut base_tps = [0.0f64; 3];
        for kind in [EngineKind::Ar, EngineKind::ArPlus, EngineKind::Vsd,
                     EngineKind::Pard] {
            let mut tps = Vec::new();
            for (i, task) in TASKS.iter().enumerate() {
                let r = cell(rt, kind, target, task, 8, 1, scale)?;
                if kind == EngineKind::ArPlus {
                    base_tps[i] = r.tps();
                }
                tps.push(r.tps());
            }
            let avg: f64 = tps.iter().sum::<f64>() / 3.0;
            let sp = |i: usize| {
                if base_tps[i] == 0.0 {
                    "-".to_string()
                } else {
                    format!("{:.2}x", tps[i] / base_tps[i])
                }
            };
            let avg_base: f64 = base_tps.iter().sum::<f64>() / 3.0;
            let draft = match kind {
                EngineKind::Vsd => "draft-s",
                EngineKind::Pard => "draft-s PARD",
                _ => "-",
            };
            t.row(vec![
                target.into(),
                kind.label().into(),
                draft.into(),
                fmt(tps[0], 1), sp(0),
                fmt(tps[1], 1), sp(1),
                fmt(tps[2], 1), sp(2),
                fmt(avg, 1),
                if avg_base > 0.0 {
                    format!("{:.2}x", avg / avg_base)
                } else {
                    "-".into()
                },
            ]);
        }
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 2 / Fig 2 — target independence: one draft × the whole family
// ---------------------------------------------------------------------------

pub fn table2(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "Table 2 — target independence: ONE draft accelerates the family",
        &["Target", "Method", "MATH500*", "HumanEval*", "GSM8K*",
          "Avg Speedup"],
    );
    for target in crate::coordinator::router::FAMILY_TARGETS {
        let mut rows: Vec<(EngineKind, Vec<f64>)> = Vec::new();
        for kind in
            [EngineKind::ArPlus, EngineKind::Vsd, EngineKind::Pard]
        {
            let mut tps = Vec::new();
            for task in TASKS {
                tps.push(cell(rt, kind, target, task, 8, 1, scale)?.tps());
            }
            rows.push((kind, tps));
        }
        let base = rows[0].1.clone();
        for (kind, tps) in rows {
            let sps: Vec<f64> = tps
                .iter()
                .zip(&base)
                .map(|(a, b)| if *b > 0.0 { a / b } else { 0.0 })
                .collect();
            let avg = sps.iter().sum::<f64>() / 3.0;
            t.row(vec![
                target.into(),
                kind.label().into(),
                format!("{:.2}x", sps[0]),
                format!("{:.2}x", sps[1]),
                format!("{:.2}x", sps[2]),
                format!("{:.2}x", avg),
            ]);
        }
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 3 — serving-framework comparison (batched engine, bs=1)
// ---------------------------------------------------------------------------

pub fn table3(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "Table 3 — serving engine comparison on target-l (bs=1, \
         vLLM-analogue = our continuous-batching coordinator)",
        &["Method", "HumanEval* TPS", "Speedup", "GSM8K* TPS", "Speedup"],
    );
    let mut base = [0.0f64; 2];
    for kind in [EngineKind::ArPlus, EngineKind::Eagle, EngineKind::Vsd,
                 EngineKind::Pard] {
        let mut tps = Vec::new();
        for (i, task) in ["code", "gsm"].iter().enumerate() {
            let r = cell(rt, kind, "target-l", task, 8, 1, scale)?;
            if kind == EngineKind::ArPlus {
                base[i] = r.tps();
            }
            tps.push(r.tps());
        }
        let label =
            if kind == EngineKind::ArPlus { "AR" } else { kind.label() };
        t.row(vec![
            label.into(),
            fmt(tps[0], 1),
            format!("{:.2}x", tps[0] / base[0]),
            fmt(tps[1], 1),
            format!("{:.2}x", tps[1] / base[1]),
        ]);
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 4 — batch-size sweep
// ---------------------------------------------------------------------------

pub fn table4(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let batches = [1usize, 2, 4, 8, 16];
    let mut t = Table::new(
        "Table 4 — speedup vs batch size (target-l, HumanEval*)",
        &["Method", "bs=1", "bs=2", "bs=4", "bs=8", "bs=16"],
    );
    let mut base = vec![0.0f64; batches.len()];
    for kind in [EngineKind::ArPlus, EngineKind::Eagle, EngineKind::Vsd,
                 EngineKind::Pard] {
        let mut row = vec![if kind == EngineKind::ArPlus {
            "AR".to_string()
        } else {
            kind.label().to_string()
        }];
        for (i, &bs) in batches.iter().enumerate() {
            let sc = RunScale {
                n_prompts: scale.n_prompts.max(bs * 2),
                max_new: scale.max_new,
            };
            let r = cell(rt, kind, "target-l", "code", 8, bs, sc)?;
            if kind == EngineKind::ArPlus {
                base[i] = r.tps();
            }
            row.push(format!("{:.2}x", r.tps() / base[i]));
        }
        t.row(row);
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 5 — acceptance rates (k-α) PARD vs EAGLE
// ---------------------------------------------------------------------------

pub fn table5(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "Table 5 — acceptance rate k-α on target-l (k = draft length)",
        &["Method", "HumanEval* 1-α", "4-α", "GSM8K* 1-α", "4-α"],
    );
    for kind in [EngineKind::Eagle, EngineKind::Vsd, EngineKind::Pard] {
        let mut cells = Vec::new();
        for task in ["code", "gsm"] {
            let r = cell(rt, kind, "target-l", task, 8, 1, scale)?;
            cells.push(r.metrics.k_alpha(1));
            cells.push(r.metrics.k_alpha(4));
        }
        t.row(vec![
            kind.label().into(),
            fmt(cells[0], 2),
            fmt(cells[1], 2),
            fmt(cells[2], 2),
            fmt(cells[3], 2),
        ]);
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 6 — draft-phase bandwidth model (paper-scale, bf16)
// ---------------------------------------------------------------------------

pub fn table6() -> Table {
    let mut t = Table::new(
        "Table 6 — draft-phase bandwidth per iteration (cost model, \
         paper-scale 1B draft / EAGLE head, bf16)",
        &["Method", "k=4", "k=6", "k=8"],
    );
    // EAGLE-scale feature head ~0.74B effective reads per pass (paper:
    // 5.94GB at k=4 -> 1.485GB/pass); PARD uses the 1.24B draft once.
    let eagle_head = ModelCost::new(0.7425e9, 0.0);
    let pard_draft = paper_model(1.24);
    let gb = 1e9;
    let mut eagle_row = vec!["EAGLE".to_string()];
    let mut pard_row = vec!["PARD".to_string()];
    for k in [4usize, 6, 8] {
        let e = A100_40GB.draft_bandwidth_bytes(&eagle_head, k) / gb;
        let p = A100_40GB.draft_bandwidth_bytes(&pard_draft, 1) / gb;
        eagle_row.push(format!("{e:.2} GB"));
        pard_row.push(format!("{p:.2} GB"));
    }
    t.row(eagle_row);
    t.row(pard_row);
    t
}

/// Measured analogue of Table 6 on the synthetic family: weight bytes
/// touched per draft phase from real pass counts.
pub fn table6_measured(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "Table 6 (measured) — draft weight-bytes per iteration, \
         synthetic family f32",
        &["Method", "k=4", "k=6", "k=8"],
    );
    for kind in [EngineKind::Eagle, EngineKind::Pard] {
        let mut row = vec![kind.label().to_string()];
        for k in [4usize, 6, 8] {
            let r = cell(rt, kind, "target-l", "code", k, 1,
                         RunScale { n_prompts: 4, ..scale })?;
            let draft_name = r.draft.clone().unwrap();
            let m = rt.model(&draft_name)?;
            let bytes_per_pass = m.n_params() * 4;
            let passes_per_iter = r.metrics.draft_passes as f64
                / r.metrics.iterations.max(1) as f64;
            row.push(format!(
                "{:.1} MB",
                passes_per_iter * bytes_per_pass as f64 / 1e6
            ));
        }
        t.row(row);
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 7 — device cost-model projection (A100 vs MI250X)
// ---------------------------------------------------------------------------

pub fn table7(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "Table 7 — device-projected speedups (measured acceptance × \
         roofline cost model; paper-scale 8B target / 1B draft)",
        &["Device", "Method", "MATH500*", "HumanEval*", "GSM8K*", "Avg"],
    );
    let target = paper_model(8.0);
    let draft = paper_model(1.24);
    let k = 8;
    for dev in [A100_40GB, MI250X] {
        project_device(rt, scale, &mut t, dev, &target, &draft, k)?;
    }
    Ok(t)
}

fn project_device(rt: &Runtime, scale: RunScale, t: &mut Table,
                  dev: DeviceProfile, target: &ModelCost,
                  draft: &ModelCost, k: usize) -> Result<()> {
    let ar_tps = dev.ar_tps(target, 1);
    for kind in [EngineKind::Vsd, EngineKind::Pard] {
        let mut sps = Vec::new();
        for task in TASKS {
            // measured tokens/iteration from the REAL pipeline...
            let r = cell(rt, kind, "target-l", task, k, 1, scale)?;
            let tpi = r.metrics.tokens_per_iter();
            // ...combined with the device's per-pass roofline costs
            let (passes, toks_per_pass) = match kind {
                EngineKind::Vsd => (k, 1),
                EngineKind::Pard => (1, 2 * k),
                _ => unreachable!(),
            };
            let tps =
                dev.sd_tps(target, draft, k, passes, toks_per_pass, tpi, 1);
            sps.push(tps / ar_tps);
        }
        let avg = sps.iter().sum::<f64>() / 3.0;
        t.row(vec![
            dev.name.into(),
            if kind == EngineKind::Vsd { "AR Draft" } else { "PARD" }
                .into(),
            format!("{:.2}", sps[0]),
            format!("{:.2}", sps[1]),
            format!("{:.2}", sps[2]),
            format!("{avg:.2}"),
        ]);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 1a — per-position acceptance; Fig 1b — draft/verify breakdown
// ---------------------------------------------------------------------------

pub fn fig1a(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "Fig 1a — acceptance rate by draft position (target-l, \
         HumanEval*)",
        &["Method", "pos0", "pos1", "pos2", "pos3", "pos4", "pos5",
          "pos6", "pos7"],
    );
    for kind in [EngineKind::Eagle, EngineKind::Vsd, EngineKind::Pard] {
        let r = cell(rt, kind, "target-l", "code", 8, 1, scale)?;
        let mut row = vec![kind.label().to_string()];
        for j in 0..8 {
            row.push(fmt(r.metrics.pos_alpha(j), 2));
        }
        t.row(row);
    }
    Ok(t)
}

pub fn fig1b(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "Fig 1b — wall-clock breakdown per request (VSD vs PARD, \
         target-l, HumanEval*)",
        &["Method", "draft s/req", "verify s/req", "draft passes/iter",
          "tokens/iter"],
    );
    for kind in [EngineKind::Vsd, EngineKind::Pard] {
        let r = cell(rt, kind, "target-l", "code", 8, 1, scale)?;
        let reqs = r.metrics.requests.max(1) as f64;
        t.row(vec![
            kind.label().into(),
            format!("{:.4}", r.metrics.draft_s / reqs),
            format!("{:.4}", r.metrics.verify_s / reqs),
            format!("{:.2}", r.metrics.draft_passes as f64
                / r.metrics.iterations.max(1) as f64),
            format!("{:.2}", r.metrics.tokens_per_iter()),
        ]);
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Fig 6a / 6b — ablations (require `make ablation` artifacts)
// ---------------------------------------------------------------------------

fn pard_cell(rt: &Runtime, variant: &str, target: &str, k: usize,
             shared: bool, scale: RunScale) -> Result<EvalResult> {
    let cfg = EngineConfig {
        kind: EngineKind::Pard,
        target: target.to_string(),
        draft: Some(variant.to_string()),
        batch: 1,
        k,
        max_new: scale.max_new,
        shared_mask: shared,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    };
    let prompts = rt.prompts("math")?.take(scale.n_prompts);
    run_eval(rt, &cfg, &prompts, scale.max_new, "math")
}

pub fn fig6a(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "Fig 6a — COD ablation: training-token cost vs final decode TPS \
         (target-m, MATH500*)",
        &["Variant", "r", "r_min", "train-token ratio vs full K*N",
          "TPS", "tokens/iter"],
    );
    let variants: Vec<String> =
        rt.manifest.pard_variants.keys().cloned().collect();
    for v in variants {
        let info = &rt.manifest.pard_variants[&v].clone();
        if info.k_train != 8 || !info.shared_mask {
            continue; // Fig 6a sweeps (r, r_min) at K=8, shared ids
        }
        // training-token ratio from the metrics json written at train time
        let ratio = read_metric_ratio(rt, &v).unwrap_or(f64::NAN);
        let r = pard_cell(rt, &v, "target-m", 8, true, scale)?;
        t.row(vec![
            v.clone(),
            format!("{:.2}", info.r),
            format!("{:.2}", info.r_min),
            format!("{ratio:.3}"),
            fmt(r.tps(), 1),
            format!("{:.2}", r.metrics.tokens_per_iter()),
        ]);
    }
    anyhow::ensure!(!t.rows.is_empty(),
                    "no ablation variants found — run `make ablation`");
    Ok(t)
}

fn read_metric_ratio(rt: &Runtime, variant: &str) -> Option<f64> {
    let p = rt.manifest.root.join(format!("metrics/{variant}.json"));
    let text = std::fs::read_to_string(p).ok()?;
    let v = crate::substrate::json::Json::parse(&text).ok()?;
    v.get("cod_token_ratio")?.as_f64()
}

pub fn fig6b(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "Fig 6b — K_train × K_infer (PARD on target-m, MATH500*; \
         K_infer > K_train = extrapolation via shared mask id)",
        &["Variant (K_train)", "K=2", "K=4", "K=8", "K=12", "K=16"],
    );
    let mut variants: Vec<(String, usize)> = rt
        .manifest
        .pard_variants
        .iter()
        .filter(|(_, i)| i.shared_mask && (i.r - 0.7).abs() < 1e-9)
        .map(|(n, i)| (n.clone(), i.k_train))
        .collect();
    variants.sort_by_key(|(_, k)| *k);
    for (v, k_train) in variants {
        let mut row = vec![format!("{v} (K_train={k_train})")];
        for k in [2usize, 4, 8, 12, 16] {
            let r = pard_cell(rt, &v, "target-m", k, true, scale)?;
            row.push(fmt(r.tps(), 1));
        }
        t.row(row);
    }
    anyhow::ensure!(!t.rows.is_empty(),
                    "no pard variants found — run `make ablation`");
    Ok(t)
}

/// §4.3 shared-vs-distinct mask id comparison (needs `make ablation`).
pub fn mask_id_ablation(rt: &Runtime, scale: RunScale) -> Result<Table> {
    let mut t = Table::new(
        "§4.3 — shared vs distinct mask ids (target-m, MATH500*)",
        &["Variant", "TPS", "tokens/iter"],
    );
    let main = rt.manifest.main_pard.clone();
    let mut pairs = vec![(main, true)];
    if rt.manifest.pard_variants.contains_key("pard-distinct") {
        pairs.push(("pard-distinct".to_string(), false));
    }
    for (v, shared) in pairs {
        let r = pard_cell(rt, &v, "target-m", 8, shared, scale)?;
        t.row(vec![
            v,
            fmt(r.tps(), 1),
            format!("{:.2}", r.metrics.tokens_per_iter()),
        ]);
    }
    Ok(t)
}
