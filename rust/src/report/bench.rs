//! Artifact-free hot-path benchmark sweep (`pard bench`, DESIGN.md
//! §Perf).
//!
//! Runs {AR+, VSD, PARD, EAGLE} × K × batch on the fast host backend
//! (DESIGN.md §8), optionally replays the *identical* sweep on the
//! scalar reference oracle, and emits a stable JSON report
//! ([`BENCH_FILE`], schema [`BENCH_SCHEMA`]) with per-engine tokens/s,
//! mean accept length, the fwd/commit time split, and speedup vs the
//! AR+ baseline — the perf trajectory later PRs regress against.
//! `tests/bench_schema.rs` pins the schema; parse with
//! [`crate::substrate::json::Json`].

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::engines::{EngineConfig, EngineKind};
use crate::coordinator::evaluate::{run_eval, EvalResult};
use crate::coordinator::router::default_draft;
use crate::substrate::json::Json;
use crate::Runtime;

/// Schema tag stamped into every report; bump on breaking field
/// changes so downstream tooling fails loudly instead of misreading.
pub const BENCH_SCHEMA: &str = "pard-bench-hotpath/v1";

/// Default report file name (written at the repo root by `pard bench`).
pub const BENCH_FILE: &str = "BENCH_hotpath.json";

/// Sweep configuration for [`hotpath_report`].
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Synthetic-family weight seed (same semantics as `--seed`).
    pub seed: u64,
    /// Prompt task to draw the workload from.
    pub task: String,
    /// Verify-side target model; drafts follow the router policy.
    pub target: String,
    /// K_infer values swept for the speculative engines.
    pub ks: Vec<usize>,
    /// Batch sizes swept for every engine.
    pub batches: Vec<usize>,
    /// Prompts per cell.
    pub n_prompts: usize,
    /// Tokens generated per prompt.
    pub max_new: usize,
    /// Also replay the sweep on the scalar reference oracle and report
    /// per-cell and aggregate host-vs-oracle speedups.
    pub oracle: bool,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            seed: 7,
            task: "code".to_string(),
            target: "target-l".to_string(),
            ks: vec![2, 4, 8],
            batches: vec![1, 4],
            n_prompts: 8,
            max_new: 32,
            oracle: true,
        }
    }
}

impl BenchOpts {
    /// Small sweep for smoke tests: one K, batch 1, two prompts.
    pub fn smoke() -> Self {
        BenchOpts {
            ks: vec![2],
            batches: vec![1],
            n_prompts: 2,
            max_new: 8,
            ..Self::default()
        }
    }
}

/// One measured sweep cell.
struct RunRow {
    engine: &'static str,
    /// `None` for the AR+ baseline (it never drafts).
    k: Option<usize>,
    batch: usize,
    r: EvalResult,
}

/// Run the full sweep on `rt`.  AR+ runs once per batch and is always
/// the first row of its batch group, so baselines exist before any
/// speedup is computed.
fn sweep(rt: &Runtime, o: &BenchOpts) -> Result<Vec<RunRow>> {
    let mut rows = Vec::new();
    for &batch in &o.batches {
        for kind in [EngineKind::ArPlus, EngineKind::Vsd,
                     EngineKind::Pard, EngineKind::Eagle] {
            let ks: Vec<Option<usize>> = if kind == EngineKind::ArPlus {
                vec![None]
            } else {
                o.ks.iter().copied().map(Some).collect()
            };
            for kopt in ks {
                let cfg = EngineConfig {
                    kind,
                    target: o.target.clone(),
                    draft: default_draft(&rt.manifest, kind, &o.target)?,
                    batch,
                    k: kopt.unwrap_or(8),
                    max_new: o.max_new,
                    shared_mask: true,
                };
                let prompts = rt.prompts(&o.task)?.take(o.n_prompts);
                let r = run_eval(rt, &cfg, &prompts, o.max_new, &o.task)?;
                rows.push(RunRow { engine: kind.label(), k: kopt, batch,
                                   r });
            }
        }
    }
    Ok(rows)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn nums(vs: &[usize]) -> Json {
    Json::Arr(vs.iter().map(|&v| Json::Num(v as f64)).collect())
}

fn row_json(row: &RunRow, base_tps: f64) -> Json {
    let m = &row.r.metrics;
    obj(vec![
        ("engine", Json::Str(row.engine.to_string())),
        ("k", row.k.map_or(Json::Null, |k| Json::Num(k as f64))),
        ("batch", num(row.batch as f64)),
        ("tokens_per_s", num(m.tps())),
        ("tokens_per_iter", num(m.tokens_per_iter())),
        ("mean_accept_len", num(m.mean_accept_len())),
        ("fwd_s", num(m.fwd_s)),
        ("commit_s", num(m.commit_s)),
        ("draft_s", num(m.draft_s)),
        ("verify_s", num(m.verify_s)),
        ("prefill_s", num(m.prefill_s)),
        ("wall_s", num(m.wall_s)),
        ("generated", num(m.generated as f64)),
        ("iterations", num(m.iterations as f64)),
        ("speedup_vs_ar_plus",
         num(if base_tps > 0.0 { m.tps() / base_tps } else { 0.0 })),
    ])
}

/// Per-batch AR+ baseline TPS, keyed by batch size.
fn baselines(rows: &[RunRow]) -> BTreeMap<usize, f64> {
    rows.iter()
        .filter(|r| r.engine == "AR+")
        .map(|r| (r.batch, r.r.tps()))
        .collect()
}

fn rows_json(rows: &[RunRow]) -> Json {
    let base = baselines(rows);
    Json::Arr(
        rows.iter()
            .map(|r| row_json(r, *base.get(&r.batch).unwrap_or(&0.0)))
            .collect(),
    )
}

/// Run the sweep and build the full report document.
///
/// The host backend is always measured; with `opts.oracle` the scalar
/// reference replays the identical sweep and the report gains an
/// `oracle` section plus `host_vs_reference` speedup aggregates
/// (acceptance bar: `geomean >= 3`).
pub fn hotpath_report(opts: &BenchOpts) -> Result<Json> {
    let host_rt = Runtime::host(opts.seed);
    let host_rows = sweep(&host_rt, opts)?;

    let mut top = vec![
        ("schema", Json::Str(BENCH_SCHEMA.to_string())),
        ("backend", Json::Str(host_rt.backend_label().to_string())),
        ("seed", num(opts.seed as f64)),
        ("task", Json::Str(opts.task.clone())),
        ("target", Json::Str(opts.target.clone())),
        ("n_prompts", num(opts.n_prompts as f64)),
        ("max_new", num(opts.max_new as f64)),
        ("sweep", obj(vec![
            ("engines", Json::Arr(
                ["AR+", "VSD", "PARD", "EAGLE"]
                    .iter()
                    .map(|e| Json::Str(e.to_string()))
                    .collect(),
            )),
            ("k", nums(&opts.ks)),
            ("batch", nums(&opts.batches)),
        ])),
        ("runs", rows_json(&host_rows)),
    ];

    if opts.oracle {
        let ref_rt = Runtime::reference(opts.seed);
        let ref_rows = sweep(&ref_rt, opts)?;
        // Same sweep function, same opts => rows align pairwise.
        let mut ratios = Vec::with_capacity(host_rows.len());
        let mut per_run = Vec::with_capacity(host_rows.len());
        for (hr, rr) in host_rows.iter().zip(&ref_rows) {
            debug_assert_eq!((hr.engine, hr.k, hr.batch),
                             (rr.engine, rr.k, rr.batch));
            let ratio = if rr.r.tps() > 0.0 {
                hr.r.tps() / rr.r.tps()
            } else {
                0.0
            };
            ratios.push(ratio);
            per_run.push(obj(vec![
                ("engine", Json::Str(hr.engine.to_string())),
                ("k", hr.k.map_or(Json::Null, |k| Json::Num(k as f64))),
                ("batch", num(hr.batch as f64)),
                ("speedup", num(ratio)),
            ]));
        }
        let positive: Vec<f64> =
            ratios.iter().copied().filter(|&r| r > 0.0).collect();
        let geomean = if positive.is_empty() {
            0.0
        } else {
            (positive.iter().map(|r| r.ln()).sum::<f64>()
                / positive.len() as f64)
                .exp()
        };
        let min = positive.iter().copied().fold(f64::INFINITY, f64::min);
        top.push(("oracle", obj(vec![
            ("backend", Json::Str(ref_rt.backend_label().to_string())),
            ("runs", rows_json(&ref_rows)),
        ])));
        top.push(("host_vs_reference", obj(vec![
            ("per_run", Json::Arr(per_run)),
            ("geomean", num(geomean)),
            ("min", num(if min.is_finite() { min } else { 0.0 })),
        ])));
    }

    Ok(obj(top))
}

/// Serialize `report` to `path` (single line + trailing newline — the
/// in-repo JSON writer emits no insignificant whitespace).
pub fn write_report(path: &Path, report: &Json) -> Result<()> {
    let mut text = report.to_string();
    text.push('\n');
    std::fs::write(path, text)
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_opts_cover_required_sweep() {
        let o = BenchOpts::default();
        assert_eq!(o.ks, vec![2, 4, 8]);
        assert!(o.batches.contains(&1));
        assert!(o.oracle);
    }
}
