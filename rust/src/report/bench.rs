//! Artifact-free hot-path benchmark sweep (`pard bench`, DESIGN.md
//! §Perf).
//!
//! Runs {AR+, VSD, PARD, EAGLE} × K × batch on the fast host backend
//! (DESIGN.md §8), optionally replays the *identical* sweep on the
//! scalar reference oracle, and emits a stable JSON report
//! ([`BENCH_FILE`], schema [`BENCH_SCHEMA`]) with per-engine tokens/s,
//! mean accept length, the fwd/commit time split, the host backend's
//! per-op forward breakdown (`fwd_ops`) and worker-pool size
//! (`threads`), paged-KV pool stats (`kv`: blocks in use, peak
//! occupancy, admission stalls), and speedup vs the AR+ baseline —
//! the perf trajectory later PRs regress against.  `tests/bench_schema.rs` pins the
//! schema; parse with [`crate::substrate::json::Json`].
//!
//! [`compare_reports`] turns the trajectory into a gate: `pard bench
//! --compare OLD.json` fails when any (engine, K, batch) cell loses
//! more than [`COMPARE_TOL`] of its tokens/s against the older report.
//! The additive `quant` section measures the int8 host twin
//! (`--backend host-q8`) against the f32 host path — per-logit error
//! probe, per-op weight-bytes ledger, tokens/s + accept deltas — and
//! is gated by [`compare_quant`], which warns (not fails) when the
//! baseline predates the section.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::engines::{EngineConfig, EngineKind};
use crate::coordinator::evaluate::{run_eval, EvalResult};
use crate::coordinator::policy::PolicyCfg;
use crate::coordinator::router::default_draft;
use crate::runtime::OpWeightBytes;
use crate::substrate::json::Json;
use crate::Runtime;

/// Schema tag stamped into every report; bump on breaking field
/// changes so downstream tooling fails loudly instead of misreading.
pub const BENCH_SCHEMA: &str = "pard-bench-hotpath/v1";

/// Default report file name (written at the repo root by `pard bench`).
pub const BENCH_FILE: &str = "BENCH_hotpath.json";

/// Sweep configuration for [`hotpath_report`].
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Synthetic-family weight seed (same semantics as `--seed`).
    pub seed: u64,
    /// Prompt task to draw the workload from.
    pub task: String,
    /// Verify-side target model; drafts follow the router policy.
    pub target: String,
    /// K_infer values swept for the speculative engines.
    pub ks: Vec<usize>,
    /// Batch sizes swept for every engine.
    pub batches: Vec<usize>,
    /// Prompts per cell.
    pub n_prompts: usize,
    /// Tokens generated per prompt.
    pub max_new: usize,
    /// Also replay the sweep on the scalar reference oracle and report
    /// per-cell and aggregate host-vs-oracle speedups.
    pub oracle: bool,
    /// Pin the host worker pool to this many lanes (`--threads`);
    /// `None` resolves `PARD_HOST_THREADS` / available cores.
    pub threads: Option<usize>,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            seed: 7,
            task: "code".to_string(),
            target: "target-l".to_string(),
            ks: vec![2, 4, 8],
            batches: vec![1, 4],
            n_prompts: 8,
            max_new: 32,
            oracle: true,
            threads: None,
        }
    }
}

impl BenchOpts {
    /// Small sweep for smoke tests: one K, batch 1, two prompts.
    pub fn smoke() -> Self {
        BenchOpts {
            ks: vec![2],
            batches: vec![1],
            n_prompts: 2,
            max_new: 8,
            ..Self::default()
        }
    }
}

/// One measured sweep cell.
struct RunRow {
    engine: &'static str,
    /// `None` for the AR+ baseline (it never drafts).
    k: Option<usize>,
    batch: usize,
    r: EvalResult,
}

/// Run the full sweep on `rt`.  AR+ runs once per batch and is always
/// the first row of its batch group, so baselines exist before any
/// speedup is computed.
fn sweep(rt: &Runtime, o: &BenchOpts) -> Result<Vec<RunRow>> {
    let mut rows = Vec::new();
    for &batch in &o.batches {
        for kind in [EngineKind::ArPlus, EngineKind::Vsd,
                     EngineKind::Pard, EngineKind::Eagle] {
            let ks: Vec<Option<usize>> = if kind == EngineKind::ArPlus {
                vec![None]
            } else {
                o.ks.iter().copied().map(Some).collect()
            };
            for kopt in ks {
                let cfg = EngineConfig {
                    kind,
                    target: o.target.clone(),
                    draft: default_draft(&rt.manifest, kind, &o.target)?,
                    batch,
                    k: kopt.unwrap_or(8),
                    max_new: o.max_new,
                    shared_mask: true,
                    kv_blocks: None,
                    prefix_cache: false,
                    sampling: None,
                    policy: PolicyCfg::default(),
                };
                let prompts = rt.prompts(&o.task)?.take(o.n_prompts);
                let r = run_eval(rt, &cfg, &prompts, o.max_new, &o.task)?;
                rows.push(RunRow { engine: kind.label(), k: kopt, batch,
                                   r });
            }
        }
    }
    Ok(rows)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn nums(vs: &[usize]) -> Json {
    Json::Arr(vs.iter().map(|&v| Json::Num(v as f64)).collect())
}

fn row_json(row: &RunRow, base_tps: f64) -> Json {
    let m = &row.r.metrics;
    let ops = &m.fwd_ops;
    obj(vec![
        ("engine", Json::Str(row.engine.to_string())),
        ("k", row.k.map_or(Json::Null, |k| Json::Num(k as f64))),
        ("batch", num(row.batch as f64)),
        ("tokens_per_s", num(m.tps())),
        ("tokens_per_iter", num(m.tokens_per_iter())),
        ("mean_accept_len", num(m.mean_accept_len())),
        ("fwd_s", num(m.fwd_s)),
        ("commit_s", num(m.commit_s)),
        // Per-op breakdown of fwd_s (host backend; zeros on backends
        // that don't instrument their forward pass).
        ("fwd_ops", obj(vec![
            ("gather_s", num(ops.gather_s)),
            ("qkv_s", num(ops.qkv_s)),
            ("attn_s", num(ops.attn_s)),
            ("wo_s", num(ops.wo_s)),
            ("mlp_s", num(ops.mlp_s)),
            ("logits_s", num(ops.logits_s)),
        ])),
        // Paged KV pool stats (DESIGN.md §7): occupancy gauges,
        // admission backpressure, and prefix-sharing counters.
        // Additive v1 fields; `--compare` keys on tokens_per_s only,
        // so older reports stay valid.
        ("kv", obj(vec![
            ("blocks_in_use", num(m.kv_blocks_in_use as f64)),
            ("peak_blocks", num(m.kv_peak_blocks as f64)),
            ("admission_stalls", num(m.admission_stalls as f64)),
            ("prefix_hit_tokens", num(m.prefix_hit_tokens as f64)),
            ("blocks_shared", num(m.kv_blocks_shared as f64)),
            ("cow_copies", num(m.cow_copies as f64)),
        ])),
        // Speculation-policy record (DESIGN.md §9).  The sweep pins
        // every engine to the fixed policy, so `mode` is "fixed" and
        // `k_hist` collapses to one bucket — the fields exist so the
        // schema already fits adaptive runs.  Additive v1 fields.
        ("policy", obj(vec![
            ("mode", Json::Str("fixed".to_string())),
            ("k_hist", Json::Arr(
                m.k_hist.iter().map(|&n| num(n as f64)).collect())),
            ("mode_switches", num(m.mode_switches as f64)),
            ("dual_mode_iters", num(m.dual_mode_iters as f64)),
            ("work_pass_units", num(m.work_pass_units)),
            ("work_col_units", num(m.work_col_units)),
        ])),
        ("draft_s", num(m.draft_s)),
        ("verify_s", num(m.verify_s)),
        ("prefill_s", num(m.prefill_s)),
        ("wall_s", num(m.wall_s)),
        ("generated", num(m.generated as f64)),
        ("iterations", num(m.iterations as f64)),
        ("speedup_vs_ar_plus",
         num(if base_tps > 0.0 { m.tps() / base_tps } else { 0.0 })),
    ])
}

/// Per-batch AR+ baseline TPS, keyed by batch size.
fn baselines(rows: &[RunRow]) -> BTreeMap<usize, f64> {
    rows.iter()
        .filter(|r| r.engine == "AR+")
        .map(|r| (r.batch, r.r.tps()))
        .collect()
}

fn rows_json(rows: &[RunRow]) -> Json {
    let base = baselines(rows);
    Json::Arr(
        rows.iter()
            .map(|r| row_json(r, *base.get(&r.batch).unwrap_or(&0.0)))
            .collect(),
    )
}

/// Shared-prefix serving rows (`serving_prefix` in the report): the
/// same shared-system-prompt trace served twice through PARD on the
/// virtual clock — prefix cache off, then on — over a deliberately
/// tight pool, so the report carries the hit-rate/concurrency win the
/// prefix cache buys (DESIGN.md §7).  Virtual clock + deterministic
/// backend ⇒ every number here is exact run-to-run.
fn serving_prefix_json(rt: &Runtime, o: &BenchOpts) -> Result<Json> {
    use crate::coordinator::batcher::serve_trace_virtual;
    use crate::coordinator::engines::build_engine;
    use crate::substrate::workload::{build_shared_prefix_trace, Arrival};
    let k = o.ks.first().copied().unwrap_or(4);
    let max_new = o.max_new.min(16);
    let (kv_blocks, n_req, n_prefixes, prefix_len) = (8usize, 8, 2, 32);
    let prompts = rt.prompts(&o.task)?.prompts;
    let trace = build_shared_prefix_trace(&prompts, n_req, n_prefixes,
                                          prefix_len, Arrival::Closed,
                                          max_new, o.seed);
    let mut rows = Vec::new();
    for share in [false, true] {
        let cfg = EngineConfig {
            kind: EngineKind::Pard,
            target: o.target.clone(),
            draft: default_draft(&rt.manifest, EngineKind::Pard,
                                 &o.target)?,
            batch: 4,
            k,
            max_new,
            shared_mask: true,
            kv_blocks: Some(kv_blocks),
            prefix_cache: share,
            sampling: None,
            policy: PolicyCfg::default(),
        };
        let mut engine = build_engine(rt, &cfg)?;
        engine.warmup()?;
        let stats = serve_trace_virtual(engine.as_mut(), &trace, 1.0)?;
        let m = engine.metrics();
        rows.push(obj(vec![
            ("prefix_cache", Json::Bool(share)),
            ("completed", num(stats.completed as f64)),
            ("peak_occupancy", num(stats.peak_occupancy as f64)),
            ("admission_stalls", num(stats.admission_stalls as f64)),
            ("kv_peak_blocks", num(m.kv_peak_blocks as f64)),
            ("prefix_hit_tokens", num(m.prefix_hit_tokens as f64)),
            ("blocks_shared", num(m.kv_blocks_shared as f64)),
            ("cow_copies", num(m.cow_copies as f64)),
        ]));
    }
    Ok(obj(vec![
        ("engine", Json::Str("PARD".to_string())),
        ("k", num(k as f64)),
        ("batch", num(4.0)),
        ("kv_blocks", num(kv_blocks as f64)),
        ("n_requests", num(n_req as f64)),
        ("shared_prefixes", num(n_prefixes as f64)),
        ("prefix_len", num(prefix_len as f64)),
        ("rows", Json::Arr(rows)),
    ]))
}

/// Adaptive-policy serving rows (`policy_mixed` in the report): a
/// mixed easy/hard trace served through PARD on the WORK-COSTED
/// virtual clock (DESIGN.md §9) under fixed K=2, fixed K=16, and the
/// adaptive controller.  Reported, not gated — the strict-win gate
/// lives in `tests/adaptive_policy.rs` (and its hostsim mirror) on a
/// scripted-acceptance engine where the win is provable; here real
/// accept dynamics decide, and the three rows document them.
fn policy_mixed_json(rt: &Runtime, o: &BenchOpts) -> Result<Json> {
    use crate::coordinator::batcher::serve_trace_virtual_costed;
    use crate::coordinator::engines::build_engine;
    use crate::substrate::workload::{build_mixed_trace, Arrival};
    let (n_req, batch, max_new) = (8usize, 4usize, o.max_new.min(16));
    let (pass_s, col_s) = (1.0, 0.05);
    let prompts = rt.prompts(&o.task)?.prompts;
    let trace = build_mixed_trace(&prompts, n_req, Arrival::Closed,
                                  max_new, o.seed);
    let adaptive = PolicyCfg { adaptive: true, k_min: 1, k_max: 16,
                               window: 4, dual_mode_occupancy: None };
    let variants: [(&str, usize, PolicyCfg); 3] = [
        ("fixed-k2", 2, PolicyCfg::default()),
        ("fixed-k16", 16, PolicyCfg::default()),
        ("adaptive", 4, adaptive),
    ];
    let mut rows = Vec::new();
    for (label, k, policy) in variants {
        let cfg = EngineConfig {
            kind: EngineKind::Pard,
            target: o.target.clone(),
            draft: default_draft(&rt.manifest, EngineKind::Pard,
                                 &o.target)?,
            batch,
            k,
            max_new,
            shared_mask: true,
            kv_blocks: None,
            prefix_cache: false,
            sampling: None,
            policy,
        };
        let mut engine = build_engine(rt, &cfg)?;
        engine.warmup()?;
        let stats = serve_trace_virtual_costed(engine.as_mut(), &trace,
                                               pass_s, col_s)?;
        let m = engine.metrics();
        rows.push(obj(vec![
            ("policy", Json::Str(label.to_string())),
            ("k", num(k as f64)),
            ("completed", num(stats.completed as f64)),
            ("generated", num(stats.generated as f64)),
            ("tokens_per_s", num(stats.throughput_tps)),
            ("virtual_s", num(stats.wall_s)),
            ("k_hist", Json::Arr(
                m.k_hist.iter().map(|&n| num(n as f64)).collect())),
            ("mode_switches", num(m.mode_switches as f64)),
            ("dual_mode_iters", num(m.dual_mode_iters as f64)),
        ]));
    }
    Ok(obj(vec![
        ("engine", Json::Str("PARD".to_string())),
        ("batch", num(batch as f64)),
        ("n_requests", num(n_req as f64)),
        ("max_new", num(max_new as f64)),
        ("pass_s", num(pass_s)),
        ("col_s", num(col_s)),
        ("rows", Json::Arr(rows)),
    ]))
}

/// Chaos serving rows (`robustness.serving_chaos` in the report): the
/// same closed trace served through PARD on the WORK-COSTED virtual
/// clock under a rising seeded fault storm (DESIGN.md §10) — draft +
/// target + pool specs at each rate.  Every row completes the whole
/// trace or fails rows typed-ly; the rate-0 row runs THROUGH the fault
/// plumbing (a plan whose specs never fire) and matches a fault-free
/// serve, documenting that the injection layer is pass-through.
/// Reported, not gated — the bit-identity gate lives in
/// `tests/fault_injection.rs` (and its hostsim mirror).
fn serving_chaos_json(rt: &Runtime, o: &BenchOpts) -> Result<Json> {
    use crate::coordinator::batcher::serve_trace_virtual_costed_with_faults;
    use crate::coordinator::engines::build_engine;
    use crate::substrate::fault::{FaultKind, FaultPlan, FaultSpec};
    use crate::substrate::workload::{build_trace, Arrival};
    let (n_req, batch, max_new) = (8usize, 4usize, o.max_new.min(16));
    let (pass_s, col_s) = (1.0, 0.05);
    let k = o.ks.first().copied().unwrap_or(4);
    let prompts = rt.prompts(&o.task)?.prompts;
    let trace =
        build_trace(&prompts, n_req, Arrival::Closed, max_new, o.seed);
    let mut rows = Vec::new();
    for rate in [0.0, 0.1, 0.3] {
        let cfg = EngineConfig {
            kind: EngineKind::Pard,
            target: o.target.clone(),
            draft: default_draft(&rt.manifest, EngineKind::Pard,
                                 &o.target)?,
            batch,
            k,
            max_new,
            shared_mask: true,
            kv_blocks: None,
            prefix_cache: false,
            sampling: None,
            policy: PolicyCfg::default(),
        };
        let mut engine = build_engine(rt, &cfg)?;
        engine.warmup()?;
        let mut plan = FaultPlan::new(vec![
            FaultSpec { kind: FaultKind::Draft, rate, seed: 11 },
            FaultSpec { kind: FaultKind::Target, rate: rate * 0.5,
                        seed: 13 },
            FaultSpec { kind: FaultKind::Pool, rate: rate * 0.25,
                        seed: 17 },
        ]);
        let stats = serve_trace_virtual_costed_with_faults(
            engine.as_mut(), &trace, pass_s, col_s, &mut plan)?;
        let m = engine.metrics();
        rows.push(obj(vec![
            ("rate", num(rate)),
            ("completed", num(stats.completed as f64)),
            ("failed", num(stats.failed as f64)),
            ("generated", num(stats.generated as f64)),
            ("tokens_per_s", num(stats.throughput_tps)),
            ("virtual_s", num(stats.wall_s)),
            ("faults_injected", num(m.faults_injected as f64)),
            ("draft_fallbacks", num(m.draft_fallbacks as f64)),
            ("row_retries", num(m.row_retries as f64)),
            ("rows_failed", num(m.rows_failed as f64)),
            ("pool_rebuilds", num(m.pool_rebuilds as f64)),
            // Leak check: the pool must drain to 0 whatever fired.
            ("kv_blocks_at_drain", num(m.kv_blocks_in_use as f64)),
        ]));
    }
    Ok(obj(vec![
        ("engine", Json::Str("PARD".to_string())),
        ("k", num(k as f64)),
        ("batch", num(batch as f64)),
        ("n_requests", num(n_req as f64)),
        ("max_new", num(max_new as f64)),
        ("pass_s", num(pass_s)),
        ("col_s", num(col_s)),
        ("rows", Json::Arr(rows)),
    ]))
}

fn weight_bytes_json(w: &OpWeightBytes) -> Json {
    obj(vec![
        ("qkv", num(w.qkv as f64)),
        ("wo", num(w.wo as f64)),
        ("mlp", num(w.mlp as f64)),
        ("logits", num(w.logits as f64)),
        ("fuse", num(w.fuse as f64)),
        ("total", num(w.total() as f64)),
    ])
}

/// Quantized-backend rows (`quant` in the report, additive v1): the
/// int8 per-panel host twin (`--backend host-q8`) measured against the
/// f32 host path it derives from.  Three parts: a fwd probe recording
/// the max per-logit |q8 − f32| error on the target model (the
/// bounded-error contract, as a number in the trajectory), the per-op
/// weight-bytes ledger for both representations (the Table 6 bytes
/// argument: ~4× less traffic), and AR+/PARD eval rows on both
/// backends with tokens/s and accept-rate deltas.  `--compare` gates
/// the q8 rows through [`compare_quant`], which *warns* instead of
/// failing when the baseline predates this section.
fn quant_json(host_rt: &Runtime, o: &BenchOpts) -> Result<Json> {
    let q8_rt = Runtime::host_q8_with_threads(o.seed, o.threads);

    // -- fwd probe: max per-logit error on the target model --
    let f32_m = host_rt.model(&o.target)?;
    let q8_m = q8_rt.model(&o.target)?;
    let toks = [0i32, 13, 20, 21, 33, 40];
    let pos = [0i32, 1, 2, 3, 4, 5];
    let t = toks.len();
    let cf = f32_m.new_cache(1)?;
    let cq = q8_m.new_cache(1)?;
    let a = f32_m.fwd(1, t, &toks, &pos, None, &cf)?;
    let b = q8_m.fwd(1, t, &toks, &pos, None, &cq)?;
    let mut max_abs_err = 0f64;
    let mut max_abs_logit = 0f64;
    for (x, y) in a.logits.iter().zip(&b.logits) {
        max_abs_err = max_abs_err.max((x - y).abs() as f64);
        max_abs_logit = max_abs_logit.max(x.abs() as f64);
    }

    // -- weight-bytes ledger, both representations --
    let (wf, wq) = (f32_m.op_weight_bytes(), q8_m.op_weight_bytes());
    let ratio = if wq.total() > 0 {
        wf.total() as f64 / wq.total() as f64
    } else {
        0.0
    };

    // -- eval rows: AR+ and PARD on f32 host vs host-q8 --
    let k = o.ks.first().copied().unwrap_or(4);
    let (n_prompts, max_new) = (o.n_prompts.min(4), o.max_new.min(16));
    let mut rows = Vec::new();
    let mut tps = BTreeMap::new();
    let mut accept = BTreeMap::new();
    for (rt, backend) in [(host_rt, "host"), (&q8_rt, "host-q8")] {
        for kind in [EngineKind::ArPlus, EngineKind::Pard] {
            let cfg = EngineConfig {
                kind,
                target: o.target.clone(),
                draft: default_draft(&rt.manifest, kind, &o.target)?,
                batch: 1,
                k,
                max_new,
                shared_mask: true,
                kv_blocks: None,
                prefix_cache: false,
                sampling: None,
                policy: PolicyCfg::default(),
            };
            let prompts = rt.prompts(&o.task)?.take(n_prompts);
            let r = run_eval(rt, &cfg, &prompts, max_new, &o.task)?;
            let m = &r.metrics;
            tps.insert((kind.label(), backend), m.tps());
            accept.insert((kind.label(), backend), m.mean_accept_len());
            rows.push(obj(vec![
                ("engine", Json::Str(kind.label().to_string())),
                ("backend", Json::Str(backend.to_string())),
                ("k", if kind == EngineKind::ArPlus {
                    Json::Null
                } else {
                    num(k as f64)
                }),
                ("batch", num(1.0)),
                ("tokens_per_s", num(m.tps())),
                ("mean_accept_len", num(m.mean_accept_len())),
                ("generated", num(m.generated as f64)),
            ]));
        }
    }
    // q8-vs-f32 deltas per engine: the throughput win the smaller
    // weight stream buys, and the accept-rate cost of drafting /
    // verifying with perturbed logits.
    let deltas = ["AR+", "PARD"]
        .iter()
        .map(|&e| {
            let f = tps.get(&(e, "host")).copied().unwrap_or(0.0);
            let q = tps.get(&(e, "host-q8")).copied().unwrap_or(0.0);
            let af = accept.get(&(e, "host")).copied().unwrap_or(0.0);
            let aq = accept.get(&(e, "host-q8")).copied().unwrap_or(0.0);
            obj(vec![
                ("engine", Json::Str(e.to_string())),
                ("tps_ratio_q8_vs_f32",
                 num(if f > 0.0 { q / f } else { 0.0 })),
                ("accept_len_delta", num(aq - af)),
            ])
        })
        .collect();
    Ok(obj(vec![
        ("backend", Json::Str(q8_rt.backend_label().to_string())),
        ("probe", obj(vec![
            ("model", Json::Str(o.target.clone())),
            ("t", num(t as f64)),
            ("max_abs_logit_err", num(max_abs_err)),
            ("max_abs_logit", num(max_abs_logit)),
        ])),
        ("weight_bytes", obj(vec![
            ("f32", weight_bytes_json(&wf)),
            ("q8", weight_bytes_json(&wq)),
            ("f32_over_q8", num(ratio)),
        ])),
        ("k", num(k as f64)),
        ("n_prompts", num(n_prompts as f64)),
        ("max_new", num(max_new as f64)),
        ("runs", Json::Arr(rows)),
        ("deltas", Json::Arr(deltas)),
    ]))
}

/// Run the sweep and build the full report document.
///
/// The host backend is always measured; with `opts.oracle` the scalar
/// reference replays the identical sweep and the report gains an
/// `oracle` section plus `host_vs_reference` speedup aggregates
/// (acceptance bar: `geomean >= 3`).
pub fn hotpath_report(opts: &BenchOpts) -> Result<Json> {
    let host_rt = Runtime::host_with_threads(opts.seed, opts.threads);
    let host_rows = sweep(&host_rt, opts)?;

    let mut top = vec![
        ("schema", Json::Str(BENCH_SCHEMA.to_string())),
        ("backend", Json::Str(host_rt.backend_label().to_string())),
        ("threads",
         num(host_rt.host_threads().unwrap_or(1) as f64)),
        ("seed", num(opts.seed as f64)),
        ("task", Json::Str(opts.task.clone())),
        ("target", Json::Str(opts.target.clone())),
        ("n_prompts", num(opts.n_prompts as f64)),
        ("max_new", num(opts.max_new as f64)),
        ("sweep", obj(vec![
            ("engines", Json::Arr(
                ["AR+", "VSD", "PARD", "EAGLE"]
                    .iter()
                    .map(|e| Json::Str(e.to_string()))
                    .collect(),
            )),
            ("k", nums(&opts.ks)),
            ("batch", nums(&opts.batches)),
        ])),
        ("runs", rows_json(&host_rows)),
        ("serving_prefix", serving_prefix_json(&host_rt, opts)?),
        ("policy_mixed", policy_mixed_json(&host_rt, opts)?),
        // Additive v1 object: `--compare` keys on runs[].tokens_per_s
        // only, so older reports stay valid.
        ("robustness", obj(vec![
            ("serving_chaos", serving_chaos_json(&host_rt, opts)?),
        ])),
        // Additive v1 object: the int8 host twin vs the f32 host path
        // ([`quant_json`]).  Baselines that predate it only *warn* in
        // `--compare` ([`compare_quant`]).
        ("quant", quant_json(&host_rt, opts)?),
    ];

    if opts.oracle {
        let ref_rt = Runtime::reference(opts.seed);
        let ref_rows = sweep(&ref_rt, opts)?;
        // Same sweep function, same opts => rows align pairwise.
        let mut ratios = Vec::with_capacity(host_rows.len());
        let mut per_run = Vec::with_capacity(host_rows.len());
        for (hr, rr) in host_rows.iter().zip(&ref_rows) {
            debug_assert_eq!((hr.engine, hr.k, hr.batch),
                             (rr.engine, rr.k, rr.batch));
            let ratio = if rr.r.tps() > 0.0 {
                hr.r.tps() / rr.r.tps()
            } else {
                0.0
            };
            ratios.push(ratio);
            per_run.push(obj(vec![
                ("engine", Json::Str(hr.engine.to_string())),
                ("k", hr.k.map_or(Json::Null, |k| Json::Num(k as f64))),
                ("batch", num(hr.batch as f64)),
                ("speedup", num(ratio)),
            ]));
        }
        let positive: Vec<f64> =
            ratios.iter().copied().filter(|&r| r > 0.0).collect();
        let geomean = if positive.is_empty() {
            0.0
        } else {
            (positive.iter().map(|r| r.ln()).sum::<f64>()
                / positive.len() as f64)
                .exp()
        };
        let min = positive.iter().copied().fold(f64::INFINITY, f64::min);
        top.push(("oracle", obj(vec![
            ("backend", Json::Str(ref_rt.backend_label().to_string())),
            ("runs", rows_json(&ref_rows)),
        ])));
        top.push(("host_vs_reference", obj(vec![
            ("per_run", Json::Arr(per_run)),
            ("geomean", num(geomean)),
            ("min", num(if min.is_finite() { min } else { 0.0 })),
        ])));
    }

    Ok(obj(top))
}

/// Max fractional tokens/s loss a sweep cell may show against an older
/// report before [`compare_reports`] flags it (10%).
pub const COMPARE_TOL: f64 = 0.10;

/// Identity of one sweep cell, as printable strings so `k = null`
/// (AR+) keys cleanly.
fn cell_key(run: &Json) -> (String, String, String) {
    let field = |k: &str| {
        run.get(k).map(|v| v.to_string()).unwrap_or_default()
    };
    (field("engine"), field("k"), field("batch"))
}

fn cell_tps(run: &Json) -> f64 {
    run.get("tokens_per_s").and_then(|v| v.as_f64()).unwrap_or(0.0)
}

/// Diff two bench reports cell by cell and return one human-readable
/// line per regression (empty = pass).  A cell regresses when its
/// tokens/s drops more than `tol` (a fraction, e.g. 0.10) below the
/// old report's value, or when it disappears from the new sweep while
/// the old one measured it.  Cells only the new report has are fine —
/// widening the sweep is not a regression.
pub fn compare_reports(old: &Json, new: &Json, tol: f64) -> Vec<String> {
    let runs = |j: &Json| -> Vec<Json> {
        j.get("runs")
            .and_then(|r| r.as_arr())
            .map(|a| a.to_vec())
            .unwrap_or_default()
    };
    let new_tps: BTreeMap<_, f64> = runs(new)
        .iter()
        .map(|r| (cell_key(r), cell_tps(r)))
        .collect();
    let mut lines = Vec::new();
    for run in runs(old) {
        let key = cell_key(&run);
        let old_tps = cell_tps(&run);
        if old_tps <= 0.0 {
            continue; // nothing measured to regress against
        }
        match new_tps.get(&key) {
            None => lines.push(format!(
                "engine={} k={} batch={}: cell missing from the new \
                 report ({old_tps:.1} tok/s before)",
                key.0, key.1, key.2
            )),
            Some(&tps) if tps < old_tps * (1.0 - tol) => {
                lines.push(format!(
                    "engine={} k={} batch={}: {old_tps:.1} -> {tps:.1} \
                     tok/s ({:+.1}%, tolerance -{:.0}%)",
                    key.0, key.1, key.2,
                    (tps / old_tps - 1.0) * 100.0,
                    tol * 100.0
                ));
            }
            Some(_) => {}
        }
    }
    lines
}

/// Diff the `quant` sections of two reports.  Returns `(baseline_has
/// _quant, regressions)`: when the old report predates the `quant`
/// section entirely (reports written before the host-q8 backend
/// existed), the first element is `false` and the caller should WARN,
/// not fail — an old baseline must stay usable as a tokens/s gate.
/// When both reports carry the section, q8 cells are gated exactly
/// like the main sweep: a (engine, backend) row losing more than `tol`
/// of its tokens/s, or disappearing, is a regression line.
pub fn compare_quant(old: &Json, new: &Json, tol: f64)
                     -> (bool, Vec<String>) {
    let Some(old_q) = old.get("quant") else {
        return (false, Vec::new());
    };
    let rows = |j: &Json| -> Vec<Json> {
        j.get("runs")
            .and_then(|r| r.as_arr())
            .map(|a| a.to_vec())
            .unwrap_or_default()
    };
    let key = |run: &Json| -> (String, String) {
        let field = |k: &str| {
            run.get(k).map(|v| v.to_string()).unwrap_or_default()
        };
        (field("engine"), field("backend"))
    };
    let new_rows = new.get("quant").map(rows).unwrap_or_default();
    let new_tps: BTreeMap<_, f64> = new_rows
        .iter()
        .map(|r| (key(r), cell_tps(r)))
        .collect();
    let mut lines = Vec::new();
    for run in rows(old_q) {
        let k = key(&run);
        let old_tps = cell_tps(&run);
        if old_tps <= 0.0 {
            continue;
        }
        match new_tps.get(&k) {
            None => lines.push(format!(
                "quant engine={} backend={}: cell missing from the new \
                 report ({old_tps:.1} tok/s before)",
                k.0, k.1
            )),
            Some(&tps) if tps < old_tps * (1.0 - tol) => {
                lines.push(format!(
                    "quant engine={} backend={}: {old_tps:.1} -> \
                     {tps:.1} tok/s ({:+.1}%, tolerance -{:.0}%)",
                    k.0, k.1,
                    (tps / old_tps - 1.0) * 100.0,
                    tol * 100.0
                ));
            }
            Some(_) => {}
        }
    }
    (true, lines)
}

/// Serialize `report` to `path` (single line + trailing newline — the
/// in-repo JSON writer emits no insignificant whitespace).
pub fn write_report(path: &Path, report: &Json) -> Result<()> {
    let mut text = report.to_string();
    text.push('\n');
    std::fs::write(path, text)
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_opts_cover_required_sweep() {
        let o = BenchOpts::default();
        assert_eq!(o.ks, vec![2, 4, 8]);
        assert!(o.batches.contains(&1));
        assert!(o.oracle);
        assert!(o.threads.is_none(), "default pool size must be ambient");
    }

    /// Hand-build a report with the given (engine, k, batch, tps)
    /// cells — enough structure for compare_reports.
    fn fake_report(cells: &[(&str, Option<usize>, usize, f64)]) -> Json {
        let runs = cells
            .iter()
            .map(|&(engine, k, batch, tps)| {
                obj(vec![
                    ("engine", Json::Str(engine.to_string())),
                    ("k", k.map_or(Json::Null, |k| num(k as f64))),
                    ("batch", num(batch as f64)),
                    ("tokens_per_s", num(tps)),
                ])
            })
            .collect();
        obj(vec![("runs", Json::Arr(runs))])
    }

    #[test]
    fn compare_passes_within_tolerance() {
        let old = fake_report(&[("AR+", None, 1, 100.0),
                                ("PARD", Some(8), 1, 300.0)]);
        let new = fake_report(&[("AR+", None, 1, 95.0),
                                ("PARD", Some(8), 1, 280.0)]);
        assert!(compare_reports(&old, &new, COMPARE_TOL).is_empty(),
                "-5%/-7% are inside the 10% tolerance");
    }

    #[test]
    fn compare_flags_regressed_and_missing_cells() {
        let old = fake_report(&[("AR+", None, 1, 100.0),
                                ("PARD", Some(8), 1, 300.0),
                                ("VSD", Some(2), 4, 50.0)]);
        let new = fake_report(&[("AR+", None, 1, 100.0),
                                ("PARD", Some(8), 1, 150.0)]);
        let lines = compare_reports(&old, &new, COMPARE_TOL);
        assert_eq!(lines.len(), 2, "one regression + one missing cell");
        assert!(lines.iter().any(|l| l.contains("PARD")
                                 && l.contains("300.0")
                                 && l.contains("150.0")),
                "PARD halved must be flagged: {lines:?}");
        assert!(lines.iter().any(|l| l.contains("VSD")
                                 && l.contains("missing")),
                "dropped VSD cell must be flagged: {lines:?}");
    }

    #[test]
    fn compare_ignores_new_cells_and_zero_baselines() {
        let old = fake_report(&[("AR+", None, 1, 0.0)]);
        let new = fake_report(&[("AR+", None, 1, 0.0),
                                ("PARD", Some(16), 1, 500.0)]);
        assert!(compare_reports(&old, &new, COMPARE_TOL).is_empty(),
                "zero baselines and sweep widening are not regressions");
    }

    /// Fake report with a `quant` section holding the given
    /// (engine, backend, tps) rows.
    fn fake_quant_report(cells: &[(&str, &str, f64)]) -> Json {
        let runs = cells
            .iter()
            .map(|&(engine, backend, tps)| {
                obj(vec![
                    ("engine", Json::Str(engine.to_string())),
                    ("backend", Json::Str(backend.to_string())),
                    ("tokens_per_s", num(tps)),
                ])
            })
            .collect();
        obj(vec![
            ("runs", Json::Arr(Vec::new())),
            ("quant", obj(vec![("runs", Json::Arr(runs))])),
        ])
    }

    #[test]
    fn compare_quant_warns_when_baseline_predates_section() {
        // An old report with no `quant` key at all: signal warn, no
        // regression lines — the f32 gate must stay usable.
        let old = fake_report(&[("AR+", None, 1, 100.0)]);
        let new = fake_quant_report(&[("PARD", "host-q8", 200.0)]);
        let (has, lines) = compare_quant(&old, &new, COMPARE_TOL);
        assert!(!has, "missing quant section must be flagged as absent");
        assert!(lines.is_empty());
    }

    #[test]
    fn compare_quant_gates_q8_cells_like_the_main_sweep() {
        let old = fake_quant_report(&[("AR+", "host-q8", 100.0),
                                      ("PARD", "host-q8", 300.0),
                                      ("PARD", "host", 400.0)]);
        let new = fake_quant_report(&[("AR+", "host-q8", 97.0),
                                      ("PARD", "host-q8", 150.0)]);
        let (has, lines) = compare_quant(&old, &new, COMPARE_TOL);
        assert!(has);
        assert_eq!(lines.len(), 2,
                   "one halved q8 cell + one missing host cell: {lines:?}");
        assert!(lines.iter().any(|l| l.contains("host-q8")
                                 && l.contains("300.0")));
        assert!(lines.iter().any(|l| l.contains("missing")));
    }
}
