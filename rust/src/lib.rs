//! PARD: PARallel Draft speculative decoding — reproduction library.
//!
//! Three-layer architecture (DESIGN.md):
//! * L1 — Pallas cached-attention kernel (python, build time, AOT'd)
//! * L2 — JAX SynLlama models (python, build time, AOT'd to HLO text)
//! * L3 — this crate: the serving coordinator driving models through
//!   the [`runtime::Backend`] trait — AOT artifacts via the PJRT C API
//!   (`xla` crate, feature `pjrt`), the deterministic pure-Rust
//!   reference oracle (DESIGN.md §6), or the fast deterministic host
//!   serving path (DESIGN.md §8) — with python fully off the request
//!   path.

pub mod analysis;
pub mod coordinator;
pub mod report;
pub mod runtime;
pub mod server;
pub mod substrate;

pub use runtime::{Runtime, RuntimeSpec};
