//! Serving front-end: a dedicated engine thread behind mpsc channels.
//!
//! (The offline build vendors no async runtime, and PJRT handles are
//! not Send anyway — the natural architecture is the same one vLLM
//! uses: an engine loop on its own OS thread, callers talk to it over
//! channels.  Documented as a substitution in DESIGN.md §3.)
//!
//! Since the paged-cache refactor (DESIGN.md §7) the engine thread
//! runs the **batched serving loop**: every incoming `Generate`
//! request joins an FCFS queue, free batch slots are refilled whenever
//! the KV block pool has room ([`crate::coordinator::engines::Engine::can_admit`]),
//! and one `step` advances every live request together — concurrent
//! callers share decode iterations instead of serializing through
//! slot 0.  Finished slots release their blocks and reply on their
//! caller's channel; [`Server::submit`] is the non-blocking entry
//! ([`Server::generate`] is submit + wait).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::engines::{build_engine, Engine, EngineConfig};
use crate::coordinator::metrics::Metrics;
use crate::runtime::RuntimeSpec;

#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency_s: f64,
}

enum Msg {
    Generate(GenRequest, mpsc::Sender<GenResponse>),
    Metrics(mpsc::Sender<Metrics>),
    Shutdown,
}

/// A queued or in-flight request with its reply channel and the
/// instant it reached the engine thread (latency origin).
struct Pending {
    req: GenRequest,
    reply: mpsc::Sender<GenResponse>,
    t0: Instant,
}

/// Handle to the engine thread.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    join: Option<thread::JoinHandle<Result<()>>>,
}

impl Server {
    /// Boot an engine on its own thread.  The runtime (PJRT artifacts
    /// or the reference backend) and engine are constructed inside the
    /// thread (PJRT handles never cross threads); `RuntimeSpec` is the
    /// `Send` description of what to open.
    pub fn start(spec: RuntimeSpec, cfg: EngineConfig) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = thread::Builder::new()
            .name("pard-engine".into())
            .spawn(move || -> Result<()> {
                let rt = spec.open()?;
                let mut engine = build_engine(&rt, &cfg)?;
                engine.warmup()?;
                serve_loop(engine.as_mut(), &rx)
            })?;
        Ok(Server { tx, join: Some(join) })
    }

    /// Enqueue a request without waiting: the response arrives on the
    /// returned channel once the batched loop completes it.  Multiple
    /// outstanding submissions share batch slots and decode
    /// iterations.
    pub fn submit(&self, req: GenRequest)
                  -> Result<mpsc::Receiver<GenResponse>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Generate(req, tx))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        Ok(rx)
    }

    /// Submit and block until the response arrives.
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        Ok(self.submit(req)?.recv()?)
    }

    pub fn metrics(&self) -> Result<Metrics> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Metrics(tx))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow::anyhow!("engine panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The engine thread's batched serving loop: drain the channel (block
/// only when idle), admit queued requests into free slots while the KV
/// pool has room, step every live sequence once, harvest and reply.
/// `Shutdown` stops intake and exits once in-flight work drains.
fn serve_loop(engine: &mut dyn Engine, rx: &mpsc::Receiver<Msg>)
              -> Result<()> {
    let b = engine.batch();
    let mut queue: VecDeque<Pending> = VecDeque::new();
    let mut slots: Vec<Option<Pending>> = (0..b).map(|_| None).collect();
    let mut open = true;
    loop {
        let live = slots.iter().filter(|s| s.is_some()).count();
        let idle = live == 0 && queue.is_empty();
        if idle && !open {
            return Ok(());
        }
        if idle {
            // Nothing to do: park on the channel instead of spinning.
            match rx.recv() {
                Ok(msg) => {
                    if !handle(msg, engine, &mut queue) {
                        open = false;
                    }
                }
                Err(_) => return Ok(()), // every Server handle dropped
            }
        }
        while let Ok(msg) = rx.try_recv() {
            if !handle(msg, engine, &mut queue) {
                open = false;
            }
        }

        // FCFS admission, gated on free slots AND free KV blocks.
        for slot in 0..b {
            if slots[slot].is_some() {
                continue;
            }
            let Some(head) = queue.front() else { break };
            if !engine.can_admit(&head.req.prompt, head.req.max_new) {
                if slots.iter().all(|s| s.is_none()) {
                    // Even an empty engine can't fit it: reject THIS
                    // request — dropping its reply sender surfaces a
                    // channel error to its caller — and keep serving
                    // everyone else.
                    let p = queue.pop_front().unwrap();
                    eprintln!(
                        "pard-engine: rejecting request {}: needs \
                         more KV blocks than the whole pool holds — \
                         raise --kv-blocks",
                        p.req.id
                    );
                    continue; // next head, same pass
                }
                engine.metrics_mut().admission_stalls += 1;
                break; // backpressure: wait for a release
            }
            let p = queue.pop_front().unwrap();
            engine.admit(slot, &p.req.prompt, p.req.max_new)?;
            slots[slot] = Some(p);
        }

        if engine.any_active() {
            engine.step()?;
            engine.metrics_mut().iterations += 1;
        }

        // Harvest: reply and release finished slots.
        for slot in 0..b {
            let done = slots[slot]
                .as_ref()
                .map(|_| engine.seqs()[slot].done)
                .unwrap_or(false);
            if done {
                let p = slots[slot].take().unwrap();
                let tokens = engine.seqs()[slot].gen_tokens().to_vec();
                engine.release(slot);
                let _ = p.reply.send(GenResponse {
                    id: p.req.id,
                    tokens,
                    latency_s: p.t0.elapsed().as_secs_f64(),
                });
            }
        }
    }
}

/// Apply one control message; returns false when intake must close
/// (`Shutdown`).
fn handle(msg: Msg, engine: &mut dyn Engine,
          queue: &mut VecDeque<Pending>) -> bool {
    match msg {
        Msg::Generate(req, reply) => {
            queue.push_back(Pending { req, reply, t0: Instant::now() });
            true
        }
        Msg::Metrics(reply) => {
            let _ = reply.send(engine.metrics().clone());
            true
        }
        Msg::Shutdown => false,
    }
}
