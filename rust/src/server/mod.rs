//! Serving front-end: a dedicated engine thread behind mpsc channels.
//!
//! (The offline build vendors no async runtime, and PJRT handles are
//! not Send anyway — the natural architecture is the same one vLLM
//! uses: an engine loop on its own OS thread, callers talk to it over
//! channels.  Documented as a substitution in DESIGN.md §3.)
//!
//! Since the paged-cache refactor (DESIGN.md §7) the engine thread
//! runs the **batched serving loop**: every incoming `Generate`
//! request joins an FCFS queue, free batch slots are refilled whenever
//! the KV block pool has room ([`crate::coordinator::engines::Engine::can_admit`]),
//! and one `step` advances every live request together — concurrent
//! callers share decode iterations instead of serializing through
//! slot 0.  Finished slots release their blocks and reply on their
//! caller's channel; [`Server::submit`] is the non-blocking entry
//! ([`Server::generate`] is submit + wait).
//!
//! Request lifecycle (DESIGN.md §10): every request ends in exactly one
//! typed [`GenOutcome`] on its reply channel — `Completed`, `Rejected`
//! (oversized for the whole pool), `Cancelled`
//! ([`SubmitHandle::cancel`]), `DeadlineExceeded`
//! ([`GenRequest::deadline`], wall clock from intake), or `Failed`
//! (persistent target-pass incident, or the engine died with this
//! request in flight).  The engine thread itself never dies to an
//! injected fault: worker-pool panics are caught and retried once per
//! incident, and a fatal serve-loop error replies `Failed` to every
//! in-flight caller and stashes its message where [`Server::metrics`]
//! and [`Server::shutdown`] can surface it.

use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::engines::{build_engine, Engine, EngineConfig};
use crate::coordinator::metrics::Metrics;
use crate::runtime::RuntimeSpec;
use crate::substrate::bench::stopwatch;
use crate::substrate::fault::{FaultPlan, FaultSet};

#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Optional completion budget, measured on the WALL clock from the
    /// instant the request reaches the engine thread.  Past it the
    /// request is dropped — queued or mid-decode — its KV blocks are
    /// released, and the caller gets [`GenOutcome::DeadlineExceeded`].
    pub deadline: Option<Duration>,
}

impl GenRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> Self {
        GenRequest { id, prompt, max_new, deadline: None }
    }
}

#[derive(Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency_s: f64,
}

/// How a submitted request ended (DESIGN.md §10).  Exactly one arrives
/// on the reply channel per request, whatever happens.
#[derive(Debug)]
pub enum GenOutcome {
    Completed(GenResponse),
    /// Admission-impossible: the request needs more KV blocks than the
    /// whole pool holds even when empty.
    Rejected { id: u64, reason: String },
    /// [`SubmitHandle::cancel`] reached the engine before completion.
    Cancelled { id: u64 },
    /// [`GenRequest::deadline`] elapsed before completion.
    DeadlineExceeded { id: u64 },
    /// A persistent target-pass incident failed this row, or the
    /// engine thread hit a fatal error with this request in flight.
    Failed { id: u64, reason: String },
}

enum Msg {
    Generate(GenRequest, mpsc::Sender<GenOutcome>),
    Cancel(u64),
    Metrics(mpsc::Sender<Metrics>),
    Shutdown,
}

/// The server was shut down (or its engine thread is gone): no further
/// messages can be delivered.  A concrete type — the vendored `anyhow`
/// shim has no downcasting, so callers match on this directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerClosed;

impl fmt::Display for ServerClosed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "server is shut down (engine thread gone)")
    }
}

impl std::error::Error for ServerClosed {}

/// Live handle to one submitted request: await its [`GenOutcome`] or
/// cancel it.
pub struct SubmitHandle {
    id: u64,
    rx: mpsc::Receiver<GenOutcome>,
    ctl: mpsc::Sender<Msg>,
}

impl SubmitHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request's outcome arrives.
    pub fn recv(&self) -> Result<GenOutcome, ServerClosed> {
        self.rx.recv().map_err(|_| ServerClosed)
    }

    /// [`SubmitHandle::recv`] with a timeout (None = not yet done).
    pub fn recv_timeout(&self, d: Duration)
                        -> Result<Option<GenOutcome>, ServerClosed> {
        match self.rx.recv_timeout(d) {
            Ok(o) => Ok(Some(o)),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServerClosed),
        }
    }

    /// Ask the engine to drop this request.  Best-effort and
    /// non-blocking: if the request already finished, the original
    /// outcome stands; otherwise the caller's `recv` yields
    /// [`GenOutcome::Cancelled`] and the slot's KV blocks are released
    /// immediately.
    pub fn cancel(&self) {
        let _ = self.ctl.send(Msg::Cancel(self.id));
    }
}

/// Handle to the engine thread.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    join: Option<thread::JoinHandle<Result<()>>>,
    /// First fatal engine-thread incident (serve-loop error or panic),
    /// stashed so `metrics()`/`shutdown()`/`Drop` can surface it even
    /// after the thread is gone.
    fatal: Arc<Mutex<Option<String>>>,
}

impl Server {
    /// Boot an engine on its own thread.  The runtime (PJRT artifacts
    /// or the reference backend) and engine are constructed inside the
    /// thread (PJRT handles never cross threads); `RuntimeSpec` is the
    /// `Send` description of what to open.
    pub fn start(spec: RuntimeSpec, cfg: EngineConfig) -> Result<Self> {
        Server::start_inner(spec, cfg, None)
    }

    /// [`Server::start`] with an armed [`FaultPlan`]: the serve loop
    /// draws one fault set per decode iteration that steps an
    /// already-live batch and injects it into the engine
    /// (DESIGN.md §10).
    pub fn start_with_faults(spec: RuntimeSpec, cfg: EngineConfig,
                             fault: FaultPlan) -> Result<Self> {
        Server::start_inner(spec, cfg, Some(fault))
    }

    fn start_inner(spec: RuntimeSpec, cfg: EngineConfig,
                   fault: Option<FaultPlan>) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let fatal = Arc::new(Mutex::new(None));
        let stash = fatal.clone();
        let join = thread::Builder::new()
            .name("pard-engine".into())
            .spawn(move || -> Result<()> {
                // Catch panics from engine construction/admission too:
                // a bare unwind would leave join() with an opaque Any
                // and Drop would swallow it entirely.
                let res = catch_unwind(AssertUnwindSafe(
                    || -> Result<()> {
                        let rt = spec.open()?;
                        let mut engine = build_engine(&rt, &cfg)?;
                        engine.warmup()?;
                        serve_loop(engine.as_mut(), &rx, fault)
                    }));
                let res = match res {
                    Ok(r) => r,
                    Err(p) => Err(anyhow::anyhow!(
                        "engine thread panicked: {}", panic_msg(&p))),
                };
                if let Err(e) = &res {
                    *lock_stash(&stash) = Some(format!("{e:?}"));
                }
                res
            })?;
        Ok(Server { tx, join: Some(join), fatal })
    }

    /// Enqueue a request without waiting: its typed [`GenOutcome`]
    /// arrives on the returned handle once the batched loop resolves
    /// it.  Multiple outstanding submissions share batch slots and
    /// decode iterations.
    pub fn submit(&self, req: GenRequest)
                  -> Result<SubmitHandle, ServerClosed> {
        let id = req.id;
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Generate(req, tx))
            .map_err(|_| ServerClosed)?;
        Ok(SubmitHandle { id, rx, ctl: self.tx.clone() })
    }

    /// Submit and block until the outcome arrives; non-`Completed`
    /// outcomes surface as errors (use [`Server::submit`] to match on
    /// them).
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        match self.submit(req)?.recv()? {
            GenOutcome::Completed(resp) => Ok(resp),
            GenOutcome::Rejected { id, reason } => {
                Err(anyhow::anyhow!("request {id} rejected: {reason}"))
            }
            GenOutcome::Cancelled { id } => {
                Err(anyhow::anyhow!("request {id} cancelled"))
            }
            GenOutcome::DeadlineExceeded { id } => {
                Err(anyhow::anyhow!("request {id} exceeded its deadline"))
            }
            GenOutcome::Failed { id, reason } => {
                Err(anyhow::anyhow!("request {id} failed: {reason}"))
            }
        }
    }

    pub fn metrics(&self) -> Result<Metrics> {
        let (tx, rx) = mpsc::channel();
        if self.tx.send(Msg::Metrics(tx)).is_err() {
            return Err(self.dead_error());
        }
        rx.recv().map_err(|_| self.dead_error())
    }

    /// First fatal engine-thread incident, if any (None = healthy).
    pub fn fatal_error(&self) -> Option<String> {
        lock_stash(&self.fatal).clone()
    }

    /// Stop intake, drain in-flight work, and join the engine thread.
    /// Idempotent: later calls (and `Drop`) are no-ops.  After
    /// shutdown, [`Server::submit`] returns [`ServerClosed`].
    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            match j.join() {
                Ok(r) => r?,
                Err(_) => return Err(self.dead_error()),
            }
        }
        Ok(())
    }

    fn dead_error(&self) -> anyhow::Error {
        match lock_stash(&self.fatal).as_ref() {
            Some(m) => anyhow::anyhow!("engine thread died: {m}"),
            None => anyhow::anyhow!("engine thread gone"),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let joined = j.join();
            // Don't swallow a dying engine: surface the stashed
            // incident (or the bare panic) on stderr, since Drop has
            // no Result to return it through.
            if joined.is_err() || matches!(joined, Ok(Err(_))) {
                let msg = lock_stash(&self.fatal)
                    .clone()
                    .unwrap_or_else(|| "engine thread panicked".into());
                eprintln!("pard-engine: died: {msg}");
            }
        }
    }
}

/// Poison-tolerant access to the fatal-incident stash (audit rule R1):
/// the slot holds a plain `Option<String>`, so no invariant can be
/// torn by a panic mid-write — taking the poisoned guard is strictly
/// better than panicking the serving path.
fn lock_stash(m: &Mutex<Option<String>>)
              -> std::sync::MutexGuard<'_, Option<String>> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Best-effort panic payload → string (panics carry `&str`/`String`
/// payloads in this codebase).
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// A queued or in-flight request with its reply channel and the
/// instant it reached the engine thread (latency + deadline origin).
struct Pending {
    req: GenRequest,
    reply: mpsc::Sender<GenOutcome>,
    t0: Instant,
}

impl Pending {
    fn expired(&self) -> bool {
        self.req.deadline.is_some_and(|d| self.t0.elapsed() > d)
    }
}

struct LoopState {
    queue: VecDeque<Pending>,
    slots: Vec<Option<Pending>>,
    open: bool,
}

/// The engine thread's batched serving loop: drain the channel (block
/// only when idle), admit queued requests into free slots while the KV
/// pool has room, step every live sequence once, harvest and reply
/// with typed outcomes.  `Shutdown` stops intake and exits once
/// in-flight work drains.  On a fatal error every in-flight caller is
/// told `Failed` before the error propagates — reply channels never
/// just vanish.
fn serve_loop(engine: &mut dyn Engine, rx: &mpsc::Receiver<Msg>,
              mut fault: Option<FaultPlan>) -> Result<()> {
    let b = engine.batch();
    let mut st = LoopState {
        queue: VecDeque::new(),
        slots: (0..b).map(|_| None).collect(),
        open: true,
    };
    loop {
        match serve_pass(engine, rx, &mut st, fault.as_mut()) {
            Ok(true) => return Ok(()),
            Ok(false) => {}
            Err(e) => {
                // Satellite of DESIGN.md §10: in-flight callers get a
                // typed Failed, not a dropped sender.
                let reason = format!("engine error: {e}");
                for p in st.queue.drain(..) {
                    let _ = p.reply.send(GenOutcome::Failed {
                        id: p.req.id,
                        reason: reason.clone(),
                    });
                }
                for p in st.slots.iter_mut().filter_map(Option::take) {
                    let _ = p.reply.send(GenOutcome::Failed {
                        id: p.req.id,
                        reason: reason.clone(),
                    });
                }
                return Err(e);
            }
        }
    }
}

/// One pass of the serving loop; returns Ok(true) when the loop should
/// exit cleanly.
fn serve_pass(engine: &mut dyn Engine, rx: &mpsc::Receiver<Msg>,
              st: &mut LoopState, fault: Option<&mut FaultPlan>)
              -> Result<bool> {
    let b = engine.batch();
    let live = st.slots.iter().filter(|s| s.is_some()).count();
    let idle = live == 0 && st.queue.is_empty();
    if idle && !st.open {
        return Ok(true);
    }
    if idle {
        // Nothing to do: park on the channel instead of spinning.
        match rx.recv() {
            Ok(msg) => handle(msg, engine, st),
            Err(_) => return Ok(true), // every Server handle dropped
        }
    }
    while let Ok(msg) = rx.try_recv() {
        handle(msg, engine, st);
    }

    // Deadline sweep (wall clock, origin = intake instant).  Queued
    // requests just leave the queue; live ones are abandoned
    // mid-decode and release their KV blocks immediately.
    let mut expired_q = Vec::new();
    st.queue.retain(|p| {
        if p.expired() {
            expired_q.push((p.req.id, p.reply.clone()));
            false
        } else {
            true
        }
    });
    for (id, reply) in expired_q {
        engine.metrics_mut().deadline_exceeded += 1;
        let _ = reply.send(GenOutcome::DeadlineExceeded { id });
    }
    for slot in 0..b {
        let hit = st.slots[slot]
            .as_ref()
            .is_some_and(|p| p.expired() && !engine.seqs()[slot].done);
        if !hit {
            continue;
        }
        let Some(p) = st.slots[slot].take() else { continue };
        drop_slot(engine, slot);
        engine.metrics_mut().deadline_exceeded += 1;
        let _ = p
            .reply
            .send(GenOutcome::DeadlineExceeded { id: p.req.id });
    }

    // Fault draw: one FaultSet per iteration that steps an
    // already-live batch (mirrors the batcher's rule so a plan's
    // schedule predicts every counter).
    let live_before = st.slots.iter().filter(|s| s.is_some()).count();
    let fs = match (fault, live_before > 0) {
        (Some(plan), true) => {
            let fs = plan.begin_iteration();
            engine.metrics_mut().faults_injected += fs.injected;
            fs
        }
        _ => FaultSet::default(),
    };

    // FCFS admission, gated on free slots AND free KV blocks.  A
    // transient pool-exhaustion fault pauses admission this iteration.
    if !fs.pool {
        for slot in 0..b {
            if st.slots[slot].is_some() {
                continue;
            }
            let Some(head) = st.queue.front() else { break };
            if !engine.can_admit(&head.req.prompt, head.req.max_new) {
                if st.slots.iter().all(|s| s.is_none()) {
                    // Even an empty engine can't fit it: reject THIS
                    // request with a typed outcome and keep serving
                    // everyone else.
                    let Some(p) = st.queue.pop_front() else { break };
                    let _ = p.reply.send(GenOutcome::Rejected {
                        id: p.req.id,
                        reason: "needs more KV blocks than the whole \
                                 pool holds — raise --kv-blocks"
                            .into(),
                    });
                    continue; // next head, same pass
                }
                engine.metrics_mut().admission_stalls += 1;
                break; // backpressure: wait for a release
            }
            let Some(p) = st.queue.pop_front() else { break };
            // A request the engine cannot admit — malformed prompt,
            // reservation failure, even a prefill panic — fails THAT
            // request with a typed outcome; the daemon and every other
            // caller keep serving (audit rule R1, DESIGN.md §10).
            match catch_unwind(AssertUnwindSafe(|| {
                engine.admit(slot, &p.req.prompt, p.req.max_new)
            })) {
                Ok(Ok(())) => st.slots[slot] = Some(p),
                Ok(Err(e)) => {
                    drop_slot(engine, slot);
                    engine.metrics_mut().rows_failed += 1;
                    let _ = p.reply.send(GenOutcome::Failed {
                        id: p.req.id,
                        reason: format!("admission failed: {e}"),
                    });
                }
                Err(panic) => {
                    drop_slot(engine, slot);
                    engine.metrics_mut().rows_failed += 1;
                    let _ = p.reply.send(GenOutcome::Failed {
                        id: p.req.id,
                        reason: format!("admission panicked: {}",
                                        panic_msg(&panic)),
                    });
                }
            }
        }
    }

    if engine.any_active() {
        engine.inject_faults(fs);
        // Worker-pool incident: the prologue panics before any state
        // mutation and the pool re-arms itself, so one retry is safe;
        // a second panic is a real bug and becomes the fatal error.
        match catch_unwind(AssertUnwindSafe(|| engine.step())) {
            Ok(r) => r?,
            Err(p) => {
                engine.metrics_mut().pool_rebuilds += 1;
                catch_unwind(AssertUnwindSafe(|| engine.step()))
                    .map_err(|p2| {
                        anyhow::anyhow!(
                            "engine step panicked twice: {} then {}",
                            panic_msg(&p), panic_msg(&p2))
                    })??;
            }
        }
        engine.metrics_mut().iterations += 1;
    }

    // Harvest: reply and release finished slots.
    for slot in 0..b {
        let done = st.slots[slot]
            .as_ref()
            .map(|_| engine.seqs()[slot].done)
            .unwrap_or(false);
        if !done {
            continue;
        }
        let Some(p) = st.slots[slot].take() else { continue };
        let failed = engine.seqs()[slot].failed;
        let tokens = engine.seqs()[slot].gen_tokens().to_vec();
        engine.release(slot);
        let _ = p.reply.send(if failed {
            GenOutcome::Failed {
                id: p.req.id,
                reason: "target pass failed after retries".into(),
            }
        } else {
            GenOutcome::Completed(GenResponse {
                id: p.req.id,
                tokens,
                latency_s: p.t0.elapsed().as_secs_f64(),
            })
        });
    }
    Ok(false)
}

/// Abandon a live slot mid-decode: park its sequence and return its KV
/// blocks to the pool.
fn drop_slot(engine: &mut dyn Engine, slot: usize) {
    let seq = &mut engine.seqs_mut()[slot];
    seq.done = true;
    seq.active = false;
    engine.release(slot);
}

/// Apply one control message (may flip `st.open` on `Shutdown`).
fn handle(msg: Msg, engine: &mut dyn Engine, st: &mut LoopState) {
    match msg {
        Msg::Generate(req, reply) => {
            st.queue
                .push_back(Pending { req, reply, t0: stopwatch() });
        }
        Msg::Cancel(id) => {
            // Queued: drop from the queue.  Live: abandon the slot and
            // release its blocks.  Already finished: the original
            // outcome stands; the cancel is a no-op.
            let qpos = st.queue.iter().position(|p| p.req.id == id);
            let queued = qpos.and_then(|i| st.queue.remove(i));
            if let Some(p) = queued {
                engine.metrics_mut().cancelled += 1;
                let _ =
                    p.reply.send(GenOutcome::Cancelled { id: p.req.id });
            } else if let Some(slot) = st.slots.iter().position(|s| {
                s.as_ref().is_some_and(|p| p.req.id == id)
            }) {
                if !engine.seqs()[slot].done {
                    if let Some(p) = st.slots[slot].take() {
                        drop_slot(engine, slot);
                        engine.metrics_mut().cancelled += 1;
                        let _ = p
                            .reply
                            .send(GenOutcome::Cancelled { id: p.req.id });
                    }
                }
            }
        }
        Msg::Metrics(reply) => {
            let _ = reply.send(engine.metrics().clone());
        }
        Msg::Shutdown => st.open = false,
    }
}
