//! Serving front-end: a dedicated engine thread behind mpsc channels.
//!
//! (The offline build vendors no async runtime, and PJRT handles are
//! not Send anyway — the natural architecture is the same one vLLM
//! uses: an engine loop on its own OS thread, callers talk to it over
//! channels.  Documented as a substitution in DESIGN.md §3.)

use std::sync::mpsc;
use std::thread;

use anyhow::Result;

use crate::coordinator::engines::{build_engine, EngineConfig};
use crate::coordinator::metrics::Metrics;
use crate::runtime::RuntimeSpec;

#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency_s: f64,
}

enum Msg {
    Generate(GenRequest, mpsc::Sender<GenResponse>),
    Metrics(mpsc::Sender<Metrics>),
    Shutdown,
}

/// Handle to the engine thread.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    join: Option<thread::JoinHandle<Result<()>>>,
}

impl Server {
    /// Boot an engine on its own thread.  The runtime (PJRT artifacts
    /// or the reference backend) and engine are constructed inside the
    /// thread (PJRT handles never cross threads); `RuntimeSpec` is the
    /// `Send` description of what to open.
    pub fn start(spec: RuntimeSpec, cfg: EngineConfig) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = thread::Builder::new()
            .name("pard-engine".into())
            .spawn(move || -> Result<()> {
                let rt = spec.open()?;
                let mut engine = build_engine(&rt, &cfg)?;
                engine.warmup()?;
                // Simple loop: slot 0 serves requests FCFS; the batched
                // path is exercised through coordinator::batcher (the
                // benches drive it directly for deterministic timing).
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Generate(req, reply) => {
                            let t0 = std::time::Instant::now();
                            let outs = crate::coordinator::engines::generate(
                                engine.as_mut(),
                                std::slice::from_ref(&req.prompt),
                                req.max_new,
                            )?;
                            let _ = reply.send(GenResponse {
                                id: req.id,
                                tokens: outs.into_iter().next()
                                    .unwrap_or_default(),
                                latency_s: t0.elapsed().as_secs_f64(),
                            });
                        }
                        Msg::Metrics(reply) => {
                            let _ = reply.send(engine.metrics().clone());
                        }
                        Msg::Shutdown => break,
                    }
                }
                Ok(())
            })?;
        Ok(Server { tx, join: Some(join) })
    }

    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Generate(req, tx))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        Ok(rx.recv()?)
    }

    pub fn metrics(&self) -> Result<Metrics> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Metrics(tx))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow::anyhow!("engine panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
