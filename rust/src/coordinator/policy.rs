//! Speculation policy: a windowed accept-rate K controller with a
//! batch-level dual-mode draft/AR+ switch (DESIGN.md §9).
//!
//! The controller is deliberately a *pure function of observable
//! state*: per-sequence acceptance history (what `verify_and_commit`
//! reported for that row) and batch occupancy (how many rows are
//! live).  No wall clock, no randomness — so a policy run is
//! deterministic, replayable on the virtual clock, and exactly
//! mirrorable by `python/refsim/hostsim.py` (which ci.sh gates on).
//!
//! Two decisions are made per step, in `SpecPolicy::plan`:
//!
//! 1. **Per-sequence K.**  Each live row keeps a sliding window of the
//!    last `window` verify outcomes `(offered, accepted)`.  The next K
//!    for that row is a rate-proportional interpolation between
//!    `k_min` and `k_max`, computed in integer arithmetic
//!    (round-half-up) so the Python mirror can reproduce it exactly —
//!    see [`k_for_rate`].  An empty window (fresh sequence) falls back
//!    to the configured `--k`, clamped into `[k_min, k_max]`.
//! 2. **Dual mode.**  When `dual_mode_occupancy` is set and the
//!    fraction of live rows reaches it, every row gets K=0: the
//!    engines skip the draft pass entirely and verify with zero
//!    candidates, which commits exactly one token per row — AR+
//!    behavior with AR+ cost and (stochastically) AR+'s draw
//!    sequence.  When occupancy drops back below the threshold the
//!    batch switches back to drafting.  This is PARD-2's dual-mode
//!    argument: speculation stops paying once the batch is
//!    compute-saturated, because the verify pass already multiplies
//!    its column count by K+1 for every live row.
//!
//! **Why pinned ≡ fixed-K:** with `k_min == k_max == K` and dual mode
//! off, `plan` returns K for every live row on every step (the window
//! interpolation collapses to the single point K), `k_cap()` equals K,
//! and zero-offered observations never occur — so reservation sizes,
//! call-buffer layouts, T buckets, and per-sequence draw sequences are
//! identical to a fixed-K run, token for token.  `--policy fixed`
//! ignores the bounds entirely and always returns the configured K.

use std::collections::VecDeque;

use anyhow::{ensure, Result};

use super::metrics::Metrics;

/// Largest K any engine accepts (`build_engine` enforces the same
/// bound on `--k`); adaptive bounds must fit under it because cache
/// reservations and headroom guards are sized by `k_cap()`.
pub const K_LIMIT: usize = 16;

/// Speculation-policy knobs (CLI: `--policy`, `--k-min`, `--k-max`,
/// `--policy-window`, `--dual-mode-occupancy`).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyCfg {
    /// `--policy adaptive`; `false` is the fixed-K policy, which
    /// always drafts the configured `--k` and never enters dual mode.
    pub adaptive: bool,
    /// Lower K bound for the adaptive controller (>= 1).
    pub k_min: usize,
    /// Upper K bound for the adaptive controller (<= [`K_LIMIT`]).
    pub k_max: usize,
    /// Sliding-window length, in verify steps, of the per-sequence
    /// acceptance history the controller reads.
    pub window: usize,
    /// Batch-occupancy fraction in `(0, 1]` at which the whole batch
    /// degrades to AR+ (K=0); `None` disables dual mode.
    pub dual_mode_occupancy: Option<f64>,
}

impl Default for PolicyCfg {
    fn default() -> Self {
        PolicyCfg {
            adaptive: false,
            k_min: 1,
            k_max: K_LIMIT,
            window: 8,
            dual_mode_occupancy: None,
        }
    }
}

/// Next K for a window holding `acc` accepted out of `off` offered
/// candidates: `k_min` plus the rate-proportional share of the span,
/// rounded half-up, all in integer arithmetic (bit-for-bit mirrorable
/// in Python).  `off == 0` (no history yet) falls back to `k_init`
/// clamped into the bounds.  `acc == off` maps to `k_max`, `acc == 0`
/// to `k_min`, and the result is monotone in `acc`.
pub fn k_for_rate(acc: u64, off: u64, k_min: usize, k_max: usize,
                  k_init: usize) -> usize {
    debug_assert!(k_min <= k_max && acc <= off);
    if off == 0 {
        return k_init.clamp(k_min, k_max);
    }
    let span = (k_max - k_min) as u64;
    k_min + ((span * 2 * acc + off) / (2 * off)) as usize
}

/// Per-engine speculation controller.  Construct via
/// [`crate::coordinator::router::build_policy`], which validates the
/// knobs and pins AR engines to the inert fixed policy.
#[derive(Debug, Clone)]
pub struct SpecPolicy {
    cfg: PolicyCfg,
    /// The configured `--k`: the fixed policy's constant answer and
    /// the adaptive controller's cold-start K.
    k_init: usize,
    /// Per-slot acceptance windows, `(offered, accepted)` per verify.
    windows: Vec<VecDeque<(u32, u32)>>,
    /// Currently degraded to AR+ by the occupancy rule?
    dual_mode: bool,
}

impl SpecPolicy {
    /// Validate the knobs and build per-slot windows; `k_init` seeds
    /// every row's K.
    pub fn new(cfg: &PolicyCfg, k_init: usize, batch: usize)
               -> Result<Self> {
        ensure!(cfg.k_min >= 1, "policy k_min must be >= 1");
        ensure!(cfg.k_min <= cfg.k_max,
                "policy k_min {} > k_max {}", cfg.k_min, cfg.k_max);
        ensure!(cfg.k_max <= K_LIMIT,
                "policy k_max {} > {K_LIMIT}", cfg.k_max);
        ensure!(cfg.window >= 1, "policy window must be >= 1");
        if let Some(tau) = cfg.dual_mode_occupancy {
            ensure!(tau > 0.0 && tau <= 1.0,
                    "dual-mode occupancy {tau} outside (0, 1]");
        }
        Ok(SpecPolicy {
            cfg: cfg.clone(),
            k_init,
            windows: vec![VecDeque::new(); batch],
            dual_mode: false,
        })
    }

    /// The validated policy knobs.
    pub fn cfg(&self) -> &PolicyCfg {
        &self.cfg
    }

    /// True while the batch is degraded to AR+ commits (DESIGN.md §9).
    pub fn in_dual_mode(&self) -> bool {
        self.dual_mode
    }

    /// Worst-case K this policy can ever request: cache reservations,
    /// headroom guards, and warmup shapes are sized by this, so
    /// admission stays preemption-free under any K trajectory.
    pub fn k_cap(&self) -> usize {
        if self.cfg.adaptive {
            self.cfg.k_max
        } else {
            self.k_init
        }
    }

    /// A slot was (re)admitted: its acceptance history belongs to the
    /// previous occupant, so drop it.
    pub fn on_admit(&mut self, slot: usize) {
        self.windows[slot].clear();
    }

    /// Record one verify outcome for `slot`.  A zero-offered verify is
    /// an AR+-mode step, not an acceptance observation — recording it
    /// would drag the windowed rate toward `k_min` while the row isn't
    /// drafting at all — so it is skipped, matching
    /// `Metrics::record_acceptance`.
    pub fn on_acceptance(&mut self, slot: usize, offered: usize,
                         accepted: usize) {
        if offered == 0 {
            return;
        }
        let w = &mut self.windows[slot];
        w.push_back((offered as u32, accepted as u32));
        while w.len() > self.cfg.window {
            w.pop_front();
        }
    }

    /// The K `plan` would hand `slot` outside dual mode.
    pub fn k_for_slot(&self, slot: usize) -> usize {
        if !self.cfg.adaptive {
            return self.k_init;
        }
        let (mut acc, mut off) = (0u64, 0u64);
        for &(o, a) in &self.windows[slot] {
            off += u64::from(o);
            acc += u64::from(a);
        }
        k_for_rate(acc, off, self.cfg.k_min, self.cfg.k_max, self.k_init)
    }

    /// Decide this step's per-slot K vector from the live mask.
    /// Non-live slots get 0; dual mode forces 0 everywhere (AR+
    /// degrade).  Records the K histogram, mode switches, and
    /// dual-mode iteration count into `metrics`.
    pub fn plan(&mut self, live: &[bool], metrics: &mut Metrics)
                -> Vec<usize> {
        debug_assert_eq!(live.len(), self.windows.len());
        let n_live = live.iter().filter(|&&l| l).count();
        let dual = self.cfg.adaptive
            && self
                .cfg
                .dual_mode_occupancy
                .map(|tau| n_live as f64 >= tau * live.len() as f64)
                .unwrap_or(false);
        if dual != self.dual_mode {
            self.dual_mode = dual;
            metrics.mode_switches += 1;
        }
        if dual {
            metrics.dual_mode_iters += 1;
        }
        let ks: Vec<usize> = (0..live.len())
            .map(|slot| {
                if !live[slot] || dual {
                    0
                } else {
                    self.k_for_slot(slot)
                }
            })
            .collect();
        for (slot, &k) in ks.iter().enumerate() {
            if live[slot] {
                metrics.record_k_choice(k);
            }
        }
        ks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adaptive(k_min: usize, k_max: usize, window: usize,
                tau: Option<f64>) -> PolicyCfg {
        PolicyCfg { adaptive: true, k_min, k_max, window,
                    dual_mode_occupancy: tau }
    }

    #[test]
    fn k_for_rate_endpoints_and_bounds() {
        for k_min in 1..=4 {
            for k_max in k_min..=16 {
                for off in 1..=24u64 {
                    for acc in 0..=off {
                        let k = k_for_rate(acc, off, k_min, k_max, 8);
                        assert!((k_min..=k_max).contains(&k));
                    }
                    assert_eq!(k_for_rate(0, off, k_min, k_max, 8),
                               k_min);
                    assert_eq!(k_for_rate(off, off, k_min, k_max, 8),
                               k_max);
                }
                // empty history: clamped k_init
                assert_eq!(k_for_rate(0, 0, k_min, k_max, 8),
                           8usize.clamp(k_min, k_max));
            }
        }
    }

    #[test]
    fn k_for_rate_is_monotone_in_acceptance() {
        for off in 1..=20u64 {
            let mut prev = 0;
            for acc in 0..=off {
                let k = k_for_rate(acc, off, 1, 16, 4);
                assert!(k >= prev, "not monotone at acc={acc}/{off}");
                prev = k;
            }
        }
    }

    #[test]
    fn pinned_policy_always_returns_k() {
        let mut p =
            SpecPolicy::new(&adaptive(5, 5, 4, None), 5, 3).unwrap();
        let mut m = Metrics::default();
        for _ in 0..4 {
            assert_eq!(p.plan(&[true, true, false], &mut m),
                       vec![5, 5, 0]);
            p.on_acceptance(0, 5, 0); // terrible rate; still pinned
            p.on_acceptance(1, 5, 5);
        }
        assert_eq!(p.k_cap(), 5);
        assert_eq!(m.mode_switches, 0);
        assert_eq!(m.k_hist.get(5), Some(&8));
    }

    #[test]
    fn fixed_policy_ignores_bounds_and_history() {
        let cfg = PolicyCfg::default();
        let mut p = SpecPolicy::new(&cfg, 7, 2).unwrap();
        let mut m = Metrics::default();
        p.on_acceptance(0, 7, 0);
        assert_eq!(p.plan(&[true, true], &mut m), vec![7, 7]);
        assert_eq!(p.k_cap(), 7);
    }

    #[test]
    fn adaptive_tracks_the_window() {
        let mut p =
            SpecPolicy::new(&adaptive(1, 16, 2, None), 4, 1).unwrap();
        let mut m = Metrics::default();
        // cold start: k_init
        assert_eq!(p.plan(&[true], &mut m), vec![4]);
        // full acceptance drives K to k_max...
        p.on_acceptance(0, 4, 4);
        assert_eq!(p.plan(&[true], &mut m), vec![16]);
        // ...zero acceptance drags it down; window=2 keeps one good
        // record so the rate is 4/20 -> 1 + round(15*0.2) = 4
        p.on_acceptance(0, 16, 0);
        assert_eq!(p.plan(&[true], &mut m), vec![4]);
        // the good record ages out: rate 0 -> k_min
        p.on_acceptance(0, 4, 0);
        assert_eq!(p.plan(&[true], &mut m), vec![1]);
        // re-admission clears history back to cold start
        p.on_admit(0);
        assert_eq!(p.plan(&[true], &mut m), vec![4]);
    }

    #[test]
    fn zero_offered_is_not_an_observation() {
        let mut p =
            SpecPolicy::new(&adaptive(1, 16, 4, None), 4, 1).unwrap();
        p.on_acceptance(0, 4, 4);
        p.on_acceptance(0, 0, 0); // AR+ step: must not dilute the rate
        assert_eq!(p.k_for_slot(0), 16);
    }

    #[test]
    fn dual_mode_follows_occupancy() {
        let mut p =
            SpecPolicy::new(&adaptive(1, 16, 4, Some(0.75)), 4, 4)
                .unwrap();
        let mut m = Metrics::default();
        // 2/4 live: below threshold, drafting
        assert_eq!(p.plan(&[true, true, false, false], &mut m),
                   vec![4, 4, 0, 0]);
        assert!(!p.in_dual_mode());
        // 3/4 live: at threshold, AR+ degrade
        assert_eq!(p.plan(&[true, true, true, false], &mut m),
                   vec![0, 0, 0, 0]);
        assert!(p.in_dual_mode());
        assert_eq!(m.mode_switches, 1);
        assert_eq!(m.dual_mode_iters, 1);
        // drops back: switch back to drafting
        assert_eq!(p.plan(&[true, false, false, false], &mut m),
                   vec![4, 0, 0, 0]);
        assert!(!p.in_dual_mode());
        assert_eq!(m.mode_switches, 2);
        // k histogram saw both the drafted and the degraded choices
        assert!(m.k_hist[0] > 0 && m.k_hist[4] > 0);
    }

    #[test]
    fn fixed_policy_never_enters_dual_mode() {
        // dual_mode_occupancy is an adaptive-only knob; the fixed
        // policy ignores it even if set programmatically.
        let cfg = PolicyCfg { dual_mode_occupancy: Some(0.5),
                              ..PolicyCfg::default() };
        let mut p = SpecPolicy::new(&cfg, 3, 2).unwrap();
        let mut m = Metrics::default();
        assert_eq!(p.plan(&[true, true], &mut m), vec![3, 3]);
        assert!(!p.in_dual_mode());
        assert_eq!(m.mode_switches, 0);
    }

    #[test]
    fn bad_knobs_are_rejected() {
        for cfg in [
            adaptive(0, 4, 4, None),
            adaptive(5, 4, 4, None),
            adaptive(1, 17, 4, None),
            adaptive(1, 4, 0, None),
            adaptive(1, 4, 4, Some(0.0)),
            adaptive(1, 4, 4, Some(1.5)),
        ] {
            assert!(SpecPolicy::new(&cfg, 4, 2).is_err(), "{cfg:?}");
        }
    }
}
