//! L3 coordinator (DESIGN.md §1, §3) — the paper's serving-side system
//! contribution: decode engines (AR/AR+/VSD/PARD/EAGLE), speculative
//! acceptance, the KV-slot contract (DESIGN.md §7), continuous
//! batching, routing, and metrics.

pub mod batcher;
pub mod engines;
pub mod evaluate;
pub mod metrics;
pub mod policy;
pub mod router;
pub mod sampling;
pub mod sequence;

pub use engines::{build_engine, generate, Engine, EngineConfig,
                  EngineKind};
pub use evaluate::{run_eval, speedup, EvalResult};
pub use metrics::Metrics;
pub use policy::{PolicyCfg, SpecPolicy};
pub use sequence::Sequence;
