//! Closed-loop evaluation harness: run an engine over a prompt set and
//! report the paper's metrics (experiment index: DESIGN.md §5).
//! Shared by examples/, benches/, and the CLI `eval`/`tables`/`bench`
//! subcommands.

use anyhow::Result;

use super::engines::{build_engine, generate, EngineConfig};
use super::metrics::Metrics;
use crate::substrate::prompts::Prompt;
use crate::Runtime;

/// One engine × task closed-batch evaluation record.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub engine: String,
    pub target: String,
    pub draft: Option<String>,
    pub task: String,
    pub k: usize,
    pub batch: usize,
    pub metrics: Metrics,
    /// Per-prompt generated token streams.
    pub outputs: Vec<Vec<i32>>,
}

impl EvalResult {
    /// Decode throughput (tokens/s) over the measured wall time.
    pub fn tps(&self) -> f64 {
        self.metrics.tps()
    }
}

/// Run `cfg` over `prompts` (closed batch; greedy unless
/// `cfg.sampling` routes the engines through seeded stochastic
/// decoding).  Warmup compiles are excluded from the measured wall
/// clock.
pub fn run_eval(rt: &Runtime, cfg: &EngineConfig, prompts: &[Prompt],
                max_new: usize, task: &str) -> Result<EvalResult> {
    let mut engine = build_engine(rt, cfg)?;
    engine.warmup()?;
    let prompt_ids: Vec<Vec<i32>> =
        prompts.iter().map(|p| p.prompt.clone()).collect();
    let outputs = generate(engine.as_mut(), &prompt_ids, max_new)?;
    let mut metrics = engine.metrics().clone();
    // Greedy-agreement with the grammar reference: speculative decoding
    // must not change greedy outputs, and the grammar reference gives an
    // absolute quality guard.
    for (out, p) in outputs.iter().zip(prompts) {
        let n = out.len().min(p.reference.len());
        metrics.ref_total += n as u64;
        metrics.ref_match += out[..n]
            .iter()
            .zip(&p.reference[..n])
            .filter(|(a, b)| a == b)
            .count() as u64;
    }
    Ok(EvalResult {
        engine: cfg.kind.label().to_string(),
        target: cfg.target.clone(),
        draft: cfg.draft.clone(),
        task: task.to_string(),
        k: cfg.k,
        batch: cfg.batch,
        metrics,
        outputs,
    })
}

/// Speedup of `x` over baseline `base` by end-to-end TPS.
pub fn speedup(x: &EvalResult, base: &EvalResult) -> f64 {
    if base.tps() == 0.0 {
        0.0
    } else {
        x.tps() / base.tps()
    }
}
