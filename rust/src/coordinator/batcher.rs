//! Continuous batcher: online serving over an arrival trace
//! (DESIGN.md §3; slot reuse contract in §7).
//!
//! The vLLM-style loop behind Tables 3/4: a fixed number of batch slots;
//! arrived requests queue FCFS; finished slots are refilled between
//! decode iterations (iteration-level scheduling).  Latency accounting
//! is per request (arrival → completion).

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::engines::Engine;
use crate::substrate::workload::Trace;

#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub completed: usize,
    pub wall_s: f64,
    /// Tokens generated within THIS serving window (not engine
    /// lifetime — the engine may have served earlier traces).
    pub generated: u64,
    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    /// Window-generated tokens/s over the serving window.
    pub throughput_tps: f64,
    /// Mean live slots per decode iteration (batch efficiency).
    pub mean_occupancy: f64,
}

struct InFlight {
    request_idx: usize,
}

/// Drive `engine` through `trace`.  Requests become admittable when
/// their arrival offset has elapsed; slots refill between iterations.
pub fn serve_trace(engine: &mut dyn Engine, trace: &Trace)
                   -> Result<ServeStats> {
    let b = engine.batch();
    let t0 = Instant::now();
    // Window accounting: tokens from BEFORE this trace must not count
    // toward this trace's throughput.
    let gen0 = engine.metrics().generated;
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut next_arrival = 0usize;
    let mut slots: Vec<Option<InFlight>> = (0..b).map(|_| None).collect();
    let mut latencies: Vec<f64> = Vec::with_capacity(trace.requests.len());
    let mut occupancy_sum = 0usize;
    let mut iters = 0usize;

    loop {
        let now = t0.elapsed().as_secs_f64();
        while next_arrival < trace.requests.len()
            && trace.requests[next_arrival].arrival_s <= now
        {
            queue.push_back(next_arrival);
            next_arrival += 1;
        }

        // Harvest finished slots, refill from the queue.
        for slot in 0..b {
            let finished = slots[slot]
                .as_ref()
                .map(|_| engine.seqs()[slot].done)
                .unwrap_or(false);
            if finished {
                let f = slots[slot].take().unwrap();
                // request latency = completion - arrival (queueing incl.)
                let lat = t0.elapsed().as_secs_f64()
                    - trace.requests[f.request_idx].arrival_s;
                latencies.push(lat.max(0.0));
            }
            if slots[slot].is_none() {
                if let Some(ri) = queue.pop_front() {
                    let req = &trace.requests[ri];
                    engine.admit(slot, &req.prompt, req.max_new)?;
                    slots[slot] = Some(InFlight { request_idx: ri });
                }
            }
        }

        let live = slots.iter().filter(|s| s.is_some()).count();
        if live == 0 {
            if next_arrival >= trace.requests.len() && queue.is_empty() {
                break;
            }
            // idle until the next arrival
            std::thread::sleep(std::time::Duration::from_micros(200));
            continue;
        }

        occupancy_sum += live;
        iters += 1;
        engine.step()?;
        engine.metrics_mut().iterations += 1;
    }

    // Final harvest (defensive: the loop only exits once every slot has
    // been harvested, but keep any stragglers consistent with the
    // in-loop accounting — arrival-based, queueing delay included).
    for slot in 0..b {
        if let Some(f) = slots[slot].take() {
            let lat = t0.elapsed().as_secs_f64()
                - trace.requests[f.request_idx].arrival_s;
            latencies.push(lat.max(0.0));
        }
    }

    let wall = t0.elapsed().as_secs_f64();
    let generated = engine.metrics().generated - gen0;
    engine.metrics_mut().wall_s += wall;
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = latencies.len();
    let pct = |p: f64| -> f64 {
        if n == 0 {
            0.0
        } else {
            latencies[(p * (n - 1) as f64).round() as usize]
        }
    };
    Ok(ServeStats {
        completed: n,
        wall_s: wall,
        generated,
        latency_mean_s: latencies.iter().sum::<f64>() / n.max(1) as f64,
        latency_p50_s: pct(0.5),
        latency_p95_s: pct(0.95),
        throughput_tps: if wall > 0.0 {
            generated as f64 / wall
        } else {
            0.0
        },
        mean_occupancy: occupancy_sum as f64 / iters.max(1) as f64,
    })
}
