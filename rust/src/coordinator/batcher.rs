//! Continuous batcher: online serving over an arrival trace
//! (DESIGN.md §3; paged-cache admission contract in §7).
//!
//! The vLLM-style loop behind Tables 3/4: arrived requests queue FCFS;
//! finished slots release their KV blocks and are refilled between
//! decode iterations (iteration-level scheduling).  Admission is
//! **memory-bounded**: a request is admitted only when a batch slot is
//! free AND [`super::engines::Engine::can_admit`] reports enough
//! unreserved KV blocks for its worst case — when the pool runs dry
//! the queue simply waits (preemption-free backpressure; admitted
//! sequences always finish because their blocks are reserved up
//! front).  Latency accounting is per request (arrival → completion).
//!
//! Time comes from an internal `ServeClock`: wall mode for real
//! serving, or a virtual clock ([`serve_trace_virtual`]) that advances
//! a fixed tick
//! per decode iteration and jumps idle gaps instantly — batcher tests
//! and serving benches run deterministically, with no 200µs idle
//! sleeps and no dependence on host scheduling.
//!
//! A third mode, [`serve_trace_virtual_costed`], prices each iteration
//! from the engine's work-unit ledger (`Metrics::record_work`):
//! `dt = pass_s·Δpass_units + col_s·Δcol_units`, i.e. a bandwidth
//! term per forward pass plus a compute term per token column.  Unlike
//! the fixed tick — under which a K=16 iteration costs the same as a
//! K=1 iteration — this clock makes over-speculation visible, which is
//! what the adaptive-policy win gates measure (DESIGN.md §9).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use anyhow::Result;

use super::engines::Engine;
use crate::substrate::bench::stopwatch;
use crate::substrate::fault::{FaultPlan, FaultSet, MAX_TARGET_RETRIES};
use crate::substrate::workload::Trace;

/// How one trace request ended (DESIGN.md §10).  `ServeStats.outcomes`
/// holds one per request, in trace order, so chaos tests can compare
/// token streams request-by-request against a fault-free run.
#[derive(Debug, Clone)]
pub enum RequestOutcome {
    Completed { tokens: Vec<i32>, latency_s: f64 },
    /// A persistent target-pass incident failed this row; its KV
    /// blocks were released at harvest.
    Failed { reason: String },
    /// The request's `deadline_s` passed (queued or in flight) — the
    /// slot's blocks were released immediately.
    DeadlineExceeded,
}

/// Aggregate outcome counters for one serving-trace replay (DESIGN.md §10).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub completed: usize,
    /// Requests failed by persistent target incidents.
    pub failed: usize,
    /// Requests expired by their deadline.
    pub expired: usize,
    /// Per-request outcome, in trace order.
    pub outcomes: Vec<RequestOutcome>,
    pub wall_s: f64,
    /// Tokens generated within THIS serving window (not engine
    /// lifetime — the engine may have served earlier traces).
    pub generated: u64,
    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    /// Window-generated tokens/s over the serving window.
    pub throughput_tps: f64,
    /// Mean live slots per decode iteration (batch efficiency).
    pub mean_occupancy: f64,
    /// Most slots simultaneously live in any iteration — under a
    /// paged pool this can exceed what the dense layout's worst-case
    /// `B × S_max` budget could ever admit (DESIGN.md §7).
    pub peak_occupancy: usize,
    /// Iterations in which a ready request waited because the KV pool
    /// had no unreserved blocks (admission backpressure).
    pub admission_stalls: u64,
}

/// Time source for [`serve_trace_impl`]: real wall clock, or a
/// deterministic virtual clock that charges `tick` seconds per decode
/// iteration and skips idle gaps instantly.
enum ServeClock {
    Wall(Instant),
    Virtual { now: f64, tick: f64 },
    /// Work-costed virtual time: each iteration is priced from the
    /// engine's work deltas (`Δpass_units`, `Δcol_units`).
    VirtualCosted { now: f64, pass_s: f64, col_s: f64 },
}

impl ServeClock {
    fn now(&self) -> f64 {
        match self {
            ServeClock::Wall(t0) => t0.elapsed().as_secs_f64(),
            ServeClock::Virtual { now, .. } => *now,
            ServeClock::VirtualCosted { now, .. } => *now,
        }
    }

    /// Charge one decode iteration.  `dwp`/`dwc` are the iteration's
    /// work-unit deltas (forward-pass units, token-column units) —
    /// only the costed clock reads them.
    fn on_iteration(&mut self, dwp: f64, dwc: f64) {
        match self {
            ServeClock::Wall(_) => {}
            ServeClock::Virtual { now, tick } => {
                *now += *tick;
            }
            ServeClock::VirtualCosted { now, pass_s, col_s } => {
                *now += *pass_s * dwp + *col_s * dwc;
            }
        }
    }

    /// Nothing is live: wait for the next arrival (wall: a short
    /// sleep; virtual: jump straight to `arrival_s`).
    fn idle_until(&mut self, arrival_s: f64) {
        match self {
            ServeClock::Wall(_) => {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            ServeClock::Virtual { now, .. }
            | ServeClock::VirtualCosted { now, .. } => {
                *now = now.max(arrival_s);
            }
        }
    }
}

struct InFlight {
    request_idx: usize,
}

/// Drive `engine` through `trace` on the real wall clock.  Requests
/// become admittable when their arrival offset has elapsed; slots
/// refill between iterations, gated on free KV blocks.
pub fn serve_trace(engine: &mut dyn Engine, trace: &Trace)
                   -> Result<ServeStats> {
    serve_trace_impl(engine, trace, ServeClock::Wall(stopwatch()),
                     None)
}

/// [`serve_trace`] with an armed [`FaultPlan`]: the batcher draws one
/// [`FaultSet`] per decode iteration that steps an already-live batch,
/// injects it into the engine, and recovers from the scripted worker
/// panic (DESIGN.md §10).
pub fn serve_trace_with_faults(engine: &mut dyn Engine, trace: &Trace,
                               fault: &mut FaultPlan)
                               -> Result<ServeStats> {
    serve_trace_impl(engine, trace, ServeClock::Wall(stopwatch()),
                     Some(fault))
}

/// [`serve_trace`] on a deterministic virtual clock: every decode
/// iteration costs exactly `tick_s` seconds and idle gaps are skipped
/// instantly, so completions, latencies, and stall counts depend only
/// on the trace and the engine — not on host speed or scheduling.
pub fn serve_trace_virtual(engine: &mut dyn Engine, trace: &Trace,
                           tick_s: f64) -> Result<ServeStats> {
    anyhow::ensure!(tick_s >= 0.0 && tick_s.is_finite(),
                    "virtual tick must be a finite non-negative time");
    serve_trace_impl(engine, trace,
                     ServeClock::Virtual { now: 0.0, tick: tick_s }, None)
}

/// [`serve_trace_virtual`] with an armed [`FaultPlan`] (see
/// [`serve_trace_with_faults`]).
pub fn serve_trace_virtual_with_faults(engine: &mut dyn Engine,
                                       trace: &Trace, tick_s: f64,
                                       fault: &mut FaultPlan)
                                       -> Result<ServeStats> {
    anyhow::ensure!(tick_s >= 0.0 && tick_s.is_finite(),
                    "virtual tick must be a finite non-negative time");
    serve_trace_impl(engine, trace,
                     ServeClock::Virtual { now: 0.0, tick: tick_s },
                     Some(fault))
}

/// [`serve_trace`] on a deterministic WORK-COSTED virtual clock: each
/// decode iteration charges `pass_s` per forward-pass work unit plus
/// `col_s` per token-column work unit (deltas of the engine's
/// `Metrics` work ledger over the iteration), and idle gaps are
/// skipped instantly.  This is the clock the adaptive-policy win
/// gates run on: it prices speculation, so drafting 16 tokens that
/// all get rejected is strictly slower than drafting none.
pub fn serve_trace_virtual_costed(engine: &mut dyn Engine, trace: &Trace,
                                  pass_s: f64, col_s: f64)
                                  -> Result<ServeStats> {
    anyhow::ensure!(pass_s >= 0.0 && pass_s.is_finite()
                        && col_s >= 0.0 && col_s.is_finite(),
                    "work-cost rates must be finite non-negative times");
    serve_trace_impl(engine, trace,
                     ServeClock::VirtualCosted { now: 0.0, pass_s,
                                                 col_s },
                     None)
}

/// [`serve_trace_virtual_costed`] with an armed [`FaultPlan`] (see
/// [`serve_trace_with_faults`]) — the clock the chaos gates run on:
/// held/retried iterations still charge their wasted pass units, so
/// fault storms cost virtual time instead of deadlocking it.
pub fn serve_trace_virtual_costed_with_faults(
    engine: &mut dyn Engine, trace: &Trace, pass_s: f64, col_s: f64,
    fault: &mut FaultPlan) -> Result<ServeStats> {
    anyhow::ensure!(pass_s >= 0.0 && pass_s.is_finite()
                        && col_s >= 0.0 && col_s.is_finite(),
                    "work-cost rates must be finite non-negative times");
    serve_trace_impl(engine, trace,
                     ServeClock::VirtualCosted { now: 0.0, pass_s,
                                                 col_s },
                     Some(fault))
}

fn serve_trace_impl(engine: &mut dyn Engine, trace: &Trace,
                    mut clock: ServeClock,
                    mut fault: Option<&mut FaultPlan>)
                    -> Result<ServeStats> {
    let b = engine.batch();
    // Window accounting: tokens from BEFORE this trace must not count
    // toward this trace's throughput.
    let gen0 = engine.metrics().generated;
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut next_arrival = 0usize;
    let mut slots: Vec<Option<InFlight>> = (0..b).map(|_| None).collect();
    let mut latencies: Vec<f64> = Vec::with_capacity(trace.requests.len());
    let mut outcomes: Vec<Option<RequestOutcome>> =
        vec![None; trace.requests.len()];
    let mut failed = 0usize;
    let mut expired = 0usize;
    let mut occupancy_sum = 0usize;
    let mut peak_occupancy = 0usize;
    let mut stalls = 0u64;
    let mut iters = 0usize;

    loop {
        let now = clock.now();
        while next_arrival < trace.requests.len()
            && trace.requests[next_arrival].arrival_s <= now
        {
            queue.push_back(next_arrival);
            next_arrival += 1;
        }

        // Deadline sweep (DESIGN.md §10): expired requests are dropped
        // wherever they are.  Queued ones just leave the queue; live
        // ones are abandoned mid-decode and release their KV blocks
        // immediately, so an expired request can never pin pool space.
        let mut dropped_queued = Vec::new();
        queue.retain(|&ri| {
            let req = &trace.requests[ri];
            if req.deadline_s.is_some_and(|d| now > d) {
                dropped_queued.push(ri);
                false
            } else {
                true
            }
        });
        for ri in dropped_queued {
            outcomes[ri] = Some(RequestOutcome::DeadlineExceeded);
            expired += 1;
            engine.metrics_mut().deadline_exceeded += 1;
        }
        for slot in 0..b {
            let hit = slots[slot].as_ref().is_some_and(|f| {
                trace.requests[f.request_idx]
                    .deadline_s
                    .is_some_and(|d| now > d)
                    && !engine.seqs()[slot].done
            });
            if !hit {
                continue;
            }
            let Some(f) = slots[slot].take() else { continue };
            let seq = &mut engine.seqs_mut()[slot];
            seq.done = true;
            seq.active = false;
            engine.release(slot);
            outcomes[f.request_idx] =
                Some(RequestOutcome::DeadlineExceeded);
            expired += 1;
            engine.metrics_mut().deadline_exceeded += 1;
        }

        // Harvest finished slots (returning their KV blocks to the
        // pool).
        for slot in 0..b {
            let finished = slots[slot]
                .as_ref()
                .map(|_| engine.seqs()[slot].done)
                .unwrap_or(false);
            if !finished {
                continue;
            }
            let Some(f) = slots[slot].take() else { continue };
            let row_failed = engine.seqs()[slot].failed;
            let tokens = engine.seqs()[slot].gen_tokens().to_vec();
            engine.release(slot);
            if row_failed {
                failed += 1;
                outcomes[f.request_idx] =
                    Some(RequestOutcome::Failed {
                        reason: format!(
                            "target pass failed after \
                             {MAX_TARGET_RETRIES} retries"),
                    });
            } else {
                // latency = completion - arrival (queueing incl.)
                let lat = (clock.now()
                    - trace.requests[f.request_idx].arrival_s)
                    .max(0.0);
                latencies.push(lat);
                outcomes[f.request_idx] =
                    Some(RequestOutcome::Completed { tokens,
                                                     latency_s: lat });
            }
        }

        // Fault draw: exactly one FaultSet per iteration that will step
        // an already-live batch (rows survive harvest ⇒ a step is
        // guaranteed below), keeping the plan's schedule 1:1 with
        // injected steps so replaying the plan predicts every counter.
        let live_before = slots.iter().filter(|s| s.is_some()).count();
        let fs = match (&mut fault, live_before > 0) {
            (Some(plan), true) => {
                let fs = plan.begin_iteration();
                engine.metrics_mut().faults_injected += fs.injected;
                fs
            }
            _ => FaultSet::default(),
        };

        // Refill from the queue — FCFS, gated on both a free slot and
        // enough unreserved KV blocks.  A transient pool-exhaustion
        // fault pauses admission for this one iteration (modelling a
        // pool with momentarily no unreserved blocks); it is a fault,
        // not backpressure, so it does not count an admission stall.
        let mut stalled = false;
        if !fs.pool {
            for slot in 0..b {
                if slots[slot].is_none() && !stalled {
                    if let Some(&ri) = queue.front() {
                        let req = &trace.requests[ri];
                        if engine.can_admit(&req.prompt, req.max_new) {
                            queue.pop_front();
                            engine.admit(slot, &req.prompt, req.max_new)?;
                            slots[slot] =
                                Some(InFlight { request_idx: ri });
                        } else {
                            // Head-of-line waits for blocks; admitting
                            // a smaller later request instead would
                            // starve it (FCFS is the fairness
                            // contract).
                            stalled = true;
                        }
                    }
                }
            }
        }
        if stalled {
            stalls += 1;
            engine.metrics_mut().admission_stalls += 1;
        }

        let live = slots.iter().filter(|s| s.is_some()).count();
        if live == 0 {
            if stalled {
                // The stall may predate a release that happened later
                // in the SAME harvest pass (a lower slot consulted the
                // gate before a higher slot freed its blocks).  With
                // the engine now empty, re-consult the gate: only a
                // head that cannot fit an empty pool is hopeless.
                let Some(&ri) = queue.front() else {
                    anyhow::bail!(
                        "admission stalled with an empty queue — \
                         batcher bookkeeping bug");
                };
                let req = &trace.requests[ri];
                anyhow::ensure!(
                    engine.can_admit(&req.prompt, req.max_new),
                    "request {ri} (prompt {} + max_new {}) needs more \
                     KV blocks than the whole pool holds — raise \
                     --kv-blocks",
                    req.prompt.len(),
                    req.max_new
                );
                continue; // it fits now: admit on the next pass
            }
            if next_arrival >= trace.requests.len() && queue.is_empty() {
                break;
            }
            // idle until the next arrival
            let next_t = trace
                .requests
                .get(next_arrival)
                .map_or(clock.now(), |r| r.arrival_s);
            clock.idle_until(next_t);
            continue;
        }

        occupancy_sum += live;
        peak_occupancy = peak_occupancy.max(live);
        iters += 1;
        let (wp0, wc0) = (engine.metrics().work_pass_units,
                          engine.metrics().work_col_units);
        engine.inject_faults(fs);
        // A worker-pool incident unwinds out of step() BEFORE the
        // engine mutates any state (fault_prologue panics first), and
        // the pool itself re-arms on the panicking dispatch
        // (`WorkerPool` swaps its poisoned flag), so one clean retry
        // is safe and sufficient.  A second panic is a real bug:
        // propagate it.
        match catch_unwind(AssertUnwindSafe(|| engine.step())) {
            Ok(r) => r?,
            Err(_) => {
                engine.metrics_mut().pool_rebuilds += 1;
                engine.step()?;
            }
        }
        engine.metrics_mut().iterations += 1;
        clock.on_iteration(engine.metrics().work_pass_units - wp0,
                           engine.metrics().work_col_units - wc0);
    }

    // Final harvest (defensive: the loop only exits once every slot has
    // been harvested, but keep any stragglers consistent with the
    // in-loop accounting — arrival-based, queueing delay included).
    for slot in 0..b {
        if let Some(f) = slots[slot].take() {
            let row_failed = engine.seqs()[slot].failed;
            let tokens = engine.seqs()[slot].gen_tokens().to_vec();
            engine.release(slot);
            if row_failed {
                failed += 1;
                outcomes[f.request_idx] = Some(RequestOutcome::Failed {
                    reason: format!("target pass failed after \
                                     {MAX_TARGET_RETRIES} retries"),
                });
            } else {
                let lat = (clock.now()
                    - trace.requests[f.request_idx].arrival_s)
                    .max(0.0);
                latencies.push(lat);
                outcomes[f.request_idx] =
                    Some(RequestOutcome::Completed { tokens,
                                                     latency_s: lat });
            }
        }
    }
    // Refresh the engine's KV gauges now that the last release landed,
    // so `kv_blocks_in_use` reads 0 at drain (the chaos gate's leak
    // check).
    engine.observe_kv();

    let wall = clock.now();
    let generated = engine.metrics().generated - gen0;
    // Only REAL elapsed time may enter `Metrics::wall_s` — virtual
    // seconds land in `virtual_s`, so tokens/s derived from Metrics
    // after a virtual serve stays a wall-clock number (the ServeStats
    // below still report the virtual window).
    match &clock {
        ServeClock::Wall(_) => engine.metrics_mut().wall_s += wall,
        ServeClock::Virtual { .. }
        | ServeClock::VirtualCosted { .. } => {
            engine.metrics_mut().virtual_s += wall;
        }
    }
    // total_cmp: a NaN latency (possible only if a clock misbehaves)
    // must not panic the serve loop's accounting.
    latencies.sort_by(f64::total_cmp);
    let n = latencies.len();
    let pct = |p: f64| -> f64 {
        if n == 0 {
            0.0
        } else {
            latencies[(p * (n - 1) as f64).round() as usize]
        }
    };
    Ok(ServeStats {
        completed: n,
        failed,
        expired,
        outcomes: outcomes
            .into_iter()
            .map(|o| {
                o.unwrap_or(RequestOutcome::Failed {
                    reason: "request was never served".into(),
                })
            })
            .collect(),
        wall_s: wall,
        generated,
        latency_mean_s: latencies.iter().sum::<f64>() / n.max(1) as f64,
        latency_p50_s: pct(0.5),
        latency_p95_s: pct(0.95),
        throughput_tps: if wall > 0.0 {
            generated as f64 / wall
        } else {
            0.0
        },
        mean_occupancy: occupancy_sum as f64 / iters.max(1) as f64,
        peak_occupancy,
        admission_stalls: stalls,
    })
}
