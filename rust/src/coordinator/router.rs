//! Model routing: which draft accelerates which target (model family
//! per DESIGN.md §6).
//!
//! The paper's target-independence property (Table 2) means ONE draft
//! serves the whole family; the router encodes that policy plus the
//! target-dependent exception (EAGLE heads bind to a single target).

use anyhow::Result;

use super::engines::EngineKind;
use crate::runtime::Manifest;

/// Family targets in ascending size (Table 2 rows).  The draft itself is
/// also a valid target (paper: L3.2-1B accelerated by its own PARD
/// adaptation at 2.1x).
pub const FAMILY_TARGETS: [&str; 4] =
    ["draft-s", "target-m", "target-l", "target-xl"];

/// Default draft for (engine, target) under the routing policy.
pub fn default_draft(manifest: &Manifest, kind: EngineKind, target: &str)
                     -> Result<Option<String>> {
    Ok(match kind {
        EngineKind::Ar | EngineKind::ArPlus => None,
        // target-INDEPENDENT: same draft for every family member
        EngineKind::Vsd => Some("draft-s".to_string()),
        EngineKind::Pard => Some(manifest.main_pard.clone()),
        // target-DEPENDENT: a head exists only for its training target
        EngineKind::Eagle => {
            let head = format!("eagle-{target}");
            anyhow::ensure!(
                manifest.models.contains_key(&head),
                "no EAGLE head for target `{target}` — EAGLE is \
                 target-dependent and must be trained per target \
                 (that is the paper's point)"
            );
            Some(head)
        }
    })
}

/// Targets an engine can serve without further training.
pub fn reachable_targets(manifest: &Manifest, kind: EngineKind)
                         -> Vec<String> {
    FAMILY_TARGETS
        .iter()
        .filter(|t| manifest.models.contains_key(**t))
        .filter(|t| match kind {
            EngineKind::Eagle => {
                manifest.models.contains_key(&format!("eagle-{t}"))
            }
            _ => true,
        })
        .map(|t| t.to_string())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::Path;

    fn manifest() -> Option<Manifest> {
        let p = Path::new("artifacts");
        Manifest::load(p).ok()
    }

    #[test]
    fn pard_single_draft_for_all_targets() {
        let Some(m) = manifest() else { return };
        let drafts: Vec<_> = FAMILY_TARGETS
            .iter()
            .map(|t| default_draft(&m, EngineKind::Pard, t).unwrap())
            .collect();
        assert!(drafts.windows(2).all(|w| w[0] == w[1]),
                "PARD must be target-independent");
    }

    #[test]
    fn eagle_bound_to_trained_target() {
        let Some(m) = manifest() else { return };
        assert!(default_draft(&m, EngineKind::Eagle, "target-l").is_ok());
        assert!(default_draft(&m, EngineKind::Eagle, "target-m").is_err());
    }

    #[test]
    fn ar_needs_no_draft() {
        let Some(m) = manifest() else { return };
        assert_eq!(default_draft(&m, EngineKind::Ar, "target-l").unwrap(),
                   None);
    }
}
