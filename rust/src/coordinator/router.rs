//! Model routing: which draft accelerates which target (model family
//! per DESIGN.md §6), and which speculation policy drives the pair at
//! runtime (DESIGN.md §9).
//!
//! The paper's target-independence property (Table 2) means ONE draft
//! serves the whole family; the router encodes that policy plus the
//! target-dependent exception (EAGLE heads bind to a single target).
//! `build_policy` promotes the static lookup into a runtime object:
//! every engine gets a validated [`SpecPolicy`], with the AR kinds —
//! which never draft — pinned to the inert fixed policy no matter
//! what the CLI asked for.

use anyhow::Result;

use super::engines::{EngineConfig, EngineKind};
use super::policy::{PolicyCfg, SpecPolicy};
use crate::runtime::Manifest;

/// Family targets in ascending size (Table 2 rows).  The draft itself is
/// also a valid target (paper: L3.2-1B accelerated by its own PARD
/// adaptation at 2.1x).
pub const FAMILY_TARGETS: [&str; 4] =
    ["draft-s", "target-m", "target-l", "target-xl"];

/// Default draft for (engine, target) under the routing policy.
pub fn default_draft(manifest: &Manifest, kind: EngineKind, target: &str)
                     -> Result<Option<String>> {
    Ok(match kind {
        EngineKind::Ar | EngineKind::ArPlus => None,
        // target-INDEPENDENT: same draft for every family member
        EngineKind::Vsd => Some("draft-s".to_string()),
        EngineKind::Pard => Some(manifest.main_pard.clone()),
        // target-DEPENDENT: a head exists only for its training target
        EngineKind::Eagle => {
            let head = format!("eagle-{target}");
            anyhow::ensure!(
                manifest.models.contains_key(&head),
                "no EAGLE head for target `{target}` — EAGLE is \
                 target-dependent and must be trained per target \
                 (that is the paper's point)"
            );
            Some(head)
        }
    })
}

/// Speculation controller for an engine under construction.  The
/// knobs are validated for every kind — a bad `--k-min/--k-max` fails
/// fast even on an AR run — but AR/AR+ get the inert fixed policy:
/// they never draft, so an adaptive controller (and in particular the
/// dual-mode AR+ degrade) has nothing to act on.
pub fn build_policy(cfg: &EngineConfig) -> Result<SpecPolicy> {
    SpecPolicy::new(&cfg.policy, cfg.k, cfg.batch)?;
    match cfg.kind {
        EngineKind::Ar | EngineKind::ArPlus => {
            SpecPolicy::new(&PolicyCfg::default(), cfg.k, cfg.batch)
        }
        _ => SpecPolicy::new(&cfg.policy, cfg.k, cfg.batch),
    }
}

/// Targets an engine can serve without further training.
pub fn reachable_targets(manifest: &Manifest, kind: EngineKind)
                         -> Vec<String> {
    FAMILY_TARGETS
        .iter()
        .filter(|t| manifest.models.contains_key(**t))
        .filter(|t| match kind {
            EngineKind::Eagle => {
                manifest.models.contains_key(&format!("eagle-{t}"))
            }
            _ => true,
        })
        .map(|t| t.to_string())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // The synthetic in-memory manifest the reference backend ships:
    // same family layout as a trained artifacts/ directory, so these
    // tests run everywhere — they used to silently pass (`let Some(m)
    // = ... else { return }`) on hosts without artifacts.
    fn manifest() -> Manifest {
        crate::runtime::reference::reference_manifest()
    }

    fn cfg(kind: EngineKind, policy: PolicyCfg) -> EngineConfig {
        EngineConfig {
            kind,
            target: "target-m".into(),
            draft: None,
            batch: 2,
            k: 4,
            max_new: 8,
            shared_mask: true,
            kv_blocks: None,
            prefix_cache: false,
            sampling: None,
            policy,
        }
    }

    #[test]
    fn pard_single_draft_for_all_targets() {
        let m = manifest();
        let drafts: Vec<_> = FAMILY_TARGETS
            .iter()
            .map(|t| default_draft(&m, EngineKind::Pard, t).unwrap())
            .collect();
        assert!(drafts.windows(2).all(|w| w[0] == w[1]),
                "PARD must be target-independent");
    }

    #[test]
    fn eagle_bound_to_trained_target() {
        let m = manifest();
        assert!(default_draft(&m, EngineKind::Eagle, "target-l").is_ok());
        assert!(default_draft(&m, EngineKind::Eagle, "target-m").is_err());
    }

    #[test]
    fn ar_needs_no_draft() {
        let m = manifest();
        assert_eq!(default_draft(&m, EngineKind::Ar, "target-l").unwrap(),
                   None);
    }

    #[test]
    fn reachable_targets_follow_the_manifest() {
        let m = manifest();
        let pard = reachable_targets(&m, EngineKind::Pard);
        assert_eq!(pard, vec!["draft-s", "target-m", "target-l",
                              "target-xl"]);
        // EAGLE reaches only its trained target
        assert_eq!(reachable_targets(&m, EngineKind::Eagle),
                   vec!["target-l"]);
    }

    #[test]
    fn build_policy_pins_ar_kinds_to_fixed() {
        let adaptive = PolicyCfg { adaptive: true, k_min: 2, k_max: 8,
                                   ..PolicyCfg::default() };
        let p = build_policy(&cfg(EngineKind::Pard, adaptive.clone()))
            .unwrap();
        assert_eq!(p.k_cap(), 8);
        for kind in [EngineKind::Ar, EngineKind::ArPlus] {
            let p =
                build_policy(&cfg(kind, adaptive.clone())).unwrap();
            assert!(!p.cfg().adaptive, "AR kinds never draft");
            assert_eq!(p.k_cap(), 4);
        }
    }

    #[test]
    fn build_policy_rejects_bad_knobs_for_every_kind() {
        let bad = PolicyCfg { adaptive: true, k_min: 9, k_max: 2,
                              ..PolicyCfg::default() };
        for kind in [EngineKind::Ar, EngineKind::ArPlus,
                     EngineKind::Vsd, EngineKind::Pard,
                     EngineKind::Eagle] {
            assert!(build_policy(&cfg(kind, bad.clone())).is_err());
        }
    }
}
