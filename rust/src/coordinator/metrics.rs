//! Serving metrics: per-phase wall clock, acceptance statistics, TPS.
//!
//! Everything the paper's tables report derives from these counters:
//! TPS (Tables 1-4), k-α acceptance (Table 5, Fig. 1a), draft/verify
//! time breakdown (Fig. 1b), tokens/iteration (device-model projections
//! for Tables 6-7), and the per-op forward breakdown the host backend
//! reports (`fwd_ops` in `BENCH_hotpath.json`, DESIGN.md §8).

use std::collections::VecDeque;

use crate::runtime::{FwdOps, FwdOut};

/// Verify records kept for the windowed accept-rate view
/// (`accept_rate_k`); bounds memory on long serving runs while staying
/// far larger than any policy window.
pub const ACCEPT_RECENT_CAP: usize = 256;

/// Per-run counters and timers every engine and serving loop feeds;
/// the report layer and benches read them (DESIGN.md §3).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Wall clock inside draft fwd+commit calls.
    pub draft_s: f64,
    /// Wall clock inside target verify fwd+commit calls.
    pub verify_s: f64,
    /// Wall clock inside prefill calls.
    pub prefill_s: f64,
    /// Backend-reported forward-execution time (`FwdOut::elapsed_s`),
    /// summed over every fwd call — one side of the fwd/commit split
    /// the executable protocol imposes (DESIGN.md §7).
    pub fwd_s: f64,
    /// Backend-reported commit (KV scatter) time, the other side of
    /// the split.  `draft_s`/`verify_s`/`prefill_s` measure caller
    /// wall-clock *around* fwd+commit, so `fwd_s + commit_s` vs their
    /// sum isolates coordinator overhead.
    pub commit_s: f64,
    /// Per-op breakdown of `fwd_s`, summed over every fwd call on a
    /// backend that instruments its forward pass (the host fast path);
    /// all-zero otherwise.  `fwd_ops.total() <= fwd_s` always.
    pub fwd_ops: FwdOps,
    /// End-to-end generate() wall clock (includes coordinator overhead).
    /// REAL time only — a virtual-clock serve accrues `virtual_s`
    /// instead, so `tps()` never divides by simulated seconds.
    pub wall_s: f64,
    /// Simulated seconds accumulated by virtual-clock serving windows
    /// (`serve_trace_virtual`); kept apart from `wall_s` so derived
    /// wall-clock rates stay honest.
    pub virtual_s: f64,
    /// Decode iterations executed.
    pub iterations: u64,
    /// Draft-model forward passes (K per iter for VSD/EAGLE, 1 for PARD).
    pub draft_passes: u64,
    /// Target-model forward passes.
    pub target_passes: u64,
    /// Generated (committed) tokens, prompt excluded.
    pub generated: u64,
    /// Completed sequences.
    pub requests: u64,
    /// accept_pos[j] = number of iterations in which draft position j
    /// was accepted; offered_pos[j] = iterations where position j was
    /// offered.  accept_pos[j]/offered_pos[j] is the per-position
    /// acceptance rate (Fig. 1a); the mean over j < k is k-α (Table 5).
    pub accept_pos: Vec<u64>,
    pub offered_pos: Vec<u64>,
    /// Histogram of accepted-prefix length per iteration.
    pub accept_hist: Vec<u64>,
    /// Greedy agreement of generated tokens with the grammar reference
    /// (quality guard: speculative methods must not change outputs).
    pub ref_match: u64,
    pub ref_total: u64,
    /// KV pool blocks allocated across the engine's caches at the last
    /// observation (paged cache, DESIGN.md §7); 0 on dense backends.
    pub kv_blocks_in_use: u64,
    /// High-water mark of `kv_blocks_in_use` — the paged pool's peak
    /// occupancy over the run.
    pub kv_peak_blocks: u64,
    /// Batcher iterations in which a ready request could not be
    /// admitted because the KV pool lacked unreserved blocks
    /// (memory-bounded admission backpressure).
    pub admission_stalls: u64,
    /// Prompt tokens served from cached prefix blocks at admit,
    /// cumulative over the engine's caches (`--prefix-cache`).
    pub prefix_hit_tokens: u64,
    /// High-water mark of extra references onto shared KV blocks (a
    /// block mapped by r rows contributes r-1).
    pub kv_blocks_shared: u64,
    /// Copy-on-write block copies, cumulative over the engine's
    /// caches (0 under the engine protocol — COW is a safety net).
    pub cow_copies: u64,
    /// Stochastic verification: verify rows whose first rejection was
    /// repaired by a residual resample (at most one per row per iter;
    /// 0 under greedy decoding).
    pub residual_resamples: u64,
    /// Stochastic verification: bonus tokens sampled from the target at
    /// fully-accepting verify rows (0 under greedy decoding).
    pub bonus_samples: u64,
    /// Most recent `(offered, accepted)` verify records, newest last,
    /// capped at [`ACCEPT_RECENT_CAP`]; zero-offered verifies (AR+
    /// mode) are not observations and are never recorded.  Feeds the
    /// windowed `accept_rate_k` view.
    pub accept_recent: VecDeque<(u32, u32)>,
    /// k_hist[k] = live rows the speculation policy planned K=k for
    /// (K=0 counts dual-mode AR+ degrades).  Empty for engines that
    /// never consult a policy (AR, AR+).
    pub k_hist: Vec<u64>,
    /// Draft/AR+ dual-mode transitions (either direction).
    pub mode_switches: u64,
    /// Decode iterations planned in dual (AR+-degraded) mode.
    pub dual_mode_iters: u64,
    /// Work-unit ledger for the costed virtual clock (DESIGN.md §9):
    /// one pass unit per forward call, weighted by the model's
    /// parameter count — the weight-read (bandwidth) cost a decode
    /// step pays regardless of batch width.
    pub work_pass_units: f64,
    /// Parameter count × live (fed) columns, summed over forward
    /// calls — the compute cost that scales with batch occupancy and
    /// draft length.
    pub work_col_units: f64,
    /// Faults the injection plan fired into this engine's iterations
    /// (DESIGN.md §10); 0 outside chaos runs.
    pub faults_injected: u64,
    /// Iterations degraded losslessly because the draft pass failed
    /// (greedy: K=0 AR+ commit; sampled: held iteration).
    pub draft_fallbacks: u64,
    /// Failed target-pass attempts absorbed by bounded retry.
    pub row_retries: u64,
    /// Rows failed after a persistent target incident — the row's KV
    /// blocks are released and its caller gets a typed `Failed`.
    pub rows_failed: u64,
    /// Requests cancelled by their caller before completion.
    pub cancelled: u64,
    /// Requests expired by their deadline (wall or virtual clock).
    pub deadline_exceeded: u64,
    /// Worker-pool poison incidents recovered by rebuilding/reusing
    /// the pool and retrying the iteration.
    pub pool_rebuilds: u64,
}

impl Metrics {
    /// Account one forward call: its backend-reported execution time
    /// and, when present, its per-op breakdown.  Every engine fwd call
    /// site funnels through here so the split stays consistent.
    pub fn record_fwd(&mut self, out: &FwdOut) {
        self.fwd_s += out.elapsed_s;
        if let Some(ops) = &out.ops {
            // Ledger invariant at every call site: the op phases are
            // disjoint laps of the same call, so their sum can never
            // exceed the call's own elapsed time — nor can the running
            // totals diverge (epsilons absorb float summation noise).
            debug_assert!(ops.total() <= out.elapsed_s + 1e-9,
                          "fwd_ops {} exceeds elapsed {}",
                          ops.total(), out.elapsed_s);
            self.fwd_ops.add(ops);
            debug_assert!(self.fwd_ops.total() <= self.fwd_s + 1e-6,
                          "cumulative fwd_ops {} exceeds fwd_s {}",
                          self.fwd_ops.total(), self.fwd_s);
        }
    }

    /// Observe the engine's current KV pool occupancy (summed over its
    /// caches): records the last value and advances the peak.
    pub fn record_kv_blocks(&mut self, in_use: usize) {
        self.kv_blocks_in_use = in_use as u64;
        self.kv_peak_blocks = self.kv_peak_blocks.max(in_use as u64);
    }

    /// Observe the engine's prefix-sharing state (summed over its
    /// caches): `hit_tokens`/`cow` are the caches' cumulative counters
    /// (assigned), `shared` a gauge whose peak is kept.
    pub fn record_prefix_stats(&mut self, hit_tokens: u64, shared: usize,
                               cow: u64) {
        self.prefix_hit_tokens = hit_tokens;
        self.kv_blocks_shared = self.kv_blocks_shared.max(shared as u64);
        self.cow_copies = cow;
    }

    /// Record one verify verdict: `accepted` of `offered` candidates.
    pub fn record_acceptance(&mut self, offered: usize, accepted: usize) {
        // A zero-candidate verify is an AR+-mode step, not an
        // acceptance observation: recording it would add a phantom
        // zero-length entry to accept_hist (dragging mean_accept_len
        // down) and a (0, 0) record to the windowed rate.
        if offered == 0 {
            return;
        }
        self.accept_recent.push_back((offered as u32, accepted as u32));
        while self.accept_recent.len() > ACCEPT_RECENT_CAP {
            self.accept_recent.pop_front();
        }
        if self.offered_pos.len() < offered {
            self.offered_pos.resize(offered, 0);
            self.accept_pos.resize(offered, 0);
        }
        for j in 0..offered {
            self.offered_pos[j] += 1;
            if j < accepted {
                self.accept_pos[j] += 1;
            }
        }
        if self.accept_hist.len() <= accepted {
            self.accept_hist.resize(accepted + 1, 0);
        }
        self.accept_hist[accepted] += 1;
    }

    /// Windowed acceptance rate over the first `k` draft positions:
    /// accepted / offered among positions `< k` in the last `window`
    /// verify records.  The speculation controller consumes the same
    /// shape of number per sequence, so the edge cases are pinned by
    /// tests: no records (or only zero-offered verifies, which are
    /// never recorded) → 0.0; `accepted == offered` everywhere → 1.0;
    /// `window` larger than history → uses all of it; `k` larger than
    /// any offered length → the full-length rate.
    pub fn accept_rate_k(&self, k: usize, window: usize) -> f64 {
        let (mut num, mut den) = (0u64, 0u64);
        let skip = self.accept_recent.len().saturating_sub(window);
        for &(off, acc) in self.accept_recent.iter().skip(skip) {
            den += u64::from(off).min(k as u64);
            num += u64::from(acc).min(k as u64);
        }
        if den == 0 {
            0.0
        } else {
            num as f64 / den as f64
        }
    }

    /// Account one planned per-row K choice (speculation policy).
    pub fn record_k_choice(&mut self, k: usize) {
        if self.k_hist.len() <= k {
            self.k_hist.resize(k + 1, 0);
        }
        self.k_hist[k] += 1;
    }

    /// Account one forward call in the work-unit ledger: `n_params`
    /// pass units (weight reads) and `n_params * cols` column units,
    /// where `cols` is the number of live (actually fed) cells in the
    /// call.  Drives `serve_trace_virtual_costed`.
    pub fn record_work(&mut self, n_params: usize, cols: usize) {
        self.work_pass_units += n_params as f64;
        self.work_col_units += n_params as f64 * cols as f64;
    }

    /// Mean acceptance rate over the first `k` draft positions — the
    /// paper's k-α (Table 5).
    pub fn k_alpha(&self, k: usize) -> f64 {
        let mut num = 0u64;
        let mut den = 0u64;
        for j in 0..k.min(self.offered_pos.len()) {
            num += self.accept_pos[j];
            den += self.offered_pos[j];
        }
        if den == 0 {
            0.0
        } else {
            num as f64 / den as f64
        }
    }

    /// Acceptance rate of draft position j (Fig. 1a series).
    pub fn pos_alpha(&self, j: usize) -> f64 {
        if j >= self.offered_pos.len() || self.offered_pos[j] == 0 {
            0.0
        } else {
            self.accept_pos[j] as f64 / self.offered_pos[j] as f64
        }
    }

    /// Mean accepted-prefix length per verify iteration (the paper's
    /// mean accept length; 0 for the AR baselines, which never draft).
    pub fn mean_accept_len(&self) -> f64 {
        let total: u64 = self.accept_hist.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let accepted: u64 = self
            .accept_hist
            .iter()
            .enumerate()
            .map(|(len, &cnt)| len as u64 * cnt)
            .sum();
        accepted as f64 / total as f64
    }

    /// Mean committed tokens per decode iteration (a + 1).
    pub fn tokens_per_iter(&self) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.generated as f64 / self.iterations as f64
        }
    }

    /// Generated tokens per second of end-to-end wall clock.
    pub fn tps(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.generated as f64 / self.wall_s
        }
    }

    /// Reference-agreement rate over cross-checked positions (0 when
    /// none ran).
    pub fn ref_agreement(&self) -> f64 {
        if self.ref_total == 0 {
            0.0
        } else {
            self.ref_match as f64 / self.ref_total as f64
        }
    }

    /// Fold another run's counters into this one (bench aggregation).
    pub fn merge(&mut self, o: &Metrics) {
        self.draft_s += o.draft_s;
        self.verify_s += o.verify_s;
        self.prefill_s += o.prefill_s;
        self.fwd_s += o.fwd_s;
        self.commit_s += o.commit_s;
        self.fwd_ops.add(&o.fwd_ops);
        self.wall_s += o.wall_s;
        self.virtual_s += o.virtual_s;
        self.iterations += o.iterations;
        self.draft_passes += o.draft_passes;
        self.target_passes += o.target_passes;
        self.generated += o.generated;
        self.requests += o.requests;
        self.ref_match += o.ref_match;
        self.ref_total += o.ref_total;
        // kv occupancy is a gauge, not a counter: merged runs report
        // the worst case, stalls accumulate.
        self.kv_blocks_in_use = self.kv_blocks_in_use
            .max(o.kv_blocks_in_use);
        self.kv_peak_blocks = self.kv_peak_blocks.max(o.kv_peak_blocks);
        self.admission_stalls += o.admission_stalls;
        self.prefix_hit_tokens += o.prefix_hit_tokens;
        self.kv_blocks_shared = self.kv_blocks_shared
            .max(o.kv_blocks_shared);
        self.cow_copies += o.cow_copies;
        self.residual_resamples += o.residual_resamples;
        self.bonus_samples += o.bonus_samples;
        self.accept_recent.extend(o.accept_recent.iter().copied());
        while self.accept_recent.len() > ACCEPT_RECENT_CAP {
            self.accept_recent.pop_front();
        }
        if self.k_hist.len() < o.k_hist.len() {
            self.k_hist.resize(o.k_hist.len(), 0);
        }
        for (i, c) in o.k_hist.iter().enumerate() {
            self.k_hist[i] += c;
        }
        self.mode_switches += o.mode_switches;
        self.dual_mode_iters += o.dual_mode_iters;
        self.work_pass_units += o.work_pass_units;
        self.work_col_units += o.work_col_units;
        self.faults_injected += o.faults_injected;
        self.draft_fallbacks += o.draft_fallbacks;
        self.row_retries += o.row_retries;
        self.rows_failed += o.rows_failed;
        self.cancelled += o.cancelled;
        self.deadline_exceeded += o.deadline_exceeded;
        self.pool_rebuilds += o.pool_rebuilds;
        if self.offered_pos.len() < o.offered_pos.len() {
            self.offered_pos.resize(o.offered_pos.len(), 0);
            self.accept_pos.resize(o.accept_pos.len(), 0);
        }
        for j in 0..o.offered_pos.len() {
            self.offered_pos[j] += o.offered_pos[j];
            self.accept_pos[j] += o.accept_pos[j];
        }
        if self.accept_hist.len() < o.accept_hist.len() {
            self.accept_hist.resize(o.accept_hist.len(), 0);
        }
        for (i, c) in o.accept_hist.iter().enumerate() {
            self.accept_hist[i] += c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acceptance_accounting() {
        let mut m = Metrics::default();
        m.record_acceptance(4, 2); // positions 0,1 accepted
        m.record_acceptance(4, 4);
        m.record_acceptance(4, 0);
        assert_eq!(m.offered_pos, vec![3, 3, 3, 3]);
        assert_eq!(m.accept_pos, vec![2, 2, 1, 1]);
        assert!((m.pos_alpha(0) - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.k_alpha(4) - 6.0 / 12.0).abs() < 1e-12);
        assert_eq!(m.accept_hist, vec![1, 0, 1, 0, 1]);
        assert!((m.mean_accept_len() - 2.0).abs() < 1e-12);
        assert_eq!(Metrics::default().mean_accept_len(), 0.0);
    }

    #[test]
    fn tps_and_tpi() {
        let mut m = Metrics::default();
        m.generated = 100;
        m.iterations = 25;
        m.wall_s = 2.0;
        assert!((m.tokens_per_iter() - 4.0).abs() < 1e-12);
        assert!((m.tps() - 50.0).abs() < 1e-12);
    }

    #[test]
    fn merge_sums() {
        let mut a = Metrics::default();
        a.record_acceptance(2, 1);
        a.generated = 5;
        let mut b = Metrics::default();
        b.record_acceptance(4, 3);
        b.generated = 7;
        a.merge(&b);
        assert_eq!(a.generated, 12);
        assert_eq!(a.offered_pos, vec![2, 2, 1, 1]);
        assert_eq!(a.accept_pos, vec![2, 1, 1, 0]);
    }

    #[test]
    fn merge_sums_stochastic_counters() {
        let mut a = Metrics::default();
        a.residual_resamples = 3;
        a.bonus_samples = 2;
        let mut b = Metrics::default();
        b.residual_resamples = 1;
        b.bonus_samples = 4;
        a.merge(&b);
        assert_eq!(a.residual_resamples, 4);
        assert_eq!(a.bonus_samples, 6);
    }

    #[test]
    fn record_fwd_accumulates_elapsed_and_ops() {
        use crate::runtime::{FwdOut, KvStage};
        let mk = |elapsed: f64, ops: Option<FwdOps>| FwdOut {
            logits: Vec::new(),
            hidden: None,
            kv: KvStage::Host { k: Vec::new(), v: Vec::new() },
            elapsed_s: elapsed,
            ops,
        };
        let mut m = Metrics::default();
        let ops = FwdOps { qkv_s: 0.5, attn_s: 0.25,
                           ..FwdOps::default() };
        m.record_fwd(&mk(1.0, Some(ops)));
        m.record_fwd(&mk(2.0, None)); // oracle-style: no breakdown
        assert_eq!(m.fwd_s, 3.0);
        assert_eq!(m.fwd_ops.qkv_s, 0.5);
        assert_eq!(m.fwd_ops.attn_s, 0.25);
        assert!(m.fwd_ops.total() <= m.fwd_s);
        // merge must carry the breakdown along
        let mut other = Metrics::default();
        other.merge(&m);
        assert_eq!(other.fwd_ops.qkv_s, 0.5);
    }

    #[test]
    fn kv_gauges_track_peak_and_merge_as_worst_case() {
        let mut a = Metrics::default();
        a.record_kv_blocks(4);
        a.record_kv_blocks(9);
        a.record_kv_blocks(2);
        assert_eq!(a.kv_blocks_in_use, 2, "last observation");
        assert_eq!(a.kv_peak_blocks, 9, "high-water mark");
        a.admission_stalls = 3;
        let mut b = Metrics::default();
        b.record_kv_blocks(5);
        b.admission_stalls = 1;
        b.merge(&a);
        assert_eq!(b.kv_blocks_in_use, 5);
        assert_eq!(b.kv_peak_blocks, 9);
        assert_eq!(b.admission_stalls, 4);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert_eq!(m.tps(), 0.0);
        assert_eq!(m.k_alpha(4), 0.0);
        assert_eq!(m.pos_alpha(9), 0.0);
        assert_eq!(m.accept_rate_k(4, 8), 0.0);
    }

    #[test]
    fn zero_offered_acceptance_is_a_noop() {
        let mut m = Metrics::default();
        m.record_acceptance(0, 0);
        assert!(m.offered_pos.is_empty());
        assert!(m.accept_pos.is_empty());
        assert!(m.accept_hist.is_empty(),
                "a zero-offered verify must not add a phantom \
                 zero-length accept_hist entry");
        assert!(m.accept_recent.is_empty());
        assert_eq!(m.mean_accept_len(), 0.0);
        assert_eq!(m.accept_rate_k(4, 8), 0.0);
        // and it must not dilute real observations either
        m.record_acceptance(4, 4);
        m.record_acceptance(0, 0);
        assert!((m.mean_accept_len() - 4.0).abs() < 1e-12);
        assert!((m.accept_rate_k(4, 8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn full_acceptance_rates_one() {
        let mut m = Metrics::default();
        m.record_acceptance(3, 3);
        m.record_acceptance(7, 7);
        assert!((m.accept_rate_k(7, 8) - 1.0).abs() < 1e-12);
        assert!((m.accept_rate_k(2, 8) - 1.0).abs() < 1e-12);
        assert!((m.k_alpha(7) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn window_larger_than_history_uses_all_of_it() {
        let mut m = Metrics::default();
        m.record_acceptance(4, 2);
        m.record_acceptance(4, 0);
        // window 1000 >> 2 records: (2+0)/(4+4)
        assert!((m.accept_rate_k(4, 1000) - 0.25).abs() < 1e-12);
        // window 1 sees only the newest record
        assert_eq!(m.accept_rate_k(4, 1), 0.0);
    }

    #[test]
    fn k_larger_than_any_offered_length() {
        let mut m = Metrics::default();
        m.record_acceptance(2, 1);
        m.record_acceptance(3, 3);
        // k=16 caps nothing: (1+3)/(2+3)
        assert!((m.accept_rate_k(16, 8) - 0.8).abs() < 1e-12);
        // k=1 truncates every record to its first position
        assert!((m.accept_rate_k(1, 8) - 1.0).abs() < 1e-12);
        // positions beyond anything offered contribute nothing
        assert_eq!(m.accept_rate_k(16, 8), m.accept_rate_k(3, 8));
    }

    #[test]
    fn accept_recent_is_capped() {
        let mut m = Metrics::default();
        for i in 0..(ACCEPT_RECENT_CAP + 10) {
            m.record_acceptance(2, (i % 3 == 0) as usize);
        }
        assert_eq!(m.accept_recent.len(), ACCEPT_RECENT_CAP);
    }

    #[test]
    fn robustness_counters_merge() {
        let mut a = Metrics::default();
        a.faults_injected = 5;
        a.draft_fallbacks = 2;
        a.row_retries = 3;
        a.rows_failed = 1;
        a.cancelled = 1;
        a.deadline_exceeded = 2;
        a.pool_rebuilds = 1;
        let mut b = Metrics::default();
        b.faults_injected = 1;
        b.row_retries = 2;
        b.cancelled = 4;
        a.merge(&b);
        assert_eq!(a.faults_injected, 6);
        assert_eq!(a.draft_fallbacks, 2);
        assert_eq!(a.row_retries, 5);
        assert_eq!(a.rows_failed, 1);
        assert_eq!(a.cancelled, 5);
        assert_eq!(a.deadline_exceeded, 2);
        assert_eq!(a.pool_rebuilds, 1);
    }

    #[test]
    fn policy_counters_merge() {
        let mut a = Metrics::default();
        a.record_k_choice(2);
        a.record_k_choice(2);
        a.record_k_choice(0);
        a.mode_switches = 1;
        a.dual_mode_iters = 3;
        a.record_work(10, 4);
        let mut b = Metrics::default();
        b.record_k_choice(5);
        b.mode_switches = 2;
        b.record_work(10, 1);
        b.record_acceptance(4, 2);
        a.merge(&b);
        assert_eq!(a.k_hist, vec![1, 0, 2, 0, 0, 1]);
        assert_eq!(a.mode_switches, 3);
        assert_eq!(a.dual_mode_iters, 3);
        assert_eq!(a.work_pass_units, 20.0);
        assert_eq!(a.work_col_units, 50.0);
        assert_eq!(a.accept_recent, vec![(4, 2)]);
    }
}
