//! Per-slot sequence state shared by every decode engine.
//!
//! Invariants (DESIGN.md §7):
//! * `stream` = prompt ++ generated tokens; its last token is always the
//!   *pending* token — in the stream but with its KV not yet committed to
//!   the target cache, so `target_len == stream.len() - 1` while active.
//! * `draft_len <= stream.len() - 1`: how much of the stream the draft
//!   model's cache has consumed; the gap is re-fed on the next draft call
//!   (PARD's "re-feed accepted reals over stale mask slots").

use crate::substrate::rng::Rng;

/// One batch row's decode state: prompt + committed stream plus the
/// slot-protocol flags (DESIGN.md §5).
#[derive(Debug, Clone, Default)]
pub struct Sequence {
    pub prompt_len: usize,
    pub stream: Vec<i32>,
    /// Target-cache committed length (== stream.len()-1 while active).
    pub target_len: usize,
    /// Draft-cache committed length.
    pub draft_len: usize,
    /// Newly committed tokens from the last step (drained by callers).
    pub fresh: Vec<i32>,
    pub done: bool,
    pub active: bool,
    /// Set when a persistent target-pass incident failed this row
    /// (DESIGN.md §10): the row is done without completing, its KV
    /// blocks are released at harvest, and its caller gets a typed
    /// `Failed` outcome instead of tokens.
    pub failed: bool,
    pub max_new: usize,
    /// EAGLE: hidden state associated with the pending token (the
    /// feature row that produced it).
    pub pending_hidden: Option<Vec<f32>>,
    /// EAGLE: (token, position, hidden) pairs not yet in the head cache.
    pub eagle_backlog: Vec<(i32, i32, Vec<f32>)>,
    /// Stochastic decoding: this sequence's private sampling stream,
    /// seeded from (sample_seed, admission ordinal) so sampled output
    /// is invariant to batch size and slot assignment (DESIGN.md §6).
    /// None under greedy decoding.
    pub rng: Option<Rng>,
}

impl Sequence {
    /// Fresh sequence over `prompt`, budgeted to `max_new` tokens.
    pub fn start(prompt: &[i32], max_new: usize) -> Self {
        Sequence {
            prompt_len: prompt.len(),
            stream: prompt.to_vec(),
            target_len: 0,
            draft_len: 0,
            fresh: Vec::new(),
            done: false,
            active: true,
            failed: false,
            max_new,
            pending_hidden: None,
            eagle_backlog: Vec::new(),
            rng: None,
        }
    }

    /// Tokens generated so far (excludes the prompt).
    pub fn generated(&self) -> usize {
        self.stream.len() - self.prompt_len
    }

    /// The pending token (last of stream).
    pub fn pending(&self) -> i32 {
        *self.stream.last().expect("empty stream")
    }

    /// Commit `toks` to the stream; returns how many were actually taken
    /// (EOS or the max_new budget can cut the tail).  Marks `done`
    /// accordingly.
    pub fn push_committed(&mut self, toks: &[i32], eos: i32) -> usize {
        let mut taken = 0;
        for &t in toks {
            if self.done {
                break;
            }
            self.stream.push(t);
            self.fresh.push(t);
            taken += 1;
            if t == eos || self.generated() >= self.max_new {
                self.done = true;
            }
        }
        taken
    }

    /// The generated suffix of the stream.
    pub fn gen_tokens(&self) -> &[i32] {
        &self.stream[self.prompt_len..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_state() {
        let s = Sequence::start(&[0, 10, 11], 8);
        assert_eq!(s.prompt_len, 3);
        assert_eq!(s.pending(), 11);
        assert_eq!(s.generated(), 0);
        assert!(!s.done);
    }

    #[test]
    fn eos_stops() {
        let mut s = Sequence::start(&[0, 10], 8);
        let taken = s.push_committed(&[20, 1, 21], 1);
        assert_eq!(taken, 2); // 21 dropped after EOS
        assert!(s.done);
        assert_eq!(s.gen_tokens(), &[20, 1]);
    }

    #[test]
    fn max_new_stops() {
        let mut s = Sequence::start(&[0], 2);
        let taken = s.push_committed(&[5, 6, 7], 1);
        assert_eq!(taken, 2);
        assert!(s.done);
        assert_eq!(s.generated(), 2);
    }

    #[test]
    fn fresh_accumulates() {
        let mut s = Sequence::start(&[0], 10);
        s.push_committed(&[5], 1);
        s.push_committed(&[6, 7], 1);
        assert_eq!(s.fresh, vec![5, 6, 7]);
    }
}
