//! Token selection: greedy argmax (the paper's evaluation setting,
//! temperature 0 — what the equivalence suite of DESIGN.md §6 pins)
//! plus full speculative sampling (Leviathan et al. / Chen et al.) for
//! the stochastic path, with the residual-distribution correction
//! property-tested for distribution preservation.

use crate::substrate::rng::Rng;

/// Argmax over one logits row.
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as i32
}

/// Softmax with temperature into a probability vector.
pub fn softmax(row: &[f32], temperature: f32) -> Vec<f32> {
    let t = temperature.max(1e-6);
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut p: Vec<f32> = row.iter().map(|&x| ((x - m) / t).exp()).collect();
    let s: f32 = p.iter().sum();
    for x in &mut p {
        *x /= s;
    }
    p
}

pub fn sample(p: &[f32], rng: &mut Rng) -> i32 {
    let u = rng.f64() as f32;
    let mut acc = 0.0f32;
    for (i, &pi) in p.iter().enumerate() {
        acc += pi;
        if u < acc {
            return i as i32;
        }
    }
    (p.len() - 1) as i32
}

/// One speculative-sampling acceptance step (stochastic verification).
///
/// Given draft distribution `q`, target distribution `p`, and the drafted
/// token `x`: accept with prob min(1, p[x]/q[x]); on rejection resample
/// from the residual max(p-q, 0).  Returns (accepted, token) where
/// `token` is `x` if accepted else the residual sample — the classic
/// construction whose output provably follows `p` exactly.
pub fn spec_accept(p: &[f32], q: &[f32], x: i32, rng: &mut Rng)
                   -> (bool, i32) {
    let xi = x as usize;
    let ratio = if q[xi] <= 0.0 { 1.0 } else { (p[xi] / q[xi]).min(1.0) };
    if (rng.f64() as f32) < ratio {
        return (true, x);
    }
    let mut resid: Vec<f32> = p
        .iter()
        .zip(q.iter())
        .map(|(&pi, &qi)| (pi - qi).max(0.0))
        .collect();
    let s: f32 = resid.iter().sum();
    if s <= 0.0 {
        // p == q pointwise; rejection can't actually occur, but guard.
        return (false, sample(p, rng));
    }
    for r in &mut resid {
        *r /= s;
    }
    (false, sample(&resid, rng))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::Cases;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0]), 0); // first wins ties
    }

    #[test]
    fn argmax_tie_breaking_is_lowest_index() {
        // Lossless verification depends on draft and target resolving
        // ties identically: the FIRST maximal index wins, everywhere.
        assert_eq!(argmax(&[1.0, 7.0, 7.0, 7.0]), 1);
        assert_eq!(argmax(&[0.0, 0.0, 0.0]), 0);
        assert_eq!(argmax(&[-1.0, -1.0, -0.5, -0.5]), 2);
    }

    #[test]
    fn argmax_degenerate_rows() {
        // Empty row: the documented fallback is index 0 (callers never
        // pass empty rows; this pins the behavior all the same).
        assert_eq!(argmax(&[]), 0);
        // All -inf still yields a valid index.
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        // -inf gaps don't confuse the scan.
        assert_eq!(argmax(&[f32::NEG_INFINITY, 2.0, f32::NEG_INFINITY]),
                   1);
        // Finite rows (the NaN-free contract engines rely on): the
        // maximum wins regardless of magnitude spread.
        assert_eq!(argmax(&[f32::MIN, 0.0, f32::MAX]), 2);
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_low_temp_is_sharp() {
        let p = softmax(&[1.0, 2.0, 3.0], 0.01);
        assert!(p[2] > 0.999);
    }

    #[test]
    fn sample_respects_distribution() {
        let mut rng = Rng::new(11);
        let p = [0.1f32, 0.6, 0.3];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[sample(&p, &mut rng) as usize] += 1;
        }
        for i in 0..3 {
            let f = counts[i] as f32 / 30_000.0;
            assert!((f - p[i]).abs() < 0.02, "bin {i}: {f} vs {}", p[i]);
        }
    }

    /// The headline property: speculative sampling must reproduce the
    /// target distribution exactly, for ANY draft distribution.
    #[test]
    fn spec_sampling_preserves_target_distribution() {
        Cases::new(8).check("spec-preserves-p", |rng| {
            let n = 4 + rng.below(4);
            let mut p: Vec<f32> =
                (0..n).map(|_| rng.f64() as f32 + 0.01).collect();
            let mut q: Vec<f32> =
                (0..n).map(|_| rng.f64() as f32 + 0.01).collect();
            let sp: f32 = p.iter().sum();
            let sq: f32 = q.iter().sum();
            p.iter_mut().for_each(|x| *x /= sp);
            q.iter_mut().for_each(|x| *x /= sq);
            let trials = 40_000;
            let mut counts = vec![0usize; n];
            for _ in 0..trials {
                let x = sample(&q, rng);
                let (_, tok) = spec_accept(&p, &q, x, rng);
                counts[tok as usize] += 1;
            }
            for i in 0..n {
                let f = counts[i] as f32 / trials as f32;
                assert!(
                    (f - p[i]).abs() < 0.025,
                    "bin {i}: got {f}, want {}",
                    p[i]
                );
            }
        });
    }

    #[test]
    fn spec_accept_identical_dists_always_accepts() {
        let mut rng = Rng::new(5);
        let p = [0.25f32, 0.25, 0.25, 0.25];
        for _ in 0..200 {
            let x = sample(&p, &mut rng);
            let (acc, tok) = spec_accept(&p, &p, x, &mut rng);
            assert!(acc);
            assert_eq!(tok, x);
        }
    }
}
