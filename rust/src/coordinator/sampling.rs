//! Token selection: greedy argmax (the paper's evaluation setting,
//! temperature 0 — what the equivalence suite of DESIGN.md §6 pins)
//! plus full speculative sampling (Leviathan et al. / Chen et al.) for
//! the stochastic path, with the residual-distribution correction
//! property-tested for distribution preservation.
//!
//! Temperature 0 is routed to an EXACT first-max one-hot (not a tiny-
//! temperature softmax): ties must resolve to the same index `argmax`
//! picks, or the stochastic path at t=0 would diverge from greedy on
//! tied logits.  All CDF walks accumulate in f64 against the f64
//! uniform draw so tail mass never lands on the wrong bin.

use crate::substrate::rng::Rng;

/// Argmax over one logits row.
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as i32
}

/// Softmax with temperature into a probability vector.
///
/// `temperature <= 0` is the exact greedy limit: a one-hot at the FIRST
/// maximal index (argmax's tie rule).  A near-zero softmax instead
/// splits tied mass across every maximal index, which breaks the
/// temperature→0 ≡ greedy identity the equivalence suite asserts.
pub fn softmax(row: &[f32], temperature: f32) -> Vec<f32> {
    if row.is_empty() {
        return Vec::new();
    }
    if temperature <= 0.0 {
        let mut p = vec![0.0f32; row.len()];
        p[argmax(row) as usize] = 1.0;
        return p;
    }
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut p: Vec<f32> =
        row.iter().map(|&x| ((x - m) / temperature).exp()).collect();
    let s: f32 = p.iter().sum();
    for x in &mut p {
        *x /= s;
    }
    p
}

/// Nucleus (top-p) filter in place: keep the smallest probability-
/// sorted set whose cumulative mass reaches `top_p` (ties broken by
/// index so the kept set is deterministic), zero the rest, renormalize.
/// `top_p >= 1` is a no-op; `top_p <= 0` degenerates to top-1.
pub fn top_p_filter(p: &mut [f32], top_p: f32) {
    if top_p >= 1.0 || p.is_empty() {
        return;
    }
    let mut idx: Vec<usize> = (0..p.len()).collect();
    idx.sort_by(|&a, &b| p[b].total_cmp(&p[a]).then(a.cmp(&b)));
    let mut cum = 0.0f64;
    let mut keep = p.len();
    for (n, &i) in idx.iter().enumerate() {
        cum += p[i] as f64;
        if cum >= top_p as f64 {
            keep = n + 1;
            break;
        }
    }
    let mut kept = vec![false; p.len()];
    for &i in &idx[..keep] {
        kept[i] = true;
    }
    let mut s = 0.0f32;
    for (i, v) in p.iter_mut().enumerate() {
        if !kept[i] {
            *v = 0.0;
        } else {
            s += *v;
        }
    }
    for v in p.iter_mut() {
        *v /= s;
    }
}

/// The processed distribution of a logits row — temperature softmax
/// then nucleus filter.  This is the ONE distribution both draft
/// sampling and stochastic verification must use: the accept/residual
/// correction is lossless only when p and q pass through identical
/// processing (DESIGN.md §6).
pub fn dist(row: &[f32], temperature: f32, top_p: f32) -> Vec<f32> {
    let mut p = softmax(row, temperature);
    top_p_filter(&mut p, top_p);
    p
}

/// Inverse-CDF sample from a probability vector.  The CDF accumulates
/// in f64 — `u` is drawn at f64 precision, and an f32 accumulator can
/// misassign tail mass on near-degenerate distributions.  If rounding
/// still leaves `u` past the total mass, fall back to the LAST index
/// with nonzero probability (never a zero-probability token).
pub fn sample(p: &[f32], rng: &mut Rng) -> i32 {
    let u = rng.f64();
    let mut acc = 0.0f64;
    for (i, &pi) in p.iter().enumerate() {
        acc += pi as f64;
        if u < acc {
            return i as i32;
        }
    }
    p.iter().rposition(|&pi| pi > 0.0).unwrap_or(0) as i32
}

/// One speculative-sampling acceptance step (stochastic verification).
///
/// Given draft distribution `q`, target distribution `p`, and the drafted
/// token `x`: accept with prob min(1, p[x]/q[x]); on rejection resample
/// from the residual max(p-q, 0).  Returns (accepted, token) where
/// `token` is `x` if accepted else the residual sample — the classic
/// construction whose output provably follows `p` exactly.
///
/// `q[x] == 0` means the draft could not have proposed `x` (the pair
/// only arises from mismatched processing or a buggy caller); the limit
/// of min(1, p/q) is 1 when the target gives `x` mass and the step must
/// REJECT when it does not — force-accepting would emit a token outside
/// the target's support.
pub fn spec_accept(p: &[f32], q: &[f32], x: i32, rng: &mut Rng)
                   -> (bool, i32) {
    let xi = x as usize;
    let ratio = if q[xi] <= 0.0 {
        if p[xi] > 0.0 {
            1.0
        } else {
            0.0
        }
    } else {
        (p[xi] as f64 / q[xi] as f64).min(1.0)
    };
    if rng.f64() < ratio {
        return (true, x);
    }
    let mut resid: Vec<f32> = p
        .iter()
        .zip(q.iter())
        .map(|(&pi, &qi)| (pi - qi).max(0.0))
        .collect();
    let s: f32 = resid.iter().sum();
    if s <= 0.0 {
        // p <= q pointwise (p == q up to rounding); rejection can't
        // meaningfully occur, but guard by sampling the target itself.
        return (false, sample(p, rng));
    }
    for r in &mut resid {
        *r /= s;
    }
    (false, sample(&resid, rng))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::Cases;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0]), 0); // first wins ties
    }

    #[test]
    fn argmax_tie_breaking_is_lowest_index() {
        // Lossless verification depends on draft and target resolving
        // ties identically: the FIRST maximal index wins, everywhere.
        assert_eq!(argmax(&[1.0, 7.0, 7.0, 7.0]), 1);
        assert_eq!(argmax(&[0.0, 0.0, 0.0]), 0);
        assert_eq!(argmax(&[-1.0, -1.0, -0.5, -0.5]), 2);
    }

    #[test]
    fn argmax_degenerate_rows() {
        // Empty row: the documented fallback is index 0 (callers never
        // pass empty rows; this pins the behavior all the same).
        assert_eq!(argmax(&[]), 0);
        // All -inf still yields a valid index.
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        // -inf gaps don't confuse the scan.
        assert_eq!(argmax(&[f32::NEG_INFINITY, 2.0, f32::NEG_INFINITY]),
                   1);
        // Finite rows (the NaN-free contract engines rely on): the
        // maximum wins regardless of magnitude spread.
        assert_eq!(argmax(&[f32::MIN, 0.0, f32::MAX]), 2);
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_low_temp_is_sharp() {
        let p = softmax(&[1.0, 2.0, 3.0], 0.01);
        assert!(p[2] > 0.999);
    }

    #[test]
    fn softmax_t0_is_exact_first_max_one_hot() {
        // Regression: `temperature.max(1e-6)` used to make t=0 a tiny-
        // temperature softmax that splits TIED mass across all maximal
        // indices (here 0.5/0.5 on indices 1 and 3), diverging from
        // argmax's first-maximal-index rule.  t=0 must be the exact
        // one-hot at argmax.
        let p = softmax(&[1.0, 7.0, -2.0, 7.0], 0.0);
        assert_eq!(p, vec![0.0, 1.0, 0.0, 0.0]);
        assert_eq!(argmax(&[1.0, 7.0, -2.0, 7.0]), 1);
        // all-tied row: all mass on index 0
        let p = softmax(&[3.0, 3.0, 3.0], 0.0);
        assert_eq!(p, vec![1.0, 0.0, 0.0]);
        // and sampling a t=0 one-hot always returns argmax
        let mut rng = Rng::new(17);
        for _ in 0..100 {
            assert_eq!(sample(&softmax(&[1.0, 7.0, -2.0, 7.0], 0.0),
                              &mut rng),
                       1);
        }
    }

    #[test]
    fn top_p_keeps_nucleus_and_renormalizes() {
        // p = [0.5, 0.3, 0.2]; top_p=0.7 keeps {0, 1} (cum 0.8 >= 0.7)
        let mut p = vec![0.5f32, 0.3, 0.2];
        top_p_filter(&mut p, 0.7);
        assert_eq!(p[2], 0.0);
        assert!((p[0] - 0.625).abs() < 1e-6);
        assert!((p[1] - 0.375).abs() < 1e-6);
        // top_p=1.0 is a no-op; top_p=0 keeps exactly the max
        let mut q = vec![0.5f32, 0.3, 0.2];
        top_p_filter(&mut q, 1.0);
        assert_eq!(q, vec![0.5, 0.3, 0.2]);
        let mut r = vec![0.3f32, 0.5, 0.2];
        top_p_filter(&mut r, 0.0);
        assert_eq!(r, vec![0.0, 1.0, 0.0]);
        // tied probabilities: the LOWER index enters the nucleus first
        let mut t = vec![0.4f32, 0.4, 0.2];
        top_p_filter(&mut t, 0.4);
        assert_eq!(t, vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn dist_t0_ignores_top_p() {
        // the t=0 one-hot survives any nucleus cutoff unchanged
        let p = dist(&[1.0, 7.0, -2.0], 0.0, 0.3);
        assert_eq!(p, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn sample_respects_distribution() {
        let mut rng = Rng::new(11);
        let p = [0.1f32, 0.6, 0.3];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[sample(&p, &mut rng) as usize] += 1;
        }
        for i in 0..3 {
            let f = counts[i] as f32 / 30_000.0;
            assert!((f - p[i]).abs() < 0.02, "bin {i}: {f} vs {}", p[i]);
        }
    }

    #[test]
    fn sample_near_degenerate_never_picks_zero_mass() {
        // Regression for the f32-CDF bug: with almost all mass on bin 0
        // and a zero-probability tail bin, f32 accumulation rounding
        // (and the old unconditional `p.len()-1` fallback) could emit
        // the impossible token.  The f64 walk never does, over many
        // seeds and tail shapes.
        Cases::new(16).check("no-zero-mass-tokens", |rng| {
            let eps = 10f32.powi(-(3 + rng.below(30) as i32));
            let p = [1.0f32 - eps, eps, 0.0];
            for _ in 0..2_000 {
                let x = sample(&p, rng) as usize;
                assert!(x < 2, "sampled zero-probability bin");
            }
        });
    }

    #[test]
    fn sample_fallback_lands_on_last_nonzero() {
        // A distribution whose f32 entries undersum 1.0: the fallback
        // must land on the last index with mass, not blindly len()-1.
        let p = [0.5f32, 0.4999f32, 0.0, 0.0];
        let mut rng = Rng::new(23);
        for _ in 0..10_000 {
            assert!(sample(&p, &mut rng) < 2);
        }
    }

    /// The headline property: speculative sampling must reproduce the
    /// target distribution exactly, for ANY draft distribution —
    /// including drafts with zero-mass entries where the target has
    /// support (residual covers them) and targets with zero-mass
    /// entries the draft proposes (the tightened q[x]==0 / p[x]==0
    /// guard must reject, never force-accept).
    #[test]
    fn spec_sampling_preserves_target_distribution() {
        Cases::new(8).check("spec-preserves-p", |rng| {
            let n = 4 + rng.below(4);
            let mut p: Vec<f32> =
                (0..n).map(|_| rng.f64() as f32 + 0.01).collect();
            let mut q: Vec<f32> =
                (0..n).map(|_| rng.f64() as f32 + 0.01).collect();
            // knock holes in both supports: p[0] = 0 (q still proposes
            // it — exercises the reject-on-zero-target guard), q[1] = 0
            // (only the residual can produce it)
            p[0] = 0.0;
            q[1] = 0.0;
            let sp: f32 = p.iter().sum();
            let sq: f32 = q.iter().sum();
            p.iter_mut().for_each(|x| *x /= sp);
            q.iter_mut().for_each(|x| *x /= sq);
            let trials = 40_000;
            let mut counts = vec![0usize; n];
            for _ in 0..trials {
                let x = sample(&q, rng);
                let (_, tok) = spec_accept(&p, &q, x, rng);
                counts[tok as usize] += 1;
            }
            assert_eq!(counts[0], 0,
                       "emitted a token outside the target support");
            for i in 0..n {
                let f = counts[i] as f32 / trials as f32;
                assert!(
                    (f - p[i]).abs() < 0.025,
                    "bin {i}: got {f}, want {}",
                    p[i]
                );
            }
        });
    }

    #[test]
    fn spec_accept_rejects_zero_target_mass() {
        // Regression for the force-accept bug: q[x] == 0 used to set
        // the ratio to 1.0 unconditionally.  With p[x] == 0 too, the
        // step must reject and resample from the residual.
        let p = [0.0f32, 0.6, 0.4];
        let q = [0.0f32, 0.4, 0.6];
        let mut rng = Rng::new(31);
        for _ in 0..500 {
            let (acc, tok) = spec_accept(&p, &q, 0, &mut rng);
            assert!(!acc);
            assert_ne!(tok, 0);
        }
        // and with p[x] > 0 = q[x], the limit accepts
        let p2 = [0.5f32, 0.5];
        let q2 = [0.0f32, 1.0];
        let (acc, tok) = spec_accept(&p2, &q2, 0, &mut rng);
        assert!(acc);
        assert_eq!(tok, 0);
    }

    #[test]
    fn spec_accept_identical_dists_always_accepts() {
        let mut rng = Rng::new(5);
        let p = [0.25f32, 0.25, 0.25, 0.25];
        for _ in 0..200 {
            let x = sample(&p, &mut rng);
            let (acc, tok) = spec_accept(&p, &p, x, &mut rng);
            assert!(acc);
            assert_eq!(tok, x);
        }
    }

    #[test]
    fn spec_accept_t0_one_hots_reduce_to_greedy() {
        // The identity the engine-level suite relies on: with exact
        // one-hot p and q (temperature 0), spec_accept accepts iff the
        // candidate equals the target argmax, and a rejection's
        // residual resample IS the target argmax — token-for-token
        // greedy, regardless of rng draws.
        let mut rng = Rng::new(41);
        let hot = |i: usize| {
            let mut v = vec![0.0f32; 4];
            v[i] = 1.0;
            v
        };
        for _ in 0..200 {
            // agree: accept
            let (acc, tok) =
                spec_accept(&hot(2), &hot(2), 2, &mut rng);
            assert!(acc);
            assert_eq!(tok, 2);
            // disagree: reject, residual = target one-hot
            let (acc, tok) =
                spec_accept(&hot(1), &hot(2), 2, &mut rng);
            assert!(!acc);
            assert_eq!(tok, 1);
        }
    }
}
