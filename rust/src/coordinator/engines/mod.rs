//! Decode engines — the five generation strategies the paper compares.
//!
//! * [`ar`]:      AR  — uncached full-recompute baseline ("Transformers")
//!                AR+ — KV-cached autoregression ("Transformers+")
//! * [`vsd`]:     vanilla speculative decoding (K sequential draft passes)
//! * [`pard`]:    PARD — one parallel draft pass with shared MASK tokens
//! * [`eagle`]:   EAGLE-style target-dependent feature-chained draft
//!
//! All engines are *slot-oriented*: `admit` prefills a prompt into a
//! batch row, `step` advances every active row by one decode iteration.
//! The continuous batcher (`coordinator::batcher`) refills finished slots
//! between steps; closed-batch evaluation just admits B prompts and steps
//! until idle.
//!
//! Per-row work inside a fixed-batch executable is expressed purely
//! through (tokens, pos, commit_pos) layouts: parked rows write to the
//! reserved garbage slot and their outputs are ignored (DESIGN.md §7).
//!
//! Engines drive models only through the [`Backend`] trait, so the same
//! code executes against AOT/PJRT artifacts or the pure-Rust reference
//! backend (DESIGN.md §2) — the engine-equivalence suite relies on
//! this.

pub mod ar;
pub mod eagle;
pub mod pard;
pub mod vsd;

use anyhow::Result;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::PolicyCfg;
use crate::coordinator::sampling::{argmax, dist, sample, spec_accept};
use crate::coordinator::sequence::Sequence;
use crate::runtime::{Backend, KvCache, Runtime};
use crate::substrate::bench::stopwatch;
use crate::substrate::fault::{FaultSet, MAX_TARGET_RETRIES};
use crate::substrate::rng::Rng;

/// Shared inference-time configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub kind: EngineKind,
    pub target: String,
    /// Draft model (VSD: an AR family member; PARD: an adapted variant;
    /// EAGLE: the head).  None for AR/AR+.
    pub draft: Option<String>,
    pub batch: usize,
    /// K_infer: candidates drafted per iteration.
    pub k: usize,
    pub max_new: usize,
    /// Shared-mask strategy (paper §4.3): true = single <mask> id
    /// (enables K_infer > K_train extrapolation).
    pub shared_mask: bool,
    /// Prefix sharing across requests (`--prefix-cache`, DESIGN.md
    /// §7): released rows keep their full committed KV blocks cached,
    /// and `admit` maps the longest cached block-aligned prompt prefix
    /// into the new row, prefilling only the uncached suffix.
    /// Bit-identical outputs; host-side paged caches only.
    pub prefix_cache: bool,
    /// Block count of each KV cache's paged pool (`--kv-blocks`,
    /// DESIGN.md §7).  `None` keeps capacity parity with the dense
    /// layout (every row can grow to `S_max`); an explicit size turns
    /// on memory-bounded admission — the batcher then gates new
    /// sequences on free blocks instead of free slots alone.
    pub kv_blocks: Option<usize>,
    /// Stochastic decoding (`--temperature`/`--top-p`/`--sample-seed`).
    /// `None` = greedy argmax everywhere (the paper's evaluation
    /// setting and the default).  `Some` routes every engine through
    /// seeded sampling: AR/AR+ sample the target distribution, the
    /// speculative engines sample their drafts and verify with the
    /// Leviathan accept/residual correction — losslessly, and token-
    /// identical to greedy at temperature 0 (DESIGN.md §6).
    pub sampling: Option<SamplingCfg>,
    /// Speculation policy (`--policy`/`--k-min`/`--k-max`/
    /// `--policy-window`/`--dual-mode-occupancy`, DESIGN.md §9).  The
    /// default fixed policy drafts exactly `k` every step — the
    /// pre-policy behavior, token for token.  The adaptive policy
    /// retunes each row's K from its windowed accept rate and can
    /// degrade the whole batch to AR+ under high occupancy; inert for
    /// AR/AR+ (see `router::build_policy`).
    pub policy: PolicyCfg,
}

/// Stochastic-decoding knobs, shared by draft and verify: both sides
/// MUST process logits identically or the accept/residual correction
/// loses the losslessness guarantee.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingCfg {
    /// Softmax temperature; 0 = exact greedy limit (first-max one-hot).
    pub temperature: f32,
    /// Nucleus cutoff in (0, 1]; 1 disables the filter.
    pub top_p: f32,
    /// Base seed of the per-sequence rng streams.
    pub seed: u64,
}

/// Which of the five decode strategies an engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Ar,
    ArPlus,
    Vsd,
    Pard,
    Eagle,
}

impl EngineKind {
    /// Parse a CLI engine name (`ar|ar+|vsd|pard|eagle`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "ar" => EngineKind::Ar,
            "ar+" | "arplus" => EngineKind::ArPlus,
            "vsd" => EngineKind::Vsd,
            "pard" => EngineKind::Pard,
            "eagle" => EngineKind::Eagle,
            _ => anyhow::bail!("unknown engine `{s}` \
                                (ar|ar+|vsd|pard|eagle)"),
        })
    }

    /// Stable display name used in reports and logs.
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Ar => "AR",
            EngineKind::ArPlus => "AR+",
            EngineKind::Vsd => "VSD",
            EngineKind::Pard => "PARD",
            EngineKind::Eagle => "EAGLE",
        }
    }
}

/// One fwd call's (tokens, positions, commit positions) layout.
pub struct CallBuf {
    pub b: usize,
    pub t: usize,
    pub tokens: Vec<i32>,
    pub pos: Vec<i32>,
    pub cpos: Vec<i32>,
}

impl CallBuf {
    /// Fully parked: every cell is a PAD query at the garbage slot with a
    /// garbage commit — harmless by the slot contract.
    pub fn parked(b: usize, t: usize, pad: i32, garbage: i32) -> Self {
        CallBuf {
            b,
            t,
            tokens: vec![pad; b * t],
            pos: vec![garbage; b * t],
            cpos: vec![garbage; b * t],
        }
    }

    /// Place `tok` for `row` at column `i`, position `p`; commit the KV
    /// to `p` iff `commit` (else it goes to the garbage slot).
    pub fn set(&mut self, row: usize, i: usize, tok: i32, p: i32,
               commit: bool) {
        debug_assert!(i < self.t);
        let idx = row * self.t + i;
        self.tokens[idx] = tok;
        self.pos[idx] = p;
        if commit {
            self.cpos[idx] = p;
        }
    }
}

/// The engine interface driven by evaluators and the batcher.
pub trait Engine {
    fn kind(&self) -> EngineKind;
    fn batch(&self) -> usize;
    /// Prefill `prompt` into batch row `slot` (resets the slot and
    /// reserves its worst-case KV blocks; fails when the paged pool
    /// cannot cover the reservation — check [`Engine::can_admit`]
    /// first under memory-bounded admission).
    fn admit(&mut self, slot: usize, prompt: &[i32], max_new: usize)
             -> Result<()>;
    /// One decode iteration over all active slots.
    fn step(&mut self) -> Result<()>;
    fn seqs(&self) -> &[Sequence];
    fn seqs_mut(&mut self) -> &mut [Sequence];
    fn metrics(&self) -> &Metrics;
    fn metrics_mut(&mut self) -> &mut Metrics;
    /// Pre-compile the executables `step` will need so JIT never lands in
    /// the measured loop.
    fn warmup(&mut self) -> Result<()>;

    /// Memory-bounded admission gate (DESIGN.md §7): would `admit` of
    /// this prompt succeed right now without exhausting the KV block
    /// pools?  Engines with paged caches answer from their pools'
    /// unreserved headroom — under prefix sharing a prompt whose
    /// prefix is cached needs only its uncached remainder, so the gate
    /// takes the prompt tokens, not just a length.  The default
    /// (backend-less fakes, dense device caches) admits freely.
    fn can_admit(&self, prompt: &[i32], max_new: usize) -> bool {
        let _ = (prompt, max_new);
        true
    }

    /// Return batch row `slot`'s KV blocks to the pool after its
    /// sequence completes (the batcher calls this at harvest so freed
    /// memory is admittable before the next refill).  No-op by
    /// default.
    fn release(&mut self, slot: usize) {
        let _ = slot;
    }

    fn any_active(&self) -> bool {
        self.seqs().iter().any(|s| s.active && !s.done)
    }

    /// Arm the next `step` with an injected fault set (DESIGN.md
    /// §10).  The set is consumed by that step's prologue
    /// ([`fault_prologue`]); the default ignores it, so fakes and
    /// fault-free paths cost nothing.
    fn inject_faults(&mut self, faults: FaultSet) {
        let _ = faults;
    }

    /// Refresh the KV-occupancy gauges in `metrics`.  Engines with
    /// paged caches override; the serving loops call this after the
    /// final harvest so `kv_blocks_in_use` reflects the drained pool
    /// rather than the last mid-step observation.
    fn observe_kv(&mut self) {}
}

/// Construct the engine `cfg` names, its speculation policy bound
/// and validated (DESIGN.md §9).
pub fn build_engine(rt: &Runtime, cfg: &EngineConfig)
                    -> Result<Box<dyn Engine>> {
    anyhow::ensure!(cfg.k >= 1 && cfg.k <= 16, "k must be in 1..=16");
    // Bind the speculation policy up front: knobs are validated for
    // every kind, AR kinds get the inert fixed policy (they never
    // draft), and the drafting engines size their reservations and
    // warmup shapes by the policy's k_cap.
    let policy = crate::coordinator::router::build_policy(cfg)?;
    match cfg.kind {
        EngineKind::Ar => Ok(Box::new(ar::ArEngine::new(rt, cfg, false)?)),
        EngineKind::ArPlus => {
            Ok(Box::new(ar::ArEngine::new(rt, cfg, true)?))
        }
        EngineKind::Vsd => {
            Ok(Box::new(vsd::VsdEngine::new(rt, cfg, policy)?))
        }
        EngineKind::Pard => {
            Ok(Box::new(pard::PardEngine::new(rt, cfg, policy)?))
        }
        EngineKind::Eagle => {
            Ok(Box::new(eagle::EagleEngine::new(rt, cfg, policy)?))
        }
    }
}

// ---------------------------------------------------------------------------
// Shared building blocks
// ---------------------------------------------------------------------------

/// What an engine's `step` does with its armed fault set.
pub enum FaultAction {
    /// Run the iteration.  `force_k0` degrades every row's drafting
    /// to K=0 — the lossless AR+ commit path (DESIGN.md §9/§10).
    Proceed { force_k0: bool },
    /// Commit nothing this iteration: either a persistent target
    /// incident just failed its victim row, or a sampled-mode draft
    /// fault holds the batch so no per-row rng stream advances.
    Skip,
}

/// Shared `step` prologue: resolve the iteration's injected faults
/// BEFORE any engine state (rng streams, caches, sequences) mutates,
/// so every recovery path is bit-safe for the surviving rows.
///
/// * worker fault — panic with the worker pool's own poison message.
///   The pool catches task panics per-worker and re-raises at
///   dispatch drain (`runtime::pool`), which is exactly this shape;
///   the serving loop catches it, counts a rebuild, and retries the
///   step (the armed set was already consumed, so the retry is
///   clean).
/// * target fault — `fails` attempts fail, each charged one wasted
///   pass unit on the costed clock.  Within the retry budget
///   ([`MAX_TARGET_RETRIES`]) the pass then succeeds (`row_retries`);
///   past it the incident is persistent: the victim row (chosen by
///   admission-order index modulo the live count, so batch-layout
///   independent) is failed (`rows_failed`) and the iteration is
///   skipped — innocent rows are merely delayed.
/// * draft fault — the draft pass is lost (`draft_fallbacks`, one
///   wasted draft pass unit).  Greedy decoding degrades to a K=0
///   AR+ commit, which is token-identical by the dual-mode argument;
///   sampled decoding instead HOLDS the iteration, because a K=0
///   commit would consume different per-row rng draws than the
///   fault-free run (DESIGN.md §10).
///
/// `draft_params` is `None` for engines without a draft path (AR,
/// AR+), which therefore never see draft fallbacks.
pub fn fault_prologue(faults: FaultSet, seqs: &mut [Sequence],
                      sampled: bool, draft_params: Option<usize>,
                      target_params: usize, metrics: &mut Metrics)
                      -> FaultAction {
    if faults.worker {
        panic!("host worker-pool task panicked");
    }
    if let Some(t) = faults.target {
        let live: Vec<usize> = seqs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.active && !s.done)
            .map(|(i, _)| i)
            .collect();
        if !live.is_empty() {
            if t.fails > MAX_TARGET_RETRIES {
                // Persistent: the initial attempt plus every retry
                // failed.  Fail the victim row only.
                for _ in 0..=MAX_TARGET_RETRIES {
                    metrics.record_work(target_params, 0);
                }
                metrics.row_retries += MAX_TARGET_RETRIES;
                let victim =
                    live[(t.victim % live.len() as u64) as usize];
                let seq = &mut seqs[victim];
                seq.failed = true;
                seq.done = true;
                seq.active = false;
                metrics.rows_failed += 1;
                return FaultAction::Skip;
            }
            // Transient: `fails` wasted attempts, then success.
            for _ in 0..t.fails {
                metrics.record_work(target_params, 0);
            }
            metrics.row_retries += t.fails;
        }
    }
    if let (true, Some(dp)) = (faults.draft, draft_params) {
        metrics.draft_fallbacks += 1;
        metrics.record_work(dp, 0);
        if sampled {
            return FaultAction::Skip;
        }
        return FaultAction::Proceed { force_k0: true };
    }
    FaultAction::Proceed { force_k0: false }
}

/// Worst-case logical slots a sequence can commit across its lifetime:
/// the full stream (`prompt + max_new` plus the pending token) and the
/// deepest speculative tail any engine writes past it (`k` tentative
/// candidate commits).  [`KvCache::blocks_for`] caps this at the
/// logical window, so the per-row reservation is always finite; the
/// engines reserve exactly this much at `admit`, which is what makes
/// pool backpressure preemption-free — an admitted row can never run
/// dry mid-decode (DESIGN.md §7).
pub fn reserve_len(prompt_len: usize, max_new: usize, k: usize)
                   -> usize {
    prompt_len + max_new + k + 2
}

/// Prefill one slot of a (possibly multi-row) cache: feeds the prompt
/// from token `start` on (tokens before `start` are already committed —
/// a prefix-cache hit mapped their blocks into the row), commits the
/// suffix KV, and returns (last-position logits row, last-row hidden if
/// the model exports it).  The caller turns the logits into the first
/// generated token via [`next_token`] — greedy or sampled, its choice.
/// `start = 0` is the full dense-era prefill.
/// The suffix attends the cached prefix through the block table, so
/// the result is bit-identical to a full prefill (the cached-decode
/// identity, DESIGN.md §6).
/// Minimum prefill bucket: task prompts are < 32 tokens by
/// construction, so one stable executable serves their prefills (no
/// mid-run JIT).  Shared-prefix workloads (`--shared-prefix`) prepend
/// a system prompt and can exceed it — `pick_t` then sizes up, which
/// is exact-T (free) on the host/reference backends; a PJRT bucket
/// tuner must account for `prefix_len + tail` shapes.
pub const PREFILL_T: usize = 32;

/// Prefill one slot per the narrative above ([`PREFILL_T`]): feed
/// `prompt[start..]`, commit its KV, and return the last position's
/// logits row (+ hidden when the model exports it).
pub fn prefill_slot(model: &dyn Backend, cache: &mut KvCache, slot: usize,
                    prompt: &[i32], start: usize, pad: i32,
                    metrics: &mut Metrics)
                    -> Result<(Vec<f32>, Option<Vec<f32>>)> {
    debug_assert!(start < prompt.len(),
                  "prefix hits always leave a suffix to prefill");
    let b = cache.batch;
    let suffix = &prompt[start..];
    let t = model.pick_t(b, suffix.len().max(PREFILL_T))?;
    let garbage = cache.garbage_slot();
    let mut buf = CallBuf::parked(b, t, pad, garbage);
    for (i, &tok) in suffix.iter().enumerate() {
        buf.set(slot, i, tok, (start + i) as i32, true);
    }
    let t0 = stopwatch();
    let out = model.fwd(b, t, &buf.tokens, &buf.pos, None, cache)?;
    metrics.record_fwd(&out);
    metrics.record_work(model.n_params(), suffix.len());
    metrics.commit_s += model.commit(b, t, &out, &buf.cpos, cache)?;
    metrics.prefill_s += t0.elapsed().as_secs_f64();
    metrics.target_passes += 1;
    cache.cur_len[slot] = prompt.len() as u32;
    let vocab = model.cfg().vocab;
    let last = suffix.len() - 1;
    let row = out.logits
        [(slot * t + last) * vocab..(slot * t + last + 1) * vocab]
        .to_vec();
    let hidden = out.hidden.as_ref().map(|h| {
        let d = model.cfg().d_model;
        h[(slot * t + last) * d..(slot * t + last + 1) * d].to_vec()
    });
    Ok((row, hidden))
}

/// Seed row-local sampling state at admission: sequence `ordinal` (the
/// engine's FCFS admission counter) gets its own rng substream, so
/// sampled output depends only on (sample_seed, admission order) — not
/// batch size or slot assignment.  No-op under greedy decoding.
pub fn seed_sequence_rng(seq: &mut Sequence,
                         sampling: Option<&SamplingCfg>, ordinal: u64) {
    if let Some(s) = sampling {
        seq.rng = Some(Rng::new_stream(s.seed, ordinal));
    }
}

/// Turn a logits row into the next committed token: greedy argmax by
/// default, a temperature/top-p sample from the processed distribution
/// when the engine decodes stochastically (AR/AR+ target steps, prefill
/// first tokens).
pub fn next_token(row: &[f32], sampling: Option<&SamplingCfg>,
                  rng: Option<&mut Rng>) -> i32 {
    match (sampling, rng) {
        (Some(s), Some(rng)) => {
            sample(&dist(row, s.temperature, s.top_p), rng)
        }
        _ => argmax(row),
    }
}

/// Draft-side candidate selection: greedy argmax, or a sample from the
/// processed draft distribution, which is then RETAINED on `qrow` —
/// stochastic verification needs q exactly as the candidate was sampled
/// from it ([`spec_accept`]'s contract).
pub fn draft_token(row: &[f32], sampling: Option<&SamplingCfg>,
                   rng: Option<&mut Rng>, qrow: &mut Vec<Vec<f32>>)
                   -> i32 {
    match (sampling, rng) {
        (Some(s), Some(rng)) => {
            let q = dist(row, s.temperature, s.top_p);
            let tok = sample(&q, rng);
            qrow.push(q);
            tok
        }
        _ => argmax(row),
    }
}

/// Pure greedy acceptance (chain decoding, temperature 0): `preds[j]` is
/// the target argmax at verify row j (row 0 = the pending token's row).
/// Returns (accepted_count, committed = accepted candidates + correction).
///
/// The lossless-decoding property (speculative output == plain AR output)
/// reduces to this function — property-tested in tests/spec_equivalence.
pub fn greedy_accept(cands: &[i32], preds: &[i32]) -> (usize, Vec<i32>) {
    debug_assert!(preds.len() >= cands.len() + 1);
    let mut accepted = 0usize;
    let mut committed = Vec::with_capacity(cands.len() + 1);
    for (j, &c) in cands.iter().enumerate() {
        if c == preds[j] {
            accepted += 1;
            committed.push(c);
        } else {
            break;
        }
    }
    committed.push(preds[accepted]);
    (accepted, committed)
}

/// Outcome of one verify call for one row.
pub struct RowVerdict {
    pub accepted: usize,
    /// accepted candidates ++ correction token.
    pub committed: Vec<i32>,
    /// Hidden rows for [pending, c_0..c_{K-1}] when the target exports
    /// hidden states (EAGLE).
    pub hidden_rows: Option<Vec<Vec<f32>>>,
}

/// Per-call verification parameters: the engine's candidate depth and
/// pad token, plus the verdict mode.  `sampling == None` is pure greedy
/// acceptance; `Some` switches every row to the distribution-aware
/// path, for which `qdists[row][j]` must hold the processed draft
/// distribution candidate j of that row was sampled from (leave the
/// slice empty under greedy).
pub struct VerifySpec<'a> {
    pub k: usize,
    pub pad: i32,
    pub sampling: Option<SamplingCfg>,
    pub qdists: &'a [Vec<Vec<f32>>],
}

/// Shared verification: feed `[pending, c_0..c_{K-1}]` per active row,
/// accept a candidate prefix, commit pending + accepted KV, and return
/// per-row verdicts.  Two verdict paths share the call:
///
/// * greedy (chain decoding, temperature 0 — the paper's evaluation
///   setting): accept the longest prefix matching the target argmax;
///   the correction token is the argmax at the break point.
/// * stochastic ([`VerifySpec::sampling`] set): per position, accept
///   drafted token x with prob min(1, p[x]/q[x]) via [`spec_accept`]
///   using the row's private rng; the first rejection commits a
///   residual resample instead, and a fully-accepting row commits a
///   bonus token sampled from the target's K-th distribution.  Output
///   provably follows the target distribution (lossless), and reduces
///   token-for-token to the greedy path at temperature 0.
///
/// Both paths commit `accepted + 1` tokens, so [`apply_verdict`] and
/// the slot protocol are verdict-mode agnostic.
pub fn verify_and_commit(target: &dyn Backend, cache: &mut KvCache,
                         seqs: &mut [Sequence], cands: &[Vec<i32>],
                         spec: &VerifySpec, metrics: &mut Metrics)
                         -> Result<Vec<Option<RowVerdict>>> {
    let b = cache.batch;
    let t = target.pick_t(b, spec.k + 1)?;
    let garbage = cache.garbage_slot();
    let mut buf = CallBuf::parked(b, t, spec.pad, garbage);
    let mut cols = 0usize;
    for (row, seq) in seqs.iter().enumerate() {
        if !seq.active || seq.done {
            continue;
        }
        let base = seq.target_len as i32;
        buf.set(row, 0, seq.pending(), base, true);
        cols += 1 + cands[row].len();
        for (j, &c) in cands[row].iter().enumerate() {
            // tentative: commit decided after acceptance
            buf.set(row, 1 + j, c, base + 1 + j as i32, false);
        }
    }
    let t0 = stopwatch();
    let out = target.fwd(b, t, &buf.tokens, &buf.pos, None, cache)?;
    metrics.record_fwd(&out);
    metrics.record_work(target.n_params(), cols);
    metrics.target_passes += 1;

    let vocab = target.cfg().vocab;
    let d = target.cfg().d_model;
    let mut verdicts: Vec<Option<RowVerdict>> = Vec::with_capacity(b);
    for (row, seq) in seqs.iter_mut().enumerate() {
        if !seq.active || seq.done {
            verdicts.push(None);
            continue;
        }
        let base = seq.target_len as i32;
        let logit_row = |i: usize| {
            &out.logits[(row * t + i) * vocab..(row * t + i + 1) * vocab]
        };
        let n = cands[row].len();
        let (accepted, committed) = match spec.sampling {
            None => {
                let preds: Vec<i32> =
                    (0..=n).map(|i| argmax(logit_row(i))).collect();
                greedy_accept(&cands[row], &preds)
            }
            Some(s) => {
                let rng = seq.rng.as_mut().expect(
                    "stochastic verify needs a seeded per-row rng",
                );
                let q = &spec.qdists[row];
                debug_assert_eq!(q.len(), n,
                                 "one draft distribution per candidate");
                let mut accepted = 0usize;
                let mut committed = Vec::with_capacity(n + 1);
                for (j, &c) in cands[row].iter().enumerate() {
                    let p = dist(logit_row(j), s.temperature, s.top_p);
                    let (ok, tok) = spec_accept(&p, &q[j], c, rng);
                    committed.push(tok);
                    if !ok {
                        metrics.residual_resamples += 1;
                        break;
                    }
                    accepted += 1;
                }
                if accepted == n {
                    let p = dist(logit_row(n), s.temperature, s.top_p);
                    committed.push(sample(&p, rng));
                    metrics.bonus_samples += 1;
                }
                (accepted, committed)
            }
        };
        for j in 0..accepted {
            // accepted candidate's KV is valid: commit it
            buf.cpos[row * t + 1 + j] = base + 1 + j as i32;
        }
        // Hidden rows for [pending, c_0..]: clamp to the row's actual
        // candidate count — columns past it are parked PAD cells whose
        // hidden is garbage by contract (today every engine drafts
        // exactly `k` candidates per active row, but a short-drafting
        // row must not hand parked-cell junk to EAGLE's feature chain).
        let hidden_rows = out.hidden.as_ref().map(|h| {
            (0..=cands[row].len().min(t - 1))
                .map(|i| {
                    h[(row * t + i) * d..(row * t + i + 1) * d].to_vec()
                })
                .collect()
        });
        metrics.record_acceptance(cands[row].len(), accepted);
        verdicts.push(Some(RowVerdict { accepted, committed, hidden_rows }));
    }
    metrics.commit_s += target.commit(b, t, &out, &buf.cpos, cache)?;
    metrics.verify_s += t0.elapsed().as_secs_f64();

    Ok(verdicts)
}

/// Apply a verdict to the sequence + target cache bookkeeping.  `k` is
/// the engine's configured candidate depth — the headroom guard below
/// must track it, not a worst-case constant.
pub fn apply_verdict(seq: &mut Sequence, cache: &mut KvCache, row: usize,
                     verdict: &RowVerdict, k: usize, eos: i32,
                     metrics: &mut Metrics) {
    let taken = seq.push_committed(&verdict.committed, eos);
    metrics.generated += taken as u64;
    seq.target_len = seq.stream.len() - 1;
    cache.cur_len[row] = seq.target_len as u32;
    if seq.done {
        seq.active = false;
        metrics.requests += 1;
        return;
    }
    // Cache headroom guard: stop rows whose next iteration could
    // overflow the window.  The deepest position a verify touches is
    // `target_len + k` (pending + K candidates), guarded with the same
    // `k + 2` tail `reserve_len` reserves — NOT a hardcoded worst-case
    // K, which parked small-K rows up to 30 positions early.
    if seq.target_len as u32 + k as u32 + 2 >= cache.max_live_pos() {
        seq.done = true;
        seq.active = false;
        metrics.requests += 1;
    }
}

/// Closed-batch generation: admit up to `batch` prompts at a time, step
/// until all prompts drain (slots are refilled as they finish — simple
/// continuous batching).  Returns per-prompt generated tokens.
pub fn generate(engine: &mut dyn Engine, prompts: &[Vec<i32>],
                max_new: usize) -> Result<Vec<Vec<i32>>> {
    let b = engine.batch();
    let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
    let mut next = 0usize;
    let mut slot_owner: Vec<Option<usize>> = vec![None; b];
    let t0 = stopwatch();
    loop {
        // refill idle slots (releasing finished rows' KV blocks first
        // so their memory is admittable in the same pass)
        for slot in 0..b {
            let idle = match slot_owner[slot] {
                Some(o) => {
                    let s = &engine.seqs()[slot];
                    if s.done {
                        outputs[o] = s.gen_tokens().to_vec();
                        engine.release(slot);
                        true
                    } else {
                        false
                    }
                }
                None => true,
            };
            if idle {
                slot_owner[slot] = None;
                if next < prompts.len()
                    && engine.can_admit(&prompts[next], max_new)
                {
                    engine.admit(slot, &prompts[next], max_new)?;
                    slot_owner[slot] = Some(next);
                    next += 1;
                }
            }
        }
        if !engine.any_active() {
            // A prompt that cannot be admitted into an EMPTY engine
            // can never run: fail loudly instead of spinning.
            anyhow::ensure!(
                next >= prompts.len(),
                "prompt {next} needs more KV blocks than the whole \
                 pool holds — raise --kv-blocks"
            );
            break;
        }
        engine.step()?;
        engine.metrics_mut().iterations += 1;
    }
    engine.metrics_mut().wall_s += t0.elapsed().as_secs_f64();
    Ok(outputs)
}
