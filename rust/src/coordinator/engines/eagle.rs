//! EAGLE-style baseline: target-dependent draft head chained on the
//! target's hidden features.
//!
//! The head consumes `[target_hidden ; token_embedding]` (one decoder
//! layer).  Drafting is autoregressive at the feature level: step j feeds
//! the head its OWN hidden output from step j-1 (EAGLE's feature
//! self-regression), so the draft phase still costs K passes — which is
//! exactly the bandwidth profile Table 6 contrasts with PARD.
//!
//! Approximation noted in DESIGN.md §3: the pending (correction) token's
//! true target hidden is not yet computed at draft time, so its catch-up
//! pair uses the hidden of the row that *predicted* it.
//!
//! Verification runs on the `_h` variant of the target so every verify
//! also yields the hidden rows the next iteration's catch-up needs.

use std::rc::Rc;

use anyhow::Result;

use super::{apply_verdict, draft_token, fault_prologue, next_token,
            reserve_len, seed_sequence_rng, verify_and_commit, CallBuf,
            Engine, EngineConfig, EngineKind, FaultAction, VerifySpec};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::SpecPolicy;
use crate::coordinator::sequence::Sequence;
use crate::runtime::{Backend, KvCache, Runtime};
use crate::substrate::bench::stopwatch;
use crate::substrate::fault::FaultSet;

/// EAGLE-style engine: a target-feature-chained draft head speculates,
/// the target verifies (DESIGN.md §5).
pub struct EagleEngine {
    /// `_h` variant: exports hidden rows at verify/prefill.
    target: Rc<dyn Backend>,
    head: Rc<dyn Backend>,
    tcache: KvCache,
    ecache: KvCache,
    seqs: Vec<Sequence>,
    metrics: Metrics,
    cfg: EngineConfig,
    pad: i32,
    eos: i32,
    d_model: usize,
    /// FCFS admission counter — keys per-sequence sampling streams.
    admitted: u64,
    /// Speculation controller: plans each row's K per step
    /// (DESIGN.md §9); reservations/warmup are sized by its k_cap.
    policy: SpecPolicy,
    /// Faults armed for the next step (DESIGN.md §10).
    faults: FaultSet,
}

impl EagleEngine {
    /// Build the hidden-exporting target variant plus its draft head.
    pub fn new(rt: &Runtime, cfg: &EngineConfig, policy: SpecPolicy)
               -> Result<Self> {
        // the hidden-exporting variant of the target
        let tname = format!("{}_h", cfg.target);
        let target = rt.model(&tname)?;
        let head_name = cfg
            .draft
            .clone()
            .unwrap_or_else(|| format!("eagle-{}", cfg.target));
        let head = rt.model(&head_name)?;
        anyhow::ensure!(head.cfg().d_model == target.cfg().d_model,
                        "EAGLE head/target width mismatch");
        let mut tcache = target.new_cache_sized(cfg.batch, cfg.kv_blocks)?;
        let ecache = head.new_cache_sized(cfg.batch, cfg.kv_blocks)?;
        // Only the target cache shares prefixes.  The head cache opts
        // out: its backlog protocol re-feeds the whole prompt through
        // the first catch-up pass anyway (head K/V depend on target
        // hiddens, which admit must recompute for the backlog), so a
        // mapped prefix would only be COW-copied straight back.
        tcache.set_prefix_sharing(cfg.prefix_cache);
        Ok(EagleEngine {
            d_model: target.cfg().d_model,
            target,
            head,
            tcache,
            ecache,
            seqs: vec![Sequence::default(); cfg.batch],
            metrics: Metrics::default(),
            cfg: cfg.clone(),
            pad: rt.manifest.pad,
            eos: rt.manifest.eos,
            admitted: 0,
            policy,
            faults: FaultSet::default(),
        })
    }

    /// Record both pools' occupancy + prefix-sharing stats into the
    /// metrics gauges (the head cache never shares — see `new`).
    fn note_kv(&mut self) {
        self.metrics.record_kv_blocks(
            self.tcache.blocks_in_use() + self.ecache.blocks_in_use());
        self.metrics.record_prefix_stats(
            self.tcache.prefix_hit_tokens(),
            self.tcache.blocks_shared(),
            self.tcache.cow_copies());
    }

    /// Draft `ks[row]` candidates per row the policy planned K >= 1
    /// for: one catch-up pass over the backlog pairs, then
    /// feature-chained singles.  Returns per-row candidates plus,
    /// under stochastic decoding, the head distribution each was
    /// sampled from (rows stay empty under greedy).
    ///
    /// Rows with `ks[row] == 0` (dual-mode AR+ degrade) skip drafting
    /// AND keep their backlog: the pairs not yet fed to the head cache
    /// must survive until the row drafts again (`step` extends the
    /// backlog with newly committed pairs).  If no row drafts, no head
    /// pass runs at all.
    #[allow(clippy::type_complexity)]
    fn draft_candidates(&mut self, ks: &[usize])
                        -> Result<(Vec<Vec<i32>>, Vec<Vec<Vec<f32>>>)> {
        let b = self.ecache.batch;
        let sp = self.cfg.sampling;
        let d = self.d_model;
        let garbage = self.ecache.garbage_slot();
        let vocab = self.head.cfg().vocab;
        let mut cands: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut qdists: Vec<Vec<Vec<f32>>> = vec![Vec::new(); b];
        // chained state per row: (token, pos, hidden)
        let mut chain: Vec<Option<(i32, i32, Vec<f32>)>> = vec![None; b];

        let drafting =
            |row: usize, s: &Sequence| s.active && !s.done && ks[row] > 0;
        // (1) catch-up over backlog pairs.
        let need = self
            .seqs
            .iter()
            .enumerate()
            .filter(|(row, s)| drafting(*row, s))
            .map(|(_, s)| s.eagle_backlog.len())
            .max();
        let Some(need) = need else {
            return Ok((cands, qdists));
        };
        let t = self.head.pick_t(b, need.max(1))?;
        let mut buf = CallBuf::parked(b, t, self.pad, garbage);
        let mut hidden_in = vec![0f32; b * t * d];
        let mut cols = 0usize;
        for (row, seq) in self.seqs.iter().enumerate() {
            if !drafting(row, seq) {
                continue;
            }
            cols += seq.eagle_backlog.len();
            for (i, (tok, p, h)) in seq.eagle_backlog.iter().enumerate() {
                buf.set(row, i, *tok, *p, true);
                hidden_in[(row * t + i) * d..(row * t + i + 1) * d]
                    .copy_from_slice(h);
            }
        }
        let t0 = stopwatch();
        let out = self.head.fwd(b, t, &buf.tokens, &buf.pos,
                                Some(&hidden_in), &self.ecache)?;
        self.metrics.record_fwd(&out);
        self.metrics.record_work(self.head.n_params(), cols);
        self.metrics.commit_s +=
            self.head.commit(b, t, &out, &buf.cpos, &mut self.ecache)?;
        self.metrics.draft_passes += 1;
        let head_hidden = out
            .hidden
            .as_ref()
            .expect("eagle head exports hidden");
        for (row, seq) in self.seqs.iter_mut().enumerate() {
            if !(seq.active && !seq.done && ks[row] > 0) {
                continue;
            }
            let fed = seq.eagle_backlog.len();
            let i = fed - 1;
            let lg = &out.logits
                [(row * t + i) * vocab..(row * t + i + 1) * vocab];
            let c0 = draft_token(lg, sp.as_ref(), seq.rng.as_mut(),
                                 &mut qdists[row]);
            cands[row].push(c0);
            let h = head_hidden[(row * t + i) * d..(row * t + i + 1) * d]
                .to_vec();
            let last_pos = seq.eagle_backlog[fed - 1].1;
            chain[row] = Some((c0, last_pos + 1, h));
            seq.eagle_backlog.clear();
        }

        // (2) feature-chained singles: pass j only carries the rows
        // still short of their planned K.
        let max_k = ks.iter().copied().max().unwrap_or(0);
        for j in 1..max_k {
            let mut buf = CallBuf::parked(b, 1, self.pad, garbage);
            let mut hidden_in = vec![0f32; b * d];
            let mut cols = 0usize;
            for (row, seq) in self.seqs.iter().enumerate() {
                if !drafting(row, seq) || ks[row] <= j {
                    continue;
                }
                if let Some((tok, p, h)) = &chain[row] {
                    cols += 1;
                    buf.set(row, 0, *tok, *p, true);
                    hidden_in[row * d..(row + 1) * d].copy_from_slice(h);
                }
            }
            let out = self.head.fwd(b, 1, &buf.tokens, &buf.pos,
                                    Some(&hidden_in), &self.ecache)?;
            self.metrics.record_fwd(&out);
            self.metrics.record_work(self.head.n_params(), cols);
            self.metrics.commit_s +=
                self.head.commit(b, 1, &out, &buf.cpos,
                                 &mut self.ecache)?;
            self.metrics.draft_passes += 1;
            let hh = out.hidden.as_ref().unwrap();
            for (row, seq) in self.seqs.iter_mut().enumerate() {
                if !(seq.active && !seq.done && ks[row] > j) {
                    continue;
                }
                let c = draft_token(
                    &out.logits[row * vocab..(row + 1) * vocab],
                    sp.as_ref(), seq.rng.as_mut(), &mut qdists[row]);
                cands[row].push(c);
                let (_, p, _) = chain[row].as_ref().unwrap();
                let np = *p + 1;
                chain[row] =
                    Some((c, np, hh[row * d..(row + 1) * d].to_vec()));
            }
        }
        self.metrics.draft_s += t0.elapsed().as_secs_f64();
        Ok((cands, qdists))
    }
}

impl Engine for EagleEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Eagle
    }

    fn batch(&self) -> usize {
        self.cfg.batch
    }

    fn admit(&mut self, slot: usize, prompt: &[i32], max_new: usize)
             -> Result<()> {
        let need = reserve_len(prompt.len(), max_new, self.policy.k_cap());
        let t_hit = self.tcache.reserve_row_prefixed(slot, prompt, need)?;
        self.ecache.reserve_row(slot, need)?;
        self.policy.on_admit(slot);
        let mut seq = Sequence::start(prompt, max_new);
        seed_sequence_rng(&mut seq, self.cfg.sampling.as_ref(),
                          self.admitted);
        self.admitted += 1;
        // target prefill with hidden export
        let b = self.tcache.batch;
        let t = self.target.pick_t(b, prompt.len())?;
        let garbage = self.tcache.garbage_slot();
        let mut buf = CallBuf::parked(b, t, self.pad, garbage);
        for (i, &tok) in prompt.iter().enumerate() {
            // A cached-prefix column is still FED (its hidden row
            // feeds the head backlog, which a mapped block cannot
            // provide) but not committed: the shared blocks already
            // hold exactly these bytes — in-flight attention equals
            // committed attention bit for bit (DESIGN.md §6/§7) — so
            // EAGLE's prefix hits share memory, not prefill compute.
            buf.set(slot, i, tok, i as i32, i >= t_hit);
        }
        let t0 = stopwatch();
        let out =
            self.target.fwd(b, t, &buf.tokens, &buf.pos, None, &self.tcache)?;
        self.metrics.record_fwd(&out);
        self.metrics.record_work(self.target.n_params(), prompt.len());
        self.metrics.commit_s +=
            self.target.commit(b, t, &out, &buf.cpos, &mut self.tcache)?;
        self.metrics.prefill_s += t0.elapsed().as_secs_f64();
        self.metrics.target_passes += 1;
        self.tcache.cur_len[slot] = prompt.len() as u32;
        let vocab = self.target.cfg().vocab;
        let d = self.d_model;
        let hidden = out.hidden.as_ref().expect("_h target exports hidden");
        let last = prompt.len() - 1;
        let first = next_token(
            &out.logits
                [(slot * t + last) * vocab..(slot * t + last + 1) * vocab],
            self.cfg.sampling.as_ref(), seq.rng.as_mut());
        // head backlog under the (h_{t-1}, x_t) pairing: prompt token
        // x_q pairs with the hidden at q-1 (zeros for q=0, as trained),
        // plus the pending first token with the last prompt hidden.
        let mut backlog = Vec::with_capacity(prompt.len() + 1);
        for (i, &tok) in prompt.iter().enumerate() {
            let h = if i == 0 {
                vec![0f32; d]
            } else {
                hidden[(slot * t + i - 1) * d..(slot * t + i) * d].to_vec()
            };
            backlog.push((tok, i as i32, h));
        }
        let h_last = hidden
            [(slot * t + last) * d..(slot * t + last + 1) * d]
            .to_vec();
        backlog.push((first, prompt.len() as i32, h_last));
        seq.push_committed(&[first], self.eos);
        self.metrics.generated += 1;
        seq.target_len = seq.stream.len() - 1;
        self.tcache.cur_len[slot] = seq.target_len as u32;
        seq.eagle_backlog = backlog;
        self.seqs[slot] = seq;
        self.note_kv();
        Ok(())
    }

    fn step(&mut self) -> Result<()> {
        let faults = std::mem::take(&mut self.faults);
        let force_k0 = match fault_prologue(
            faults, &mut self.seqs, self.cfg.sampling.is_some(),
            Some(self.head.n_params()), self.target.n_params(),
            &mut self.metrics)
        {
            FaultAction::Skip => {
                self.note_kv();
                return Ok(());
            }
            FaultAction::Proceed { force_k0 } => force_k0,
        };
        let ks = if force_k0 {
            vec![0; self.seqs.len()]
        } else {
            let live: Vec<bool> = self
                .seqs
                .iter()
                .map(|s| s.active && !s.done)
                .collect();
            self.policy.plan(&live, &mut self.metrics)
        };
        let (cands, qdists) = self.draft_candidates(&ks)?;
        let spec = VerifySpec { k: ks.iter().copied().max().unwrap_or(0),
                                pad: self.pad,
                                sampling: self.cfg.sampling,
                                qdists: &qdists };
        let verdicts = verify_and_commit(&*self.target, &mut self.tcache,
                                         &mut self.seqs, &cands, &spec,
                                         &mut self.metrics)?;
        for (row, v) in verdicts.iter().enumerate() {
            let Some(v) = v else { continue };
            self.policy.on_acceptance(row, cands[row].len(), v.accepted);
            let seq = &mut self.seqs[row];
            let pre_len = seq.stream.len(); // before commit
            apply_verdict(seq, &mut self.tcache, row, v,
                          self.policy.k_cap(), self.eos,
                          &mut self.metrics);
            if seq.done {
                continue;
            }
            // Rebuild the head backlog from the verify's hidden rows:
            // committed token i sat in verify column i+... column 0 was
            // the old pending (already in head cache via catch-up), so
            // fresh tokens start at column 1.
            let rows = v.hidden_rows.as_ref().expect("_h verify hidden");
            let mut backlog = Vec::new();
            let taken = seq.stream.len() - pre_len;
            for i in 0..taken {
                let tok = seq.stream[pre_len + i];
                let p = (pre_len + i) as i32;
                // EAGLE pairing: token at position q pairs with the
                // hidden of position q-1 (the row that predicted it) —
                // the same (h_{t-1}, x_t) pairing the head trains on.
                let hrow = i;
                backlog.push((tok, p, rows[hrow].clone()));
            }
            // Extend, don't replace: a row the policy planned K=0 for
            // skipped catch-up, so its unfed pairs must survive.  For
            // drafting rows catch-up cleared the backlog, making this
            // an exact replace.
            seq.eagle_backlog.extend(backlog);
        }
        self.note_kv();
        Ok(())
    }

    fn can_admit(&self, prompt: &[i32], max_new: usize) -> bool {
        let need = reserve_len(prompt.len(), max_new, self.policy.k_cap());
        self.tcache.can_reserve_prefixed(prompt, need)
            && self.ecache.can_reserve(need)
    }

    fn release(&mut self, slot: usize) {
        // Target blocks register for prefix reuse; the head cache
        // opts out of sharing (see `new`).
        self.tcache.release_row_cached(slot, &self.seqs[slot].stream);
        self.ecache.release_row(slot);
        self.note_kv();
    }

    fn seqs(&self) -> &[Sequence] {
        &self.seqs
    }

    fn seqs_mut(&mut self) -> &mut [Sequence] {
        &mut self.seqs
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    fn inject_faults(&mut self, faults: FaultSet) {
        self.faults = faults;
    }

    fn observe_kv(&mut self) {
        self.note_kv();
    }

    fn warmup(&mut self) -> Result<()> {
        let b = self.cfg.batch;
        let pf_t = self.target.pick_t(b, super::PREFILL_T)?;
        let ver_t = self.target.pick_t(b, self.policy.k_cap() + 1)?;
        self.target.warmup(b, &[pf_t, ver_t])?;
        // backlog catch-up: the head only exports T in {1, 32}
        let bk_t = self.head.pick_t(b, super::PREFILL_T)?;
        self.head.warmup(b, &[1, bk_t])?;
        Ok(())
    }
}
