//! Vanilla speculative decoding (paper's VSD baseline, Eq. 3; slot
//! contract per DESIGN.md §7).
//!
//! Per iteration: (1) a catch-up draft pass re-feeds the stream tokens
//! the draft cache hasn't consumed (its last logits row yields c_0);
//! (2) K-1 sequential T=1 draft passes chain the remaining candidates —
//! the K-pass autoregressive drafting whose latency PARD collapses;
//! (3) one shared verify pass on the target.

use std::rc::Rc;

use anyhow::Result;

use super::{apply_verdict, draft_token, fault_prologue, next_token,
            prefill_slot, reserve_len, seed_sequence_rng,
            verify_and_commit, CallBuf, Engine, EngineConfig,
            EngineKind, FaultAction, VerifySpec};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::SpecPolicy;
use crate::coordinator::sequence::Sequence;
use crate::runtime::{Backend, KvCache, Runtime};
use crate::substrate::bench::stopwatch;
use crate::substrate::fault::FaultSet;

/// Vanilla speculative decoding: K sequential draft passes, then one
/// target verify (DESIGN.md §5).
pub struct VsdEngine {
    target: Rc<dyn Backend>,
    draft: Rc<dyn Backend>,
    tcache: KvCache,
    dcache: KvCache,
    seqs: Vec<Sequence>,
    metrics: Metrics,
    cfg: EngineConfig,
    pad: i32,
    eos: i32,
    /// FCFS admission counter — keys per-sequence sampling streams.
    admitted: u64,
    /// Speculation controller: plans each row's K per step
    /// (DESIGN.md §9); reservations/warmup are sized by its k_cap.
    policy: SpecPolicy,
    /// Faults armed for the next step (DESIGN.md §10).
    faults: FaultSet,
}

impl VsdEngine {
    /// Build the target plus its autoregressive draft.
    pub fn new(rt: &Runtime, cfg: &EngineConfig, policy: SpecPolicy)
               -> Result<Self> {
        let target = rt.model(&cfg.target)?;
        let draft_name = cfg
            .draft
            .clone()
            .ok_or_else(|| anyhow::anyhow!("VSD requires a draft model"))?;
        let draft = rt.model(&draft_name)?;
        let mut tcache = target.new_cache_sized(cfg.batch, cfg.kv_blocks)?;
        let mut dcache = draft.new_cache_sized(cfg.batch, cfg.kv_blocks)?;
        tcache.set_prefix_sharing(cfg.prefix_cache);
        dcache.set_prefix_sharing(cfg.prefix_cache);
        Ok(VsdEngine {
            target,
            draft,
            tcache,
            dcache,
            seqs: vec![Sequence::default(); cfg.batch],
            metrics: Metrics::default(),
            cfg: cfg.clone(),
            pad: rt.manifest.pad,
            eos: rt.manifest.eos,
            admitted: 0,
            policy,
            faults: FaultSet::default(),
        })
    }

    /// Record both pools' occupancy + prefix-sharing stats into the
    /// metrics gauges.
    fn note_kv(&mut self) {
        self.metrics.record_kv_blocks(
            self.tcache.blocks_in_use() + self.dcache.blocks_in_use());
        self.metrics.record_prefix_stats(
            self.tcache.prefix_hit_tokens()
                + self.dcache.prefix_hit_tokens(),
            self.tcache.blocks_shared() + self.dcache.blocks_shared(),
            self.tcache.cow_copies() + self.dcache.cow_copies());
    }

    /// Draft `ks[row]` candidates for every row the policy planned
    /// K >= 1 for: one catch-up pass plus chained singles until each
    /// row has its K.  Returns per-row candidates plus, under
    /// stochastic decoding, the draft distribution each was sampled
    /// from (rows stay empty under greedy).
    ///
    /// Rows with `ks[row] == 0` (dual-mode AR+ degrade) skip drafting;
    /// their `draft_len` lags and the next catch-up brings the draft
    /// cache current.  If no row drafts, no draft pass runs at all.
    #[allow(clippy::type_complexity)]
    fn draft_candidates(&mut self, ks: &[usize])
                        -> Result<(Vec<Vec<i32>>, Vec<Vec<Vec<f32>>>)> {
        let b = self.dcache.batch;
        let sp = self.cfg.sampling;
        let garbage = self.dcache.garbage_slot();
        let vocab = self.draft.cfg().vocab;
        let mut cands: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut qdists: Vec<Vec<Vec<f32>>> = vec![Vec::new(); b];

        let drafting =
            |row: usize, s: &Sequence| s.active && !s.done && ks[row] > 0;
        // (1) catch-up: feed stream[draft_len..] (includes pending).
        let need = self
            .seqs
            .iter()
            .enumerate()
            .filter(|(row, s)| drafting(*row, s))
            .map(|(_, s)| s.stream.len() - s.draft_len)
            .max();
        let Some(need) = need else {
            return Ok((cands, qdists));
        };
        let t = self.draft.pick_t(b, need)?;
        let mut buf = CallBuf::parked(b, t, self.pad, garbage);
        let mut cols = 0usize;
        for (row, seq) in self.seqs.iter().enumerate() {
            if !drafting(row, seq) {
                continue;
            }
            cols += seq.stream.len() - seq.draft_len;
            for (i, &tok) in seq.stream[seq.draft_len..].iter().enumerate() {
                buf.set(row, i, tok, (seq.draft_len + i) as i32, true);
            }
        }
        let t0 = stopwatch();
        let out =
            self.draft.fwd(b, t, &buf.tokens, &buf.pos, None, &self.dcache)?;
        self.metrics.record_fwd(&out);
        self.metrics.record_work(self.draft.n_params(), cols);
        self.metrics.commit_s +=
            self.draft.commit(b, t, &out, &buf.cpos, &mut self.dcache)?;
        self.metrics.draft_passes += 1;
        for (row, seq) in self.seqs.iter_mut().enumerate() {
            if !(seq.active && !seq.done && ks[row] > 0) {
                continue;
            }
            let fed = seq.stream.len() - seq.draft_len;
            let row_logits = &out.logits
                [(row * t + fed - 1) * vocab..(row * t + fed) * vocab];
            cands[row].push(draft_token(row_logits, sp.as_ref(),
                                        seq.rng.as_mut(),
                                        &mut qdists[row]));
            seq.draft_len = seq.stream.len();
            self.dcache.cur_len[row] = seq.draft_len as u32;
        }

        // (2) chain: sequential single-token draft passes; pass j only
        // carries the rows still short of their planned K.  The
        // candidate KVs land past draft_len; they are tentative and get
        // overwritten by the next catch-up (slot contract).
        let max_k = ks.iter().copied().max().unwrap_or(0);
        for j in 1..max_k {
            let mut buf = CallBuf::parked(b, 1, self.pad, garbage);
            let mut cols = 0usize;
            for (row, seq) in self.seqs.iter().enumerate() {
                if !drafting(row, seq) || ks[row] <= j {
                    continue;
                }
                cols += 1;
                let p = (seq.draft_len + j - 1) as i32;
                buf.set(row, 0, cands[row][j - 1], p, true);
            }
            let out = self.draft.fwd(b, 1, &buf.tokens, &buf.pos, None,
                                     &self.dcache)?;
            self.metrics.record_fwd(&out);
            self.metrics.record_work(self.draft.n_params(), cols);
            self.metrics.commit_s +=
                self.draft.commit(b, 1, &out, &buf.cpos,
                                  &mut self.dcache)?;
            self.metrics.draft_passes += 1;
            for (row, seq) in self.seqs.iter_mut().enumerate() {
                if !(seq.active && !seq.done && ks[row] > j) {
                    continue;
                }
                cands[row].push(draft_token(
                    &out.logits[row * vocab..(row + 1) * vocab],
                    sp.as_ref(), seq.rng.as_mut(), &mut qdists[row]));
            }
        }
        self.metrics.draft_s += t0.elapsed().as_secs_f64();
        Ok((cands, qdists))
    }
}

impl Engine for VsdEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Vsd
    }

    fn batch(&self) -> usize {
        self.cfg.batch
    }

    fn admit(&mut self, slot: usize, prompt: &[i32], max_new: usize)
             -> Result<()> {
        // Reserve for the policy's worst-case K so an adaptive row can
        // never outgrow its reservation mid-decode.
        let need = reserve_len(prompt.len(), max_new, self.policy.k_cap());
        // Prefix hits map cached blocks in; only the uncached suffix
        // of each cache is prefilled (hits may differ per cache).
        let t_hit = self.tcache.reserve_row_prefixed(slot, prompt, need)?;
        let d_hit = self.dcache.reserve_row_prefixed(slot, prompt, need)?;
        let mut seq = Sequence::start(prompt, max_new);
        seed_sequence_rng(&mut seq, self.cfg.sampling.as_ref(),
                          self.admitted);
        self.admitted += 1;
        let (last_row, _) = prefill_slot(&*self.target, &mut self.tcache,
                                         slot, prompt, t_hit, self.pad,
                                         &mut self.metrics)?;
        let first = next_token(&last_row, self.cfg.sampling.as_ref(),
                               seq.rng.as_mut());
        // draft prefill: its own cache over the same prompt
        let mut dm = Metrics::default();
        let _ = prefill_slot(&*self.draft, &mut self.dcache, slot, prompt,
                             d_hit, self.pad, &mut dm)?;
        self.metrics.prefill_s += dm.prefill_s;
        self.metrics.fwd_s += dm.fwd_s;
        self.metrics.fwd_ops.add(&dm.fwd_ops);
        self.metrics.commit_s += dm.commit_s;
        seq.push_committed(&[first], self.eos);
        self.metrics.generated += 1;
        seq.target_len = seq.stream.len() - 1;
        seq.draft_len = prompt.len();
        self.tcache.cur_len[slot] = seq.target_len as u32;
        self.dcache.cur_len[slot] = seq.draft_len as u32;
        self.seqs[slot] = seq;
        self.policy.on_admit(slot);
        self.note_kv();
        Ok(())
    }

    fn step(&mut self) -> Result<()> {
        let faults = std::mem::take(&mut self.faults);
        let force_k0 = match fault_prologue(
            faults, &mut self.seqs, self.cfg.sampling.is_some(),
            Some(self.draft.n_params()), self.target.n_params(),
            &mut self.metrics)
        {
            FaultAction::Skip => {
                self.note_kv();
                return Ok(());
            }
            FaultAction::Proceed { force_k0 } => force_k0,
        };
        let ks = if force_k0 {
            vec![0; self.seqs.len()]
        } else {
            let live: Vec<bool> =
                self.seqs.iter().map(|s| s.active && !s.done).collect();
            self.policy.plan(&live, &mut self.metrics)
        };
        let (cands, qdists) = self.draft_candidates(&ks)?;
        let spec = VerifySpec { k: ks.iter().copied().max().unwrap_or(0),
                                pad: self.pad,
                                sampling: self.cfg.sampling,
                                qdists: &qdists };
        let verdicts = verify_and_commit(&*self.target, &mut self.tcache,
                                         &mut self.seqs, &cands, &spec,
                                         &mut self.metrics)?;
        for (row, v) in verdicts.iter().enumerate() {
            if let Some(v) = v {
                self.policy.on_acceptance(row, cands[row].len(),
                                          v.accepted);
                apply_verdict(&mut self.seqs[row], &mut self.tcache, row, v,
                              self.policy.k_cap(), self.eos,
                              &mut self.metrics);
            }
        }
        self.note_kv();
        Ok(())
    }

    fn can_admit(&self, prompt: &[i32], max_new: usize) -> bool {
        let need = reserve_len(prompt.len(), max_new, self.policy.k_cap());
        self.tcache.can_reserve_prefixed(prompt, need)
            && self.dcache.can_reserve_prefixed(prompt, need)
    }

    fn release(&mut self, slot: usize) {
        // Registers the released row's full committed blocks for
        // prefix reuse (no-op with --prefix-cache off).
        self.tcache.release_row_cached(slot, &self.seqs[slot].stream);
        self.dcache.release_row_cached(slot, &self.seqs[slot].stream);
        self.note_kv();
    }

    fn seqs(&self) -> &[Sequence] {
        &self.seqs
    }

    fn seqs_mut(&mut self) -> &mut [Sequence] {
        &mut self.seqs
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    fn inject_faults(&mut self, faults: FaultSet) {
        self.faults = faults;
    }

    fn observe_kv(&mut self) {
        self.note_kv();
    }

    fn warmup(&mut self) -> Result<()> {
        let b = self.cfg.batch;
        // Warm the policy's worst-case shapes (== cfg.k when fixed);
        // smaller adaptive K lands in smaller T buckets, exact-T
        // (free) on the host/reference backends.
        let k = self.policy.k_cap();
        let pf_t = self.target.pick_t(b, super::PREFILL_T)?;
        let ver_t = self.target.pick_t(b, k + 1)?;
        self.target.warmup(b, &[pf_t, ver_t])?;
        // catch-up feeds 1..=K+2 reals depending on last acceptance
        self.draft.warmup_range(b, 1, k + 2)?;
        self.draft
            .warmup(b, &[self.draft.pick_t(b, super::PREFILL_T)?])?;
        Ok(())
    }
}
