//! AR / AR+ baselines (cache/commit contract: DESIGN.md §7).
//!
//! * AR ("Transformers" row in Table 1): no KV reuse — every step re-feeds
//!   the whole prefix through the smallest fitting T bucket and takes the
//!   last logits row.  This reproduces the unoptimized-framework baseline
//!   the paper measures (~0.5x of AR+).
//! * AR+ ("Transformers+"): standard KV-cached decode — prefill once,
//!   then T=1 steps with cache commits.  This is the 1.00x baseline every
//!   speedup in the paper is measured against.

use std::rc::Rc;

use anyhow::Result;

use super::{fault_prologue, next_token, prefill_slot, reserve_len,
            seed_sequence_rng, CallBuf, Engine, EngineConfig,
            EngineKind, FaultAction};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::sequence::Sequence;
use crate::runtime::{Backend, KvCache, Runtime};
use crate::substrate::bench::stopwatch;
use crate::substrate::fault::FaultSet;

/// AR / AR+: plain autoregression — full recompute (AR) or KV-cached
/// (AR+), the paper's Transformers / Transformers+ baselines.
pub struct ArEngine {
    target: Rc<dyn Backend>,
    cache: KvCache,
    seqs: Vec<Sequence>,
    metrics: Metrics,
    cfg: EngineConfig,
    cached: bool,
    pad: i32,
    eos: i32,
    /// FCFS admission counter — keys per-sequence sampling streams.
    admitted: u64,
    /// Faults armed for the next step (DESIGN.md §10).
    faults: FaultSet,
}

impl ArEngine {
    /// Build against `cfg.target`; `cached` selects AR+ over AR.
    pub fn new(rt: &Runtime, cfg: &EngineConfig, cached: bool)
               -> Result<Self> {
        let target = rt.model(&cfg.target)?;
        let mut cache = target.new_cache_sized(cfg.batch, cfg.kv_blocks)?;
        // Prefix sharing only helps the cached variant; uncached AR
        // commits nothing, so there is nothing to share.
        cache.set_prefix_sharing(cached && cfg.prefix_cache);
        Ok(ArEngine {
            target,
            cache,
            seqs: vec![Sequence::default(); cfg.batch],
            metrics: Metrics::default(),
            cfg: cfg.clone(),
            cached,
            pad: rt.manifest.pad,
            eos: rt.manifest.eos,
            admitted: 0,
            faults: FaultSet::default(),
        })
    }

    /// Record the pool's occupancy + prefix-sharing stats into the
    /// metrics gauges.
    fn note_kv(&mut self) {
        self.metrics.record_kv_blocks(self.cache.blocks_in_use());
        self.metrics.record_prefix_stats(self.cache.prefix_hit_tokens(),
                                         self.cache.blocks_shared(),
                                         self.cache.cow_copies());
    }

    fn step_cached(&mut self) -> Result<()> {
        let b = self.cache.batch;
        let garbage = self.cache.garbage_slot();
        let mut buf = CallBuf::parked(b, 1, self.pad, garbage);
        let mut cols = 0usize;
        for (row, seq) in self.seqs.iter().enumerate() {
            if seq.active && !seq.done {
                cols += 1;
                buf.set(row, 0, seq.pending(), seq.target_len as i32, true);
            }
        }
        let t0 = stopwatch();
        let out =
            self.target.fwd(b, 1, &buf.tokens, &buf.pos, None, &self.cache)?;
        self.metrics.record_fwd(&out);
        self.metrics.record_work(self.target.n_params(), cols);
        self.metrics.commit_s +=
            self.target.commit(b, 1, &out, &buf.cpos, &mut self.cache)?;
        self.metrics.verify_s += t0.elapsed().as_secs_f64();
        self.metrics.target_passes += 1;
        let vocab = self.target.cfg().vocab;
        let sp = self.cfg.sampling;
        for (row, seq) in self.seqs.iter_mut().enumerate() {
            if !seq.active || seq.done {
                continue;
            }
            let next = next_token(
                &out.logits[row * vocab..(row + 1) * vocab],
                sp.as_ref(), seq.rng.as_mut());
            let taken = seq.push_committed(&[next], self.eos);
            self.metrics.generated += taken as u64;
            seq.target_len = seq.stream.len() - 1;
            self.cache.cur_len[row] = seq.target_len as u32;
            if seq.done
                || seq.target_len as u32 + 4 >= self.cache.max_live_pos()
            {
                seq.done = true;
                seq.active = false;
                self.metrics.requests += 1;
            }
        }
        Ok(())
    }

    fn step_uncached(&mut self) -> Result<()> {
        // Full-prefix recompute: one fwd over the longest active stream;
        // nothing is ever committed, so the (zeroed) cache contributes
        // nothing and attention sees only this call's in-flight KV.
        let b = self.cache.batch;
        let need = self
            .seqs
            .iter()
            .filter(|s| s.active && !s.done)
            .map(|s| s.stream.len())
            .max()
            .unwrap_or(1);
        let t = self.target.pick_t(b, need)?;
        let garbage = self.cache.garbage_slot();
        let mut buf = CallBuf::parked(b, t, self.pad, garbage);
        let mut cols = 0usize;
        for (row, seq) in self.seqs.iter().enumerate() {
            if !seq.active || seq.done {
                continue;
            }
            cols += seq.stream.len();
            for (i, &tok) in seq.stream.iter().enumerate() {
                buf.set(row, i, tok, i as i32, false);
            }
        }
        let t0 = stopwatch();
        let out =
            self.target.fwd(b, t, &buf.tokens, &buf.pos, None, &self.cache)?;
        self.metrics.record_fwd(&out);
        self.metrics.record_work(self.target.n_params(), cols);
        self.metrics.verify_s += t0.elapsed().as_secs_f64();
        self.metrics.target_passes += 1;
        let vocab = self.target.cfg().vocab;
        let sp = self.cfg.sampling;
        for (row, seq) in self.seqs.iter_mut().enumerate() {
            if !seq.active || seq.done {
                continue;
            }
            let last = seq.stream.len() - 1;
            let next = next_token(
                &out.logits
                    [(row * t + last) * vocab..(row * t + last + 1) * vocab],
                sp.as_ref(), seq.rng.as_mut());
            let taken = seq.push_committed(&[next], self.eos);
            self.metrics.generated += taken as u64;
            seq.target_len = seq.stream.len() - 1;
            // stream must keep fitting the largest exported bucket
            if seq.done || seq.stream.len() + 1 >= 64 {
                seq.done = true;
                seq.active = false;
                self.metrics.requests += 1;
            }
        }
        Ok(())
    }
}

impl Engine for ArEngine {
    fn kind(&self) -> EngineKind {
        if self.cached {
            EngineKind::ArPlus
        } else {
            EngineKind::Ar
        }
    }

    fn batch(&self) -> usize {
        self.cfg.batch
    }

    fn admit(&mut self, slot: usize, prompt: &[i32], max_new: usize)
             -> Result<()> {
        // AR+ never drafts: its reservation carries no speculative
        // tail (k = 0).  A prefix hit maps cached blocks and shrinks
        // the prefill to the uncached suffix.
        let hit = if self.cached {
            self.cache.reserve_row_prefixed(
                slot, prompt, reserve_len(prompt.len(), max_new, 0))?
        } else {
            // uncached AR commits nothing — the row needs no blocks
            self.cache.release_row(slot);
            0
        };
        let mut seq = Sequence::start(prompt, max_new);
        seed_sequence_rng(&mut seq, self.cfg.sampling.as_ref(),
                          self.admitted);
        self.admitted += 1;
        if self.cached {
            let (last_row, _) = prefill_slot(&*self.target, &mut self.cache,
                                             slot, prompt, hit, self.pad,
                                             &mut self.metrics)?;
            let first = next_token(&last_row, self.cfg.sampling.as_ref(),
                                   seq.rng.as_mut());
            seq.target_len = prompt.len();
            // pending token joins the stream; its KV commits next step
            seq.push_committed(&[first], self.eos);
            self.metrics.generated += 1;
            seq.target_len = seq.stream.len() - 1;
            self.cache.cur_len[slot] = seq.target_len as u32;
        } else {
            // uncached AR computes the first token inside its first step;
            // seed pending with the prompt's last token semantics by
            // running one uncached step just for this row below.
        }
        self.seqs[slot] = seq;
        self.note_kv();
        Ok(())
    }

    fn step(&mut self) -> Result<()> {
        // AR kinds have no draft path (`draft_params = None`), so the
        // prologue only sees target incidents and injected worker
        // panics.
        let faults = std::mem::take(&mut self.faults);
        if let FaultAction::Skip = fault_prologue(
            faults, &mut self.seqs, self.cfg.sampling.is_some(), None,
            self.target.n_params(), &mut self.metrics)
        {
            self.note_kv();
            return Ok(());
        }
        if self.cached {
            self.step_cached()?;
        } else {
            self.step_uncached()?;
        }
        self.note_kv();
        Ok(())
    }

    fn can_admit(&self, prompt: &[i32], max_new: usize) -> bool {
        !self.cached
            || self.cache.can_reserve_prefixed(
                prompt, reserve_len(prompt.len(), max_new, 0))
    }

    fn release(&mut self, slot: usize) {
        // Registers the released row's full committed blocks for
        // prefix reuse (no-op with --prefix-cache off / uncached AR).
        self.cache.release_row_cached(slot, &self.seqs[slot].stream);
        self.note_kv();
    }

    fn seqs(&self) -> &[Sequence] {
        &self.seqs
    }

    fn seqs_mut(&mut self) -> &mut [Sequence] {
        &mut self.seqs
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    fn inject_faults(&mut self, faults: FaultSet) {
        self.faults = faults;
    }

    fn observe_kv(&mut self) {
        self.note_kv();
    }

    fn warmup(&mut self) -> Result<()> {
        let b = self.cfg.batch;
        if self.cached {
            let pf = self.target.pick_t(b, super::PREFILL_T)?;
            self.target.warmup(b, &[1, pf])?;
        } else {
            self.target.warmup_range(b, 1, 64)?;
        }
        Ok(())
    }
}
