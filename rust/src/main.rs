//! `pard` CLI — leader entrypoint (layer map in DESIGN.md §1).
//!
//! Subcommands (hand-rolled parsing; clap is not vendored offline):
//!   eval   --engine pard --target target-l [--task code] [--k 8]
//!          [--batch 1] [--prompts N] [--max-new N] [--draft NAME]
//!          [--kv-blocks N] [--prefix-cache] [--temperature T]
//!          [--top-p P] [--sample-seed N] [--policy fixed|adaptive]
//!          [--k-min N] [--k-max N] [--policy-window N]
//!          [--dual-mode-occupancy F]
//!   serve  --engine pard --target target-l [--n N] [--rate R]
//!          [--kv-blocks N] [--virtual-tick S] [--virtual-cost P,C]
//!          [--prefix-cache] [--shared-prefix N] [--prefix-len L]
//!          [--policy fixed|adaptive] [--k-min N] [--k-max N]
//!          [--policy-window N] [--dual-mode-occupancy F]
//!          [--fault-spec KIND:RATE:SEED[,..]] [--deadline-ms MS]
//!   bench  [--k 2,4,8] [--batch 1,4] [--prompts N] [--max-new N]
//!          [--task code] [--target target-l] [--seed N] [--no-oracle]
//!          [--out BENCH_hotpath.json] [--compare OLD.json]
//!   tables [--which 1,2,...] [--full]
//!   fig    --which 1a|1b|2|6a|6b
//!   info
//!   audit  [--root DIR] [--json PATH]
//!
//! Every subcommand accepts `--backend pjrt|reference|host|host-q8`
//! (default pjrt; `bench` is always artifact-free): `reference` runs
//! the deterministic scalar oracle (DESIGN.md §6), `host` the fast
//! host serving path over the same weights (DESIGN.md §8) — no
//! artifacts, no Python — with `--seed N` selecting the synthetic
//! weights, and `host-q8` the int8 per-panel quantized twin (~4× less
//! weight traffic, bounded-error rather than bit-identity contract).
//! The host backends also take `--threads N` to pin their worker-pool
//! size (default: `PARD_HOST_THREADS`, then available cores); outputs
//! are bit-identical for every pool size.  `--kv-blocks N` sizes each KV
//! cache's paged block pool (DESIGN.md §7) — admission then waits on
//! free blocks instead of assuming worst-case dense rows — and
//! `serve --virtual-tick S` runs the batcher on a deterministic
//! virtual clock (S seconds per decode iteration).  `--prefix-cache`
//! turns on cross-request prefix sharing in the paged pools (released
//! rows keep their full blocks cached; later prompts map the longest
//! cached prefix and prefill only the suffix — bit-identical outputs),
//! and `serve --shared-prefix N` generates the matching workload: N
//! distinct system prompts of `--prefix-len L` tokens (default 32)
//! prepended round-robin to the task prompts.  `bench --compare
//! OLD.json` fails on any >10% tokens/s regression against an older
//! report.  `--temperature T` switches every engine from greedy argmax
//! to seeded stochastic decoding (speculative engines verify with the
//! lossless accept/residual correction); `--top-p P` adds nucleus
//! filtering and `--sample-seed N` keys the per-sequence rng streams —
//! same seed, same output, at any batch size.  Temperature 0 is exact
//! greedy (DESIGN.md §6).  `--policy adaptive` turns on the windowed
//! accept-rate K controller (DESIGN.md §9): each sequence's draft
//! length is retuned every step within `[--k-min, --k-max]` from its
//! last `--policy-window` verify outcomes, and
//! `--dual-mode-occupancy F` degrades the whole batch to AR+ (K=0)
//! while live slots >= F x batch; `--k` stays the initial/default K.
//! `serve --virtual-cost PASS,COL` runs the batcher on the
//! work-costed virtual clock (PASS seconds per forward-pass unit +
//! COL per token-column unit), which prices speculation instead of
//! charging every iteration a flat tick.
//! `serve --fault-spec KIND:RATE:SEED[,..]` arms a deterministic
//! fault plan (DESIGN.md §10): KIND ∈ draft|target|pool|worker, RATE
//! the per-iteration firing probability, SEED its private rng stream —
//! the serve loop degrades losslessly (draft → K=0 / held iteration,
//! target → bounded retry then fail one row, pool → one-iteration
//! admission pause, worker → caught panic + pool rebuild) instead of
//! dying.  `serve --deadline-ms MS` gives every request an
//! arrival+MS completion deadline; expired requests — queued or
//! mid-decode — release their KV blocks and report a typed
//! DeadlineExceeded outcome.

use std::path::{Path, PathBuf};

use anyhow::Result;
use pard::coordinator::engines::{EngineConfig, EngineKind, SamplingCfg};
use pard::coordinator::evaluate::run_eval;
use pard::coordinator::policy::PolicyCfg;
use pard::coordinator::router::default_draft;
use pard::coordinator::batcher::{
    serve_trace, serve_trace_virtual, serve_trace_virtual_costed,
    serve_trace_virtual_costed_with_faults,
    serve_trace_virtual_with_faults, serve_trace_with_faults,
};
use pard::substrate::fault::FaultPlan;
use pard::report::bench::{compare_quant, compare_reports,
                          hotpath_report, write_report, BenchOpts,
                          BENCH_FILE, COMPARE_TOL};
use pard::report::{self, RunScale};
use pard::substrate::json::Json;
use pard::substrate::workload::{build_shared_prefix_trace, build_trace,
                                Arrival};
use pard::Runtime;

struct Args {
    cmd: String,
    opts: std::collections::HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut opts = std::collections::HashMap::new();
    let mut key: Option<String> = None;
    for a in it {
        if let Some(k) = a.strip_prefix("--") {
            if let Some(prev) = key.take() {
                opts.insert(prev, "true".to_string());
            }
            key = Some(k.to_string());
        } else if let Some(k) = key.take() {
            opts.insert(k, a);
        }
    }
    if let Some(prev) = key.take() {
        opts.insert(prev, "true".to_string());
    }
    Args { cmd, opts }
}

impl Args {
    fn get(&self, k: &str, default: &str) -> String {
        self.opts.get(k).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, k: &str, default: usize) -> usize {
        self.opts
            .get(k)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn flag(&self, k: &str) -> bool {
        self.opts.get(k).map(|v| v == "true").unwrap_or(false)
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts", "artifacts"))
}

/// Which backend `--backend` selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BackendSel {
    Pjrt,
    Reference,
    HostFast,
    HostQ8,
}

/// `--backend` parse.  Unknown values are an error, not a silent
/// fall-through to PJRT.
fn backend_sel(args: &Args) -> Result<BackendSel> {
    match args.get("backend", "pjrt").as_str() {
        "reference" | "ref" => Ok(BackendSel::Reference),
        "host" => Ok(BackendSel::HostFast),
        "host-q8" => Ok(BackendSel::HostQ8),
        "pjrt" => Ok(BackendSel::Pjrt),
        other => anyhow::bail!("unknown backend `{other}` \
                                (pjrt|reference|host|host-q8)"),
    }
}

/// `--threads N` (host worker-pool size).  `None` when absent; a value
/// that doesn't parse as a positive integer is an error, not a silent
/// fall-through to the default.
fn threads_opt(args: &Args) -> Result<Option<usize>> {
    match args.opts.get("threads") {
        None => Ok(None),
        Some(v) => {
            let n: usize = v.parse().map_err(|_| {
                anyhow::anyhow!("--threads wants a positive integer, \
                                 got `{v}`")
            })?;
            anyhow::ensure!(n >= 1, "--threads must be >= 1");
            Ok(Some(n))
        }
    }
}

fn open_runtime(args: &Args) -> Result<Runtime> {
    let seed = args.usize("seed", 7) as u64;
    let threads = threads_opt(args)?;
    let sel = backend_sel(args)?;
    anyhow::ensure!(
        threads.is_none()
            || matches!(sel, BackendSel::HostFast | BackendSel::HostQ8),
        "--threads only applies to --backend host|host-q8"
    );
    match sel {
        BackendSel::Reference => Ok(Runtime::reference(seed)),
        BackendSel::HostFast => {
            Ok(Runtime::host_with_threads(seed, threads))
        }
        BackendSel::HostQ8 => {
            Ok(Runtime::host_q8_with_threads(seed, threads))
        }
        BackendSel::Pjrt => Runtime::load(&artifacts_dir(args)),
    }
}

/// `--kv-blocks N` (paged KV pool size per cache).  `None` when
/// absent; a value that doesn't parse as an integer >= 2 is an error,
/// not a silent fall-through to the default pool.
fn kv_blocks_opt(args: &Args) -> Result<Option<usize>> {
    match args.opts.get("kv-blocks") {
        None => Ok(None),
        Some(v) => {
            let n: usize = v.parse().map_err(|_| {
                anyhow::anyhow!("--kv-blocks wants an integer >= 2, \
                                 got `{v}`")
            })?;
            anyhow::ensure!(n >= 2, "--kv-blocks must be >= 2 \
                                     (1 live + 1 garbage block)");
            Ok(Some(n))
        }
    }
}

/// `--temperature T [--top-p P] [--sample-seed N]` (stochastic
/// decoding).  `None` without `--temperature` — the greedy default; the
/// companion flags alone are an error, not silently ignored knobs.
/// Values that don't parse or are out of range error instead of falling
/// through to a default.
fn sampling_opt(args: &Args) -> Result<Option<SamplingCfg>> {
    let Some(tv) = args.opts.get("temperature") else {
        anyhow::ensure!(
            args.opts.get("top-p").is_none()
                && args.opts.get("sample-seed").is_none(),
            "--top-p/--sample-seed require --temperature"
        );
        return Ok(None);
    };
    let temperature: f32 = tv.parse().map_err(|_| {
        anyhow::anyhow!("--temperature wants a number >= 0, got `{tv}`")
    })?;
    anyhow::ensure!(temperature >= 0.0 && temperature.is_finite(),
                    "--temperature must be finite and >= 0");
    let top_p = match args.opts.get("top-p") {
        None => 1.0,
        Some(v) => {
            let p: f32 = v.parse().map_err(|_| {
                anyhow::anyhow!("--top-p wants a number in (0, 1], \
                                 got `{v}`")
            })?;
            anyhow::ensure!(p > 0.0 && p <= 1.0,
                            "--top-p must be in (0, 1]");
            p
        }
    };
    let seed = match args.opts.get("sample-seed") {
        None => 0,
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!("--sample-seed wants an integer, got `{v}`")
        })?,
    };
    Ok(Some(SamplingCfg { temperature, top_p, seed }))
}

/// `--policy fixed|adaptive [--k-min N] [--k-max N]
/// [--policy-window N] [--dual-mode-occupancy F]` (speculation
/// controller, DESIGN.md §9).  The companion knobs without `--policy
/// adaptive` are an error, not silently ignored; values out of range
/// fail here AND again inside `SpecPolicy::new` (belt and braces).
fn policy_opt(args: &Args) -> Result<PolicyCfg> {
    let adaptive = match args.get("policy", "fixed").as_str() {
        "fixed" => false,
        "adaptive" => true,
        other => anyhow::bail!("unknown policy `{other}` \
                                (fixed|adaptive)"),
    };
    if !adaptive {
        anyhow::ensure!(
            args.opts.get("k-min").is_none()
                && args.opts.get("k-max").is_none()
                && args.opts.get("policy-window").is_none()
                && args.opts.get("dual-mode-occupancy").is_none(),
            "--k-min/--k-max/--policy-window/--dual-mode-occupancy \
             require --policy adaptive"
        );
        return Ok(PolicyCfg::default());
    }
    let uint = |key: &str, default: usize| -> Result<usize> {
        match args.opts.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--{key} wants a positive integer, \
                                 got `{v}`")
            }),
        }
    };
    let dual = match args.opts.get("dual-mode-occupancy") {
        None => None,
        Some(v) => {
            let f: f64 = v.parse().map_err(|_| {
                anyhow::anyhow!("--dual-mode-occupancy wants a number \
                                 in (0, 1], got `{v}`")
            })?;
            Some(f)
        }
    };
    Ok(PolicyCfg {
        adaptive: true,
        k_min: uint("k-min", 1)?,
        k_max: uint("k-max", 16)?,
        window: uint("policy-window", 8)?,
        dual_mode_occupancy: dual,
    })
}

/// `--fault-spec KIND:RATE:SEED[,..]` (deterministic fault plan,
/// DESIGN.md §10).  `None` when absent; a spec that doesn't parse is
/// an error, not a silently fault-free serve.
fn fault_opt(args: &Args) -> Result<Option<FaultPlan>> {
    match args.opts.get("fault-spec") {
        None => Ok(None),
        Some(v) => Ok(Some(FaultPlan::parse(v)?)),
    }
}

/// `--deadline-ms MS` (per-request completion budget).  `None` when
/// absent; a value that doesn't parse as a positive number is an
/// error, not a silently unbounded request.
fn deadline_opt(args: &Args) -> Result<Option<f64>> {
    match args.opts.get("deadline-ms") {
        None => Ok(None),
        Some(v) => {
            let ms: f64 = v.parse().map_err(|_| {
                anyhow::anyhow!("--deadline-ms wants a positive number \
                                 of milliseconds, got `{v}`")
            })?;
            anyhow::ensure!(ms > 0.0 && ms.is_finite(),
                            "--deadline-ms must be finite and > 0");
            Ok(Some(ms / 1000.0))
        }
    }
}

fn engine_config(rt: &Runtime, args: &Args) -> Result<EngineConfig> {
    let kind = EngineKind::parse(&args.get("engine", "pard"))?;
    let target = args.get("target", "target-l");
    let draft = match args.opts.get("draft") {
        Some(d) => Some(d.clone()),
        None => default_draft(&rt.manifest, kind, &target)?,
    };
    Ok(EngineConfig {
        kind,
        target,
        draft,
        batch: args.usize("batch", 1),
        k: args.usize("k", 8),
        max_new: args.usize("max-new", 64),
        shared_mask: !args.flag("distinct-mask"),
        kv_blocks: kv_blocks_opt(args)?,
        prefix_cache: args.flag("prefix-cache"),
        sampling: sampling_opt(args)?,
        policy: policy_opt(args)?,
    })
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let cfg = engine_config(&rt, args)?;
    let task = args.get("task", "code");
    let n = args.usize("prompts", 16);
    let prompts = rt.prompts(&task)?.take(n);
    let r = run_eval(&rt, &cfg, &prompts, cfg.max_new, &task)?;
    let m = &r.metrics;
    println!("engine={} target={} draft={:?} task={} k={} batch={}",
             r.engine, r.target, r.draft, r.task, r.k, r.batch);
    println!("generated={} iterations={} tokens/iter={:.2}",
             m.generated, m.iterations, m.tokens_per_iter());
    println!("TPS={:.1}  draft={:.3}s verify={:.3}s prefill={:.3}s \
              wall={:.3}s", m.tps(), m.draft_s, m.verify_s, m.prefill_s,
             m.wall_s);
    // reference backend has no grammar ground truth: show n/a, not 0
    let ref_agree = if m.ref_total == 0 {
        "n/a".to_string()
    } else {
        format!("{:.3}", m.ref_agreement())
    };
    println!("1-α={:.3} 4-α={:.3} 8-α={:.3}  ref-agreement={ref_agree}",
             m.k_alpha(1), m.k_alpha(4), m.k_alpha(8));
    if let Some(s) = &cfg.sampling {
        println!("sampling: temperature={} top-p={} seed={}  \
                  residual-resamples={} bonus-samples={}",
                 s.temperature, s.top_p, s.seed,
                 m.residual_resamples, m.bonus_samples);
    }
    if cfg.policy.adaptive {
        let hist: Vec<String> = m
            .k_hist
            .iter()
            .enumerate()
            .filter(|(_, n)| **n > 0)
            .map(|(k, n)| format!("{k}:{n}"))
            .collect();
        println!("policy: adaptive k=[{}..{}] window={}  \
                  k-hist {{{}}}  mode-switches={} dual-mode-iters={}",
                 cfg.policy.k_min, cfg.policy.k_max, cfg.policy.window,
                 hist.join(" "), m.mode_switches, m.dual_mode_iters);
    }
    if args.flag("show") {
        for (i, out) in r.outputs.iter().take(3).enumerate() {
            println!("[{i}] {}", rt.tokenizer.detok(out));
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let cfg = engine_config(&rt, args)?;
    let task = args.get("task", "code");
    let n = args.usize("n", 32);
    let prompts = rt.prompts(&task)?.prompts;
    let arrival = match args.opts.get("rate") {
        Some(r) => Arrival::Poisson { rate: r.parse()? },
        None => Arrival::Closed,
    };
    // --shared-prefix N: synthesize N distinct system prompts of
    // --prefix-len tokens and prepend them round-robin (the workload
    // --prefix-cache exists for).
    let seed = args.usize("seed", 7) as u64;
    let mut trace = match args.usize("shared-prefix", 0) {
        0 => build_trace(&prompts, n, arrival, cfg.max_new, seed),
        np => build_shared_prefix_trace(&prompts, n, np,
                                        args.usize("prefix-len", 32),
                                        arrival, cfg.max_new, seed),
    };
    if let Some(budget_s) = deadline_opt(args)? {
        trace = trace.with_deadline_budget(budget_s);
    }
    let mut fault = fault_opt(args)?;
    let mut engine =
        pard::coordinator::engines::build_engine(&rt, &cfg)?;
    engine.warmup()?;
    // --virtual-tick S: deterministic virtual clock (S seconds per
    // decode iteration); --virtual-cost PASS,COL: work-costed virtual
    // clock (seconds per forward-pass unit, per token-column unit).
    anyhow::ensure!(
        args.opts.get("virtual-tick").is_none()
            || args.opts.get("virtual-cost").is_none(),
        "--virtual-tick and --virtual-cost are mutually exclusive"
    );
    let stats = match (args.opts.get("virtual-tick"),
                       args.opts.get("virtual-cost")) {
        (Some(v), _) => {
            let tick: f64 = v.parse().map_err(|_| {
                anyhow::anyhow!("--virtual-tick wants seconds, got `{v}`")
            })?;
            match &mut fault {
                Some(plan) => serve_trace_virtual_with_faults(
                    engine.as_mut(), &trace, tick, plan)?,
                None => {
                    serve_trace_virtual(engine.as_mut(), &trace, tick)?
                }
            }
        }
        (_, Some(v)) => {
            let bad = || {
                anyhow::anyhow!("--virtual-cost wants PASS_S,COL_S \
                                 seconds, got `{v}`")
            };
            let (p, c) = v.split_once(',').ok_or_else(bad)?;
            let pass_s: f64 = p.trim().parse().map_err(|_| bad())?;
            let col_s: f64 = c.trim().parse().map_err(|_| bad())?;
            match &mut fault {
                Some(plan) => serve_trace_virtual_costed_with_faults(
                    engine.as_mut(), &trace, pass_s, col_s, plan)?,
                None => serve_trace_virtual_costed(engine.as_mut(),
                                                   &trace, pass_s,
                                                   col_s)?,
            }
        }
        (None, None) => match &mut fault {
            Some(plan) => {
                serve_trace_with_faults(engine.as_mut(), &trace, plan)?
            }
            None => serve_trace(engine.as_mut(), &trace)?,
        },
    };
    println!("engine={} batch={} completed={} wall={:.2}s",
             cfg.kind.label(), cfg.batch, stats.completed, stats.wall_s);
    println!("throughput={:.1} tok/s  occupancy mean={:.2} peak={}",
             stats.throughput_tps, stats.mean_occupancy,
             stats.peak_occupancy);
    println!("latency mean={:.3}s p50={:.3}s p95={:.3}s",
             stats.latency_mean_s, stats.latency_p50_s,
             stats.latency_p95_s);
    let m = engine.metrics();
    println!("kv: peak blocks={}  admission stalls={}",
             m.kv_peak_blocks, stats.admission_stalls);
    if fault.is_some() || stats.failed > 0 || stats.expired > 0 {
        println!("robustness: faults={} draft-fallbacks={} \
                  row-retries={} rows-failed={} pool-rebuilds={}  \
                  outcomes: completed={} failed={} expired={}",
                 m.faults_injected, m.draft_fallbacks, m.row_retries,
                 m.rows_failed, m.pool_rebuilds, stats.completed,
                 stats.failed, stats.expired);
    }
    if cfg.policy.adaptive {
        println!("policy: adaptive  mode-switches={}  \
                  dual-mode-iters={}",
                 m.mode_switches, m.dual_mode_iters);
    }
    if cfg.prefix_cache {
        println!("prefix cache: hit tokens={}  peak shared blocks={}  \
                  cow copies={}",
                 m.prefix_hit_tokens, m.kv_blocks_shared, m.cow_copies);
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let scale = if args.flag("full") {
        RunScale::full()
    } else {
        RunScale::quick()
    };
    let which = args.get("which", "1,2,3,4,5,6,7");
    for w in which.split(',') {
        match w.trim() {
            "1" => report::table1(&rt, scale)?.print(),
            "2" => report::table2(&rt, scale)?.print(),
            "3" => report::table3(&rt, scale)?.print(),
            "4" => report::table4(&rt, scale)?.print(),
            "5" => report::table5(&rt, scale)?.print(),
            "6" => {
                report::table6().print();
                report::table6_measured(&rt, scale)?.print();
            }
            "7" => report::table7(&rt, scale)?.print(),
            other => eprintln!("unknown table `{other}`"),
        }
    }
    Ok(())
}

fn cmd_fig(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let scale = if args.flag("full") {
        RunScale::full()
    } else {
        RunScale::quick()
    };
    match args.get("which", "1a").as_str() {
        "1a" => report::fig1a(&rt, scale)?.print(),
        "1b" => report::fig1b(&rt, scale)?.print(),
        "2" => report::table2(&rt, scale)?.print(), // same data as T2
        "6a" => report::fig6a(&rt, scale)?.print(),
        "6b" => report::fig6b(&rt, scale)?.print(),
        "mask" => report::mask_id_ablation(&rt, scale)?.print(),
        other => eprintln!("unknown figure `{other}`"),
    }
    Ok(())
}

/// Parse a comma-separated usize list option, e.g. `--k 2,4,8`.
fn parse_list(args: &Args, key: &str, default: &[usize]) -> Vec<usize> {
    match args.opts.get(key) {
        Some(s) => s
            .split(',')
            .filter_map(|x| x.trim().parse().ok())
            .collect(),
        None => default.to_vec(),
    }
}

/// `pard bench`: the artifact-free hot-path sweep (DESIGN.md §Perf).
/// Always measures the fast host backend; unless `--no-oracle`, the
/// scalar reference replays the same sweep as the speedup baseline.
fn cmd_bench(args: &Args) -> Result<()> {
    // bench always measures the host backend (the scalar oracle rides
    // along unless --no-oracle); still validate the option so typos and
    // non-host backends error instead of silently measuring host.
    match args.get("backend", "host").as_str() {
        "host" => {}
        "pjrt" | "reference" | "ref" | "host-q8" => anyhow::bail!(
            "pard bench always measures the host backend (the scalar \
             oracle is included unless --no-oracle, and the q8 twin is \
             measured in the report's `quant` section) — drop --backend"),
        other => anyhow::bail!("unknown backend `{other}` \
                                (pjrt|reference|host|host-q8)"),
    }
    let opts = BenchOpts {
        seed: args.usize("seed", 7) as u64,
        task: args.get("task", "code"),
        target: args.get("target", "target-l"),
        ks: parse_list(args, "k", &[2, 4, 8]),
        batches: parse_list(args, "batch", &[1, 4]),
        n_prompts: args.usize("prompts", 8),
        max_new: args.usize("max-new", 32),
        oracle: !args.flag("no-oracle"),
        threads: threads_opt(args)?,
    };
    anyhow::ensure!(!opts.ks.is_empty() && !opts.batches.is_empty(),
                    "--k/--batch must list at least one value");
    let out = PathBuf::from(args.get("out", BENCH_FILE));
    // Load the --compare baseline BEFORE anything is written: --out and
    // --compare may legitimately name the same file (refresh the
    // committed baseline and gate against its previous contents in one
    // run), and a bad baseline path should fail before the sweep runs.
    let baseline: Option<(&String, Json)> = match args.opts.get("compare")
    {
        Some(old_path) => {
            let text =
                std::fs::read_to_string(old_path).map_err(|e| {
                    anyhow::anyhow!("reading --compare {old_path}: {e}")
                })?;
            let old = Json::parse(text.trim()).map_err(|e| {
                anyhow::anyhow!("parsing --compare {old_path}: {e}")
            })?;
            Some((old_path, old))
        }
        None => None,
    };
    eprintln!(
        "bench: {{AR+, VSD, PARD, EAGLE}} x k={:?} x batch={:?}, \
         {} prompts x {} tokens, task={}, target={}, oracle={}",
        opts.ks, opts.batches, opts.n_prompts, opts.max_new, opts.task,
        opts.target, opts.oracle
    );
    let report = hotpath_report(&opts)?;
    write_report(&out, &report)?;
    print_bench_summary(&report);
    println!("wrote {}", out.display());

    // --compare OLD.json: fail loudly on any >10% tokens/s loss at any
    // (engine, K, batch) cell — the perf trajectory as a gate, not
    // advisory prose.
    if let Some((old_path, old)) = baseline {
        let mut regressions = compare_reports(&old, &report, COMPARE_TOL);
        // quant section: gate when the baseline has it, warn-not-fail
        // when the baseline predates the host-q8 backend entirely.
        let (has_quant, quant_lines) =
            compare_quant(&old, &report, COMPARE_TOL);
        if has_quant {
            regressions.extend(quant_lines);
        } else {
            eprintln!("compare: baseline {old_path} predates the \
                       `quant` section — q8 cells not gated this run \
                       (refresh the baseline to arm them)");
        }
        if regressions.is_empty() {
            println!("compare: no >{:.0}% tokens/s regression vs {}",
                     COMPARE_TOL * 100.0, old_path);
        } else {
            for line in &regressions {
                eprintln!("REGRESSION: {line}");
            }
            anyhow::bail!("{} tokens/s regression(s) vs {old_path}",
                          regressions.len());
        }
    }
    Ok(())
}

/// Human-readable recap of the report the JSON file now holds.
fn print_bench_summary(report: &Json) {
    if let Some(th) = report.get("threads").and_then(|v| v.as_f64()) {
        println!("host worker pool: {th:.0} lane(s)");
    }
    println!("{:<7} {:>4} {:>6} {:>12} {:>8} {:>10}",
             "engine", "k", "batch", "tokens/s", "accept", "vs AR+");
    if let Some(runs) = report.get("runs").and_then(|r| r.as_arr()) {
        for run in runs {
            let f = |k: &str| {
                run.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
            };
            let k = run
                .get("k")
                .and_then(|v| v.as_f64())
                .map_or("-".to_string(), |v| format!("{v:.0}"));
            println!(
                "{:<7} {:>4} {:>6} {:>12.1} {:>8.2} {:>9.2}x",
                run.get("engine").and_then(|v| v.as_str()).unwrap_or("?"),
                k,
                f("batch"),
                f("tokens_per_s"),
                f("mean_accept_len"),
                f("speedup_vs_ar_plus")
            );
        }
    }
    if let Some(hvr) = report.get("host_vs_reference") {
        let g = hvr.get("geomean").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let m = hvr.get("min").and_then(|v| v.as_f64()).unwrap_or(0.0);
        println!("host vs scalar oracle: geomean {g:.2}x  min {m:.2}x \
                  (bar: geomean >= 3x)");
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    println!("artifacts: {}", rt.manifest.root.display());
    println!("vocab: {}  mask id: {}", rt.manifest.vocab_size,
             rt.manifest.mask);
    println!("models:");
    for (name, m) in &rt.manifest.models {
        println!("  {name:<22} arch={:<16} layers={} d={} params≈{}",
                 m.arch, m.cfg.n_layers, m.cfg.d_model,
                 m.cfg.n_params(false));
    }
    println!("pard variants: {:?}",
             rt.manifest.pard_variants.keys().collect::<Vec<_>>());
    println!("prompt sets: {:?}",
             rt.manifest.prompts.keys().collect::<Vec<_>>());
    Ok(())
}

/// `pard audit [--root DIR] [--json PATH]`: the static-analysis pass
/// over the crate's own sources (DESIGN.md §11).  Prints the report,
/// optionally writes the pard-audit-v1 JSON, and fails on any
/// unwaived violation.  Default root: the repository checkout this
/// binary was built from (the crate dir's parent).
fn cmd_audit(args: &Args) -> Result<()> {
    let root = match args.opts.get("root") {
        Some(r) => PathBuf::from(r),
        None => Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap_or_else(|| Path::new("."))
            .to_path_buf(),
    };
    let rep = pard::analysis::audit_tree(&root)?;
    print!("{}", rep.render());
    if let Some(path) = args.opts.get("json") {
        std::fs::write(path, rep.to_json().to_string() + "\n")
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    anyhow::ensure!(rep.passed(), "{} unwaived audit violation(s)",
                    rep.total_violations());
    Ok(())
}

fn main() -> Result<()> {
    let args = parse_args();
    // `bench` is artifact-free by construction, `audit` reads only
    // the source tree; everything else needs artifacts only on the
    // PJRT backend.
    if args.cmd != "help"
        && args.cmd != "bench"
        && args.cmd != "audit"
        && backend_sel(&args)? == BackendSel::Pjrt
        && !Path::new(&artifacts_dir(&args)).exists()
    {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first \
                       (or use --backend reference|host)");
    }
    match args.cmd.as_str() {
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "bench" => cmd_bench(&args),
        "tables" => cmd_tables(&args),
        "fig" => cmd_fig(&args),
        "info" => cmd_info(&args),
        "audit" => cmd_audit(&args),
        _ => {
            println!(
                "pard — PARD speculative-decoding coordinator\n\
                 usage: pard <eval|serve|bench|tables|fig|info|audit> \
                 [--opt val]…\n\
                 see README.md"
            );
            Ok(())
        }
    }
}
