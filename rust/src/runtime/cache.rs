//! Backend-agnostic KV cache handles.
//!
//! Logically the cache is a `[2, L, B, S_max, H, D]` f32 tensor; the
//! backing store is backend-private: a device-resident PJRT buffer that
//! never crosses to the host, or a host `Vec<f32>` for the reference
//! backend.  `fwd` reads it in place and `commit` scatters this step's
//! accepted K/V into it.
//!
//! Speculative semantics (DESIGN.md §7): `cur_len[row]` is the committed
//! length.  Slot `s` always holds live data for `s < cur_len`; rejected
//! speculative columns are *redirected to the reserved garbage slot*
//! `S_max - 1` at commit time rather than erased — queries can never
//! attend it because generation is capped at position `S_max - 2`.

use anyhow::Result;

use super::artifact::ModelCfg;

/// The backing store for the `[2, L, B, S, H, D]` tensor.
pub enum CacheState {
    /// Host-resident row-major f32 (reference backend, test fakes).
    Host(Vec<f32>),
    /// Device-resident PJRT buffer (never crosses to the host).
    #[cfg(feature = "pjrt")]
    Device(xla::PjRtBuffer),
}

/// One model's KV cache: `[2, L, B, S_max, H, D]` plus per-row
/// committed lengths.  The speculative commit contract (garbage slot,
/// stale-slot reuse) is documented at module level and in DESIGN.md §7.
pub struct KvCache {
    /// Backend-private backing store (host vector / device buffer).
    pub state: CacheState,
    /// Batch rows `B` this cache was built for.
    pub batch: usize,
    /// Slot capacity `S_max`; slot `S_max - 1` is the write-only
    /// garbage slot, so live positions are capped at `S_max - 2`.
    pub s_max: usize,
    /// Cached layers `L`.
    pub n_layers: usize,
    /// Attention heads `H` per layer.
    pub n_heads: usize,
    /// Head dimension `D`.
    pub d_head: usize,
    /// Committed sequence length per batch row: slot `s < cur_len[row]`
    /// always holds live data; slots at or past it are stale until the
    /// engine re-feeds real tokens over them.
    pub cur_len: Vec<u32>,
}

impl KvCache {
    /// Host-backed cache (reference backend and backend fakes).
    pub fn host(cfg: &ModelCfg, batch: usize) -> Self {
        let n = 2 * cfg.n_layers * batch * cfg.s_max * cfg.n_heads
            * cfg.d_head;
        KvCache {
            state: CacheState::Host(vec![0f32; n]),
            batch,
            s_max: cfg.s_max,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            d_head: cfg.d_head,
            cur_len: vec![0; batch],
        }
    }

    /// Device-backed cache (PJRT).
    #[cfg(feature = "pjrt")]
    pub fn device(client: &xla::PjRtClient, cfg: &ModelCfg, batch: usize)
                  -> Result<Self> {
        let n = 2 * cfg.n_layers * batch * cfg.s_max * cfg.n_heads
            * cfg.d_head;
        let zeros = vec![0f32; n];
        let dims = [2, cfg.n_layers, batch, cfg.s_max, cfg.n_heads,
                    cfg.d_head];
        let buf = client.buffer_from_host_buffer(&zeros, &dims, None)?;
        Ok(KvCache {
            state: CacheState::Device(buf),
            batch,
            s_max: cfg.s_max,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            d_head: cfg.d_head,
            cur_len: vec![0; batch],
        })
    }

    /// The reserved write-only slot for rejected speculative columns.
    pub fn garbage_slot(&self) -> i32 {
        (self.s_max - 1) as i32
    }

    /// Highest position a live token may occupy.
    pub fn max_live_pos(&self) -> u32 {
        (self.s_max - 2) as u32
    }

    /// Reset a single row (slot reuse under continuous batching).  The
    /// stale data needs no zeroing: the position-mask contract means
    /// slots >= cur_len are rewritten before they become attendable.
    pub fn reset_row(&mut self, row: usize) {
        self.cur_len[row] = 0;
    }

    pub fn headroom(&self, row: usize) -> u32 {
        self.max_live_pos().saturating_sub(self.cur_len[row])
    }

    /// Flat offset of `[c, l, row, slot, 0, 0]` in a `[2, L, B, S, H*D]`
    /// tensor — the single source of truth for the host cache layout.
    /// `pub(crate)` so the host fast path (DESIGN.md §8) can read the
    /// tensor in place through a `Sync` view instead of copying it.
    pub(crate) fn flat_off(n_layers: usize, batch: usize, s_max: usize,
                           hd: usize, c: usize, l: usize, row: usize,
                           slot: usize) -> usize {
        (((c * n_layers + l) * batch + row) * s_max + slot) * hd
    }

    /// [`Self::flat_off`] with this cache's dimensions.
    pub(crate) fn host_off(&self, c: usize, l: usize, row: usize,
                           slot: usize) -> usize {
        Self::flat_off(self.n_layers, self.batch, self.s_max,
                       self.n_heads * self.d_head, c, l, row, slot)
    }

    /// Scatter staged K/V (`[L, b, t, H, D]`) into a host-backed cache
    /// at `pos` — the commit primitive shared by the reference backend
    /// and scripted test backends.  Later columns overwrite earlier
    /// ones at the same slot (only ever exercised at the garbage slot).
    pub fn host_scatter(&mut self, b: usize, t: usize, k: &[f32],
                        v: &[f32], pos: &[i32]) -> Result<()> {
        let hd = self.n_heads * self.d_head;
        anyhow::ensure!(b == self.batch, "batch mismatch: {b} vs cache {}",
                        self.batch);
        anyhow::ensure!(pos.len() == b * t, "pos len {} != b*t", pos.len());
        let want = self.n_layers * b * t * hd;
        anyhow::ensure!(k.len() == want && v.len() == want,
                        "staged kv len {} != {want}", k.len());
        let s_max = self.s_max;
        let n_layers = self.n_layers;
        let batch = self.batch;
        let data = match &mut self.state {
            CacheState::Host(d) => d,
            #[cfg(feature = "pjrt")]
            CacheState::Device(_) => {
                anyhow::bail!("host_scatter on a device cache")
            }
        };
        for l in 0..n_layers {
            for row in 0..b {
                for col in 0..t {
                    let slot = pos[row * t + col]
                        .clamp(0, s_max as i32 - 1) as usize;
                    let src = ((l * b + row) * t + col) * hd;
                    let kdst = Self::flat_off(n_layers, batch, s_max, hd,
                                              0, l, row, slot);
                    let vdst = Self::flat_off(n_layers, batch, s_max, hd,
                                              1, l, row, slot);
                    data[kdst..kdst + hd]
                        .copy_from_slice(&k[src..src + hd]);
                    data[vdst..vdst + hd]
                        .copy_from_slice(&v[src..src + hd]);
                }
            }
        }
        Ok(())
    }

    /// Read one `[H*D]` slot of a host-backed cache (`c`: 0 = K, 1 = V).
    /// Test/debug helper; `None` for device caches or out-of-range slots.
    pub fn host_kv(&self, c: usize, l: usize, row: usize, slot: usize)
                   -> Option<&[f32]> {
        if c >= 2 || l >= self.n_layers || row >= self.batch
            || slot >= self.s_max
        {
            return None;
        }
        let hd = self.n_heads * self.d_head;
        let off = self.host_off(c, l, row, slot);
        match &self.state {
            CacheState::Host(d) => d.get(off..off + hd),
            #[cfg(feature = "pjrt")]
            CacheState::Device(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 2,
            d_ff: 8,
            s_max: 6,
        }
    }

    #[test]
    fn host_scatter_places_rows() {
        let c = cfg();
        let mut cache = KvCache::host(&c, 2);
        let (b, t, hd) = (2usize, 2usize, 4usize);
        let n = c.n_layers * b * t * hd;
        // stage value encodes (layer, row, col)
        let k: Vec<f32> = (0..n)
            .map(|i| {
                let col = (i / hd) % t;
                let row = (i / (hd * t)) % b;
                let l = i / (hd * t * b);
                (l * 100 + row * 10 + col) as f32
            })
            .collect();
        let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
        // row 0 commits cols to slots 1,2; row 1 redirects col 1 to
        // the garbage slot
        let pos = [1, 2, 0, 5];
        cache.host_scatter(b, t, &k, &v, &pos).unwrap();
        assert_eq!(cache.host_kv(0, 0, 0, 1).unwrap()[0], 0.0);
        assert_eq!(cache.host_kv(0, 0, 0, 2).unwrap()[0], 1.0);
        assert_eq!(cache.host_kv(0, 1, 0, 2).unwrap()[0], 101.0);
        assert_eq!(cache.host_kv(0, 0, 1, 0).unwrap()[0], 10.0);
        assert_eq!(cache.host_kv(0, 0, 1, 5).unwrap()[0], 11.0);
        assert_eq!(cache.host_kv(1, 0, 0, 1).unwrap()[0], 0.5);
        // untouched slots stay zero
        assert_eq!(cache.host_kv(0, 0, 0, 3).unwrap()[0], 0.0);
    }

    #[test]
    fn slot_bounds() {
        let c = cfg();
        let cache = KvCache::host(&c, 1);
        assert_eq!(cache.garbage_slot(), 5);
        assert_eq!(cache.max_live_pos(), 4);
        assert!(cache.host_kv(0, 0, 0, 6).is_none());
        assert!(cache.host_kv(2, 0, 0, 0).is_none());
    }
}
