//! Paged KV cache: a shared block pool behind per-sequence block
//! tables (DESIGN.md §7).
//!
//! Logically the cache still holds `[2, L, B, S_max, H, D]` f32 — but
//! the host backing store is no longer a dense tensor with a
//! worst-case `S_max` row per batch slot.  Storage is a pool of
//! fixed-size blocks ([`KV_BLOCK`] slots each); every batch row owns a
//! [`BlockTable`] mapping logical slot `s` to `(block, s % KV_BLOCK)`,
//! so resident memory is proportional to *live tokens*, not to
//! `B × S_max`.  Blocks are taken from a free list as commits reach
//! new slots and returned when a sequence releases its row — the same
//! pool therefore sustains far more concurrent short sequences than
//! the dense layout could hold in the same memory budget.
//!
//! Admission is memory-bounded and preemption-free: [`KvCache::reserve_row`]
//! claims (but does not yet allocate) the worst-case block count a
//! sequence can touch, and [`KvCache::can_reserve`] is the batcher's
//! admission gate.  A reserved row can always take its blocks
//! mid-decode, so an admitted sequence never stalls on the pool; when
//! the unreserved headroom runs dry, new admissions wait instead.
//!
//! Speculative semantics are unchanged from the dense layout:
//! `cur_len[row]` is the committed length, slot `s` holds live data
//! for `s < cur_len`, and rejected speculative columns are *redirected*
//! at commit time rather than erased — `commit_pos` points them at the
//! reserved garbage position `S_max - 1`, which resolves to the row's
//! private write-only *garbage block*.  Queries can never attend it
//! because generation is capped at position `S_max - 2`.  Slots past
//! `cur_len` may hold stale junk (freed blocks are reused unzeroed);
//! the position mask keeps them unattendable until re-fed, exactly as
//! before.
//!
//! **Prefix sharing** (`--prefix-cache`, DESIGN.md §7): with sharing
//! enabled, a released row's *full* committed blocks stay registered
//! in a content index keyed by the chained hash of the token prefix
//! they hold (the cache is per model, so the key is effectively
//! `(model, token-prefix)`).  [`KvCache::reserve_row_prefixed`] maps
//! the longest cached block-aligned prefix of a new prompt straight
//! into the row's table — several rows then *share* physical blocks,
//! refcounted, and the engine prefills only the uncached suffix.
//! Bit-identity holds because a shared block contains exactly the
//! bytes a private dense prefill would produce: same tokens, same
//! positions, deterministic weights ⇒ identical K/V.  Registered
//! blocks nobody references sit on an LRU list: reusable by the next
//! prefix hit, evicted (oldest first) when the free list runs dry.
//! Commits into a block a row shares trigger **copy-on-write** — a
//! safety net the engine protocol never exercises (only full blocks
//! are shared and commits never land below `cur_len`), kept so a
//! buggy or future caller can never corrupt another row's prefix.
//!
//! The PJRT device cache (feature `pjrt`) keeps its dense
//! device-resident layout; the paged machinery is host-side state and
//! degenerates to no-ops there.

use std::collections::{BTreeMap, VecDeque};

use anyhow::Result;

use super::artifact::ModelCfg;

/// Slots per KV block.  16 lines up with the host path's `PANEL`
/// (one 64-byte cache line of f32 per `H·D` multiple) and divides
/// every synthetic-family `S_max`.
pub const KV_BLOCK: usize = 16;

/// The backing store for the logical `[2, L, B, S, H, D]` tensor.
pub enum CacheState {
    /// Host-resident block pool: `[n_blocks, 2, L, KV_BLOCK, H*D]`
    /// row-major (reference backend, host fast path, test fakes).
    Host(Vec<f32>),
    /// Device-resident PJRT buffer, dense `[2, L, B, S, H, D]`
    /// (never crosses to the host).
    #[cfg(feature = "pjrt")]
    Device(xla::PjRtBuffer),
}

/// Chain-hash seed of the empty prefix (FNV-1a offset basis): block 0
/// of every row hashes against this parent.
const PREFIX_CHAIN_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// One link of the prefix chain hash: fold `tokens` into `parent`
/// (FNV-1a over the little-endian token bytes).  The chain makes a
/// block's key depend on its *entire* token history, which is what
/// K/V bytes at a slot actually depend on.
fn chain_hash(parent: u64, tokens: &[i32]) -> u64 {
    let mut h = parent ^ 0x9e37_79b9_7f4a_7c15;
    for &t in tokens {
        // i32::to_le_bytes is bit-identical to the old `as u32`
        // round-trip (two's complement), so chain hashes — and every
        // prefix-cache key — are unchanged.
        for b in t.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Registration record of a cached full block: the chain hash it is
/// indexed under, its parent's chain hash, and the [`KV_BLOCK`] tokens
/// it holds.  Lookups verify `parent` and `tokens` (not just the
/// 64-bit key), so a hash collision cannot alias two different
/// prefixes — the chain below the match was verified the same way.
#[derive(Debug, Clone)]
struct BlockMeta {
    hash: u64,
    parent: u64,
    tokens: Vec<i32>,
}

/// One batch row's view of the pool: which physical block backs each
/// logical [`KV_BLOCK`]-slot range, plus the row's private garbage
/// block and its outstanding admission reservation.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    /// `blocks[i]` backs logical slots `i*KV_BLOCK .. (i+1)*KV_BLOCK`.
    blocks: Vec<u32>,
    /// Write-only destination for rejected speculative columns
    /// (allocated on the row's first garbage-redirected commit).
    garbage: Option<u32>,
    /// Blocks this row may still take against its admission
    /// reservation before it has to compete for unreserved headroom.
    reserved: usize,
}

/// One model's KV cache: a block pool plus per-row tables and
/// committed lengths.  The speculative commit contract (garbage
/// redirection, stale-slot reuse) is documented at module level and in
/// DESIGN.md §7.
pub struct KvCache {
    /// Backend-private backing store (host block pool / device buffer).
    pub state: CacheState,
    /// Batch rows `B` this cache was built for.
    pub batch: usize,
    /// Logical slot capacity `S_max`; position `S_max - 1` is the
    /// write-only garbage redirect, so live positions are capped at
    /// `S_max - 2`.
    pub s_max: usize,
    /// Cached layers `L`.
    pub n_layers: usize,
    /// Attention heads `H` per layer.
    pub n_heads: usize,
    /// Head dimension `D`.
    pub d_head: usize,
    /// Committed sequence length per batch row: slot `s < cur_len[row]`
    /// always holds live data; slots at or past it are stale until the
    /// engine re-feeds real tokens over them.
    pub cur_len: Vec<u32>,
    /// False for the dense device cache, where the block machinery is
    /// inert (tables empty, reservations always succeed).
    paged: bool,
    /// Total pool blocks.
    n_blocks: usize,
    /// Unallocated block ids with no cached content (LIFO; freed
    /// blocks are reused unzeroed).
    free: Vec<u32>,
    /// Sum of all rows' outstanding reservations; the invariant
    /// `reclaimable() >= reserved_total` is what makes admitted rows
    /// stall-free (LRU-cached blocks are evictable on demand).
    reserved_total: usize,
    /// Per-row block tables.
    tables: Vec<BlockTable>,
    /// High-water mark of allocated blocks over this cache's lifetime.
    peak_in_use: usize,
    /// Prefix sharing enabled (`--prefix-cache`): released rows
    /// register their full committed blocks for reuse.
    sharing: bool,
    /// Row-table references per pool block (a block shared by two rows
    /// counts 2; garbage blocks count 1; free/LRU blocks 0).
    ref_count: Vec<u32>,
    /// Prefix-chain hash → cached block id (one block per hash).
    index: BTreeMap<u64, u32>,
    /// Registration record per block (`None` = unregistered).
    meta: Vec<Option<BlockMeta>>,
    /// Registered blocks no row references, oldest first: reusable by
    /// a prefix hit, evicted from the front when `free` runs dry.
    lru: VecDeque<u32>,
    /// Cumulative prompt tokens served from cached blocks at admit.
    prefix_hits: u64,
    /// Cumulative copy-on-write block copies.
    cow: u64,
}

impl KvCache {
    /// Host-backed paged cache with capacity parity to the old dense
    /// layout: every row can still grow to the full `S_max` window
    /// (plus its garbage block), so closed-batch callers never hit the
    /// pool limit.  Serving paths size the pool explicitly via
    /// [`KvCache::host_paged`] (`--kv-blocks`).
    pub fn host(cfg: &ModelCfg, batch: usize) -> Self {
        let per_row = cfg.s_max.div_ceil(KV_BLOCK) + 1;
        Self::host_paged(cfg, batch, batch * per_row)
            .expect("parity-sized pool is always valid")
    }

    /// Host-backed paged cache over an explicitly sized pool of
    /// `n_blocks` blocks shared by all `batch` rows.  The pool must
    /// hold at least one live block and one garbage block.
    pub fn host_paged(cfg: &ModelCfg, batch: usize, n_blocks: usize)
                      -> Result<Self> {
        anyhow::ensure!(n_blocks >= 2,
                        "--kv-blocks must be >= 2 (1 live + 1 garbage), \
                         got {n_blocks}");
        let hd = cfg.n_heads * cfg.d_head;
        let block_elems = 2 * cfg.n_layers * KV_BLOCK * hd;
        let top = u32::try_from(n_blocks).map_err(|_| {
            anyhow::anyhow!("--kv-blocks {n_blocks} exceeds u32")
        })?;
        Ok(KvCache {
            state: CacheState::Host(vec![0f32; n_blocks * block_elems]),
            batch,
            s_max: cfg.s_max,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            d_head: cfg.d_head,
            cur_len: vec![0; batch],
            paged: true,
            n_blocks,
            // LIFO from the low end so block 0 is handed out first.
            free: (0..top).rev().collect(),
            reserved_total: 0,
            tables: vec![BlockTable::default(); batch],
            peak_in_use: 0,
            sharing: false,
            ref_count: vec![0; n_blocks],
            index: BTreeMap::new(),
            meta: vec![None; n_blocks],
            lru: VecDeque::new(),
            prefix_hits: 0,
            cow: 0,
        })
    }

    /// Device-backed cache (PJRT): dense `[2, L, B, S, H, D]` on the
    /// device, block machinery inert.
    #[cfg(feature = "pjrt")]
    pub fn device(client: &xla::PjRtClient, cfg: &ModelCfg, batch: usize)
                  -> Result<Self> {
        let n = 2 * cfg.n_layers * batch * cfg.s_max * cfg.n_heads
            * cfg.d_head;
        let zeros = vec![0f32; n];
        let dims = [2, cfg.n_layers, batch, cfg.s_max, cfg.n_heads,
                    cfg.d_head];
        let buf = client.buffer_from_host_buffer(&zeros, &dims, None)?;
        Ok(KvCache {
            state: CacheState::Device(buf),
            batch,
            s_max: cfg.s_max,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            d_head: cfg.d_head,
            cur_len: vec![0; batch],
            paged: false,
            n_blocks: 0,
            free: Vec::new(),
            reserved_total: 0,
            tables: vec![BlockTable::default(); batch],
            peak_in_use: 0,
            sharing: false,
            ref_count: Vec::new(),
            index: BTreeMap::new(),
            meta: Vec::new(),
            lru: VecDeque::new(),
            prefix_hits: 0,
            cow: 0,
        })
    }

    /// The reserved write-only position rejected speculative columns
    /// are redirected to (resolves to the row's garbage block).
    pub fn garbage_slot(&self) -> i32 {
        i32::try_from(self.s_max - 1).expect("s_max fits i32")
    }

    /// Highest position a live token may occupy.
    pub fn max_live_pos(&self) -> u32 {
        u32::try_from(self.s_max - 2).expect("s_max fits u32")
    }

    /// Positions `row` can still commit before hitting the window.
    pub fn headroom(&self, row: usize) -> u32 {
        self.max_live_pos().saturating_sub(self.cur_len[row])
    }

    /// Floats per pool block: `[2, L, KV_BLOCK, H*D]`.
    pub(crate) fn block_elems(&self) -> usize {
        2 * self.n_layers * KV_BLOCK * self.n_heads * self.d_head
    }

    /// Pool blocks a sequence of `len` slots needs, including its
    /// garbage block (`len` is capped at the logical window).
    pub fn blocks_for(&self, len: usize) -> usize {
        len.min(self.s_max - 1).div_ceil(KV_BLOCK) + 1
    }

    /// Total pool blocks.
    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Currently allocated blocks: referenced by a row table (shared
    /// blocks count once).  Cached-but-unreferenced (LRU) blocks are
    /// reclaimable and do not count.
    pub fn blocks_in_use(&self) -> usize {
        self.n_blocks - self.free.len() - self.lru.len()
    }

    /// Lifetime high-water mark of [`KvCache::blocks_in_use`].
    pub fn peak_blocks(&self) -> usize {
        self.peak_in_use
    }

    /// Blocks an allocation can draw from: the free list plus the
    /// cached-but-unreferenced LRU blocks (evictable on demand).
    fn reclaimable(&self) -> usize {
        self.free.len() + self.lru.len()
    }

    /// Reclaimable blocks not promised to any admitted row — the
    /// headroom new admissions draw from.
    pub fn unreserved_free(&self) -> usize {
        self.reclaimable() - self.reserved_total
    }

    /// Memory-bounded admission gate: can a sequence of up to `len`
    /// slots be admitted right now without eating another admitted
    /// row's reservation?  Always true on non-paged (device) caches.
    pub fn can_reserve(&self, len: usize) -> bool {
        !self.paged || self.unreserved_free() >= self.blocks_for(len)
    }

    /// Admission arithmetic shared by the gate and the reservation —
    /// ONE definition so `can_reserve_prefixed == true` always implies
    /// `reserve_row_prefixed` succeeds (the batcher's backpressure
    /// contract): the matched prefix blocks, how many of them would
    /// leave the LRU (shrinking the reclaimable pool), and the
    /// fresh-block need for `len` slots past them.
    fn admission_plan(&self, tokens: &[i32], len: usize)
                      -> (Vec<u32>, usize, usize) {
        let blocks = self.match_blocks(tokens);
        let from_lru = blocks
            .iter()
            .filter(|&&b| self.ref_count[b as usize] == 0)
            .count();
        let need = self.blocks_for(len).saturating_sub(blocks.len());
        (blocks, from_lru, need)
    }

    /// Does a plan from [`KvCache::admission_plan`] fit the pool right
    /// now without eating another admitted row's reservation?
    fn admission_fits(&self, from_lru: usize, need: usize) -> bool {
        self.reclaimable() - from_lru >= self.reserved_total + need
    }

    /// [`KvCache::can_reserve`] over shared headroom: a prompt whose
    /// prefix is cached needs only its uncached remainder of fresh
    /// blocks (matched blocks are shared and counted once).
    pub fn can_reserve_prefixed(&self, tokens: &[i32], len: usize)
                                -> bool {
        if !self.paged {
            return true;
        }
        let (_, from_lru, need) = self.admission_plan(tokens, len);
        self.admission_fits(from_lru, need)
    }

    /// Enable/disable prefix sharing (no-op on non-paged caches):
    /// released rows register their full committed blocks, and
    /// [`KvCache::reserve_row_prefixed`] serves prefix hits from them.
    pub fn set_prefix_sharing(&mut self, on: bool) {
        self.sharing = on && self.paged;
    }

    /// Cumulative prompt tokens served from cached prefix blocks.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.prefix_hits
    }

    /// Cumulative copy-on-write block copies (0 under the engine
    /// protocol — see the module docs).
    pub fn cow_copies(&self) -> u64 {
        self.cow
    }

    /// Extra row-table references onto shared blocks right now: a
    /// block mapped by `r` rows contributes `r - 1`.
    pub fn blocks_shared(&self) -> usize {
        self.ref_count
            .iter()
            .filter(|&&r| r > 1)
            .map(|&r| r as usize - 1)
            .sum()
    }

    /// Drop the content registration of `blk` (about to be evicted or
    /// overwritten): its bytes no longer answer for any prefix.
    fn unregister(&mut self, blk: u32) {
        if let Some(m) = self.meta[blk as usize].take() {
            self.index.remove(&m.hash);
        }
    }

    /// Walk the prefix chain of `tokens` through the content index and
    /// return the cached block ids covering its longest block-aligned
    /// proper prefix.  Proper: at least one suffix token is always
    /// left for the caller to prefill (first-token logits need it).
    fn match_blocks(&self, tokens: &[i32]) -> Vec<u32> {
        let mut blocks = Vec::new();
        if !self.sharing || tokens.is_empty() {
            return blocks;
        }
        let nb_max = (tokens.len() - 1) / KV_BLOCK;
        let mut parent = PREFIX_CHAIN_SEED;
        for i in 0..nb_max {
            let toks = &tokens[i * KV_BLOCK..(i + 1) * KV_BLOCK];
            let h = chain_hash(parent, toks);
            match self.index.get(&h) {
                Some(&blk)
                    if self.meta[blk as usize]
                        .as_ref()
                        .is_some_and(|m| m.parent == parent
                                     && m.tokens == toks) =>
                {
                    blocks.push(blk);
                    parent = h;
                }
                _ => break,
            }
        }
        blocks
    }

    /// Longest cached block-aligned proper prefix of `tokens`, in
    /// tokens (0 with sharing disabled or on a miss).
    pub fn prefix_match(&self, tokens: &[i32]) -> usize {
        self.match_blocks(tokens).len() * KV_BLOCK
    }

    /// [`KvCache::reserve_row`] with prefix reuse: map the longest
    /// cached block-aligned prefix of `tokens` into the row's table —
    /// sharing the physical blocks, refcounted, counted once by the
    /// admission accounting — and reserve only the remaining worst
    /// case for `len` slots.  Returns the number of prefix tokens
    /// served from cache; the caller prefills only `tokens[hit..]`
    /// (always at least the final token).  With sharing off (or a
    /// miss) this is exactly [`KvCache::reserve_row`].
    pub fn reserve_row_prefixed(&mut self, row: usize, tokens: &[i32],
                                len: usize) -> Result<usize> {
        self.release_row(row);
        if !self.paged {
            return Ok(0);
        }
        let (blocks, from_lru, need) = self.admission_plan(tokens, len);
        anyhow::ensure!(
            self.admission_fits(from_lru, need),
            "kv block pool exhausted: row wants {need} fresh blocks \
             past a {}-block prefix hit, {} unreserved of {} \
             reclaimable (pool {})",
            blocks.len(), self.unreserved_free(), self.reclaimable(),
            self.n_blocks
        );
        let matched = blocks.len() * KV_BLOCK;
        for blk in blocks {
            if self.ref_count[blk as usize] == 0 {
                self.lru.retain(|&b| b != blk);
            }
            self.ref_count[blk as usize] += 1;
            self.tables[row].blocks.push(blk);
        }
        self.peak_in_use = self.peak_in_use.max(self.blocks_in_use());
        self.tables[row].reserved = need;
        self.reserved_total += need;
        self.cur_len[row] =
            u32::try_from(matched).expect("prefix hit fits u32");
        self.prefix_hits += matched as u64;
        Ok(matched)
    }

    /// Admit a sequence into `row`: release whatever the row held and
    /// reserve the worst-case block count for `len` slots.  Reserved
    /// blocks are allocated lazily as commits reach them, so resident
    /// memory tracks live tokens while the reservation guarantees the
    /// row never stalls mid-decode.  Fails when the pool's unreserved
    /// headroom is too small (the batcher's backpressure signal).
    pub fn reserve_row(&mut self, row: usize, len: usize) -> Result<()> {
        self.release_row(row);
        if !self.paged {
            return Ok(());
        }
        let need = self.blocks_for(len);
        anyhow::ensure!(
            self.unreserved_free() >= need,
            "kv block pool exhausted: row wants {need} blocks, \
             {} unreserved of {} free (pool {})",
            self.unreserved_free(), self.free.len(), self.n_blocks
        );
        self.tables[row].reserved = need;
        self.reserved_total += need;
        Ok(())
    }

    /// Drop one row-table reference on `blk`; the last reference sends
    /// the block to the LRU list when its content is registered (so a
    /// later prefix hit can revive it), to the free list otherwise.
    fn drop_ref(&mut self, blk: u32) {
        let rc = &mut self.ref_count[blk as usize];
        debug_assert!(*rc > 0, "unbalanced block refcount");
        *rc -= 1;
        if *rc == 0 {
            if self.meta[blk as usize].is_some() {
                self.lru.push_back(blk);
            } else {
                self.free.push(blk);
            }
        }
    }

    /// Return `row`'s blocks (live + garbage) and any outstanding
    /// reservation to the pool; the row's committed length resets.
    /// Freed blocks are reused unzeroed — the position-mask contract
    /// makes stale content unattendable (module docs).  Under prefix
    /// sharing, blocks other rows still reference stay allocated, and
    /// registered blocks park on the LRU list instead of freeing.
    pub fn release_row(&mut self, row: usize) {
        let blocks = std::mem::take(&mut self.tables[row].blocks);
        let garbage = self.tables[row].garbage.take();
        let reserved = std::mem::take(&mut self.tables[row].reserved);
        self.reserved_total -= reserved;
        for blk in blocks.into_iter().chain(garbage) {
            self.drop_ref(blk);
        }
        self.cur_len[row] = 0;
    }

    /// [`KvCache::release_row`] that first registers the row's full
    /// committed blocks in the prefix index (no-op with sharing off).
    /// `tokens` is the row's committed stream; only blocks entirely
    /// below the committed length are cacheable — their 16 slots all
    /// hold live K/V for exactly these tokens.
    pub fn release_row_cached(&mut self, row: usize, tokens: &[i32]) {
        if self.paged && self.sharing {
            let n = (self.cur_len[row] as usize).min(tokens.len());
            let full = (n / KV_BLOCK).min(self.tables[row].blocks.len());
            let mut parent = PREFIX_CHAIN_SEED;
            for i in 0..full {
                let toks = &tokens[i * KV_BLOCK..(i + 1) * KV_BLOCK];
                let h = chain_hash(parent, toks);
                let blk = self.tables[row].blocks[i];
                // First block in wins; an identical-content duplicate
                // stays unregistered and frees normally.
                if self.meta[blk as usize].is_none()
                    && !self.index.contains_key(&h)
                {
                    self.meta[blk as usize] = Some(BlockMeta {
                        hash: h,
                        parent,
                        tokens: toks.to_vec(),
                    });
                    self.index.insert(h, blk);
                }
                parent = h;
            }
        }
        self.release_row(row);
    }

    /// Take one block for `row`: against its reservation when one is
    /// outstanding, else from the unreserved headroom.  Draws from the
    /// free list first, then evicts the least-recently-cached LRU
    /// block.  Errors only when the pool is truly dry — an admitted
    /// (reserved) row cannot hit this.
    fn take_block(&mut self, row: usize) -> Result<u32> {
        let from_reservation = self.tables[row].reserved > 0;
        anyhow::ensure!(
            if from_reservation {
                self.reclaimable() > 0
            } else {
                self.reclaimable() > self.reserved_total
            },
            "kv block pool exhausted ({} blocks, {} reclaimable, \
             {} reserved) — admit fewer sequences or raise --kv-blocks",
            self.n_blocks, self.reclaimable(), self.reserved_total
        );
        let blk = match self.free.pop() {
            Some(b) => b,
            None => {
                let b = self.lru.pop_front()
                    .expect("reclaimable > 0 with free empty");
                self.unregister(b);
                b
            }
        };
        if from_reservation {
            self.tables[row].reserved -= 1;
            self.reserved_total -= 1;
        }
        self.peak_in_use = self.peak_in_use.max(self.blocks_in_use());
        Ok(blk)
    }

    /// Extend `row`'s table until logical `slot` is mapped.
    fn ensure_covered(&mut self, row: usize, slot: usize) -> Result<()> {
        while self.tables[row].blocks.len() * KV_BLOCK <= slot {
            let blk = self.take_block(row)?;
            self.ref_count[blk as usize] = 1;
            self.tables[row].blocks.push(blk);
        }
        Ok(())
    }

    /// Allocate `row`'s garbage block if it doesn't exist yet.
    fn ensure_garbage(&mut self, row: usize) -> Result<()> {
        if self.tables[row].garbage.is_none() {
            let blk = self.take_block(row)?;
            self.ref_count[blk as usize] = 1;
            self.tables[row].garbage = Some(blk);
        }
        Ok(())
    }

    /// Copy-on-write: give `row` a private copy of the shared block
    /// backing its logical block `lb` before a write diverges it.  The
    /// other rows keep the original bytes untouched.
    fn cow_copy(&mut self, row: usize, lb: usize) -> Result<()> {
        let old = self.tables[row].blocks[lb] as usize;
        let fresh = self.take_block(row)?;
        let be = self.block_elems();
        let data = match &mut self.state {
            CacheState::Host(d) => d,
            #[cfg(feature = "pjrt")]
            CacheState::Device(_) => {
                anyhow::bail!("copy-on-write on a device cache")
            }
        };
        data.copy_within(old * be..(old + 1) * be,
                         fresh as usize * be);
        self.ref_count[old] -= 1;
        self.ref_count[fresh as usize] = 1;
        self.tables[row].blocks[lb] = fresh;
        self.cow += 1;
        Ok(())
    }

    /// `row`'s live block table (logical order) — the host fast path
    /// builds its in-place read map from this.
    pub(crate) fn row_blocks(&self, row: usize) -> &[u32] {
        &self.tables[row].blocks
    }

    /// Flat offset of `[c, l, row, slot]`'s `H*D` vector in the host
    /// pool, resolved through the row's block table; `None` when the
    /// slot is unmapped (never committed — unattendable by contract).
    /// The single source of truth for the paged layout.
    pub(crate) fn slot_index(&self, c: usize, l: usize, row: usize,
                             slot: usize) -> Option<usize> {
        let t = &self.tables[row];
        let (blk, off) = if slot == self.s_max - 1 {
            (t.garbage?, slot % KV_BLOCK)
        } else {
            (*t.blocks.get(slot / KV_BLOCK)?, slot % KV_BLOCK)
        };
        let hd = self.n_heads * self.d_head;
        Some(blk as usize * self.block_elems()
             + ((c * self.n_layers + l) * KV_BLOCK + off) * hd)
    }

    /// Scatter staged K/V (`[L, b, t, H, D]`) into a host-backed cache
    /// at `pos` — the commit primitive shared by the reference and
    /// host backends and scripted test fakes.  Live slots allocate
    /// blocks on demand through the row's table; columns redirected to
    /// the garbage position land in the row's garbage block (dropped
    /// entirely for rows that hold no storage at all — a parked batch
    /// row costs zero blocks).  Later columns overwrite earlier ones
    /// at the same slot (only ever exercised at the garbage redirect).
    pub fn host_scatter(&mut self, b: usize, t: usize, k: &[f32],
                        v: &[f32], pos: &[i32]) -> Result<()> {
        let hd = self.n_heads * self.d_head;
        anyhow::ensure!(b == self.batch, "batch mismatch: {b} vs cache {}",
                        self.batch);
        anyhow::ensure!(pos.len() == b * t, "pos len {} != b*t", pos.len());
        let want = self.n_layers * b * t * hd;
        anyhow::ensure!(k.len() == want && v.len() == want,
                        "staged kv len {} != {want}", k.len());
        anyhow::ensure!(
            matches!(self.state, CacheState::Host(_)),
            "host_scatter on a device cache"
        );
        let s_max = self.s_max;
        let garbage = s_max - 1;
        let max_slot = i32::try_from(garbage).map_err(|_| {
            anyhow::anyhow!("s_max {s_max} exceeds i32")
        })?;
        // Pass 1 — resolve every column to (block, in-block offset),
        // allocating on demand.  Garbage writes to a row with no
        // storage (never admitted / already released) are dropped:
        // the garbage block is write-only, so nothing can observe the
        // difference, and parked rows stay at zero blocks.
        let mut dest: Vec<Option<(usize, usize)>> =
            Vec::with_capacity(b * t);
        for row in 0..b {
            for col in 0..t {
                let slot =
                    pos[row * t + col].clamp(0, max_slot) as usize;
                let blk = if slot == garbage {
                    let tab = &self.tables[row];
                    let live = !tab.blocks.is_empty()
                        || tab.garbage.is_some()
                        || tab.reserved > 0;
                    if live {
                        self.ensure_garbage(row)?;
                    }
                    self.tables[row].garbage
                } else {
                    self.ensure_covered(row, slot)?;
                    let lb = slot / KV_BLOCK;
                    let blk = self.tables[row].blocks[lb];
                    if self.ref_count[blk as usize] > 1 {
                        // the row shares this block: copy-on-write so
                        // the other rows' prefix bytes stay intact
                        self.cow_copy(row, lb)?;
                    } else if self.meta[blk as usize].is_some() {
                        // sole owner writing into a registered block:
                        // its bytes will no longer answer for the
                        // registered prefix — unregister it.
                        self.unregister(blk);
                    }
                    Some(self.tables[row].blocks[lb])
                };
                dest.push(
                    blk.map(|id| (id as usize, slot % KV_BLOCK)));
            }
        }
        // Pass 2 — copy, same (l, row, col) order as the dense layout
        // so overwrite semantics at a shared cell are unchanged.
        let (n_layers, block_elems) = (self.n_layers, self.block_elems());
        let data = match &mut self.state {
            CacheState::Host(d) => d,
            #[cfg(feature = "pjrt")]
            CacheState::Device(_) => unreachable!("checked above"),
        };
        for l in 0..n_layers {
            for row in 0..b {
                for col in 0..t {
                    let Some((blk, off)) = dest[row * t + col] else {
                        continue;
                    };
                    let src = ((l * b + row) * t + col) * hd;
                    let base = blk * block_elems;
                    let kdst = base + (l * KV_BLOCK + off) * hd;
                    let vdst = base
                        + ((n_layers + l) * KV_BLOCK + off) * hd;
                    data[kdst..kdst + hd]
                        .copy_from_slice(&k[src..src + hd]);
                    data[vdst..vdst + hd]
                        .copy_from_slice(&v[src..src + hd]);
                }
            }
        }
        Ok(())
    }

    /// Read one `[H*D]` slot of a host-backed cache (`c`: 0 = K, 1 = V)
    /// through the row's block table.  Test/debug helper; `None` for
    /// device caches, out-of-range arguments, or unmapped slots.
    pub fn host_kv(&self, c: usize, l: usize, row: usize, slot: usize)
                   -> Option<&[f32]> {
        if c >= 2 || l >= self.n_layers || row >= self.batch
            || slot >= self.s_max
        {
            return None;
        }
        let hd = self.n_heads * self.d_head;
        let off = self.slot_index(c, l, row, slot)?;
        match &self.state {
            CacheState::Host(d) => d.get(off..off + hd),
            #[cfg(feature = "pjrt")]
            CacheState::Device(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 2,
            d_ff: 8,
            s_max: 6,
        }
    }

    /// A config whose window spans several blocks (s_max = 96).
    fn big_cfg() -> ModelCfg {
        ModelCfg { s_max: 96, ..cfg() }
    }

    #[test]
    fn host_scatter_places_rows() {
        let c = cfg();
        let mut cache = KvCache::host(&c, 2);
        let (b, t, hd) = (2usize, 2usize, 4usize);
        let n = c.n_layers * b * t * hd;
        // stage value encodes (layer, row, col)
        let k: Vec<f32> = (0..n)
            .map(|i| {
                let col = (i / hd) % t;
                let row = (i / (hd * t)) % b;
                let l = i / (hd * t * b);
                (l * 100 + row * 10 + col) as f32
            })
            .collect();
        let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
        // row 0 commits cols to slots 1,2; row 1 redirects col 1 to
        // the garbage position (its garbage block)
        let pos = [1, 2, 0, 5];
        cache.host_scatter(b, t, &k, &v, &pos).unwrap();
        assert_eq!(cache.host_kv(0, 0, 0, 1).unwrap()[0], 0.0);
        assert_eq!(cache.host_kv(0, 0, 0, 2).unwrap()[0], 1.0);
        assert_eq!(cache.host_kv(0, 1, 0, 2).unwrap()[0], 101.0);
        assert_eq!(cache.host_kv(0, 0, 1, 0).unwrap()[0], 10.0);
        assert_eq!(cache.host_kv(0, 0, 1, 5).unwrap()[0], 11.0);
        assert_eq!(cache.host_kv(1, 0, 0, 1).unwrap()[0], 0.5);
        // untouched slots in a mapped block stay zero (fresh pool)
        assert_eq!(cache.host_kv(0, 0, 0, 3).unwrap()[0], 0.0);
        // row 0 never wrote garbage: no garbage block was allocated
        assert!(cache.host_kv(0, 0, 0, 5).is_none());
    }

    #[test]
    fn slot_bounds() {
        let c = cfg();
        let cache = KvCache::host(&c, 1);
        assert_eq!(cache.garbage_slot(), 5);
        assert_eq!(cache.max_live_pos(), 4);
        assert!(cache.host_kv(0, 0, 0, 6).is_none());
        assert!(cache.host_kv(2, 0, 0, 0).is_none());
        // fresh row: nothing mapped yet
        assert!(cache.host_kv(0, 0, 0, 0).is_none());
    }

    #[test]
    fn blocks_free_and_reuse_across_rows() {
        let c = big_cfg();
        // 3 blocks: enough for ONE row of ≤16 live slots + garbage,
        // with one block spare.
        let mut cache = KvCache::host_paged(&c, 1, 3).unwrap();
        let hd = 4;
        let stage = vec![1.5f32; c.n_layers * hd];
        for round in 0..4 {
            cache.reserve_row(0, 10).unwrap();
            cache.host_scatter(1, 1, &stage, &stage, &[3]).unwrap();
            cache
                .host_scatter(1, 1, &stage, &stage,
                              &[cache.garbage_slot()])
                .unwrap();
            assert_eq!(cache.blocks_in_use(), 2, "round {round}");
            cache.release_row(0);
            assert_eq!(cache.blocks_in_use(), 0,
                       "release must return blocks to the pool");
        }
        assert_eq!(cache.peak_blocks(), 2);
    }

    #[test]
    fn reservation_gates_admission_and_guarantees_growth() {
        let c = big_cfg();
        // Pool of 4; a 20-slot sequence needs ceil(20/16)+1 = 3.
        let mut cache = KvCache::host_paged(&c, 2, 4).unwrap();
        assert!(cache.can_reserve(20));
        cache.reserve_row(0, 20).unwrap();
        // Only 1 unreserved block left: a second 20-slot row must wait.
        assert!(!cache.can_reserve(20));
        assert!(cache.reserve_row(1, 20).is_err());
        // The admitted row can still take every reserved block.
        let hd = 4;
        let stage = vec![2.0f32; c.n_layers * hd];
        cache.host_scatter(2, 1, &stage.repeat(2), &stage.repeat(2),
                           &[19, cache.garbage_slot()])
            .unwrap();
        cache.host_scatter(2, 1, &stage.repeat(2), &stage.repeat(2),
                           &[cache.garbage_slot(),
                             cache.garbage_slot()])
            .unwrap();
        assert_eq!(cache.blocks_in_use(), 3,
                   "two live blocks + row 0's garbage block");
        cache.release_row(0);
        assert!(cache.can_reserve(20), "release restores admission");
    }

    #[test]
    fn pool_exhaustion_is_an_error_not_a_corruption() {
        let c = big_cfg();
        let mut cache = KvCache::host_paged(&c, 1, 2).unwrap();
        let hd = 4;
        let stage = vec![1.0f32; c.n_layers * hd];
        // Block 0 -> slots 0..16, block 1 -> garbage; slot 16 must fail.
        cache.host_scatter(1, 1, &stage, &stage, &[0]).unwrap();
        cache
            .host_scatter(1, 1, &stage, &stage, &[cache.garbage_slot()])
            .unwrap();
        let err = cache
            .host_scatter(1, 1, &stage, &stage, &[16])
            .unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        // earlier writes intact
        assert_eq!(cache.host_kv(0, 0, 0, 0).unwrap()[0], 1.0);
    }

    #[test]
    fn parked_rows_cost_zero_blocks() {
        let c = big_cfg();
        let mut cache = KvCache::host_paged(&c, 4, 8).unwrap();
        let hd = 4;
        let n = c.n_layers * 4 * hd;
        let stage = vec![3.0f32; n];
        let g = cache.garbage_slot();
        // one live row, three parked rows writing garbage only
        cache.host_scatter(4, 1, &stage, &stage, &[0, g, g, g]).unwrap();
        assert_eq!(cache.blocks_in_use(), 1,
                   "parked rows must not allocate garbage blocks");
        assert!(cache.host_kv(0, 0, 1, g as usize).is_none());
    }

    /// Commit `tokens.len()` live slots into `row` of a batch-2 cache
    /// (the other row parked at the garbage redirect), staging value
    /// `base + slot` at every cell so divergence is observable.
    fn commit_row(cache: &mut KvCache, row: usize, n: usize, base: f32) {
        let hd = cache.n_heads * cache.d_head;
        let b = cache.batch;
        let g = cache.garbage_slot();
        for slot in 0..n {
            let mut k = vec![0f32; cache.n_layers * b * hd];
            for l in 0..cache.n_layers {
                let off = (l * b + row) * hd;
                k[off..off + hd].fill(base + slot as f32);
            }
            let mut pos = vec![g; b];
            pos[row] = slot as i32;
            cache.host_scatter(b, 1, &k, &k, &pos).unwrap();
        }
        cache.cur_len[row] = n as u32;
    }

    #[test]
    fn prefix_chain_verifies_tokens_not_just_hashes() {
        let c = big_cfg();
        let mut cache = KvCache::host_paged(&c, 2, 8).unwrap();
        cache.set_prefix_sharing(true);
        let tokens: Vec<i32> = (0..40).map(|i| 12 + i).collect();
        cache.reserve_row(0, 42).unwrap();
        commit_row(&mut cache, 0, 40, 100.0);
        cache.release_row_cached(0, &tokens);
        // 40 committed tokens = 2 full blocks = 32 cacheable tokens
        assert_eq!(cache.prefix_match(&tokens), 32);
        // a proper prefix never swallows the whole prompt
        assert_eq!(cache.prefix_match(&tokens[..32]), 16);
        assert_eq!(cache.prefix_match(&tokens[..16]), 0);
        // divergence inside block 1 keeps only block 0
        let mut fork = tokens.clone();
        fork[20] += 1;
        assert_eq!(cache.prefix_match(&fork), 16);
        // divergence inside block 0 kills the chain entirely
        fork = tokens.clone();
        fork[3] += 1;
        assert_eq!(cache.prefix_match(&fork), 0);
        // sharing off: the same index answers no hits
        cache.set_prefix_sharing(false);
        assert_eq!(cache.prefix_match(&tokens), 0);
    }

    #[test]
    fn prefix_hit_maps_shared_blocks_and_reserves_the_rest() {
        let c = big_cfg();
        let mut cache = KvCache::host_paged(&c, 8, 8).unwrap();
        cache.set_prefix_sharing(true);
        let tokens: Vec<i32> = (0..40).map(|i| 12 + i).collect();
        cache.reserve_row(0, 42).unwrap();
        commit_row(&mut cache, 0, 40, 100.0);
        cache.release_row_cached(0, &tokens);
        assert_eq!(cache.blocks_in_use(), 0,
                   "cached blocks are reclaimable, not in use");
        // two rows admit the same prompt: both map the 2 cached blocks
        let hit0 = cache.reserve_row_prefixed(0, &tokens, 42).unwrap();
        let hit1 = cache.reserve_row_prefixed(1, &tokens, 42).unwrap();
        assert_eq!((hit0, hit1), (32, 32));
        assert_eq!(cache.cur_len[0], 32);
        assert_eq!(cache.blocks_shared(), 2,
                   "each shared block carries one extra reference");
        assert_eq!(cache.blocks_in_use(), 2,
                   "shared blocks count once");
        assert_eq!(cache.prefix_hit_tokens(), 64);
        // the shared bytes read identically through both tables
        for s in [0usize, 17, 31] {
            assert_eq!(cache.host_kv(0, 0, 0, s).unwrap(),
                       cache.host_kv(0, 0, 1, s).unwrap());
        }
        // releasing one row keeps the other's mapping intact
        cache.release_row(1);
        assert_eq!(cache.blocks_shared(), 0);
        assert_eq!(cache.host_kv(0, 0, 0, 17).unwrap()[0], 117.0);
    }

    #[test]
    fn cow_gives_the_writer_a_private_copy() {
        let c = big_cfg();
        let mut cache = KvCache::host_paged(&c, 2, 8).unwrap();
        cache.set_prefix_sharing(true);
        let tokens: Vec<i32> = (0..40).map(|i| 12 + i).collect();
        cache.reserve_row(0, 42).unwrap();
        commit_row(&mut cache, 0, 40, 100.0);
        cache.release_row_cached(0, &tokens);
        cache.reserve_row_prefixed(0, &tokens, 42).unwrap();
        cache.reserve_row_prefixed(1, &tokens, 42).unwrap();
        let before = cache.blocks_in_use();
        // row 1 commits into slot 5 of the shared block 0 (the helper
        // also parks LIVE row 0 at the garbage redirect, so row 0's
        // garbage block is allocated alongside the COW copy)
        commit_row(&mut cache, 1, 6, 500.0);
        assert_eq!(cache.cow_copies(), 1, "one shared block diverged");
        assert_eq!(cache.blocks_in_use(), before + 2,
                   "one COW copy + row 0's garbage block");
        assert_eq!(cache.host_kv(0, 0, 1, 5).unwrap()[0], 505.0,
                   "writer sees its own bytes");
        assert_eq!(cache.host_kv(0, 0, 0, 5).unwrap()[0], 105.0,
                   "the other row's prefix bytes stay intact");
        // slot 31 sits in block 1, still shared untouched
        assert_eq!(cache.host_kv(0, 0, 0, 31).unwrap(),
                   cache.host_kv(0, 0, 1, 31).unwrap());
    }

    #[test]
    fn lru_eviction_reclaims_cached_blocks_when_free_runs_dry() {
        let c = big_cfg();
        let mut cache = KvCache::host_paged(&c, 2, 4).unwrap();
        cache.set_prefix_sharing(true);
        let tokens: Vec<i32> = (0..40).map(|i| 12 + i).collect();
        cache.reserve_row(0, 40).unwrap();
        commit_row(&mut cache, 0, 40, 100.0);
        cache.release_row_cached(0, &tokens);
        assert_eq!(cache.prefix_match(&tokens), 32);
        assert_eq!(cache.unreserved_free(), 4,
                   "cached blocks stay admission headroom");
        // a different 40-token sequence needs 3 live blocks + garbage:
        // the free list (1 partial + 1 garbage block) runs dry and the
        // oldest cached blocks are evicted.
        let other: Vec<i32> = (0..40).map(|i| 60 - i).collect();
        cache.reserve_row_prefixed(0, &other, 40).unwrap();
        commit_row(&mut cache, 0, 40, 900.0);
        assert_eq!(cache.prefix_match(&tokens), 0,
                   "evicted blocks must leave the index");
        cache.release_row_cached(0, &other);
        assert_eq!(cache.prefix_match(&other), 32,
                   "the new sequence is cached in their place");
        assert_eq!(cache.blocks_in_use(), 0);
    }

    #[test]
    fn garbage_redirect_is_isolated_per_row() {
        let c = big_cfg();
        let mut cache = KvCache::host_paged(&c, 2, 6).unwrap();
        let hd = 4;
        let stage: Vec<f32> =
            (0..c.n_layers * 2 * hd).map(|i| i as f32).collect();
        let g = cache.garbage_slot();
        // both rows live at slot 0, then both redirect to garbage
        cache.host_scatter(2, 1, &stage, &stage, &[0, 0]).unwrap();
        cache.host_scatter(2, 1, &stage, &stage, &[g, g]).unwrap();
        let g = g as usize;
        let r0 = cache.host_kv(0, 0, 0, g).unwrap().to_vec();
        let r1 = cache.host_kv(0, 0, 1, g).unwrap().to_vec();
        assert_ne!(r0, r1, "rows stage different values here");
        assert_eq!(cache.blocks_in_use(), 4, "2 live + 2 garbage blocks");
    }
}
