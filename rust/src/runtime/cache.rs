//! Device-resident KV cache handles.
//!
//! The cache is a single `[2, L, B, S_max, H, D]` f32 PJRT buffer that
//! never crosses to the host: `fwd` executables read it in place and
//! `commit` executables produce a new device buffer with this step's
//! accepted K/V scattered in (see aot.py's module docstring for why the
//! two-executable split exists).
//!
//! Speculative semantics (DESIGN.md §7): `cur_len[row]` is the committed
//! length.  Slot `s` always holds live data for `s < cur_len`; rejected
//! speculative columns are *redirected to the reserved garbage slot*
//! `S_max - 1` at commit time rather than erased — queries can never
//! attend it because generation is capped at position `S_max - 2`.

use anyhow::Result;
use xla::{PjRtBuffer, PjRtClient};

use super::artifact::ModelCfg;

pub struct KvCache {
    pub buf: PjRtBuffer,
    pub batch: usize,
    pub s_max: usize,
    pub n_layers: usize,
    /// Committed sequence length per batch row.
    pub cur_len: Vec<u32>,
}

impl KvCache {
    pub fn new(client: &PjRtClient, cfg: &ModelCfg, batch: usize)
               -> Result<Self> {
        let n = 2 * cfg.n_layers * batch * cfg.s_max * cfg.n_heads
            * cfg.d_head;
        let zeros = vec![0f32; n];
        let dims = [2, cfg.n_layers, batch, cfg.s_max, cfg.n_heads,
                    cfg.d_head];
        let buf = client.buffer_from_host_buffer(&zeros, &dims, None)?;
        Ok(KvCache {
            buf,
            batch,
            s_max: cfg.s_max,
            n_layers: cfg.n_layers,
            cur_len: vec![0; batch],
        })
    }

    /// The reserved write-only slot for rejected speculative columns.
    pub fn garbage_slot(&self) -> i32 {
        (self.s_max - 1) as i32
    }

    /// Highest position a live token may occupy.
    pub fn max_live_pos(&self) -> u32 {
        (self.s_max - 2) as u32
    }

    /// Reset a single row (slot reuse under continuous batching).  The
    /// stale device data needs no zeroing: the position-mask contract
    /// means slots >= cur_len are rewritten before they become
    /// attendable.
    pub fn reset_row(&mut self, row: usize) {
        self.cur_len[row] = 0;
    }

    pub fn headroom(&self, row: usize) -> u32 {
        self.max_live_pos().saturating_sub(self.cur_len[row])
    }
}
