//! PJRT runtime: load `artifacts/` (HLO text + npz weights + manifest)
//! and execute from the rust hot path.  Python never runs at serve time.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute_b`.

pub mod artifact;
pub mod cache;
pub mod model;

use std::path::Path;
use std::rc::Rc;

use anyhow::Result;
use xla::PjRtClient;

pub use artifact::{Bucket, Manifest, ModelCfg, ModelEntry, ModelKind};
pub use cache::KvCache;
pub use model::{FwdOut, ModelRt};

use crate::substrate::prompts::PromptSet;
use crate::substrate::tokenizer::Tokenizer;

/// Owns the PJRT client + manifest; hands out loaded models.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    pub tokenizer: Tokenizer,
}

impl Runtime {
    pub fn load(artifacts: &Path) -> Result<Self> {
        let client = PjRtClient::cpu()?;
        let manifest = Manifest::load(artifacts)?;
        let tokenizer = Tokenizer::load(&artifacts.join("vocab.json"))?;
        Ok(Runtime { client, manifest, tokenizer })
    }

    pub fn model(&self, name: &str) -> Result<Rc<ModelRt>> {
        Ok(Rc::new(ModelRt::load(&self.client, &self.manifest, name)?))
    }

    pub fn prompts(&self, task: &str) -> Result<PromptSet> {
        let file = self.manifest.prompts.get(task).ok_or_else(|| {
            anyhow::anyhow!(
                "no prompt set `{task}` (have: {:?})",
                self.manifest.prompts.keys().collect::<Vec<_>>()
            )
        })?;
        PromptSet::load(&self.manifest.root.join(file), task)
    }
}
