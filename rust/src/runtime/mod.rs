//! Model runtimes behind the [`Backend`] trait (DESIGN.md §2):
//!
//! * **PJRT** (feature `pjrt`): load `artifacts/` (HLO text + npz
//!   weights + manifest) and execute AOT-compiled executables from the
//!   rust hot path — python never runs at serve time.  Pattern follows
//!   /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//!   `client.compile` → `execute_b`.
//! * **Reference**: a deterministic pure-Rust transformer family with
//!   identical cache semantics — no artifacts, no Python, runs in plain
//!   `cargo test` (DESIGN.md §6).
//! * **Host**: the same synthetic family through the fast host serving
//!   path (DESIGN.md §8) — bit-identical live-cell outputs to the
//!   reference oracle, built for artifact-free speed: the backend
//!   `pard bench` measures against.  Its int8 per-panel quantized twin
//!   (`--backend host-q8`, [`quant`]) trades bit-identity for ~4× less
//!   weight traffic under a bounded-error contract.

pub mod artifact;
pub mod backend;
pub mod cache;
pub mod host;
#[cfg(feature = "pjrt")]
pub mod model;
pub mod pool;
pub mod quant;
pub mod reference;

use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::Result;

pub use artifact::{Bucket, Manifest, ModelCfg, ModelEntry, ModelKind};
pub use backend::{Backend, FwdOps, FwdOut, KvStage, OpWeightBytes};
pub use cache::{CacheState, KvCache, KV_BLOCK};
pub use host::HostModel;
#[cfg(feature = "pjrt")]
pub use model::ModelRt;
pub use pool::WorkerPool;

use crate::substrate::prompts::PromptSet;
use crate::substrate::tokenizer::Tokenizer;

enum Host {
    #[cfg(feature = "pjrt")]
    Pjrt { client: xla::PjRtClient },
    /// Scalar reference oracle (DESIGN.md §6).
    Reference { seed: u64 },
    /// Fast host serving path over the same weights (DESIGN.md §8),
    /// with the persistent worker pool every model of this runtime
    /// dispatches onto.  `quant` selects the int8 per-panel quantized
    /// twin (`--backend host-q8`, bounded-error contract — see
    /// [`quant`]).
    HostFast { seed: u64, pool: Arc<WorkerPool>, quant: bool },
}

/// Owns the manifest + backend host; hands out loaded models as
/// [`Backend`] trait objects.
pub struct Runtime {
    pub manifest: Manifest,
    pub tokenizer: Tokenizer,
    host: Host,
}

/// A `Send` description of how to open a [`Runtime`] — lets the serve
/// thread (and any other thread) construct its own runtime, since PJRT
/// handles must never cross threads.
#[derive(Debug, Clone)]
pub enum RuntimeSpec {
    /// AOT artifacts directory (PJRT backend).
    Artifacts(PathBuf),
    /// Deterministic in-process reference backend (scalar oracle).
    Reference { seed: u64 },
    /// Deterministic in-process fast host backend (DESIGN.md §8).
    /// `threads` pins the worker-pool size; `None` resolves
    /// `PARD_HOST_THREADS` / available cores at open time.
    Host { seed: u64, threads: Option<usize> },
    /// Int8 per-panel quantized host backend (`--backend host-q8`):
    /// same family and seed semantics as `Host`, weights quantized at
    /// load ([`quant`]) under a bounded-error (not bit-identity)
    /// contract.
    HostQ8 { seed: u64, threads: Option<usize> },
}

impl RuntimeSpec {
    /// Open a runtime for this description (constructed on the calling
    /// thread — PJRT handles never migrate).
    pub fn open(&self) -> Result<Runtime> {
        match self {
            RuntimeSpec::Artifacts(p) => Runtime::load(p),
            RuntimeSpec::Reference { seed } => {
                Ok(Runtime::reference(*seed))
            }
            RuntimeSpec::Host { seed, threads } => {
                Ok(Runtime::host_with_threads(*seed, *threads))
            }
            RuntimeSpec::HostQ8 { seed, threads } => {
                Ok(Runtime::host_q8_with_threads(*seed, *threads))
            }
        }
    }
}

impl Runtime {
    /// Open the PJRT runtime over an artifact dir (feature `pjrt`).
    #[cfg(feature = "pjrt")]
    pub fn load(artifacts: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        let manifest = Manifest::load(artifacts)?;
        let tokenizer = Tokenizer::load(&artifacts.join("vocab.json"))?;
        Ok(Runtime { manifest, tokenizer, host: Host::Pjrt { client } })
    }

    /// Artifact-free builds: loading PJRT artifacts is a typed error.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(_artifacts: &Path) -> Result<Self> {
        anyhow::bail!(
            "this build has no PJRT runtime (feature `pjrt` disabled) — \
             run artifact-free (--backend host or --backend reference) \
             or rebuild with --features pjrt"
        )
    }

    /// Deterministic artifact-free runtime over the synthetic reference
    /// family.  Same `seed` ⇒ bit-identical weights, prompts, outputs.
    pub fn reference(seed: u64) -> Self {
        Self::synthetic(Host::Reference { seed })
    }

    /// Deterministic artifact-free runtime over the *fast host* backend
    /// (DESIGN.md §8): same synthetic family, same weights, same seed
    /// semantics as [`Runtime::reference`], bit-identical live outputs —
    /// but built for throughput rather than auditability.  Pool size
    /// resolves `PARD_HOST_THREADS`, then available cores.
    pub fn host(seed: u64) -> Self {
        Self::host_with_threads(seed, None)
    }

    /// [`Runtime::host`] with the worker-pool size pinned (`--threads`
    /// on the CLI).  `None` keeps the default resolution; outputs are
    /// bit-identical for every pool size — only wall clock changes
    /// (DESIGN.md §8).  One pool is shared by all models this runtime
    /// loads, so target and draft dispatch onto the same parked
    /// threads instead of competing pools.
    pub fn host_with_threads(seed: u64, threads: Option<usize>) -> Self {
        let lanes = threads.unwrap_or_else(pool::default_threads);
        Self::synthetic(Host::HostFast {
            seed,
            pool: Arc::new(WorkerPool::new(lanes)),
            quant: false,
        })
    }

    /// [`Runtime::host`] with int8 per-panel quantized weights
    /// (`--backend host-q8`): same family, same seed semantics, ~4×
    /// less weight traffic, bounded-error (not bit-identity) contract
    /// — see [`quant`].
    pub fn host_q8(seed: u64) -> Self {
        Self::host_q8_with_threads(seed, None)
    }

    /// [`Runtime::host_q8`] with the worker-pool size pinned.  q8
    /// outputs are still bit-identical across pool sizes — the relaxed
    /// contract is vs the f32 oracle, not vs itself.
    pub fn host_q8_with_threads(seed: u64, threads: Option<usize>)
                                -> Self {
        let lanes = threads.unwrap_or_else(pool::default_threads);
        Self::synthetic(Host::HostFast {
            seed,
            pool: Arc::new(WorkerPool::new(lanes)),
            quant: true,
        })
    }

    /// Worker-pool lanes of the host backend (`None` on other
    /// backends) — recorded into bench reports.
    pub fn host_threads(&self) -> Option<usize> {
        match &self.host {
            Host::HostFast { pool, .. } => Some(pool.lanes()),
            _ => None,
        }
    }

    fn synthetic(host: Host) -> Self {
        let manifest = reference::reference_manifest();
        let tokenizer = Tokenizer::synthetic(
            manifest.vocab_size,
            manifest.bos,
            manifest.eos,
            manifest.pad,
            manifest.mask,
            manifest.distinct_masks.clone(),
        );
        Runtime { manifest, tokenizer, host }
    }

    /// True for the artifact-free in-process backends (reference/host).
    pub fn is_reference(&self) -> bool {
        match &self.host {
            Host::Reference { .. } | Host::HostFast { .. } => true,
            #[cfg(feature = "pjrt")]
            Host::Pjrt { .. } => false,
        }
    }

    /// Stable name of the active backend
    /// (`pjrt`/`reference`/`host`/`host-q8`) — recorded into bench
    /// reports.
    pub fn backend_label(&self) -> &'static str {
        match &self.host {
            Host::Reference { .. } => "reference",
            Host::HostFast { quant: false, .. } => "host",
            Host::HostFast { quant: true, .. } => "host-q8",
            #[cfg(feature = "pjrt")]
            Host::Pjrt { .. } => "pjrt",
        }
    }

    /// Open model `name` on this runtime's backend.
    pub fn model(&self, name: &str) -> Result<Rc<dyn Backend>> {
        match &self.host {
            #[cfg(feature = "pjrt")]
            Host::Pjrt { client } => Ok(Rc::new(ModelRt::load(
                client, &self.manifest, name)?)),
            Host::Reference { seed } => {
                let entry = self.manifest.model(name)?;
                Ok(Rc::new(reference::RefModel::build(*seed, entry)?))
            }
            Host::HostFast { seed, pool, quant } => {
                let entry = self.manifest.model(name)?;
                let pool = Arc::clone(pool);
                Ok(Rc::new(if *quant {
                    host::HostModel::build_q8_with_pool(*seed, entry,
                                                        pool)?
                } else {
                    host::HostModel::build_with_pool(*seed, entry, pool)?
                }))
            }
        }
    }

    /// The task's prompt set (synthetic on artifact-free backends).
    pub fn prompts(&self, task: &str) -> Result<PromptSet> {
        match &self.host {
            Host::Reference { seed }
            | Host::HostFast { seed, .. } => {
                reference::synthetic_prompts(task, *seed, &self.manifest)
            }
            #[cfg(feature = "pjrt")]
            Host::Pjrt { .. } => {
                let file =
                    self.manifest.prompts.get(task).ok_or_else(|| {
                        anyhow::anyhow!(
                            "no prompt set `{task}` (have: {:?})",
                            self.manifest.prompts.keys().collect::<Vec<_>>()
                        )
                    })?;
                PromptSet::load(&self.manifest.root.join(file), task)
            }
        }
    }
}
