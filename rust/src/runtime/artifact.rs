//! Artifact registry: parses `artifacts/manifest.json` (authored by
//! `python/compile/aot.py`) into a typed view of every AOT-exported
//! executable, checkpoint, and prompt set — the load side of the
//! backend abstraction (DESIGN.md §2).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::substrate::json::Json;

/// Architecture hyper-parameters shared by python and rust (mirrors
/// `compile.model.ModelConfig` / `EagleConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub s_max: usize,
}

impl ModelCfg {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(ModelCfg {
            name: v.str_req("name")?,
            vocab: v.usize_req("vocab")?,
            d_model: v.usize_req("d_model")?,
            // EagleConfig has no n_layers field: the head is one layer.
            n_layers: v.get("n_layers").and_then(|x| x.as_usize()).unwrap_or(1),
            n_heads: v.usize_req("n_heads")?,
            d_head: v.usize_req("d_head")?,
            d_ff: v.usize_req("d_ff")?,
            s_max: v.usize_req("s_max")?,
        })
    }

    /// Parameter count (tied lm head; eagle heads add the fuse matrix).
    pub fn n_params(&self, eagle: bool) -> usize {
        let d = self.d_model;
        let attn = 4 * d * self.n_heads * self.d_head;
        let mlp = 3 * d * self.d_ff;
        let per_layer = attn + mlp + 2 * d;
        let base = self.vocab * d + self.n_layers * per_layer + d;
        if eagle {
            base + 2 * d * d
        } else {
            base
        }
    }
}

/// One exported (batch, T) HLO bucket.
#[derive(Debug, Clone)]
pub struct Bucket {
    pub b: usize,
    pub t: usize,
    pub file: String,
}

fn buckets(v: &Json) -> Result<Vec<Bucket>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("entries not an array"))?
        .iter()
        .map(|e| {
            Ok(Bucket {
                b: e.usize_req("b")?,
                t: e.usize_req("t")?,
                file: e.str_req("file")?,
            })
        })
        .collect()
}

/// What call shape a model's fwd executable expects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Standard LM: fwd(params…, tokens, pos, cache).
    Lm,
    /// EAGLE head: fwd(params…, hidden, tokens, pos, cache).
    Eagle,
}

/// One model in the manifest: weights, call shape, and exported
/// buckets.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub kind: ModelKind,
    /// fwd returns a trailing hidden-state output.
    pub hidden: bool,
    /// Architecture name — keys the shared commit executables.
    pub arch: String,
    pub weights: String,
    pub cfg: ModelCfg,
    pub entries: Vec<Bucket>,
}

/// PARD training metadata of an adapted draft variant (paper §4).
#[derive(Debug, Clone)]
pub struct PardVariantInfo {
    pub k_train: usize,
    pub r: f64,
    pub r_min: f64,
    pub shared_mask: bool,
}

/// Parsed `manifest.json`: every model, commit executable, and
/// prompt set the artifact dir exports.
#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub vocab_size: usize,
    pub bos: i32,
    pub eos: i32,
    pub pad: i32,
    pub mask: i32,
    pub distinct_masks: Vec<i32>,
    pub models: BTreeMap<String, ModelEntry>,
    pub commits: BTreeMap<String, Vec<Bucket>>,
    pub prompts: BTreeMap<String, String>,
    pub pard_variants: BTreeMap<String, PardVariantInfo>,
    pub main_pard: String,
}

impl Manifest {
    /// Parse `<root>/manifest.json`.
    pub fn load(root: &Path) -> Result<Self> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let v = Json::parse(&text).context("parsing manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, m) in v.req("models")?.as_obj().unwrap() {
            let kind = match m.str_req("kind")?.as_str() {
                "eagle" => ModelKind::Eagle,
                _ => ModelKind::Lm,
            };
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    kind,
                    hidden: m
                        .get("hidden")
                        .and_then(|x| x.as_bool())
                        .unwrap_or(false),
                    arch: m.str_req("arch")?,
                    weights: m.str_req("weights")?,
                    cfg: ModelCfg::from_json(m.req("config")?)?,
                    entries: buckets(m.req("entries")?)?,
                },
            );
        }

        let mut commits = BTreeMap::new();
        for (arch, c) in v.req("commits")?.as_obj().unwrap() {
            commits.insert(arch.clone(), buckets(c)?);
        }

        let mut prompts = BTreeMap::new();
        for (task, f) in v.req("prompts")?.as_obj().unwrap() {
            prompts.insert(task.clone(), f.as_str().unwrap().to_string());
        }

        let mut pard_variants = BTreeMap::new();
        if let Some(obj) = v.get("pard_variants").and_then(|x| x.as_obj()) {
            for (name, p) in obj {
                pard_variants.insert(
                    name.clone(),
                    PardVariantInfo {
                        k_train: p.usize_req("k_train")?,
                        r: p.f64_req("r")?,
                        r_min: p.f64_req("r_min")?,
                        shared_mask: p
                            .req("shared_mask")?
                            .as_bool()
                            .unwrap_or(true),
                    },
                );
            }
        }

        Ok(Manifest {
            root: root.to_path_buf(),
            vocab_size: v.usize_req("vocab_size")?,
            bos: v.usize_req("bos")? as i32,
            eos: v.usize_req("eos")? as i32,
            pad: v.usize_req("pad")? as i32,
            mask: v.usize_req("mask")? as i32,
            distinct_masks: v
                .req("distinct_masks")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_i64().map(|i| i as i32))
                .collect(),
            models,
            commits,
            prompts,
            pard_variants,
            main_pard: v.str_req("main_pard")?,
        })
    }

    /// Look up a model by manifest name.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "model `{name}` not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Smallest exported T bucket >= `t_needed` for batch `b`.
    pub fn pick_bucket(entries: &[Bucket], b: usize, t_needed: usize)
                       -> Result<(usize, usize)> {
        entries
            .iter()
            .filter(|e| e.b == b && e.t >= t_needed)
            .map(|e| e.t)
            .min()
            .map(|t| (b, t))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no bucket for b={b}, t>={t_needed} (have {:?})",
                    entries
                        .iter()
                        .map(|e| (e.b, e.t))
                        .collect::<Vec<_>>()
                )
            })
    }

    /// The exported HLO file serving exactly bucket `(b, t)`.
    pub fn bucket_file(entries: &[Bucket], b: usize, t: usize)
                       -> Result<&str> {
        entries
            .iter()
            .find(|e| e.b == b && e.t == t)
            .map(|e| e.file.as_str())
            .ok_or_else(|| anyhow::anyhow!("no exact bucket b={b} t={t}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_bucket_smallest_fit() {
        let entries = vec![
            Bucket { b: 1, t: 1, file: "a".into() },
            Bucket { b: 1, t: 16, file: "b".into() },
            Bucket { b: 1, t: 64, file: "c".into() },
            Bucket { b: 4, t: 16, file: "d".into() },
        ];
        assert_eq!(Manifest::pick_bucket(&entries, 1, 9).unwrap(), (1, 16));
        assert_eq!(Manifest::pick_bucket(&entries, 1, 1).unwrap(), (1, 1));
        assert_eq!(Manifest::pick_bucket(&entries, 1, 17).unwrap(), (1, 64));
        assert!(Manifest::pick_bucket(&entries, 1, 65).is_err());
        assert!(Manifest::pick_bucket(&entries, 2, 1).is_err());
    }

    #[test]
    fn model_cfg_param_count() {
        let cfg = ModelCfg {
            name: "draft-s".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_head: 32,
            d_ff: 256,
            s_max: 256,
        };
        // matches compile.model.ModelConfig.n_params for draft-s
        assert_eq!(cfg.n_params(false), 393_856);
    }
}
