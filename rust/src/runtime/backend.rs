//! The execution-backend abstraction (DESIGN.md §2).
//!
//! Every decode engine drives a model through this object-safe trait:
//! `fwd` runs one forward call over a `(tokens, pos)` layout against a
//! KV cache, `commit` scatters the call's K/V into the cache at
//! caller-chosen positions (rejected columns → the garbage slot,
//! DESIGN.md §7).  Three implementations exist:
//!
//! * `runtime::model::ModelRt` — AOT-compiled PJRT executables (only
//!   under feature `pjrt`, so no doc-link — the module is compiled out
//!   otherwise), the measured serving path on device artifacts;
//! * [`crate::runtime::reference::RefModel`] — the deterministic
//!   pure-Rust scalar oracle (DESIGN.md §6) behind the
//!   engine-equivalence test suite;
//! * [`crate::runtime::host::HostModel`] — the fast host serving path
//!   (DESIGN.md §8): same weights and bit-identical live outputs as the
//!   oracle, restructured for artifact-free throughput; `pard bench`
//!   measures on it.
//!
//! The trait owns exactly the surface the engines need; anything
//! PJRT-specific (bucket files, executable caches) stays behind it.

use anyhow::Result;

use super::artifact::{ModelCfg, ModelKind};
use super::cache::KvCache;

/// This call's staged K/V (shape `[L, b, t, H, D]`), kept in whatever
/// form the backend can cheaply re-consume in the follow-up `commit`.
pub enum KvStage {
    /// Host-resident f32 rows (reference backend, scripted test fakes).
    Host { k: Vec<f32>, v: Vec<f32> },
    /// Host literals awaiting device upload (PJRT backend).
    #[cfg(feature = "pjrt")]
    Pjrt { k: xla::Literal, v: xla::Literal },
}

/// Per-op wall-clock breakdown of one `fwd` call, reported by backends
/// that instrument their forward pass (currently the host fast path,
/// DESIGN.md §8).  Each field covers a disjoint phase of the call, so
/// the sum is bounded by `FwdOut::elapsed_s`; `pard bench` aggregates
/// these into the `fwd_ops` column of `BENCH_hotpath.json`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FwdOps {
    /// Live-cell gather, token embeddings, rotary tables, slot map.
    pub gather_s: f64,
    /// Attention rmsnorm + fused QKV matmul + rope + K/V staging.
    pub qkv_s: f64,
    /// Score / softmax / weighted-V chains over the cache.
    pub attn_s: f64,
    /// Attention output projection (+ residual accumulate).
    pub wo_s: f64,
    /// MLP rmsnorm + fused W1/W3 matmul + SiLU + W2 (+ residual).
    pub mlp_s: f64,
    /// Final norm, logit projection, output scatter/assembly.
    pub logits_s: f64,
}

impl FwdOps {
    /// Accumulate another breakdown into this one (field-wise sum).
    pub fn add(&mut self, o: &FwdOps) {
        self.gather_s += o.gather_s;
        self.qkv_s += o.qkv_s;
        self.attn_s += o.attn_s;
        self.wo_s += o.wo_s;
        self.mlp_s += o.mlp_s;
        self.logits_s += o.logits_s;
    }

    /// Sum of all phases (≤ the owning call's `elapsed_s`).
    pub fn total(&self) -> f64 {
        self.gather_s + self.qkv_s + self.attn_s + self.wo_s + self.mlp_s
            + self.logits_s
    }
}

/// Weight bytes one full forward pass streams, bucketed to match the
/// [`FwdOps`] time ledger (gather and attention read activations and
/// KV, not matmul weights, so they have no bucket here).  Together with
/// the per-op times this is the measured side of the paper's Table 6
/// bandwidth argument: bytes-per-token for a draft phase is
/// `draft_passes · total() / tokens` — flat in K for PARD (one pass
/// drafts K tokens), linear in K for sequential drafters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpWeightBytes {
    /// Fused `[d, 3·H·D]` QKV projection panels.
    pub qkv: usize,
    /// `[H·D, d]` attention output projection.
    pub wo: usize,
    /// Fused `[d, 2·ff]` gate/up + `[ff, d]` down projections.
    pub mlp: usize,
    /// Packed `[d, vocab]` tied-embedding transpose (logit projection).
    pub logits: usize,
    /// `[2d, d]` EAGLE fuse projection (0 on standard LM models).
    pub fuse: usize,
}

impl OpWeightBytes {
    /// All matmul weight bytes one forward pass sweeps.
    pub fn total(&self) -> usize {
        self.qkv + self.wo + self.mlp + self.logits + self.fuse
    }
}

/// Host-side result of one `fwd` call.
pub struct FwdOut {
    /// `[b, t, vocab]` row-major.
    pub logits: Vec<f32>,
    /// `[b, t, d_model]` when the model exports hidden states (EAGLE).
    pub hidden: Option<Vec<f32>>,
    /// This call's K/V columns for the follow-up `commit`.
    pub kv: KvStage,
    /// Wall-clock of the forward execution + transfers.
    pub elapsed_s: f64,
    /// Per-op breakdown of `elapsed_s` where the backend instruments
    /// it (`None` on the scalar oracle and PJRT paths).
    pub ops: Option<FwdOps>,
}

/// The forward/commit call surface of a loaded model (object-safe).
pub trait Backend {
    /// Architecture hyper-parameters (vocab, widths, `s_max`, …).
    fn cfg(&self) -> &ModelCfg;

    /// Standard LM vs EAGLE head (hidden-input) call convention.
    fn kind(&self) -> ModelKind;

    fn n_params(&self) -> usize;

    /// Smallest T the backend can execute with `t >= t_needed` for
    /// batch `b` (PJRT: exported bucket; reference: exact fit).
    fn pick_t(&self, b: usize, t_needed: usize) -> Result<usize>;

    fn new_cache(&self, batch: usize) -> Result<KvCache>;

    /// Weight bytes one forward pass streams, per [`FwdOps`] bucket, in
    /// this backend's storage representation (f32 panels vs int8+scales
    /// on `host-q8`).  Default: all zeros, for backends that don't
    /// account weight traffic (oracle, PJRT, scripted test fakes).
    fn op_weight_bytes(&self) -> OpWeightBytes {
        OpWeightBytes::default()
    }

    /// [`Backend::new_cache`] with the host block pool pinned to
    /// `kv_blocks` blocks (`--kv-blocks`, DESIGN.md §7).  `None` keeps
    /// the default capacity-parity pool; backends without a paged host
    /// cache (PJRT device caches) reject an explicit size.
    fn new_cache_sized(&self, batch: usize, kv_blocks: Option<usize>)
                       -> Result<KvCache> {
        anyhow::ensure!(kv_blocks.is_none(),
                        "--kv-blocks is not supported on this backend \
                         (its KV cache is not host-paged)");
        self.new_cache(batch)
    }

    /// Run the forward pass.  `tokens`/`pos` are `[b * t]` row-major;
    /// `hidden_in` is required iff this is an EAGLE head.
    fn fwd(&self, b: usize, t: usize, tokens: &[i32], pos: &[i32],
           hidden_in: Option<&[f32]>, cache: &KvCache) -> Result<FwdOut>;

    /// Scatter this step's K/V into the cache at `commit_pos`
    /// (`[b * t]`; rejected columns point at the garbage slot).
    /// Returns elapsed seconds.
    fn commit(&self, b: usize, t: usize, out: &FwdOut, commit_pos: &[i32],
              cache: &mut KvCache) -> Result<f64>;

    /// Pre-compile / pre-warm the `(b, t)` shapes an engine will need.
    /// No-op for backends that have nothing to JIT.
    fn warmup(&self, _b: usize, _ts: &[usize]) -> Result<()> {
        Ok(())
    }

    /// Warm every shape a dynamic T in `lo..=hi` could resolve to.
    fn warmup_range(&self, _b: usize, _lo: usize, _hi: usize)
                    -> Result<()> {
        Ok(())
    }
}
