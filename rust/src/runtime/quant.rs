//! Int8 per-panel weight quantization for the host backend
//! (`--backend host-q8`, DESIGN.md §8).
//!
//! [`QuantizedMat`] is the int8 twin of [`PackedMat`](super::host):
//! same `[n_panels, din, PANEL]` column-panel layout, same panel-range
//! sweep signature (so the worker-pool partition in `par_matmul` is
//! shared verbatim), but each panel's weights are stored as `i8` codes
//! under one symmetric f32 scale.  A full sweep therefore streams ~4×
//! fewer weight bytes — the lever the paper's Table 6 bandwidth
//! argument says decode is bound by.
//!
//! # Quantization scheme
//!
//! Per panel (16 output columns spanning all `din` rows):
//!
//! ```text
//! scale = max(|w|) / 127        (0 when the panel is all zeros)
//! q     = clamp(round(w / scale), -127, 127)    // round half away
//! ```
//!
//! `f32::round` rounds half away from zero; the refsim mirror
//! (`python/refsim/hostsim.py`) reproduces that explicitly because
//! numpy's `round` is half-to-even.  Codes stay in `[-127, 127]`
//! (never -128), keeping the scheme symmetric.
//!
//! # The relaxed contract
//!
//! q8 CANNOT be bit-identical to the `reference.rs` oracle — the
//! weights themselves differ.  It carries a two-part contract instead:
//!
//! 1. **Deterministic.**  The dot kernel accumulates
//!    `acc += a[k] · (q as f32)` in f32, k ascending from 0, then
//!    applies the panel scale once: `out += scale · acc`.  Every
//!    output cell is still one fixed-order chain owned by one lane, so
//!    lane count and panel partition can never change a bit — the same
//!    §8 column-decomposition argument as f32, just against q8's own
//!    reference stream.
//! 2. **Bounded error vs f32.**  Per-logit absolute error against the
//!    f32 host path is asserted under an empirically calibrated bound
//!    in `tests/host_backend.rs`, and the hostsim.py mirror replays
//!    the quantization and the sweep bit-for-bit as an independent
//!    gate.
//!
//! Hoisting the scale out of the k loop (rather than multiplying
//! `scale · q` per element) both saves a multiply per MAC and keeps
//! the integer codes exactly representable in f32 (|q| ≤ 127), so the
//! mirror can reproduce the accumulation exactly.

use super::host::{lane8_fma, LANE, PANEL};
use super::pool::SharedSlice;

/// Column-panel int8 weight matrix: `[n_panels, din, PANEL]` codes,
/// one symmetric f32 scale per panel.  See module docs.
pub struct QuantizedMat {
    /// `[n_panels, din, PANEL]` int8 codes, ragged tail zero-padded.
    data: Vec<i8>,
    /// One `max(|w|)/127` scale per panel (0 for all-zero panels).
    scales: Vec<f32>,
    din: usize,
    dout: usize,
}

impl QuantizedMat {
    /// Quantize a row-major `[din, dout]` f32 matrix.
    pub fn quantize(w: &[f32], din: usize, dout: usize) -> QuantizedMat {
        assert_eq!(w.len(), din * dout, "quantize: weight shape mismatch");
        let panels = dout.div_ceil(PANEL);
        let mut data = vec![0i8; panels * din * PANEL];
        let mut scales = vec![0f32; panels];
        for p in 0..panels {
            let cols = (dout - p * PANEL).min(PANEL);
            let mut amax = 0f32;
            for k in 0..din {
                for c in 0..cols {
                    amax = amax.max(w[k * dout + p * PANEL + c].abs());
                }
            }
            if amax == 0.0 {
                continue; // all-zero panel: scale 0, codes 0
            }
            let scale = amax / 127.0;
            scales[p] = scale;
            let inv = 1.0 / scale;
            for k in 0..din {
                for c in 0..cols {
                    let q = (w[k * dout + p * PANEL + c] * inv)
                        .round()
                        .clamp(-127.0, 127.0);
                    data[(p * din + k) * PANEL + c] = q as i8;
                }
            }
        }
        QuantizedMat { data, scales, din, dout }
    }

    /// Input width (columns) of the quantized matrix.
    pub fn din(&self) -> usize {
        self.din
    }

    /// Output width (rows) of the quantized matrix.
    pub fn dout(&self) -> usize {
        self.dout
    }

    /// Number of PANEL-wide output panels (ragged tail included).
    pub fn n_panels(&self) -> usize {
        self.dout.div_ceil(PANEL)
    }

    /// Bytes one full sweep streams: i8 panel codes (incl. ragged-tail
    /// padding) plus one f32 scale per panel — the q8 numerator of the
    /// `benches/table6_bandwidth.rs` bandwidth model.
    pub(crate) fn weight_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * std::mem::size_of::<f32>()
    }

    /// `out[n, dout] += a[n, din] @ dequant(w)` restricted to panels
    /// `p0..p1` — the q8 twin of `PackedMat::matmul_acc_panels`, same
    /// [`lane8_fma`] micro-kernel over two `[f32; LANE]` register
    /// accumulators.  The accumulators start at zero (NOT the existing
    /// output): the panel scale applies once to the finished integer
    /// chain, then lands on the output in one add.  Deterministic for
    /// any panel partition; see the module-level contract.
    pub(crate) fn matmul_acc_panels(&self, a: &[f32], out: &SharedSlice,
                                    n: usize, p0: usize, p1: usize) {
        let (din, dout) = (self.din, self.dout);
        let mut deq = vec![0f32; din * PANEL];
        for p in p0..p1 {
            let cols = (dout - p * PANEL).min(PANEL);
            let c0 = p * PANEL;
            let scale = self.scales[p];
            // Widen the panel's codes to f32 once per panel (integer
            // codes are exact in f32), so the k loop below is the same
            // pure-f32 micro-kernel as the f32 path and the per-k work
            // is one fma per lane, not a convert + fma.
            let pan = &self.data[p * din * PANEL..(p + 1) * din * PANEL];
            for (dq, &q) in deq.iter_mut().zip(pan.iter()) {
                *dq = q as f32;
            }
            for i in 0..n {
                let ar = &a[i * din..(i + 1) * din];
                // SAFETY: lanes own disjoint panel ranges, so these
                // column cells belong to this lane alone.
                let or = unsafe { out.range(i * dout + c0, cols) };
                let mut acc0 = [0f32; LANE];
                let mut acc1 = [0f32; LANE];
                for (ki, &av) in ar.iter().enumerate() {
                    let wr = &deq[ki * PANEL..(ki + 1) * PANEL];
                    lane8_fma(&mut acc0, av, &wr[..LANE]);
                    lane8_fma(&mut acc1, av, &wr[LANE..]);
                }
                let lo = cols.min(LANE);
                for c in 0..lo {
                    or[c] += scale * acc0[c];
                }
                for c in LANE..cols {
                    or[c] += scale * acc1[c - LANE];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    /// Scalar q8 reference: quantize, then the same chain order the
    /// panel kernel commits to (k ascending from 0, scale applied once).
    fn q8_scalar(a: &[f32], qm: &QuantizedMat, out: &mut [f32], n: usize) {
        let (din, dout) = (qm.din, qm.dout);
        for i in 0..n {
            for p in 0..qm.n_panels() {
                let cols = (dout - p * PANEL).min(PANEL);
                for c in 0..cols {
                    let mut acc = 0f32;
                    for k in 0..din {
                        acc += a[i * din + k]
                            * (qm.data[(p * din + k) * PANEL + c] as f32);
                    }
                    out[i * dout + p * PANEL + c] +=
                        qm.scales[p] * acc;
                }
            }
        }
    }

    #[test]
    fn quantize_roundtrip_error_is_bounded_by_half_scale() {
        let mut rng = Rng::new(0x51);
        let (din, dout) = (24usize, 40usize);
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32).collect();
        let qm = QuantizedMat::quantize(&w, din, dout);
        for p in 0..qm.n_panels() {
            let cols = (dout - p * PANEL).min(PANEL);
            let scale = qm.scales[p];
            assert!(scale > 0.0, "random panel must get a scale");
            for k in 0..din {
                for c in 0..cols {
                    let orig = w[k * dout + p * PANEL + c];
                    let deq = scale
                        * (qm.data[(p * din + k) * PANEL + c] as f32);
                    assert!((orig - deq).abs() <= scale * 0.5 + 1e-7,
                            "code error exceeds half a step at p={p}");
                }
            }
        }
    }

    #[test]
    fn zero_panel_gets_zero_scale_and_codes() {
        // First PANEL columns all zero, rest random: panel 0 must be
        // scale 0 / codes 0, and sweeping it adds exactly nothing.
        let mut rng = Rng::new(0x52);
        let (din, dout) = (8usize, 32usize);
        let mut w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32).collect();
        for k in 0..din {
            for c in 0..PANEL {
                w[k * dout + c] = 0.0;
            }
        }
        let qm = QuantizedMat::quantize(&w, din, dout);
        assert_eq!(qm.scales[0], 0.0);
        assert!(qm.data[..din * PANEL].iter().all(|&q| q == 0));
        let a: Vec<f32> = (0..din).map(|i| i as f32 * 0.3).collect();
        let mut out = vec![7.0f32; dout];
        qm.matmul_acc_panels(&a, &SharedSlice::new(&mut out), 1, 0, 1);
        assert!(out.iter().all(|&x| x == 7.0),
                "zero panel must leave the output untouched");
    }

    #[test]
    fn codes_stay_symmetric_in_range() {
        let mut rng = Rng::new(0x53);
        let (din, dout) = (16usize, 48usize);
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32 * 3.0).collect();
        let qm = QuantizedMat::quantize(&w, din, dout);
        assert!(qm.data.iter().all(|&q| (-127..=127).contains(&q)),
                "codes must stay in [-127, 127]");
        assert!(qm.data.iter().any(|&q| q == 127 || q == -127),
                "the panel max must hit a full-scale code");
    }

    #[test]
    fn panel_sweep_matches_scalar_reference_any_partition() {
        // The kernel's chain order is its own spec: any panel
        // partition (and ragged tails) must match the scalar replay
        // bit for bit.
        let mut rng = Rng::new(0x54);
        for &(n, din, dout) in
            &[(3usize, 32usize, 48usize), (1, 16, 21), (2, 24, 7),
              (4, 8, 33)]
        {
            let a: Vec<f32> =
                (0..n * din).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> =
                (0..din * dout).map(|_| rng.normal() as f32).collect();
            let qm = QuantizedMat::quantize(&w, din, dout);
            let mut want: Vec<f32> =
                (0..n * dout).map(|i| (i % 3) as f32 * 0.2).collect();
            let mut got = want.clone();
            q8_scalar(&a, &qm, &mut want, n);
            let panels = qm.n_panels();
            let shared = SharedSlice::new(&mut got);
            let mid = panels / 2;
            qm.matmul_acc_panels(&a, &shared, n, mid, panels);
            qm.matmul_acc_panels(&a, &shared, n, 0, mid);
            assert_eq!(want, got,
                       "q8 panels diverged at {n}x{din}x{dout}");
        }
    }

    #[test]
    fn quantized_sweep_approximates_f32_matmul() {
        // End-to-end sanity: dequantized matmul error per output cell
        // is bounded by the accumulated step error (din · scale/2 ·
        // max|a| is very loose; assert a comfortable practical bound).
        let mut rng = Rng::new(0x55);
        let (n, din, dout) = (2usize, 32usize, 32usize);
        let a: Vec<f32> =
            (0..n * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32).collect();
        let mut exact = vec![0f32; n * dout];
        for i in 0..n {
            for j in 0..dout {
                for k in 0..din {
                    exact[i * dout + j] += a[i * din + k] * w[k * dout + j];
                }
            }
        }
        let qm = QuantizedMat::quantize(&w, din, dout);
        let mut got = vec![0f32; n * dout];
        qm.matmul_acc_panels(&a, &SharedSlice::new(&mut got), n, 0,
                             qm.n_panels());
        let max_err = exact
            .iter()
            .zip(&got)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(max_err > 0.0, "q8 exactly equal to f32 is suspicious");
        assert!(max_err < 0.2,
                "q8 matmul error {max_err} far beyond step noise");
    }

    #[test]
    fn weight_bytes_counts_codes_plus_scales() {
        let w = vec![1.0f32; 24 * 40];
        let qm = QuantizedMat::quantize(&w, 24, 40);
        let panels = 40usize.div_ceil(PANEL);
        assert_eq!(qm.weight_bytes(), panels * 24 * PANEL + panels * 4);
    }
}
