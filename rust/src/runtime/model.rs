//! PJRT model runtime: weights on device + lazily compiled per-bucket
//! executables, implementing the [`Backend`] trait's `fwd` / `commit`
//! call surface (DESIGN.md §2; split rationale in §7).
//!
//! Call protocol (set by `python/compile/aot.py`):
//!   fwd  (weights…, [hidden,] tokens[b,t], pos[b,t], cache) ->
//!        tuple(logits[b,t,V], k_new[L,b,t,H,D], v_new[, hidden_out])
//!   commit (cache, k_new, v_new, pos[b,t]) -> cache'
//!
//! `tokens`/`pos` layouts are chosen by the coordinator engines; this
//! module only moves bytes and tracks per-phase timing.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{Context, Result};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient,
          PjRtLoadedExecutable, XlaComputation};

use crate::substrate::bench::stopwatch;
use super::artifact::{Bucket, Manifest, ModelCfg, ModelEntry, ModelKind};
use super::backend::{Backend, FwdOut, KvStage};
use super::cache::{CacheState, KvCache};

/// Synchronous f32 upload (safe wrt the async-literal hazard; see
/// `ModelRt::load`).
pub fn upload_f32_literal(client: &PjRtClient, l: &Literal)
                          -> Result<PjRtBuffer> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> =
        shape.dims().iter().map(|d| *d as usize).collect();
    let data = l.to_vec::<f32>()?;
    Ok(client.buffer_from_host_buffer(&data, &dims, None)?)
}

/// One PJRT model: device weights plus lazily compiled per-bucket
/// fwd/commit executables.
pub struct ModelRt {
    pub entry: ModelEntry,
    client: PjRtClient,
    root: PathBuf,
    weights: Vec<PjRtBuffer>,
    commit_buckets: Vec<Bucket>,
    fwd_exes: RefCell<BTreeMap<(usize, usize), Rc<PjRtLoadedExecutable>>>,
    commit_exes: RefCell<BTreeMap<(usize, usize), Rc<PjRtLoadedExecutable>>>,
    /// Cumulative time compiling executables (reported, not counted
    /// against serving benchmarks — compilation is a load-time cost).
    pub compile_s: RefCell<f64>,
}

impl ModelRt {
    /// Upload `name`'s weights and commit buckets from the manifest.
    pub fn load(client: &PjRtClient, manifest: &Manifest, name: &str)
                -> Result<Self> {
        let entry = manifest.model(name)?.clone();
        let wpath = manifest.root.join(&entry.weights);
        // NOTE two xla-0.1.6 hazards handled here (see DESIGN.md §Perf):
        // * PjRtBuffer::read_npz mistypes f32 as f16 (ElementType-vs-
        //   PrimitiveType enum cast in buffer_from_host_raw_bytes), so read
        //   through Literal which types correctly;
        // * buffer_from_host_literal is ASYNC (no await in the C shim) and
        //   use-after-frees if the literal drops early, so upload through
        //   buffer_from_host_buffer, which copies during the call.
        let named = Literal::read_npz(&wpath, &())
            .with_context(|| format!("loading weights {}", wpath.display()))?;
        // npz keys are p000.. in jax tree-flatten order == HLO param order.
        let mut named: Vec<(String, Literal)> = named;
        named.sort_by(|a, b| a.0.cmp(&b.0));
        let weights: Vec<PjRtBuffer> = named
            .into_iter()
            .map(|(_, l)| upload_f32_literal(client, &l))
            .collect::<Result<_>>()?;
        let commit_buckets = manifest
            .commits
            .get(&entry.arch)
            .ok_or_else(|| {
                anyhow::anyhow!("no commit executables for arch {}",
                                entry.arch)
            })?
            .clone();
        Ok(ModelRt {
            entry,
            client: client.clone(),
            root: manifest.root.clone(),
            weights,
            commit_buckets,
            fwd_exes: RefCell::new(BTreeMap::new()),
            commit_exes: RefCell::new(BTreeMap::new()),
            compile_s: RefCell::new(0.0),
        })
    }

    fn compile(&self, file: &str) -> Result<PjRtLoadedExecutable> {
        let t0 = stopwatch();
        let path = self.root.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        *self.compile_s.borrow_mut() += t0.elapsed().as_secs_f64();
        Ok(exe)
    }

    fn fwd_exe(&self, b: usize, t: usize)
               -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.fwd_exes.borrow().get(&(b, t)) {
            return Ok(e.clone());
        }
        let file =
            Manifest::bucket_file(&self.entry.entries, b, t)?.to_string();
        let exe = Rc::new(self.compile(&file)?);
        self.fwd_exes.borrow_mut().insert((b, t), exe.clone());
        Ok(exe)
    }

    fn commit_exe(&self, b: usize, t: usize)
                  -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.commit_exes.borrow().get(&(b, t)) {
            return Ok(e.clone());
        }
        let file =
            Manifest::bucket_file(&self.commit_buckets, b, t)?.to_string();
        let exe = Rc::new(self.compile(&file)?);
        self.commit_exes.borrow_mut().insert((b, t), exe.clone());
        Ok(exe)
    }

    fn upload_i32(&self, data: &[i32], b: usize, t: usize)
                  -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, &[b, t], None)?)
    }
}

impl Backend for ModelRt {
    fn cfg(&self) -> &ModelCfg {
        &self.entry.cfg
    }

    fn kind(&self) -> ModelKind {
        self.entry.kind
    }

    fn n_params(&self) -> usize {
        self.entry.cfg.n_params(self.entry.kind == ModelKind::Eagle)
    }

    /// Smallest exported fwd bucket with `t >= t_needed`.
    fn pick_t(&self, b: usize, t_needed: usize) -> Result<usize> {
        Ok(Manifest::pick_bucket(&self.entry.entries, b, t_needed)?.1)
    }

    fn new_cache(&self, batch: usize) -> Result<KvCache> {
        KvCache::device(&self.client, &self.entry.cfg, batch)
    }

    /// Eagerly compile the buckets an engine will need (keeps JIT cost
    /// out of the measured serving loop).
    fn warmup(&self, b: usize, ts: &[usize]) -> Result<()> {
        for &t in ts {
            self.fwd_exe(b, t)?;
            self.commit_exe(b, t)?;
        }
        Ok(())
    }

    /// Warm every bucket a dynamic T in `lo..=hi` could resolve to.
    fn warmup_range(&self, b: usize, lo: usize, hi: usize) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        for need in lo..=hi {
            let t = self.pick_t(b, need)?;
            if seen.insert(t) {
                self.fwd_exe(b, t)?;
                self.commit_exe(b, t)?;
            }
        }
        Ok(())
    }

    /// Run the forward executable.
    fn fwd(&self, b: usize, t: usize, tokens: &[i32], pos: &[i32],
           hidden_in: Option<&[f32]>, cache: &KvCache) -> Result<FwdOut> {
        debug_assert_eq!(tokens.len(), b * t);
        debug_assert_eq!(pos.len(), b * t);
        let CacheState::Device(cache_buf) = &cache.state else {
            anyhow::bail!("PJRT fwd needs a device cache")
        };
        let t0 = stopwatch();
        let exe = self.fwd_exe(b, t)?;
        let tok_buf = self.upload_i32(tokens, b, t)?;
        let pos_buf = self.upload_i32(pos, b, t)?;
        let mut args: Vec<&PjRtBuffer> =
            Vec::with_capacity(self.weights.len() + 4);
        args.extend(self.weights.iter());
        let hid_buf;
        match (self.entry.kind, hidden_in) {
            (ModelKind::Eagle, Some(h)) => {
                debug_assert_eq!(h.len(), b * t * self.entry.cfg.d_model);
                hid_buf = self.client.buffer_from_host_buffer(
                    h, &[b, t, self.entry.cfg.d_model], None)?;
                args.push(&hid_buf);
            }
            (ModelKind::Eagle, None) => {
                anyhow::bail!("EAGLE fwd requires hidden input")
            }
            (ModelKind::Lm, Some(_)) => {
                anyhow::bail!("LM fwd takes no hidden input")
            }
            (ModelKind::Lm, None) => {}
        }
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(cache_buf);

        let result = exe.execute_b(&args)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.decompose_tuple()?;
        let want = if self.entry.hidden { 4 } else { 3 };
        anyhow::ensure!(parts.len() == want,
                        "fwd returned {} outputs, want {want}", parts.len());
        let mut it = parts.into_iter();
        let logits = it.next().unwrap().to_vec::<f32>()?;
        let k_new = it.next().unwrap();
        let v_new = it.next().unwrap();
        let hidden = match it.next() {
            Some(h) => Some(h.to_vec::<f32>()?),
            None => None,
        };
        Ok(FwdOut {
            logits,
            hidden,
            kv: KvStage::Pjrt { k: k_new, v: v_new },
            elapsed_s: t0.elapsed().as_secs_f64(),
            ops: None,
        })
    }

    /// Scatter this step's K/V into the device cache at `commit_pos`.
    /// Replaces the cache buffer in place.
    fn commit(&self, b: usize, t: usize, out: &FwdOut, commit_pos: &[i32],
              cache: &mut KvCache) -> Result<f64> {
        debug_assert_eq!(commit_pos.len(), b * t);
        let KvStage::Pjrt { k, v } = &out.kv else {
            anyhow::bail!("host-staged FwdOut fed to the PJRT commit")
        };
        let t0 = stopwatch();
        let exe = self.commit_exe(b, t)?;
        let k_buf = upload_f32_literal(&self.client, k)?;
        let v_buf = upload_f32_literal(&self.client, v)?;
        let pos_buf = self.upload_i32(commit_pos, b, t)?;
        let CacheState::Device(cache_buf) = &mut cache.state else {
            anyhow::bail!("PJRT commit needs a device cache")
        };
        let args: [&PjRtBuffer; 4] = [cache_buf, &k_buf, &v_buf, &pos_buf];
        let mut result = exe.execute_b(&args)?;
        // commit is lowered with return_tuple=False: single array output
        // that stays on device — the whole point of the split.
        *cache_buf = result
            .pop()
            .and_then(|mut v| v.pop())
            .ok_or_else(|| anyhow::anyhow!("commit returned no buffer"))?;
        Ok(t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod npz_tests {
    use xla::{FromRawBytes, PjRtBuffer, PjRtClient};

    #[test]
    fn npz_order_and_shapes() {
        let p = std::path::Path::new("artifacts/ckpt/draft-s.npz");
        if !p.exists() {
            return; // artifacts not built in this checkout
        }
        let client = PjRtClient::cpu().unwrap();
        let lits = xla::Literal::read_npz(p, &()).unwrap();
        let bufs: Vec<(String, PjRtBuffer)> = lits.into_iter().map(|(n, l)| (n, super::upload_f32_literal(&client, &l).unwrap())).collect();
        for (name, b) in bufs.iter().take(4) {
            eprintln!("{} {:?}", name, b.on_device_shape().unwrap());
        }
        assert_eq!(bufs[0].0, "p000");
    }
}
