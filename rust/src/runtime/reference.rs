//! Deterministic pure-Rust reference backend (DESIGN.md §6).
//!
//! A small f32 LLaMA-style transformer implementing the same `extend`
//! semantics as the AOT path (`python/compile/model.py`): per layer,
//! this call's rotary K/V are scattered into a *transient copy* of the
//! cache at `pos`, then every query attends cache slot `s` iff
//! `s <= pos[query]` — so in-flight columns see each other exactly like
//! committed slots, and the garbage slot `S_max - 1` is unreachable
//! from any live position.  `commit` is the only operation that
//! mutates the persistent cache, mirroring the fwd/commit executable
//! split (DESIGN.md §7).  Persistent reads resolve through the row's
//! block table (`slot_index`), so prefix-shared blocks (DESIGN.md §7)
//! are read transparently — same bytes wherever the table points —
//! and commits route through `host_scatter`, which carries the
//! copy-on-write hook.
//!
//! Weights are seeded from `substrate::rng` (splitmix/xoshiro — no
//! platform dependence); every floating-point loop runs in a fixed
//! order, so outputs are bit-identical across runs AND across batch
//! layouts: each (row, column) is computed independently, which is what
//! lets the equivalence suite compare engines across batch sizes.
//!
//! The synthetic family mirrors the artifact family's names so every
//! engine, the router, the batcher, and the CLI run unmodified:
//! draft-s / target-m / target-l / target-xl, the hidden-exporting
//! `target-l_h`, the PARD adaptation `pard-main` (same weights as
//! draft-s: adaptation is weight-only, and weight-sharing gives the
//! suite a deterministic handle on the accept-everything path), and an
//! `eagle-target-l` head.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;

use super::artifact::{Manifest, ModelCfg, ModelEntry, ModelKind,
                      PardVariantInfo};
use super::backend::{Backend, FwdOut, KvStage};
use super::cache::{CacheState, KvCache};
use crate::substrate::bench::stopwatch;
use crate::substrate::prompts::{Prompt, PromptSet};
use crate::substrate::rng::Rng;

/// Synthetic-family vocabulary size.
pub const REF_VOCAB: usize = 64;
/// Synthetic-family logical window (sequence slots per row).
pub const REF_S_MAX: usize = 96;
const REF_D_HEAD: usize = 16;
/// Token ids below this are special (bos/eos/pad/mask/distinct masks).
pub const REF_FIRST_PLAIN: i32 = 12;
const ROPE_THETA: f32 = 10000.0;

/// Stable per-name seed derivation (FNV-1a over the base seed).
fn key_seed(base: u64, name: &str) -> u64 {
    let mut h = base ^ 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The synthetic in-memory manifest the reference runtime serves.
pub fn reference_manifest() -> Manifest {
    let entry = |name: &str, d: usize, l: usize, h: usize, ff: usize,
                 weight_key: &str, kind: ModelKind, hidden: bool| {
        (
            name.to_string(),
            ModelEntry {
                name: name.to_string(),
                kind,
                hidden,
                arch: weight_key.to_string(),
                // `weights` carries the weight-seed key: models sharing
                // it share parameters (target-l_h, pard-main).
                weights: weight_key.to_string(),
                cfg: ModelCfg {
                    name: name.to_string(),
                    vocab: REF_VOCAB,
                    d_model: d,
                    n_layers: l,
                    n_heads: h,
                    d_head: REF_D_HEAD,
                    d_ff: ff,
                    s_max: REF_S_MAX,
                },
                entries: Vec::new(),
            },
        )
    };
    let models: BTreeMap<String, ModelEntry> = [
        entry("draft-s", 32, 2, 2, 64, "draft-s", ModelKind::Lm, false),
        entry("target-m", 48, 3, 3, 96, "target-m", ModelKind::Lm, false),
        entry("target-l", 64, 4, 4, 128, "target-l", ModelKind::Lm, false),
        entry("target-xl", 80, 5, 5, 160, "target-xl", ModelKind::Lm,
              false),
        entry("target-l_h", 64, 4, 4, 128, "target-l", ModelKind::Lm,
              true),
        entry("pard-main", 32, 2, 2, 64, "draft-s", ModelKind::Lm, false),
        entry("eagle-target-l", 64, 1, 4, 128, "eagle-target-l",
              ModelKind::Eagle, true),
    ]
    .into_iter()
    .collect();
    let prompts: BTreeMap<String, String> = ["code", "math", "gsm"]
        .into_iter()
        .map(|t| (t.to_string(), "<synthetic>".to_string()))
        .collect();
    let mut pard_variants = BTreeMap::new();
    pard_variants.insert(
        "pard-main".to_string(),
        PardVariantInfo { k_train: 8, r: 0.7, r_min: 0.3,
                          shared_mask: true },
    );
    Manifest {
        root: PathBuf::from("<reference>"),
        vocab_size: REF_VOCAB,
        bos: 0,
        eos: 1,
        pad: 2,
        mask: 3,
        distinct_masks: (4..12).collect(),
        models,
        commits: BTreeMap::new(),
        prompts,
        pard_variants,
        main_pard: "pard-main".to_string(),
    }
}

/// Deterministic synthetic prompt sets (references are empty: there is
/// no grammar ground truth on the reference backend; equivalence tests
/// compare engines against each other instead).
pub fn synthetic_prompts(task: &str, seed: u64, manifest: &Manifest)
                         -> Result<PromptSet> {
    anyhow::ensure!(
        manifest.prompts.contains_key(task),
        "no prompt set `{task}` (have: {:?})",
        manifest.prompts.keys().collect::<Vec<_>>()
    );
    let mut rng = Rng::new(key_seed(seed, task) ^ 0x5052_4f4d_5054);
    let n = 32;
    let prompts = (0..n)
        .map(|_| {
            let len = rng.range(4, 9);
            let mut ids = Vec::with_capacity(len + 1);
            ids.push(manifest.bos);
            for _ in 0..len {
                ids.push(rng.range(REF_FIRST_PLAIN as usize,
                                   REF_VOCAB - 1) as i32);
            }
            Prompt { task: task.to_string(), prompt: ids,
                     reference: Vec::new() }
        })
        .collect();
    Ok(PromptSet { task: task.to_string(), prompts })
}

/// One decoder layer's parameters.  Fields are `pub(crate)` so the host
/// fast path ([`super::host::HostModel`], DESIGN.md §8) can drive the
/// *same* weights through restructured loops.
pub(crate) struct RefLayer {
    pub(crate) wq: Vec<f32>,      // [d, h*dh]
    pub(crate) wk: Vec<f32>,      // [d, h*dh]
    pub(crate) wv: Vec<f32>,      // [d, h*dh]
    pub(crate) wo: Vec<f32>,      // [h*dh, d]
    pub(crate) w1: Vec<f32>,      // [d, ff]
    pub(crate) w2: Vec<f32>,      // [ff, d]
    pub(crate) w3: Vec<f32>,      // [d, ff]
    pub(crate) ln_attn: Vec<f32>, // [d]
    pub(crate) ln_mlp: Vec<f32>,  // [d]
}

/// The deterministic scalar reference model — the bit-identity
/// oracle every backend is checked against (DESIGN.md §6).
pub struct RefModel {
    pub(crate) cfg: ModelCfg,
    pub(crate) kind: ModelKind,
    /// fwd exports a trailing hidden-state output.
    pub(crate) hidden: bool,
    pub(crate) embed: Vec<f32>, // [vocab, d]; lm head is tied
    pub(crate) layers: Vec<RefLayer>,
    pub(crate) ln_f: Vec<f32>,
    pub(crate) fuse: Option<Vec<f32>>, // [2d, d] (EAGLE)
    pub(crate) inv_freq: Vec<f32>,     // [d_head / 2]
}

fn dense(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Vec<f32> {
    (0..rows * cols).map(|_| rng.normal() as f32 * scale).collect()
}

impl RefModel {
    /// Build the model named by `entry`, deterministically from
    /// `seed` + the entry's weight key.
    pub fn build(seed: u64, entry: &ModelEntry) -> Result<RefModel> {
        let cfg = entry.cfg.clone();
        let (d, h, dh, ff, v) = (cfg.d_model, cfg.n_heads, cfg.d_head,
                                 cfg.d_ff, cfg.vocab);
        let hd = h * dh;
        let mut rng = Rng::new(key_seed(seed, &entry.weights));
        let embed = dense(&mut rng, v, d, 0.02);
        let layers = (0..cfg.n_layers)
            .map(|_| RefLayer {
                wq: dense(&mut rng, d, hd, (d as f32).powf(-0.5)),
                wk: dense(&mut rng, d, hd, (d as f32).powf(-0.5)),
                wv: dense(&mut rng, d, hd, (d as f32).powf(-0.5)),
                wo: dense(&mut rng, hd, d, (hd as f32).powf(-0.5)),
                w1: dense(&mut rng, d, ff, (d as f32).powf(-0.5)),
                w2: dense(&mut rng, ff, d, (ff as f32).powf(-0.5)),
                w3: dense(&mut rng, d, ff, (d as f32).powf(-0.5)),
                ln_attn: vec![1.0; d],
                ln_mlp: vec![1.0; d],
            })
            .collect();
        let fuse = match entry.kind {
            ModelKind::Eagle => Some(dense(&mut rng, 2 * d, d,
                                           (2.0 * d as f32).powf(-0.5))),
            ModelKind::Lm => None,
        };
        let half = dh / 2;
        let inv_freq = (0..half)
            .map(|c| ROPE_THETA.powf(-(c as f32) / half as f32))
            .collect();
        Ok(RefModel {
            cfg,
            kind: entry.kind,
            hidden: entry.hidden,
            embed,
            layers,
            ln_f: vec![1.0; d],
            fuse,
            inv_freq,
        })
    }
}

// ---------------------------------------------------------------------------
// fixed-order f32 math (order must never depend on batch layout)
// ---------------------------------------------------------------------------

/// rmsnorm per `d`-row: `x * rsqrt(mean(x²) + eps) * w`.
pub(crate) fn rmsnorm(x: &[f32], d: usize, w: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; x.len()];
    for i in 0..x.len() / d {
        let row = &x[i * d..(i + 1) * d];
        let mut ss = 0f32;
        for &e in row {
            ss += e * e;
        }
        let inv = 1.0 / (ss / d as f32 + 1e-5).sqrt();
        for j in 0..d {
            out[i * d + j] = row[j] * inv * w[j];
        }
    }
    out
}

/// `out[n, dout] += a[n, din] @ w[din, dout]` (fixed k-outer order).
///
/// The per-cell reduction order is `k` ascending starting from the
/// existing `out` value — the crate-wide canonical order every backend
/// must reproduce (DESIGN.md §6/§8).  The k-outer/j-inner loop shape
/// keeps the inner loop free of cross-iteration dependencies so the
/// compiler can vectorize across output cells without reassociating
/// any per-cell sum.
pub(crate) fn matmul_acc(a: &[f32], w: &[f32], out: &mut [f32], n: usize,
                         din: usize, dout: usize) {
    matmul_acc_range(a, w, out, n, din, dout, 0, dout);
}

/// [`matmul_acc`] restricted to output columns `c0..c1` — the
/// *spec-side anchor* of the host path's column decomposition
/// (DESIGN.md §8): each output cell `(i, j)` is an independent
/// reduction chain, so any column partition reproduces `matmul_acc`
/// bit for bit — the per-cell order stays `k` ascending from the
/// existing `out` value no matter which lane owns the column.  The
/// kernel that actually executes on the hot path is the packed-panel
/// sweep in `host.rs` (`PackedMat::matmul_acc_panels`), which must
/// keep exactly this contract; this scalar form states the claim at
/// oracle level and backs the column-split unit test.  `out` is still
/// the full `[n, dout]` buffer; only cells in `c0..c1` are touched.
#[allow(clippy::too_many_arguments)] // flat kernel signature, hot path
pub(crate) fn matmul_acc_range(a: &[f32], w: &[f32], out: &mut [f32],
                               n: usize, din: usize, dout: usize,
                               c0: usize, c1: usize) {
    debug_assert!(c0 <= c1 && c1 <= dout);
    let cols = c1 - c0;
    for i in 0..n {
        let ar = &a[i * din..(i + 1) * din];
        let or = &mut out[i * dout + c0..i * dout + c1];
        for (ki, &av) in ar.iter().enumerate() {
            let wr = &w[ki * dout + c0..ki * dout + c1];
            for j in 0..cols {
                or[j] += av * wr[j];
            }
        }
    }
}

/// Rotary embedding in place over one `[h, dh]` token vector.
fn rope(vecs: &mut [f32], p: i32, h: usize, dh: usize, inv_freq: &[f32]) {
    let half = dh / 2;
    for head in 0..h {
        let base = head * dh;
        for c in 0..half {
            let ang = p as f32 * inv_freq[c];
            let (sin, cos) = ang.sin_cos();
            let x1 = vecs[base + c];
            let x2 = vecs[base + half + c];
            vecs[base + c] = x1 * cos - x2 * sin;
            vecs[base + half + c] = x1 * sin + x2 * cos;
        }
    }
}

impl Backend for RefModel {
    fn cfg(&self) -> &ModelCfg {
        &self.cfg
    }

    fn kind(&self) -> ModelKind {
        self.kind
    }

    fn n_params(&self) -> usize {
        self.cfg.n_params(self.kind == ModelKind::Eagle)
    }

    /// No bucket grid: the reference path executes any T exactly.
    fn pick_t(&self, _b: usize, t_needed: usize) -> Result<usize> {
        Ok(t_needed.max(1))
    }

    fn new_cache(&self, batch: usize) -> Result<KvCache> {
        Ok(KvCache::host(&self.cfg, batch))
    }

    fn new_cache_sized(&self, batch: usize, kv_blocks: Option<usize>)
                       -> Result<KvCache> {
        match kv_blocks {
            Some(n) => KvCache::host_paged(&self.cfg, batch, n),
            None => self.new_cache(batch),
        }
    }

    fn fwd(&self, b: usize, t: usize, tokens: &[i32], pos: &[i32],
           hidden_in: Option<&[f32]>, cache: &KvCache) -> Result<FwdOut> {
        let t0 = stopwatch();
        let (d, h, dh, ff, vocab) = (self.cfg.d_model, self.cfg.n_heads,
                                     self.cfg.d_head, self.cfg.d_ff,
                                     self.cfg.vocab);
        let hd = h * dh;
        let s_max = cache.s_max;
        anyhow::ensure!(tokens.len() == b * t && pos.len() == b * t,
                        "tokens/pos must be [b*t]");
        anyhow::ensure!(b == cache.batch, "batch {b} != cache batch {}",
                        cache.batch);
        let host = match &cache.state {
            CacheState::Host(data) => data,
            #[cfg(feature = "pjrt")]
            CacheState::Device(_) => {
                anyhow::bail!("reference fwd needs a host cache")
            }
        };

        // token embeddings (EAGLE: fuse [target hidden ; embedding])
        let mut x = vec![0f32; b * t * d];
        match (self.kind, hidden_in) {
            (ModelKind::Lm, None) => {
                for i in 0..b * t {
                    let tok =
                        tokens[i].clamp(0, vocab as i32 - 1) as usize;
                    x[i * d..(i + 1) * d]
                        .copy_from_slice(&self.embed[tok * d..(tok + 1) * d]);
                }
            }
            (ModelKind::Eagle, Some(hin)) => {
                anyhow::ensure!(hin.len() == b * t * d,
                                "hidden_in must be [b*t*d]");
                let fuse = self.fuse.as_ref().expect("eagle has fuse");
                let mut cat = vec![0f32; 2 * d];
                for i in 0..b * t {
                    let tok =
                        tokens[i].clamp(0, vocab as i32 - 1) as usize;
                    cat[..d].copy_from_slice(&hin[i * d..(i + 1) * d]);
                    cat[d..].copy_from_slice(&self.embed[tok * d..(tok + 1) * d]);
                    let or = &mut x[i * d..(i + 1) * d];
                    for (r, &cv) in cat.iter().enumerate() {
                        let wr = &fuse[r * d..(r + 1) * d];
                        for j in 0..d {
                            or[j] += cv * wr[j];
                        }
                    }
                }
            }
            (ModelKind::Eagle, None) => {
                anyhow::bail!("EAGLE fwd requires hidden input")
            }
            (ModelKind::Lm, Some(_)) => {
                anyhow::bail!("LM fwd takes no hidden input")
            }
        }

        let n_layers = self.layers.len();
        let mut k_stage = vec![0f32; n_layers * b * t * hd];
        let mut v_stage = vec![0f32; n_layers * b * t * hd];
        let scale = 1.0 / (dh as f32).sqrt();
        let mut scores = vec![0f32; s_max];

        for (li, lyr) in self.layers.iter().enumerate() {
            let xn = rmsnorm(&x, d, &lyr.ln_attn);
            let mut q = vec![0f32; b * t * hd];
            let mut k = vec![0f32; b * t * hd];
            let mut v = vec![0f32; b * t * hd];
            matmul_acc(&xn, &lyr.wq, &mut q, b * t, d, hd);
            matmul_acc(&xn, &lyr.wk, &mut k, b * t, d, hd);
            matmul_acc(&xn, &lyr.wv, &mut v, b * t, d, hd);
            for i in 0..b * t {
                rope(&mut q[i * hd..(i + 1) * hd], pos[i], h, dh,
                     &self.inv_freq);
                rope(&mut k[i * hd..(i + 1) * hd], pos[i], h, dh,
                     &self.inv_freq);
            }
            k_stage[li * b * t * hd..(li + 1) * b * t * hd]
                .copy_from_slice(&k);
            v_stage[li * b * t * hd..(li + 1) * b * t * hd]
                .copy_from_slice(&v);

            // Transient cache view: persistent slots + this call's K/V
            // scattered at `pos` (the `extend` semantics; the persistent
            // cache is only mutated by `commit`).  Live queries attend
            // only slots <= pos < garbage, so the view is truncated at
            // the highest LIVE position; parked columns (pos == the
            // garbage slot) neither scatter into it nor attend it —
            // their outputs are ignored by contract, so they get zeros.
            let garbage = s_max - 1;
            let s_used = pos
                .iter()
                .map(|&p| p.clamp(0, s_max as i32 - 1) as usize)
                .filter(|&p| p < garbage)
                .max()
                .map_or(1, |p| p + 1);
            // Persistent slots resolve through the row's block table
            // (DESIGN.md §7); unmapped slots stay zero — they were
            // never committed, so the position mask already makes
            // them unattendable and the bytes cannot reach an output.
            let mut ck = vec![0f32; b * s_used * hd];
            let mut cv = vec![0f32; b * s_used * hd];
            for row in 0..b {
                for s in 0..s_used {
                    let dst = (row * s_used + s) * hd;
                    if let Some(off) = cache.slot_index(0, li, row, s) {
                        ck[dst..dst + hd]
                            .copy_from_slice(&host[off..off + hd]);
                    }
                    if let Some(off) = cache.slot_index(1, li, row, s) {
                        cv[dst..dst + hd]
                            .copy_from_slice(&host[off..off + hd]);
                    }
                }
            }
            for row in 0..b {
                for col in 0..t {
                    let slot = pos[row * t + col]
                        .clamp(0, s_max as i32 - 1) as usize;
                    if slot >= s_used {
                        continue; // parked column: garbage slot only
                    }
                    let src = (row * t + col) * hd;
                    let dst = (row * s_used + slot) * hd;
                    ck[dst..dst + hd].copy_from_slice(&k[src..src + hd]);
                    cv[dst..dst + hd].copy_from_slice(&v[src..src + hd]);
                }
            }

            // causal cached attention: slot s attendable iff s <= pos
            let mut attn = vec![0f32; b * t * hd];
            for row in 0..b {
                for col in 0..t {
                    let p = pos[row * t + col]
                        .clamp(0, s_max as i32 - 1) as usize;
                    if p >= s_used {
                        continue; // parked query: output ignored, zeros
                    }
                    for head in 0..h {
                        let qv = &q[(row * t + col) * hd + head * dh..];
                        let qv = &qv[..dh];
                        let mut m = f32::NEG_INFINITY;
                        for (s, sc) in scores.iter_mut()
                            .enumerate().take(p + 1)
                        {
                            let kv = &ck[(row * s_used + s) * hd
                                + head * dh..];
                            let mut acc = 0f32;
                            for e in 0..dh {
                                acc += qv[e] * kv[e];
                            }
                            *sc = acc * scale;
                            if *sc > m {
                                m = *sc;
                            }
                        }
                        let mut denom = 0f32;
                        for sc in scores.iter_mut().take(p + 1) {
                            *sc = (*sc - m).exp();
                            denom += *sc;
                        }
                        let out = &mut attn[(row * t + col) * hd
                            + head * dh..(row * t + col) * hd
                            + head * dh + dh];
                        for (s, sc) in scores.iter().enumerate()
                            .take(p + 1)
                        {
                            let w = sc / denom;
                            let vv = &cv[(row * s_used + s) * hd
                                + head * dh..];
                            for e in 0..dh {
                                out[e] += w * vv[e];
                            }
                        }
                    }
                }
            }
            matmul_acc(&attn, &lyr.wo, &mut x, b * t, hd, d);

            let xn2 = rmsnorm(&x, d, &lyr.ln_mlp);
            let mut g = vec![0f32; b * t * ff];
            let mut u = vec![0f32; b * t * ff];
            matmul_acc(&xn2, &lyr.w1, &mut g, b * t, d, ff);
            matmul_acc(&xn2, &lyr.w3, &mut u, b * t, d, ff);
            for i in 0..b * t * ff {
                let gv = g[i];
                g[i] = gv * (1.0 / (1.0 + (-gv).exp())) * u[i];
            }
            matmul_acc(&g, &lyr.w2, &mut x, b * t, ff, d);
        }

        let hidden = rmsnorm(&x, d, &self.ln_f);
        let mut logits = vec![0f32; b * t * vocab];
        for i in 0..b * t {
            let hr = &hidden[i * d..(i + 1) * d];
            for tok in 0..vocab {
                let er = &self.embed[tok * d..(tok + 1) * d];
                let mut acc = 0f32;
                for j in 0..d {
                    acc += hr[j] * er[j];
                }
                logits[i * vocab + tok] = acc;
            }
        }
        Ok(FwdOut {
            logits,
            hidden: if self.hidden { Some(hidden) } else { None },
            kv: KvStage::Host { k: k_stage, v: v_stage },
            elapsed_s: t0.elapsed().as_secs_f64(),
            ops: None,
        })
    }

    fn commit(&self, b: usize, t: usize, out: &FwdOut, commit_pos: &[i32],
              cache: &mut KvCache) -> Result<f64> {
        let t0 = stopwatch();
        match &out.kv {
            KvStage::Host { k, v } => {
                cache.host_scatter(b, t, k, v, commit_pos)?;
            }
            #[cfg(feature = "pjrt")]
            KvStage::Pjrt { .. } => {
                anyhow::bail!("PJRT FwdOut fed to the reference commit")
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sampling::argmax;

    fn model(name: &str) -> RefModel {
        let m = reference_manifest();
        RefModel::build(7, m.models.get(name).unwrap()).unwrap()
    }

    #[test]
    fn deterministic_weights_and_logits() {
        let a = model("draft-s");
        let b = model("draft-s");
        assert_eq!(a.embed, b.embed);
        let ca = a.new_cache(1).unwrap();
        let cb = b.new_cache(1).unwrap();
        let oa = a.fwd(1, 3, &[0, 13, 14], &[0, 1, 2], None, &ca).unwrap();
        let ob = b.fwd(1, 3, &[0, 13, 14], &[0, 1, 2], None, &cb).unwrap();
        assert_eq!(oa.logits, ob.logits);
    }

    #[test]
    fn weight_keys_share_and_split() {
        let tl = model("target-l");
        let tlh = model("target-l_h");
        assert_eq!(tl.embed, tlh.embed, "target-l_h shares target-l");
        let ds = model("draft-s");
        let pm = model("pard-main");
        assert_eq!(ds.embed, pm.embed, "pard-main shares draft-s");
        let tm = model("target-m");
        assert_ne!(&tm.embed[..8], &tl.embed[..8]);
    }

    #[test]
    fn padding_columns_do_not_change_live_logits() {
        // Same prompt through exact-T and padded-T calls (pads parked
        // at the garbage slot) must give identical live logits.
        let m = model("target-m");
        let cache = m.new_cache(1).unwrap();
        let g = cache.garbage_slot();
        let prompt = [0i32, 13, 20, 21];
        let vocab = m.cfg().vocab;
        let exact = m
            .fwd(1, 4, &prompt, &[0, 1, 2, 3], None, &cache)
            .unwrap();
        let mut toks = prompt.to_vec();
        let mut pos = vec![0, 1, 2, 3];
        toks.extend([2, 2, 2]); // pad
        pos.extend([g, g, g]);
        let padded = m.fwd(1, 7, &toks, &pos, None, &cache).unwrap();
        assert_eq!(exact.logits[..4 * vocab], padded.logits[..4 * vocab]);
    }

    #[test]
    fn commit_then_decode_matches_in_call_attention() {
        // Feeding [a, b] in one call must equal feeding [a], committing,
        // then feeding [b] — the cached-decode identity the engines
        // build on.
        let m = model("draft-s");
        let vocab = m.cfg().vocab;
        let joint_cache = m.new_cache(1).unwrap();
        let joint = m
            .fwd(1, 2, &[0, 17], &[0, 1], None, &joint_cache)
            .unwrap();
        let mut cache = m.new_cache(1).unwrap();
        let o0 = m.fwd(1, 1, &[0], &[0], None, &cache).unwrap();
        m.commit(1, 1, &o0, &[0], &mut cache).unwrap();
        cache.cur_len[0] = 1;
        let o1 = m.fwd(1, 1, &[17], &[1], None, &cache).unwrap();
        assert_eq!(&joint.logits[vocab..2 * vocab], &o1.logits[..vocab]);
        assert_eq!(argmax(&joint.logits[vocab..2 * vocab]),
                   argmax(&o1.logits[..vocab]));
    }

    #[test]
    fn rows_are_independent() {
        // Batch row r's logits must not depend on what other rows do.
        let m = model("draft-s");
        let cache1 = m.new_cache(1).unwrap();
        let solo = m.fwd(1, 2, &[0, 30], &[0, 1], None, &cache1).unwrap();
        let cache2 = m.new_cache(2).unwrap();
        let g = cache2.garbage_slot();
        let duo = m
            .fwd(2, 2, &[0, 30, 2, 2], &[0, 1, g, g], None, &cache2)
            .unwrap();
        let vocab = m.cfg().vocab;
        assert_eq!(solo.logits[..2 * vocab], duo.logits[..2 * vocab]);
    }

    #[test]
    fn eagle_head_runs_and_exports_hidden() {
        let m = model("eagle-target-l");
        let d = m.cfg().d_model;
        let cache = m.new_cache(1).unwrap();
        let hin = vec![0.25f32; 2 * d];
        let out = m.fwd(1, 2, &[0, 13], &[0, 1], Some(&hin), &cache)
            .unwrap();
        assert_eq!(out.hidden.as_ref().unwrap().len(), 2 * d);
        assert!(m.fwd(1, 1, &[0], &[0], None, &cache).is_err(),
                "eagle fwd without hidden must fail");
    }

    #[test]
    fn column_split_matmul_is_bit_identical() {
        // The §8 bit-safety claim at its smallest: computing output
        // columns in disjoint ranges (any partition, any order) must
        // reproduce the full-width matmul exactly, because no per-cell
        // reduction chain crosses a column.
        let mut rng = Rng::new(0x00C0_FFEE);
        let (n, din, dout) = (5usize, 24usize, 40usize);
        let a = dense(&mut rng, n, din, 0.3);
        let w = dense(&mut rng, din, dout, 0.3);
        let mut full: Vec<f32> =
            (0..n * dout).map(|i| (i % 7) as f32 * 0.01).collect();
        let mut split = full.clone();
        matmul_acc(&a, &w, &mut full, n, din, dout);
        // ragged three-way split, applied right-to-left
        for &(c0, c1) in &[(29usize, 40usize), (13, 29), (0, 13)] {
            matmul_acc_range(&a, &w, &mut split, n, din, dout, c0, c1);
        }
        assert_eq!(full, split, "column partition changed bits");
    }

    #[test]
    fn synthetic_prompts_are_deterministic_and_plain() {
        let m = reference_manifest();
        let a = synthetic_prompts("code", 7, &m).unwrap();
        let b = synthetic_prompts("code", 7, &m).unwrap();
        assert_eq!(a.prompts[0].prompt, b.prompts[0].prompt);
        assert!(a.prompts.iter().all(|p| {
            p.prompt[0] == m.bos
                && p.prompt[1..]
                    .iter()
                    .all(|&t| t >= REF_FIRST_PLAIN
                         && t < REF_VOCAB as i32)
        }));
        assert!(synthetic_prompts("nope", 7, &m).is_err());
    }
}
