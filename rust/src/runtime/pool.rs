//! Persistent worker pool for the host fast path (DESIGN.md §8).
//!
//! [`WorkerPool`] owns `threads - 1` long-lived parked OS threads; the
//! thread calling [`WorkerPool::run`] is always lane 0, so a pool of
//! `threads` lanes costs `threads - 1` spawns — once, at
//! `HostModel::build`, instead of per `fwd` call the way the previous
//! `std::thread::scope` design paid it.  Decode-shaped calls issue many
//! small dispatches back to back, so the dispatch protocol is built for
//! low latency:
//!
//! * **Publish**: `run` stores the task and bumps an epoch under a
//!   mutex, then notifies.  Workers watch the epoch with a bounded spin
//!   (`SPIN_ROUNDS` of `spin_loop`) before parking on a condvar — a hot
//!   decode loop never pays a futex wake.
//! * **Join**: workers decrement a `remaining` counter; `run` spins on
//!   it briefly, then parks on a second condvar.  `run` returns only
//!   after every lane finished, which is what makes it sound to hand
//!   workers closures borrowing the caller's stack (the borrow is
//!   erased to `'static` internally but never outlives the call).
//! * **Determinism**: the pool only decides *who* computes which
//!   output cells, never the per-cell reduction order (DESIGN.md §8),
//!   so results are bit-identical across lane counts — including
//!   `threads = 1`, where `run` degenerates to a plain call.
//!
//! A task panic is caught on the worker, recorded, and re-raised on the
//! caller after the dispatch drains, so a bug fails the call instead of
//! deadlocking the pool.

// Under `--cfg loom` the pool is built against loom's permutation-
// exploring twins of the std sync primitives, so the publish/park
// handshake can be model-checked exhaustively (see `loom_tests`
// below).  loom is NOT a committed dependency — the offline vendored
// build stays dependency-free; toolchain hosts add it as a local
// dev-dependency when running the opt-in ci.sh step (PARD_CI_LOOM).
#[cfg(loom)]
use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
#[cfg(loom)]
use loom::sync::{Arc, Condvar, Mutex};
#[cfg(loom)]
use loom::thread::JoinHandle;
#[cfg(not(loom))]
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
#[cfg(not(loom))]
use std::sync::{Arc, Condvar, Mutex};
#[cfg(not(loom))]
use std::thread::JoinHandle;

/// Sanity cap on pool lanes (`PARD_HOST_THREADS=9999` should not fork
/// bomb the host).
pub const MAX_THREADS: usize = 64;

/// Bounded busy-wait rounds before a waiter parks on its condvar.
/// Roughly a few microseconds: long enough to catch the back-to-back
/// dispatches of a decode loop, short enough that an idle pool costs
/// nothing measurable.
#[cfg(not(loom))]
const SPIN_ROUNDS: u32 = 1 << 14;
/// Loom models every spin iteration as a scheduling point; one round
/// keeps the state space tractable while still covering both the
/// spin-hit and the park path.
#[cfg(loom)]
const SPIN_ROUNDS: u32 = 1;

/// One bounded-spin pause.  Under loom this must be a model-visible
/// yield (not a CPU hint) or the scheduler would never interleave
/// inside the spin window.
#[inline]
fn spin_pause() {
    #[cfg(loom)]
    loom::thread::yield_now();
    #[cfg(not(loom))]
    std::hint::spin_loop();
}

#[cfg(loom)]
fn thread_builder() -> loom::thread::Builder {
    loom::thread::Builder::new()
}
#[cfg(not(loom))]
fn thread_builder() -> std::thread::Builder {
    std::thread::Builder::new()
}

/// A published task: called once per lane with the lane index.  The
/// `'static` is a lie told only inside this module — `run` blocks until
/// every worker has finished, so the erased borrow never escapes the
/// caller's frame.
type Task = &'static (dyn Fn(usize) + Sync);

struct Shared {
    /// Bumped once per published task (and once at shutdown); workers
    /// spin on this before touching the mutex.
    epoch: AtomicUsize,
    /// Workers still running the current task.
    remaining: AtomicUsize,
    /// A worker task panicked; re-raised on the caller after the join.
    panicked: AtomicBool,
    shutdown: AtomicBool,
    /// The current task, if a dispatch is in flight.  Written only
    /// under the lock that `go` waiters hold.
    task: Mutex<Option<Task>>,
    /// Workers park here between tasks (after the bounded spin).
    go: Condvar,
    /// `run` parks here waiting for the last worker.
    done_lock: Mutex<()>,
    done: Condvar,
}

/// Long-lived worker pool; see the module docs for the protocol.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    lanes: usize,
    /// Serializes whole `run` calls: the epoch/remaining/task protocol
    /// handles one dispatch at a time, and overlapping dispatches from
    /// two threads sharing this pool (it's `Sync` behind an `Arc`)
    /// would otherwise clobber each other's join state — which could
    /// let a caller return while workers still hold its
    /// lifetime-erased borrow.
    dispatch: Mutex<()>,
}

/// Pool size when the caller doesn't pin one: `PARD_HOST_THREADS` if
/// set to a positive integer, else `std::thread::available_parallelism`.
pub fn default_threads() -> usize {
    std::env::var("PARD_HOST_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .min(MAX_THREADS)
}

/// Contiguous balanced chunk of `0..n_items` owned by `lane` out of
/// `lanes`: the first `n_items % lanes` lanes get one extra item.
/// Returns `(start, end)`; empty when there are more lanes than items.
pub fn chunk(n_items: usize, lanes: usize, lane: usize) -> (usize, usize) {
    debug_assert!(lane < lanes);
    let base = n_items / lanes;
    let rem = n_items % lanes;
    let start = lane * base + lane.min(rem);
    let len = base + usize::from(lane < rem);
    (start, (start + len).min(n_items))
}

fn worker_loop(sh: &Shared, lane: usize) {
    // Epoch of the last task this worker ran (0 = none yet; the pool
    // starts at epoch 0 and bumps before the first dispatch).
    let mut seen = 0usize;
    loop {
        // Bounded spin: catches back-to-back decode dispatches without
        // a syscall.  The authoritative check happens under the mutex.
        let mut rounds = 0u32;
        while sh.epoch.load(Ordering::Acquire) == seen
            && rounds < SPIN_ROUNDS
        {
            spin_pause();
            rounds += 1;
        }
        let task = {
            let mut guard = sh.task.lock().unwrap();
            loop {
                if sh.shutdown.load(Ordering::Acquire) {
                    return;
                }
                let e = sh.epoch.load(Ordering::Acquire);
                if e != seen {
                    seen = e;
                    // Epoch only moves with a task published (run) or
                    // shutdown set (checked above).  `Task` is a shared
                    // ref, so this copies out of the guard.
                    break (*guard).expect("epoch bumped without a task");
                }
                guard = sh.go.wait(guard).unwrap();
            }
        };
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| task(lane)),
        );
        if result.is_err() {
            sh.panicked.store(true, Ordering::Release);
        }
        if sh.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last lane out: wake the caller.  Taking the lock orders
            // the notify after the caller's remaining-check-then-wait.
            let _guard = sh.done_lock.lock().unwrap();
            sh.done.notify_all();
        }
    }
}

impl WorkerPool {
    /// Build a pool with `threads` total lanes (clamped to
    /// `1..=MAX_THREADS`); spawns `threads - 1` worker threads.
    pub fn new(threads: usize) -> Self {
        let lanes = threads.clamp(1, MAX_THREADS);
        let shared = Arc::new(Shared {
            epoch: AtomicUsize::new(0),
            remaining: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            task: Mutex::new(None),
            go: Condvar::new(),
            done_lock: Mutex::new(()),
            done: Condvar::new(),
        });
        let workers = (1..lanes)
            .map(|lane| {
                let sh = Arc::clone(&shared);
                thread_builder()
                    .name(format!("pard-host-{lane}"))
                    .spawn(move || worker_loop(&sh, lane))
                    .expect("spawn host worker thread")
            })
            .collect();
        WorkerPool { shared, workers, lanes, dispatch: Mutex::new(()) }
    }

    /// Total lanes (worker threads + the calling thread).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Execute `f(lane)` once per lane (0 = the calling thread) and
    /// return when every lane finished.  `f` decides its own slice of
    /// the work from the lane index (see [`chunk`]); the pool never
    /// splits anything itself, so it cannot change any reduction order.
    /// Concurrent `run` calls from threads sharing the pool serialize
    /// on an internal lock (one dispatch in flight at a time).
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.workers.is_empty() {
            f(0);
            return;
        }
        // One dispatch at a time: see the `dispatch` field docs.  A
        // poisoned lock just means an earlier dispatch re-raised a
        // task panic while holding it — the protocol state was already
        // drained, so the pool stays usable.
        let _in_flight =
            self.dispatch.lock().unwrap_or_else(|e| e.into_inner());
        let sh = &*self.shared;
        {
            let mut guard = sh.task.lock().unwrap();
            // SAFETY: lifetime erasure only — this call blocks below
            // until `remaining` hits 0, i.e. until no worker can still
            // dereference the borrow.
            #[allow(clippy::useless_transmute)] // erases a region, not a no-op
            let erased: Task = unsafe {
                std::mem::transmute::<
                    &(dyn Fn(usize) + Sync),
                    &'static (dyn Fn(usize) + Sync),
                >(f)
            };
            *guard = Some(erased);
            sh.remaining.store(self.workers.len(), Ordering::Release);
            sh.epoch.fetch_add(1, Ordering::Release);
            sh.go.notify_all();
        }
        // The caller lane must not unwind past the join below while
        // workers still hold the lifetime-erased borrow — catch, join,
        // then resume.
        let lane0 = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(0)),
        );
        // Join: spin briefly (balanced partitions finish together),
        // then park.
        let mut rounds = 0u32;
        while sh.remaining.load(Ordering::Acquire) != 0
            && rounds < SPIN_ROUNDS
        {
            spin_pause();
            rounds += 1;
        }
        if sh.remaining.load(Ordering::Acquire) != 0 {
            let mut guard = sh.done_lock.lock().unwrap();
            while sh.remaining.load(Ordering::Acquire) != 0 {
                guard = sh.done.wait(guard).unwrap();
            }
        }
        *sh.task.lock().unwrap() = None;
        let worker_panicked = sh.panicked.swap(false, Ordering::AcqRel);
        if let Err(payload) = lane0 {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("host worker-pool task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let _guard = self.shared.task.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
            // Bump the epoch so spinning workers re-check shutdown.
            self.shared.epoch.fetch_add(1, Ordering::Release);
            self.shared.go.notify_all();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Shared-mutable view of an `f32` buffer for pool lanes writing
/// *disjoint* index ranges (matmul column panels, per-(row, head)
/// attention outputs).  The soundness argument is the same one the
/// column decomposition's bit-safety rests on: every output cell is
/// owned by exactly one lane.
pub(crate) struct SharedSlice {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: lanes only touch disjoint ranges (asserted by the callers
// handing out panel/item partitions); the pool's join provides the
// release/acquire edge back to the caller.
unsafe impl Send for SharedSlice {}
unsafe impl Sync for SharedSlice {}

impl SharedSlice {
    pub(crate) fn new(buf: &mut [f32]) -> Self {
        SharedSlice { ptr: buf.as_mut_ptr(), len: buf.len() }
    }

    /// Reborrow `start..start + len` mutably.
    ///
    /// # Safety
    /// Concurrent callers must hand out non-overlapping ranges, and no
    /// range may outlive the buffer borrowed by [`SharedSlice::new`]
    /// (both hold for pool tasks: partitions are disjoint by
    /// construction and `run` joins before the buffer dies).
    #[allow(clippy::mut_from_ref)] // deliberate: disjoint-range cells
    #[inline]
    pub(crate) unsafe fn range(&self, start: usize, len: usize)
                               -> &mut [f32] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    #[test]
    fn chunks_cover_and_balance() {
        for &(n, lanes) in
            &[(10usize, 3usize), (4, 8), (0, 2), (7, 1), (64, 5)]
        {
            let mut covered = vec![0u32; n];
            let mut sizes = Vec::new();
            for lane in 0..lanes {
                let (s, e) = chunk(n, lanes, lane);
                sizes.push(e - s);
                for c in covered.iter_mut().take(e).skip(s) {
                    *c += 1;
                }
            }
            assert!(covered.iter().all(|&c| c == 1),
                    "chunk({n}, {lanes}) must partition exactly once");
            let (min, max) = (sizes.iter().min().unwrap(),
                              sizes.iter().max().unwrap());
            assert!(max - min <= 1, "chunks must be balanced");
        }
    }

    #[test]
    fn pool_runs_every_lane_and_is_reusable() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.lanes(), 4);
        let hits = AtomicU64::new(0);
        for round in 0..50u64 {
            pool.run(&|lane| {
                hits.fetch_add(1 << (8 * lane as u64), Ordering::Relaxed);
            });
            // every lane ran exactly once per round
            let h = hits.load(Ordering::Relaxed);
            for lane in 0..4 {
                assert_eq!((h >> (8 * lane)) & 0xff, round + 1,
                           "lane {lane} after round {round}");
            }
        }
    }

    #[test]
    fn pool_of_one_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.lanes(), 1);
        let touched = AtomicBool::new(false);
        pool.run(&|lane| {
            assert_eq!(lane, 0, "a 1-lane pool runs on the caller");
            touched.store(true, Ordering::Relaxed);
        });
        assert!(touched.load(Ordering::Relaxed));
    }

    #[test]
    fn disjoint_writes_assemble() {
        let pool = WorkerPool::new(3);
        let n = 1000usize;
        let mut buf = vec![0f32; n];
        let out = SharedSlice::new(&mut buf);
        pool.run(&|lane| {
            let (s, e) = chunk(n, 3, lane);
            // SAFETY: chunks are disjoint.
            let dst = unsafe { out.range(s, e - s) };
            for (i, d) in dst.iter_mut().enumerate() {
                *d = (s + i) as f32;
            }
        });
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
    }

    #[test]
    fn worker_panic_propagates_instead_of_deadlocking() {
        let pool = WorkerPool::new(2);
        let poisoned = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run(&|lane| {
                    if lane == 1 {
                        panic!("boom");
                    }
                });
            }),
        );
        assert!(poisoned.is_err(), "worker panic must surface");
        // the pool survives and serves the next dispatch
        let ok = AtomicU64::new(0);
        pool.run(&|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn default_threads_is_positive_and_capped() {
        let n = default_threads();
        assert!((1..=MAX_THREADS).contains(&n));
    }
}

/// Loom model checks for the publish/park handshake (DESIGN.md §11).
///
/// Not part of the default test run: loom is not a committed
/// dependency (the offline vendored build must stay dependency-free).
/// On a toolchain host with network access:
///
/// ```text
/// cargo add loom --dev        # local only — do NOT commit
/// RUSTFLAGS="--cfg loom" cargo test --release loom_
/// ```
///
/// or let ci.sh drive it via `PARD_CI_LOOM=1 ./ci.sh`.  Each test body
/// runs under `loom::model`, which exhaustively permutes every
/// scheduling decision the shims expose (SPIN_ROUNDS = 1 under loom
/// keeps the state space tractable while still covering both the
/// spin-hit and the park path).
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use loom::sync::atomic::AtomicUsize as LoomUsize;

    /// Every dispatch runs every lane exactly once, and a second
    /// dispatch on the same pool cannot lose its wakeup: if the
    /// publish→notify could race a worker's spin→park transition, some
    /// interleaving would deadlock (loom reports it) or drop a lane
    /// (the counter check fails).
    #[test]
    fn loom_no_lost_wakeups() {
        loom::model(|| {
            let pool = WorkerPool::new(2);
            let hits = LoomUsize::new(0);
            pool.run(&|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 2);
            pool.run(&|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 4);
        });
    }

    /// Disjoint lane writes through `SharedSlice` are all visible to
    /// the caller after `run` returns, under every interleaving: the
    /// join's release/acquire edge is what publishes them.
    #[test]
    fn loom_disjoint_writes_are_published() {
        loom::model(|| {
            let pool = WorkerPool::new(2);
            let n = 4usize;
            let mut buf = vec![0f32; n];
            let out = SharedSlice::new(&mut buf);
            pool.run(&|lane| {
                let (s, e) = chunk(n, 2, lane);
                // SAFETY: chunks are disjoint.
                let dst = unsafe { out.range(s, e - s) };
                for (i, d) in dst.iter_mut().enumerate() {
                    *d = (s + i) as f32 + 1.0;
                }
            });
            for (i, &v) in buf.iter().enumerate() {
                assert_eq!(v, i as f32 + 1.0, "lane write lost at {i}");
            }
        });
    }

    /// Drop must terminate parked AND spinning workers in every
    /// interleaving — a missed shutdown wakeup would deadlock the
    /// `join` in `Drop` and loom would report the stuck branch.
    #[test]
    fn loom_shutdown_terminates_workers() {
        loom::model(|| {
            let pool = WorkerPool::new(2);
            drop(pool);
        });
    }
}
