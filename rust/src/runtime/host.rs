//! Fast deterministic host serving backend (DESIGN.md §8).
//!
//! [`HostModel`] drives the *same weights* as the scalar reference
//! oracle ([`super::reference::RefModel`], DESIGN.md §6) through a
//! restructured forward pass built for speed on real host CPUs while
//! keeping the oracle's bit-exact determinism contract:
//!
//! * **Identical per-cell arithmetic.**  Every floating-point reduction
//!   (matmul cells, attention scores, softmax sums, weighted-V sums,
//!   rmsnorm squares, logit dot products) runs in exactly the scalar
//!   oracle's fixed ascending order, starting from the same initial
//!   value.  Loop *shape* is free — k-outer vs dot-product, slot
//!   unrolling, thread partitioning — as long as no per-cell sum is
//!   reassociated.  Live-cell outputs are therefore bit-identical to
//!   `RefModel`, which is what lets the engine-equivalence suite (and
//!   `tests/host_backend.rs`) compare the two backends exactly instead
//!   of approximately.
//! * **Dead work is skipped, not recomputed.**  Parked cells (queries
//!   positioned at the garbage slot, DESIGN.md §7) are dropped before
//!   the first matmul; their logits/hidden/staged-KV outputs are zeros.
//!   The slot contract already promises nobody reads them — the scalar
//!   oracle spends full matmul/MLP/logit FLOPs on them anyway (a 32-wide
//!   prefill call with an 8-token prompt does 4x the live work).
//! * **The KV cache is read in place.**  The oracle materialises a
//!   transient `[b, s_used, H*D]` copy of the persistent cache *per
//!   layer per call*; the host path resolves each attended slot through
//!   a per-row `slot -> staged column` map — staged K/V from this call
//!   win, otherwise the persistent tensor is read directly through a
//!   `Sync` borrowed view (`CacheView`).  No copies, identical values.
//! * **Rotary tables are computed once per call.**  `sin/cos(pos *
//!   inv_freq)` depends only on the cell position, yet the oracle
//!   re-evaluates it per layer *and per head*: `2 * L * H * (D/2)`
//!   `sin_cos` calls per cell where one `D/2` pass suffices.  The trig
//!   elimination alone is the single largest win on decode-shaped calls.
//! * **Batch rows run in parallel.**  Rows are partitioned into
//!   contiguous chunks executed on `std::thread::scope` threads.  Rows
//!   share no state (DESIGN.md §6 row independence), every chunk writes
//!   a private output block, and per-cell order never depends on the
//!   partition — so outputs are bit-identical across thread counts,
//!   machines, and runs.
//!
//! What stays deliberately identical to the oracle: `f32::exp` in
//! softmax/SiLU and `sin_cos` values (same libm calls, same bits), the
//! fwd/commit split, `pick_t` exact-T semantics, and the garbage-slot
//! commit protocol via [`KvCache::host_scatter`].

// Kernel-style index loops are deliberate here: the fixed per-cell
// reduction order *is* the spec (see module docs), and explicit indices
// keep that order auditable against reference.rs line by line.
#![allow(clippy::needless_range_loop)]

use std::time::Instant;

use anyhow::Result;

use super::artifact::{ModelCfg, ModelEntry, ModelKind};
use super::backend::{Backend, FwdOut, KvStage};
use super::cache::{CacheState, KvCache};
use super::reference::{matmul_acc, rmsnorm, RefModel};

/// Read-only view of a host cache tensor plus its layout.  `KvCache`
/// itself cannot cross a scoped-thread boundary (its PJRT variant holds
/// non-`Send` device handles under `--features pjrt`); this borrowed
/// view is plain `&[f32]` + dimensions and is always `Sync`.
struct CacheView<'a> {
    data: &'a [f32],
    n_layers: usize,
    batch: usize,
    s_max: usize,
    hd: usize,
}

impl CacheView<'_> {
    /// Offset of `[c, l, row, slot 0]` — delegates to the cache's
    /// single-source-of-truth layout formula.
    #[inline]
    fn off(&self, c: usize, l: usize, row: usize) -> usize {
        KvCache::flat_off(self.n_layers, self.batch, self.s_max, self.hd,
                          c, l, row, 0)
    }
}

/// One thread's private output block covering batch rows
/// `r0 .. r0 + rows` (assembled into the `FwdOut` layout by `fwd`).
struct RowBlock {
    r0: usize,
    rows: usize,
    /// `[rows, t, vocab]`; parked cells are zero.
    logits: Vec<f32>,
    /// `[rows, t, d]` when the model exports hidden states.
    hidden: Option<Vec<f32>>,
    /// `[L, rows, t, H*D]`; parked cells are zero.
    k_stage: Vec<f32>,
    v_stage: Vec<f32>,
}

/// Resolve the K or V vector attended at `slot`: this call's staged
/// column if the slot map says the slot was written in-flight, else the
/// persistent cache tensor read in place.  Returns exactly the bytes
/// the oracle's transient merged copy would hold.
#[inline(always)]
fn slot_kv<'a>(stage: &'a [f32], cache: &'a [f32], map: &[i32],
               map_base: usize, slot: usize, cache_base: usize,
               hd: usize, head_off: usize, dh: usize) -> &'a [f32] {
    let j = map[map_base + slot];
    if j >= 0 {
        &stage[j as usize * hd + head_off..][..dh]
    } else {
        &cache[cache_base + slot * hd + head_off..][..dh]
    }
}

/// The fast host backend: scalar-oracle weights, restructured execution.
pub struct HostModel {
    m: RefModel,
    /// `[d, vocab]` transpose of the tied embedding, so the logit
    /// projection runs through `matmul_acc` (k-outer, vectorizable)
    /// instead of the oracle's scalar per-cell dot products.  Same
    /// per-cell add order, same bits.
    embed_t: Vec<f32>,
    /// Worker threads to span batch rows across (`>= 1`).
    threads: usize,
}

impl HostModel {
    /// Build the model named by `entry` — same deterministic weights as
    /// [`RefModel::build`] for the same `(seed, entry)`.
    pub fn build(seed: u64, entry: &ModelEntry) -> Result<HostModel> {
        let m = RefModel::build(seed, entry)?;
        let (v, d) = (m.cfg.vocab, m.cfg.d_model);
        let mut embed_t = vec![0f32; d * v];
        for tok in 0..v {
            for j in 0..d {
                embed_t[j * v + tok] = m.embed[tok * d + j];
            }
        }
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Ok(HostModel { m, embed_t, threads })
    }

    /// Forward over batch rows `r0 .. r0 + rows` only.  Pure function of
    /// its row range: no other row's tokens, cache lines, or scratch are
    /// ever read, which is what makes the scoped-thread split bit-safe.
    fn fwd_rows(&self, view: &CacheView, t: usize, r0: usize, rows: usize,
                tokens: &[i32], pos: &[i32], hidden_in: Option<&[f32]>,
                s_used: usize) -> RowBlock {
        let cfg = &self.m.cfg;
        let (d, h, dh, ff, vocab) =
            (cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff, cfg.vocab);
        let hd = h * dh;
        let half = dh / 2;
        let n_layers = self.m.layers.len();
        let mut blk = RowBlock {
            r0,
            rows,
            logits: vec![0f32; rows * t * vocab],
            hidden: if self.m.hidden {
                Some(vec![0f32; rows * t * d])
            } else {
                None
            },
            k_stage: vec![0f32; n_layers * rows * t * hd],
            v_stage: vec![0f32; n_layers * rows * t * hd],
        };

        // Live-cell gather: local cell index (lrow * t + col), local
        // batch row, clamped position.  Everything parked is dropped
        // here and never touched again.
        let mut cells: Vec<usize> = Vec::with_capacity(rows * t);
        let mut lrows: Vec<usize> = Vec::with_capacity(rows * t);
        let mut ps: Vec<usize> = Vec::with_capacity(rows * t);
        // Raw (unclamped) positions, kept separately: the oracle ropes
        // Q/K with the raw `pos` value and clamps only for slot
        // scatter/attention — bit-identity requires doing the same.
        let mut praw: Vec<i32> = Vec::with_capacity(rows * t);
        for lrow in 0..rows {
            for col in 0..t {
                let gi = (r0 + lrow) * t + col;
                let p = pos[gi].clamp(0, view.s_max as i32 - 1) as usize;
                if p < s_used {
                    cells.push(lrow * t + col);
                    lrows.push(lrow);
                    ps.push(p);
                    praw.push(pos[gi]);
                }
            }
        }
        let n = cells.len();
        if n == 0 {
            return blk;
        }

        // Token embeddings (EAGLE: fuse [target hidden ; embedding]),
        // gathered densely over live cells only.
        let mut x = vec![0f32; n * d];
        match (self.m.kind, hidden_in) {
            (ModelKind::Lm, _) => {
                for j in 0..n {
                    // global cell = (r0 + lrow) * t + col = r0*t + cells[j]
                    let tok = tokens[r0 * t + cells[j]]
                        .clamp(0, vocab as i32 - 1) as usize;
                    x[j * d..(j + 1) * d].copy_from_slice(
                        &self.m.embed[tok * d..(tok + 1) * d]);
                }
            }
            (ModelKind::Eagle, Some(hin)) => {
                let fuse = self.m.fuse.as_ref().expect("eagle has fuse");
                let mut cat = vec![0f32; n * 2 * d];
                for j in 0..n {
                    let gi = r0 * t + cells[j];
                    let tok =
                        tokens[gi].clamp(0, vocab as i32 - 1) as usize;
                    cat[j * 2 * d..j * 2 * d + d]
                        .copy_from_slice(&hin[gi * d..(gi + 1) * d]);
                    cat[j * 2 * d + d..(j + 1) * 2 * d]
                        .copy_from_slice(&self.m.embed[tok * d..(tok + 1) * d]);
                }
                matmul_acc(&cat, fuse, &mut x, n, 2 * d, d);
            }
            (ModelKind::Eagle, None) => {
                unreachable!("validated by fwd()")
            }
        }

        // Rotary tables: one sin/cos row per live cell, shared by every
        // layer and head (the oracle recomputes these 2*L*H times).
        let mut sin_t = vec![0f32; n * half];
        let mut cos_t = vec![0f32; n * half];
        for j in 0..n {
            for c in 0..half {
                let ang = praw[j] as f32 * self.m.inv_freq[c];
                let (s, co) = ang.sin_cos();
                sin_t[j * half + c] = s;
                cos_t[j * half + c] = co;
            }
        }

        // slot -> live-cell map per local row: which in-flight column
        // occupies a cache slot for the duration of this call (later
        // columns win, matching the oracle's scatter order).
        let mut staged_at = vec![-1i32; rows * s_used];
        for j in 0..n {
            staged_at[lrows[j] * s_used + ps[j]] = j as i32;
        }

        // Layer-loop scratch, allocated once and reused.
        let mut q = vec![0f32; n * hd];
        let mut k = vec![0f32; n * hd];
        let mut v = vec![0f32; n * hd];
        let mut attn = vec![0f32; n * hd];
        let mut g = vec![0f32; n * ff];
        let mut u = vec![0f32; n * ff];
        let mut scores = vec![0f32; s_used];
        let scale = 1.0 / (dh as f32).sqrt();

        for (l, lyr) in self.m.layers.iter().enumerate() {
            let xn = rmsnorm(&x, d, &lyr.ln_attn);
            q.fill(0.0);
            k.fill(0.0);
            v.fill(0.0);
            matmul_acc(&xn, &lyr.wq, &mut q, n, d, hd);
            matmul_acc(&xn, &lyr.wk, &mut k, n, d, hd);
            matmul_acc(&xn, &lyr.wv, &mut v, n, d, hd);

            // Rotary, from the precomputed tables.
            for j in 0..n {
                let (st, ct) =
                    (&sin_t[j * half..(j + 1) * half],
                     &cos_t[j * half..(j + 1) * half]);
                for head in 0..h {
                    let base = j * hd + head * dh;
                    for c in 0..half {
                        let (sin, cos) = (st[c], ct[c]);
                        let q1 = q[base + c];
                        let q2 = q[base + half + c];
                        q[base + c] = q1 * cos - q2 * sin;
                        q[base + half + c] = q1 * sin + q2 * cos;
                        let k1 = k[base + c];
                        let k2 = k[base + half + c];
                        k[base + c] = k1 * cos - k2 * sin;
                        k[base + half + c] = k1 * sin + k2 * cos;
                    }
                }
            }

            // Stage this call's K/V into the output block (parked cells
            // stay zero; they only ever commit to the garbage slot).
            for j in 0..n {
                let dst = (l * rows * t + cells[j]) * hd;
                blk.k_stage[dst..dst + hd]
                    .copy_from_slice(&k[j * hd..(j + 1) * hd]);
                blk.v_stage[dst..dst + hd]
                    .copy_from_slice(&v[j * hd..(j + 1) * hd]);
            }

            // Causal cached attention, persistent tensor read in place.
            attn.fill(0.0);
            for j in 0..n {
                let (lrow, p) = (lrows[j], ps[j]);
                let grow = r0 + lrow;
                let map_base = lrow * s_used;
                let kc_base = view.off(0, l, grow);
                let vc_base = view.off(1, l, grow);
                for head in 0..h {
                    let head_off = head * dh;
                    let qv = &q[j * hd + head_off..j * hd + head_off + dh];
                    // Scores: 4 independent accumulator chains hide the
                    // serial-add latency; each chain is still the
                    // oracle's e-ascending per-cell order.
                    let mut s = 0usize;
                    while s + 4 <= p + 1 {
                        let k0 = slot_kv(&k, view.data, &staged_at,
                                         map_base, s, kc_base, hd,
                                         head_off, dh);
                        let k1 = slot_kv(&k, view.data, &staged_at,
                                         map_base, s + 1, kc_base, hd,
                                         head_off, dh);
                        let k2 = slot_kv(&k, view.data, &staged_at,
                                         map_base, s + 2, kc_base, hd,
                                         head_off, dh);
                        let k3 = slot_kv(&k, view.data, &staged_at,
                                         map_base, s + 3, kc_base, hd,
                                         head_off, dh);
                        let (mut a0, mut a1, mut a2, mut a3) =
                            (0f32, 0f32, 0f32, 0f32);
                        for e in 0..dh {
                            let qe = qv[e];
                            a0 += qe * k0[e];
                            a1 += qe * k1[e];
                            a2 += qe * k2[e];
                            a3 += qe * k3[e];
                        }
                        scores[s] = a0 * scale;
                        scores[s + 1] = a1 * scale;
                        scores[s + 2] = a2 * scale;
                        scores[s + 3] = a3 * scale;
                        s += 4;
                    }
                    while s <= p {
                        let kr = slot_kv(&k, view.data, &staged_at,
                                         map_base, s, kc_base, hd,
                                         head_off, dh);
                        let mut acc = 0f32;
                        for e in 0..dh {
                            acc += qv[e] * kr[e];
                        }
                        scores[s] = acc * scale;
                        s += 1;
                    }
                    let mut m = f32::NEG_INFINITY;
                    for &sc in scores.iter().take(p + 1) {
                        if sc > m {
                            m = sc;
                        }
                    }
                    let mut denom = 0f32;
                    for sc in scores.iter_mut().take(p + 1) {
                        *sc = (*sc - m).exp();
                        denom += *sc;
                    }
                    let out = &mut attn
                        [j * hd + head_off..j * hd + head_off + dh];
                    for s in 0..=p {
                        let w = scores[s] / denom;
                        let vr = slot_kv(&v, view.data, &staged_at,
                                         map_base, s, vc_base, hd,
                                         head_off, dh);
                        for e in 0..dh {
                            out[e] += w * vr[e];
                        }
                    }
                }
            }
            matmul_acc(&attn, &lyr.wo, &mut x, n, hd, d);

            let xn2 = rmsnorm(&x, d, &lyr.ln_mlp);
            g.fill(0.0);
            u.fill(0.0);
            matmul_acc(&xn2, &lyr.w1, &mut g, n, d, ff);
            matmul_acc(&xn2, &lyr.w3, &mut u, n, d, ff);
            for i in 0..n * ff {
                let gv = g[i];
                g[i] = gv * (1.0 / (1.0 + (-gv).exp())) * u[i];
            }
            matmul_acc(&g, &lyr.w2, &mut x, n, ff, d);
        }

        // Final norm + tied-embedding logits, scattered back to the
        // (zeros-padded) call layout.
        let hidden = rmsnorm(&x, d, &self.m.ln_f);
        let mut logits = vec![0f32; n * vocab];
        matmul_acc(&hidden, &self.embed_t, &mut logits, n, d, vocab);
        for j in 0..n {
            let dst = cells[j] * vocab;
            blk.logits[dst..dst + vocab]
                .copy_from_slice(&logits[j * vocab..(j + 1) * vocab]);
        }
        if let Some(bh) = blk.hidden.as_mut() {
            for j in 0..n {
                let dst = cells[j] * d;
                bh[dst..dst + d]
                    .copy_from_slice(&hidden[j * d..(j + 1) * d]);
            }
        }
        blk
    }
}

impl Backend for HostModel {
    fn cfg(&self) -> &ModelCfg {
        &self.m.cfg
    }

    fn kind(&self) -> ModelKind {
        self.m.kind
    }

    fn n_params(&self) -> usize {
        self.m.cfg.n_params(self.m.kind == ModelKind::Eagle)
    }

    /// No bucket grid: the host path executes any T exactly (same call
    /// layouts as the scalar oracle, so engine traffic is identical).
    fn pick_t(&self, _b: usize, t_needed: usize) -> Result<usize> {
        Ok(t_needed.max(1))
    }

    fn new_cache(&self, batch: usize) -> Result<KvCache> {
        Ok(KvCache::host(&self.m.cfg, batch))
    }

    fn fwd(&self, b: usize, t: usize, tokens: &[i32], pos: &[i32],
           hidden_in: Option<&[f32]>, cache: &KvCache) -> Result<FwdOut> {
        let t0 = Instant::now();
        let cfg = &self.m.cfg;
        let (d, vocab) = (cfg.d_model, cfg.vocab);
        let hd = cfg.n_heads * cfg.d_head;
        let s_max = cache.s_max;
        anyhow::ensure!(b >= 1 && t >= 1, "empty call shape {b}x{t}");
        anyhow::ensure!(tokens.len() == b * t && pos.len() == b * t,
                        "tokens/pos must be [b*t]");
        anyhow::ensure!(b == cache.batch, "batch {b} != cache batch {}",
                        cache.batch);
        match (self.m.kind, hidden_in) {
            (ModelKind::Eagle, None) => {
                anyhow::bail!("EAGLE fwd requires hidden input")
            }
            (ModelKind::Lm, Some(_)) => {
                anyhow::bail!("LM fwd takes no hidden input")
            }
            (ModelKind::Eagle, Some(hin)) => {
                anyhow::ensure!(hin.len() == b * t * d,
                                "hidden_in must be [b*t*d]");
            }
            (ModelKind::Lm, None) => {}
        }
        let data = match &cache.state {
            CacheState::Host(data) => data,
            #[cfg(feature = "pjrt")]
            CacheState::Device(_) => {
                anyhow::bail!("host fwd needs a host cache")
            }
        };
        let view = CacheView {
            data,
            n_layers: cache.n_layers,
            batch: cache.batch,
            s_max,
            hd,
        };

        // Same truncated-view bound as the oracle: the highest LIVE
        // position; cells at or past it are parked.
        let garbage = s_max - 1;
        let s_used = pos
            .iter()
            .map(|&p| p.clamp(0, s_max as i32 - 1) as usize)
            .filter(|&p| p < garbage)
            .max()
            .map_or(1, |p| p + 1);

        // Partition batch rows into contiguous per-thread chunks.  The
        // per-cell math is row-local, so the partition (and thread
        // count) can never change a single output bit — only wall
        // clock.  Scoped threads are spawned per call, so tiny
        // (decode-shaped) calls stay single-threaded: spawn+join costs
        // tens of microseconds, comparable to a whole t=1 row on the
        // synthetic models.
        let live_total = pos
            .iter()
            .filter(|&&p| {
                (p.clamp(0, s_max as i32 - 1) as usize) < s_used
            })
            .count();
        const PAR_MIN_LIVE_CELLS: usize = 16;
        let workers = if live_total >= PAR_MIN_LIVE_CELLS {
            self.threads.min(b).max(1)
        } else {
            1
        };
        let chunk = b.div_ceil(workers);
        let ranges: Vec<(usize, usize)> = (0..b)
            .step_by(chunk)
            .map(|r0| (r0, chunk.min(b - r0)))
            .collect();
        let blocks: Vec<RowBlock> = if ranges.len() == 1 {
            vec![self.fwd_rows(&view, t, 0, b, tokens, pos, hidden_in,
                               s_used)]
        } else {
            let view_ref = &view;
            std::thread::scope(|sc| {
                let handles: Vec<_> = ranges
                    .iter()
                    .map(|&(r0, rows)| {
                        sc.spawn(move || {
                            self.fwd_rows(view_ref, t, r0, rows, tokens,
                                          pos, hidden_in, s_used)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|hdl| hdl.join().expect("host worker panicked"))
                    .collect()
            })
        };

        // Assemble private row blocks into the FwdOut layouts.
        let n_layers = self.m.layers.len();
        let mut logits = vec![0f32; b * t * vocab];
        let mut hidden_out = if self.m.hidden {
            Some(vec![0f32; b * t * d])
        } else {
            None
        };
        let mut k_stage = vec![0f32; n_layers * b * t * hd];
        let mut v_stage = vec![0f32; n_layers * b * t * hd];
        for blk in &blocks {
            let (r0, rows) = (blk.r0, blk.rows);
            logits[r0 * t * vocab..(r0 + rows) * t * vocab]
                .copy_from_slice(&blk.logits);
            if let (Some(hout), Some(bh)) =
                (hidden_out.as_mut(), blk.hidden.as_ref())
            {
                hout[r0 * t * d..(r0 + rows) * t * d].copy_from_slice(bh);
            }
            for l in 0..n_layers {
                let src = &blk.k_stage[l * rows * t * hd
                    ..(l + 1) * rows * t * hd];
                k_stage[(l * b + r0) * t * hd..(l * b + r0 + rows) * t * hd]
                    .copy_from_slice(src);
                let src = &blk.v_stage[l * rows * t * hd
                    ..(l + 1) * rows * t * hd];
                v_stage[(l * b + r0) * t * hd..(l * b + r0 + rows) * t * hd]
                    .copy_from_slice(src);
            }
        }
        Ok(FwdOut {
            logits,
            hidden: hidden_out,
            kv: KvStage::Host { k: k_stage, v: v_stage },
            elapsed_s: t0.elapsed().as_secs_f64(),
        })
    }

    fn commit(&self, b: usize, t: usize, out: &FwdOut, commit_pos: &[i32],
              cache: &mut KvCache) -> Result<f64> {
        let t0 = Instant::now();
        match &out.kv {
            KvStage::Host { k, v } => {
                cache.host_scatter(b, t, k, v, commit_pos)?;
            }
            #[cfg(feature = "pjrt")]
            KvStage::Pjrt { .. } => {
                anyhow::bail!("PJRT FwdOut fed to the host commit")
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::reference_manifest;

    fn pair(name: &str) -> (RefModel, HostModel) {
        let man = reference_manifest();
        let entry = man.models.get(name).unwrap();
        (RefModel::build(7, entry).unwrap(),
         HostModel::build(7, entry).unwrap())
    }

    #[test]
    fn fwd_is_bit_identical_to_oracle() {
        let (oracle, host) = pair("target-m");
        let co = oracle.new_cache(1).unwrap();
        let ch = host.new_cache(1).unwrap();
        let toks = [0i32, 13, 20, 21, 33];
        let pos = [0i32, 1, 2, 3, 4];
        let a = oracle.fwd(1, 5, &toks, &pos, None, &co).unwrap();
        let b = host.fwd(1, 5, &toks, &pos, None, &ch).unwrap();
        assert_eq!(a.logits, b.logits, "host logits diverged from oracle");
    }

    #[test]
    fn staged_kv_and_commit_match_oracle() {
        let (oracle, host) = pair("draft-s");
        let mut co = oracle.new_cache(1).unwrap();
        let mut ch = host.new_cache(1).unwrap();
        let toks = [0i32, 17, 25];
        let pos = [0i32, 1, 2];
        let a = oracle.fwd(1, 3, &toks, &pos, None, &co).unwrap();
        let b = host.fwd(1, 3, &toks, &pos, None, &ch).unwrap();
        oracle.commit(1, 3, &a, &pos, &mut co).unwrap();
        host.commit(1, 3, &b, &pos, &mut ch).unwrap();
        for l in 0..oracle.cfg().n_layers {
            for slot in 0..3 {
                assert_eq!(co.host_kv(0, l, 0, slot),
                           ch.host_kv(0, l, 0, slot),
                           "K cache diverged at l={l} slot={slot}");
                assert_eq!(co.host_kv(1, l, 0, slot),
                           ch.host_kv(1, l, 0, slot),
                           "V cache diverged at l={l} slot={slot}");
            }
        }
    }

    #[test]
    fn parked_cells_are_skipped_but_live_cells_exact() {
        // Pad the call out with parked columns and a parked row: live
        // logits must stay bit-identical to the oracle's, parked cells
        // are zero (the oracle computes pad-token logits there; both
        // are unread by contract).
        let (oracle, host) = pair("target-m");
        let vocab = oracle.cfg().vocab;
        let co = oracle.new_cache(2).unwrap();
        let ch = host.new_cache(2).unwrap();
        let gslot = ch.garbage_slot();
        let toks = [0i32, 13, 20, 2, 2, 2, 2, 2];
        let pos = [0i32, 1, 2, gslot, gslot, gslot, gslot, gslot];
        let a = oracle.fwd(2, 4, &toks, &pos, None, &co).unwrap();
        let b = host.fwd(2, 4, &toks, &pos, None, &ch).unwrap();
        assert_eq!(a.logits[..3 * vocab], b.logits[..3 * vocab]);
        assert!(b.logits[4 * vocab..].iter().all(|&x| x == 0.0),
                "parked row must be zeros on the host path");
    }

    #[test]
    fn eagle_head_matches_oracle() {
        let (oracle, host) = pair("eagle-target-l");
        let d = oracle.cfg().d_model;
        let co = oracle.new_cache(1).unwrap();
        let ch = host.new_cache(1).unwrap();
        let hin: Vec<f32> = (0..2 * d).map(|i| (i as f32) * 0.01).collect();
        let a = oracle
            .fwd(1, 2, &[0, 13], &[0, 1], Some(&hin), &co)
            .unwrap();
        let b = host.fwd(1, 2, &[0, 13], &[0, 1], Some(&hin), &ch).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.hidden, b.hidden);
        assert!(host.fwd(1, 1, &[0], &[0], None, &ch).is_err(),
                "eagle fwd without hidden must fail");
    }

    #[test]
    fn out_of_range_pos_ropes_with_raw_value() {
        // A raw pos below 0 clamps to slot 0 (live) for attention and
        // scatter, but the oracle ropes Q/K with the RAW value — the
        // host path must too, or bit-identity breaks at the surface.
        let (oracle, host) = pair("draft-s");
        let co = oracle.new_cache(1).unwrap();
        let ch = host.new_cache(1).unwrap();
        let a = oracle.fwd(1, 1, &[5], &[-3], None, &co).unwrap();
        let b = host.fwd(1, 1, &[5], &[-3], None, &ch).unwrap();
        assert_eq!(a.logits, b.logits, "OOB-pos logits diverged");
        match (&a.kv, &b.kv) {
            (KvStage::Host { k: ka, .. }, KvStage::Host { k: kb, .. }) => {
                assert_eq!(ka, kb, "OOB-pos staged K diverged");
            }
            #[cfg(feature = "pjrt")]
            _ => unreachable!("host backends stage host KV"),
        }
    }

    #[test]
    fn decode_after_commit_matches_oracle() {
        // Cached decode: prefill, commit, then T=1 steps — the
        // in-place cache read must equal the oracle's transient copy.
        let (oracle, host) = pair("draft-s");
        let vocab = oracle.cfg().vocab;
        let run = |m: &dyn Backend| -> Vec<f32> {
            let mut cache = m.new_cache(1).unwrap();
            let toks = [0i32, 17, 25, 30];
            let pos = [0i32, 1, 2, 3];
            let out = m.fwd(1, 4, &toks, &pos, None, &cache).unwrap();
            m.commit(1, 4, &out, &pos, &mut cache).unwrap();
            cache.cur_len[0] = 4;
            let step = m.fwd(1, 1, &[19], &[4], None, &cache).unwrap();
            step.logits[..vocab].to_vec()
        };
        assert_eq!(run(&oracle), run(&host));
    }
}
