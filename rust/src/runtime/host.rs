//! Fast deterministic host serving backend (DESIGN.md §8).
//!
//! [`HostModel`] drives the *same weights* as the scalar reference
//! oracle ([`super::reference::RefModel`], DESIGN.md §6) through a
//! restructured forward pass built for speed on real host CPUs while
//! keeping the oracle's bit-exact determinism contract:
//!
//! * **Identical per-cell arithmetic.**  Every floating-point reduction
//!   (matmul cells, attention scores, softmax sums, weighted-V sums,
//!   rmsnorm squares, logit dot products) runs in exactly the scalar
//!   oracle's fixed ascending order, starting from the same initial
//!   value.  Loop *shape* is free — k-outer vs dot-product, panel
//!   packing, lane partitioning — as long as no per-cell sum is
//!   reassociated.  Live-cell outputs are therefore bit-identical to
//!   `RefModel`, which is what lets the engine-equivalence suite (and
//!   `tests/host_backend.rs`) compare the two backends exactly instead
//!   of approximately.
//! * **A persistent worker pool with column-granular work.**  A pool of
//!   parked worker threads ([`WorkerPool`]) is built once per runtime
//!   (size from `--threads` / `PARD_HOST_THREADS` /
//!   `available_parallelism`) and work is split at *output-cell*
//!   granularity: matmul column panels, per-(row, head) attention
//!   chains.  Each output cell is an independent reduction chain owned
//!   by exactly one lane, so the partition — and the lane count — can
//!   change wall clock only, never a bit.  Unlike the earlier
//!   batch-row split on per-call scoped threads, this parallelizes
//!   batch=1 decode and single-row prefill, and pays thread spawn cost
//!   zero times per call instead of once.
//! * **Packed, fused weights swept by 8-wide lane micro-kernels.**
//!   All weight matrices are packed into contiguous column panels
//!   (`PackedMat`) at build time; the Q/K/V projections are fused into
//!   one `[d, 3·H·D]` sweep and the MLP gate/up into one `[d, 2·ff]`
//!   sweep, cutting three (two) passes over the normed activations to
//!   one while preserving each output cell's k-ascending chain.  The
//!   logit projection runs over the packed transpose of the tied
//!   embedding, as before.  Every panel sweep runs through an explicit
//!   [`LANE`]-wide (`[f32; 8]`) register micro-kernel — two lanes per
//!   [`PANEL`] — that keeps each column cell's chain in a register
//!   across the whole k loop instead of a load/add/store per k.  The
//!   lane split is across output columns `j` while every reduction
//!   index is `k`, so no per-cell chain is reassociated and bit-
//!   identity survives (DESIGN.md §8).
//! * **An int8 per-panel quantized twin** ([`super::quant`],
//!   `--backend host-q8`): [`HostMat`] lets every matmul site hold
//!   either the f32 panels or their symmetric per-panel int8
//!   quantization.  q8 trades the bit-identity contract for ~4×
//!   less weight traffic under a bounded-error contract of its own
//!   (see `quant.rs`); everything else in this file is shared.
//! * **Dead work is skipped, not recomputed.**  Parked cells (queries
//!   positioned at the garbage slot, DESIGN.md §7) are dropped before
//!   the first matmul; their logits/hidden/staged-KV outputs are zeros.
//! * **The KV cache is read in place, through the block table.**  Each
//!   attended slot resolves through a per-row `slot -> staged column`
//!   map — staged K/V from this call win, otherwise the paged block
//!   pool (DESIGN.md §7) is read directly through a `Sync` borrowed
//!   view (`CacheView`) carrying precomputed per-row block bases.  No
//!   copies, identical values; unmapped (never-committed) slots read
//!   as zeros, which the position mask keeps unobservable.  Prefix
//!   sharing (§7) needs nothing extra here: a shared block is just
//!   another base two rows' tables point at, and commits route
//!   through `KvCache::host_scatter`, which owns the COW hook.
//! * **Rotary tables are computed once per call.**  One `D/2`-wide
//!   sin/cos row per live cell, shared by every layer and head (the
//!   oracle recomputes the trig `2·L·H` times per cell).
//!
//! What stays deliberately identical to the oracle: `f32::exp` in
//! softmax/SiLU and `sin_cos` values (same libm calls, same bits), the
//! fwd/commit split, `pick_t` exact-T semantics, and the garbage-slot
//! commit protocol via [`KvCache::host_scatter`].  `fwd` additionally
//! reports a per-op time breakdown ([`FwdOps`]) that `pard bench`
//! aggregates into `BENCH_hotpath.json`.

// Kernel-style index loops are deliberate here: the fixed per-cell
// reduction order *is* the spec (see module docs), and explicit indices
// keep that order auditable against reference.rs line by line.
#![allow(clippy::needless_range_loop)]

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::substrate::bench::stopwatch;
use super::artifact::{ModelCfg, ModelEntry, ModelKind};
use super::backend::{Backend, FwdOps, FwdOut, KvStage, OpWeightBytes};
use super::cache::{CacheState, KvCache, KV_BLOCK};
use super::pool::{chunk, default_threads, SharedSlice, WorkerPool};
use super::quant::QuantizedMat;
use super::reference::{rmsnorm, RefModel};

/// Packed panel width (output columns per panel).  16 f32 = one 64-byte
/// cache line, and every synthetic-family width (`h·dh`, `ff`, `vocab`,
/// `d`) is a multiple of it; ragged tails are still handled.
pub(crate) const PANEL: usize = 16;

/// SIMD micro-kernel width: 8 f32 lanes (one AVX/NEON-pair register),
/// two lanes per [`PANEL`].  The kernels below are written as portable
/// `[f32; LANE]` chunk ops the autovectorizer cannot miss; the value
/// is a layout choice only — lanes split output *columns*, never a
/// reduction, so it can never change a bit (DESIGN.md §8).
pub(crate) const LANE: usize = 8;

/// The 8-wide micro-kernel: `acc[l] += av * wr[l]` for one k step.
/// Each accumulator lane is one output cell's chain, so the k loop
/// around this performs exactly the oracle's per-cell adds in order —
/// just eight chains abreast, in registers.
#[inline(always)]
pub(crate) fn lane8_fma(acc: &mut [f32; LANE], av: f32, wr: &[f32]) {
    for l in 0..LANE {
        acc[l] += av * wr[l];
    }
}

/// Minimum matmul MACs (`n · din · dout`) before a pool dispatch beats
/// running the sweep on the caller lane.  Chosen so decode-shaped
/// draft-s calls stay serial while verify/prefill shapes parallelize;
/// the choice affects wall clock only, never bits.
const PAR_MIN_MACS: usize = 8192;

/// Same gate for the attention stage, in score-chain MACs
/// (`n · h · s_used · dh`).
const PAR_MIN_ATTN_MACS: usize = 4096;

/// Column-panel packed weight matrix: output columns are grouped into
/// [`PANEL`]-wide panels, each stored `[din, PANEL]` contiguously, so a
/// lane sweeping a panel range streams its weights linearly.  The sweep
/// keeps the oracle's per-cell reduction order (k ascending from the
/// existing output value) — packing changes *where* a weight lives,
/// never *when* it is accumulated.
pub(crate) struct PackedMat {
    /// `[n_panels, din, PANEL]`, ragged last panel zero-padded.
    data: Vec<f32>,
    din: usize,
    dout: usize,
}

impl PackedMat {
    /// Pack a row-major `[din, dout]` matrix.
    pub(crate) fn pack(w: &[f32], din: usize, dout: usize) -> PackedMat {
        assert_eq!(w.len(), din * dout, "pack: weight shape mismatch");
        let panels = dout.div_ceil(PANEL);
        let mut data = vec![0f32; panels * din * PANEL];
        for p in 0..panels {
            let cols = (dout - p * PANEL).min(PANEL);
            for k in 0..din {
                let src = k * dout + p * PANEL;
                let dst = (p * din + k) * PANEL;
                data[dst..dst + cols].copy_from_slice(&w[src..src + cols]);
            }
        }
        PackedMat { data, din, dout }
    }

    pub(crate) fn n_panels(&self) -> usize {
        self.dout.div_ceil(PANEL)
    }

    /// Bytes of packed weight data one full sweep streams (f32 panels
    /// including ragged-tail padding) — the bandwidth-model numerator
    /// for `benches/table6_bandwidth.rs`.
    pub(crate) fn weight_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// `out[n, dout] += a[n, din] @ w` restricted to panels `p0..p1`.
    /// Bit-identical to `matmul_acc` over the matching column range for
    /// any panel partition (the §8 column-decomposition contract).
    ///
    /// `out` is a [`SharedSlice`] so concurrent lanes can each own a
    /// disjoint panel range of the same buffer.
    ///
    /// Each panel runs the [`lane8_fma`] micro-kernel on two
    /// `[f32; LANE]` register accumulators loaded from the existing
    /// output values, so every column cell's chain still starts where
    /// the oracle's does and adds in the same k-ascending order — but
    /// stays in a register across the whole k loop instead of paying a
    /// load/add/store per k.  Ragged tails load/store only the live
    /// `cols` cells; the dead lanes accumulate over the panel's zero
    /// padding and are never written back, so widths below one SIMD
    /// chunk (`cols < LANE`) take the same code path.
    pub(crate) fn matmul_acc_panels(&self, a: &[f32], out: &SharedSlice,
                                    n: usize, p0: usize, p1: usize) {
        let (din, dout) = (self.din, self.dout);
        for p in p0..p1 {
            let cols = (dout - p * PANEL).min(PANEL);
            let c0 = p * PANEL;
            let pan = &self.data[p * din * PANEL..(p + 1) * din * PANEL];
            for i in 0..n {
                let ar = &a[i * din..(i + 1) * din];
                // SAFETY: lanes own disjoint panel ranges, so these
                // column cells belong to this lane alone.
                let or = unsafe { out.range(i * dout + c0, cols) };
                let mut acc0 = [0f32; LANE];
                let mut acc1 = [0f32; LANE];
                let lo = cols.min(LANE);
                acc0[..lo].copy_from_slice(&or[..lo]);
                if cols > LANE {
                    acc1[..cols - LANE]
                        .copy_from_slice(&or[LANE..cols]);
                }
                for (ki, &av) in ar.iter().enumerate() {
                    let wr = &pan[ki * PANEL..(ki + 1) * PANEL];
                    lane8_fma(&mut acc0, av, &wr[..LANE]);
                    lane8_fma(&mut acc1, av, &wr[LANE..]);
                }
                or[..lo].copy_from_slice(&acc0[..lo]);
                if cols > LANE {
                    or[LANE..cols]
                        .copy_from_slice(&acc1[..cols - LANE]);
                }
            }
        }
    }
}

/// A matmul weight in either host representation: f32 panels (the
/// bit-identical fast path) or their int8 per-panel quantization
/// (`--backend host-q8`, bounded-error contract — see
/// [`super::quant`]).  Both share the `[n_panels, din, PANEL]` layout,
/// the panel-range sweep signature, and therefore the pool partition.
pub(crate) enum HostMat {
    F32(PackedMat),
    Q8(QuantizedMat),
}

impl HostMat {
    fn din(&self) -> usize {
        match self {
            HostMat::F32(m) => m.din,
            HostMat::Q8(m) => m.din(),
        }
    }

    fn dout(&self) -> usize {
        match self {
            HostMat::F32(m) => m.dout,
            HostMat::Q8(m) => m.dout(),
        }
    }

    fn n_panels(&self) -> usize {
        match self {
            HostMat::F32(m) => m.n_panels(),
            HostMat::Q8(m) => m.n_panels(),
        }
    }

    /// Weight bytes one full sweep streams in this representation
    /// (q8: ~1/4 of f32, plus one scale per panel).
    pub(crate) fn weight_bytes(&self) -> usize {
        match self {
            HostMat::F32(m) => m.weight_bytes(),
            HostMat::Q8(m) => m.weight_bytes(),
        }
    }

    fn matmul_acc_panels(&self, a: &[f32], out: &SharedSlice, n: usize,
                         p0: usize, p1: usize) {
        match self {
            HostMat::F32(m) => m.matmul_acc_panels(a, out, n, p0, p1),
            HostMat::Q8(m) => m.matmul_acc_panels(a, out, n, p0, p1),
        }
    }
}

/// One layer's build-time packed weights (see module docs), in either
/// representation (f32 panels or int8 per-panel quantization).
struct PackedLayer {
    /// Fused `[d, 3·H·D]`: columns `[wq | wk | wv]`.
    wqkv: HostMat,
    /// `[H·D, d]` attention output projection.
    wo: HostMat,
    /// Fused `[d, 2·ff]`: columns `[w1 | w3]` (gate | up).
    w13: HostMat,
    /// `[ff, d]` MLP down projection.
    w2: HostMat,
}

/// Read-only view of the host block pool plus a flattened block-base
/// map (DESIGN.md §7).  `KvCache` itself cannot cross a worker-lane
/// boundary (its PJRT variant holds non-`Send` device handles under
/// `--features pjrt`); this borrowed view is plain `&[f32]` + a
/// precomputed `Vec<i64>` and is always `Sync`.
struct CacheView<'a> {
    data: &'a [f32],
    /// `[b, max_lb]` row-major: flat pool offset of each row's mapped
    /// logical block (`block_id * block_elems`), or `-1` when the
    /// row's table does not map it.  Built once per `fwd` call, so the
    /// attention loop resolves a slot with one shift, one mask, and
    /// one add — no per-slot table walk.
    row_blocks: Vec<i64>,
    /// Logical blocks covered per row (`ceil(s_used / KV_BLOCK)`).
    max_lb: usize,
}

/// Resolve the K or V vector attended at `slot`: this call's staged
/// column if the slot map says the slot was written in-flight, else
/// the persistent block pool read in place through the row's block
/// bases (`cl_off` selects the `(c, l)` plane inside a block).
/// Unmapped slots resolve to `zeros` — by the §7 contract they were
/// never committed, so the position mask keeps them unattendable and
/// the substitute bytes can never reach a live output.  Returns
/// exactly the bytes the oracle's transient merged copy would hold.
#[inline(always)]
#[allow(clippy::too_many_arguments)] // hot-path accessor, args are flat
fn slot_kv<'a>(stage: &'a [f32], stride: usize, base: usize,
               pool: &'a [f32], map: &[i32], map_base: usize,
               slot: usize, row_blocks: &[i64], cl_off: usize,
               zeros: &'a [f32], hd: usize, head_off: usize, dh: usize)
               -> &'a [f32] {
    let j = map[map_base + slot];
    if j >= 0 {
        return &stage[j as usize * stride + base + head_off..][..dh];
    }
    let blk_base = row_blocks[slot / KV_BLOCK];
    if blk_base < 0 {
        return &zeros[head_off..head_off + dh];
    }
    &pool[blk_base as usize + cl_off + (slot % KV_BLOCK) * hd
        + head_off..][..dh]
}

/// Lap timer for the per-op breakdown: one clock read per phase
/// boundary instead of two.
struct OpClock {
    last: Instant,
}

impl OpClock {
    fn start() -> OpClock {
        OpClock { last: stopwatch() }
    }

    fn lap(&mut self) -> f64 {
        let now = stopwatch();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// The fast host backend: scalar-oracle weights, packed layout,
/// pool-parallel column-granular execution.
pub struct HostModel {
    m: RefModel,
    /// Per-layer packed weights (fused QKV / W13, packed WO / W2).
    packed: Vec<PackedLayer>,
    /// Packed `[d, vocab]` transpose of the tied embedding: the logit
    /// projection runs the same k-outer panel sweep as every other
    /// matmul.  Same per-cell add order as the oracle, same bits
    /// (f32); bounded error (q8).
    embed_t: HostMat,
    /// Packed `[2d, d]` EAGLE fuse projection, when present.
    fuse_p: Option<HostMat>,
    /// Persistent worker pool; shared across the runtime's models so
    /// target and draft dispatch onto the same parked threads.
    pool: Arc<WorkerPool>,
}

impl HostModel {
    /// Build the model named by `entry` — same deterministic weights as
    /// [`RefModel::build`] for the same `(seed, entry)` — with its own
    /// default-sized pool (`PARD_HOST_THREADS` / available cores).
    pub fn build(seed: u64, entry: &ModelEntry) -> Result<HostModel> {
        Self::build_with_pool(
            seed, entry, Arc::new(WorkerPool::new(default_threads())))
    }

    /// [`HostModel::build`] dispatching onto a caller-provided pool
    /// (`Runtime::host` shares one pool across all its models).
    pub fn build_with_pool(seed: u64, entry: &ModelEntry,
                           pool: Arc<WorkerPool>) -> Result<HostModel> {
        Self::build_impl(seed, entry, pool, false)
    }

    /// Build the int8 per-panel quantized twin (`--backend host-q8`):
    /// same deterministic f32 weights, then every matmul operand is
    /// quantized at load with symmetric per-panel scales
    /// ([`QuantizedMat`]).  NOT bit-identical to the oracle — see
    /// `quant.rs` for the bounded-error contract it carries instead.
    pub fn build_q8(seed: u64, entry: &ModelEntry) -> Result<HostModel> {
        Self::build_q8_with_pool(
            seed, entry, Arc::new(WorkerPool::new(default_threads())))
    }

    /// [`HostModel::build_q8`] dispatching onto a caller-provided pool.
    pub fn build_q8_with_pool(seed: u64, entry: &ModelEntry,
                              pool: Arc<WorkerPool>)
                              -> Result<HostModel> {
        Self::build_impl(seed, entry, pool, true)
    }

    fn build_impl(seed: u64, entry: &ModelEntry, pool: Arc<WorkerPool>,
                  quant: bool) -> Result<HostModel> {
        let m = RefModel::build(seed, entry)?;
        let cfg = &m.cfg;
        let (v, d, ff) = (cfg.vocab, cfg.d_model, cfg.d_ff);
        let hd = cfg.n_heads * cfg.d_head;
        // One packing closure decides the representation: the fused
        // row-major assembly above it is identical either way.  (The
        // token-embedding *gather* stays f32 on both: per-token row
        // reads are a negligible share of bytes, and the embedding is
        // tied — only its packed transpose, the logit projection, is
        // quantized.)
        let mk = |w: &[f32], din: usize, dout: usize| -> HostMat {
            if quant {
                HostMat::Q8(QuantizedMat::quantize(w, din, dout))
            } else {
                HostMat::F32(PackedMat::pack(w, din, dout))
            }
        };
        let packed = m
            .layers
            .iter()
            .map(|lyr| {
                let mut wqkv = vec![0f32; d * 3 * hd];
                let mut w13 = vec![0f32; d * 2 * ff];
                for k in 0..d {
                    wqkv[k * 3 * hd..k * 3 * hd + hd]
                        .copy_from_slice(&lyr.wq[k * hd..(k + 1) * hd]);
                    wqkv[k * 3 * hd + hd..k * 3 * hd + 2 * hd]
                        .copy_from_slice(&lyr.wk[k * hd..(k + 1) * hd]);
                    wqkv[k * 3 * hd + 2 * hd..(k + 1) * 3 * hd]
                        .copy_from_slice(&lyr.wv[k * hd..(k + 1) * hd]);
                    w13[k * 2 * ff..k * 2 * ff + ff]
                        .copy_from_slice(&lyr.w1[k * ff..(k + 1) * ff]);
                    w13[k * 2 * ff + ff..(k + 1) * 2 * ff]
                        .copy_from_slice(&lyr.w3[k * ff..(k + 1) * ff]);
                }
                PackedLayer {
                    wqkv: mk(&wqkv, d, 3 * hd),
                    wo: mk(&lyr.wo, hd, d),
                    w13: mk(&w13, d, 2 * ff),
                    w2: mk(&lyr.w2, ff, d),
                }
            })
            .collect();
        let mut embed_t = vec![0f32; d * v];
        for tok in 0..v {
            for j in 0..d {
                embed_t[j * v + tok] = m.embed[tok * d + j];
            }
        }
        let embed_t = mk(&embed_t, d, v);
        let fuse_p = m.fuse.as_ref().map(|f| mk(f, 2 * d, d));
        Ok(HostModel { m, packed, embed_t, fuse_p, pool })
    }

    /// Pool lanes this model dispatches onto (1 = fully serial).
    pub fn threads(&self) -> usize {
        self.pool.lanes()
    }

    /// True when this model's matmul weights are int8 per-panel
    /// quantized (`--backend host-q8`).
    pub fn is_quantized(&self) -> bool {
        matches!(self.embed_t, HostMat::Q8(_))
    }

    /// `out[n, dout] += a @ w`, panel-partitioned across the pool when
    /// the shape is worth a dispatch.  The gate and the partition pick
    /// *who* computes each output cell, never the order within it —
    /// results are bit-identical for every lane count (DESIGN.md §8).
    fn par_matmul(&self, a: &[f32], w: &HostMat, out: &mut [f32],
                  n: usize) {
        let panels = w.n_panels();
        let lanes = self.pool.lanes().min(panels);
        let shared = SharedSlice::new(out);
        if lanes <= 1 || n * w.din() * w.dout() < PAR_MIN_MACS {
            w.matmul_acc_panels(a, &shared, n, 0, panels);
            return;
        }
        self.pool.run(&|lane| {
            if lane < lanes {
                let (p0, p1) = chunk(panels, lanes, lane);
                w.matmul_acc_panels(a, &shared, n, p0, p1);
            }
        });
    }
}

impl Backend for HostModel {
    fn cfg(&self) -> &ModelCfg {
        &self.m.cfg
    }

    fn kind(&self) -> ModelKind {
        self.m.kind
    }

    fn n_params(&self) -> usize {
        self.m.cfg.n_params(self.m.kind == ModelKind::Eagle)
    }

    /// No bucket grid: the host path executes any T exactly (same call
    /// layouts as the scalar oracle, so engine traffic is identical).
    fn pick_t(&self, _b: usize, t_needed: usize) -> Result<usize> {
        Ok(t_needed.max(1))
    }

    fn new_cache(&self, batch: usize) -> Result<KvCache> {
        Ok(KvCache::host(&self.m.cfg, batch))
    }

    /// Weight bytes one full forward pass streams, per fwd_ops bucket,
    /// in whatever representation this model holds (f32 panels or q8).
    /// Gather and attention carry no matmul weight traffic by
    /// construction, matching the ledger's bucket semantics.
    fn op_weight_bytes(&self) -> OpWeightBytes {
        let mut w = OpWeightBytes::default();
        for pk in &self.packed {
            w.qkv += pk.wqkv.weight_bytes();
            w.wo += pk.wo.weight_bytes();
            w.mlp += pk.w13.weight_bytes() + pk.w2.weight_bytes();
        }
        w.logits = self.embed_t.weight_bytes();
        w.fuse = self.fuse_p.as_ref().map_or(0, |f| f.weight_bytes());
        w
    }

    fn new_cache_sized(&self, batch: usize, kv_blocks: Option<usize>)
                       -> Result<KvCache> {
        match kv_blocks {
            Some(n) => KvCache::host_paged(&self.m.cfg, batch, n),
            None => self.new_cache(batch),
        }
    }

    fn fwd(&self, b: usize, t: usize, tokens: &[i32], pos: &[i32],
           hidden_in: Option<&[f32]>, cache: &KvCache) -> Result<FwdOut> {
        let t0 = stopwatch();
        let cfg = &self.m.cfg;
        let (d, h, dh, ff, vocab) =
            (cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff, cfg.vocab);
        let hd = h * dh;
        let half = dh / 2;
        let s_max = cache.s_max;
        anyhow::ensure!(b >= 1 && t >= 1, "empty call shape {b}x{t}");
        anyhow::ensure!(tokens.len() == b * t && pos.len() == b * t,
                        "tokens/pos must be [b*t]");
        anyhow::ensure!(b == cache.batch, "batch {b} != cache batch {}",
                        cache.batch);
        match (self.m.kind, hidden_in) {
            (ModelKind::Eagle, None) => {
                anyhow::bail!("EAGLE fwd requires hidden input")
            }
            (ModelKind::Lm, Some(_)) => {
                anyhow::bail!("LM fwd takes no hidden input")
            }
            (ModelKind::Eagle, Some(hin)) => {
                anyhow::ensure!(hin.len() == b * t * d,
                                "hidden_in must be [b*t*d]");
            }
            (ModelKind::Lm, None) => {}
        }
        let data = match &cache.state {
            CacheState::Host(data) => data,
            #[cfg(feature = "pjrt")]
            CacheState::Device(_) => {
                anyhow::bail!("host fwd needs a host cache")
            }
        };

        // Clock starts here so the slot-map/view construction below is
        // attributed to `gather_s` (it is part of the gather phase, not
        // untracked overhead — keeps `ops.total()` honest vs `fwd_s`).
        let mut ops = FwdOps::default();
        let mut clock = OpClock::start();

        // Same truncated-view bound as the oracle: the highest LIVE
        // position; cells at or past it are parked.
        let garbage = s_max - 1;
        let s_used = pos
            .iter()
            .map(|&p| p.clamp(0, s_max as i32 - 1) as usize)
            .filter(|&p| p < garbage)
            .max()
            .map_or(1, |p| p + 1);

        // Per-row block-base map over the logical blocks this call can
        // attend: resolves slot -> pool offset without walking the
        // table in the attention loop.
        let max_lb = s_used.div_ceil(KV_BLOCK);
        let block_elems = cache.block_elems();
        let mut row_blocks = vec![-1i64; b * max_lb];
        for row in 0..b {
            for (lb, &blk) in
                cache.row_blocks(row).iter().take(max_lb).enumerate()
            {
                row_blocks[row * max_lb + lb] =
                    (blk as usize * block_elems) as i64;
            }
        }
        let view = CacheView { data, row_blocks, max_lb };
        // Substitute row for unmapped (never-committed) slots; §7 says
        // nothing can attend them, so the value is unobservable.
        let zeros = vec![0f32; hd];

        let n_layers = self.m.layers.len();

        // Call-layout outputs (parked cells stay zero).
        let mut logits = vec![0f32; b * t * vocab];
        let mut hidden_out = if self.m.hidden {
            Some(vec![0f32; b * t * d])
        } else {
            None
        };
        let mut k_stage = vec![0f32; n_layers * b * t * hd];
        let mut v_stage = vec![0f32; n_layers * b * t * hd];

        // Live-cell gather: global cell index (row * t + col), row,
        // clamped position.  Everything parked is dropped here and
        // never touched again.
        let mut cells: Vec<usize> = Vec::with_capacity(b * t);
        let mut rows_: Vec<usize> = Vec::with_capacity(b * t);
        let mut ps: Vec<usize> = Vec::with_capacity(b * t);
        // Raw (unclamped) positions, kept separately: the oracle ropes
        // Q/K with the raw `pos` value and clamps only for slot
        // scatter/attention — bit-identity requires doing the same.
        let mut praw: Vec<i32> = Vec::with_capacity(b * t);
        for gi in 0..b * t {
            let p = pos[gi].clamp(0, s_max as i32 - 1) as usize;
            if p < s_used {
                cells.push(gi);
                rows_.push(gi / t);
                ps.push(p);
                praw.push(pos[gi]);
            }
        }
        let n = cells.len();
        if n == 0 {
            ops.gather_s += clock.lap();
            return Ok(FwdOut {
                logits,
                hidden: hidden_out,
                kv: KvStage::Host { k: k_stage, v: v_stage },
                elapsed_s: t0.elapsed().as_secs_f64(),
                ops: Some(ops),
            });
        }

        // Token embeddings (EAGLE: fuse [target hidden ; embedding]),
        // gathered densely over live cells only.
        let mut x = vec![0f32; n * d];
        match (self.m.kind, hidden_in) {
            (ModelKind::Lm, _) => {
                for j in 0..n {
                    let tok = tokens[cells[j]]
                        .clamp(0, vocab as i32 - 1) as usize;
                    x[j * d..(j + 1) * d].copy_from_slice(
                        &self.m.embed[tok * d..(tok + 1) * d]);
                }
            }
            (ModelKind::Eagle, Some(hin)) => {
                let fuse_p =
                    self.fuse_p.as_ref().expect("eagle has packed fuse");
                let mut cat = vec![0f32; n * 2 * d];
                for j in 0..n {
                    let gi = cells[j];
                    let tok =
                        tokens[gi].clamp(0, vocab as i32 - 1) as usize;
                    cat[j * 2 * d..j * 2 * d + d]
                        .copy_from_slice(&hin[gi * d..(gi + 1) * d]);
                    cat[j * 2 * d + d..(j + 1) * 2 * d]
                        .copy_from_slice(&self.m.embed[tok * d..(tok + 1) * d]);
                }
                self.par_matmul(&cat, fuse_p, &mut x, n);
            }
            (ModelKind::Eagle, None) => {
                unreachable!("validated above")
            }
        }

        // Rotary tables: one sin/cos row per live cell, shared by every
        // layer and head (the oracle recomputes these 2*L*H times).
        let mut sin_t = vec![0f32; n * half];
        let mut cos_t = vec![0f32; n * half];
        for j in 0..n {
            for c in 0..half {
                let ang = praw[j] as f32 * self.m.inv_freq[c];
                let (s, co) = ang.sin_cos();
                sin_t[j * half + c] = s;
                cos_t[j * half + c] = co;
            }
        }

        // slot -> live-cell map per batch row: which in-flight column
        // occupies a cache slot for the duration of this call (later
        // columns win, matching the oracle's scatter order).
        let mut staged_at = vec![-1i32; b * s_used];
        for j in 0..n {
            staged_at[rows_[j] * s_used + ps[j]] = j as i32;
        }
        ops.gather_s += clock.lap();

        // Layer-loop scratch, allocated once and reused.
        let qkv_stride = 3 * hd;
        let mut qkv = vec![0f32; n * qkv_stride];
        let mut attn = vec![0f32; n * hd];
        let mut gu = vec![0f32; n * 2 * ff];
        let mut gact = vec![0f32; n * ff];
        let scale = 1.0 / (dh as f32).sqrt();

        for (l, (lyr, pk)) in
            self.m.layers.iter().zip(self.packed.iter()).enumerate()
        {
            // --- fused QKV projection + rope + staging ---
            let xn = rmsnorm(&x, d, &lyr.ln_attn);
            qkv.fill(0.0);
            self.par_matmul(&xn, &pk.wqkv, &mut qkv, n);

            // Rotary, from the precomputed tables, on the Q and K
            // thirds of the fused buffer.
            for j in 0..n {
                let (st, ct) =
                    (&sin_t[j * half..(j + 1) * half],
                     &cos_t[j * half..(j + 1) * half]);
                for part in 0..2 {
                    for head in 0..h {
                        let base =
                            j * qkv_stride + part * hd + head * dh;
                        for c in 0..half {
                            let (sin, cos) = (st[c], ct[c]);
                            let x1 = qkv[base + c];
                            let x2 = qkv[base + half + c];
                            qkv[base + c] = x1 * cos - x2 * sin;
                            qkv[base + half + c] = x1 * sin + x2 * cos;
                        }
                    }
                }
            }

            // Stage this call's K/V into the output tensors (parked
            // cells stay zero; they only ever commit to the garbage
            // slot).
            for j in 0..n {
                let src = j * qkv_stride;
                let dst = (l * b * t + cells[j]) * hd;
                k_stage[dst..dst + hd]
                    .copy_from_slice(&qkv[src + hd..src + 2 * hd]);
                v_stage[dst..dst + hd]
                    .copy_from_slice(&qkv[src + 2 * hd..src + 3 * hd]);
            }
            ops.qkv_s += clock.lap();

            // --- causal cached attention, persistent tensor read in
            // place, one (cell, head) chain per work item ---
            attn.fill(0.0);
            let items = n * h;
            let attn_out = SharedSlice::new(&mut attn);
            let qkv_ref: &[f32] = &qkv;
            // (c, l) plane offsets inside a pool block for this layer.
            let kc_off = l * KV_BLOCK * hd;
            let vc_off = (n_layers + l) * KV_BLOCK * hd;
            let zeros_ref: &[f32] = &zeros;
            let run_items = |i0: usize, i1: usize| {
                let mut scores = vec![0f32; s_used];
                for it in i0..i1 {
                    let (j, head) = (it / h, it % h);
                    let (grow, p) = (rows_[j], ps[j]);
                    let map_base = grow * s_used;
                    let blocks = &view.row_blocks
                        [grow * view.max_lb..(grow + 1) * view.max_lb];
                    let head_off = head * dh;
                    let qv = &qkv_ref[j * qkv_stride + head_off
                        ..j * qkv_stride + head_off + dh];
                    // Scores: 4 independent accumulator chains hide
                    // the serial-add latency; each chain is still the
                    // oracle's e-ascending per-cell order.
                    let mut s = 0usize;
                    while s + 4 <= p + 1 {
                        let k0 = slot_kv(qkv_ref, qkv_stride, hd,
                                         view.data, &staged_at, map_base,
                                         s, blocks, kc_off, zeros_ref,
                                         hd, head_off, dh);
                        let k1 = slot_kv(qkv_ref, qkv_stride, hd,
                                         view.data, &staged_at, map_base,
                                         s + 1, blocks, kc_off,
                                         zeros_ref, hd, head_off, dh);
                        let k2 = slot_kv(qkv_ref, qkv_stride, hd,
                                         view.data, &staged_at, map_base,
                                         s + 2, blocks, kc_off,
                                         zeros_ref, hd, head_off, dh);
                        let k3 = slot_kv(qkv_ref, qkv_stride, hd,
                                         view.data, &staged_at, map_base,
                                         s + 3, blocks, kc_off,
                                         zeros_ref, hd, head_off, dh);
                        let (mut a0, mut a1, mut a2, mut a3) =
                            (0f32, 0f32, 0f32, 0f32);
                        for e in 0..dh {
                            let qe = qv[e];
                            a0 += qe * k0[e];
                            a1 += qe * k1[e];
                            a2 += qe * k2[e];
                            a3 += qe * k3[e];
                        }
                        scores[s] = a0 * scale;
                        scores[s + 1] = a1 * scale;
                        scores[s + 2] = a2 * scale;
                        scores[s + 3] = a3 * scale;
                        s += 4;
                    }
                    while s <= p {
                        let kr = slot_kv(qkv_ref, qkv_stride, hd,
                                         view.data, &staged_at, map_base,
                                         s, blocks, kc_off, zeros_ref,
                                         hd, head_off, dh);
                        let mut acc = 0f32;
                        for e in 0..dh {
                            acc += qv[e] * kr[e];
                        }
                        scores[s] = acc * scale;
                        s += 1;
                    }
                    let mut m = f32::NEG_INFINITY;
                    for &sc in scores.iter().take(p + 1) {
                        if sc > m {
                            m = sc;
                        }
                    }
                    let mut denom = 0f32;
                    for sc in scores.iter_mut().take(p + 1) {
                        *sc = (*sc - m).exp();
                        denom += *sc;
                    }
                    // SAFETY: work item (j, head) is owned by exactly
                    // one lane; items map to disjoint [dh] output
                    // ranges.
                    let out = unsafe {
                        attn_out.range(j * hd + head_off, dh)
                    };
                    for s in 0..=p {
                        let w = scores[s] / denom;
                        let vr = slot_kv(qkv_ref, qkv_stride, 2 * hd,
                                         view.data, &staged_at, map_base,
                                         s, blocks, vc_off, zeros_ref,
                                         hd, head_off, dh);
                        for e in 0..dh {
                            out[e] += w * vr[e];
                        }
                    }
                }
            };
            let lanes = self.pool.lanes().min(items);
            if lanes <= 1 || items * s_used * dh < PAR_MIN_ATTN_MACS {
                run_items(0, items);
            } else {
                self.pool.run(&|lane| {
                    if lane < lanes {
                        let (i0, i1) = chunk(items, lanes, lane);
                        run_items(i0, i1);
                    }
                });
            }
            ops.attn_s += clock.lap();

            // --- attention output projection (+ residual) ---
            self.par_matmul(&attn, &pk.wo, &mut x, n);
            ops.wo_s += clock.lap();

            // --- fused MLP ---
            let xn2 = rmsnorm(&x, d, &lyr.ln_mlp);
            gu.fill(0.0);
            self.par_matmul(&xn2, &pk.w13, &mut gu, n);
            for j in 0..n {
                let (gr, ur) = (j * 2 * ff, j * 2 * ff + ff);
                for e in 0..ff {
                    let gv = gu[gr + e];
                    gact[j * ff + e] =
                        gv * (1.0 / (1.0 + (-gv).exp())) * gu[ur + e];
                }
            }
            self.par_matmul(&gact, &pk.w2, &mut x, n);
            ops.mlp_s += clock.lap();
        }

        // Final norm + tied-embedding logits, scattered back to the
        // (zeros-padded) call layout.
        let hidden = rmsnorm(&x, d, &self.m.ln_f);
        let mut dense = vec![0f32; n * vocab];
        self.par_matmul(&hidden, &self.embed_t, &mut dense, n);
        for j in 0..n {
            let dst = cells[j] * vocab;
            logits[dst..dst + vocab]
                .copy_from_slice(&dense[j * vocab..(j + 1) * vocab]);
        }
        if let Some(hout) = hidden_out.as_mut() {
            for j in 0..n {
                let dst = cells[j] * d;
                hout[dst..dst + d]
                    .copy_from_slice(&hidden[j * d..(j + 1) * d]);
            }
        }
        ops.logits_s += clock.lap();

        Ok(FwdOut {
            logits,
            hidden: hidden_out,
            kv: KvStage::Host { k: k_stage, v: v_stage },
            elapsed_s: t0.elapsed().as_secs_f64(),
            ops: Some(ops),
        })
    }

    fn commit(&self, b: usize, t: usize, out: &FwdOut, commit_pos: &[i32],
              cache: &mut KvCache) -> Result<f64> {
        let t0 = stopwatch();
        match &out.kv {
            KvStage::Host { k, v } => {
                cache.host_scatter(b, t, k, v, commit_pos)?;
            }
            #[cfg(feature = "pjrt")]
            KvStage::Pjrt { .. } => {
                anyhow::bail!("PJRT FwdOut fed to the host commit")
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::{matmul_acc, reference_manifest};
    use crate::substrate::rng::Rng;

    fn pair(name: &str) -> (RefModel, HostModel) {
        let man = reference_manifest();
        let entry = man.models.get(name).unwrap();
        (RefModel::build(7, entry).unwrap(),
         HostModel::build(7, entry).unwrap())
    }

    #[test]
    fn packed_panel_matmul_is_bit_identical_to_matmul_acc() {
        // Panel packing + any panel partition must reproduce the
        // oracle's matmul bit for bit — including a ragged tail panel.
        let mut rng = Rng::new(0xBEEF);
        for &(n, din, dout) in
            &[(3usize, 32usize, 48usize), (5, 24, 40), (1, 16, 21)]
        {
            let a: Vec<f32> =
                (0..n * din).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> =
                (0..din * dout).map(|_| rng.normal() as f32).collect();
            let mut want: Vec<f32> =
                (0..n * dout).map(|i| (i % 5) as f32 * 0.1).collect();
            let mut got = want.clone();
            matmul_acc(&a, &w, &mut want, n, din, dout);
            let pm = PackedMat::pack(&w, din, dout);
            let panels = pm.n_panels();
            let shared = SharedSlice::new(&mut got);
            // split the panel range in two, applied out of order
            let mid = panels / 2;
            pm.matmul_acc_panels(&a, &shared, n, mid, panels);
            pm.matmul_acc_panels(&a, &shared, n, 0, mid);
            assert_eq!(want, got,
                       "packed panels diverged at {n}x{din}x{dout}");
        }
    }

    #[test]
    fn ragged_last_panel_edges_are_bit_identical() {
        // dout % PANEL ∈ {1, 8, 15}: one live lane0 cell, exactly one
        // full SIMD chunk, and a chunk plus a 7-wide tail.  Each must
        // reproduce the oracle bit for bit and leave the zero-padded
        // dead lanes unwritten.
        let mut rng = Rng::new(0xA11);
        for &(n, din, dout) in
            &[(2usize, 24usize, 17usize), (3, 32, 24), (1, 16, 31),
              (4, 8, 33), (2, 40, 47)]
        {
            assert!(matches!(dout % PANEL, 1 | 8 | 15));
            let a: Vec<f32> =
                (0..n * din).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> =
                (0..din * dout).map(|_| rng.normal() as f32).collect();
            let mut want: Vec<f32> =
                (0..n * dout).map(|i| (i % 7) as f32 * 0.25).collect();
            let mut got = want.clone();
            matmul_acc(&a, &w, &mut want, n, din, dout);
            let pm = PackedMat::pack(&w, din, dout);
            pm.matmul_acc_panels(&a, &SharedSlice::new(&mut got), n, 0,
                                 pm.n_panels());
            assert_eq!(want, got,
                       "ragged tail diverged at {n}x{din}x{dout}");
        }
    }

    #[test]
    fn widths_below_one_simd_chunk_are_bit_identical() {
        // dout < LANE: the whole matrix is a partial lane0; acc1 and
        // the upper lane0 cells run over padding and never store.
        let mut rng = Rng::new(0xC0FFEE);
        for &dout in &[1usize, 2, 5, 7] {
            let (n, din) = (3usize, 24usize);
            let a: Vec<f32> =
                (0..n * din).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> =
                (0..din * dout).map(|_| rng.normal() as f32).collect();
            let mut want = vec![0.5f32; n * dout];
            let mut got = want.clone();
            matmul_acc(&a, &w, &mut want, n, din, dout);
            let pm = PackedMat::pack(&w, din, dout);
            pm.matmul_acc_panels(&a, &SharedSlice::new(&mut got), n, 0,
                                 pm.n_panels());
            assert_eq!(want, got, "sub-chunk width {dout} diverged");
        }
    }

    #[test]
    fn pool_partitioned_matmul_matches_serial() {
        let mut rng = Rng::new(0xF00D);
        let (n, din, dout) = (4usize, 32usize, 64usize);
        let a: Vec<f32> =
            (0..n * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32).collect();
        let pm = PackedMat::pack(&w, din, dout);
        let mut serial = vec![0f32; n * dout];
        pm.matmul_acc_panels(&a, &SharedSlice::new(&mut serial), n, 0,
                             pm.n_panels());
        let pool = WorkerPool::new(3);
        let mut par = vec![0f32; n * dout];
        let shared = SharedSlice::new(&mut par);
        let panels = pm.n_panels();
        pool.run(&|lane| {
            let (p0, p1) = chunk(panels, 3, lane);
            pm.matmul_acc_panels(&a, &shared, n, p0, p1);
        });
        assert_eq!(serial, par, "lane partition changed bits");
    }

    #[test]
    fn fwd_is_bit_identical_to_oracle() {
        let (oracle, host) = pair("target-m");
        let co = oracle.new_cache(1).unwrap();
        let ch = host.new_cache(1).unwrap();
        let toks = [0i32, 13, 20, 21, 33];
        let pos = [0i32, 1, 2, 3, 4];
        let a = oracle.fwd(1, 5, &toks, &pos, None, &co).unwrap();
        let b = host.fwd(1, 5, &toks, &pos, None, &ch).unwrap();
        assert_eq!(a.logits, b.logits, "host logits diverged from oracle");
        let ops = b.ops.expect("host fwd reports a per-op breakdown");
        assert!(ops.total() > 0.0, "op breakdown must be populated");
        assert!(ops.total() <= b.elapsed_s,
                "op breakdown cannot exceed elapsed");
    }

    #[test]
    fn staged_kv_and_commit_match_oracle() {
        let (oracle, host) = pair("draft-s");
        let mut co = oracle.new_cache(1).unwrap();
        let mut ch = host.new_cache(1).unwrap();
        let toks = [0i32, 17, 25];
        let pos = [0i32, 1, 2];
        let a = oracle.fwd(1, 3, &toks, &pos, None, &co).unwrap();
        let b = host.fwd(1, 3, &toks, &pos, None, &ch).unwrap();
        oracle.commit(1, 3, &a, &pos, &mut co).unwrap();
        host.commit(1, 3, &b, &pos, &mut ch).unwrap();
        for l in 0..oracle.cfg().n_layers {
            for slot in 0..3 {
                assert_eq!(co.host_kv(0, l, 0, slot),
                           ch.host_kv(0, l, 0, slot),
                           "K cache diverged at l={l} slot={slot}");
                assert_eq!(co.host_kv(1, l, 0, slot),
                           ch.host_kv(1, l, 0, slot),
                           "V cache diverged at l={l} slot={slot}");
            }
        }
    }

    #[test]
    fn parked_cells_are_skipped_but_live_cells_exact() {
        // Pad the call out with parked columns and a parked row: live
        // logits must stay bit-identical to the oracle's, parked cells
        // are zero (the oracle computes pad-token logits there; both
        // are unread by contract).
        let (oracle, host) = pair("target-m");
        let vocab = oracle.cfg().vocab;
        let co = oracle.new_cache(2).unwrap();
        let ch = host.new_cache(2).unwrap();
        let gslot = ch.garbage_slot();
        let toks = [0i32, 13, 20, 2, 2, 2, 2, 2];
        let pos = [0i32, 1, 2, gslot, gslot, gslot, gslot, gslot];
        let a = oracle.fwd(2, 4, &toks, &pos, None, &co).unwrap();
        let b = host.fwd(2, 4, &toks, &pos, None, &ch).unwrap();
        assert_eq!(a.logits[..3 * vocab], b.logits[..3 * vocab]);
        assert!(b.logits[4 * vocab..].iter().all(|&x| x == 0.0),
                "parked row must be zeros on the host path");
    }

    #[test]
    fn eagle_head_matches_oracle() {
        let (oracle, host) = pair("eagle-target-l");
        let d = oracle.cfg().d_model;
        let co = oracle.new_cache(1).unwrap();
        let ch = host.new_cache(1).unwrap();
        let hin: Vec<f32> = (0..2 * d).map(|i| (i as f32) * 0.01).collect();
        let a = oracle
            .fwd(1, 2, &[0, 13], &[0, 1], Some(&hin), &co)
            .unwrap();
        let b = host.fwd(1, 2, &[0, 13], &[0, 1], Some(&hin), &ch).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.hidden, b.hidden);
        assert!(host.fwd(1, 1, &[0], &[0], None, &ch).is_err(),
                "eagle fwd without hidden must fail");
    }

    #[test]
    fn out_of_range_pos_ropes_with_raw_value() {
        // A raw pos below 0 clamps to slot 0 (live) for attention and
        // scatter, but the oracle ropes Q/K with the RAW value — the
        // host path must too, or bit-identity breaks at the surface.
        let (oracle, host) = pair("draft-s");
        let co = oracle.new_cache(1).unwrap();
        let ch = host.new_cache(1).unwrap();
        let a = oracle.fwd(1, 1, &[5], &[-3], None, &co).unwrap();
        let b = host.fwd(1, 1, &[5], &[-3], None, &ch).unwrap();
        assert_eq!(a.logits, b.logits, "OOB-pos logits diverged");
        match (&a.kv, &b.kv) {
            (KvStage::Host { k: ka, .. }, KvStage::Host { k: kb, .. }) => {
                assert_eq!(ka, kb, "OOB-pos staged K diverged");
            }
            #[cfg(feature = "pjrt")]
            _ => unreachable!("host backends stage host KV"),
        }
    }

    #[test]
    fn decode_after_commit_matches_oracle() {
        // Cached decode: prefill, commit, then T=1 steps — the
        // in-place cache read must equal the oracle's transient copy.
        let (oracle, host) = pair("draft-s");
        let vocab = oracle.cfg().vocab;
        let run = |m: &dyn Backend| -> Vec<f32> {
            let mut cache = m.new_cache(1).unwrap();
            let toks = [0i32, 17, 25, 30];
            let pos = [0i32, 1, 2, 3];
            let out = m.fwd(1, 4, &toks, &pos, None, &cache).unwrap();
            m.commit(1, 4, &out, &pos, &mut cache).unwrap();
            cache.cur_len[0] = 4;
            let step = m.fwd(1, 1, &[19], &[4], None, &cache).unwrap();
            step.logits[..vocab].to_vec()
        };
        assert_eq!(run(&oracle), run(&host));
    }

    #[test]
    fn lane_count_does_not_change_fwd_bits() {
        // The §8 invariance at the backend-call surface: the same fwd
        // through pools of 1, 2, and 8 lanes is bit-identical (the
        // engine-level sweep lives in tests/host_backend.rs).
        let man = reference_manifest();
        let entry = man.models.get("target-m").unwrap();
        let toks = [0i32, 13, 20, 21, 33, 40];
        let pos = [0i32, 1, 2, 3, 4, 5];
        let mut base: Option<Vec<f32>> = None;
        for lanes in [1usize, 2, 8] {
            let m = HostModel::build_with_pool(
                7, entry, Arc::new(WorkerPool::new(lanes))).unwrap();
            assert_eq!(m.threads(), lanes);
            let c = m.new_cache(1).unwrap();
            let out = m.fwd(1, 6, &toks, &pos, None, &c).unwrap();
            match &base {
                None => base = Some(out.logits),
                Some(want) => {
                    assert_eq!(want, &out.logits,
                               "{lanes}-lane fwd changed bits");
                }
            }
        }
    }

    #[test]
    fn op_weight_bytes_covers_every_matmul_site() {
        // f32: the bucket totals must equal the packed panel bytes of
        // every weight the forward pass sweeps (incl. fuse on EAGLE).
        let (_, host) = pair("target-m");
        let w = host.op_weight_bytes();
        assert!(w.qkv > 0 && w.wo > 0 && w.mlp > 0 && w.logits > 0);
        assert_eq!(w.fuse, 0, "LM models have no fuse projection");
        assert_eq!(w.total(), w.qkv + w.wo + w.mlp + w.logits + w.fuse);
        let man = reference_manifest();
        let eagle = HostModel::build(
            7, man.models.get("eagle-target-l").unwrap()).unwrap();
        assert!(eagle.op_weight_bytes().fuse > 0,
                "EAGLE fuse projection must be counted");
    }

    #[test]
    fn q8_model_quantizes_every_matmul_weight() {
        let man = reference_manifest();
        let entry = man.models.get("target-m").unwrap();
        let f32m = HostModel::build(7, entry).unwrap();
        let q8m = HostModel::build_q8(7, entry).unwrap();
        assert!(!f32m.is_quantized());
        assert!(q8m.is_quantized());
        // q8 streams ~4x fewer weight bytes: i8 panels + one f32 scale
        // per panel vs f32 panels.
        let (fb, qb) = (f32m.op_weight_bytes().total(),
                        q8m.op_weight_bytes().total());
        assert!(qb * 3 < fb && qb * 5 > fb,
                "q8/f32 weight bytes {qb}/{fb} not ~1/4");
    }

    #[test]
    fn q8_fwd_logits_stay_close_to_f32() {
        // The q8 bounded-error contract at the fwd surface: per-logit
        // absolute error stays small on every family model.  The bound
        // is generous (~10x what the refsim mirror calibrates) so it
        // fails on real kernel bugs, not quantization noise.
        let man = reference_manifest();
        for name in ["draft-s", "target-m", "target-l"] {
            let entry = man.models.get(name).unwrap();
            let f32m = HostModel::build(7, entry).unwrap();
            let q8m = HostModel::build_q8(7, entry).unwrap();
            let cf = f32m.new_cache(1).unwrap();
            let cq = q8m.new_cache(1).unwrap();
            let toks = [0i32, 13, 20, 21, 33];
            let pos = [0i32, 1, 2, 3, 4];
            let a = f32m.fwd(1, 5, &toks, &pos, None, &cf).unwrap();
            let b = q8m.fwd(1, 5, &toks, &pos, None, &cq).unwrap();
            let mut max_err = 0f32;
            for (x, y) in a.logits.iter().zip(&b.logits) {
                max_err = max_err.max((x - y).abs());
            }
            assert!(max_err > 0.0,
                    "{name}: q8 bit-identical to f32 is suspicious");
            assert!(max_err < 0.5,
                    "{name}: q8 per-logit error {max_err} out of bounds");
        }
    }

    #[test]
    fn q8_fwd_is_deterministic_across_lane_counts() {
        // q8 drops bit-identity to the *oracle*, not determinism: the
        // same q8 fwd through 1/2/8 lanes must be bit-identical to
        // itself (same column-decomposition argument as f32).
        let man = reference_manifest();
        let entry = man.models.get("target-m").unwrap();
        let toks = [0i32, 13, 20, 21, 33, 40];
        let pos = [0i32, 1, 2, 3, 4, 5];
        let mut base: Option<Vec<f32>> = None;
        for lanes in [1usize, 2, 8] {
            let m = HostModel::build_q8_with_pool(
                7, entry, Arc::new(WorkerPool::new(lanes))).unwrap();
            let c = m.new_cache(1).unwrap();
            let out = m.fwd(1, 6, &toks, &pos, None, &c).unwrap();
            match &base {
                None => base = Some(out.logits),
                Some(want) => {
                    assert_eq!(want, &out.logits,
                               "{lanes}-lane q8 fwd changed bits");
                }
            }
        }
    }
}
