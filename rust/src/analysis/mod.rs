//! `pard audit` — dependency-free static analysis over the crate's
//! own sources (DESIGN.md §11).
//!
//! A lexer-lite scanner ([`scanner`]) plus a rule engine ([`rules`])
//! enforce the project's determinism/safety/robustness invariants as
//! checkable rules instead of conventions:
//!
//! * D1 det-hash-iter — no HashMap/HashSet in determinism paths
//! * D2 wall-clock    — wall time only via `substrate::bench`
//! * D3 rng-discipline — no ambient entropy; literal seed/stream
//!   pairs must not collide across sites
//! * D4 float-reassoc — no reassociating accumulators in backend
//!   identity paths
//! * S1 unsafe-hygiene — `unsafe` confined and SAFETY-commented
//! * R1 no-panic-serving — no unwrap/expect/panic! on request paths
//! * R2 lossy-cast    — no narrowing casts in cache index arithmetic
//! * H1 doc-coverage  — public runtime/coordinator items documented
//!
//! Findings can be waived inline with the `audit:allow` comment
//! (rule list in parentheses, then a mandatory reason — full syntax
//! in DESIGN.md §11); waivers cover their own line and the next, are
//! counted and reported, and are themselves audited: an unknown rule
//! id, a missing reason, or an unused waiver is a violation.
//!
//! `python/refsim/auditsim.py` is the executable mirror (same rules,
//! same scanner, same report schema) for hosts without a Rust
//! toolchain; ci.sh gates on both.  Exit contract: zero unwaived
//! violations = success.

mod report;
mod rules;
mod scanner;

use std::path::Path;

use anyhow::{Context, Result};

pub use report::{audit, AuditReport, Finding, WaiverError};
pub use rules::{is_rule, RULES};
pub use scanner::{has_token, rng_literal_sites, strip_code, FileScan,
                  Waiver, WAIVER_MARK};

/// Sorted (relpath, text) set under `<root>/rust/src/**/*.rs`.
pub fn walk_sources(root: &Path) -> Result<Vec<(String, String)>> {
    let src = root.join("rust").join("src");
    let mut out = Vec::new();
    collect(&src, &src, &mut out)?;
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

fn collect(src: &Path, dir: &Path,
           out: &mut Vec<(String, String)>) -> Result<()> {
    let mut entries: Vec<std::fs::DirEntry> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()
        .with_context(|| format!("reading {}", dir.display()))?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            collect(src, &path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            let rel = path
                .strip_prefix(src)
                .context("source path outside rust/src")?
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            out.push((rel, text));
        }
    }
    Ok(())
}

/// Walk `<root>/rust/src` and audit every source file.
pub fn audit_tree(root: &Path) -> Result<AuditReport> {
    let files = walk_sources(root)?;
    anyhow::ensure!(!files.is_empty(),
                    "no .rs files under {}/rust/src — wrong --root?",
                    root.display());
    Ok(audit(&files))
}
