//! Whole-tree audit: waiver application, the cross-file D3 registry,
//! and the stable machine-readable report (schema pard-audit-v1).

use std::collections::{BTreeMap, BTreeSet};

use super::rules::{collect_rng_registry, scan_rules, RULES};
use super::scanner::{FileScan, WAIVER_MARK};
use crate::substrate::json::Json;

/// One finding: a rule hit at a file/line (waived iff `reason` set).
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule id (D1..H1).
    pub rule: &'static str,
    /// Path relative to rust/src.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Human-readable description of the hit.
    pub msg: String,
    /// The waiver reason when this finding is waived.
    pub reason: Option<String>,
}

/// A malformed or unused waiver comment — counted as a violation, so
/// stale waivers can never silently disarm a rule.
#[derive(Debug, Clone)]
pub struct WaiverError {
    /// Path relative to rust/src.
    pub file: String,
    /// 1-based line of the waiver comment.
    pub line: usize,
    /// What is wrong with it.
    pub msg: String,
}

/// The audit result both implementations produce.  Exit contract:
/// success iff [`AuditReport::total_violations`] is zero (waived
/// findings are counted and reported, never hidden).
pub struct AuditReport {
    /// Files scanned under rust/src.
    pub files_scanned: usize,
    /// Unwaived findings.
    pub violations: Vec<Finding>,
    /// Findings covered by a valid waiver (reason attached).
    pub waived: Vec<Finding>,
    /// Malformed/unused waiver comments.
    pub waiver_errors: Vec<WaiverError>,
    /// rule id -> (unwaived, waived) counts.
    pub rule_counts: BTreeMap<&'static str, (usize, usize)>,
}

/// Audit an ordered (relpath, text) file set.
pub fn audit(files: &[(String, String)]) -> AuditReport {
    let scans: Vec<FileScan> = files
        .iter()
        .map(|(rel, text)| FileScan::new(rel, text))
        .collect();

    // D3 registry: literal seed/stream pairs must be globally unique
    // across non-test sites (duplicate pairs = colliding rng streams).
    let mut registry: BTreeMap<(String, String), Vec<(String, usize)>> =
        BTreeMap::new();
    for fs in &scans {
        for (pair, lineno) in collect_rng_registry(fs) {
            registry
                .entry(pair)
                .or_default()
                .push((fs.relpath.clone(), lineno));
        }
    }
    let mut collisions: BTreeMap<String, Vec<(usize, String)>> =
        BTreeMap::new();
    for (pair, sites) in &registry {
        if sites.len() < 2 {
            continue;
        }
        let (ffile, fline) = &sites[0];
        for (rel, lineno) in &sites[1..] {
            collisions.entry(rel.clone()).or_default().push((
                *lineno,
                format!("literal rng seed/stream ({}, {}) collides \
                         with {}:{}", pair.0, pair.1, ffile, fline),
            ));
        }
    }

    let mut violations = Vec::new();
    let mut waived = Vec::new();
    let mut waiver_errors = Vec::new();
    let mut rule_counts: BTreeMap<&'static str, (usize, usize)> =
        RULES.iter().map(|(id, _)| (*id, (0, 0))).collect();
    let mut used: BTreeSet<(String, usize)> = BTreeSet::new();

    for fs in &scans {
        let mut findings = scan_rules(fs);
        if let Some(cols) = collisions.get(&fs.relpath) {
            for (lineno, msg) in cols {
                findings.push(("D3", *lineno, msg.clone()));
            }
        }
        findings.sort_by(|a, b| (a.1, a.0).cmp(&(b.1, b.0)));
        for (rule, lineno, msg) in findings {
            let waiver = fs.waivers.get(&lineno).and_then(|ws| {
                ws.iter().find(|w| w.rules.iter().any(|r| r == rule))
            });
            let entry = Finding {
                rule,
                file: fs.relpath.clone(),
                line: lineno,
                msg,
                reason: waiver.map(|w| w.reason.clone()),
            };
            match waiver {
                Some(w) => {
                    waived.push(entry);
                    if let Some(c) = rule_counts.get_mut(rule) {
                        c.1 += 1;
                    }
                    used.insert((fs.relpath.clone(), w.line));
                }
                None => {
                    violations.push(entry);
                    if let Some(c) = rule_counts.get_mut(rule) {
                        c.0 += 1;
                    }
                }
            }
        }
        for (lineno, msg) in &fs.waiver_errors {
            waiver_errors.push(WaiverError {
                file: fs.relpath.clone(),
                line: *lineno,
                msg: msg.clone(),
            });
        }
        for w in &fs.waiver_sites {
            if !used.contains(&(fs.relpath.clone(), w.line)) {
                waiver_errors.push(WaiverError {
                    file: fs.relpath.clone(),
                    line: w.line,
                    msg: format!("unused {WAIVER_MARK}{}) waiver",
                                 w.rules.join(",")),
                });
            }
        }
    }

    AuditReport {
        files_scanned: scans.len(),
        violations,
        waived,
        waiver_errors,
        rule_counts,
    }
}

impl AuditReport {
    /// Unwaived findings plus waiver errors — the exit-code driver.
    pub fn total_violations(&self) -> usize {
        self.violations.len() + self.waiver_errors.len()
    }

    /// Findings covered by a valid waiver.
    pub fn total_waived(&self) -> usize {
        self.waived.len()
    }

    /// Does the tree pass (zero unwaived violations)?
    pub fn passed(&self) -> bool {
        self.total_violations() == 0
    }

    /// The stable machine-readable report (schema pard-audit-v1).
    pub fn to_json(&self) -> Json {
        let finding = |f: &Finding| {
            let mut o = BTreeMap::new();
            o.insert("rule".to_string(), Json::Str(f.rule.to_string()));
            o.insert("file".to_string(), Json::Str(f.file.clone()));
            o.insert("line".to_string(), Json::Num(f.line as f64));
            o.insert("msg".to_string(), Json::Str(f.msg.clone()));
            if let Some(r) = &f.reason {
                o.insert("reason".to_string(), Json::Str(r.clone()));
            }
            Json::Obj(o)
        };
        let mut rules = BTreeMap::new();
        for (id, desc) in RULES {
            let (v, w) = self.rule_counts[id];
            let mut o = BTreeMap::new();
            o.insert("description".to_string(),
                     Json::Str(desc.to_string()));
            o.insert("violations".to_string(), Json::Num(v as f64));
            o.insert("waived".to_string(), Json::Num(w as f64));
            rules.insert(id.to_string(), Json::Obj(o));
        }
        let errs = self
            .waiver_errors
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                o.insert("file".to_string(), Json::Str(e.file.clone()));
                o.insert("line".to_string(), Json::Num(e.line as f64));
                o.insert("msg".to_string(), Json::Str(e.msg.clone()));
                Json::Obj(o)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("schema".to_string(),
                   Json::Str("pard-audit-v1".to_string()));
        top.insert("files_scanned".to_string(),
                   Json::Num(self.files_scanned as f64));
        top.insert("rules".to_string(), Json::Obj(rules));
        top.insert("violations".to_string(),
                   Json::Arr(self.violations.iter().map(finding)
                                 .collect()));
        top.insert("waived".to_string(),
                   Json::Arr(self.waived.iter().map(finding)
                                 .collect()));
        top.insert("waiver_errors".to_string(), Json::Arr(errs));
        top.insert("total_violations".to_string(),
                   Json::Num(self.total_violations() as f64));
        top.insert("total_waived".to_string(),
                   Json::Num(self.total_waived() as f64));
        Json::Obj(top)
    }

    /// The human-readable report `pard audit` prints.
    pub fn render(&self) -> String {
        let mut out = format!(
            "pard audit — scanned {} files under rust/src\n",
            self.files_scanned
        );
        for (id, _) in RULES {
            let (v, w) = self.rule_counts[id];
            out += &format!("  {id}  {v} violations, {w} waived\n");
        }
        for f in &self.violations {
            out += &format!("  {}:{}: {} {}\n", f.file, f.line, f.rule,
                            f.msg);
        }
        for e in &self.waiver_errors {
            out += &format!("  {}:{}: waiver error: {}\n", e.file,
                            e.line, e.msg);
        }
        for f in &self.waived {
            out += &format!("  waived {} at {}:{} — {}\n", f.rule,
                            f.file, f.line,
                            f.reason.as_deref().unwrap_or(""));
        }
        if self.passed() {
            out += &format!("AUDIT OK — 0 violations, {} waived\n",
                            self.total_waived());
        } else {
            out += &format!("AUDIT FAIL — {} unwaived violation(s)\n",
                            self.total_violations());
        }
        out
    }
}

// Fixture tests mirror python/refsim/auditsim.py selftest() — one
// violation + one clean snippet per rule.  Fixtures are single-line
// string literals ("…\n…") on purpose: the lexer-lite scanner blanks
// one-line strings, so fixture contents never leak into this file's
// own audit (a multi-line raw string WOULD leak — documented
// limitation).
#[cfg(test)]
mod tests {
    use super::*;

    fn vio(files: &[(&str, &str)]) -> Vec<(String, String, usize)> {
        rep(files)
            .violations
            .iter()
            .map(|f| (f.rule.to_string(), f.file.clone(), f.line))
            .collect()
    }

    fn rep(files: &[(&str, &str)]) -> AuditReport {
        let owned: Vec<(String, String)> = files
            .iter()
            .map(|(a, b)| (a.to_string(), b.to_string()))
            .collect();
        audit(&owned)
    }

    fn hit(rule: &str, file: &str, line: usize)
           -> Vec<(String, String, usize)> {
        vec![(rule.to_string(), file.to_string(), line)]
    }

    // Build waiver fixtures without embedding the contiguous marker in
    // this file's own raw lines (the audit scans raw lines for it).
    fn allow(tail: &str) -> String {
        format!("// {WAIVER_MARK}{tail}")
    }

    #[test]
    fn d1_hash_in_determinism_path() {
        let dirty = "use std::collections::HashMap;\n";
        assert_eq!(vio(&[("runtime/fx.rs", dirty)]),
                   hit("D1", "runtime/fx.rs", 1));
        assert!(vio(&[("runtime/fx.rs",
                       "use std::collections::BTreeMap;\n")])
            .is_empty());
        assert!(vio(&[("main.rs", dirty)]).is_empty());
        let in_test = format!("#[cfg(test)]\n{dirty}");
        assert!(vio(&[("runtime/fx.rs", in_test.as_str())]).is_empty());
    }

    #[test]
    fn d2_wall_clock_whitelist() {
        let dirty = "let t0 = Instant::now();\n";
        assert_eq!(vio(&[("coordinator/fx.rs", dirty)]),
                   hit("D2", "coordinator/fx.rs", 1));
        assert!(vio(&[("substrate/bench.rs", dirty)]).is_empty());
        assert_eq!(vio(&[("coordinator/fx.rs",
                          "let t = SystemTime::now();\n")]),
                   hit("D2", "coordinator/fx.rs", 1));
    }

    #[test]
    fn d3_ambient_entropy() {
        assert_eq!(vio(&[("runtime/fx.rs",
                          "let r = rand::random::<u64>();\n")]),
                   hit("D3", "runtime/fx.rs", 1));
        assert!(vio(&[("runtime/fx.rs",
                       "let r = Rng::new_stream(seed, i);\n")])
            .is_empty());
    }

    #[test]
    fn d3_literal_pair_collisions() {
        let a = "let r = Rng::new_stream(7, 1);\n";
        assert_eq!(vio(&[("runtime/a.rs", a), ("runtime/b.rs", a)]),
                   hit("D3", "runtime/b.rs", 1));
        assert!(vio(&[("runtime/a.rs", a),
                      ("runtime/b.rs",
                       "let r = Rng::new_stream(7, 2);\n")])
            .is_empty());
        assert!(vio(&[("runtime/a.rs", "let r = Rng::new(7);\n"),
                      ("runtime/b.rs",
                       "#[cfg(test)]\nlet r = Rng::new(7);\n")])
            .is_empty());
    }

    #[test]
    fn d4_reassociating_accumulators() {
        let dirty = "let s: f32 = xs.iter().sum();\n";
        assert_eq!(vio(&[("runtime/host.rs", dirty)]),
                   hit("D4", "runtime/host.rs", 1));
        let explicit =
            "let mut s = 0f32; for k in 0..n { s += xs[k]; }\n";
        assert!(vio(&[("runtime/host.rs", explicit)]).is_empty());
        assert!(vio(&[("coordinator/fx.rs", dirty)]).is_empty());
    }

    #[test]
    fn s1_unsafe_confinement_and_hygiene() {
        assert_eq!(vio(&[("coordinator/fx.rs", "unsafe { run() }\n")]),
                   hit("S1", "coordinator/fx.rs", 1));
        assert_eq!(vio(&[("runtime/pool.rs", "unsafe { run() }\n")]),
                   hit("S1", "runtime/pool.rs", 1));
        // (single-line fixture strings on purpose: a string continued
        // across source lines would leak its tail into this file's own
        // line-local audit scan)
        let ok = "// SAFETY: fixture invariant.\nunsafe { run() }\n";
        assert!(vio(&[("runtime/pool.rs", ok)]).is_empty());
        // unsafe is checked inside test regions too
        let t = "#[cfg(test)]\nmod t {\nunsafe { run() }\n}\n";
        assert_eq!(vio(&[("runtime/pool.rs", t)]),
                   hit("S1", "runtime/pool.rs", 3));
    }

    #[test]
    fn r1_panics_on_serving_paths() {
        let dirty = "let g = m.lock().unwrap();\n";
        assert_eq!(vio(&[("server/mod.rs", dirty)]),
                   hit("R1", "server/mod.rs", 1));
        let ok = "let g = l.unwrap_or_else(PoisonError::into_inner);\n";
        assert!(vio(&[("server/mod.rs", ok)]).is_empty());
        assert!(vio(&[("runtime/fx.rs", dirty)]).is_empty());
        assert_eq!(vio(&[("coordinator/batcher.rs",
                          "panic!(\"boom\");\n")]),
                   hit("R1", "coordinator/batcher.rs", 1));
    }

    #[test]
    fn r2_narrowing_casts_in_cache() {
        assert_eq!(vio(&[("runtime/cache.rs", "let b = t as u32;\n")]),
                   hit("R2", "runtime/cache.rs", 1));
        assert!(vio(&[("runtime/cache.rs", "let b = t as usize;\n")])
            .is_empty());
    }

    #[test]
    fn h1_doc_coverage() {
        assert_eq!(vio(&[("runtime/fx.rs", "pub fn f() {}\n")]),
                   hit("H1", "runtime/fx.rs", 1));
        assert!(vio(&[("runtime/fx.rs", "/// Doc.\npub fn f() {}\n")])
            .is_empty());
        assert!(vio(&[("runtime/fx.rs",
                       "/// Doc.\n#[inline]\n#[cold]\npub fn f() {}\n")])
            .is_empty());
        assert!(vio(&[("runtime/fx.rs", "pub(crate) fn f() {}\n")])
            .is_empty());
        assert!(vio(&[("runtime/fx.rs", "pub mod fx;\n")]).is_empty());
    }

    #[test]
    fn waivers_cover_own_and_next_line() {
        let own = allow("D2) fixture timing\nlet t = Instant::now();\n");
        let r = rep(&[("coordinator/fx.rs", own.as_str())]);
        assert!(r.passed(), "{}", r.render());
        assert_eq!(r.total_waived(), 1);
        let same = format!("let t = Instant::now(); {}",
                           allow("D2) same-line\n"));
        let r = rep(&[("coordinator/fx.rs", same.as_str())]);
        assert!(r.passed(), "{}", r.render());
        assert_eq!(r.total_waived(), 1);
    }

    #[test]
    fn waiver_errors_are_violations() {
        let unknown = allow("Z9) what\n");
        assert_eq!(rep(&[("coordinator/fx.rs", unknown.as_str())])
                       .total_violations(), 1);
        let no_reason = allow("D2)\n");
        assert_eq!(rep(&[("coordinator/fx.rs", no_reason.as_str())])
                       .total_violations(), 1);
        let unused = allow("D2) nothing here\n");
        assert_eq!(rep(&[("coordinator/fx.rs", unused.as_str())])
                       .total_violations(), 1);
    }

    #[test]
    fn strings_and_comments_never_match() {
        let src = "// HashMap in a comment\n\
                   let s = \"HashMap Instant::now unsafe\";\n\
                   let r = r#\"HashSet .unwrap()\"#;\n\
                   let c = '\"'; let l: &'static str = \"x\";\n";
        assert!(vio(&[("runtime/fx.rs", src)]).is_empty());
        assert!(vio(&[("server/mod.rs", src)]).is_empty());
    }

    #[test]
    fn json_report_schema() {
        let r = rep(&[("runtime/fx.rs", "pub fn f() {}\n")]);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("schema").and_then(|v| v.as_str()),
                   Some("pard-audit-v1"));
        assert_eq!(j.usize_req("total_violations").unwrap(), 1);
        let h1 = j.req("rules").unwrap().req("H1").unwrap();
        assert_eq!(h1.usize_req("violations").unwrap(), 1);
        let v = &j.req("violations").unwrap().as_arr().unwrap()[0];
        assert_eq!(v.str_req("file").unwrap(), "runtime/fx.rs");
        assert_eq!(v.usize_req("line").unwrap(), 1);
    }

    /// The committed tree itself must be violation-free — the same
    /// gate ci.sh enforces through the python mirror in-container.
    #[test]
    fn committed_tree_is_violation_free() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("crate dir has a parent")
            .to_path_buf();
        let r = super::super::audit_tree(&root).unwrap();
        assert!(r.passed(), "{}", r.render());
        assert!(r.files_scanned > 20);
    }
}
