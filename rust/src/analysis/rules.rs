//! Rule catalog and per-file checks (DESIGN.md §11).
//!
//! Scope tables, token lists, and messages are kept in lockstep with
//! `python/refsim/auditsim.py` — same rule ids, same file scopes, same
//! match semantics.  Rule patterns below live in string literals, so
//! the audit's own stripped-line scan never matches this file.

use super::scanner::{has_token, rng_literal_sites, FileScan};

/// Rule ids and one-line descriptions (the report `rules` section).
pub const RULES: [(&str, &str); 8] = [
    ("D1",
     "det-hash-iter: HashMap/HashSet in a determinism path (iteration \
      order is a bit-identity hazard) — use BTreeMap/BTreeSet, or \
      waive a pure-lookup use"),
    ("D2",
     "wall-clock: Instant::now()/SystemTime outside the timing \
      whitelist — route through substrate::bench::stopwatch()"),
    ("D3",
     "rng-discipline: ambient entropy, or a literal Rng seed/stream \
      pair colliding with another site"),
    ("D4",
     "float-reassoc: .sum()/.product()/.fold() in a backend identity \
      path — write the explicit k-ascending loop"),
    ("S1",
     "unsafe-hygiene: `unsafe` outside pool/host/quant, or without a \
      SAFETY comment within 8 lines"),
    ("R1",
     "no-panic-serving: unwrap/expect/panic! on a serving request \
      path — surface a typed outcome instead"),
    ("R2",
     "lossy-cast: narrowing `as` cast in cache/block-table index \
      arithmetic — use try_from or widen"),
    ("H1",
     "doc-coverage: public runtime/coordinator item without a doc \
      comment"),
];

/// Is `id` a known rule id?
pub fn is_rule(id: &str) -> bool {
    RULES.iter().any(|(r, _)| *r == id)
}

const D1_PREFIXES: [&str; 4] =
    ["coordinator/", "runtime/", "substrate/", "server/"];
const D2_WHITELIST: [&str; 2] =
    ["coordinator/metrics.rs", "substrate/bench.rs"];
const D4_FILES: [&str; 3] =
    ["runtime/reference.rs", "runtime/host.rs", "runtime/quant.rs"];
const S1_ALLOWED: [&str; 3] =
    ["runtime/pool.rs", "runtime/host.rs", "runtime/quant.rs"];
const S1_LOOKBACK: usize = 8;
const R1_FILES: [&str; 2] = ["server/mod.rs", "coordinator/batcher.rs"];
const R2_FILES: [&str; 1] = ["runtime/cache.rs"];
const R2_NARROW: [&str; 6] = ["u32", "i32", "u16", "i16", "u8", "i8"];
const H1_PREFIXES: [&str; 2] = ["runtime/", "coordinator/"];
const H1_ITEMS: [&str; 6] = ["pub fn ", "pub struct ", "pub enum ",
                             "pub trait ", "pub const ", "pub type "];

const R1_PATTERNS: [&str; 6] = [".unwrap()", ".expect(", "panic!",
                                "unreachable!", "todo!",
                                "unimplemented!"];
const D3_ENTROPY: [&str; 5] = ["rand::", "thread_rng", "from_entropy",
                               "RandomState", "DefaultHasher"];

/// All single-file rule findings: (rule, 1-based line, message).
pub fn scan_rules(fs: &FileScan) -> Vec<(&'static str, usize, String)> {
    let rel = fs.relpath.as_str();
    let mut findings = Vec::new();

    let d1 = D1_PREFIXES.iter().any(|p| rel.starts_with(p));
    let d2 = !D2_WHITELIST.contains(&rel);
    let d4 = D4_FILES.contains(&rel);
    let s1_ok_file = S1_ALLOWED.contains(&rel);
    let r1 = R1_FILES.contains(&rel);
    let r2 = R2_FILES.contains(&rel);
    let h1 = H1_PREFIXES.iter().any(|p| rel.starts_with(p));

    for (idx, line) in fs.stripped.iter().enumerate() {
        let lineno = idx + 1;
        let in_test = fs.in_test(lineno);

        if d1 && !in_test {
            for tok in ["HashMap", "HashSet"] {
                if has_token(line, tok) {
                    findings.push((
                        "D1", lineno,
                        format!("{tok} in determinism path — iteration \
                                 order is a bit-identity hazard"),
                    ));
                }
            }
        }
        if d2 && !in_test
            && (line.contains("Instant::now")
                || has_token(line, "SystemTime"))
        {
            findings.push((
                "D2", lineno,
                "wall-clock read outside the timing whitelist — use \
                 substrate::bench::stopwatch()".to_string(),
            ));
        }
        if !in_test {
            for tok in D3_ENTROPY {
                if has_token(line, tok) {
                    findings.push((
                        "D3", lineno,
                        format!("ambient entropy `{tok}` — all \
                                 randomness flows through \
                                 substrate::rng"),
                    ));
                }
            }
        }
        if d4 && !in_test {
            for pat in [".sum(", ".sum::<", ".product(", ".fold("] {
                if line.contains(pat) {
                    findings.push((
                        "D4", lineno,
                        format!("reassociating accumulator `{pat}…` \
                                 in a backend identity path"),
                    ));
                    break;
                }
            }
        }
        // S1 applies in test regions too: unsafe is unsafe everywhere.
        if has_token(line, "unsafe") {
            if !s1_ok_file {
                findings.push((
                    "S1", lineno,
                    "`unsafe` outside runtime/{pool,host,quant}.rs"
                        .to_string(),
                ));
            } else {
                let lo = idx.saturating_sub(S1_LOOKBACK);
                let commented = fs.raw[lo..=idx].iter().any(|w| {
                    w.contains("SAFETY:") || w.contains("# Safety")
                });
                if !commented {
                    findings.push((
                        "S1", lineno,
                        format!("`unsafe` without a SAFETY comment \
                                 within {S1_LOOKBACK} lines"),
                    ));
                }
            }
        }
        if r1 && !in_test {
            for pat in R1_PATTERNS {
                if line.contains(pat) {
                    findings.push((
                        "R1", lineno,
                        format!("`{pat}…` on a serving request path — \
                                 surface a typed outcome"),
                    ));
                }
            }
        }
        if r2 && !in_test {
            for ty in R2_NARROW {
                if has_token(line, &format!("as {ty}")) {
                    findings.push((
                        "R2", lineno,
                        format!("narrowing `as {ty}` in cache index \
                                 arithmetic — use try_from or widen"),
                    ));
                }
            }
        }
        if h1 && !in_test {
            let body = line.trim_start();
            if H1_ITEMS.iter().any(|it| body.starts_with(it)) {
                // walk back over attribute lines, then look for a doc
                let mut j = idx;
                while j > 0
                    && fs.raw[j - 1].trim_start().starts_with("#[")
                {
                    j -= 1;
                }
                let doc = j > 0 && {
                    let p = fs.raw[j - 1].trim_start();
                    p.starts_with("///") || p.starts_with("//!")
                        || p.starts_with("#[doc")
                };
                if !doc {
                    findings.push((
                        "H1", lineno,
                        "public item without a doc comment".to_string(),
                    ));
                }
            }
        }
    }
    findings
}

/// Non-test literal (seed, stream) sites of one file, for the
/// cross-file D3 collision registry.
pub fn collect_rng_registry(fs: &FileScan)
                            -> Vec<((String, String), usize)> {
    let mut sites = Vec::new();
    for (idx, line) in fs.stripped.iter().enumerate() {
        let lineno = idx + 1;
        if fs.in_test(lineno) {
            continue;
        }
        for pair in rng_literal_sites(line) {
            sites.push((pair, lineno));
        }
    }
    sites
}
