//! Lexer-lite line scanner (DESIGN.md §11): line-local comment and
//! string stripping, column-0 `#[cfg(test)]`-to-EOF test regions, and
//! the waiver table.
//!
//! Deliberately NOT a Rust parser: rules match token patterns on
//! stripped lines, which is exact for this codebase's style and keeps
//! the subsystem dependency-free.  The documented limitation is that
//! strings and block comments spanning lines leak their continuation
//! lines into the scan (multi-line raw strings in particular); the
//! committed tree avoids scan-relevant tokens in such positions, and
//! fixture tests use single-line string literals for the same reason.
//!
//! Kept in lockstep with `python/refsim/auditsim.py` — the
//! toolchain-free mirror ci.sh gates on.  Any divergence between the
//! two implementations is itself a bug.

use std::collections::BTreeMap;

use super::rules::is_rule;

/// The waiver comment marker.  Assembled from two halves so this
/// file's own raw lines never contain the contiguous marker (the
/// waiver scan below runs on raw lines, comments and strings
/// included — the marker IS a comment).
pub const WAIVER_MARK: &str = concat!("audit:", "allow(");

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Find the char-index of `pat` in `ch` at or after `from`.
fn find_seq(ch: &[char], pat: &str, from: usize) -> Option<usize> {
    let p: Vec<char> = pat.chars().collect();
    if p.is_empty() || ch.len() < p.len() {
        return None;
    }
    (from..=ch.len() - p.len()).find(|&i| ch[i..i + p.len()] == p[..])
}

fn push_blank(out: &mut String, k: usize) {
    for _ in 0..k {
        out.push(' ');
    }
}

/// Blank string/char-literal contents and drop comment tails.
///
/// Line-local by design (the documented lexer-lite limitation).
/// Handles `//` tails, `/* .. */` on one line, `"…"` with escapes,
/// raw/byte strings with hash counting, and the
/// char-literal-vs-lifetime ambiguity of `'`.
pub fn strip_code(line: &str) -> String {
    let ch: Vec<char> = line.chars().collect();
    let n = ch.len();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    while i < n {
        let c = ch[i];
        if c == '/' && i + 1 < n && ch[i + 1] == '/' {
            break; // comment tail (///, //!, // alike)
        }
        if c == '/' && i + 1 < n && ch[i + 1] == '*' {
            let Some(end) = find_seq(&ch, "*/", i + 2) else {
                break;
            };
            push_blank(&mut out, end - i + 2);
            i = end + 2;
            continue;
        }
        if (c == 'r' || c == 'b') && (i == 0 || !is_ident(ch[i - 1])) {
            // raw/byte string starts: r"…", r#"…"#, b"…", br"…"
            let mut j = i + 1;
            if j < n && c == 'b' && ch[j] == 'r' {
                j += 1;
            }
            let mut hashes = 0;
            while j < n && ch[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && ch[j] == '"' {
                let close: String = std::iter::once('"')
                    .chain(std::iter::repeat('#').take(hashes))
                    .collect();
                let stop = match find_seq(&ch, &close, j + 1) {
                    None => n,
                    Some(end) => end + close.len(),
                };
                push_blank(&mut out, stop - i);
                i = stop;
                continue;
            }
        }
        if c == '"' {
            let mut j = i + 1;
            while j < n {
                if ch[j] == '\\' {
                    j += 2;
                    continue;
                }
                if ch[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            let j = j.min(n);
            push_blank(&mut out, j - i);
            i = j;
            continue;
        }
        if c == '\'' {
            // char literal vs lifetime: '\x' escapes and 'x' forms
            // are literals; anything else is a lifetime tick.
            if i + 1 < n && ch[i + 1] == '\\' {
                let stop = match find_seq(&ch, "'", i + 3) {
                    None => n,
                    Some(end) => end + 1,
                };
                push_blank(&mut out, stop - i);
                i = stop;
                continue;
            }
            if i + 2 < n && ch[i + 2] == '\'' {
                out.push_str("   ");
                i += 3;
                continue;
            }
            out.push(' ');
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

/// Substring match with non-identifier boundaries, enforced only on
/// edges where the token itself ends in an identifier char (so
/// `rand::` needs no right boundary but `u32` does).
pub fn has_token(line: &str, tok: &str) -> bool {
    let (Some(first), Some(last)) = (tok.chars().next(), tok.chars().last())
    else {
        return false;
    };
    let mut start = 0;
    while let Some(off) = line[start..].find(tok) {
        let i = start + off;
        let before = !is_ident(first)
            || line[..i].chars().next_back().map_or(true, |c| !is_ident(c));
        let j = i + tok.len();
        let after = !is_ident(last)
            || line[j..].chars().next().map_or(true, |c| !is_ident(c));
        if before && after {
            return true;
        }
        start = i + first.len_utf8();
    }
    false
}

/// Literal-argument Rng constructor calls on one stripped line.
///
/// Returns (seed, stream) string pairs; a one-argument constructor
/// registers as stream "-".  Non-literal arguments (idents,
/// expressions) are not registry entries — only repeated literal
/// pairs are collisions.
pub fn rng_literal_sites(stripped: &str) -> Vec<(String, String)> {
    let mut sites = Vec::new();
    for (call, nargs) in [("Rng::new_stream(", 2usize), ("Rng::new(", 1)] {
        let mut start = 0;
        while let Some(off) = stripped[start..].find(call) {
            let args_at = start + off + call.len();
            start = args_at;
            let Some(close_off) = stripped[args_at..].find(')') else {
                continue;
            };
            let close = args_at + close_off;
            let args: Vec<String> = stripped[args_at..close]
                .split(',')
                .map(|a| a.trim().replace('_', ""))
                .collect();
            let all_lit = args.len() == nargs
                && args
                    .iter()
                    .all(|a| !a.is_empty()
                        && a.chars().all(|c| c.is_ascii_digit()));
            if all_lit {
                let stream = if nargs == 2 {
                    args[1].clone()
                } else {
                    "-".to_string()
                };
                sites.push((args[0].clone(), stream));
            }
        }
    }
    sites
}

/// One waiver comment: the rules it covers, its reason, and its own
/// 1-based line.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// Rule ids this waiver covers.
    pub rules: Vec<String>,
    /// The mandatory free-text justification.
    pub reason: String,
    /// 1-based line of the waiver comment itself.
    pub line: usize,
}

/// One file's raw/stripped lines, test region, and waiver table.
pub struct FileScan {
    /// Path relative to rust/src, '/'-separated.
    pub relpath: String,
    /// Raw source lines (waiver/SAFETY/doc detection scans these).
    pub raw: Vec<String>,
    /// [`strip_code`] of each raw line (rule patterns scan these).
    pub stripped: Vec<String>,
    /// 1-based line where the column-0 `#[cfg(test)]` region starts
    /// (past EOF when the file has none).
    pub test_start: usize,
    /// Covered line (the waiver's own + the next) -> waivers.
    pub waivers: BTreeMap<usize, Vec<Waiver>>,
    /// Every syntactically valid waiver, for unused-waiver reporting.
    pub waiver_sites: Vec<Waiver>,
    /// Malformed waiver comments: (line, message).
    pub waiver_errors: Vec<(usize, String)>,
}

impl FileScan {
    /// Scan `text` (the contents of `relpath`) into lines, the test
    /// region, and the waiver table.
    pub fn new(relpath: &str, text: &str) -> Self {
        let raw: Vec<String> =
            text.split('\n').map(str::to_string).collect();
        let stripped: Vec<String> =
            raw.iter().map(|l| strip_code(l)).collect();
        let mut test_start = raw.len() + 1;
        for (idx, line) in raw.iter().enumerate() {
            if line.starts_with("#[cfg(test)]") {
                test_start = idx + 1;
                break;
            }
        }
        let mut waivers: BTreeMap<usize, Vec<Waiver>> = BTreeMap::new();
        let mut waiver_sites = Vec::new();
        let mut waiver_errors = Vec::new();
        for (idx, line) in raw.iter().enumerate() {
            let Some(m) = line.find(WAIVER_MARK) else {
                continue;
            };
            let lineno = idx + 1;
            let Some(close_rel) = line[m..].find(')') else {
                waiver_errors.push((
                    lineno,
                    format!("unterminated {WAIVER_MARK}...)"),
                ));
                continue;
            };
            let close = m + close_rel;
            let rules: Vec<String> = line[m + WAIVER_MARK.len()..close]
                .split(',')
                .map(|r| r.trim().to_string())
                .collect();
            let bad: Vec<&str> = rules
                .iter()
                .filter(|r| !is_rule(r))
                .map(|r| r.as_str())
                .collect();
            if !bad.is_empty() {
                waiver_errors.push((
                    lineno,
                    format!("unknown rule id(s) in waiver: {}",
                            bad.join(",")),
                ));
                continue;
            }
            let reason = line[close + 1..].trim().to_string();
            if reason.is_empty() {
                waiver_errors.push((
                    lineno,
                    "audit:allow waiver needs a reason".to_string(),
                ));
                continue;
            }
            let w = Waiver { rules, reason, line: lineno };
            waiver_sites.push(w.clone());
            for covered in [lineno, lineno + 1] {
                waivers.entry(covered).or_default().push(w.clone());
            }
        }
        FileScan {
            relpath: relpath.to_string(),
            raw,
            stripped,
            test_start,
            waivers,
            waiver_sites,
            waiver_errors,
        }
    }

    /// Is this 1-based line inside the `#[cfg(test)]` region?
    pub fn in_test(&self, lineno: usize) -> bool {
        lineno >= self.test_start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        assert_eq!(strip_code("let x = 1; // HashMap"), "let x = 1; ");
        assert_eq!(strip_code("a /* unsafe */ b"), "a            b");
        let s = strip_code("let s = \"Instant::now\";");
        assert!(!s.contains("Instant"));
        assert!(s.starts_with("let s = "));
        let r = strip_code("let r = r#\"HashSet .unwrap()\"#;");
        assert!(!r.contains("HashSet"));
        // char literal vs lifetime: the quote literal is blanked, the
        // lifetime tick survives as a space without eating the line.
        let c = strip_code("let c = '\"'; let l: &'static str = \"x\";");
        assert!(c.contains("static"));
        assert!(!c.contains('"'));
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("use std::collections::HashMap;", "HashMap"));
        assert!(!has_token("let HashMapLike = 1;", "HashMap"));
        assert!(has_token("let r = rand::random();", "rand::"));
        assert!(has_token("x as u32", "as u32"));
        assert!(!has_token("x as u32x", "as u32"));
    }

    #[test]
    fn rng_sites_literal_only() {
        assert_eq!(rng_literal_sites("Rng::new_stream(7, 1)"),
                   vec![("7".to_string(), "1".to_string())]);
        assert_eq!(rng_literal_sites("Rng::new(42)"),
                   vec![("42".to_string(), "-".to_string())]);
        assert!(rng_literal_sites("Rng::new_stream(seed, i)").is_empty());
        assert!(rng_literal_sites("Rng::new(seed ^ 3)").is_empty());
    }

    #[test]
    fn test_region_starts_at_cfg_test() {
        let fs = FileScan::new("runtime/fx.rs",
                               "fn a() {}\n#[cfg(test)]\nmod t {}\n");
        assert!(!fs.in_test(1));
        assert!(fs.in_test(2));
        assert!(fs.in_test(3));
    }
}
