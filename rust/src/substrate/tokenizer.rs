//! Synthetic-vocab tokenizer (an offline substrate, DESIGN.md §4):
//! loads `artifacts/vocab.json` (authored by
//! `python/compile/corpus.py`) and detokenizes id streams for logs,
//! examples, and debugging.  Token ids are the wire format everywhere;
//! there is deliberately no encode path at serve time (prompts arrive
//! pre-tokenized in `prompts_{task}.json`, as in a real deployment where
//! tokenization happens at the API edge).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::json::Json;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab_size: usize,
    pub bos: i32,
    pub eos: i32,
    pub pad: i32,
    pub mask: i32,
    pub distinct_masks: Vec<i32>,
    tok_of: BTreeMap<i32, String>,
}

impl Tokenizer {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).context("parsing vocab.json")?;
        let mut tok_of = BTreeMap::new();
        if let Some(toks) = v.req("tokens")?.as_obj() {
            for (k, s) in toks {
                if let (Ok(id), Some(s)) = (k.parse::<i32>(), s.as_str()) {
                    tok_of.insert(id, s.to_string());
                }
            }
        }
        Ok(Tokenizer {
            vocab_size: v.usize_req("vocab_size")?,
            bos: v.usize_req("bos")? as i32,
            eos: v.usize_req("eos")? as i32,
            pad: v.usize_req("pad")? as i32,
            mask: v.usize_req("mask")? as i32,
            distinct_masks: v
                .req("distinct_masks")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_i64().map(|i| i as i32))
                .collect(),
            tok_of,
        })
    }

    /// In-memory tokenizer for the artifact-free reference backend:
    /// specials get readable names, plain ids render as `<id>`.
    pub fn synthetic(vocab_size: usize, bos: i32, eos: i32, pad: i32,
                     mask: i32, distinct_masks: Vec<i32>) -> Self {
        let mut tok_of = BTreeMap::new();
        tok_of.insert(bos, "<bos>".to_string());
        tok_of.insert(eos, "<eos>".to_string());
        tok_of.insert(pad, "<pad>".to_string());
        tok_of.insert(mask, "<mask>".to_string());
        for (j, &id) in distinct_masks.iter().enumerate() {
            tok_of.insert(id, format!("<mask_{j}>"));
        }
        Tokenizer {
            vocab_size,
            bos,
            eos,
            pad,
            mask,
            distinct_masks,
            tok_of,
        }
    }

    /// Human-readable rendering of a token-id stream.
    pub fn detok(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|id| {
                self.tok_of
                    .get(id)
                    .cloned()
                    .unwrap_or_else(|| format!("<{id}>"))
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn is_special(&self, id: i32) -> bool {
        id == self.bos
            || id == self.eos
            || id == self.pad
            || id == self.mask
            || self.distinct_masks.contains(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_vocab(dir: &Path) -> std::path::PathBuf {
        let p = dir.join("vocab.json");
        let mut f = std::fs::File::create(&p).unwrap();
        write!(
            f,
            r#"{{"vocab_size": 16, "bos": 0, "eos": 1, "pad": 2,
                "mask": 3, "distinct_masks": [4, 5],
                "tokens": {{"0": "<bos>", "1": "<eos>", "12": "def"}}}}"#
        )
        .unwrap();
        p
    }

    /// Regression (audit rule D1): vocab round-trips must not depend
    /// on source key order.  Two files carrying the same entries in
    /// scrambled order must yield byte-identical detok output AND a
    /// byte-identical Debug rendering — the latter iterates `tok_of`,
    /// which is exactly where HashMap's seeded order used to leak.
    #[test]
    fn vocab_order_stable() {
        let dir = std::env::temp_dir().join("pard_tok_order_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |name: &str, body: &str| {
            let p = dir.join(name);
            std::fs::write(&p, body).unwrap();
            p
        };
        let head = r#"{"vocab_size": 16, "bos": 0, "eos": 1, "pad": 2,
                       "mask": 3, "distinct_masks": [4, 5],"#;
        let fwd = mk(
            "fwd.json",
            &format!(
                r#"{head} "tokens": {{"0": "<bos>", "1": "<eos>",
                   "12": "def", "7": "ret", "9": "add"}}}}"#
            ),
        );
        let rev = mk(
            "rev.json",
            &format!(
                r#"{head} "tokens": {{"9": "add", "7": "ret",
                   "12": "def", "1": "<eos>", "0": "<bos>"}}}}"#
            ),
        );
        let a = Tokenizer::load(&fwd).unwrap();
        let b = Tokenizer::load(&rev).unwrap();
        let ids = [9, 7, 12, 0, 1, 99];
        assert_eq!(a.detok(&ids), b.detok(&ids));
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn load_and_detok() {
        let dir = std::env::temp_dir().join("pard_tok_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = fake_vocab(&dir);
        let t = Tokenizer::load(&p).unwrap();
        assert_eq!(t.vocab_size, 16);
        assert_eq!(t.mask, 3);
        assert_eq!(t.detok(&[0, 12, 99]), "<bos> def <99>");
        assert!(t.is_special(4));
        assert!(!t.is_special(12));
    }
}
