//! Minimal JSON parser + writer (an offline substrate, DESIGN.md §4).
//!
//! The offline build environment vendors only the `xla` crate tree, so the
//! artifact manifest / vocab / prompt files (all JSON, authored by
//! `python/compile/aot.py`) are parsed with this ~300-line recursive-descent
//! parser instead of serde.  It supports the full JSON grammar we emit:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name — manifest reads
    /// should fail loudly, not default silently.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_req(&self, key: &str) -> anyhow::Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json key `{key}` not a string"))?
            .to_string())
    }

    pub fn usize_req(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("json key `{key}` not a number"))
    }

    pub fn f64_req(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("json key `{key}` not a number"))
    }

    // -- writer -------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our files;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#)
            .unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""A""#).unwrap();
        assert_eq!(v.as_str(), Some("A"));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k": [1, -2.5, "s", null, true], "m": {"x": 3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn req_errors_name_key() {
        let v = Json::parse("{}").unwrap();
        let e = v.req("missing_key").unwrap_err();
        assert!(e.to_string().contains("missing_key"));
    }
}
