//! Substrates built from scratch for the offline environment: JSON,
//! RNG, tokenizer, prompt sets, workload traces, device cost model,
//! bench + property-test harnesses.  See DESIGN.md §4.

pub mod bench;
pub mod devices;
pub mod fault;
pub mod json;
pub mod prompts;
pub mod prop;
pub mod rng;
pub mod tokenizer;
pub mod workload;
