//! Deterministic fault injection for the serving stack (DESIGN.md
//! §10).
//!
//! A [`FaultPlan`] is a seeded, replayable schedule of injected
//! failures: draft-pass errors, target-pass errors, transient KV-pool
//! exhaustion, and host-pool worker panics.  Each configured
//! [`FaultSpec`] owns its own decorrelated rng stream
//! (`Rng::new_stream(seed, kind_id)`), and `begin_iteration` draws
//! exactly one Bernoulli sample per spec per serving iteration — so
//! the schedule is a pure function of (specs, iteration index),
//! independent of batch occupancy, timing, or which requests are in
//! flight.  Cloning the plan and replaying it is how tests compute
//! the exact expected fault schedule (`tests/fault_injection.rs`,
//! mirrored in python/refsim/hostsim.py).
//!
//! The plan lives on the serving layer's virtual clock: one
//! `begin_iteration` call per engine step, drawn *before* any engine
//! state mutates, so recovery paths (retry, degrade, skip) can be
//! bit-safe for every non-faulted row.

use anyhow::{bail, Result};

use crate::substrate::rng::Rng;

/// Transient target faults retry up to this many times before the
/// incident is declared persistent and the victim row is failed.
pub const MAX_TARGET_RETRIES: u64 = 2;

/// Which layer a fault strikes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Draft forward pass fails — the iteration degrades losslessly
    /// (greedy: K=0 AR+ commit; sampled: hold, see DESIGN.md §10).
    Draft,
    /// Target forward pass fails — bounded retries, then only the
    /// victim row is failed.
    Target,
    /// Transient KV-pool exhaustion — admission pauses one iteration.
    Pool,
    /// Host worker-pool task panic — caught, pool rebuilt once.
    Worker,
}

impl FaultKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "draft" => FaultKind::Draft,
            "target" => FaultKind::Target,
            "pool" => FaultKind::Pool,
            "worker" => FaultKind::Worker,
            _ => bail!(
                "unknown fault kind `{s}` (want draft|target|pool|\
                 worker)"
            ),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            FaultKind::Draft => "draft",
            FaultKind::Target => "target",
            FaultKind::Pool => "pool",
            FaultKind::Worker => "worker",
        }
    }

    /// Stable per-kind stream id — keeps multi-spec plans
    /// decorrelated even when every spec shares one seed.
    fn stream(self) -> u64 {
        match self {
            FaultKind::Draft => 1,
            FaultKind::Target => 2,
            FaultKind::Pool => 3,
            FaultKind::Worker => 4,
        }
    }
}

/// One `kind:rate:seed` clause of `--fault-spec`.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    pub kind: FaultKind,
    pub rate: f64,
    pub seed: u64,
}

impl FaultSpec {
    /// Parse a single `kind:rate:seed` clause.
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 {
            bail!(
                "bad fault spec `{s}` (want kind:rate:seed, e.g. \
                 draft:0.25:11)"
            );
        }
        let kind = FaultKind::parse(parts[0])?;
        let rate: f64 = match parts[1].parse() {
            Ok(r) => r,
            Err(_) => bail!("bad fault rate `{}` in `{s}`", parts[1]),
        };
        if !(0.0..=1.0).contains(&rate) {
            bail!("fault rate {rate} out of [0, 1] in `{s}`");
        }
        let seed: u64 = match parts[2].parse() {
            Ok(r) => r,
            Err(_) => bail!("bad fault seed `{}` in `{s}`", parts[2]),
        };
        Ok(FaultSpec { kind, rate, seed })
    }
}

/// A fired target fault: how many consecutive attempts fail this
/// iteration, and which live row is the victim if the incident turns
/// persistent (`fails > MAX_TARGET_RETRIES`).  `victim` indexes the
/// live rows modulo their count — admission order, so the choice is
/// batch-layout independent.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TargetFault {
    pub fails: u64,
    pub victim: u64,
}

/// Everything the plan injects into one serving iteration.  Drawn
/// before the iteration touches any engine state.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultSet {
    /// Number of faults fired this iteration (feeds
    /// `Metrics::faults_injected`).
    pub injected: u64,
    pub draft: bool,
    pub target: Option<TargetFault>,
    pub pool: bool,
    pub worker: bool,
}

impl FaultSet {
    pub fn any(&self) -> bool {
        self.injected > 0
    }
}

/// Seeded, replayable fault schedule.  `Clone` is load-bearing:
/// tests clone the plan before handing it to the serving loop, then
/// replay the clone to compute the exact expected schedule.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    specs: Vec<(FaultSpec, Rng)>,
    /// One-shot scripted faults: (kind, iteration index).
    scripted: Vec<(FaultKind, u64)>,
    iteration: u64,
    injected: u64,
}

impl FaultPlan {
    pub fn new(specs: Vec<FaultSpec>) -> Self {
        let specs = specs
            .into_iter()
            .map(|s| {
                let rng = Rng::new_stream(s.seed, s.kind.stream());
                (s, rng)
            })
            .collect();
        FaultPlan { specs, scripted: Vec::new(), iteration: 0,
                    injected: 0 }
    }

    /// Parse a comma-separated `kind:rate:seed[,kind:rate:seed...]`
    /// list (the `--fault-spec` argument).
    pub fn parse(s: &str) -> Result<Self> {
        let mut specs = Vec::new();
        for clause in s.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            specs.push(FaultSpec::parse(clause)?);
        }
        if specs.is_empty() {
            bail!("empty --fault-spec `{s}`");
        }
        Ok(FaultPlan::new(specs))
    }

    /// Script a one-shot fault at an exact iteration index (0-based).
    /// Scripted target faults are persistent (`MAX_TARGET_RETRIES +
    /// 1` failed attempts) with `victim = iteration`.
    pub fn script(&mut self, kind: FaultKind, iteration: u64) {
        self.scripted.push((kind, iteration));
    }

    /// Draw the fault set for the next iteration.  Exactly one
    /// Bernoulli draw per spec regardless of outcome (plus the
    /// fails/victim draws when a target spec fires), so the schedule
    /// replays bit-for-bit.
    pub fn begin_iteration(&mut self) -> FaultSet {
        let mut set = FaultSet::default();
        for (spec, rng) in &mut self.specs {
            if !rng.chance(spec.rate) {
                continue;
            }
            set.injected += 1;
            match spec.kind {
                FaultKind::Draft => set.draft = true,
                FaultKind::Target => {
                    let fails = 1 + rng.below(3) as u64;
                    let victim = rng.next_u64();
                    // First firing wins if two target specs collide.
                    set.target.get_or_insert(TargetFault {
                        fails,
                        victim,
                    });
                }
                FaultKind::Pool => set.pool = true,
                FaultKind::Worker => set.worker = true,
            }
        }
        let it = self.iteration;
        for (kind, when) in &self.scripted {
            if *when != it {
                continue;
            }
            set.injected += 1;
            match kind {
                FaultKind::Draft => set.draft = true,
                FaultKind::Target => {
                    set.target = Some(TargetFault {
                        fails: MAX_TARGET_RETRIES + 1,
                        victim: it,
                    });
                }
                FaultKind::Pool => set.pool = true,
                FaultKind::Worker => set.worker = true,
            }
        }
        self.iteration += 1;
        self.injected += set.injected;
        set
    }

    /// Total faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Iterations drawn so far.
    pub fn iteration(&self) -> u64 {
        self.iteration
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_single_and_list() {
        let p = FaultPlan::parse("draft:0.25:11").unwrap();
        assert_eq!(p.specs.len(), 1);
        let p =
            FaultPlan::parse("draft:0.25:11,target:0.1:13,pool:0.2:17")
                .unwrap();
        assert_eq!(p.specs.len(), 3);
        assert_eq!(p.specs[1].0.kind, FaultKind::Target);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("draft:0.25").is_err());
        assert!(FaultPlan::parse("gamma:0.25:1").is_err());
        assert!(FaultPlan::parse("draft:1.5:1").is_err());
        assert!(FaultPlan::parse("draft:x:1").is_err());
        assert!(FaultPlan::parse("draft:0.1:y").is_err());
    }

    #[test]
    fn schedule_replays_bit_for_bit() {
        let mut a = FaultPlan::parse(
            "draft:0.3:7,target:0.2:9,pool:0.1:5,worker:0.05:3",
        )
        .unwrap();
        let mut b = a.clone();
        for _ in 0..256 {
            let fa = a.begin_iteration();
            let fb = b.begin_iteration();
            assert_eq!(fa.draft, fb.draft);
            assert_eq!(fa.target, fb.target);
            assert_eq!(fa.pool, fb.pool);
            assert_eq!(fa.worker, fb.worker);
            assert_eq!(fa.injected, fb.injected);
        }
        assert_eq!(a.injected(), b.injected());
        assert!(a.injected() > 0, "a 256-iteration storm must fire");
    }

    #[test]
    fn rate_zero_never_fires_rate_one_always_fires() {
        let mut p =
            FaultPlan::parse("draft:0:1,pool:1:2").unwrap();
        for _ in 0..64 {
            let f = p.begin_iteration();
            assert!(!f.draft);
            assert!(f.pool);
            assert_eq!(f.injected, 1);
        }
        assert_eq!(p.injected(), 64);
    }

    #[test]
    fn scripted_one_shots_fire_exactly_once() {
        let mut p = FaultPlan::new(vec![]);
        p.script(FaultKind::Worker, 3);
        p.script(FaultKind::Target, 5);
        for it in 0..8u64 {
            let f = p.begin_iteration();
            assert_eq!(f.worker, it == 3, "iteration {it}");
            if it == 5 {
                let t = f.target.unwrap();
                assert_eq!(t.fails, MAX_TARGET_RETRIES + 1,
                           "scripted target faults are persistent");
                assert_eq!(t.victim, 5);
            } else {
                assert!(f.target.is_none());
            }
        }
        assert_eq!(p.injected(), 2);
        assert_eq!(p.iteration(), 8);
    }

    #[test]
    fn target_draws_fails_and_victim_only_when_fired() {
        // A rate-1 target spec fires every iteration with bounded
        // fails; transient vs persistent is decided by the draw.
        let mut p = FaultPlan::parse("target:1:42").unwrap();
        for _ in 0..64 {
            let t = p.begin_iteration().target.unwrap();
            assert!((1..=3).contains(&t.fails));
        }
    }
}
