//! Deterministic RNG (splitmix64 + xoshiro256**) — no `rand` crate
//! offline (an offline substrate, DESIGN.md §4).
//!
//! Used by the workload generator, sampling, and the in-repo property-test
//! harness.  Determinism matters: benchmark tables must be reproducible
//! run-to-run.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        Rng {
            s: [
                splitmix64(&mut x),
                splitmix64(&mut x),
                splitmix64(&mut x),
                splitmix64(&mut x),
            ],
        }
    }

    /// Deterministic substream `stream` of `seed`.  Both words pass
    /// through splitmix64 before seeding the xoshiro state, so distinct
    /// (seed, stream) pairs yield decorrelated generators even for
    /// adjacent stream ids.  The coordinator keys one stream per
    /// admitted sequence (DESIGN.md §6): `new_stream(sample_seed,
    /// admission_ordinal)` makes sampled output invariant to batch size
    /// and slot assignment.
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        let mut x = seed;
        let base = splitmix64(&mut x);
        let mut y =
            base ^ stream.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Rng {
            s: [
                splitmix64(&mut y),
                splitmix64(&mut y),
                splitmix64(&mut y),
                splitmix64(&mut y),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (used for reference-backend
    /// weight init; determinism inherits from the integer stream).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64(); // (0, 1] — keeps ln() finite
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential inter-arrival sample with the given rate (per second).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn streams_deterministic_and_distinct() {
        let mut a = Rng::new_stream(9, 3);
        let mut b = Rng::new_stream(9, 3);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // adjacent streams and adjacent seeds both decorrelate
        assert_ne!(
            Rng::new_stream(9, 0).next_u64(),
            Rng::new_stream(9, 1).next_u64()
        );
        assert_ne!(
            Rng::new_stream(8, 0).next_u64(),
            Rng::new_stream(9, 0).next_u64()
        );
    }

    #[test]
    fn f64_in_unit() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(8);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_positive() {
        let mut r = Rng::new(6);
        let mean: f64 =
            (0..2000).map(|_| r.exp(2.0)).sum::<f64>() / 2000.0;
        assert!((mean - 0.5).abs() < 0.1, "mean {mean}");
    }
}
