//! Eval prompt sets: `artifacts/prompts_{task}.json` — the HumanEval /
//! GSM8K / MATH500 stand-ins (held-out grammar samples, see DESIGN.md §3).

use std::path::Path;

use anyhow::{Context, Result};

use super::json::Json;

#[derive(Debug, Clone)]
pub struct Prompt {
    pub task: String,
    pub prompt: Vec<i32>,
    /// Grammar ground-truth continuation (used to sanity-check output
    /// quality and to report per-task agreement; generation does NOT see
    /// this).
    pub reference: Vec<i32>,
}

#[derive(Debug, Clone)]
pub struct PromptSet {
    pub task: String,
    pub prompts: Vec<Prompt>,
}

fn ids(v: &Json) -> Vec<i32> {
    v.as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|x| x.as_i64().map(|i| i as i32))
        .collect()
}

impl PromptSet {
    pub fn load(path: &Path, task: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).context("parsing prompts json")?;
        let rows = v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("prompts json not an array"))?;
        let prompts = rows
            .iter()
            .map(|r| -> Result<Prompt> {
                Ok(Prompt {
                    task: r.str_req("task")?,
                    prompt: ids(r.req("prompt")?),
                    reference: ids(r.req("reference")?),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!prompts.is_empty(), "empty prompt set {task}");
        Ok(PromptSet { task: task.to_string(), prompts })
    }

    pub fn len(&self) -> usize {
        self.prompts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prompts.is_empty()
    }

    /// First `n` prompts (deterministic eval subsets for fast benches).
    pub fn take(&self, n: usize) -> Vec<Prompt> {
        self.prompts.iter().take(n).cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn load_set() {
        let dir = std::env::temp_dir().join("pard_prompt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("prompts_code.json");
        let mut f = std::fs::File::create(&p).unwrap();
        write!(
            f,
            r#"[{{"task": "code", "prompt": [0, 12, 13],
                 "reference": [14, 1]}}]"#
        )
        .unwrap();
        let s = PromptSet::load(&p, "code").unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.prompts[0].prompt, vec![0, 12, 13]);
        assert_eq!(s.prompts[0].reference, vec![14, 1]);
    }
}
