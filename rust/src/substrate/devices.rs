//! Device cost model: roofline projection of decode-phase performance
//! (feeds the Tables 6/7 runners of DESIGN.md §5).
//!
//! The paper reports absolute TPS and draft-phase bandwidth on A100-40GB
//! (Tables 1-6) and MI250X (Table 7).  We execute on PJRT-CPU, so absolute
//! numbers come from this analytical model instead: decoding small batches
//! is memory-bound (paper §2.1), so a forward pass costs
//! `max(bytes_touched / hbm_bw, flops / peak_flops) + launch_overhead`.
//! Speedup *ratios* combine these per-pass costs with the acceptance
//! statistics measured by the real rust/PJRT pipeline — the same
//! methodology the paper's Eq. 3/4 analysis uses.

/// Hardware profile (published public specs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Effective memory bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Peak dense bf16 throughput, flops/s.
    pub peak_flops: f64,
    /// Per-kernel-launch / framework overhead per forward pass, seconds.
    pub launch_overhead: f64,
}

/// A100-40GB SXM: 1.555 TB/s, 312 TFLOPS bf16.
pub const A100_40GB: DeviceProfile = DeviceProfile {
    name: "A100-40GB",
    hbm_bw: 1.555e12,
    peak_flops: 312e12,
    launch_overhead: 35e-6,
};

/// MI250X (single GCD): 1.6 TB/s, 191.5 TFLOPS bf16 per GCD.
/// Lower achievable fraction in practice — the paper's Table 7 speedups
/// are uniformly below the A100 ones; the higher overhead models the
/// less-tuned software stack.
pub const MI250X: DeviceProfile = DeviceProfile {
    name: "MI250X",
    hbm_bw: 1.6e12,
    peak_flops: 191.5e12,
    launch_overhead: 60e-6,
};

/// Model footprint description for the cost model.
#[derive(Debug, Clone, Copy)]
pub struct ModelCost {
    /// Parameter count.
    pub n_params: f64,
    /// Bytes per parameter (2 for bf16 — the paper's serving dtype).
    pub bytes_per_param: f64,
    /// KV-cache bytes read per forward pass at current context length.
    pub kv_bytes: f64,
}

impl ModelCost {
    pub fn new(n_params: f64, kv_bytes: f64) -> Self {
        ModelCost { n_params, bytes_per_param: 2.0, kv_bytes }
    }

    pub fn weight_bytes(&self) -> f64 {
        self.n_params * self.bytes_per_param
    }
}

impl DeviceProfile {
    /// Cost of one forward pass over `tokens` positions, `batch` rows.
    /// Weights are read once regardless of tokens (the memory-bound
    /// regime); flops scale with tokens*batch.
    pub fn fwd_seconds(&self, m: &ModelCost, tokens: usize,
                       batch: usize) -> f64 {
        let bytes = m.weight_bytes() + m.kv_bytes * batch as f64;
        let flops = 2.0 * m.n_params * tokens as f64 * batch as f64;
        (bytes / self.hbm_bw).max(flops / self.peak_flops)
            + self.launch_overhead
    }

    /// Decode-phase TPS of plain cached autoregression (the AR+ baseline).
    pub fn ar_tps(&self, target: &ModelCost, batch: usize) -> f64 {
        batch as f64 / self.fwd_seconds(target, 1, batch)
    }

    /// TPS of a draft-then-verify method.
    ///
    /// * `draft_passes`: forward passes of the draft per iteration
    ///   (K for VSD/EAGLE, 1 for PARD — paper Eq. 3 vs Eq. 4).
    /// * `draft_tokens_per_pass`: positions per draft pass.
    /// * `tokens_per_iter`: measured mean accepted+1 per iteration.
    pub fn sd_tps(&self, target: &ModelCost, draft: &ModelCost, k: usize,
                  draft_passes: usize, draft_tokens_per_pass: usize,
                  tokens_per_iter: f64, batch: usize) -> f64 {
        let t_draft = draft_passes as f64
            * self.fwd_seconds(draft, draft_tokens_per_pass, batch);
        let t_verify = self.fwd_seconds(target, k + 1, batch);
        batch as f64 * tokens_per_iter / (t_draft + t_verify)
    }

    /// Draft-phase bytes moved per iteration (Table 6): weights are
    /// re-read on every pass, so AR drafting scales with k while PARD
    /// reads once.
    pub fn draft_bandwidth_bytes(&self, draft: &ModelCost,
                                 draft_passes: usize) -> f64 {
        draft_passes as f64 * (draft.weight_bytes() + draft.kv_bytes)
    }
}

/// Paper-scale reference models (for Tables 6/7 absolute columns):
/// bf16 params, kv term folded into weight traffic for simplicity.
pub fn paper_model(n_params_billion: f64) -> ModelCost {
    ModelCost::new(n_params_billion * 1e9, 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_is_memory_bound() {
        let m = paper_model(8.0);
        // at batch 1 the bandwidth term must dominate the flop term
        let t = A100_40GB.fwd_seconds(&m, 1, 1);
        let bw_term = m.weight_bytes() / A100_40GB.hbm_bw;
        assert!((t - bw_term - A100_40GB.launch_overhead).abs() < 1e-9);
    }

    #[test]
    fn ar_tps_order_of_magnitude() {
        // LLaMA3.1-8B on A100: paper AR+ ~77 tok/s; the pure roofline
        // bound is higher (~90-95); same order, and the ratio analysis
        // only uses relative costs.
        let tps = A100_40GB.ar_tps(&paper_model(8.0), 1);
        assert!(tps > 50.0 && tps < 130.0, "tps {tps}");
    }

    #[test]
    fn pard_beats_vsd_at_equal_acceptance() {
        let target = paper_model(8.0);
        let draft = paper_model(1.0);
        let k = 8;
        let vsd = A100_40GB.sd_tps(&target, &draft, k, k, 1, 4.0, 1);
        let pard = A100_40GB.sd_tps(&target, &draft, k, 1, 2 * k, 4.0, 1);
        assert!(pard > 1.4 * vsd, "pard {pard} vsd {vsd}");
    }

    #[test]
    fn table6_shape_pard_flat_eagle_linear() {
        let d = paper_model(1.0);
        let e4 = A100_40GB.draft_bandwidth_bytes(&d, 4);
        let e8 = A100_40GB.draft_bandwidth_bytes(&d, 8);
        let p4 = A100_40GB.draft_bandwidth_bytes(&d, 1);
        let p8 = A100_40GB.draft_bandwidth_bytes(&d, 1);
        assert!((e8 / e4 - 2.0).abs() < 1e-9);
        assert_eq!(p4, p8);
    }

    #[test]
    fn batch_shifts_compute_bound() {
        // Table 4 mechanism: at large batch the flop term overtakes the
        // bandwidth term, shrinking speculative gains.
        let m = paper_model(8.0);
        let t1 = A100_40GB.fwd_seconds(&m, 9, 1);
        // small batches ride the bandwidth roofline for free…
        let t4 = A100_40GB.fwd_seconds(&m, 9, 4);
        assert!((t4 - t1).abs() < 1e-9);
        // …until the flop term takes over and verify scales with batch
        let t64 = A100_40GB.fwd_seconds(&m, 9, 64);
        assert!(t64 > 2.0 * t1, "crossover must appear at large batch");
    }

    #[test]
    fn mi250x_slower_than_a100() {
        let m = paper_model(8.0);
        assert!(MI250X.ar_tps(&m, 1) < A100_40GB.ar_tps(&m, 1) * 1.2);
        assert!(MI250X.fwd_seconds(&m, 1, 1) > 0.0);
    }
}
