//! Request workload generation: arrival traces over the eval prompt
//! sets (an offline substrate, DESIGN.md §4).
//!
//! The serving experiments (Tables 3/4) drive the coordinator with a
//! request stream; this module synthesizes Poisson or closed-loop traces
//! deterministically from a seed.

use super::prompts::Prompt;
use super::rng::Rng;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset in seconds from trace start.
    pub arrival_s: f64,
    pub prompt: Vec<i32>,
    pub reference: Vec<i32>,
    pub task: String,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<Request>,
}

#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// All requests available at t=0 (offline throughput measurement —
    /// what the paper's TPS tables report).
    Closed,
    /// Poisson arrivals at `rate` requests/second (online serving).
    Poisson { rate: f64 },
}

pub fn build_trace(prompts: &[Prompt], n: usize, arrival: Arrival,
                   max_new: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let p = &prompts[i % prompts.len()];
        if let Arrival::Poisson { rate } = arrival {
            t += rng.exp(rate);
        }
        requests.push(Request {
            id: i as u64,
            arrival_s: t,
            prompt: p.prompt.clone(),
            reference: p.reference.clone(),
            task: p.task.clone(),
            max_new,
        });
    }
    Trace { requests }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompts() -> Vec<Prompt> {
        (0..3)
            .map(|i| Prompt {
                task: "code".into(),
                prompt: vec![0, 12 + i],
                reference: vec![20, 1],
            })
            .collect()
    }

    #[test]
    fn closed_trace_all_at_zero() {
        let t = build_trace(&prompts(), 7, Arrival::Closed, 32, 1);
        assert_eq!(t.requests.len(), 7);
        assert!(t.requests.iter().all(|r| r.arrival_s == 0.0));
        // round-robins over prompts
        assert_eq!(t.requests[3].prompt, t.requests[0].prompt);
    }

    #[test]
    fn poisson_monotone_arrivals() {
        let t = build_trace(&prompts(), 20,
                            Arrival::Poisson { rate: 10.0 }, 32, 2);
        for w in t.requests.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        assert!(t.requests.last().unwrap().arrival_s > 0.0);
    }

    #[test]
    fn deterministic() {
        let a = build_trace(&prompts(), 10,
                            Arrival::Poisson { rate: 5.0 }, 16, 9);
        let b = build_trace(&prompts(), 10,
                            Arrival::Poisson { rate: 5.0 }, 16, 9);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }
}
