//! Request workload generation: arrival traces over the eval prompt
//! sets (an offline substrate, DESIGN.md §4).
//!
//! The serving experiments (Tables 3/4) drive the coordinator with a
//! request stream; this module synthesizes Poisson or closed-loop traces
//! deterministically from a seed.

use super::prompts::Prompt;
use super::rng::Rng;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset in seconds from trace start.
    pub arrival_s: f64,
    pub prompt: Vec<i32>,
    pub reference: Vec<i32>,
    pub task: String,
    pub max_new: usize,
    /// Optional completion deadline, ABSOLUTE seconds on the serving
    /// clock (same scale as `arrival_s`).  Once the clock passes it the
    /// batcher drops the request — queued or in flight — releases its
    /// KV blocks, and reports a typed `DeadlineExceeded` outcome
    /// (DESIGN.md §10).  `None` = no deadline.
    pub deadline_s: Option<f64>,
}

impl Trace {
    /// Stamp every request with `arrival + budget` as its deadline.
    pub fn with_deadline_budget(mut self, budget_s: f64) -> Trace {
        for r in &mut self.requests {
            r.deadline_s = Some(r.arrival_s + budget_s);
        }
        self
    }
}

#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<Request>,
}

#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// All requests available at t=0 (offline throughput measurement —
    /// what the paper's TPS tables report).
    Closed,
    /// Poisson arrivals at `rate` requests/second (online serving).
    Poisson { rate: f64 },
}

pub fn build_trace(prompts: &[Prompt], n: usize, arrival: Arrival,
                   max_new: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let p = &prompts[i % prompts.len()];
        if let Arrival::Poisson { rate } = arrival {
            t += rng.exp(rate);
        }
        requests.push(Request {
            id: i as u64,
            arrival_s: t,
            prompt: p.prompt.clone(),
            reference: p.reference.clone(),
            task: p.task.clone(),
            max_new,
            deadline_s: None,
        });
    }
    Trace { requests }
}

/// [`build_trace`] over a shared-system-prompt workload (`--shared-prefix`):
/// `n_prefixes` distinct synthetic system prompts of `prefix_len`
/// tokens are generated once, and request `i` carries
/// `prefix[i % n_prefixes] ++ tail of prompts[i % len]` — the
/// production shape the prefix cache (DESIGN.md §7) exists for.
/// Prefix tokens are drawn from the alphabet the base prompts already
/// use (skipping each prompt's leading BOS), so every request stays a
/// valid model input; the whole trace is a pure function of `seed`.
pub fn build_shared_prefix_trace(prompts: &[Prompt], n: usize,
                                 n_prefixes: usize, prefix_len: usize,
                                 arrival: Arrival, max_new: usize,
                                 seed: u64) -> Trace {
    assert!(n_prefixes >= 1 && prefix_len >= 1,
            "shared-prefix traces need at least one prefix token");
    let mut rng = Rng::new(seed ^ 0x5348_5052_4546); // "SHPREF"
    let bos = prompts[0].prompt[0];
    let alphabet: Vec<i32> = prompts
        .iter()
        .flat_map(|p| p.prompt[1..].iter().copied())
        .collect();
    let prefixes: Vec<Vec<i32>> = (0..n_prefixes)
        .map(|_| {
            let mut v = Vec::with_capacity(prefix_len);
            v.push(bos);
            while v.len() < prefix_len {
                v.push(alphabet[rng.below(alphabet.len())]);
            }
            v
        })
        .collect();
    let mut t = 0.0f64;
    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let p = &prompts[i % prompts.len()];
        if let Arrival::Poisson { rate } = arrival {
            t += rng.exp(rate);
        }
        let mut prompt = prefixes[i % n_prefixes].clone();
        prompt.extend_from_slice(&p.prompt[1..]);
        requests.push(Request {
            id: i as u64,
            arrival_s: t,
            prompt,
            reference: p.reference.clone(),
            task: p.task.clone(),
            max_new,
            deadline_s: None,
        });
    }
    Trace { requests }
}

/// [`build_trace`] over a mixed easy/hard workload for the adaptive
/// speculation policy experiments (DESIGN.md §9): even requests are
/// "easy" (BOS followed by one token repeated — a maximally
/// predictable continuation, where a draft accepts nearly everything
/// and big K pays), odd requests are "hard" (BOS followed by a cycle
/// of pairwise-adjacent-distinct tokens — where drafts miss and big K
/// burns verify columns).  A fixed K is wrong for one half or the
/// other; a per-sequence adaptive K can be right for both, which is
/// exactly the contrast the strict-win gate measures.  Tokens are
/// drawn from the alphabet the base prompts already use, so every
/// request stays a valid model input; the trace is a pure function of
/// `seed`.
pub fn build_mixed_trace(prompts: &[Prompt], n: usize, arrival: Arrival,
                         max_new: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0x4d49_5845_44); // "MIXED"
    let bos = prompts[0].prompt[0];
    let alphabet: Vec<i32> = prompts
        .iter()
        .flat_map(|p| p.prompt[1..].iter().copied())
        .collect();
    let mut distinct = alphabet.clone();
    distinct.sort_unstable();
    distinct.dedup();
    assert!(distinct.len() >= 2,
            "mixed traces need at least two distinct prompt tokens");
    let mut t = 0.0f64;
    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        if let Arrival::Poisson { rate } = arrival {
            t += rng.exp(rate);
        }
        let len = 4 + rng.below(6); // prompt body of 4..=9 tokens
        let mut prompt = Vec::with_capacity(len + 1);
        prompt.push(bos);
        let task = if i % 2 == 0 {
            // easy: one token, repeated
            let tok = alphabet[rng.below(alphabet.len())];
            prompt.extend(std::iter::repeat(tok).take(len));
            "easy"
        } else {
            // hard: cycle through the distinct alphabet so adjacent
            // tokens always differ
            let start = rng.below(distinct.len());
            prompt.extend(
                (0..len).map(|j| distinct[(start + j) % distinct.len()]));
            "hard"
        };
        requests.push(Request {
            id: i as u64,
            arrival_s: t,
            prompt,
            reference: Vec::new(),
            task: task.to_string(),
            max_new,
            deadline_s: None,
        });
    }
    Trace { requests }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompts() -> Vec<Prompt> {
        (0..3)
            .map(|i| Prompt {
                task: "code".into(),
                prompt: vec![0, 12 + i],
                reference: vec![20, 1],
            })
            .collect()
    }

    #[test]
    fn closed_trace_all_at_zero() {
        let t = build_trace(&prompts(), 7, Arrival::Closed, 32, 1);
        assert_eq!(t.requests.len(), 7);
        assert!(t.requests.iter().all(|r| r.arrival_s == 0.0));
        // round-robins over prompts
        assert_eq!(t.requests[3].prompt, t.requests[0].prompt);
    }

    #[test]
    fn poisson_monotone_arrivals() {
        let t = build_trace(&prompts(), 20,
                            Arrival::Poisson { rate: 10.0 }, 32, 2);
        for w in t.requests.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        assert!(t.requests.last().unwrap().arrival_s > 0.0);
    }

    #[test]
    fn shared_prefix_trace_shares_block_aligned_heads() {
        let t = build_shared_prefix_trace(&prompts(), 6, 2, 32,
                                          Arrival::Closed, 8, 4);
        assert_eq!(t.requests.len(), 6);
        for r in &t.requests {
            assert_eq!(r.prompt[0], 0, "prefix keeps the BOS head");
            assert!(r.prompt.len() > 32, "tail must follow the prefix");
        }
        // requests 0 and 2 share prefix 0; 1 and 3 share prefix 1
        assert_eq!(t.requests[0].prompt[..32], t.requests[2].prompt[..32]);
        assert_eq!(t.requests[1].prompt[..32], t.requests[3].prompt[..32]);
        assert_ne!(t.requests[0].prompt[..32], t.requests[1].prompt[..32]);
        // tails still round-robin over the base prompts
        assert_eq!(t.requests[0].prompt[32..],
                   t.requests[3].prompt[32..]);
        // deterministic in the seed
        let u = build_shared_prefix_trace(&prompts(), 6, 2, 32,
                                          Arrival::Closed, 8, 4);
        for (a, b) in t.requests.iter().zip(&u.requests) {
            assert_eq!(a.prompt, b.prompt);
        }
    }

    #[test]
    fn mixed_trace_alternates_easy_and_hard() {
        let t = build_mixed_trace(&prompts(), 8, Arrival::Closed, 16, 3);
        assert_eq!(t.requests.len(), 8);
        for (i, r) in t.requests.iter().enumerate() {
            assert_eq!(r.prompt[0], 0, "mixed trace keeps the BOS head");
            assert!(r.prompt.len() >= 5 && r.prompt.len() <= 10);
            let body = &r.prompt[1..];
            if i % 2 == 0 {
                assert_eq!(r.task, "easy");
                assert!(body.windows(2).all(|w| w[0] == w[1]));
            } else {
                assert_eq!(r.task, "hard");
                assert!(body.windows(2).all(|w| w[0] != w[1]));
            }
        }
        // deterministic in the seed
        let u = build_mixed_trace(&prompts(), 8, Arrival::Closed, 16, 3);
        for (a, b) in t.requests.iter().zip(&u.requests) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.task, b.task);
        }
        // a different seed moves the bodies
        let v = build_mixed_trace(&prompts(), 8, Arrival::Closed, 16, 4);
        assert!(t.requests.iter().zip(&v.requests)
                    .any(|(a, b)| a.prompt != b.prompt));
    }

    #[test]
    fn deterministic() {
        let a = build_trace(&prompts(), 10,
                            Arrival::Poisson { rate: 5.0 }, 16, 9);
        let b = build_trace(&prompts(), 10,
                            Arrival::Poisson { rate: 5.0 }, 16, 9);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }
}
