//! In-repo micro-benchmark harness (criterion is not vendored offline).
//!
//! `cargo bench` targets use `harness = false` main functions built on
//! this: warmup, fixed-duration sampling, and robust summary statistics
//! (median + MAD), printed in a stable grep-friendly format that the
//! EXPERIMENTS.md tables quote directly.  The harness is
//! backend-agnostic — benches time whatever closure they are handed, so
//! the same target runs against PJRT artifacts, the scalar reference
//! oracle, or the fast host backend (DESIGN.md §8); stats also export
//! as JSON ([`BenchStats::to_json`]) for machine-read baselines like
//! `BENCH_hotpath.json`.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use super::json::Json;

/// Crate-wide wall-clock chokepoint (audit rule D2, DESIGN.md §11):
/// every `Instant::now()` outside the whitelisted timing modules
/// (`coordinator/metrics.rs`, this file) routes through here, so the
/// static audit can prove virtual-clock and determinism paths never
/// read wall time except to *record* durations into metrics — never
/// to decide a token.
#[inline]
pub fn stopwatch() -> Instant {
    Instant::now()
}

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub mad_s: f64,
}

impl BenchStats {
    /// Stable JSON form (seconds, like the struct fields) for
    /// machine-read perf baselines.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("samples".to_string(), Json::Num(self.samples as f64));
        m.insert("median_s".to_string(), Json::Num(self.median_s));
        m.insert("mean_s".to_string(), Json::Num(self.mean_s));
        m.insert("min_s".to_string(), Json::Num(self.min_s));
        m.insert("max_s".to_string(), Json::Num(self.max_s));
        m.insert("mad_s".to_string(), Json::Num(self.mad_s));
        Json::Obj(m)
    }

    pub fn print(&self) {
        println!(
            "bench {name:<40} median {median:>10.3}ms  mean {mean:>10.3}ms  \
             min {min:>10.3}ms  max {max:>10.3}ms  n={n}",
            name = self.name,
            median = self.median_s * 1e3,
            mean = self.mean_s * 1e3,
            min = self.min_s * 1e3,
            max = self.max_s * 1e3,
            n = self.samples
        );
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_samples: 200,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            max_samples: 50,
        }
    }

    /// Benchmark `f`, which performs one iteration per call and returns a
    /// value kept alive to prevent dead-code elimination.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut times = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.measure && times.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        stats(name, &mut times)
    }
}

fn stats(name: &str, times: &mut [f64]) -> BenchStats {
    assert!(!times.is_empty());
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let mut dev: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStats {
        name: name.to_string(),
        samples: times.len(),
        median_s: median,
        mean_s: mean,
        min_s: times[0],
        max_s: *times.last().unwrap(),
        mad_s: dev[dev.len() / 2],
    }
}

/// Print a markdown table row list with a header — the standard output
/// format for the table-reproduction benches.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        println!("\n## {}\n", self.title);
        let widths: Vec<usize> = self
            .header
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain(std::iter::once(h.len()))
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s
        };
        println!("{}", fmt_row(&self.header));
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", fmt_row(&sep));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            max_samples: 10,
        };
        let s = b.run("noop", || 1 + 1);
        assert!(s.samples >= 1 && s.samples <= 10);
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
        let j = s.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("noop"));
        assert!(j.get("median_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn table_builds() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
        t.print();
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
