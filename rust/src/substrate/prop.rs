//! Tiny property-testing harness (proptest is not vendored offline;
//! an offline substrate, DESIGN.md §4).
//!
//! Coordinator invariants (KV-slot manager, acceptance, batcher) are
//! checked over many seeded random cases with first-failure reporting.
//! No shrinking — cases print their seed so failures replay exactly.

use super::rng::Rng;

pub struct Cases {
    pub n: usize,
    pub seed: u64,
}

impl Default for Cases {
    fn default() -> Self {
        Cases { n: 256, seed: 0xC0FFEE }
    }
}

impl Cases {
    pub fn new(n: usize) -> Self {
        Cases { n, seed: 0xC0FFEE }
    }

    /// Run `prop` over `n` independently seeded RNGs; panic with the case
    /// seed on the first failure.
    pub fn check(&self, name: &str, mut prop: impl FnMut(&mut Rng)) {
        for i in 0..self.n {
            let case_seed = self.seed ^ (i as u64).wrapping_mul(0x9E3779B9);
            let mut rng = Rng::new(case_seed);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || prop(&mut rng),
            ));
            if let Err(e) = r {
                eprintln!(
                    "property `{name}` failed on case {i} \
                     (seed {case_seed:#x})"
                );
                std::panic::resume_unwind(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        Cases::new(64).check("sum-commutes", |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic]
    fn reports_bad_property() {
        Cases::new(64).check("always-small", |rng| {
            assert!(rng.below(100) < 50);
        });
    }
}
