//! Typed request lifecycle demo (DESIGN.md §10): serve normal traffic
//! through the engine-thread server on the artifact-free reference
//! backend, alongside a cancelled request and one whose deadline has
//! already passed — every caller gets exactly one typed `GenOutcome`.
//!
//! Run with: `cargo run --example serve_trace`

use std::time::Duration;

use anyhow::Result;
use pard::coordinator::engines::{EngineConfig, EngineKind};
use pard::coordinator::policy::PolicyCfg;
use pard::runtime::RuntimeSpec;
use pard::server::{GenOutcome, GenRequest, Server};
use pard::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::reference(7);
    let prompt = rt.prompts("code")?.prompts[0].prompt.clone();
    let cfg = EngineConfig {
        kind: EngineKind::Pard,
        target: "target-m".into(),
        draft: Some("pard-main".into()),
        // batch 1 keeps the demo deterministic: requests 1..3 are
        // still queued while request 0 decodes, so the cancel and the
        // expired deadline land before their rows ever start.
        batch: 1,
        k: 4,
        max_new: 12,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    };
    let mut server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, cfg)?;

    // Normal traffic…
    let a = server.submit(GenRequest::new(0, prompt.clone(), 12))?;
    let b = server.submit(GenRequest::new(1, prompt.clone(), 12))?;
    // …a request we immediately regret…
    let c = server.submit(GenRequest::new(2, prompt.clone(), 12))?;
    c.cancel();
    // …and one whose completion budget is already spent.
    let mut late = GenRequest::new(3, prompt, 12);
    late.deadline = Some(Duration::ZERO);
    let d = server.submit(late)?;

    for h in [a, b, c, d] {
        match h.recv()? {
            GenOutcome::Completed(r) => {
                println!("request {}: completed — {} tokens in {:.3}s",
                         r.id, r.tokens.len(), r.latency_s);
            }
            GenOutcome::Rejected { id, reason } => {
                println!("request {id}: rejected — {reason}");
            }
            GenOutcome::Cancelled { id } => {
                println!("request {id}: cancelled");
            }
            GenOutcome::DeadlineExceeded { id } => {
                println!("request {id}: deadline exceeded");
            }
            GenOutcome::Failed { id, reason } => {
                println!("request {id}: failed — {reason}");
            }
        }
    }

    let m = server.metrics()?;
    println!("metrics: cancelled={} deadline_exceeded={}",
             m.cancelled, m.deadline_exceeded);
    server.shutdown()?;
    Ok(())
}
