//! Regenerates paper Table 1 (AR/AR+/VSD/PARD × tasks on the large
//! targets) and reports per-engine end-to-end timing.
use std::path::Path;
use pard::report::{table1, RunScale};
use pard::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    let scale = if std::env::var("PARD_FULL").is_ok() {
        RunScale::full()
    } else {
        RunScale::quick()
    };
    let t0 = std::time::Instant::now();
    table1(&rt, scale)?.print();
    println!("\n[bench table1] wall {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
