//! Regenerates paper Fig 1a (per-position acceptance by method) and
//! Fig 1b (draft vs verify wall-clock, VSD vs PARD).
use std::path::Path;
use pard::report::{fig1a, fig1b, RunScale};
use pard::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    fig1a(&rt, RunScale::quick())?.print();
    fig1b(&rt, RunScale::quick())?.print();
    Ok(())
}
