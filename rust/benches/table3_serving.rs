//! Regenerates paper Table 3 (serving-engine comparison incl. EAGLE).
use std::path::Path;
use pard::report::{table3, RunScale};
use pard::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    let t0 = std::time::Instant::now();
    table3(&rt, RunScale::quick())?.print();
    println!("\n[bench table3] wall {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
