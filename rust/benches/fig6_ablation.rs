//! Regenerates paper Fig 6a (COD retention sweep), Fig 6b (K_train ×
//! K_infer), and the §4.3 mask-id ablation.  Needs `make ablation`.
use std::path::Path;
use pard::report::{fig6a, fig6b, mask_id_ablation, RunScale};
use pard::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    let scale = RunScale::quick();
    match fig6a(&rt, scale) {
        Ok(t) => t.print(),
        Err(e) => println!("fig6a skipped: {e}"),
    }
    match fig6b(&rt, scale) {
        Ok(t) => t.print(),
        Err(e) => println!("fig6b skipped: {e}"),
    }
    match mask_id_ablation(&rt, scale) {
        Ok(t) => t.print(),
        Err(e) => println!("mask ablation skipped: {e}"),
    }
    Ok(())
}
