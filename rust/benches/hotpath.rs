//! Hot-path micro-benchmarks (the DESIGN.md §Perf profiling surface):
//! individual fwd/commit costs per phase, PARD draft vs VSD draft
//! chain, verify, end-to-end engine iterations.
//!
//! Artifact-free by default: the backend is chosen by `PARD_BACKEND`
//! (`pjrt` | `reference` | `host`); unset, it uses PJRT when an
//! `artifacts/` directory exists and this build has the `pjrt`
//! feature, otherwise the fast host backend (DESIGN.md §8) — it never
//! panics just because artifacts are missing.  On the in-process
//! backends the same fwd micro-benchmarks also run on the scalar
//! reference oracle, printing the host-vs-oracle speedup per shape,
//! and on the host backend a second pass pins the worker pool to one
//! lane (`PARD_HOST_THREADS=1` equivalent) to show what the
//! column-granular pool dispatch buys per shape on this machine.

use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::coordinator::policy::PolicyCfg;
use pard::runtime::Backend;
use pard::substrate::bench::{BenchStats, Bencher};
use pard::Runtime;

/// Open the benchmark runtime per `PARD_BACKEND` / artifact presence.
fn open_runtime() -> anyhow::Result<Runtime> {
    let pick = std::env::var("PARD_BACKEND").unwrap_or_default();
    match pick.as_str() {
        "pjrt" => Runtime::load(std::path::Path::new("artifacts")),
        "reference" | "ref" => Ok(Runtime::reference(7)),
        "host" => Ok(Runtime::host(7)),
        "" => {
            if std::path::Path::new("artifacts").exists() {
                // Prefer measured artifacts, but a stub/partial tree
                // must not kill the bench — fall back to host.
                Runtime::load(std::path::Path::new("artifacts"))
                    .or_else(|_| Ok(Runtime::host(7)))
            } else {
                Ok(Runtime::host(7))
            }
        }
        other => anyhow::bail!(
            "PARD_BACKEND=`{other}` (want pjrt|reference|host)"),
    }
}

/// The raw-executable shapes every engine's inner loop touches.
fn fwd_shapes(b: &Bencher, rt: &Runtime, tag: &str)
              -> anyhow::Result<Vec<BenchStats>> {
    let target = rt.model("target-l")?;
    let draft = rt.model(&rt.manifest.main_pard)?;
    let tcache = target.new_cache(1)?;
    let dcache = draft.new_cache(1)?;
    target.warmup(1, &[1, 10, 16, 32])?;
    draft.warmup(1, &[1, 16])?;

    let mut all = Vec::new();
    let s = b.run(&format!("[{tag}] target-l fwd t=1 (AR+ step)"), || {
        target.fwd(1, 1, &[5], &[10], None, &tcache).unwrap()
    });
    s.print();
    all.push(s);
    let s = b.run(
        &format!("[{tag}] target-l fwd t=16 (verify K=8, pre-§Perf \
                  bucket)"),
        || {
            target
                .fwd(1, 16, &[5; 16], &(10..26).collect::<Vec<i32>>(),
                     None, &tcache)
                .unwrap()
        },
    );
    s.print();
    all.push(s);
    let s = b.run(
        &format!("[{tag}] target-l fwd t=10 (verify K=8, tightened \
                  bucket)"),
        || {
            target
                .fwd(1, 10, &[5; 10], &(10..20).collect::<Vec<i32>>(),
                     None, &tcache)
                .unwrap()
        },
    );
    s.print();
    all.push(s);
    let s = b.run(&format!("[{tag}] pard draft fwd t=16 (ONE parallel \
                            pass)"),
                  || {
        draft
            .fwd(1, 16, &[5; 16], &(10..26).collect::<Vec<i32>>(), None,
                 &dcache)
            .unwrap()
    });
    s.print();
    all.push(s);
    let s = b.run(
        &format!("[{tag}] draft fwd t=1 (one VSD chain step; VSD pays \
                  K of these)"),
        || draft.fwd(1, 1, &[5], &[10], None, &dcache).unwrap(),
    );
    s.print();
    all.push(s);
    let out = target.fwd(1, 1, &[5], &[10], None, &tcache)?;
    let mut c2 = target.new_cache(1)?;
    let s = b.run(&format!("[{tag}] target-l commit t=1"), || {
        target.commit(1, 1, &out, &[10], &mut c2).unwrap()
    });
    s.print();
    all.push(s);
    Ok(all)
}

fn main() -> anyhow::Result<()> {
    let rt = open_runtime()?;
    println!("backend: {}", rt.backend_label());
    if let Some(lanes) = rt.host_threads() {
        println!("host worker pool: {lanes} lane(s) \
                  (set PARD_HOST_THREADS to pin)");
    }
    let b = Bencher::default();

    let main_stats = fwd_shapes(&b, &rt, rt.backend_label())?;

    // On the artifact-free backends, rerun the same shapes on the
    // scalar oracle and report per-shape host speedup (the §Perf
    // baseline claim, continuously re-measured).
    if rt.backend_label() == "host" {
        let oracle = Runtime::reference(7);
        let oracle_stats = fwd_shapes(&b, &oracle, "reference")?;
        println!();
        for (h, o) in main_stats.iter().zip(&oracle_stats) {
            if h.median_s > 0.0 {
                println!("speedup {:<55} {:>6.2}x",
                         h.name.trim_start_matches("[host] "),
                         o.median_s / h.median_s);
            }
        }
    }

    // Pool scaling: the same shapes with the pool pinned to one lane.
    // Outputs are bit-identical either way (DESIGN.md §8); the ratio
    // is what the column-granular dispatch buys on this machine.
    if rt.backend_label() == "host" && rt.host_threads() != Some(1) {
        let single = Runtime::host_with_threads(7, Some(1));
        let single_stats = fwd_shapes(&b, &single, "host 1-lane")?;
        println!();
        for (h, s) in main_stats.iter().zip(&single_stats) {
            if h.median_s > 0.0 {
                println!("pool speedup {:<50} {:>6.2}x",
                         h.name.trim_start_matches("[host] "),
                         s.median_s / h.median_s);
            }
        }
    }

    // End-to-end iteration costs on the selected backend.
    for kind in [EngineKind::ArPlus, EngineKind::Vsd, EngineKind::Pard] {
        let cfg = EngineConfig {
            kind,
            target: "target-l".into(),
            draft: match kind {
                EngineKind::Pard => Some(rt.manifest.main_pard.clone()),
                EngineKind::Vsd => Some("draft-s".into()),
                _ => None,
            },
            batch: 1,
            k: 8,
            max_new: 32,
            shared_mask: true,
            kv_blocks: None,
            prefix_cache: false,
            sampling: None,
            policy: PolicyCfg::default(),
        };
        let mut engine = build_engine(&rt, &cfg)?;
        engine.warmup()?;
        let prompts: Vec<Vec<i32>> = rt
            .prompts("code")?
            .take(2)
            .into_iter()
            .map(|p| p.prompt)
            .collect();
        let s = b.run(&format!("e2e {} 2 prompts x 32 tok", kind.label()),
                      || generate(engine.as_mut(), &prompts, 32).unwrap());
        s.print();
    }
    Ok(())
}
