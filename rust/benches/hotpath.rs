//! Hot-path micro-benchmarks (the §Perf profiling surface): individual
//! fwd/commit costs per phase, PARD draft vs VSD draft chain, verify.
use std::path::Path;
use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::runtime::Backend;
use pard::substrate::bench::Bencher;
use pard::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    let b = Bencher::default();

    // raw executable costs
    let target = rt.model("target-l")?;
    let draft = rt.model(&rt.manifest.main_pard)?;
    let tcache = target.new_cache(1)?;
    let dcache = draft.new_cache(1)?;
    target.warmup(1, &[1, 10, 16, 32])?;
    draft.warmup(1, &[1, 16])?;

    let s = b.run("target-l fwd t=1 (AR+ step)", || {
        target.fwd(1, 1, &[5], &[10], None, &tcache).unwrap()
    });
    s.print();
    let s = b.run("target-l fwd t=16 (verify K=8, pre-§Perf bucket)", || {
        target
            .fwd(1, 16, &[5; 16], &(10..26).collect::<Vec<i32>>(), None,
                 &tcache)
            .unwrap()
    });
    s.print();
    let s = b.run("target-l fwd t=10 (verify K=8, tightened bucket)", || {
        target
            .fwd(1, 10, &[5; 10], &(10..20).collect::<Vec<i32>>(), None,
                 &tcache)
            .unwrap()
    });
    s.print();
    let s = b.run("pard draft fwd t=16 (ONE parallel pass)", || {
        draft
            .fwd(1, 16, &[5; 16], &(10..26).collect::<Vec<i32>>(), None,
                 &dcache)
            .unwrap()
    });
    s.print();
    let s = b.run("draft fwd t=1 (one VSD chain step; VSD pays K of these)",
                  || draft.fwd(1, 1, &[5], &[10], None, &dcache).unwrap());
    s.print();
    let out = target.fwd(1, 1, &[5], &[10], None, &tcache)?;
    let mut c2 = target.new_cache(1)?;
    let s = b.run("target-l commit t=1", || {
        target.commit(1, 1, &out, &[10], &mut c2).unwrap()
    });
    s.print();

    // end-to-end iteration costs
    for kind in [EngineKind::ArPlus, EngineKind::Vsd, EngineKind::Pard] {
        let cfg = EngineConfig {
            kind,
            target: "target-l".into(),
            draft: match kind {
                EngineKind::Pard => Some(rt.manifest.main_pard.clone()),
                EngineKind::Vsd => Some("draft-s".into()),
                _ => None,
            },
            batch: 1,
            k: 8,
            max_new: 32,
            shared_mask: true,
        };
        let mut engine = build_engine(&rt, &cfg)?;
        engine.warmup()?;
        let prompts: Vec<Vec<i32>> = rt
            .prompts("code")?
            .take(2)
            .into_iter()
            .map(|p| p.prompt)
            .collect();
        let s = b.run(&format!("e2e {} 2 prompts x 32 tok", kind.label()),
                      || generate(engine.as_mut(), &prompts, 32).unwrap());
        s.print();
    }
    Ok(())
}
