//! Regenerates paper Table 6 (draft-phase bandwidth: PARD flat in K,
//! EAGLE linear in K) — the paper-scale cost model plus MEASURED
//! bytes/token on the artifact-free host backends, f32 and int8.
//!
//! Three stages:
//!
//! 1. The paper-scale cost model (`table6()`), unchanged.
//! 2. Per-op weight bytes next to the per-op times: one PARD run per
//!    host backend (f32 `host`, quantized `host-q8`), printing each
//!    `fwd_ops` time bucket beside the weight bytes one forward pass
//!    streams through that bucket (`Backend::op_weight_bytes`) for the
//!    target and draft models.  This is the measured side of the
//!    bandwidth argument: where the time goes vs where the bytes go,
//!    and what q8 shrinks.
//! 3. The paper's shape, measured: PARD vs EAGLE draft-phase
//!    bytes/generated-token at K ∈ {2, 4, 8, 16} on both backends.
//!    PARD pays ONE draft pass per iteration regardless of K (flat);
//!    EAGLE chains K head passes (linear).  Bytes per pass come from
//!    the packed representation actually swept, so the q8 rows are
//!    ~4× below the f32 rows.
//!
//! Artifact-free: always runs the in-process host backends; no PJRT,
//! no Python.  `PARD_HOST_THREADS` pins the worker pool as usual.

use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::coordinator::policy::PolicyCfg;
use pard::coordinator::router::default_draft;
use pard::report::table6;
use pard::Runtime;

const TARGET: &str = "target-l";
const KS: [usize; 4] = [2, 4, 8, 16];

fn engine_cfg(rt: &Runtime, kind: EngineKind, k: usize)
              -> anyhow::Result<EngineConfig> {
    Ok(EngineConfig {
        kind,
        target: TARGET.into(),
        draft: default_draft(&rt.manifest, kind, TARGET)?,
        batch: 1,
        k,
        max_new: 16,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    })
}

/// Run one engine at one K over a small prompt set; return
/// (draft-phase weight bytes per generated token, generated tokens).
fn draft_bytes_per_token(rt: &Runtime, kind: EngineKind, k: usize)
                         -> anyhow::Result<(f64, u64)> {
    let cfg = engine_cfg(rt, kind, k)?;
    let draft_name = cfg.draft.clone().expect("speculative engines draft");
    let bytes_per_pass =
        rt.model(&draft_name)?.op_weight_bytes().total() as f64;
    let mut engine = build_engine(rt, &cfg)?;
    engine.warmup()?;
    let prompts: Vec<Vec<i32>> = rt
        .prompts("code")?
        .take(2)
        .into_iter()
        .map(|p| p.prompt)
        .collect();
    generate(engine.as_mut(), &prompts, cfg.max_new)?;
    let m = engine.metrics();
    let per_tok =
        m.draft_passes as f64 * bytes_per_pass / m.generated.max(1) as f64;
    Ok((per_tok, m.generated))
}

/// Per-op times beside per-op weight bytes for one PARD run.
fn ops_vs_bytes(rt: &Runtime) -> anyhow::Result<()> {
    let cfg = engine_cfg(rt, EngineKind::Pard, 8)?;
    let target = rt.model(TARGET)?;
    let draft = rt.model(cfg.draft.as_ref().unwrap())?;
    let (tw, dw) = (target.op_weight_bytes(), draft.op_weight_bytes());
    let mut engine = build_engine(rt, &cfg)?;
    engine.warmup()?;
    let prompts: Vec<Vec<i32>> = rt
        .prompts("code")?
        .take(2)
        .into_iter()
        .map(|p| p.prompt)
        .collect();
    generate(engine.as_mut(), &prompts, cfg.max_new)?;
    let ops = engine.metrics().fwd_ops;
    let mb = |b: usize| b as f64 / 1e6;
    println!("  [{}] PARD K=8: fwd_ops time vs weight bytes/pass \
              (target {TARGET} + draft)", rt.backend_label());
    println!("    {:<8} {:>10} {:>14} {:>14}",
             "op", "time (s)", "target (MB)", "draft (MB)");
    let rows: [(&str, f64, usize, usize); 6] = [
        ("gather", ops.gather_s, 0, 0),
        ("qkv", ops.qkv_s, tw.qkv, dw.qkv),
        ("attn", ops.attn_s, 0, 0),
        ("wo", ops.wo_s, tw.wo, dw.wo),
        ("mlp", ops.mlp_s, tw.mlp, dw.mlp),
        ("logits", ops.logits_s, tw.logits, dw.logits),
    ];
    for (name, t, tb, db) in rows {
        println!("    {name:<8} {t:>10.4} {:>14.3} {:>14.3}",
                 mb(tb), mb(db));
    }
    println!("    {:<8} {:>10.4} {:>14.3} {:>14.3}  (ops ≤ fwd_s: {})",
             "total", ops.total(), mb(tw.total()), mb(dw.total()),
             ops.total() <= engine.metrics().fwd_s + 1e-6);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // Paper-scale cost model (unchanged).
    table6().print();
    println!();

    let backends: [(&str, Runtime); 2] =
        [("host", Runtime::host(7)), ("host-q8", Runtime::host_q8(7))];

    // Per-op time vs per-op bytes, both representations.
    for (_, rt) in &backends {
        ops_vs_bytes(rt)?;
        println!();
    }

    // Measured PARD-flat vs EAGLE-linear draft bytes/token.
    println!("  draft-phase weight bytes per generated token \
              (measured, synthetic family)");
    println!("    {:<18} {}", "method",
             KS.map(|k| format!("{:>12}", format!("k={k}"))).join(""));
    for (label, rt) in &backends {
        for kind in [EngineKind::Pard, EngineKind::Eagle] {
            let mut cells = String::new();
            for k in KS {
                let (per_tok, _) = draft_bytes_per_token(rt, kind, k)?;
                cells.push_str(&format!("{:>12}",
                                        format!("{:.2} MB",
                                                per_tok / 1e6)));
            }
            println!("    {:<18} {cells}",
                     format!("{} {label}", kind.label()));
        }
    }
    println!("\n  PARD rows are flat in K (one parallel draft pass per \
              iteration); EAGLE rows grow with K (one head pass per \
              drafted token).  host-q8 rows stream ~4x fewer bytes.");
    Ok(())
}
