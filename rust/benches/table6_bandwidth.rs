//! Regenerates paper Table 6 (draft-phase bandwidth: PARD flat in k,
//! EAGLE linear) — cost-model at paper scale + measured pass counts.
use std::path::Path;
use pard::report::{table6, table6_measured, RunScale};
use pard::Runtime;

fn main() -> anyhow::Result<()> {
    table6().print();
    let rt = Runtime::load(Path::new("artifacts"))?;
    table6_measured(&rt, RunScale::quick())?.print();
    Ok(())
}
