//! Regenerates paper Table 7 (MI250X vs A100): measured acceptance ×
//! roofline device cost model.
use std::path::Path;
use pard::report::{table7, RunScale};
use pard::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    table7(&rt, RunScale::quick())?.print();
    Ok(())
}
