//! Regenerates paper Table 5 (k-α acceptance rates, PARD vs EAGLE/VSD).
use std::path::Path;
use pard::report::{table5, RunScale};
use pard::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    let t0 = std::time::Instant::now();
    table5(&rt, RunScale::quick())?.print();
    println!("\n[bench table5] wall {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
