//! Regenerates paper Table 4 (speedup vs batch size 1..16 — the
//! memory-bound → compute-bound crossover).
use std::path::Path;
use pard::report::{table4, RunScale};
use pard::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    let t0 = std::time::Instant::now();
    table4(&rt, RunScale { n_prompts: 8, max_new: 32 })?.print();
    println!("\n[bench table4] wall {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
