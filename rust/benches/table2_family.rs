//! Regenerates paper Table 2 / Fig 2 (target independence).
use std::path::Path;
use pard::report::{table2, RunScale};
use pard::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    let scale = if std::env::var("PARD_FULL").is_ok() {
        RunScale::full()
    } else {
        RunScale::quick()
    };
    let t0 = std::time::Instant::now();
    table2(&rt, scale)?.print();
    println!("\n[bench table2] wall {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
