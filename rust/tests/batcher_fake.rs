//! Continuous-batcher tests with a fake `Engine`: FCFS admission,
//! slot refill between iterations, occupancy accounting, window-based
//! throughput, and sane stats on a zero-request trace — all without
//! any model backend.

use anyhow::Result;
use pard::coordinator::batcher::serve_trace;
use pard::coordinator::engines::{Engine, EngineKind};
use pard::coordinator::metrics::Metrics;
use pard::coordinator::sequence::Sequence;
use pard::substrate::workload::{Request, Trace};

/// One token per active slot per step; requests identify themselves via
/// `prompt[0]` so admission order can be asserted.
struct FakeEngine {
    seqs: Vec<Sequence>,
    metrics: Metrics,
    admitted: Vec<i32>,
}

impl FakeEngine {
    fn new(batch: usize) -> Self {
        FakeEngine {
            seqs: vec![Sequence::default(); batch],
            metrics: Metrics::default(),
            admitted: Vec::new(),
        }
    }
}

impl Engine for FakeEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::ArPlus
    }

    fn batch(&self) -> usize {
        self.seqs.len()
    }

    fn admit(&mut self, slot: usize, prompt: &[i32], max_new: usize)
             -> Result<()> {
        self.admitted.push(prompt[0]);
        self.seqs[slot] = Sequence::start(prompt, max_new);
        Ok(())
    }

    fn step(&mut self) -> Result<()> {
        for seq in &mut self.seqs {
            if !seq.active || seq.done {
                continue;
            }
            let taken = seq.push_committed(&[42], -1);
            self.metrics.generated += taken as u64;
            if seq.done {
                seq.active = false;
                self.metrics.requests += 1;
            }
        }
        Ok(())
    }

    fn seqs(&self) -> &[Sequence] {
        &self.seqs
    }

    fn seqs_mut(&mut self) -> &mut [Sequence] {
        &mut self.seqs
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    fn warmup(&mut self) -> Result<()> {
        Ok(())
    }
}

fn closed_trace(n: usize, max_new: usize) -> Trace {
    Trace {
        requests: (0..n)
            .map(|i| Request {
                id: i as u64,
                arrival_s: 0.0,
                prompt: vec![i as i32, 7, 8],
                reference: Vec::new(),
                task: "t".into(),
                max_new,
            })
            .collect(),
    }
}

#[test]
fn fcfs_admission_order() {
    let mut e = FakeEngine::new(2);
    let stats = serve_trace(&mut e, &closed_trace(5, 3)).unwrap();
    assert_eq!(stats.completed, 5);
    assert_eq!(e.admitted, vec![0, 1, 2, 3, 4],
               "queue must drain first-come-first-served");
}

#[test]
fn slot_refill_and_occupancy_accounting() {
    // 5 requests × 3 tokens on 2 slots: waves (0,1), (2,3), (4) →
    // 9 iterations, occupancy (2+2+2 + 2+2+2 + 1+1+1)/9 = 5/3.
    let mut e = FakeEngine::new(2);
    let stats = serve_trace(&mut e, &closed_trace(5, 3)).unwrap();
    assert_eq!(e.metrics.iterations, 9);
    assert_eq!(stats.generated, 15);
    assert!((stats.mean_occupancy - 5.0 / 3.0).abs() < 1e-9,
            "occupancy {}", stats.mean_occupancy);
}

#[test]
fn throughput_counts_only_this_window() {
    // An engine that already served an earlier trace must not have its
    // lifetime token count leak into this trace's throughput.
    let mut e = FakeEngine::new(2);
    e.metrics.generated = 1_000_000;
    let stats = serve_trace(&mut e, &closed_trace(4, 2)).unwrap();
    assert_eq!(stats.generated, 8, "window tokens, not lifetime");
    assert!(stats.wall_s > 0.0);
    let expect = stats.generated as f64 / stats.wall_s;
    assert!((stats.throughput_tps - expect).abs() < 1e-9);
}

#[test]
fn latency_includes_queueing_delay() {
    // All requests arrive at t=0 but only 1 slot exists: the later
    // request queues while the first runs, so its arrival-based latency
    // must be >= the first one's.
    let mut e = FakeEngine::new(1);
    let stats = serve_trace(&mut e, &closed_trace(2, 64)).unwrap();
    assert_eq!(stats.completed, 2);
    assert!(stats.latency_p95_s >= stats.latency_p50_s);
    // p95 (last finisher) covers both requests' serving time; the mean
    // would be identical only if queueing were dropped.
    assert!(stats.latency_mean_s < stats.latency_p95_s);
}

#[test]
fn zero_request_trace_yields_sane_stats() {
    let mut e = FakeEngine::new(2);
    let stats = serve_trace(&mut e, &Trace { requests: Vec::new() })
        .unwrap();
    assert_eq!(stats.completed, 0);
    assert_eq!(stats.generated, 0);
    for v in [stats.latency_mean_s, stats.latency_p50_s,
              stats.latency_p95_s, stats.throughput_tps,
              stats.mean_occupancy]
    {
        assert!(v.is_finite(), "stat must be finite, got {v}");
        assert_eq!(v, 0.0);
    }
}
