//! Continuous-batcher tests with a fake `Engine`: FCFS admission,
//! slot refill between iterations, occupancy accounting, window-based
//! throughput, memory-bounded admission backpressure, and sane stats
//! on a zero-request trace — all without any model backend.  Most
//! tests run on the VIRTUAL clock (`serve_trace_virtual`), so
//! latencies and stall counts are exact numbers, not sleep-dependent
//! approximations.

use anyhow::Result;
use pard::coordinator::batcher::{serve_trace, serve_trace_virtual};
use pard::coordinator::engines::{Engine, EngineKind};
use pard::coordinator::metrics::Metrics;
use pard::coordinator::sequence::Sequence;
use pard::substrate::workload::{Request, Trace};

/// One token per active slot per step; requests identify themselves via
/// `prompt[0]` so admission order can be asserted.  `pool_blocks`
/// simulates a paged KV pool: every admitted row holds
/// `blocks_per_row` until released (`None` = unbounded, the dense-era
/// default behavior).
struct FakeEngine {
    seqs: Vec<Sequence>,
    metrics: Metrics,
    admitted: Vec<i32>,
    pool_blocks: Option<usize>,
    blocks_per_row: usize,
    held: Vec<usize>,
}

impl FakeEngine {
    fn new(batch: usize) -> Self {
        FakeEngine {
            seqs: vec![Sequence::default(); batch],
            metrics: Metrics::default(),
            admitted: Vec::new(),
            pool_blocks: None,
            blocks_per_row: 0,
            held: vec![0; batch],
        }
    }

    /// Bounded-pool variant: `pool` blocks total, each admitted row
    /// holding `per_row` until released.
    fn with_pool(batch: usize, pool: usize, per_row: usize) -> Self {
        FakeEngine {
            pool_blocks: Some(pool),
            blocks_per_row: per_row,
            ..Self::new(batch)
        }
    }

    /// Bounded-pool variant where a request's block need is its PROMPT
    /// LENGTH (`blocks_per_row == 0` is the marker) — lets one trace
    /// mix differently sized requests.
    fn with_prompt_sized_pool(batch: usize, pool: usize) -> Self {
        FakeEngine { pool_blocks: Some(pool), ..Self::new(batch) }
    }

    fn need_of(&self, prompt_len: usize) -> usize {
        if self.blocks_per_row == 0 {
            prompt_len
        } else {
            self.blocks_per_row
        }
    }

    fn in_use(&self) -> usize {
        self.held.iter().sum()
    }
}

impl Engine for FakeEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::ArPlus
    }

    fn batch(&self) -> usize {
        self.seqs.len()
    }

    fn admit(&mut self, slot: usize, prompt: &[i32], max_new: usize)
             -> Result<()> {
        if let Some(pool) = self.pool_blocks {
            let need = self.need_of(prompt.len());
            anyhow::ensure!(
                self.in_use() - self.held[slot] + need <= pool,
                "fake pool exhausted"
            );
            self.held[slot] = need;
        }
        self.admitted.push(prompt[0]);
        self.seqs[slot] = Sequence::start(prompt, max_new);
        Ok(())
    }

    fn step(&mut self) -> Result<()> {
        for seq in &mut self.seqs {
            if !seq.active || seq.done {
                continue;
            }
            let taken = seq.push_committed(&[42], -1);
            self.metrics.generated += taken as u64;
            if seq.done {
                seq.active = false;
                self.metrics.requests += 1;
            }
        }
        Ok(())
    }

    fn can_admit(&self, prompt: &[i32], _max_new: usize) -> bool {
        match self.pool_blocks {
            Some(pool) => {
                self.in_use() + self.need_of(prompt.len()) <= pool
            }
            None => true,
        }
    }

    fn release(&mut self, slot: usize) {
        self.held[slot] = 0;
    }

    fn seqs(&self) -> &[Sequence] {
        &self.seqs
    }

    fn seqs_mut(&mut self) -> &mut [Sequence] {
        &mut self.seqs
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    fn warmup(&mut self) -> Result<()> {
        Ok(())
    }
}

fn closed_trace(n: usize, max_new: usize) -> Trace {
    Trace {
        requests: (0..n)
            .map(|i| Request {
                id: i as u64,
                arrival_s: 0.0,
                prompt: vec![i as i32, 7, 8],
                reference: Vec::new(),
                task: "t".into(),
                max_new,
                deadline_s: None,
            })
            .collect(),
    }
}

#[test]
fn fcfs_admission_order() {
    let mut e = FakeEngine::new(2);
    let stats = serve_trace_virtual(&mut e, &closed_trace(5, 3), 1.0)
        .unwrap();
    assert_eq!(stats.completed, 5);
    assert_eq!(e.admitted, vec![0, 1, 2, 3, 4],
               "queue must drain first-come-first-served");
}

#[test]
fn slot_refill_and_occupancy_accounting() {
    // 5 requests × 3 tokens on 2 slots: waves (0,1), (2,3), (4) →
    // 9 iterations, occupancy (2+2+2 + 2+2+2 + 1+1+1)/9 = 5/3.
    let mut e = FakeEngine::new(2);
    let stats = serve_trace_virtual(&mut e, &closed_trace(5, 3), 1.0)
        .unwrap();
    assert_eq!(e.metrics.iterations, 9);
    assert_eq!(stats.generated, 15);
    assert!((stats.mean_occupancy - 5.0 / 3.0).abs() < 1e-9,
            "occupancy {}", stats.mean_occupancy);
    assert_eq!(stats.peak_occupancy, 2);
    assert_eq!(stats.admission_stalls, 0);
}

#[test]
fn virtual_clock_latencies_are_exact() {
    // Each decode iteration costs exactly one virtual second, so every
    // latency is an integer: waves finish at t = 3, 6, 9.
    let mut e = FakeEngine::new(2);
    let stats = serve_trace_virtual(&mut e, &closed_trace(5, 3), 1.0)
        .unwrap();
    assert_eq!(stats.wall_s, 9.0, "9 iterations × 1s tick");
    assert_eq!(stats.latency_p50_s, 6.0);
    assert_eq!(stats.latency_p95_s, 9.0);
    assert!((stats.latency_mean_s - 27.0 / 5.0).abs() < 1e-12);
    assert!((stats.throughput_tps - 15.0 / 9.0).abs() < 1e-12);
}

#[test]
fn virtual_clock_skips_idle_gaps_deterministically() {
    // One request arrives late: the virtual clock jumps straight to
    // its arrival instead of sleeping, so the run is exact.
    let mut requests = closed_trace(1, 2).requests;
    requests[0].arrival_s = 5.0;
    let mut e = FakeEngine::new(1);
    let stats =
        serve_trace_virtual(&mut e, &Trace { requests }, 1.0).unwrap();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.wall_s, 7.0, "jump to t=5, then 2 iterations");
    assert_eq!(stats.latency_p50_s, 2.0, "latency excludes the gap");
}

#[test]
fn throughput_counts_only_this_window() {
    // An engine that already served an earlier trace must not have its
    // lifetime token count leak into this trace's throughput.  (Wall
    // clock: the one batcher path virtual mode doesn't exercise.)
    let mut e = FakeEngine::new(2);
    e.metrics.generated = 1_000_000;
    let stats = serve_trace(&mut e, &closed_trace(4, 2)).unwrap();
    assert_eq!(stats.generated, 8, "window tokens, not lifetime");
    assert!(stats.wall_s > 0.0);
    let expect = stats.generated as f64 / stats.wall_s;
    assert!((stats.throughput_tps - expect).abs() < 1e-9);
    // a wall-clock serve accrues wall_s, never virtual_s
    assert!(e.metrics.wall_s > 0.0);
    assert_eq!(e.metrics.virtual_s, 0.0);
}

#[test]
fn virtual_serve_never_pollutes_wall_clock_metrics() {
    // Regression: serve_trace_virtual used to add its SIMULATED
    // seconds into Metrics::wall_s, corrupting every tokens/s derived
    // from Metrics afterwards.  Virtual time must land in virtual_s.
    let mut e = FakeEngine::new(2);
    let stats = serve_trace_virtual(&mut e, &closed_trace(5, 3), 1.0)
        .unwrap();
    assert_eq!(stats.wall_s, 9.0, "ServeStats still report the window");
    assert_eq!(e.metrics.wall_s, 0.0,
               "virtual seconds must not enter wall_s");
    assert_eq!(e.metrics.virtual_s, 9.0);
    assert_eq!(e.metrics.tps(), 0.0,
               "no wall time observed -> no wall tokens/s claim");
}

#[test]
fn latency_includes_queueing_delay() {
    // All requests arrive at t=0 but only 1 slot exists: the later
    // request queues while the first runs, so its arrival-based
    // latency covers both serving times — exactly, on the virtual
    // clock.
    let mut e = FakeEngine::new(1);
    let stats = serve_trace_virtual(&mut e, &closed_trace(2, 4), 1.0)
        .unwrap();
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.latency_p95_s, 8.0, "queued request waits 4s");
    assert_eq!(stats.latency_mean_s, 6.0, "(4 + 8) / 2");
}

#[test]
fn pool_backpressure_stalls_then_completes() {
    // 4 slots but a pool that fits only 2 rows: admission must wait
    // for releases, stall at least once, and still complete everything
    // FCFS.
    let mut e = FakeEngine::with_pool(4, 6, 3);
    let stats = serve_trace_virtual(&mut e, &closed_trace(6, 3), 1.0)
        .unwrap();
    assert_eq!(stats.completed, 6, "backpressure must not drop work");
    assert_eq!(e.admitted, vec![0, 1, 2, 3, 4, 5], "FCFS preserved");
    assert_eq!(stats.peak_occupancy, 2,
               "pool admits 2 concurrent rows, not 4");
    assert!(stats.admission_stalls > 0, "stalls must be visible");
    assert_eq!(e.metrics.admission_stalls, stats.admission_stalls,
               "stalls are mirrored into engine metrics");
    assert_eq!(e.in_use(), 0, "all blocks released at drain");
}

#[test]
fn stall_before_same_pass_release_is_not_fatal() {
    // Regression: slot 0 consults the gate (and stalls) BEFORE slot 1
    // is harvested in the same pass.  When that release empties the
    // engine, the batcher must re-check the head against the empty
    // pool and admit it next pass — not conclude it can never fit.
    // Pool 6, needs = prompt length: A=2, B=4 run together; C=5 fits
    // only an empty pool.
    let mk = |id: i32, plen: usize, max_new: usize| {
        let mut prompt = vec![9; plen];
        prompt[0] = id;
        Request {
            id: id as u64,
            arrival_s: 0.0,
            prompt,
            reference: Vec::new(),
            task: "t".into(),
            max_new,
            deadline_s: None,
        }
    };
    let trace = Trace {
        requests: vec![mk(0, 2, 2), mk(1, 4, 4), mk(2, 5, 2)],
    };
    let mut e = FakeEngine::with_prompt_sized_pool(2, 6);
    let stats = serve_trace_virtual(&mut e, &trace, 1.0).unwrap();
    assert_eq!(stats.completed, 3,
               "C must be admitted once the pool empties");
    assert_eq!(e.admitted, vec![0, 1, 2], "FCFS preserved");
    assert!(stats.admission_stalls > 0, "C did wait on blocks");
    assert_eq!(e.in_use(), 0);
}

#[test]
fn impossible_request_fails_loudly_instead_of_spinning() {
    // A per-row need larger than the whole pool can never be admitted:
    // the batcher must error out, not livelock.
    let mut e = FakeEngine::with_pool(2, 2, 3);
    let err = serve_trace_virtual(&mut e, &closed_trace(1, 2), 1.0)
        .unwrap_err();
    assert!(err.to_string().contains("KV blocks"), "{err}");
}

#[test]
fn zero_request_trace_yields_sane_stats() {
    let mut e = FakeEngine::new(2);
    let stats =
        serve_trace_virtual(&mut e, &Trace { requests: Vec::new() }, 1.0)
            .unwrap();
    assert_eq!(stats.completed, 0);
    assert_eq!(stats.generated, 0);
    assert_eq!(stats.peak_occupancy, 0);
    for v in [stats.latency_mean_s, stats.latency_p50_s,
              stats.latency_p95_s, stats.throughput_tps,
              stats.mean_occupancy]
    {
        assert!(v.is_finite(), "stat must be finite, got {v}");
        assert_eq!(v, 0.0);
    }
}
