//! Host-backend equivalence suite (DESIGN.md §8): the fast host
//! serving path must be *token-identical* to the scalar reference
//! oracle (DESIGN.md §6) for every engine, across K, batch size, and
//! worker-pool lane count — and, because it keeps the oracle's
//! per-cell reduction order, even bit-identical at the logits level.
//! Runs in plain `cargo test` with NO Python/XLA artifacts.

use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::coordinator::policy::PolicyCfg;
use pard::coordinator::router::default_draft;
use pard::runtime::Backend;
use pard::Runtime;

fn cfg(rt: &Runtime, kind: EngineKind, target: &str, k: usize,
       batch: usize) -> EngineConfig {
    EngineConfig {
        kind,
        target: target.to_string(),
        draft: default_draft(&rt.manifest, kind, target).unwrap(),
        batch,
        k,
        max_new: 20,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    }
}

fn gen(rt: &Runtime, c: &EngineConfig, prompts: &[Vec<i32>])
       -> Vec<Vec<i32>> {
    let mut e = build_engine(rt, c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), prompts, c.max_new).unwrap()
}

fn some_prompts(rt: &Runtime, n: usize) -> Vec<Vec<i32>> {
    rt.prompts("code")
        .unwrap()
        .take(n)
        .into_iter()
        .map(|p| p.prompt)
        .collect()
}

/// The satellite acceptance sweep: every engine's host-backend outputs
/// must equal the scalar oracle's, for K ∈ {2, 8} × batch ∈ {1, 4}.
#[test]
fn host_engines_token_identical_to_oracle_across_k_and_batch() {
    let oracle = Runtime::reference(7);
    let host = Runtime::host(7);
    let prompts = some_prompts(&oracle, 4);
    assert_eq!(prompts, some_prompts(&host, 4),
               "both backends must serve the same synthetic prompts");
    for kind in [EngineKind::ArPlus, EngineKind::Vsd, EngineKind::Pard,
                 EngineKind::Eagle] {
        for k in [2usize, 8] {
            for batch in [1usize, 4] {
                let a = gen(&oracle, &cfg(&oracle, kind, "target-l", k,
                                          batch), &prompts);
                let b = gen(&host, &cfg(&host, kind, "target-l", k,
                                        batch), &prompts);
                assert!(a.iter().all(|o| !o.is_empty()),
                        "oracle generated nothing");
                assert_eq!(
                    a, b,
                    "{kind:?} k={k} batch={batch}: host diverged from \
                     the scalar oracle"
                );
            }
        }
    }
}

/// Host AR+ equals host uncached AR — the cache machinery holds on the
/// fast path itself, not just relative to the oracle.
#[test]
fn host_cached_equals_host_uncached() {
    let host = Runtime::host(7);
    let prompts = some_prompts(&host, 3);
    let a = gen(&host, &cfg(&host, EngineKind::Ar, "target-m", 8, 1),
                &prompts);
    let b = gen(&host, &cfg(&host, EngineKind::ArPlus, "target-m", 8, 1),
                &prompts);
    assert_eq!(a, b, "host KV-cached decode must equal full recompute");
}

/// Bit-level check at the backend call surface: logits of a multi-token
/// call and of a cached decode step match the oracle exactly.
#[test]
fn host_logits_bit_identical_to_oracle() {
    let oracle = Runtime::reference(7);
    let host = Runtime::host(7);
    for name in ["draft-s", "target-m", "target-l"] {
        let mo = oracle.model(name).unwrap();
        let mh = host.model(name).unwrap();
        let mut co = mo.new_cache(2).unwrap();
        let mut ch = mh.new_cache(2).unwrap();
        let toks = [0i32, 13, 20, 21, 0, 30, 31, 32];
        let pos = [0i32, 1, 2, 3, 0, 1, 2, 3];
        let a = mo.fwd(2, 4, &toks, &pos, None, &co).unwrap();
        let b = mh.fwd(2, 4, &toks, &pos, None, &ch).unwrap();
        assert_eq!(a.logits, b.logits, "{name}: fwd logits diverged");
        mo.commit(2, 4, &a, &pos, &mut co).unwrap();
        mh.commit(2, 4, &b, &pos, &mut ch).unwrap();
        co.cur_len = vec![4, 4];
        ch.cur_len = vec![4, 4];
        let a = mo.fwd(2, 1, &[17, 19], &[4, 4], None, &co).unwrap();
        let b = mh.fwd(2, 1, &[17, 19], &[4, 4], None, &ch).unwrap();
        assert_eq!(a.logits, b.logits, "{name}: decode logits diverged");
    }
}

/// Host backend outputs must not depend on batch layout (the same
/// row-independence the oracle guarantees — here it also certifies the
/// scoped-thread row partition).
#[test]
fn host_batch_size_does_not_change_outputs() {
    let host = Runtime::host(7);
    let prompts = some_prompts(&host, 6);
    let base = gen(&host, &cfg(&host, EngineKind::Pard, "target-l", 8, 1),
                   &prompts);
    for bs in [2usize, 4] {
        let out = gen(&host,
                      &cfg(&host, EngineKind::Pard, "target-l", 8, bs),
                      &prompts);
        assert_eq!(base, out, "host PARD batch={bs} changed outputs");
    }
}

/// Continuous batching serves a trace on the host backend.
#[test]
fn host_continuous_batching_serves_trace() {
    use pard::coordinator::batcher::serve_trace;
    use pard::substrate::workload::{build_trace, Arrival};
    let host = Runtime::host(7);
    let ps = host.prompts("gsm").unwrap().prompts;
    let trace = build_trace(&ps, 9, Arrival::Closed, 16, 3);
    let c = cfg(&host, EngineKind::Pard, "target-m", 8, 4);
    let mut e = build_engine(&host, &c).unwrap();
    e.warmup().unwrap();
    let stats = serve_trace(e.as_mut(), &trace).unwrap();
    assert_eq!(stats.completed, 9, "all requests must complete");
    assert!(stats.generated > 0);
}

/// The serve thread opens a host runtime from its `RuntimeSpec`,
/// including a pinned worker-pool size.
#[test]
fn host_runtime_spec_opens() {
    use pard::runtime::RuntimeSpec;
    let rt = RuntimeSpec::Host { seed: 7, threads: None }.open().unwrap();
    assert!(rt.is_reference());
    assert_eq!(rt.backend_label(), "host");
    let m = rt.model("target-m").unwrap();
    assert_eq!(m.cfg().n_layers, 3);
    let pinned =
        RuntimeSpec::Host { seed: 7, threads: Some(2) }.open().unwrap();
    assert_eq!(pinned.host_threads(), Some(2));
}

/// Satellite acceptance: one PARD decode with the pool pinned to 1, 2,
/// and 8 lanes must produce bit-identical logits and token streams —
/// the DESIGN.md §8 claim that the column partition decides only *who*
/// computes a cell, never the order within it.
#[test]
fn host_thread_count_invariance() {
    let oracle = Runtime::reference(7);
    let prompts = some_prompts(&oracle, 3);
    let oracle_streams = gen(
        &oracle, &cfg(&oracle, EngineKind::Pard, "target-l", 8, 1),
        &prompts);
    let fwd_toks = [0i32, 13, 20, 21, 33];
    let fwd_pos = [0i32, 1, 2, 3, 4];
    let mut base_logits: Option<Vec<f32>> = None;
    for threads in [1usize, 2, 8] {
        let host = Runtime::host_with_threads(7, Some(threads));
        assert_eq!(host.host_threads(), Some(threads));
        let streams = gen(
            &host, &cfg(&host, EngineKind::Pard, "target-l", 8, 1),
            &prompts);
        assert_eq!(oracle_streams, streams,
                   "{threads}-lane PARD token stream diverged");
        let m = host.model("target-l").unwrap();
        let cache = m.new_cache(1).unwrap();
        let out = m.fwd(1, 5, &fwd_toks, &fwd_pos, None, &cache).unwrap();
        match &base_logits {
            None => base_logits = Some(out.logits),
            Some(want) => assert_eq!(
                want, &out.logits,
                "{threads}-lane fwd logits diverged bit-wise"),
        }
    }
}

/// The q8 runtime opens through every front door — constructor,
/// RuntimeSpec, CLI label — and serves quantized models.
#[test]
fn host_q8_runtime_opens() {
    use pard::runtime::RuntimeSpec;
    let rt = Runtime::host_q8(7);
    assert!(rt.is_reference(), "q8 is artifact-free");
    assert_eq!(rt.backend_label(), "host-q8");
    let m = rt.model("target-m").unwrap();
    assert_eq!(m.cfg().n_layers, 3);
    assert!(m.op_weight_bytes().total() > 0,
            "q8 models account their weight traffic");
    let spec =
        RuntimeSpec::HostQ8 { seed: 7, threads: Some(2) }.open().unwrap();
    assert_eq!(spec.backend_label(), "host-q8");
    assert_eq!(spec.host_threads(), Some(2));
}

/// Greedy speculative decoding is lossless *relative to its own
/// target*: on the q8 backend, AR+ and every speculative engine must
/// emit identical token streams (the accept rule compares q8 draft
/// argmax against q8 target argmax — quantization shifts both the same
/// way).  This is q8's engine-level correctness gate, needing no f32
/// comparison at all.
#[test]
fn q8_engines_token_identical_to_q8_ar_plus() {
    let q8 = Runtime::host_q8(7);
    let prompts = some_prompts(&q8, 3);
    let base = gen(&q8, &cfg(&q8, EngineKind::ArPlus, "target-l", 8, 1),
                   &prompts);
    assert!(base.iter().all(|o| !o.is_empty()),
            "q8 AR+ generated nothing");
    for kind in [EngineKind::Vsd, EngineKind::Pard, EngineKind::Eagle] {
        let out = gen(&q8, &cfg(&q8, kind, "target-l", 8, 1), &prompts);
        assert_eq!(base, out,
                   "{kind:?} on host-q8 diverged from q8 AR+ (greedy \
                    speculative decoding must stay lossless)");
    }
}

/// q8 keeps the full §8 determinism contract against itself: pinned
/// 1/2/8-lane pools produce identical PARD token streams.
#[test]
fn q8_thread_count_invariance() {
    let prompts =
        some_prompts(&Runtime::host_q8(7), 2);
    let mut base: Option<Vec<Vec<i32>>> = None;
    for threads in [1usize, 2, 8] {
        let rt = Runtime::host_q8_with_threads(7, Some(threads));
        let streams = gen(
            &rt, &cfg(&rt, EngineKind::Pard, "target-l", 8, 1), &prompts);
        match &base {
            None => base = Some(streams),
            Some(want) => assert_eq!(
                want, &streams,
                "{threads}-lane q8 PARD token stream diverged"),
        }
    }
}

/// Satellite acceptance (fwd_ops audit): after a full run of EVERY
/// engine — including the prefill and EAGLE-chain call sites PR 7
/// added — the per-op time ledger stays bounded by the recorded fwd
/// time, with every matmul phase populated.
#[test]
fn fwd_ops_bounded_for_every_engine() {
    for rt in [Runtime::host(7), Runtime::host_q8(7)] {
        let prompts = some_prompts(&rt, 2);
        for kind in [EngineKind::Ar, EngineKind::ArPlus, EngineKind::Vsd,
                     EngineKind::Pard, EngineKind::Eagle] {
            let c = cfg(&rt, kind, "target-l", 4, 1);
            let mut e = build_engine(&rt, &c).unwrap();
            e.warmup().unwrap();
            generate(e.as_mut(), &prompts, c.max_new).unwrap();
            let m = e.metrics();
            assert!(m.fwd_ops.total() > 0.0,
                    "{kind:?} on {}: fwd_ops must be populated",
                    rt.backend_label());
            assert!(m.fwd_ops.total() <= m.fwd_s + 1e-6,
                    "{kind:?} on {}: fwd_ops {} exceeds fwd_s {}",
                    rt.backend_label(), m.fwd_ops.total(), m.fwd_s);
            assert!(m.fwd_ops.qkv_s > 0.0 && m.fwd_ops.mlp_s > 0.0
                    && m.fwd_ops.logits_s > 0.0,
                    "{kind:?}: matmul phases must all be attributed");
        }
    }
}

/// Satellite acceptance: the Metrics fwd/commit split is recorded and
/// coherent after an engine run — both sides nonzero, their sum inside
/// the end-to-end wall clock, and the host backend's per-op breakdown
/// populated and bounded by fwd_s.
#[test]
fn metrics_fwd_commit_split_recorded() {
    let host = Runtime::host(7);
    let prompts = some_prompts(&host, 2);
    let c = cfg(&host, EngineKind::Pard, "target-m", 4, 1);
    let mut e = build_engine(&host, &c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), &prompts, c.max_new).unwrap();
    let m = e.metrics();
    assert!(m.fwd_s > 0.0, "fwd_s must be recorded by the engines");
    assert!(m.commit_s > 0.0, "commit_s must be recorded by the engines");
    assert!(m.wall_s > 0.0, "generate() must clock the run");
    assert!(
        m.fwd_s + m.commit_s <= m.wall_s + 1e-9,
        "fwd ({}) + commit ({}) cannot exceed wall clock ({})",
        m.fwd_s, m.commit_s, m.wall_s
    );
    // host fwd instruments every phase of its forward pass
    assert!(m.fwd_ops.qkv_s > 0.0 && m.fwd_ops.attn_s > 0.0
            && m.fwd_ops.logits_s > 0.0,
            "host per-op breakdown must be populated: {:?}", m.fwd_ops);
    assert!(m.fwd_ops.total() <= m.fwd_s + 1e-9,
            "per-op breakdown cannot exceed fwd_s");
}
