//! End-to-end integration over the REAL artifacts: every engine drives
//! AOT-compiled PJRT executables.  Built only with the `pjrt` feature
//! and gated on `artifacts/manifest.json` (run `make artifacts` first);
//! the artifact-free equivalent lives in tests/engine_equivalence.rs.
#![cfg(feature = "pjrt")]
//!
//! The central assertion is the LOSSLESS property on the real stack:
//! VSD/PARD/EAGLE greedy outputs are token-identical to AR+ greedy
//! outputs, for every prompt, at any K and batch size.

use std::path::Path;

use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::coordinator::policy::PolicyCfg;
use pard::coordinator::router::default_draft;
use pard::Runtime;

fn runtime() -> Option<Runtime> {
    let p = Path::new("artifacts");
    if !p.join("manifest.json").exists() {
        eprintln!("skipping integration test: artifacts/ missing");
        return None;
    }
    Some(Runtime::load(p).expect("runtime loads"))
}

fn cfg(rt: &Runtime, kind: EngineKind, target: &str, k: usize,
       batch: usize) -> EngineConfig {
    EngineConfig {
        kind,
        target: target.to_string(),
        draft: default_draft(&rt.manifest, kind, target).unwrap(),
        batch,
        k,
        max_new: 32,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    }
}

fn gen(rt: &Runtime, c: &EngineConfig, prompts: &[Vec<i32>])
       -> Vec<Vec<i32>> {
    let mut e = build_engine(rt, c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), prompts, c.max_new).unwrap()
}

fn some_prompts(rt: &Runtime, n: usize) -> Vec<Vec<i32>> {
    rt.prompts("code")
        .unwrap()
        .take(n)
        .into_iter()
        .map(|p| p.prompt)
        .collect()
}

#[test]
fn lossless_vsd_pard_eagle_vs_ar_plus() {
    let Some(rt) = runtime() else { return };
    let prompts = some_prompts(&rt, 4);
    let base = gen(&rt, &cfg(&rt, EngineKind::ArPlus, "target-l", 8, 1),
                   &prompts);
    for kind in [EngineKind::Vsd, EngineKind::Pard, EngineKind::Eagle] {
        let out = gen(&rt, &cfg(&rt, kind, "target-l", 8, 1), &prompts);
        assert_eq!(base, out,
                   "{:?} must reproduce AR+ greedy outputs exactly", kind);
    }
}

#[test]
fn lossless_across_k() {
    let Some(rt) = runtime() else { return };
    let prompts = some_prompts(&rt, 2);
    let base = gen(&rt, &cfg(&rt, EngineKind::ArPlus, "target-m", 8, 1),
                   &prompts);
    for k in [1usize, 2, 4, 12, 16] {
        let out = gen(&rt, &cfg(&rt, EngineKind::Pard, "target-m", k, 1),
                      &prompts);
        assert_eq!(base, out, "PARD K={k} must stay lossless");
    }
}

#[test]
fn lossless_across_batch() {
    let Some(rt) = runtime() else { return };
    let prompts = some_prompts(&rt, 6);
    let base = gen(&rt, &cfg(&rt, EngineKind::ArPlus, "target-l", 8, 1),
                   &prompts);
    for bs in [2usize, 4] {
        let out = gen(&rt, &cfg(&rt, EngineKind::Pard, "target-l", 8, bs),
                      &prompts);
        assert_eq!(base, out, "batch={bs} must not change outputs");
    }
}

#[test]
fn uncached_ar_matches_cached_ar() {
    // The AR (full recompute) and AR+ (KV cached) paths are numerically
    // different computations of the SAME function — greedy outputs must
    // agree, which certifies the whole cache scatter/mask machinery.
    let Some(rt) = runtime() else { return };
    let prompts = some_prompts(&rt, 3);
    let a = gen(&rt, &cfg(&rt, EngineKind::Ar, "target-m", 8, 1),
                &prompts);
    let b = gen(&rt, &cfg(&rt, EngineKind::ArPlus, "target-m", 8, 1),
                &prompts);
    assert_eq!(a, b, "KV-cached decode must equal full recompute");
}

#[test]
fn slot_reuse_is_clean() {
    // Re-admitting a new prompt into a used slot must behave like a
    // fresh engine (stale cache content is unreachable by construction).
    let Some(rt) = runtime() else { return };
    let prompts = some_prompts(&rt, 5);
    let c = cfg(&rt, EngineKind::Pard, "target-m", 8, 1);
    // one engine, sequential slot reuse
    let reused = gen(&rt, &c, &prompts);
    // fresh engine per prompt
    for (i, p) in prompts.iter().enumerate() {
        let fresh = gen(&rt, &c, std::slice::from_ref(p));
        assert_eq!(fresh[0], reused[i], "slot reuse leaked state at {i}");
    }
}

#[test]
fn target_independence_one_draft_many_targets() {
    // The PARD draft must run against every family member without any
    // retraining — and stay lossless on each.
    let Some(rt) = runtime() else { return };
    let prompts = some_prompts(&rt, 2);
    for target in ["draft-s", "target-m", "target-l", "target-xl"] {
        let base = gen(&rt, &cfg(&rt, EngineKind::ArPlus, target, 8, 1),
                       &prompts);
        let out = gen(&rt, &cfg(&rt, EngineKind::Pard, target, 8, 1),
                      &prompts);
        assert_eq!(base, out, "PARD lossless on {target}");
    }
}

#[test]
fn acceptance_metrics_populated() {
    let Some(rt) = runtime() else { return };
    let prompts = some_prompts(&rt, 3);
    let c = cfg(&rt, EngineKind::Pard, "target-l", 8, 1);
    let mut e = build_engine(&rt, &c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), &prompts, 32).unwrap();
    let m = e.metrics();
    assert!(m.generated > 0);
    assert!(m.iterations > 0);
    assert!(m.k_alpha(1) > 0.2, "1-α suspiciously low: {}", m.k_alpha(1));
    assert!(m.tokens_per_iter() > 1.0,
            "speculation should beat 1 token/iter");
    assert!(m.draft_passes as f64 / m.iterations as f64 <= 1.01,
            "PARD must draft in ONE pass per iteration");
}

#[test]
fn vsd_pays_k_draft_passes() {
    let Some(rt) = runtime() else { return };
    let prompts = some_prompts(&rt, 2);
    let c = cfg(&rt, EngineKind::Vsd, "target-l", 8, 1);
    let mut e = build_engine(&rt, &c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), &prompts, 24).unwrap();
    let m = e.metrics();
    let passes_per_iter =
        m.draft_passes as f64 / m.iterations.max(1) as f64;
    assert!((passes_per_iter - 8.0).abs() < 0.01,
            "VSD drafts K passes/iter, got {passes_per_iter}");
}

#[test]
fn continuous_batching_serves_trace() {
    use pard::coordinator::batcher::serve_trace;
    use pard::substrate::workload::{build_trace, Arrival};
    let Some(rt) = runtime() else { return };
    let ps = rt.prompts("gsm").unwrap().prompts;
    let trace = build_trace(&ps, 9, Arrival::Closed, 24, 3);
    let c = cfg(&rt, EngineKind::Pard, "target-l", 8, 4);
    let mut e = build_engine(&rt, &c).unwrap();
    e.warmup().unwrap();
    let stats = serve_trace(e.as_mut(), &trace).unwrap();
    assert_eq!(stats.completed, 9, "all requests must complete");
    assert!(stats.throughput_tps > 0.0);
    assert!(stats.mean_occupancy > 1.0,
            "batcher should keep multiple slots busy");
}

#[test]
fn eos_and_max_new_respected() {
    let Some(rt) = runtime() else { return };
    let eos = rt.manifest.eos;
    let prompts = some_prompts(&rt, 4);
    let mut c = cfg(&rt, EngineKind::Pard, "target-m", 8, 1);
    c.max_new = 10;
    let outs = gen(&rt, &c, &prompts);
    for o in outs {
        let cut = o.iter().position(|&t| t == eos);
        match cut {
            Some(i) => assert!(i + 1 == o.len() && o.len() <= 10),
            None => assert!(o.len() <= 10),
        }
    }
}
