//! Adaptive speculation-policy tier (DESIGN.md §9) — runs in plain
//! `cargo test` with NO artifacts.
//!
//! Three layers, each deterministic:
//!
//! * **Pinned ≡ fixed-K.**  An adaptive controller pinned to
//!   `k_min == k_max == K` (dual mode off) must be TOKEN-IDENTICAL to
//!   the fixed-K policy for all five engines, greedy AND sampled: the
//!   plan collapses to the constant K, so buffers, T buckets, and
//!   per-sequence draw streams cannot diverge.  This certifies that
//!   threading per-row K vectors through every engine changed nothing
//!   when the policy asks for what fixed-K always did.
//! * **Controller invariants.**  The adaptive policy is a pure
//!   function of acceptance history: seed-deterministic, invariant to
//!   batch size (per-slot windows travel with the sequence), and
//!   randomized-checked through the in-repo `Cases` harness.
//! * **Strict win.**  On a mixed easy/hard trace under the
//!   work-costed virtual clock, adaptive K must strictly beat BOTH
//!   fixed K=2 and fixed K=16 on tokens/s.  Real accept dynamics are
//!   chaotic, so the gate drives the REAL `SpecPolicy` + REAL batcher
//!   + costed clock through a scripted-acceptance engine (easy rows
//!   accept everything, hard rows nothing) — provable, replayable,
//!   and mirrored line-for-line in `python/refsim/hostsim.py` so
//!   ci.sh gates the same numbers without a Rust toolchain.

use anyhow::Result;
use pard::coordinator::batcher::serve_trace_virtual_costed;
use pard::coordinator::engines::{build_engine, generate, Engine,
                                 EngineConfig, EngineKind, SamplingCfg};
use pard::coordinator::metrics::Metrics;
use pard::coordinator::policy::{PolicyCfg, SpecPolicy};
use pard::coordinator::router::default_draft;
use pard::coordinator::sequence::Sequence;
use pard::substrate::prompts::Prompt;
use pard::substrate::prop::Cases;
use pard::substrate::workload::{build_mixed_trace, Arrival, Trace};
use pard::Runtime;

fn rt() -> Runtime {
    Runtime::reference(7)
}

fn cfg(rt: &Runtime, kind: EngineKind, k: usize, batch: usize,
       sampling: Option<SamplingCfg>, policy: PolicyCfg)
       -> EngineConfig {
    EngineConfig {
        kind,
        target: "target-l".to_string(),
        draft: default_draft(&rt.manifest, kind, "target-l").unwrap(),
        batch,
        k,
        max_new: 16,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling,
        policy,
    }
}

fn gen(rt: &Runtime, c: &EngineConfig, prompts: &[Vec<i32>])
       -> Vec<Vec<i32>> {
    let mut e = build_engine(rt, c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), prompts, c.max_new).unwrap()
}

fn some_prompts(rt: &Runtime, n: usize) -> Vec<Vec<i32>> {
    rt.prompts("code")
        .unwrap()
        .take(n)
        .into_iter()
        .map(|p| p.prompt)
        .collect()
}

/// Pinned adaptive controller: `k_min == k_max == k`, dual mode off.
fn pinned(k: usize) -> PolicyCfg {
    PolicyCfg { adaptive: true, k_min: k, k_max: k,
                ..PolicyCfg::default() }
}

// ---------------------------------------------------------------------
// Pinned ≡ fixed-K, all five engines, greedy and sampled
// ---------------------------------------------------------------------

#[test]
fn pinned_adaptive_is_token_identical_to_fixed_k_all_engines() {
    let rt = rt();
    let prompts = some_prompts(&rt, 3);
    let samplings: [Option<SamplingCfg>; 2] = [
        None,
        Some(SamplingCfg { temperature: 0.9, top_p: 0.95, seed: 5 }),
    ];
    for kind in [EngineKind::Ar, EngineKind::ArPlus, EngineKind::Vsd,
                 EngineKind::Pard, EngineKind::Eagle] {
        for sampling in &samplings {
            let fixed = gen(&rt,
                            &cfg(&rt, kind, 4, 2, *sampling,
                                 PolicyCfg::default()),
                            &prompts);
            let pin = gen(&rt,
                          &cfg(&rt, kind, 4, 2, *sampling, pinned(4)),
                          &prompts);
            assert_eq!(fixed, pin,
                       "{kind:?} pinned adaptive (k_min==k_max==4) \
                        must equal fixed K=4 (sampling {sampling:?})");
        }
    }
}

// ---------------------------------------------------------------------
// Adaptive controller invariants on real engines
// ---------------------------------------------------------------------

fn adaptive_cfg() -> PolicyCfg {
    PolicyCfg { adaptive: true, k_min: 1, k_max: 8, window: 4,
                dual_mode_occupancy: None }
}

#[test]
fn adaptive_is_seed_deterministic_and_batch_invariant() {
    let rt = rt();
    let prompts = some_prompts(&rt, 5);
    let samplings: [Option<SamplingCfg>; 2] = [
        None,
        Some(SamplingCfg { temperature: 0.8, top_p: 0.9, seed: 11 }),
    ];
    for sampling in &samplings {
        let base = gen(&rt,
                       &cfg(&rt, EngineKind::Pard, 4, 1, *sampling,
                            adaptive_cfg()),
                       &prompts);
        // same run twice: bit-for-bit (no wall clock in the policy)
        let again = gen(&rt,
                        &cfg(&rt, EngineKind::Pard, 4, 1, *sampling,
                             adaptive_cfg()),
                        &prompts);
        assert_eq!(base, again, "adaptive runs must replay exactly");
        // batch-size invariance: per-slot windows travel with the
        // sequence (cleared at admit), so K trajectories — and
        // therefore outputs — only depend on the sequence itself.
        // (Dual mode is off: occupancy IS batch-dependent.)
        for batch in [2usize, 4] {
            let out = gen(&rt,
                          &cfg(&rt, EngineKind::Pard, 4, batch,
                               *sampling, adaptive_cfg()),
                          &prompts);
            assert_eq!(base, out,
                       "adaptive output changed at batch {batch} \
                        (sampling {sampling:?})");
        }
    }
}

#[test]
fn randomized_policy_invariants() {
    // Pure-controller properties over random histories: bounds hold,
    // non-live rows plan 0, pinned collapses to fixed, replay is
    // exact.  The in-repo Cases harness prints the failing seed.
    Cases::new(128).check("policy-invariants", |rng| {
        let k_min = 1 + rng.below(8);
        let k_max = k_min + rng.below(17 - k_min);
        let window = 1 + rng.below(6);
        let k_init = 1 + rng.below(16);
        let batch = 1 + rng.below(4);
        let cfg = PolicyCfg { adaptive: true, k_min, k_max, window,
                              dual_mode_occupancy: None };
        let mut pol = SpecPolicy::new(&cfg, k_init, batch).unwrap();
        let mut fixed = SpecPolicy::new(&PolicyCfg::default(), k_init,
                                        batch).unwrap();
        let mut pin = SpecPolicy::new(
            &PolicyCfg { adaptive: true, k_min: k_init, k_max: k_init,
                         window, dual_mode_occupancy: None },
            k_init, batch).unwrap();
        let mut m = Metrics::default();
        type Step = (Vec<bool>, Vec<usize>, Vec<(usize, usize)>);
        let mut replay: Vec<Step> = Vec::new();
        for _ in 0..10 {
            let live: Vec<bool> =
                (0..batch).map(|_| rng.below(4) > 0).collect();
            let ks = pol.plan(&live, &mut m);
            for (slot, &k) in ks.iter().enumerate() {
                if live[slot] {
                    assert!(k >= k_min && k <= k_max,
                            "planned k {k} outside [{k_min},{k_max}]");
                } else {
                    assert_eq!(k, 0, "non-live slots must plan 0");
                }
            }
            // pinned == fixed for every live mask and any history
            assert_eq!(pin.plan(&live, &mut m),
                       fixed.plan(&live, &mut m),
                       "pinned adaptive must collapse to fixed");
            let mut obs = Vec::new();
            for (slot, &k) in ks.iter().enumerate() {
                if live[slot] && k > 0 {
                    let acc = rng.below(k + 1);
                    pol.on_acceptance(slot, k, acc);
                    obs.push((k, acc));
                } else {
                    obs.push((0, 0));
                }
            }
            replay.push((live, ks, obs));
        }
        // exact replay: the controller is a pure function of history
        let mut pol2 = SpecPolicy::new(&cfg, k_init, batch).unwrap();
        let mut m2 = Metrics::default();
        for (live, ks, obs) in &replay {
            assert_eq!(&pol2.plan(live, &mut m2), ks,
                       "same history must replan identically");
            for (slot, &(off, acc)) in obs.iter().enumerate() {
                pol2.on_acceptance(slot, off, acc);
            }
        }
        assert_eq!(pol.k_for_slot(0), pol2.k_for_slot(0),
                   "same history must yield the same K");
    });
}

// ---------------------------------------------------------------------
// Scripted-acceptance engine: the strict-win and dual-mode gates
// ---------------------------------------------------------------------

/// Token every scripted commit emits (never EOS).
const FILLER: i32 = 7;
const EOS: i32 = -1;
/// Work units per draft pass / per verify pass ("model sizes" of the
/// scripted pair: an 8x verify-to-draft cost ratio, Table 6 shape).
const DRAFT_UNITS: usize = 1;
const TARGET_UNITS: usize = 8;
/// Costed-clock rates: 1s of bandwidth per pass unit + 0.05s of
/// compute per column unit.
const PASS_S: f64 = 1.0;
const COL_S: f64 = 0.05;

/// A backend-free engine with SCRIPTED acceptance driving the real
/// `SpecPolicy`: rows admitted from an "easy" prompt (body is one
/// repeated token) accept every offered candidate, "hard" rows accept
/// none.  Work is charged exactly like a real draft/verify pair —
/// one draft pass over all planned columns (skipped when nobody
/// drafts), one verify pass over K+1 columns per live row — so the
/// costed clock prices over- and under-speculation the way DESIGN.md
/// §9 argues.  Admission charges nothing: the gates compare policies
/// on identical traces, so constant prefill cost would only dilute
/// the contrast.  Mirrored in python/refsim/hostsim.py.
struct ScriptedSpecEngine {
    batch: usize,
    seqs: Vec<Sequence>,
    easy: Vec<bool>,
    metrics: Metrics,
    policy: SpecPolicy,
}

impl ScriptedSpecEngine {
    fn new(batch: usize, policy: SpecPolicy) -> Self {
        ScriptedSpecEngine {
            batch,
            seqs: vec![Sequence::default(); batch],
            easy: vec![false; batch],
            metrics: Metrics::default(),
            policy,
        }
    }
}

impl Engine for ScriptedSpecEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Pard
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn admit(&mut self, slot: usize, prompt: &[i32], max_new: usize)
             -> Result<()> {
        self.easy[slot] =
            prompt[1..].windows(2).all(|w| w[0] == w[1]);
        self.policy.on_admit(slot);
        let mut seq = Sequence::start(prompt, max_new);
        // like every real engine, admission commits the first token
        let taken = seq.push_committed(&[FILLER], EOS);
        self.metrics.generated += taken as u64;
        self.seqs[slot] = seq;
        Ok(())
    }

    fn step(&mut self) -> Result<()> {
        let live: Vec<bool> = self
            .seqs
            .iter()
            .map(|s| s.active && !s.done)
            .collect();
        let ks = self.policy.plan(&live, &mut self.metrics);
        // draft: one pass over every planned candidate column
        let draft_cols: usize = ks.iter().sum();
        if draft_cols > 0 {
            self.metrics.record_work(DRAFT_UNITS, draft_cols);
            self.metrics.draft_passes += 1;
        }
        // verify: K+1 columns per live row (candidates + pending)
        let ver_cols: usize = live
            .iter()
            .zip(&ks)
            .filter(|(l, _)| **l)
            .map(|(_, k)| k + 1)
            .sum();
        self.metrics.record_work(TARGET_UNITS, ver_cols);
        self.metrics.target_passes += 1;
        for row in 0..self.batch {
            if !live[row] {
                continue;
            }
            let offered = ks[row];
            let accepted = if self.easy[row] { offered } else { 0 };
            self.metrics.record_acceptance(offered, accepted);
            self.policy.on_acceptance(row, offered, accepted);
            let seq = &mut self.seqs[row];
            let taken =
                seq.push_committed(&vec![FILLER; accepted + 1], EOS);
            self.metrics.generated += taken as u64;
            if seq.done {
                self.metrics.requests += 1;
            }
        }
        Ok(())
    }

    fn seqs(&self) -> &[Sequence] {
        &self.seqs
    }

    fn seqs_mut(&mut self) -> &mut [Sequence] {
        &mut self.seqs
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    fn warmup(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Base prompts whose alphabet seeds the mixed trace — the same shape
/// `substrate::workload` tests use, so the trace (and its hostsim.py
/// mirror) needs no runtime.
fn base_prompts() -> Vec<Prompt> {
    (0..3)
        .map(|i| Prompt {
            task: "code".into(),
            prompt: vec![0, 12 + i],
            reference: vec![20, 1],
        })
        .collect()
}

fn serve_scripted(trace: &Trace, batch: usize, k_init: usize,
                  policy: &PolicyCfg)
                  -> (pard::coordinator::batcher::ServeStats, Metrics) {
    let pol = SpecPolicy::new(policy, k_init, batch).unwrap();
    let mut e = ScriptedSpecEngine::new(batch, pol);
    let stats =
        serve_trace_virtual_costed(&mut e, trace, PASS_S, COL_S)
            .unwrap();
    (stats, e.metrics)
}

#[test]
fn adaptive_strictly_beats_fixed_k2_and_k16_on_mixed_trace() {
    let trace = build_mixed_trace(&base_prompts(), 16, Arrival::Closed,
                                  32, 7);
    let adaptive = PolicyCfg { adaptive: true, k_min: 1, k_max: 16,
                               window: 4, dual_mode_occupancy: None };
    let (s2, _) = serve_scripted(&trace, 4, 2, &PolicyCfg::default());
    let (s16, _) = serve_scripted(&trace, 4, 16, &PolicyCfg::default());
    let (sa, ma) = serve_scripted(&trace, 4, 4, &adaptive);
    // identical service: every policy finishes the same work
    for s in [&s2, &s16, &sa] {
        assert_eq!(s.completed, 16, "all requests must complete");
        assert_eq!(s.generated, 16 * 32,
                   "tokens are policy-invariant; only time moves");
    }
    // THE gate: adaptive strictly faster than both fixed corners on
    // the work-costed clock (under-speculation loses on easy rows,
    // over-speculation loses on hard rows; adaptive tracks each).
    assert!(sa.throughput_tps > s2.throughput_tps,
            "adaptive {:.3} tok/s must beat fixed K=2 {:.3} tok/s",
            sa.throughput_tps, s2.throughput_tps);
    assert!(sa.throughput_tps > s16.throughput_tps,
            "adaptive {:.3} tok/s must beat fixed K=16 {:.3} tok/s",
            sa.throughput_tps, s16.throughput_tps);
    // the controller visited both regimes
    assert!(ma.k_hist.len() > 2,
            "adaptive must have planned K > 1: {:?}", ma.k_hist);
    // and the whole gate is replay-exact
    let (sa2, _) = serve_scripted(&trace, 4, 4, &adaptive);
    assert_eq!(sa.wall_s, sa2.wall_s, "costed serve must replay");
    assert_eq!(sa.throughput_tps, sa2.throughput_tps);
}

#[test]
fn dual_mode_degrades_to_ar_plus_and_switches_back() {
    // 13 requests over 4 slots: three full waves at occupancy 4
    // (>= 0.75 x 4 => dual mode, K=0 everywhere), then a final wave
    // of one (1 < 3 => drafting resumes) — so the run must switch
    // into dual mode once and back out once.
    let trace = build_mixed_trace(&base_prompts(), 13, Arrival::Closed,
                                  16, 7);
    let dual = PolicyCfg { adaptive: true, k_min: 1, k_max: 16,
                           window: 4,
                           dual_mode_occupancy: Some(0.75) };
    let (stats, m) = serve_scripted(&trace, 4, 4, &dual);
    assert_eq!(stats.completed, 13);
    assert_eq!(stats.generated, 13 * 16);
    assert_eq!(m.mode_switches, 2,
               "one switch into dual mode, one back out");
    assert!(m.dual_mode_iters > 0, "dual-mode iterations must count");
    assert!(m.k_hist.first().copied().unwrap_or(0) > 0,
            "dual mode plans K=0: {:?}", m.k_hist);
    // dual-mode steps commit exactly one token per live row (AR+),
    // so nothing is lost — only drafting stops while saturated.
    let no_dual = PolicyCfg { dual_mode_occupancy: None, ..dual };
    let (free, m2) = serve_scripted(&trace, 4, 4, &no_dual);
    assert_eq!(free.generated, stats.generated);
    assert_eq!(m2.mode_switches, 0,
               "without a threshold the mode never moves");
}
