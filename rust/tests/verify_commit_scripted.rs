//! Unit tests for the shared verification core (`verify_and_commit`,
//! `apply_verdict`) against a SCRIPTED backend — logits come from a
//! test script, not from any model, so these pin down the acceptance
//! arithmetic and the (tokens, pos, commit_pos) garbage-slot protocol
//! independent of PJRT and of the reference transformer.
//!
//! Covers both verdict paths: greedy prefix acceptance and the
//! stochastic accept/residual path (`VerifySpec::sampling` set), whose
//! scripted cases mirror the greedy ones — all-accept with a bonus
//! sample, first-reject with a residual resample, and the K=2 window
//! edge — plus a per-position acceptance-rate check against the
//! analytic expectation alpha = sum_x min(p(x), q(x)).

use std::cell::RefCell;
use std::collections::VecDeque;

use anyhow::Result;
use pard::coordinator::engines::{apply_verdict, verify_and_commit,
                                 RowVerdict, SamplingCfg, VerifySpec};
use pard::coordinator::metrics::Metrics;
use pard::coordinator::sampling::sample;
use pard::coordinator::sequence::Sequence;
use pard::runtime::{Backend, FwdOut, KvCache, KvStage, ModelCfg,
                    ModelKind};
use pard::substrate::rng::Rng;

const VOCAB: usize = 32;
const PAD: i32 = 2;
const EOS: i32 = 1;

fn cfg() -> ModelCfg {
    ModelCfg {
        name: "scripted".into(),
        vocab: VOCAB,
        d_model: 8,
        n_layers: 1,
        n_heads: 1,
        d_head: 4,
        d_ff: 16,
        s_max: 64,
    }
}

/// Backend whose fwd pops one "argmax plan" per call: a `[b*t]` vector
/// of token ids the logits row should argmax to.  Staged K/V carry a
/// per-column marker so commits can be traced into cache slots.
struct Scripted {
    cfg: ModelCfg,
    plans: RefCell<VecDeque<Vec<i32>>>,
}

impl Scripted {
    fn new(plans: Vec<Vec<i32>>) -> Self {
        Scripted { cfg: cfg(), plans: RefCell::new(plans.into()) }
    }
}

/// Marker written into the staged K for (row, col).
fn marker(row: usize, col: usize) -> f32 {
    (row * 1000 + col + 1) as f32
}

impl Backend for Scripted {
    fn cfg(&self) -> &ModelCfg {
        &self.cfg
    }

    fn kind(&self) -> ModelKind {
        ModelKind::Lm
    }

    fn n_params(&self) -> usize {
        0
    }

    fn pick_t(&self, _b: usize, t_needed: usize) -> Result<usize> {
        Ok(t_needed.max(1))
    }

    fn new_cache(&self, batch: usize) -> Result<KvCache> {
        Ok(KvCache::host(&self.cfg, batch))
    }

    fn fwd(&self, b: usize, t: usize, _tokens: &[i32], _pos: &[i32],
           _hidden_in: Option<&[f32]>, _cache: &KvCache)
           -> Result<FwdOut> {
        let plan = self
            .plans
            .borrow_mut()
            .pop_front()
            .ok_or_else(|| anyhow::anyhow!("script exhausted"))?;
        anyhow::ensure!(plan.len() == b * t,
                        "plan len {} != {b}x{t}", plan.len());
        let mut logits = vec![0f32; b * t * VOCAB];
        for (i, &tok) in plan.iter().enumerate() {
            logits[i * VOCAB + tok as usize] = 1.0;
        }
        let hd = self.cfg.n_heads * self.cfg.d_head;
        let mut k = vec![0f32; b * t * hd];
        for row in 0..b {
            for col in 0..t {
                let off = (row * t + col) * hd;
                k[off..off + hd].fill(marker(row, col));
            }
        }
        let v = k.iter().map(|x| x + 0.5).collect();
        Ok(FwdOut {
            logits,
            hidden: None,
            kv: KvStage::Host { k, v },
            elapsed_s: 0.0,
            ops: None,
        })
    }

    fn commit(&self, b: usize, t: usize, out: &FwdOut,
              commit_pos: &[i32], cache: &mut KvCache) -> Result<f64> {
        match &out.kv {
            KvStage::Host { k, v } => {
                cache.host_scatter(b, t, k, v, commit_pos)?;
            }
            #[cfg(feature = "pjrt")]
            KvStage::Pjrt { .. } => {
                anyhow::bail!("scripted backend stages host kv")
            }
        }
        Ok(0.0)
    }
}

/// A sequence mid-decode: prompt of `plen` tokens, first generated
/// token already pending (the state every engine is in when verifying).
fn mid_seq(plen: usize, pending: i32, max_new: usize) -> Sequence {
    let prompt: Vec<i32> = (0..plen as i32).map(|i| 12 + i).collect();
    let mut s = Sequence::start(&prompt, max_new);
    s.push_committed(&[pending], EOS);
    s.target_len = s.stream.len() - 1;
    s
}

/// Same, with a seeded per-row sampling stream (the state engines put
/// rows in under stochastic decoding).
fn mid_seq_rng(plen: usize, pending: i32, max_new: usize, stream: u64)
               -> Sequence {
    let mut s = mid_seq(plen, pending, max_new);
    s.rng = Some(Rng::new_stream(7, stream));
    s
}

fn greedy_spec(k: usize) -> VerifySpec<'static> {
    VerifySpec { k, pad: PAD, sampling: None, qdists: &[] }
}

fn stoch_spec(k: usize, temperature: f32, qdists: &[Vec<Vec<f32>>])
              -> VerifySpec<'_> {
    VerifySpec {
        k,
        pad: PAD,
        sampling: Some(SamplingCfg { temperature, top_p: 1.0, seed: 7 }),
        qdists,
    }
}

fn one_hot(tok: i32) -> Vec<f32> {
    let mut p = vec![0f32; VOCAB];
    p[tok as usize] = 1.0;
    p
}

#[test]
fn verify_accepts_longest_prefix_and_routes_rejects_to_garbage() {
    let k = 3;
    // row 0: cands [5,6,7], target preds [5,6,9,?] → accept 2, commit
    //        [5,6,9];  row 1: cands [4,4,4], preds [8,...] → accept 0.
    let plan = vec![5, 6, 9, 21, 8, 22, 23, 24];
    let be = Scripted::new(vec![plan]);
    let mut cache = be.new_cache(2).unwrap();
    let mut seqs =
        vec![mid_seq(4, 30, 16), mid_seq(4, 31, 16)];
    let base = seqs[0].target_len as i32; // == 4
    cache.cur_len[0] = base as u32;
    cache.cur_len[1] = base as u32;
    let cands = vec![vec![5, 6, 7], vec![4, 4, 4]];
    let mut m = Metrics::default();
    let verdicts = verify_and_commit(&be, &mut cache, &mut seqs, &cands,
                                     &greedy_spec(k), &mut m)
        .unwrap();

    let v0 = verdicts[0].as_ref().unwrap();
    assert_eq!(v0.accepted, 2);
    assert_eq!(v0.committed, vec![5, 6, 9]);
    let v1 = verdicts[1].as_ref().unwrap();
    assert_eq!(v1.accepted, 0);
    assert_eq!(v1.committed, vec![8]);

    // acceptance accounting: both rows offered 3
    assert_eq!(m.offered_pos, vec![2, 2, 2]);
    assert_eq!(m.accept_pos, vec![1, 1, 0]);
    assert_eq!(m.target_passes, 1);

    // cache protocol: pending + accepted columns landed at their true
    // slots; rejected columns went to the garbage slot.
    let g = cache.garbage_slot() as usize;
    let b = base as usize;
    // row 0: col0 (pending) at slot 4, cols 1,2 (accepted) at 5,6
    assert_eq!(cache.host_kv(0, 0, 0, b).unwrap()[0], marker(0, 0));
    assert_eq!(cache.host_kv(0, 0, 0, b + 1).unwrap()[0], marker(0, 1));
    assert_eq!(cache.host_kv(0, 0, 0, b + 2).unwrap()[0], marker(0, 2));
    // col 3 (rejected) went to garbage; slot b+3 untouched (zero)
    assert_eq!(cache.host_kv(0, 0, 0, b + 3).unwrap()[0], 0.0);
    assert_eq!(cache.host_kv(0, 0, 0, g).unwrap()[0], marker(0, 3));
    // row 1: only the pending column committed live
    assert_eq!(cache.host_kv(0, 0, 1, b).unwrap()[0], marker(1, 0));
    assert_eq!(cache.host_kv(0, 0, 1, b + 1).unwrap()[0], 0.0);
    assert_eq!(cache.host_kv(0, 0, 1, g).unwrap()[0], marker(1, 3));
    // V plane mirrors K with the +0.5 marker
    assert_eq!(cache.host_kv(1, 0, 0, b).unwrap()[0],
               marker(0, 0) + 0.5);
}

#[test]
fn verify_skips_parked_rows() {
    let k = 2;
    let plan = vec![7, 8, 9, 0, 0, 0];
    let be = Scripted::new(vec![plan]);
    let mut cache = be.new_cache(2).unwrap();
    let mut seqs = vec![mid_seq(3, 20, 16), mid_seq(3, 20, 16)];
    seqs[1].active = false; // parked slot
    let cands = vec![vec![7, 8], vec![9, 9]];
    let mut m = Metrics::default();
    let verdicts = verify_and_commit(&be, &mut cache, &mut seqs, &cands,
                                     &greedy_spec(k), &mut m)
        .unwrap();
    assert!(verdicts[0].is_some());
    assert!(verdicts[1].is_none(), "parked row must yield no verdict");
    // Parked rows own NO storage under the paged cache: their garbage
    // writes are dropped, so nothing is mapped — not even the slot the
    // live row committed at.
    let base = seqs[1].target_len as usize;
    assert!(cache.host_kv(0, 0, 1, base).is_none(),
            "parked row must not allocate blocks");
    assert!(cache.host_kv(0, 0, 1, cache.garbage_slot() as usize)
                .is_none(),
            "parked row must not allocate a garbage block");
}

#[test]
fn full_accept_commits_k_plus_one() {
    let k = 3;
    let plan = vec![5, 6, 7, 9];
    let be = Scripted::new(vec![plan]);
    let mut cache = be.new_cache(1).unwrap();
    let mut seqs = vec![mid_seq(4, 30, 16)];
    let cands = vec![vec![5, 6, 7]];
    let mut m = Metrics::default();
    let v = verify_and_commit(&be, &mut cache, &mut seqs, &cands,
                              &greedy_spec(k), &mut m)
        .unwrap();
    let v0 = v[0].as_ref().unwrap();
    assert_eq!(v0.accepted, 3);
    assert_eq!(v0.committed, vec![5, 6, 7, 9]);
    assert_eq!(m.accept_hist, vec![0, 0, 0, 1]);
}

#[test]
fn stochastic_t0_all_accept_commits_bonus_sample() {
    // Temperature 0 turns every target row into an exact one-hot; with
    // one-hot draft distributions on matching candidates the accept
    // ratio is exactly 1.0, so the row fully accepts and commits a
    // bonus token sampled from the (one-hot) K-th target row —
    // deterministic regardless of the rng draws.
    let k = 3;
    let plan = vec![5, 6, 7, 9];
    let be = Scripted::new(vec![plan]);
    let mut cache = be.new_cache(1).unwrap();
    let mut seqs = vec![mid_seq_rng(4, 30, 16, 0)];
    let cands = vec![vec![5, 6, 7]];
    let q = vec![vec![one_hot(5), one_hot(6), one_hot(7)]];
    let mut m = Metrics::default();
    let v = verify_and_commit(&be, &mut cache, &mut seqs, &cands,
                              &stoch_spec(k, 0.0, &q), &mut m)
        .unwrap();
    let v0 = v[0].as_ref().unwrap();
    assert_eq!(v0.accepted, 3);
    assert_eq!(v0.committed, vec![5, 6, 7, 9]);
    assert_eq!(m.bonus_samples, 1);
    assert_eq!(m.residual_resamples, 0);
    assert_eq!(m.accept_hist, vec![0, 0, 0, 1]);
}

#[test]
fn stochastic_first_reject_commits_residual_resample() {
    // Candidate 5 was "drafted" from a one-hot at 5, but the target
    // one-hot sits at 8: accept probability p[5]/q[5] = 0, so the row
    // must reject and resample from the residual max(p-q, 0)⁺ — which
    // is the target one-hot, i.e. token 8, deterministically.
    let k = 1;
    let plan = vec![8, 21];
    let be = Scripted::new(vec![plan]);
    let mut cache = be.new_cache(1).unwrap();
    let mut seqs = vec![mid_seq_rng(4, 30, 16, 0)];
    let cands = vec![vec![5]];
    let q = vec![vec![one_hot(5)]];
    let mut m = Metrics::default();
    let v = verify_and_commit(&be, &mut cache, &mut seqs, &cands,
                              &stoch_spec(k, 0.0, &q), &mut m)
        .unwrap();
    let v0 = v[0].as_ref().unwrap();
    assert_eq!(v0.accepted, 0);
    assert_eq!(v0.committed, vec![8]);
    assert_eq!(m.residual_resamples, 1);
    assert_eq!(m.bonus_samples, 0);
}

#[test]
fn stochastic_k2_window_edge_mirrors_greedy_protocol() {
    // K=2 mirror of the greedy garbage-slot case: row 0 fully accepts
    // (both candidate columns commit live, bonus token appended);
    // row 1 rejects at position 0 (both candidate columns go to the
    // garbage slot, residual replaces the candidate).
    let k = 2;
    let plan = vec![5, 6, 9, 8, 22, 23];
    let be = Scripted::new(vec![plan]);
    let mut cache = be.new_cache(2).unwrap();
    let mut seqs =
        vec![mid_seq_rng(4, 30, 16, 0), mid_seq_rng(4, 31, 16, 1)];
    let base = seqs[0].target_len as i32; // == 4
    cache.cur_len[0] = base as u32;
    cache.cur_len[1] = base as u32;
    let cands = vec![vec![5, 6], vec![4, 4]];
    let q = vec![vec![one_hot(5), one_hot(6)],
                 vec![one_hot(4), one_hot(4)]];
    let mut m = Metrics::default();
    let verdicts = verify_and_commit(&be, &mut cache, &mut seqs, &cands,
                                     &stoch_spec(k, 0.0, &q), &mut m)
        .unwrap();

    let v0 = verdicts[0].as_ref().unwrap();
    assert_eq!(v0.accepted, 2);
    assert_eq!(v0.committed, vec![5, 6, 9]);
    let v1 = verdicts[1].as_ref().unwrap();
    assert_eq!(v1.accepted, 0);
    assert_eq!(v1.committed, vec![8]);
    assert_eq!(m.bonus_samples, 1);
    assert_eq!(m.residual_resamples, 1);
    assert_eq!(m.offered_pos, vec![2, 2]);
    assert_eq!(m.accept_pos, vec![1, 1]);

    // identical cache protocol to the greedy path: pending + accepted
    // columns live, rejected columns at the garbage slot.
    let g = cache.garbage_slot() as usize;
    let b = base as usize;
    assert_eq!(cache.host_kv(0, 0, 0, b).unwrap()[0], marker(0, 0));
    assert_eq!(cache.host_kv(0, 0, 0, b + 1).unwrap()[0], marker(0, 1));
    assert_eq!(cache.host_kv(0, 0, 0, b + 2).unwrap()[0], marker(0, 2));
    assert_eq!(cache.host_kv(0, 0, 1, b).unwrap()[0], marker(1, 0));
    assert_eq!(cache.host_kv(0, 0, 1, b + 1).unwrap()[0], 0.0);
    assert_eq!(cache.host_kv(0, 0, 1, g).unwrap()[0], marker(1, 2));
}

#[test]
fn stochastic_t0_verdicts_match_greedy_exactly() {
    // The same scripted plan through both verdict paths: at
    // temperature 0 the stochastic path must produce identical
    // (accepted, committed) per row — including the partial-accept
    // case, where the residual distribution collapses onto the target
    // argmax.
    let k = 3;
    let plan = vec![5, 6, 9, 21, 8, 22, 23, 24];
    let cands = vec![vec![5, 6, 7], vec![4, 4, 4]];

    let be_g = Scripted::new(vec![plan.clone()]);
    let mut cache_g = be_g.new_cache(2).unwrap();
    let mut seqs_g = vec![mid_seq(4, 30, 16), mid_seq(4, 31, 16)];
    let mut mg = Metrics::default();
    let vg = verify_and_commit(&be_g, &mut cache_g, &mut seqs_g, &cands,
                               &greedy_spec(k), &mut mg)
        .unwrap();

    let be_s = Scripted::new(vec![plan]);
    let mut cache_s = be_s.new_cache(2).unwrap();
    let mut seqs_s =
        vec![mid_seq_rng(4, 30, 16, 0), mid_seq_rng(4, 31, 16, 1)];
    let q: Vec<Vec<Vec<f32>>> = cands
        .iter()
        .map(|row| row.iter().map(|&c| one_hot(c)).collect())
        .collect();
    let mut ms = Metrics::default();
    let vs = verify_and_commit(&be_s, &mut cache_s, &mut seqs_s, &cands,
                               &stoch_spec(k, 0.0, &q), &mut ms)
        .unwrap();

    for (g, s) in vg.iter().zip(vs.iter()) {
        let (g, s) = (g.as_ref().unwrap(), s.as_ref().unwrap());
        assert_eq!(g.accepted, s.accepted);
        assert_eq!(g.committed, s.committed);
    }
    assert_eq!(mg.offered_pos, ms.offered_pos);
    assert_eq!(mg.accept_pos, ms.accept_pos);
}

#[test]
fn stochastic_acceptance_rate_matches_analytic_expectation() {
    // K=1 at temperature 1: the scripted target row is a one-peak
    // softmax over VOCAB=32 (logit 1 at the peak, 0 elsewhere), the
    // draft q is uniform.  With the candidate drawn from q, the accept
    // probability is alpha = sum_x min(p(x), q(x))
    //   = 1/32 + 31/(e+31)   (peak capped by q, tail capped by p)
    // and `Metrics::k_alpha(1)` over many trials must converge to it.
    // Fixed seeds make this exact-reproducible, not flaky.
    let trials = 4000;
    let uniform = vec![1.0f32 / VOCAB as f32; VOCAB];
    let mut crng = Rng::new_stream(123, 9); // candidate draws, x ~ q
    let mut m = Metrics::default();
    for trial in 0..trials {
        let peak = (trial % VOCAB) as i32;
        let plan = vec![peak, 0];
        let be = Scripted::new(vec![plan]);
        let mut cache = be.new_cache(1).unwrap();
        let mut seqs = vec![mid_seq_rng(4, 30, 16, trial as u64)];
        let cands = vec![vec![sample(&uniform, &mut crng)]];
        let q = vec![vec![uniform.clone()]];
        verify_and_commit(&be, &mut cache, &mut seqs, &cands,
                          &stoch_spec(1, 1.0, &q), &mut m)
            .unwrap();
    }
    let e = std::f64::consts::E;
    let alpha = 1.0 / 32.0 + 31.0 / (e + 31.0);
    let got = m.k_alpha(1);
    assert!((got - alpha).abs() < 0.02,
            "empirical acceptance {got:.4} vs analytic {alpha:.4}");
    // every trial ends in exactly one residual or bonus commit
    assert_eq!(m.residual_resamples + m.bonus_samples, trials as u64);
}

#[test]
fn apply_verdict_advances_stream_and_cache() {
    let be = Scripted::new(vec![]);
    let mut cache = be.new_cache(1).unwrap();
    let mut seq = mid_seq(4, 30, 16);
    let mut m = Metrics::default();
    let verdict = RowVerdict {
        accepted: 2,
        committed: vec![5, 6, 9],
        hidden_rows: None,
    };
    apply_verdict(&mut seq, &mut cache, 0, &verdict, 3, EOS, &mut m);
    // stream = prompt(4) + pending(30) + [5,6,9]; new pending is 9
    assert_eq!(seq.stream.len(), 8);
    assert_eq!(seq.pending(), 9);
    assert_eq!(seq.target_len, 7);
    assert_eq!(cache.cur_len[0], 7);
    assert_eq!(m.generated, 3);
    assert!(!seq.done);
    assert!(seq.active);
}

#[test]
fn apply_verdict_stops_on_eos_and_counts_request() {
    let be = Scripted::new(vec![]);
    let mut cache = be.new_cache(1).unwrap();
    let mut seq = mid_seq(4, 30, 16);
    let mut m = Metrics::default();
    let verdict = RowVerdict {
        accepted: 2,
        committed: vec![5, EOS, 9], // 9 must be dropped after EOS
        hidden_rows: None,
    };
    apply_verdict(&mut seq, &mut cache, 0, &verdict, 3, EOS, &mut m);
    assert!(seq.done);
    assert!(!seq.active);
    assert_eq!(m.requests, 1);
    assert_eq!(m.generated, 2, "token after EOS must not count");
    assert_eq!(*seq.stream.last().unwrap(), EOS);
}

#[test]
fn apply_verdict_headroom_guard_parks_near_capacity() {
    let be = Scripted::new(vec![]);
    let mut cache = be.new_cache(1).unwrap(); // s_max 64 → max live 62
    let mut seq = mid_seq(56, 30, 200);
    let mut m = Metrics::default();
    let verdict = RowVerdict {
        accepted: 0,
        committed: vec![9],
        hidden_rows: None,
    };
    apply_verdict(&mut seq, &mut cache, 0, &verdict, 3, EOS, &mut m);
    // target_len 57; 57 + 3 + 2 = 62 >= 62 → must stop the row
    assert!(seq.done, "row near cache capacity must be stopped");
    assert!(!seq.active);
    assert_eq!(m.requests, 1);
}

#[test]
fn headroom_guard_tracks_configured_k_not_a_hardcoded_worst_case() {
    // Regression: the guard used to hardcode `2*16 + 2` (worst-case
    // K) instead of the engine's configured `k + 2`, parking small-K
    // rows up to 30 positions before the window was actually full.
    let be = Scripted::new(vec![]);
    let mut cache = be.new_cache(1).unwrap(); // s_max 64 → max live 62
    let mut m = Metrics::default();
    let verdict = RowVerdict {
        accepted: 0,
        committed: vec![9],
        hidden_rows: None,
    };
    // target_len becomes 41: the old guard stopped here (41+34 >= 62)
    // even though a K=2 verify only ever reaches position 43.
    let mut seq = mid_seq(40, 30, 200);
    apply_verdict(&mut seq, &mut cache, 0, &verdict, 2, EOS, &mut m);
    assert!(!seq.done,
            "K=2 row with 21 free positions must keep generating");
    assert!(seq.active);
    assert_eq!(m.requests, 0);
    // at the true K=2 edge (58 + 2 + 2 >= 62) it must still stop
    let mut edge = mid_seq(57, 30, 200);
    apply_verdict(&mut edge, &mut cache, 0, &verdict, 2, EOS, &mut m);
    assert!(edge.done, "the guard must still fire at the real edge");
}
